//! NNtoP4: compile a BNN architecture into a PISA pipeline program.
//!
//! Follows Fig. 9's five logical steps per layer: (1) replicate the input
//! across per-neuron PHV lanes, (2) XNOR with constant weights (the
//! P4-NetFPGA port bakes weights as constants — §4.2 "we had to write the
//! weights as constant values in the MAU's operations code"), (3) popcount
//! via Algorithm 2's shift/mask/add tree, (4) mask-based SIGN, (5) fold
//! the resulting bits into packed fields for the next layer.
//!
//! The compiler enforces the PISA resource constraints that produce the
//! paper's scaling wall: a layer needing more parallel lane bits than the
//! PHV can hold fails to compile (§6.3: N3IC-P4 "could not scale" to
//! 128-neuron layers).

use crate::bnn::{BnnLayer, BnnModel};

use super::program::{Op, PisaProgram, Stage};

/// P4-NetFPGA pipeline clock (§6 Testbed: 200 MHz).
pub const PISA_CLOCK_HZ: f64 = 200e6;

/// Maximum PHV bits available for one layer's parallel neuron lanes.
/// Calibrated so 64-neuron × 256-bit layers compile and 128-neuron ones
/// do not (Fig. 17/18: "results for 128 neurons are missing").
pub const PHV_MAX_LANE_BITS: usize = 16_384;

/// Popcount tree masks/shifts (HAKMEM / Algorithm 2 over 32-bit words).
const POPCOUNT_LEVELS: [(u32, u32); 5] = [
    (0x5555_5555, 1),
    (0x3333_3333, 2),
    (0x0F0F_0F0F, 4),
    (0x00FF_00FF, 8),
    (0x0000_FFFF, 16),
];

/// Compilation failure modes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CompileError {
    /// Layer lanes exceed the PHV (the paper's scaling wall).
    PhvOverflow {
        layer: usize,
        needed_bits: usize,
        limit: usize,
    },
    /// Model failed structural validation.
    InvalidModel(String),
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompileError::PhvOverflow {
                layer,
                needed_bits,
                limit,
            } => write!(
                f,
                "layer {layer}: {needed_bits} PHV lane bits exceed the {limit}-bit PISA budget"
            ),
            CompileError::InvalidModel(m) => write!(f, "invalid model: {m}"),
        }
    }
}

impl std::error::Error for CompileError {}

/// Compile a whole BNN into one pipeline program.
pub fn compile_bnn(model: &BnnModel) -> Result<PisaProgram, CompileError> {
    model
        .validate()
        .map_err(|e| CompileError::InvalidModel(e.to_string()))?;
    // Constraint check first (the paper's Table 2 / §6.3 behaviour).
    for (k, layer) in model.layers.iter().enumerate() {
        let lane_bits = layer.neurons * layer.in_words * 32;
        if lane_bits > PHV_MAX_LANE_BITS {
            return Err(CompileError::PhvOverflow {
                layer: k,
                needed_bits: lane_bits,
                limit: PHV_MAX_LANE_BITS,
            });
        }
    }

    let mut b = Builder::new(model.in_words());
    let mut input_fields: Vec<usize> = (0..model.in_words()).collect();
    let n_layers = model.layers.len();
    for (k, layer) in model.layers.iter().enumerate() {
        let is_last = k == n_layers - 1;
        input_fields = b.emit_layer(layer, &input_fields, is_last, k);
    }
    Ok(b.finish(input_fields))
}

struct Builder {
    next_field: usize,
    in_words: usize,
    stages: Vec<Stage>,
}

impl Builder {
    fn new(in_words: usize) -> Self {
        Self {
            next_field: in_words,
            in_words,
            stages: Vec::new(),
        }
    }

    fn alloc(&mut self, n: usize) -> usize {
        let base = self.next_field;
        self.next_field += n;
        base
    }

    fn stage(&mut self, label: impl Into<String>) -> &mut Stage {
        self.stages.push(Stage {
            ops: Vec::new(),
            label: label.into(),
        });
        self.stages.last_mut().unwrap()
    }

    /// Emit one layer; returns the fields holding its output (packed words
    /// for hidden layers, raw scores for the last).
    fn emit_layer(
        &mut self,
        layer: &BnnLayer,
        input: &[usize],
        is_last: bool,
        k: usize,
    ) -> Vec<usize> {
        let n = layer.neurons;
        let iw = layer.in_words;
        let lanes = n * iw;
        // Lane fields: t (running popcount value), a/bb (tree scratch).
        let t0 = self.alloc(lanes);
        let a0 = self.alloc(lanes);
        let b0 = self.alloc(lanes);

        // Step 1+2 (Fig. 9): replicate + XNOR with constant weights.  The
        // replication is implicit in reading `input[j]` from every lane.
        let st = self.stage(format!("L{k}.xnor"));
        for neuron in 0..n {
            for j in 0..iw {
                st.ops.push(Op::XnorConst {
                    dst: t0 + neuron * iw + j,
                    a: input[j],
                    k: layer.row(neuron)[j],
                });
            }
        }

        // Step 3: Algorithm 2 popcount tree — 3 MAU stages per level.
        for (lvl, (mask, sh)) in POPCOUNT_LEVELS.iter().enumerate() {
            let st = self.stage(format!("L{k}.pop{lvl}.split"));
            for l in 0..lanes {
                st.ops.push(Op::AndConst {
                    dst: a0 + l,
                    a: t0 + l,
                    k: *mask,
                });
                st.ops.push(Op::Shr {
                    dst: b0 + l,
                    a: t0 + l,
                    sh: *sh,
                });
            }
            let st = self.stage(format!("L{k}.pop{lvl}.mask"));
            for l in 0..lanes {
                st.ops.push(Op::AndConst {
                    dst: b0 + l,
                    a: b0 + l,
                    k: *mask,
                });
            }
            let st = self.stage(format!("L{k}.pop{lvl}.add"));
            for l in 0..lanes {
                st.ops.push(Op::Add {
                    dst: t0 + l,
                    a: a0 + l,
                    b: b0 + l,
                });
            }
        }

        // Word-sum per neuron: pairwise reduction tree over the iw lanes.
        let mut stride = 1;
        while stride < iw {
            let st = self.stage(format!("L{k}.sum{stride}"));
            for neuron in 0..n {
                let mut j = 0;
                while j + stride < iw {
                    st.ops.push(Op::Add {
                        dst: t0 + neuron * iw + j,
                        a: t0 + neuron * iw + j,
                        b: t0 + neuron * iw + j + stride,
                    });
                    j += stride * 2;
                }
            }
            stride *= 2;
        }
        // Scores now live at t0 + neuron*iw.

        if is_last {
            // Copy scores to compact output fields.
            let out = self.alloc(n);
            let st = self.stage(format!("L{k}.out"));
            for neuron in 0..n {
                st.ops.push(Op::Copy {
                    dst: out + neuron,
                    a: t0 + neuron * iw,
                });
            }
            return (out..out + n).collect();
        }

        // Step 4: mask-based SIGN (no `if` in P4-SDNet MAU ops).
        let bits = self.alloc(n);
        let st = self.stage(format!("L{k}.sign"));
        for neuron in 0..n {
            st.ops.push(Op::GeConst {
                dst: bits + neuron,
                a: t0 + neuron * iw,
                k: layer.threshold as u32,
            });
        }

        // Step 5: fold bits into packed words: shift, then OR-reduce.
        let st = self.stage(format!("L{k}.shift"));
        for neuron in 0..n {
            st.ops.push(Op::Shl {
                dst: bits + neuron,
                a: bits + neuron,
                sh: (neuron % 32) as u32,
            });
        }
        let ow = layer.out_words();
        // OR-reduction tree within each 32-neuron group.
        let mut stride = 1;
        while stride < 32 {
            let st = self.stage(format!("L{k}.fold{stride}"));
            for w in 0..ow {
                let base = w * 32;
                let group = (n - base).min(32);
                let mut j = 0;
                while j + stride < group {
                    st.ops.push(Op::Or {
                        dst: bits + base + j,
                        a: bits + base + j,
                        b: bits + base + j + stride,
                    });
                    j += stride * 2;
                }
            }
            stride *= 2;
        }
        (0..ow).map(|w| bits + w * 32).collect()
    }

    fn finish(self, out_fields: Vec<usize>) -> PisaProgram {
        // Compact outputs are contiguous only for the last layer; record
        // base/count directly.
        let out_base = out_fields[0];
        let out_count = out_fields.len();
        debug_assert!(out_fields
            .iter()
            .enumerate()
            .all(|(i, &f)| f == out_base + i));
        PisaProgram {
            phv_fields: self.next_field,
            in_words: self.in_words,
            out_base,
            out_count,
            stages: self.stages,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::{infer_scores, BnnLayer, BnnModel};

    #[test]
    fn compiled_pipeline_bit_exact_traffic_net() {
        let model = BnnModel::random("traffic", 256, &[32, 16, 2], 11);
        let prog = compile_bnn(&model).unwrap();
        prog.check_stage_hazards().unwrap();
        for seed in 0..20 {
            let x = BnnLayer::random(1, 256, 1000 + seed).words;
            assert_eq!(prog.run(&x), infer_scores(&model, &x), "seed {seed}");
        }
    }

    #[test]
    fn compiled_pipeline_bit_exact_tomo32() {
        let model = BnnModel::random("tomo32", 152, &[32, 16, 2], 5);
        let prog = compile_bnn(&model).unwrap();
        for seed in 0..10 {
            let x = BnnLayer::random(1, 152, 2000 + seed).words;
            assert_eq!(prog.run(&x), infer_scores(&model, &x));
        }
    }

    #[test]
    fn single_fc_layers_up_to_64_compile() {
        for n in [32usize, 64] {
            let model = BnnModel::random("fc", 256, &[n], 1);
            assert!(compile_bnn(&model).is_ok(), "{n} neurons must compile");
        }
    }

    #[test]
    fn scaling_wall_at_128_neurons() {
        // §6.3: "results for 128 neurons are missing. As anticipated,
        // N3IC-P4 could not scale to handle such layers."
        let model = BnnModel::random("fc", 256, &[128], 1);
        match compile_bnn(&model) {
            Err(CompileError::PhvOverflow { needed_bits, .. }) => {
                assert_eq!(needed_bits, 128 * 256);
            }
            other => panic!("expected PHV overflow, got {other:?}"),
        }
    }

    #[test]
    fn tomography_128_rejected_tomo32_accepted() {
        // §6.2: "N3IC-P4 cannot scale to run such NN, and can only run the
        // smaller 32, 16, 2 neurons networks".
        let big = BnnModel::random("t128", 152, &[128, 64, 2], 1);
        assert!(compile_bnn(&big).is_err());
        let small = BnnModel::random("t32", 152, &[32, 16, 2], 1);
        assert!(compile_bnn(&small).is_ok());
    }

    #[test]
    fn latency_in_paper_band() {
        // Fig. 14/15: ~2 µs for the 32-16-2 nets.
        let model = BnnModel::random("traffic", 256, &[32, 16, 2], 3);
        let prog = compile_bnn(&model).unwrap();
        let lat = prog.latency_ns(64);
        assert!((800.0..3_500.0).contains(&lat), "lat={lat}ns");
    }
}

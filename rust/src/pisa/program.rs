//! PISA pipeline IR + interpreter.
//!
//! A program operates on a PHV (packet header vector) of 32-bit fields.
//! Stages execute in sequence; ops inside a stage execute in parallel
//! (reads see the previous stage's values), matching MAU semantics.
//! Only operations available to P4 targets are representable: bitwise
//! logic, shifts, integer add/sub, constants — no loops, no `if` (the
//! SIGN function is built from masks, §4.2).

/// A single ALU operation.  `dst`/`a`/`b` are PHV field indices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// dst = ~(a ^ const)
    XnorConst { dst: usize, a: usize, k: u32 },
    /// dst = a & const
    AndConst { dst: usize, a: usize, k: u32 },
    /// dst = a >> shift (logical)
    Shr { dst: usize, a: usize, sh: u32 },
    /// dst = a + b
    Add { dst: usize, a: usize, b: usize },
    /// dst = a + const
    AddConst { dst: usize, a: usize, k: u32 },
    /// dst = a - const
    SubConst { dst: usize, a: usize, k: u32 },
    /// dst = a | b
    Or { dst: usize, a: usize, b: usize },
    /// dst = (a << shift)
    Shl { dst: usize, a: usize, sh: u32 },
    /// dst = const
    Const { dst: usize, k: u32 },
    /// dst = a
    Copy { dst: usize, a: usize },
    /// Mask-based sign: dst = (a >= k) ? 1 : 0, computed as
    /// ((a - k) >> 31) ^ 1 on two's-complement fields (no branch).
    GeConst { dst: usize, a: usize, k: u32 },
}

impl Op {
    pub fn dst(&self) -> usize {
        match *self {
            Op::XnorConst { dst, .. }
            | Op::AndConst { dst, .. }
            | Op::Shr { dst, .. }
            | Op::Add { dst, .. }
            | Op::AddConst { dst, .. }
            | Op::SubConst { dst, .. }
            | Op::Or { dst, .. }
            | Op::Shl { dst, .. }
            | Op::Const { dst, .. }
            | Op::Copy { dst, .. }
            | Op::GeConst { dst, .. } => dst,
        }
    }
}

/// One logical pipeline stage (ops execute in parallel).
#[derive(Debug, Clone, Default)]
pub struct Stage {
    pub ops: Vec<Op>,
    pub label: String,
}

/// A compiled pipeline program.
#[derive(Debug, Clone)]
pub struct PisaProgram {
    /// Number of PHV fields (each 32 bits).
    pub phv_fields: usize,
    /// Input words are loaded into fields [0, in_words).
    pub in_words: usize,
    /// Output scores live in fields [out_base, out_base + out_count).
    pub out_base: usize,
    pub out_count: usize,
    pub stages: Vec<Stage>,
}

impl PisaProgram {
    /// Execute the pipeline on packed input words; returns output scores.
    ///
    /// MAU semantics: within a stage, all reads observe the PHV as left by
    /// the previous stage.
    pub fn run(&self, input: &[u32]) -> Vec<i32> {
        assert_eq!(input.len(), self.in_words, "input word count");
        let mut phv = vec![0u32; self.phv_fields];
        phv[..self.in_words].copy_from_slice(input);
        let mut next = phv.clone();
        for stage in &self.stages {
            for op in &stage.ops {
                let v = match *op {
                    Op::XnorConst { a, k, .. } => !(phv[a] ^ k),
                    Op::AndConst { a, k, .. } => phv[a] & k,
                    Op::Shr { a, sh, .. } => phv[a] >> sh,
                    Op::Add { a, b, .. } => phv[a].wrapping_add(phv[b]),
                    Op::AddConst { a, k, .. } => phv[a].wrapping_add(k),
                    Op::SubConst { a, k, .. } => phv[a].wrapping_sub(k),
                    Op::Or { a, b, .. } => phv[a] | phv[b],
                    Op::Shl { a, sh, .. } => phv[a] << sh,
                    Op::Const { k, .. } => k,
                    Op::Copy { a, .. } => phv[a],
                    Op::GeConst { a, k, .. } => {
                        // mask trick: sign bit of (a - k) as i32, inverted
                        ((((phv[a].wrapping_sub(k) as i32) >> 31) as u32) & 1) ^ 1
                    }
                };
                next[op.dst()] = v;
            }
            phv.copy_from_slice(&next);
        }
        (self.out_base..self.out_base + self.out_count)
            .map(|i| phv[i] as i32)
            .collect()
    }

    pub fn total_ops(&self) -> usize {
        self.stages.iter().map(|s| s.ops.len()).sum()
    }

    /// Pipeline latency at 200 MHz assuming `ops_per_mau` ops fused per
    /// MAU stage (P4-SDNet packs many ops per MAU, §4.2).
    pub fn latency_ns(&self, ops_per_mau: usize) -> f64 {
        let maus: usize = self
            .stages
            .iter()
            .map(|s| s.ops.len().div_ceil(ops_per_mau).max(1))
            .sum();
        maus as f64 * 5.0 * 2.0 // 2 cycles per MAU at 200 MHz
    }

    /// Initiation interval: fully pipelined, one packet per cycle per MAU
    /// — throughput is clock-bound (the paper's "very high throughput at
    /// the cost of limited scalability").
    pub fn throughput_per_sec(&self) -> f64 {
        super::compiler::PISA_CLOCK_HZ
    }

    /// Verify no op writes a field read by another op in the same stage
    /// with a different value semantics — i.e., SSA-per-stage sanity.
    pub fn check_stage_hazards(&self) -> Result<(), String> {
        for (i, stage) in self.stages.iter().enumerate() {
            let mut written = std::collections::HashSet::new();
            for op in &stage.ops {
                if !written.insert(op.dst()) {
                    return Err(format!(
                        "stage {i} ({}) writes field {} twice",
                        stage.label,
                        op.dst()
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ge_const_mask_trick() {
        let prog = PisaProgram {
            phv_fields: 2,
            in_words: 1,
            out_base: 1,
            out_count: 1,
            stages: vec![Stage {
                ops: vec![Op::GeConst { dst: 1, a: 0, k: 5 }],
                label: "sign".into(),
            }],
        };
        assert_eq!(prog.run(&[4])[0], 0);
        assert_eq!(prog.run(&[5])[0], 1);
        assert_eq!(prog.run(&[6])[0], 1);
        assert_eq!(prog.run(&[0])[0], 0);
    }

    #[test]
    fn stage_parallelism_reads_previous_values() {
        // swap two fields in one stage — only possible with MAU semantics.
        let prog = PisaProgram {
            phv_fields: 3,
            in_words: 2,
            out_base: 0,
            out_count: 2,
            stages: vec![Stage {
                ops: vec![Op::Copy { dst: 0, a: 1 }, Op::Copy { dst: 1, a: 0 }],
                label: "swap".into(),
            }],
        };
        assert_eq!(prog.run(&[7, 9]), vec![9, 7]);
    }

    #[test]
    fn hazard_detection() {
        let bad = PisaProgram {
            phv_fields: 2,
            in_words: 1,
            out_base: 0,
            out_count: 1,
            stages: vec![Stage {
                ops: vec![Op::Const { dst: 1, k: 1 }, Op::Const { dst: 1, k: 2 }],
                label: "dup".into(),
            }],
        };
        assert!(bad.check_stage_hazards().is_err());
    }
}

//! N3IC-P4 FPGA-resource accounting (Table 2 row 3).
//!
//! The P4-NetFPGA toolchain expands and unrolls the pipeline into the
//! FPGA fabric (§6.3), so LUT/BRAM cost scales with the *total unrolled
//! compute*: every weight bit becomes dedicated XNOR/popcount-tree logic.
//! Calibrated to Table 2: the traffic net (8,768 weight bits) costs
//! +95.1k LUTs and +324 BRAMs over the reference NIC.

use crate::bnn::BnnModel;

use crate::fpga::resources::{FpgaResources, REFERENCE_NIC_BRAM, REFERENCE_NIC_LUT};

/// LUTs per unrolled weight bit (XNOR + share of the popcount tree +
/// sign/fold logic).
pub const LUT_PER_WEIGHT_BIT: f64 = 10.8;
/// BRAMs per weight bit (MAU lookup-table structures the toolchain emits
/// even for constant weights).
pub const BRAM_PER_WEIGHT_BIT: f64 = 0.037;

/// Total weight bits across layers (padded widths — what gets unrolled).
pub fn unrolled_weight_bits(model: &BnnModel) -> usize {
    model
        .layers
        .iter()
        .map(|l| l.neurons * l.in_words * 32)
        .sum()
}

/// Resource usage of the full N3IC-P4 design for `model`.
#[derive(Debug, Clone, Copy)]
pub struct PisaResources {
    pub design: FpgaResources,
}

impl PisaResources {
    pub fn for_model(model: &BnnModel) -> Self {
        let bits = unrolled_weight_bits(model) as f64;
        Self {
            design: FpgaResources {
                lut: REFERENCE_NIC_LUT + (bits * LUT_PER_WEIGHT_BIT) as usize,
                bram: REFERENCE_NIC_BRAM + (bits * BRAM_PER_WEIGHT_BIT) as usize,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_n3ic_p4_row() {
        // Table 2: N3IC-P4 = 144.5k LUT (33.4%), 518 BRAM (35.2%).
        let model = BnnModel::random("traffic", 256, &[32, 16, 2], 1);
        let r = PisaResources::for_model(&model).design;
        assert!((138_000..152_000).contains(&r.lut), "lut={}", r.lut);
        assert!((490..545).contains(&r.bram), "bram={}", r.bram);
        assert!((32.0..35.0).contains(&r.lut_pct()), "{}", r.lut_pct());
    }

    #[test]
    fn p4_dwarfs_dedicated_module() {
        // §6.4: P4 uses "a large amount of NIC resources" vs the module.
        let model = BnnModel::random("traffic", 256, &[32, 16, 2], 1);
        let p4 = PisaResources::for_model(&model).design;
        let fpga = FpgaResources::n3ic_fpga(&model, 1);
        assert!(p4.lut > 2 * fpga.lut);
        assert!(p4.bram > 2 * fpga.bram);
    }
}

//! N3IC-P4: PISA match-action pipeline + the NNtoP4 compiler (§4.2).
//!
//! * [`program`] — the pipeline IR (PHV fields + per-stage ALU ops) and a
//!   bit-exact interpreter (stands in for bmv2 functional testing).
//! * [`compiler`] — **NNtoP4**: BNN architecture → pipeline program,
//!   using only P4-expressible operations: XNOR, the HAKMEM shift/mask/add
//!   popcount tree (Algorithm 2), mask-based SIGN (P4-SDNet has no `if`
//!   in MAU ops), and bit folding.
//! * [`p4gen`] — emits actual P4₁₆ source for the generated pipeline.
//! * [`resources`] — PHV width / stage / LUT accounting that reproduces
//!   the paper's scaling wall (128-neuron layers do not fit) and the
//!   Table 2 footprint.

pub mod bmv2;
pub mod compiler;
pub mod p4gen;
pub mod program;
pub mod resources;

pub use compiler::{compile_bnn, CompileError};
pub use program::{Op, PisaProgram, Stage};
pub use resources::PisaResources;

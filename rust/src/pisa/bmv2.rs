//! bmv2 backend for NNtoP4 (§4.2: "The compiler targets both a software
//! bmv2 switch and a P4 NIC").
//!
//! Emits the behavioral-model JSON configuration (the format
//! `simple_switch` consumes after p4c): header/metadata field declarations
//! plus one primitive-action sequence per pipeline stage.  Paired with the
//! in-crate interpreter (`program.rs`), which plays the role of
//! `simple_switch` for functional testing.

use crate::bnn::BnnModel;
use crate::json::{obj, Json};

use super::program::{Op, PisaProgram};

/// Render the compiled pipeline as a bmv2-style JSON config.
pub fn to_bmv2_json(model: &BnnModel, prog: &PisaProgram) -> Json {
    let fields: Vec<Json> = (0..prog.phv_fields)
        .map(|f| Json::Arr(vec![Json::Str(format!("f{f}")), Json::Num(32.0), Json::Bool(false)]))
        .collect();
    let mut actions = Vec::new();
    for (i, stage) in prog.stages.iter().enumerate() {
        let prims: Vec<Json> = stage.ops.iter().map(op_to_primitive).collect();
        actions.push(obj(vec![
            ("name", Json::Str(format!("stage_{i}_{}", stage.label))),
            ("id", Json::Num(i as f64)),
            ("primitives", Json::Arr(prims)),
        ]));
    }
    obj(vec![
        ("program", Json::Str(format!("nntop4_{}", model.name))),
        ("__meta__", obj(vec![
            ("arch", Json::Str(model.describe())),
            ("stages", Json::Num(prog.stages.len() as f64)),
            ("phv_fields", Json::Num(prog.phv_fields as f64)),
            ("in_words", Json::Num(prog.in_words as f64)),
            ("out_base", Json::Num(prog.out_base as f64)),
            ("out_count", Json::Num(prog.out_count as f64)),
        ])),
        ("header_types", Json::Arr(vec![obj(vec![
            ("name", Json::Str("metadata_t".into())),
            ("id", Json::Num(0.0)),
            ("fields", Json::Arr(fields)),
        ])])),
        ("actions", Json::Arr(actions)),
    ])
}

fn field(f: usize) -> Json {
    obj(vec![
        ("type", Json::Str("field".into())),
        ("value", Json::Arr(vec![Json::Str("meta".into()), Json::Str(format!("f{f}"))])),
    ])
}

fn hexconst(k: u32) -> Json {
    obj(vec![
        ("type", Json::Str("hexstr".into())),
        ("value", Json::Str(format!("0x{k:08x}"))),
    ])
}

fn prim(op: &str, params: Vec<Json>) -> Json {
    obj(vec![
        ("op", Json::Str(op.into())),
        ("parameters", Json::Arr(params)),
    ])
}

fn op_to_primitive(op: &Op) -> Json {
    match *op {
        // bmv2 has no xnor primitive; p4c lowers ~(a^b) to xor + not —
        // we emit the fused expression form the JSON supports.
        Op::XnorConst { dst, a, k } => prim("assign_xnor", vec![field(dst), field(a), hexconst(k)]),
        Op::AndConst { dst, a, k } => prim("bit_and", vec![field(dst), field(a), hexconst(k)]),
        Op::Shr { dst, a, sh } => prim("shift_right", vec![field(dst), field(a), hexconst(sh)]),
        Op::Shl { dst, a, sh } => prim("shift_left", vec![field(dst), field(a), hexconst(sh)]),
        Op::Add { dst, a, b } => prim("add", vec![field(dst), field(a), field(b)]),
        Op::AddConst { dst, a, k } => prim("add", vec![field(dst), field(a), hexconst(k)]),
        Op::SubConst { dst, a, k } => prim("subtract", vec![field(dst), field(a), hexconst(k)]),
        Op::Or { dst, a, b } => prim("bit_or", vec![field(dst), field(a), field(b)]),
        Op::Const { dst, k } => prim("assign", vec![field(dst), hexconst(k)]),
        Op::Copy { dst, a } => prim("assign", vec![field(dst), field(a)]),
        Op::GeConst { dst, a, k } => prim("assign_ge_mask", vec![field(dst), field(a), hexconst(k)]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pisa::compile_bnn;

    #[test]
    fn bmv2_config_structure() {
        let model = BnnModel::random("traffic", 256, &[32, 16, 2], 4);
        let prog = compile_bnn(&model).unwrap();
        let cfg = to_bmv2_json(&model, &prog);
        // Round-trips through our JSON layer.
        let text = cfg.dump();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.req_str("program").unwrap(), "nntop4_traffic");
        let meta = back.req("__meta__").unwrap();
        assert_eq!(meta.req_usize("stages").unwrap(), prog.stages.len());
        assert_eq!(meta.req_usize("phv_fields").unwrap(), prog.phv_fields);
        let actions = back.req_array("actions").unwrap();
        assert_eq!(actions.len(), prog.stages.len());
        // Every op became exactly one primitive.
        let prim_count: usize = actions
            .iter()
            .map(|a| a.req_array("primitives").unwrap().len())
            .sum();
        assert_eq!(prim_count, prog.total_ops());
    }

    #[test]
    fn header_fields_are_32_bit() {
        let model = BnnModel::random("m", 64, &[8, 2], 1);
        let prog = compile_bnn(&model).unwrap();
        let cfg = to_bmv2_json(&model, &prog);
        let hdr = &cfg.req_array("header_types").unwrap()[0];
        for f in hdr.req_array("fields").unwrap() {
            assert_eq!(f.as_array().unwrap()[1].as_usize().unwrap(), 32);
        }
    }
}

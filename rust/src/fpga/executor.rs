//! Cycle-accurate N3IC-FPGA NN-executor model.

use crate::bnn::{padded_bits, BnnExecutor, BnnModel};

/// FPGA clock: 200 MHz for both N3IC-FPGA and N3IC-P4 (§6 Testbed).
pub const CLOCK_HZ: f64 = 200e6;
pub const CYCLE_NS: f64 = 1e9 / CLOCK_HZ;

/// BRAM row width (§4.3: "tables ... with a width of 256b").
pub const BRAM_ROW_BITS: usize = 256;
/// Cycles per BRAM row read (§4.3: "Each row can be read in 2 clock
/// cycles").
pub const CYCLES_PER_ROW: usize = 2;
/// Pipeline depth of one layer block (§4.3: read/XNOR → LT popcount →
/// sum/sign).
pub const PIPELINE_STAGES: usize = 3;
/// Input load + output drain between inferences (module reuse overhead).
pub const SETUP_CYCLES: usize = 30;

/// Timing model of one NN-executor module for a fixed model.
#[derive(Debug, Clone)]
pub struct FpgaTiming {
    /// BRAM rows per layer (weights packed: multiple narrow neurons per
    /// row, or one row per wide neuron).
    pub rows_per_layer: Vec<usize>,
    pub total_rows: usize,
    pub latency_cycles: usize,
}

impl FpgaTiming {
    pub fn new(model: &BnnModel) -> Self {
        let mut rows_per_layer = Vec::new();
        let mut cycles = 0usize;
        for layer in &model.layers {
            let in_bits = layer.in_words * 32;
            let rows = rows_for(layer.neurons, in_bits);
            cycles += rows * CYCLES_PER_ROW + PIPELINE_STAGES;
            rows_per_layer.push(rows);
        }
        let total_rows = rows_per_layer.iter().sum();
        Self {
            rows_per_layer,
            total_rows,
            latency_cycles: cycles,
        }
    }

    /// Inference latency (ns) — Fig. 18/28.
    pub fn latency_ns(&self) -> f64 {
        self.latency_cycles as f64 * CYCLE_NS
    }

    /// Per-module throughput (inferences/s) — Fig. 17/27: one inference
    /// in flight per module (the design computes neurons serially in a
    /// loop structure, §6.4).
    pub fn throughput_per_sec(&self) -> f64 {
        1e9 / ((self.latency_cycles + SETUP_CYCLES) as f64 * CYCLE_NS)
    }
}

/// How many 256-bit BRAM rows hold `neurons` of `in_bits` weights each:
/// narrow neurons pack multiple per row; wide neurons take ceil(bits/256)
/// rows each.
pub fn rows_for(neurons: usize, in_bits: usize) -> usize {
    let in_bits = padded_bits(in_bits);
    if in_bits <= BRAM_ROW_BITS {
        let per_row = BRAM_ROW_BITS / in_bits;
        neurons.div_ceil(per_row)
    } else {
        neurons * in_bits.div_ceil(BRAM_ROW_BITS)
    }
}

/// A bank of parallel NN-executor modules (functional + timed).
pub struct FpgaExecutor {
    exec: BnnExecutor,
    pub timing: FpgaTiming,
    pub modules: usize,
}

impl FpgaExecutor {
    pub fn new(model: BnnModel, modules: usize) -> Self {
        let timing = FpgaTiming::new(&model);
        Self {
            exec: BnnExecutor::new(model),
            timing,
            modules: modules.max(1),
        }
    }

    pub fn model(&self) -> &BnnModel {
        self.exec.model()
    }

    /// Bit-exact inference (the functional half of the model).
    pub fn infer(&mut self, x: &[u32], scores: &mut [i32]) {
        self.exec.infer(x, scores)
    }

    pub fn classify(&mut self, x: &[u32]) -> usize {
        self.exec.classify(x)
    }

    /// Aggregate throughput: modules run independent inferences (Fig. 27/
    /// 29 — "each NN Executor module increases by about 1.8M inferences
    /// per second").
    pub fn throughput_per_sec(&self) -> f64 {
        self.modules as f64 * self.timing.throughput_per_sec()
    }

    /// Latency is per-module, unaffected by the module count (Fig. 28).
    pub fn latency_ns(&self) -> f64 {
        self.timing.latency_ns()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn traffic() -> BnnModel {
        BnnModel::random("traffic", 256, &[32, 16, 2], 1)
    }

    fn tomo128() -> BnnModel {
        BnnModel::random("tomo", 152, &[128, 64, 2], 2)
    }

    #[test]
    fn row_packing() {
        assert_eq!(rows_for(32, 256), 32); // 1 neuron/row
        assert_eq!(rows_for(16, 32), 2); // 8 neurons/row
        assert_eq!(rows_for(2, 32), 1);
        assert_eq!(rows_for(128, 160), 128); // 160b < 256 → 1/row
        assert_eq!(rows_for(4, 512), 8); // wide: 2 rows/neuron
    }

    #[test]
    fn traffic_latency_half_microsecond() {
        // Fig. 14: N3IC-FPGA p95 ≈ 0.5 µs for the traffic nets.
        let t = FpgaTiming::new(&traffic());
        let lat = t.latency_ns();
        assert!((300.0..650.0).contains(&lat), "lat={lat}ns");
    }

    #[test]
    fn module_throughput_about_1_8m() {
        // Fig. 29: ~1.8M inferences/s per module on the anomaly NN.
        let t = FpgaTiming::new(&traffic());
        let tput = t.throughput_per_sec();
        assert!((1.5e6..2.5e6).contains(&tput), "tput={tput}");
    }

    #[test]
    fn tomography_latency_under_2us() {
        // §6.2: "below 2µs for N3IC-FPGA" on the 128-64-2 net.
        let t = FpgaTiming::new(&tomo128());
        assert!(t.latency_ns() < 2_000.0, "lat={}", t.latency_ns());
        // And above the traffic net's latency (bigger NN).
        assert!(t.latency_ns() > FpgaTiming::new(&traffic()).latency_ns());
    }

    #[test]
    fn modules_scale_throughput_not_latency() {
        let e1 = FpgaExecutor::new(traffic(), 1);
        let e16 = FpgaExecutor::new(traffic(), 16);
        assert!((e16.throughput_per_sec() / e1.throughput_per_sec() - 16.0).abs() < 1e-9);
        assert_eq!(e1.latency_ns(), e16.latency_ns());
    }

    #[test]
    fn functional_path_bit_exact() {
        let model = traffic();
        let mut f = FpgaExecutor::new(model.clone(), 4);
        let x = crate::bnn::BnnLayer::random(1, 256, 9).words;
        assert_eq!(f.classify(&x), crate::bnn::infer_packed(&model, &x));
    }

    #[test]
    fn latency_linear_in_nn_size() {
        // Fig. 28: latency grows linearly with neurons (256-bit input FC).
        let l32 = FpgaTiming::new(&BnnModel::random("a", 256, &[32], 1)).latency_cycles;
        let l64 = FpgaTiming::new(&BnnModel::random("b", 256, &[64], 1)).latency_cycles;
        let l128 = FpgaTiming::new(&BnnModel::random("c", 256, &[128], 1)).latency_cycles;
        assert!(l64 > l32 && l128 > l64);
        let r = (l128 - l64) as f64 / (l64 - l32) as f64;
        assert!((r - 2.0).abs() < 0.2, "r={r}");
    }
}

//! FPGA resource accounting (Table 2, Figs. 29–31).
//!
//! Structural model calibrated to the paper's synthesis results on the
//! Virtex-7 690T: the reference NIC baseline plus per-module costs that
//! scale with the popcount-LT count and the CAM-backed weight store.

use crate::bnn::BnnModel;

use super::executor::{rows_for, FpgaTiming};

/// Virtex-7 690T totals (Table 2 percentages are relative to these).
pub const VIRTEX7_LUT: usize = 433_200;
pub const VIRTEX7_BRAM: usize = 1_470;

/// NetFPGA reference NIC baseline (Table 2 row 1).
pub const REFERENCE_NIC_LUT: usize = 49_400;
pub const REFERENCE_NIC_BRAM: usize = 194;

/// LUT/BRAM usage of a design.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FpgaResources {
    pub lut: usize,
    pub bram: usize,
}

impl FpgaResources {
    pub fn lut_pct(&self) -> f64 {
        self.lut as f64 * 100.0 / VIRTEX7_LUT as f64
    }

    pub fn bram_pct(&self) -> f64 {
        self.bram as f64 * 100.0 / VIRTEX7_BRAM as f64
    }

    pub fn reference_nic() -> Self {
        Self {
            lut: REFERENCE_NIC_LUT,
            bram: REFERENCE_NIC_BRAM,
        }
    }

    /// One NN-executor module for `model`:
    /// * control/pipeline base ≈ 500 LUTs;
    /// * one 256-entry popcount LT per 8 input bits per layer ≈ 55 LUTs
    ///   each (§4.3: "Each block has n/8 of these LTs");
    /// * CAM-backed weight rows ≈ 1 BRAM per 2.2 rows + 2 fixed (the CAM
    ///   IP is not shared between modules — footnote 12).
    pub fn executor_module(model: &BnnModel) -> Self {
        let mut lts = 0usize;
        let mut rows = 0usize;
        for layer in &model.layers {
            let in_bits = layer.in_words * 32;
            lts += in_bits / 8;
            rows += rows_for(layer.neurons, in_bits);
        }
        Self {
            lut: 500 + lts * 55,
            bram: 2 + (rows as f64 / 2.2).round() as usize,
        }
    }

    /// Full N3IC-FPGA design: reference NIC + `modules` executor modules
    /// (management logic is negligible — App. B.2).
    pub fn n3ic_fpga(model: &BnnModel, modules: usize) -> Self {
        let m = Self::executor_module(model);
        Self {
            lut: REFERENCE_NIC_LUT + m.lut * modules,
            bram: REFERENCE_NIC_BRAM + m.bram * modules,
        }
    }

    /// Aggregate throughput/resources trade-off point (Figs. 29–31).
    pub fn scaling_point(model: &BnnModel, modules: usize) -> (f64, Self) {
        let tput = FpgaTiming::new(model).throughput_per_sec() * modules as f64;
        (tput, Self::n3ic_fpga(model, modules))
    }

    /// Footnote-12 ablation: share one CAM weight store across all
    /// modules (weights are read-only).  BRAM then pays the store once
    /// plus a small per-module read-port cost; LUTs are unchanged.
    pub fn n3ic_fpga_shared_cam(model: &BnnModel, modules: usize) -> Self {
        let m = Self::executor_module(model);
        let per_module_ports = 2; // replicated read port + mux
        Self {
            lut: REFERENCE_NIC_LUT + m.lut * modules,
            bram: REFERENCE_NIC_BRAM + m.bram + per_module_ports * modules.saturating_sub(1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn traffic() -> BnnModel {
        BnnModel::random("traffic", 256, &[32, 16, 2], 1)
    }

    #[test]
    fn table2_single_module() {
        // Table 2: N3IC-FPGA = 52.0k LUT (12.0%), 211 BRAM (14.4%).
        let r = FpgaResources::n3ic_fpga(&traffic(), 1);
        assert!((50_500..54_000).contains(&r.lut), "lut={}", r.lut);
        assert!((205..218).contains(&r.bram), "bram={}", r.bram);
        assert!((11.5..12.6).contains(&r.lut_pct()), "{}", r.lut_pct());
        assert!((13.9..14.9).contains(&r.bram_pct()), "{}", r.bram_pct());
    }

    #[test]
    fn sixteen_modules_ten_pct_luts_nineteen_pct_brams() {
        // §6.4: 16 modules → +10% LUTs, +19% BRAMs over the reference.
        let r1 = FpgaResources::reference_nic();
        let r16 = FpgaResources::n3ic_fpga(&traffic(), 16);
        let extra_lut_pct = (r16.lut - r1.lut) as f64 * 100.0 / VIRTEX7_LUT as f64;
        let extra_bram_pct = (r16.bram - r1.bram) as f64 * 100.0 / VIRTEX7_BRAM as f64;
        assert!((8.0..12.0).contains(&extra_lut_pct), "{extra_lut_pct}");
        assert!((16.0..22.0).contains(&extra_bram_pct), "{extra_bram_pct}");
    }

    #[test]
    fn linear_scaling_figs_29_31() {
        let m = traffic();
        let (t1, r1) = FpgaResources::scaling_point(&m, 1);
        let (t4, r4) = FpgaResources::scaling_point(&m, 4);
        let (t8, r8) = FpgaResources::scaling_point(&m, 8);
        assert!((t4 / t1 - 4.0).abs() < 1e-9);
        assert!((t8 / t1 - 8.0).abs() < 1e-9);
        let dl14 = r4.lut - r1.lut;
        let dl48 = r8.lut - r4.lut;
        assert!((dl14 as f64 / 3.0 - (dl48 as f64 / 4.0)).abs() < 1.0);
        assert!(r8.bram - r4.bram == (r4.bram - r1.bram) / 3 * 4);
    }

    #[test]
    fn bigger_nets_use_more_brams() {
        let small = FpgaResources::executor_module(&traffic());
        let big = FpgaResources::executor_module(&BnnModel::random(
            "tomo", 152, &[128, 64, 2], 2,
        ));
        assert!(big.bram > small.bram * 3);
    }
}

//! N3IC-FPGA: the dedicated hardware NN-executor module (§4.3).
//!
//! * [`executor`] — cycle-accurate model of the Verilog design: per-layer
//!   blocks, 256-bit BRAM rows read in 2 cycles, 8-bit popcount LTs,
//!   3-stage pipeline, 200 MHz clock; multiple modules in parallel.
//! * [`resources`] — LUT/BRAM accounting calibrated to Table 2 and
//!   Figs. 29–31 (linear scaling per module; CAM-based weight store).
//!
//! The executor also *computes* (bit-exactly, via the shared [`crate::bnn`]
//! core) so functional tests cover it like real hardware would be covered
//! by a testbench.

pub mod executor;
pub mod resources;

pub use executor::{FpgaExecutor, FpgaTiming};
pub use resources::{FpgaResources, VIRTEX7_BRAM, VIRTEX7_LUT};

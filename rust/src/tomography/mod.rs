//! Modified SIMON (§5 #3): per-queue congestion inference from probe
//! delays, with the NN running on the NIC instead of a centralized GPU.
//!
//! Pipeline: fat-tree sim → probe rounds → quantize (ProbeCollector) →
//! one BNN per monitored queue → congestion verdicts, compared against
//! the simulator's ground-truth backlogs.  The latency side (Fig. 15):
//! probe period at 40/100/400 Gb/s is 250/100/25 µs; an executor is
//! *real-time capable* if its per-NN latency × NNs-per-NIC fits the
//! period.

use crate::bnn::{BnnExecutor, BnnModel};
use crate::fattree::{
    FatTreeSim, IncastWorkload, ProbeCollector, SimConfig, Topology,
    N_MONITORED_QUEUES,
};

/// Probe periods required by SIMON at different link speeds (§6.2).
pub const PROBE_PERIOD_40G_NS: f64 = 250_000.0;
pub const PROBE_PERIOD_100G_NS: f64 = 100_000.0;
pub const PROBE_PERIOD_400G_NS: f64 = 25_000.0;

/// Result of a tomography run.
#[derive(Debug, Clone)]
pub struct TomographyReport {
    /// Per-queue accuracy of the calibrated detectors.
    pub accuracy: Vec<f64>,
    /// Accuracy of the *deployed BNN* (trained on the Python queue model,
    /// transferred to this packet-level simulator) on queue 0.
    pub bnn_q0_accuracy: f64,
    /// Number of evaluated rounds.
    pub rounds: usize,
    pub median_accuracy: f64,
}

/// Executor-side real-time check (Fig. 15): can `latency_ns`-per-NN
/// hardware evaluate `nns` queue models within `period_ns`?
pub fn meets_deadline(latency_ns: f64, nns: usize, period_ns: f64) -> bool {
    // N3IC-FPGA serializes NNs on one module (§7); the NIC must finish all
    // of its queues' NNs before the next probe sweep.
    latency_ns * nns as f64 <= period_ns
}

/// Train-free evaluation path: run the fat-tree sim and score *pre-trained*
/// per-queue models (all queues share the architecture; we deploy the
/// single exported canonical model per size against every queue's labels
/// after per-queue threshold calibration — the Python pass trains the
/// full per-queue set and reports Fig. 16's distribution).
pub struct TomographyRun {
    pub topo: Topology,
    pub cfg: SimConfig,
    pub seed: u64,
}

impl Default for TomographyRun {
    fn default() -> Self {
        Self {
            topo: Topology::new(),
            cfg: SimConfig {
                probe_interval_ns: 1e6,
                load: 1.1,
                ..SimConfig::default()
            },
            seed: 7,
        }
    }
}

impl TomographyRun {
    /// Run `rounds` intervals; use simple per-queue linear probes-sum
    /// detectors *plus* the given BNN (for queue 0, where a trained model
    /// exists) and report accuracies.
    pub fn evaluate(&self, model: &BnnModel, rounds: usize) -> TomographyReport {
        let mut wl = IncastWorkload::new(&self.topo, &self.cfg);
        let mut sim = FatTreeSim::new(self.topo.clone(), self.cfg, self.seed);
        let data = sim.run(rounds, &mut wl);
        let half = data.len() / 2;
        let collector = ProbeCollector::fit(&data[..half], 0.25);
        let incidence = self.topo.probe_incidence();

        let mut exec = BnnExecutor::new(model.clone());
        let mut correct = vec![0usize; N_MONITORED_QUEUES];
        let mut bnn_correct = 0usize;
        let mut total = 0usize;
        // Calibration: per-queue decision threshold on the delay-sum of
        // incident probes (the linear detector the BNN approximates); the
        // BNN itself handles queue 0.
        let mut cal_sums: Vec<Vec<(f64, bool)>> =
            vec![Vec::new(); N_MONITORED_QUEUES];
        for r in &data[..half] {
            let s = collector.sample(r);
            for q in 0..N_MONITORED_QUEUES {
                let sum: f64 = (0..19)
                    .filter(|&p| incidence[p][q] == 1)
                    .map(|p| s.delays_q[p] as f64)
                    .sum();
                cal_sums[q].push((sum, s.congested[q]));
            }
        }
        let thresholds: Vec<f64> = cal_sums
            .iter()
            .map(|v| best_threshold(v))
            .collect();

        for r in &data[half..] {
            let s = collector.sample(r);
            total += 1;
            if (exec.classify(&s.packed) == 1) == s.congested[0] {
                bnn_correct += 1;
            }
            for q in 0..N_MONITORED_QUEUES {
                let sum: f64 = (0..19)
                    .filter(|&p| incidence[p][q] == 1)
                    .map(|p| s.delays_q[p] as f64)
                    .sum();
                if (sum > thresholds[q]) == s.congested[q] {
                    correct[q] += 1;
                }
            }
        }
        let mut accuracy: Vec<f64> = correct
            .iter()
            .map(|&c| c as f64 / total.max(1) as f64)
            .collect();
        let mut sorted = accuracy.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        accuracy.truncate(N_MONITORED_QUEUES);
        TomographyReport {
            accuracy,
            bnn_q0_accuracy: bnn_correct as f64 / total.max(1) as f64,
            rounds: total,
            median_accuracy: median,
        }
    }
}

/// Threshold maximizing accuracy on calibration pairs (sum, label).
fn best_threshold(pairs: &[(f64, bool)]) -> f64 {
    if pairs.is_empty() {
        return 0.0;
    }
    let mut sums: Vec<f64> = pairs.iter().map(|p| p.0).collect();
    sums.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut best = (0usize, sums[0] - 1.0);
    for i in 0..sums.len() {
        let thr = sums[i];
        let acc = pairs
            .iter()
            .filter(|(s, l)| (*s > thr) == *l)
            .count();
        if acc > best.0 {
            best = (acc, thr);
        }
    }
    best.1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadlines_match_paper_fig15() {
        // bnn-exec ≈ 40 µs: fits 100 µs (100G) but not 25 µs (400G).
        assert!(meets_deadline(40_000.0, 1, PROBE_PERIOD_100G_NS));
        assert!(!meets_deadline(40_000.0, 1, PROBE_PERIOD_400G_NS));
        // N3IC-FPGA < 2 µs: fits 400G even with several NNs serialized.
        assert!(meets_deadline(1_700.0, 8, PROBE_PERIOD_400G_NS));
        // N3IC-NFP 170 µs: misses even 40G budget... (§6.2: only 250 µs
        // budget is met, 100 µs is not).
        assert!(meets_deadline(170_000.0, 1, PROBE_PERIOD_40G_NS));
        assert!(!meets_deadline(170_000.0, 1, PROBE_PERIOD_100G_NS));
    }

    #[test]
    fn deadline_boundary_exact_fit_and_zero_nns() {
        // Exact fit: latency × NNs == period is *meeting* the deadline
        // (the paper's ≤, not <) — at every link speed.
        assert!(meets_deadline(PROBE_PERIOD_40G_NS / 4.0, 4, PROBE_PERIOD_40G_NS));
        assert!(meets_deadline(PROBE_PERIOD_100G_NS, 1, PROBE_PERIOD_100G_NS));
        assert!(meets_deadline(PROBE_PERIOD_400G_NS / 17.0, 17, PROBE_PERIOD_400G_NS));
        // One unit past the exact fit misses.
        assert!(!meets_deadline(PROBE_PERIOD_100G_NS + 1.0, 1, PROBE_PERIOD_100G_NS));
        // Zero NNs to run: trivially met, even with absurd latency —
        // `nns` must scale the cost, not gate the comparison.
        assert!(meets_deadline(1e12, 0, PROBE_PERIOD_400G_NS));
        // Zero period with work to do misses; zero period with no work
        // is the degenerate exact fit.
        assert!(!meets_deadline(1.0, 1, 0.0));
        assert!(meets_deadline(1.0, 0, 0.0));
    }

    #[test]
    fn probe_periods_match_simon_link_speeds() {
        // §6.2's budgets: 250/100/25 µs at 40/100/400 Gb/s.  The period
        // scales inversely with link speed (2.5× then 4×).
        assert_eq!(PROBE_PERIOD_40G_NS, 250_000.0);
        assert_eq!(PROBE_PERIOD_100G_NS, 100_000.0);
        assert_eq!(PROBE_PERIOD_400G_NS, 25_000.0);
        assert_eq!(PROBE_PERIOD_40G_NS / PROBE_PERIOD_100G_NS, 2.5);
        assert_eq!(PROBE_PERIOD_100G_NS / PROBE_PERIOD_400G_NS, 4.0);
    }

    #[test]
    fn linear_detectors_beat_chance() {
        let run = TomographyRun::default();
        let model = crate::bnn::BnnModel::random("tomo", 152, &[32, 16, 2], 3);
        let rep = run.evaluate(&model, 160);
        assert_eq!(rep.accuracy.len(), N_MONITORED_QUEUES);
        // Median of the calibrated detectors must beat the 75% base rate
        // meaningfully (the BNN for q0 is random here, so exclude it).
        let mut accs: Vec<f64> = rep.accuracy.to_vec();
        accs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = accs[accs.len() / 2];
        assert!(med > 0.7, "median={med}");
    }
}

//! Fat-tree topology (Fig. 33): 2 pods, 10 switches, 32 hosts.

/// Monitored output queues on host-0-bound paths (paper: 17).
pub const N_MONITORED_QUEUES: usize = 17;
/// Distinct probe paths kept (paper: 19 probes, one per distinct path).
pub const N_PROBE_PATHS: usize = 19;

pub const N_TORS: usize = 4;
pub const N_AGGS: usize = 4;
pub const N_CORES: usize = 2;
pub const HOSTS_PER_TOR: usize = 8;
pub const N_HOSTS: usize = N_TORS * HOSTS_PER_TOR;

/// A directed link in the network; `queue` is Some(q) if this link's
/// output queue is one of the monitored 17.
#[derive(Debug, Clone, Copy)]
pub struct Link {
    pub id: usize,
    pub queue: Option<usize>,
}

/// Static topology with precomputed host→host0 paths.
#[derive(Debug, Clone)]
pub struct Topology {
    /// links[id] — all directed links.
    pub links: Vec<Link>,
    /// Path (sequence of link ids) from each host to host 0.
    pub paths_to_h0: Vec<Vec<usize>>,
    /// Monitored-queue incidence per path: `path_queues[h][q]`.
    pub path_queues: Vec<Vec<usize>>,
}

impl Topology {
    /// Build the 2-pod CLOS of Fig. 33.  Link ids are assigned in a fixed
    /// order; the 17 monitored queues are the distinct output queues that
    /// host-0-bound traffic can traverse:
    ///
    /// * 3 intra-ToR0 "up" host links are unmonitored (they never queue);
    ///   we monitor: ToR uplinks to each agg (4 ToR × 1 hashed agg choice
    ///   kept distinct = 8 up queues in pod units), agg→core ups, core→agg
    ///   downs, agg→ToR0 downs and the ToR0→host0 down — 17 total.
    pub fn new() -> Self {
        // Enumerate the queue-bearing hops toward host 0.
        // Pod 0 = {tor0, tor1, agg0, agg1}, pod 1 = {tor2, tor3, agg2, agg3}.
        // Monitored queues (toward host 0):
        //  q0          : tor0 → host0 (the final down queue)
        //  q1, q2      : agg0 → tor0, agg1 → tor0 (pod-0 down)
        //  q3, q4      : core0 → agg0, core1 → agg1 (cross-pod down)
        //  q5..q8      : tor1..tor3 uplinks ×(2 agg choices for tor1) etc.
        // Construction below assigns ids mechanically; the exact labels
        // don't matter, only the path/queue incidence structure.
        let mut links = Vec::new();
        let mut alloc = |queue: Option<usize>| {
            let id = links.len();
            links.push(Link { id, queue });
            id
        };

        // Queue ids are handed out sequentially.
        let mut next_q = 0;
        let mut q = || {
            let v = next_q;
            next_q += 1;
            Some(v)
        };

        // Final hop: tor0 → host0.
        let l_tor0_h0 = alloc(q()); // q0
        // Pod-0 agg → tor0 downs.
        let l_agg_tor0: Vec<usize> = (0..2).map(|_| alloc(q())).collect(); // q1,q2
        // Core → pod-0 agg downs.
        let l_core_agg0: Vec<usize> = (0..2).map(|_| alloc(q())).collect(); // q3,q4
        // ToR uplinks (tor0..tor3 × 2 aggs of their pod): tor0's uplinks
        // are never used toward host 0, so they're unmonitored.
        let mut l_tor_up = vec![vec![0usize; 2]; N_TORS];
        for tor in 0..N_TORS {
            for a in 0..2 {
                l_tor_up[tor][a] = if tor == 0 { alloc(None) } else { alloc(q()) };
            }
        } // q5..q10 (6 queues: tor1,2,3 × 2)
        // Pod-1 agg → core uplinks (2 aggs × 2 cores used toward pod 0 = 4).
        let mut l_agg_up = vec![vec![0usize; N_CORES]; 2];
        for (a, row) in l_agg_up.iter_mut().enumerate() {
            for (c, slot) in row.iter_mut().enumerate() {
                let _ = (a, c);
                *slot = alloc(q());
            }
        } // q11..q14
        // Host → ToR access links for senders (unmonitored, but they can
        // queue slightly; keep 2 shared classes to reach 17 with the
        // paper's count: pod-0 host-up aggregate and pod-1 host-up).
        let l_hostup_pod0 = alloc(q()); // q15
        let l_hostup_pod1 = alloc(q()); // q16
        assert_eq!(next_q, N_MONITORED_QUEUES);

        // Paths to host 0 for every host.
        let mut paths = Vec::with_capacity(N_HOSTS);
        for h in 0..N_HOSTS {
            let tor = h / HOSTS_PER_TOR;
            let mut path = Vec::new();
            if h != 0 {
                path.push(if tor <= 1 { l_hostup_pod0 } else { l_hostup_pod1 });
            }
            if tor == 0 {
                if h != 0 {
                    path.push(l_tor0_h0);
                }
            } else if tor == 1 {
                // same pod: tor1 → agg (hash by host) → tor0 → host0
                let a = h % 2;
                path.push(l_tor_up[tor][a]);
                path.push(l_agg_tor0[a]);
                path.push(l_tor0_h0);
            } else {
                // cross-pod: tor → agg (pod 1) → core → agg (pod 0) → tor0
                let a = h % 2;
                let c = (h / 2) % 2;
                path.push(l_tor_up[tor][a]);
                path.push(l_agg_up[a][c]);
                path.push(l_core_agg0[c]);
                path.push(l_agg_tor0[c]);
                path.push(l_tor0_h0);
            }
            paths.push(path);
        }

        let path_queues = paths
            .iter()
            .map(|p| {
                p.iter()
                    .filter_map(|&l| links[l].queue)
                    .collect::<Vec<_>>()
            })
            .collect();

        Self {
            links,
            paths_to_h0: paths,
            path_queues,
        }
    }

    /// Choose 19 probe senders covering distinct paths (App. C.2: "19 out
    /// of 31 probes in order to keep 1 probe per distinct path").
    pub fn probe_hosts(&self) -> Vec<usize> {
        let mut seen = std::collections::HashSet::new();
        let mut hosts = Vec::new();
        for h in 1..N_HOSTS {
            let key = self.paths_to_h0[h].clone();
            if seen.insert(key) {
                hosts.push(h);
            }
            if hosts.len() == N_PROBE_PATHS {
                break;
            }
        }
        // Distinct-path count of this topology is smaller than 19 by
        // construction (hash classes); extend with additional hosts to
        // reach 19 probes like the paper's probe set.
        let mut h = 1;
        while hosts.len() < N_PROBE_PATHS {
            if !hosts.contains(&h) {
                hosts.push(h);
            }
            h += 1;
        }
        hosts.sort_unstable();
        hosts.truncate(N_PROBE_PATHS);
        hosts
    }

    /// 19×17 incidence matrix (probe path × monitored queue).
    pub fn probe_incidence(&self) -> Vec<Vec<u8>> {
        self.probe_hosts()
            .iter()
            .map(|&h| {
                let mut row = vec![0u8; N_MONITORED_QUEUES];
                for &q in &self.path_queues[h] {
                    row[q] = 1;
                }
                row
            })
            .collect()
    }
}

impl Default for Topology {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seventeen_monitored_queues() {
        let t = Topology::new();
        let max_q = t.links.iter().filter_map(|l| l.queue).max().unwrap();
        assert_eq!(max_q + 1, N_MONITORED_QUEUES);
    }

    #[test]
    fn every_queue_observable_by_some_probe() {
        let t = Topology::new();
        let inc = t.probe_incidence();
        assert_eq!(inc.len(), N_PROBE_PATHS);
        for q in 0..N_MONITORED_QUEUES {
            assert!(
                inc.iter().any(|row| row[q] == 1),
                "queue {q} unobserved"
            );
        }
    }

    #[test]
    fn paths_terminate_at_host0_queue() {
        let t = Topology::new();
        for h in 1..N_HOSTS {
            let last = *t.paths_to_h0[h].last().unwrap();
            assert_eq!(t.links[last].queue, Some(0), "host {h}");
        }
        assert!(t.paths_to_h0[0].is_empty());
    }

    #[test]
    fn cross_pod_paths_longer_than_intra_pod() {
        let t = Topology::new();
        let intra = t.paths_to_h0[HOSTS_PER_TOR].len(); // a tor1 host
        let cross = t.paths_to_h0[2 * HOSTS_PER_TOR].len(); // a tor2 host
        assert!(cross > intra);
    }
}

//! Incast workload generator (App. C.2: "The datacenter operates under an
//! incast traffic load").
//!
//! Bursty on/off senders target host 0: each sender alternates between
//! idle and burst states; during bursts it emits packets at a high rate.
//! Aggregate load is scaled by `SimConfig::load` relative to the host-0
//! access link.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::net::traffic::Rng;

use super::sim::SimConfig;
use super::topology::{Topology, N_HOSTS};

/// Per-sender on/off state.
pub struct IncastWorkload {
    burst: Vec<bool>,
    /// Mean packets/interval per sender when bursting.
    burst_pkts: f64,
    /// Baseline packets/interval when idle.
    idle_pkts: f64,
    /// State-flip probabilities per interval (sticky bursts).
    p_enter: f64,
    p_exit: f64,
}

impl IncastWorkload {
    pub fn new(_topo: &Topology, cfg: &SimConfig) -> Self {
        // Scale so that with ~25% of senders bursting the bottleneck sees
        // cfg.load × capacity.
        let cap_pkts_per_interval =
            cfg.link_gbps * cfg.probe_interval_ns / (cfg.pkt_bytes as f64 * 8.0);
        let expected_bursters = (N_HOSTS - 1) as f64 * 0.25;
        let burst_pkts = cfg.load * cap_pkts_per_interval / expected_bursters;
        Self {
            burst: vec![false; N_HOSTS],
            burst_pkts,
            idle_pkts: burst_pkts * 0.05,
            p_enter: 0.09,
            p_exit: 0.30,
        }
    }

    /// Emit (time, src) events for [t0, t1) into the heap.
    pub fn fill_interval(
        &mut self,
        t0: f64,
        t1: f64,
        rng: &mut Rng,
        heap: &mut BinaryHeap<Reverse<(u64, usize)>>,
    ) {
        let dt = t1 - t0;
        for h in 1..N_HOSTS {
            // sticky on/off flip
            let r = rng.next_f64();
            self.burst[h] = if self.burst[h] {
                r > self.p_exit
            } else {
                r < self.p_enter
            };
            let mean = if self.burst[h] {
                self.burst_pkts
            } else {
                self.idle_pkts
            };
            // Poisson(mean) arrivals uniform in the interval.
            let n = poisson(rng, mean);
            for _ in 0..n {
                let ts = t0 + rng.next_f64() * dt;
                heap.push(Reverse((ts as u64, h)));
            }
        }
    }

    /// Currently bursting sender count (tests).
    pub fn active_bursters(&self) -> usize {
        self.burst.iter().filter(|&&b| b).count()
    }
}

/// Knuth Poisson sampler, capped for safety at high means (uses normal
/// approximation above 64).
fn poisson(rng: &mut Rng, mean: f64) -> usize {
    if mean <= 0.0 {
        return 0;
    }
    if mean > 64.0 {
        // normal approximation
        let u1 = rng.next_f64().max(1e-12);
        let u2 = rng.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        return (mean + z * mean.sqrt()).max(0.0) as usize;
    }
    let l = (-mean).exp();
    let mut k = 0usize;
    let mut p = 1.0;
    loop {
        p *= rng.next_f64();
        if p <= l || k > 10_000 {
            return k;
        }
        k += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean() {
        let mut rng = Rng::new(1);
        let n = 20_000;
        let mean = 7.5;
        let total: usize = (0..n).map(|_| poisson(&mut rng, mean)).sum();
        let emp = total as f64 / n as f64;
        assert!((emp - mean).abs() < 0.15, "emp={emp}");
    }

    #[test]
    fn burst_states_sticky_and_bounded() {
        let topo = Topology::new();
        let cfg = SimConfig::default();
        let mut wl = IncastWorkload::new(&topo, &cfg);
        let mut rng = Rng::new(3);
        let mut heap = BinaryHeap::new();
        let mut active_sum = 0usize;
        for i in 0..200 {
            wl.fill_interval(i as f64 * 1e6, (i + 1) as f64 * 1e6, &mut rng, &mut heap);
            active_sum += wl.active_bursters();
        }
        let mean_active = active_sum as f64 / 200.0;
        // Stationary burst fraction ≈ p_enter/(p_enter+p_exit) ≈ 0.23.
        let frac = mean_active / (N_HOSTS - 1) as f64;
        assert!((0.1..0.4).contains(&frac), "frac={frac}");
        assert!(!heap.is_empty());
    }
}

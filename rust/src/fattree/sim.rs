//! Packet-level discrete-event engine over the fat-tree links.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::net::traffic::Rng;

use super::topology::{Topology, N_MONITORED_QUEUES};
use super::workload::IncastWorkload;

/// Simulation parameters.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// Link speed (Gb/s) — the paper sweeps 100Mb/s..10Gb/s in ns-3.
    pub link_gbps: f64,
    /// Per-link queue capacity in packets (tail drop beyond).
    pub queue_cap: usize,
    /// Probe interval (ns) — 10 ms in App. C.2.
    pub probe_interval_ns: f64,
    /// Mean offered incast load as a fraction of the bottleneck link.
    pub load: f64,
    /// Workload packet size (bytes).
    pub pkt_bytes: u32,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            link_gbps: 10.0,
            queue_cap: 256,
            probe_interval_ns: 10e6,
            load: 0.85,
            pkt_bytes: 1000,
        }
    }
}

/// Per-link FIFO state.  The instantaneous backlog (the "queue size"
/// SIMON estimates) is derived from `free_at - now` in units of one
/// packet's serialization time.
struct LinkState {
    /// Time the link becomes free.
    free_at: f64,
}

/// One probe result: per-path one-way delay + ground-truth queue sizes.
#[derive(Debug, Clone)]
pub struct ProbeRound {
    pub t_ns: f64,
    /// One-way delay per probe path (ns).
    pub delays_ns: Vec<f64>,
    /// Monitored queue backlogs (packets) at probe time.
    pub queue_sizes: Vec<usize>,
}

/// The discrete-event simulator.
pub struct FatTreeSim {
    pub topo: Topology,
    pub cfg: SimConfig,
    links: Vec<LinkState>,
    rng: Rng,
}

impl FatTreeSim {
    pub fn new(topo: Topology, cfg: SimConfig, seed: u64) -> Self {
        let links = topo
            .links
            .iter()
            .map(|_| LinkState { free_at: 0.0 })
            .collect();
        Self {
            topo,
            cfg,
            links,
            rng: Rng::new(seed),
        }
    }

    /// Serialization delay of one packet on one link (ns).
    fn tx_ns(&self, bytes: u32) -> f64 {
        bytes as f64 * 8.0 / self.cfg.link_gbps
    }

    /// Send one packet along `path` starting at `t0`; returns arrival time
    /// or None if tail-dropped.  Link busy periods model queueing: the
    /// packet waits until the link is free, then occupies it for tx_ns.
    fn send(&mut self, path: &[usize], t0: f64, bytes: u32) -> Option<f64> {
        let mut t = t0;
        let tx = self.tx_ns(bytes);
        for &l in path {
            let st = &mut self.links[l];
            let wait = (st.free_at - t).max(0.0);
            if wait / tx > self.cfg.queue_cap as f64 {
                return None; // tail drop: queue full
            }
            let start = t + wait;
            st.free_at = start + tx;
            t = start + tx + 500.0; // 500 ns propagation + switching
        }
        Some(t)
    }

    /// Instantaneous backlog (packets) of each monitored queue at time t.
    fn queue_snapshot(&self, t: f64, bytes: u32) -> Vec<usize> {
        let tx = self.tx_ns(bytes);
        let mut out = vec![0usize; N_MONITORED_QUEUES];
        for link in &self.topo.links {
            if let Some(q) = link.queue {
                let backlog_ns = (self.links[link.id].free_at - t).max(0.0);
                out[q] = (backlog_ns / tx) as usize;
            }
        }
        out
    }

    /// Run `rounds` probe intervals under the incast workload; returns one
    /// ProbeRound per interval.
    pub fn run(&mut self, rounds: usize, workload: &mut IncastWorkload) -> Vec<ProbeRound> {
        let probe_hosts = self.topo.probe_hosts();
        let mut out = Vec::with_capacity(rounds);
        let bytes = self.cfg.pkt_bytes;
        let mut t = 0.0f64;
        // Event heap of background packets (send time, src host) — ordered.
        let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
        for round in 0..rounds {
            let t_end = (round + 1) as f64 * self.cfg.probe_interval_ns;
            // Generate this interval's background traffic.
            workload.fill_interval(t, t_end, &mut self.rng, &mut heap);
            // Deliver background packets in time order.
            while let Some(&Reverse((ts, src))) = heap.peek() {
                let ts = ts as f64;
                if ts > t_end {
                    break;
                }
                heap.pop();
                let path = self.topo.paths_to_h0[src].clone();
                let _ = self.send(&path, ts, bytes);
            }
            // Probe sweep at end of interval.
            let mut delays = Vec::with_capacity(probe_hosts.len());
            let snapshot = self.queue_snapshot(t_end, bytes);
            for &h in &probe_hosts {
                let path = self.topo.paths_to_h0[h].clone();
                let t0 = t_end + self.rng.next_f64() * 1000.0;
                let arrive = self.send(&path, t0, 100).unwrap_or(t0 + 1e9);
                delays.push(arrive - t0);
            }
            out.push(ProbeRound {
                t_ns: t_end,
                delays_ns: delays,
                queue_sizes: snapshot,
            });
            t = t_end;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_sim(load: f64, rounds: usize) -> Vec<ProbeRound> {
        let topo = Topology::new();
        let cfg = SimConfig {
            probe_interval_ns: 1e6, // 1 ms to keep tests fast
            load,
            ..SimConfig::default()
        };
        let mut wl = IncastWorkload::new(&topo, &cfg);
        let mut sim = FatTreeSim::new(topo, cfg, 42);
        sim.run(rounds, &mut wl)
    }

    #[test]
    fn probes_measure_positive_delays() {
        let rounds = quick_sim(0.5, 20);
        assert_eq!(rounds.len(), 20);
        for r in &rounds {
            assert_eq!(r.delays_ns.len(), 19);
            assert_eq!(r.queue_sizes.len(), 17);
            for &d in &r.delays_ns {
                assert!(d > 0.0);
            }
        }
    }

    #[test]
    fn higher_load_builds_bigger_queues() {
        let low: usize = quick_sim(0.3, 30).iter().map(|r| r.queue_sizes[0]).sum();
        let high: usize = quick_sim(1.4, 30).iter().map(|r| r.queue_sizes[0]).sum();
        assert!(high > low, "low={low} high={high}");
    }

    #[test]
    fn congested_paths_have_longer_probe_delays() {
        let rounds = quick_sim(1.2, 60);
        // Split rounds by bottleneck queue size; delays on q0-crossing
        // paths must correlate.
        let mut busy = Vec::new();
        let mut idle = Vec::new();
        for r in &rounds {
            let d: f64 = r.delays_ns.iter().sum::<f64>() / r.delays_ns.len() as f64;
            if r.queue_sizes[0] > 4 {
                busy.push(d);
            } else {
                idle.push(d);
            }
        }
        if !busy.is_empty() && !idle.is_empty() {
            let mb = busy.iter().sum::<f64>() / busy.len() as f64;
            let mi = idle.iter().sum::<f64>() / idle.len() as f64;
            assert!(mb > mi, "busy={mb} idle={mi}");
        }
    }
}

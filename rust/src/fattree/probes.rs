//! Probe-delay collection + quantization: the bridge from the fat-tree
//! simulator to the BNN input format (19 × 8-bit delays, App. C.2).

use super::sim::ProbeRound;
use crate::net::features::pack_features;

/// Thermometer-code levels per probe delay (App. C.2: unary encoding
/// preserves ordinal structure — Hamming distance between two codes
/// equals the L1 distance between their levels).
pub const THERMO_LEVELS: usize = 8;

/// Unary (thermometer) code of a quantized `[0, 255]` delay: the bottom
/// `level` bits set, where `level` scales linearly with the delay.
pub fn thermo_code(delay_q: u16, levels: usize) -> u16 {
    let level = (delay_q as usize * levels / 255).min(levels);
    ((1u32 << level) - 1) as u16
}

/// One quantized probe sample ready for inference.
#[derive(Debug, Clone)]
pub struct ProbeSample {
    /// Quantized one-way delays (19 × 8-bit).
    pub delays_q: Vec<u16>,
    /// Ground-truth congestion label per monitored queue.
    pub congested: Vec<bool>,
    /// Packed BNN input (5 words = 160 bits for 152 used).
    pub packed: Vec<u32>,
}

/// Collects rounds, fits the quantization scale, emits samples.
pub struct ProbeCollector {
    /// Delay scale: value mapped to 255 (p99 of observed delays).
    pub scale_ns: f64,
    /// Queue-size congestion threshold (packets).
    pub threshold: usize,
}

impl ProbeCollector {
    /// Fit scale/threshold from a calibration set of rounds: scale at the
    /// ~p99 delay, threshold at the `congested_frac` occupancy quantile.
    pub fn fit(rounds: &[ProbeRound], congested_frac: f64) -> Self {
        let mut delays: Vec<f64> = rounds
            .iter()
            .flat_map(|r| r.delays_ns.iter().copied())
            .collect();
        delays.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let scale_ns = if delays.is_empty() {
            1.0
        } else {
            delays[((delays.len() - 1) as f64 * 0.99) as usize].max(1.0)
        };
        let mut sizes: Vec<usize> = rounds
            .iter()
            .flat_map(|r| r.queue_sizes.iter().copied())
            .collect();
        sizes.sort_unstable();
        let threshold = if sizes.is_empty() {
            1
        } else {
            sizes[((sizes.len() - 1) as f64 * (1.0 - congested_frac)) as usize].max(1)
        };
        Self {
            scale_ns,
            threshold,
        }
    }

    /// Quantize one round into a BNN-ready sample.
    pub fn sample(&self, round: &ProbeRound) -> ProbeSample {
        let delays_q: Vec<u16> = round
            .delays_ns
            .iter()
            .map(|&d| ((d * 255.0 / self.scale_ns).clamp(0.0, 255.0)) as u16)
            .collect();
        let congested = round
            .queue_sizes
            .iter()
            .map(|&s| s > self.threshold)
            .collect();
        let packed = pack_features(&delays_q, 8, 5);
        ProbeSample {
            delays_q,
            congested,
            packed,
        }
    }

    /// Like [`sample`](Self::sample), but the packed input uses the
    /// thermometer encoding: 19 delays × [`THERMO_LEVELS`] unary bits
    /// (152 bits → 5 words), so Hamming distance over the packed vector
    /// is the L1 distance over quantized delay levels — the geometry a
    /// nearest-centroid BNN classifies on.
    pub fn thermo_sample(&self, round: &ProbeRound) -> ProbeSample {
        let mut s = self.sample(round);
        let codes: Vec<u16> = s
            .delays_q
            .iter()
            .map(|&d| thermo_code(d, THERMO_LEVELS))
            .collect();
        s.packed = pack_features(
            &codes,
            THERMO_LEVELS,
            crate::bnn::words_for(codes.len() * THERMO_LEVELS),
        );
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fattree::sim::ProbeRound;

    fn mk_round(base: f64) -> ProbeRound {
        ProbeRound {
            t_ns: 0.0,
            delays_ns: (0..19).map(|i| base + i as f64 * 100.0).collect(),
            queue_sizes: (0..17).map(|i| i * 2).collect(),
        }
    }

    #[test]
    fn fit_and_quantize() {
        let rounds: Vec<ProbeRound> = (0..50).map(|i| mk_round(1000.0 + i as f64 * 50.0)).collect();
        let c = ProbeCollector::fit(&rounds, 0.25);
        assert!(c.scale_ns > 1000.0);
        let s = c.sample(&rounds[10]);
        assert_eq!(s.delays_q.len(), 19);
        assert_eq!(s.packed.len(), 5);
        assert!(s.delays_q.iter().all(|&v| v <= 255));
        // Monotone: later probes (longer delays) → larger quantized value.
        assert!(s.delays_q[18] >= s.delays_q[0]);
    }

    #[test]
    fn thermo_code_boundaries_and_l1_geometry() {
        // Boundaries: zero delay → empty code, max delay → all bits set.
        assert_eq!(thermo_code(0, THERMO_LEVELS), 0);
        assert_eq!(
            thermo_code(255, THERMO_LEVELS),
            (1u16 << THERMO_LEVELS) - 1
        );
        // Monotone, and Hamming(code_a, code_b) == |level_a - level_b|.
        let level = |d: u16| (d as usize * THERMO_LEVELS / 255).min(THERMO_LEVELS);
        for a in (0..=255u16).step_by(5) {
            for b in (0..=255u16).step_by(7) {
                let h = (thermo_code(a, THERMO_LEVELS) ^ thermo_code(b, THERMO_LEVELS))
                    .count_ones() as usize;
                let l1 = level(a).abs_diff(level(b));
                assert_eq!(h, l1, "a={a} b={b}");
            }
        }
    }

    #[test]
    fn thermo_sample_packs_152_bits() {
        let rounds: Vec<ProbeRound> = (0..50).map(|i| mk_round(1000.0 + i as f64 * 50.0)).collect();
        let c = ProbeCollector::fit(&rounds, 0.25);
        let s = c.thermo_sample(&rounds[10]);
        assert_eq!(s.packed.len(), 5, "19 × 8 thermo bits = 152 → 5 words");
        // Labels and raw quantized delays are unchanged from sample().
        let plain = c.sample(&rounds[10]);
        assert_eq!(s.delays_q, plain.delays_q);
        assert_eq!(s.congested, plain.congested);
        // Total set bits = sum of levels.
        let set: u32 = s.packed.iter().map(|w| w.count_ones()).sum();
        let levels: u32 = s
            .delays_q
            .iter()
            .map(|&d| (d as usize * THERMO_LEVELS / 255).min(THERMO_LEVELS) as u32)
            .sum();
        assert_eq!(set, levels);
    }

    #[test]
    fn threshold_separates_queues() {
        let rounds: Vec<ProbeRound> = (0..50).map(|i| mk_round(i as f64)).collect();
        let c = ProbeCollector::fit(&rounds, 0.25);
        let s = c.sample(&rounds[0]);
        let congested = s.congested.iter().filter(|&&b| b).count();
        // roughly the top quarter of queues
        assert!((2..=7).contains(&congested), "{congested}");
    }
}

//! Discrete-event CLOS fat-tree simulator — the ns-3 substitute for the
//! SIMON network-tomography use case (§5 #3, App. C.2, Fig. 33).
//!
//! Two-pod topology: 4 ToR + 4 aggregation + 2 core switches, 32 hosts
//! (8 per ToR).  All traffic of interest flows toward host 0; the 17
//! output queues on host-0-bound paths are the monitored set.  Probes are
//! periodically sent from 19 selected hosts to host 0 and their one-way
//! delays recorded — the BNN input.

pub mod probes;
pub mod sim;
pub mod topology;
pub mod workload;

pub use probes::{thermo_code, ProbeCollector, ProbeSample, THERMO_LEVELS};
pub use sim::{FatTreeSim, SimConfig};
pub use topology::{Topology, N_MONITORED_QUEUES, N_PROBE_PATHS};
pub use workload::IncastWorkload;

//! In-process retraining: a bounded reservoir of recent labeled feature
//! vectors and a native-Rust refit — no Python anywhere in the loop.
//!
//! The refit is two-staged, mirroring how the paper's binary models are
//! produced offline:
//!
//! 1. **Centroid refit** ([`centroid_fit`]) — per-class majority vote
//!    over the packed sample bits.  This is the same machinery the
//!    scenario oracles train their seed models with
//!    ([`scenario::centroid_model`](crate::scenario::centroid_model)
//!    delegates here), so a retrained model is directly comparable to
//!    the model it replaces.
//! 2. **Optional STE fine-tune** ([`refit`] with `ste_epochs > 0`) — a
//!    straight-through-estimator pass over the training slice: latent
//!    integer weights are initialized from the centroid signs, each
//!    misclassified sample nudges the true class's latent weights toward
//!    its bits (and the predicted class's away), and the binarized signs
//!    are re-derived after every epoch.  Sample order is fixed and the
//!    only randomness is the seeded epoch-offset walk, so a refit is a
//!    pure function of `(samples, epochs, seed)`.

use crate::bnn::{words_for, BnnExecutor, BnnLayer, BnnModel, ModelMetrics, BLOCK_SIZE};

/// One labeled training sample: the packed BNN input that was scored
/// live, plus the oracle label for the packet that triggered it.
#[derive(Debug, Clone)]
pub struct Sample {
    pub packed: Vec<u32>,
    pub label: usize,
}

/// Bounded ring of the most recent labeled samples (recency-biased on
/// purpose: after drift, the freshest slice is the new distribution).
#[derive(Debug, Default)]
pub struct Reservoir {
    cap: usize,
    buf: std::collections::VecDeque<Sample>,
}

impl Reservoir {
    pub fn new(cap: usize) -> Self {
        Self { cap: cap.max(1), buf: std::collections::VecDeque::new() }
    }

    pub fn push(&mut self, packed: Vec<u32>, label: usize) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(Sample { packed, label });
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// The `take` freshest samples after skipping the `skip` freshest —
    /// newest first.  `split(h, t)` style callers use `recent(0, h)` as
    /// a holdout and `recent(h, t)` as the (disjoint) training slice.
    pub fn recent(&self, skip: usize, take: usize) -> Vec<&Sample> {
        self.buf.iter().rev().skip(skip).take(take).collect()
    }
}

/// Per-class majority-vote centroid model: two neurons, one layer, the
/// canonical seed shape for the paper's binary-feature use cases.  Class
/// scores are bit-agreement with the class centroid; an empty class
/// falls back to the complement of the other's centroid (maximally far),
/// and two empty classes yield the degenerate zero/ones pair.
pub fn centroid_fit(name: &str, in_bits: usize, class0: &[Vec<u32>], class1: &[Vec<u32>]) -> BnnModel {
    let in_words = words_for(in_bits);
    let majority = |vs: &[Vec<u32>]| -> Vec<u32> {
        let mut out = vec![0u32; in_words];
        for (w, slot) in out.iter_mut().enumerate() {
            for bit in 0..BLOCK_SIZE {
                let ones = vs.iter().filter(|v| (v[w] >> bit) & 1 == 1).count();
                if ones * 2 >= vs.len() && !vs.is_empty() {
                    *slot |= 1 << bit;
                }
            }
        }
        out
    };
    let complement = |v: &[u32]| v.iter().map(|w| !w).collect::<Vec<u32>>();
    let (c0, c1) = match (class0.is_empty(), class1.is_empty()) {
        (false, false) => (majority(class0), majority(class1)),
        (false, true) => {
            let c0 = majority(class0);
            let c1 = complement(&c0);
            (c0, c1)
        }
        (true, false) => {
            let c1 = majority(class1);
            (complement(&c1), c1)
        }
        (true, true) => (vec![0u32; in_words], vec![!0u32; in_words]),
    };
    let mut words = c0;
    words.extend_from_slice(&c1);
    let layer = BnnLayer::new(2, in_words, words).expect("centroid layer dimensions");
    BnnModel {
        name: name.to_string(),
        in_bits,
        neurons: vec![2],
        layers: vec![layer],
        metrics: ModelMetrics::default(),
    }
}

/// Latent-weight clamp for the STE pass: wide enough that a confident
/// sign survives a burst of outliers, small enough that the sign can
/// still flip within a few epochs of consistent disagreement.
const LATENT_CLAMP: i32 = 8;

/// Refit a candidate from labeled samples: centroid majority vote, then
/// `ste_epochs` straight-through fine-tune passes.  Deterministic for a
/// given `(samples, ste_epochs, seed)`.
pub fn refit(
    name: &str,
    in_bits: usize,
    samples: &[&Sample],
    ste_epochs: u32,
    seed: u64,
) -> BnnModel {
    let class0: Vec<Vec<u32>> = samples
        .iter()
        .filter(|s| s.label == 0)
        .map(|s| s.packed.clone())
        .collect();
    let class1: Vec<Vec<u32>> = samples
        .iter()
        .filter(|s| s.label != 0)
        .map(|s| s.packed.clone())
        .collect();
    let mut model = centroid_fit(name, in_bits, &class0, &class1);
    if ste_epochs == 0 || samples.is_empty() {
        return model;
    }

    let in_words = words_for(in_bits);
    let padded = in_words * BLOCK_SIZE;
    // Latent per-class per-bit weights: +clamp where the centroid bit is
    // set, −clamp otherwise (the straight-through "real" weights whose
    // signs are the binary model).
    let layer = &model.layers[0];
    let mut latent = vec![vec![0i32; padded]; 2];
    for (c, lat) in latent.iter_mut().enumerate() {
        let row = layer.row(c);
        for (b, l) in lat.iter_mut().enumerate() {
            let set = (row[b / BLOCK_SIZE] >> (b % BLOCK_SIZE)) & 1 == 1;
            *l = if set { LATENT_CLAMP } else { -LATENT_CLAMP };
        }
    }
    let bit = |v: &[u32], b: usize| (v[b / BLOCK_SIZE] >> (b % BLOCK_SIZE)) & 1 == 1;
    for epoch in 0..ste_epochs {
        // Seeded epoch offset: a cheap deterministic reshuffle that
        // avoids pathological sample-order lock-in without an RNG on
        // the sample data itself.
        let offset = ((seed.wrapping_add(u64::from(epoch)).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            >> 33) as usize)
            % samples.len().max(1);
        let mut exec = BnnExecutor::new(model.clone());
        let mut changed = false;
        for k in 0..samples.len() {
            let s = samples[(k + offset) % samples.len()];
            let truth = usize::from(s.label != 0);
            let pred = exec.classify(&s.packed);
            if pred == truth {
                continue;
            }
            changed = true;
            // Straight-through update: move the true class's latent
            // weights toward the sample bits, the mispredicting class's
            // away from them.
            for b in 0..padded {
                let x = if bit(&s.packed, b) { 1 } else { -1 };
                latent[truth][b] = (latent[truth][b] + x).clamp(-LATENT_CLAMP, LATENT_CLAMP);
                latent[pred][b] = (latent[pred][b] - x).clamp(-LATENT_CLAMP, LATENT_CLAMP);
            }
            // Re-binarize (sign function; 0 rounds up, matching the
            // packed ±1 convention where a set bit is +1).
            let mut words = vec![0u32; 2 * in_words];
            for (c, lat) in latent.iter().enumerate() {
                for (b, &l) in lat.iter().enumerate() {
                    if l >= 0 {
                        words[c * in_words + b / BLOCK_SIZE] |= 1 << (b % BLOCK_SIZE);
                    }
                }
            }
            model.layers[0] =
                BnnLayer::new(2, in_words, words).expect("fine-tuned layer dimensions");
            exec = BnnExecutor::new(model.clone());
        }
        if !changed {
            break; // converged on the training slice
        }
    }
    model
}

/// Labeled accuracy of `model` over `samples` (1.0 on an empty slice:
/// no evidence of error).
pub fn score(model: &BnnModel, samples: &[&Sample]) -> f64 {
    if samples.is_empty() {
        return 1.0;
    }
    let mut exec = BnnExecutor::new(model.clone());
    let correct = samples
        .iter()
        .filter(|s| exec.classify(&s.packed) == usize::from(s.label != 0))
        .count();
    correct as f64 / samples.len() as f64
}

/// Swap the two class rows of a single-layer two-class model — the
/// "sabotaged candidate" used to exercise gate rejection and probation
/// rollback: systematically wrong wherever the honest model is right.
pub fn invert_classes(model: &mut BnnModel) {
    let layer = &mut model.layers[0];
    debug_assert_eq!(layer.neurons, 2, "invert_classes expects a 2-class layer");
    let w = layer.in_words;
    let (a, b) = layer.words.split_at_mut(w);
    a.swap_with_slice(&mut b[..w]);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(words: [u32; 8], label: usize) -> Sample {
        Sample { packed: words.to_vec(), label }
    }

    /// Two well-separated clusters: class 0 near all-zeros, class 1 near
    /// all-ones, with per-sample noise bits.
    fn separable(n: usize) -> Vec<Sample> {
        (0..n)
            .map(|i| {
                let noise = 1u32 << (i % 32);
                if i % 2 == 0 {
                    sample([noise, 0, noise, 0, 0, 0, 0, 0], 0)
                } else {
                    sample([!noise, !0, !0, !noise, !0, !0, !0, !0], 1)
                }
            })
            .collect()
    }

    #[test]
    fn reservoir_is_bounded_and_recency_ordered() {
        let mut r = Reservoir::new(4);
        for i in 0..10u32 {
            r.push(vec![i], (i % 2) as usize);
        }
        assert_eq!(r.len(), 4);
        let newest: Vec<u32> = r.recent(0, 2).iter().map(|s| s.packed[0]).collect();
        assert_eq!(newest, vec![9, 8]);
        // Disjoint holdout/train split: skip the holdout.
        let train: Vec<u32> = r.recent(2, 2).iter().map(|s| s.packed[0]).collect();
        assert_eq!(train, vec![7, 6]);
    }

    #[test]
    fn centroid_refit_separates_clusters() {
        let samples = separable(40);
        let refs: Vec<&Sample> = samples.iter().collect();
        let model = refit("m", 256, &refs, 0, 7);
        assert!((score(&model, &refs) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ste_pass_never_degrades_separable_fit_and_is_deterministic() {
        let samples = separable(40);
        let refs: Vec<&Sample> = samples.iter().collect();
        let tuned = refit("m", 256, &refs, 3, 42);
        assert!((score(&tuned, &refs) - 1.0).abs() < 1e-12);
        let again = refit("m", 256, &refs, 3, 42);
        assert_eq!(tuned.layers[0].words, again.layers[0].words);
    }

    #[test]
    fn ste_survives_heavy_class_imbalance() {
        // 30:8 imbalance with the minority class carrying a narrow
        // signal (words 4–5 only).  The guard: STE's per-sample updates
        // must never undo a fit the centroid init already achieves, no
        // matter how lopsided the per-epoch update traffic is.
        let mut samples = Vec::new();
        for i in 0..30u32 {
            samples.push(sample([1 << (i % 32), 0, 0, 0, 0, 0, 0, 0], 0));
        }
        // 8 "hard" class-1 samples: weak signal, near the class-0 cloud.
        for i in 0..8u32 {
            samples.push(sample([1 << (i % 32), 0, 0, 0, !0, !0, 0, 0], 1));
        }
        let refs: Vec<&Sample> = samples.iter().collect();
        let plain = score(&refit("m", 256, &refs, 0, 7), &refs);
        let tuned = score(&refit("m", 256, &refs, 5, 7), &refs);
        assert!(tuned >= plain, "STE must not lose to its own init: {tuned} < {plain}");
        assert!(tuned > 0.95, "STE should nearly fit the training slice, got {tuned}");
    }

    #[test]
    fn empty_class_falls_back_to_complement() {
        let samples = separable(10);
        let zeros_only: Vec<&Sample> = samples.iter().filter(|s| s.label == 0).collect();
        let model = refit("m", 256, &zeros_only, 0, 7);
        // Class-0 samples still classify as 0 against the complement.
        assert!((score(&model, &zeros_only) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn inverted_candidate_is_systematically_wrong() {
        let samples = separable(40);
        let refs: Vec<&Sample> = samples.iter().collect();
        let mut model = refit("m", 256, &refs, 0, 7);
        invert_classes(&mut model);
        assert!(score(&model, &refs) < 0.05);
    }

    #[test]
    fn score_of_empty_slice_is_one() {
        let model = centroid_fit("m", 256, &[], &[]);
        assert_eq!(score(&model, &[]), 1.0);
    }
}

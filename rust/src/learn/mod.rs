//! Online learning: drift detection → in-process retraining → guarded
//! live republish.  The closed training loop over the registry's
//! zero-downtime hot swap (the paper's "monitoring models must track
//! live traffic" use case, §5).
//!
//! ```text
//!           ┌────────────── OnlineLearner (at ingress) ──────────────┐
//! packets ─►│ shadow flow table ─► route ─► classify ─► label oracle │
//!           │        │                          │                    │
//!           │   reservoir (labeled)      accuracy windows            │
//!           │        │                          │                    │
//!           │   trainer::refit ◄── DriftDetector (Page–Hinkley)      │
//!           │        │                                               │
//!           │   PromotionGate (holdout score, probation, rollback)   │
//!           └────────┼───────────────────────────────────────────────┘
//!                    ▼  (after a lane barrier: see below)
//!            ModelRegistry::publish / ::rollback
//! ```
//!
//! **Determinism contract.**  Everything runs on the packet clock: the
//! learner sees every packet exactly once at ingress (before fan-out in
//! the pipelined runtime), windows close at fixed packet counts, the
//! Page–Hinkley statistic is pure arithmetic, and the trainer is a pure
//! function of `(samples, epochs, seed)`.  A registry write would still
//! be racy in the pipelined mode — batch lanes downstream may hold
//! triggered flows that a worker could score before *or* after the
//! publish depending on thread timing — so every learner-driven write
//! is **two-phase**: `on_packet` only *stages* it (`commit` flag), the
//! runtime force-flushes all batch lanes (serial: directly; pipelined:
//! a barrier broadcast through the stages, acked back to ingress), and
//! only then calls [`OnlineLearner::commit_pending`].  The set of
//! verdicts scored under the old weights is therefore exactly "every
//! packet up to the committing one", in both runtimes.

pub mod drift;
pub mod gate;
pub mod trainer;

pub use drift::DriftDetector;
pub use gate::{GateMode, GateOutcome, PromotionGate};
pub use trainer::{centroid_fit, invert_classes, refit, Reservoir, Sample};

use std::sync::Arc;

use crate::bnn::{BnnModel, ModelEpoch, MultiModelExecutor, RegistryError, RegistryHandle};
use crate::coordinator::service::{select_packed_input, PacketEvent, RouteLogic};
use crate::net::flow::{EvictPolicy, ShardedFlowTable, FLOW_SHARDS};
use crate::net::packet::Packet;

/// Ground-truth oracle: the label of the flow this packet belongs to.
/// Scenario oracles derive this from the generator recipe; a live
/// deployment would plug in delayed feedback (IDS alerts, billing, …).
pub type LabelFn = Arc<dyn Fn(&Packet) -> usize + Send + Sync>;

/// Keep at most this many closed windows in the exported timeline (a
/// multi-hour serve would otherwise grow `ServiceStats` without bound).
const TIMELINE_CAP: usize = 4096;

/// Configuration of the online-learning loop for one registry slot.
#[derive(Clone)]
pub struct LearnSpec {
    /// Registry slot to watch and retrain.
    pub model: String,
    /// Ground-truth label oracle.
    pub labeler: LabelFn,
    /// Accuracy-window length on the packet clock.
    pub window_pkts: u64,
    /// Bounded labeled-sample reservoir capacity.
    pub reservoir: usize,
    /// Freshest samples reserved for gate scoring (never trained on).
    pub holdout: usize,
    /// Training-slice size (taken just below the holdout).
    pub train_recent: usize,
    /// Page–Hinkley noise tolerance δ.
    pub ph_delta: f64,
    /// Page–Hinkley firing threshold λ.
    pub ph_lambda: f64,
    /// Absolute holdout-accuracy floor for promotion (and, minus
    /// `rollback_drop`, the probation rollback floor).
    pub min_gate_accuracy: f64,
    /// How much a candidate must beat the live model by.
    pub gate_margin: f64,
    /// Post-swap probation length, in windows.
    pub probation_windows: u32,
    /// Probation tolerance below `min_gate_accuracy` before rollback.
    pub rollback_drop: f64,
    /// Straight-through fine-tune epochs on top of the centroid refit.
    pub ste_epochs: u32,
    /// Trainer seed (epoch-offset walk).
    pub seed: u64,
    /// Gate fault-injection mode (`Normal` in production).
    pub mode: GateMode,
}

impl LearnSpec {
    /// Defaults tuned for the drift scenario's window/accuracy scales.
    pub fn new(model: &str, labeler: LabelFn) -> Self {
        Self {
            model: model.to_string(),
            labeler,
            window_pkts: 250,
            reservoir: 512,
            holdout: 48,
            train_recent: 128,
            ph_delta: 0.05,
            ph_lambda: 0.6,
            min_gate_accuracy: 0.75,
            gate_margin: 0.05,
            probation_windows: 3,
            rollback_drop: 0.10,
            ste_epochs: 2,
            seed: 7,
            mode: GateMode::Normal,
        }
    }
}

impl std::fmt::Debug for LearnSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LearnSpec")
            .field("model", &self.model)
            .field("window_pkts", &self.window_pkts)
            .field("reservoir", &self.reservoir)
            .field("holdout", &self.holdout)
            .field("train_recent", &self.train_recent)
            .field("ph_delta", &self.ph_delta)
            .field("ph_lambda", &self.ph_lambda)
            .field("min_gate_accuracy", &self.min_gate_accuracy)
            .field("gate_margin", &self.gate_margin)
            .field("probation_windows", &self.probation_windows)
            .field("rollback_drop", &self.rollback_drop)
            .field("ste_epochs", &self.ste_epochs)
            .field("seed", &self.seed)
            .field("mode", &self.mode)
            .finish_non_exhaustive() // labeler is an opaque closure
    }
}

/// One closed accuracy window of one model — `ServiceStats::
/// accuracy_timeline` material.
#[derive(Debug, Clone, PartialEq)]
pub struct AccuracyWindow {
    pub model: String,
    /// Packet index (1-based, at ingress) at which the window closed.
    pub end_packet: u64,
    /// Labeled verdicts scored inside the window.
    pub evaluated: u64,
    pub correct: u64,
    /// Registry version serving when the window closed.
    pub version: u64,
}

impl AccuracyWindow {
    /// Labeled accuracy; windows with nothing evaluated read as perfect
    /// (no evidence of error — the detector skips them anyway).
    pub fn accuracy(&self) -> f64 {
        if self.evaluated == 0 {
            1.0
        } else {
            self.correct as f64 / self.evaluated as f64
        }
    }
}

/// Counters of the learning loop.  Merge semantics are explicit per
/// field (see [`merge`](Self::merge)) because exactly one learner runs
/// per service — the other side of a stage merge carries `None`.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct LearnStats {
    /// Accuracy windows closed.
    pub windows: u64,
    /// Labeled verdicts scored.
    pub evaluated: u64,
    /// Packet index at which drift first fired (never resets).
    pub drift_fired_at: Option<u64>,
    /// Retraining attempts (gate-accepted or not).
    pub retrains: u64,
    /// Candidates published through the gate.
    pub promotions: u64,
    /// Candidates the gate refused.
    pub rejections: u64,
    /// Probation rollbacks performed.
    pub rollbacks: u64,
    /// Accuracy of the last window with any evaluations.
    pub last_window_accuracy: f64,
    /// Last gate decision's candidate/current holdout scores.
    pub gate_last_candidate: Option<f64>,
    pub gate_last_current: Option<f64>,
    /// A promotion is currently on probation.
    pub in_probation: bool,
}

impl LearnStats {
    /// Fold `other` into `self`.  Counts add (partitions of the work);
    /// `drift_fired_at` takes the earliest firing; the `last_*` /
    /// `in_probation` point-in-time fields are taken from whichever side
    /// has closed windows (at most one side has, since one learner
    /// exists per service — when both have, `other` wins as the later
    /// snapshot).
    pub fn merge(&mut self, other: &LearnStats) {
        self.windows += other.windows;
        self.evaluated += other.evaluated;
        self.retrains += other.retrains;
        self.promotions += other.promotions;
        self.rejections += other.rejections;
        self.rollbacks += other.rollbacks;
        self.drift_fired_at = match (self.drift_fired_at, other.drift_fired_at) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        if other.windows > 0 {
            self.last_window_accuracy = other.last_window_accuracy;
            self.gate_last_candidate = other.gate_last_candidate;
            self.gate_last_current = other.gate_last_current;
            self.in_probation = other.in_probation;
        }
    }
}

/// The in-process learning loop: shadow flow state, per-window labeled
/// accuracy, drift detection, retraining, and the two-phase registry
/// writes.  Lives at ingress (exactly one per service run).
pub struct OnlineLearner {
    spec: LearnSpec,
    registry: RegistryHandle,
    /// Registry-reading executor of the watched slot (route 0 here).
    exec: MultiModelExecutor,
    /// Clone of the service's routing logic, replayed on the shadow
    /// table so the learner evaluates exactly the flows the service
    /// classifies.
    route: RouteLogic,
    /// The watched model's route index in the *service's* route space.
    route_idx: usize,
    /// Shadow replica of the service's flow state (same shard split and
    /// eviction policy ⇒ same per-flow feature stats).
    flows: ShardedFlowTable,
    reservoir: Reservoir,
    detector: DriftDetector,
    gate: PromotionGate,
    in_bits: usize,
    packets: u64,
    win_evaluated: u64,
    win_correct: u64,
    /// Drift fired and no candidate has been promoted yet: retrain at
    /// every window close until the gate accepts one.
    drifting: bool,
    /// One-shot admin-requested retrain at the next window close.
    forced: bool,
    pending_publish: Option<BnnModel>,
    pending_rollback: Option<Arc<ModelEpoch>>,
    stats: LearnStats,
    timeline: Vec<AccuracyWindow>,
}

impl OnlineLearner {
    /// `route`/`flow_capacity`/`evict`/`latency_ns` must mirror the
    /// service's own configuration — the shadow state is only a replica
    /// if it is built the same way.
    pub(crate) fn new(
        spec: LearnSpec,
        registry: RegistryHandle,
        route: RouteLogic,
        latency_ns: f64,
        flow_capacity: usize,
        evict: EvictPolicy,
    ) -> Result<Self, RegistryError> {
        let mut exec = MultiModelExecutor::new(&registry, &[spec.model.clone()], latency_ns)?;
        let in_bits = exec.epoch(0).in_words() * crate::bnn::BLOCK_SIZE;
        let route_idx = route
            .names()
            .and_then(|ns| ns.iter().position(|n| *n == spec.model))
            .unwrap_or(0);
        let detector = DriftDetector::new(spec.ph_delta, spec.ph_lambda);
        let gate = PromotionGate::new(
            spec.min_gate_accuracy,
            spec.gate_margin,
            spec.probation_windows,
            spec.rollback_drop,
            spec.mode,
        );
        let reservoir = Reservoir::new(spec.reservoir);
        Ok(Self {
            spec,
            registry,
            exec,
            route,
            route_idx,
            flows: ShardedFlowTable::with_total_capacity(FLOW_SHARDS, flow_capacity, evict),
            reservoir,
            detector,
            gate,
            in_bits,
            packets: 0,
            win_evaluated: 0,
            win_correct: 0,
            drifting: false,
            forced: false,
            pending_publish: None,
            pending_rollback: None,
            stats: LearnStats::default(),
            timeline: Vec::new(),
        })
    }

    /// Observe one ingress packet (call *after* the serving side has
    /// seen it).  Returns `true` when a registry write is staged: the
    /// caller must flush all batch lanes, then call
    /// [`commit_pending`](Self::commit_pending).
    pub fn on_packet(&mut self, ev: &PacketEvent) -> bool {
        self.packets += 1;
        if let Some(up) = self.flows.update(&ev.packet) {
            if self.route.route(&ev.packet, up.is_new, up.pkts) == Some(self.route_idx) {
                let packed = select_packed_input(ev, up.stats);
                let (class, _tag) = self.exec.classify(0, &packed);
                let label = (self.spec.labeler)(&ev.packet);
                self.win_evaluated += 1;
                self.stats.evaluated += 1;
                if class == usize::from(label != 0) {
                    self.win_correct += 1;
                }
                self.reservoir.push(packed, label);
            }
        }
        if self.spec.window_pkts > 0 && self.packets % self.spec.window_pkts == 0 {
            self.close_window();
        }
        self.pending_publish.is_some() || self.pending_rollback.is_some()
    }

    /// Admin surface hook (`POST /models/<name>/retrain`): one retrain
    /// attempt at the next window close, drift or no drift.
    pub fn request_retrain(&mut self) {
        self.forced = true;
    }

    fn close_window(&mut self) {
        let version = self.exec.epoch(0).version();
        let evaluated = std::mem::take(&mut self.win_evaluated);
        let correct = std::mem::take(&mut self.win_correct);
        self.stats.windows += 1;
        self.timeline.push(AccuracyWindow {
            model: self.spec.model.clone(),
            end_packet: self.packets,
            evaluated,
            correct,
            version,
        });
        if self.timeline.len() > TIMELINE_CAP {
            self.timeline.remove(0);
        }
        if evaluated == 0 {
            // No labeled verdicts: no signal.  The detector never sees
            // empty windows, so sparse traffic cannot fake a recovery.
            return;
        }
        let acc = correct as f64 / evaluated as f64;
        self.stats.last_window_accuracy = acc;
        if self.gate.in_probation() {
            // During probation the gate owns the verdict on this window;
            // the detector stays paused until the promotion settles.
            if let Some(pre) = self.gate.observe_window(acc) {
                self.pending_rollback = Some(pre);
            }
            return;
        }
        if self.detector.observe(1.0 - acc) && !self.drifting {
            self.drifting = true;
            if self.stats.drift_fired_at.is_none() {
                self.stats.drift_fired_at = Some(self.packets);
            }
        }
        if self.drifting || self.forced {
            self.forced = false;
            self.attempt_retrain();
        }
    }

    /// Refit a candidate from the reservoir and put it to the gate.
    /// While drift persists this runs at every window close: early
    /// candidates trained on a mixed pre/post-drift reservoir score low
    /// and are rejected; once post-drift samples dominate, one clears
    /// the gate and is staged for publish.
    fn attempt_retrain(&mut self) {
        let holdout = self.reservoir.recent(0, self.spec.holdout);
        let train = self.reservoir.recent(self.spec.holdout, self.spec.train_recent);
        if holdout.len() < self.spec.holdout || train.len() < self.spec.holdout {
            return; // not enough labeled evidence yet
        }
        self.stats.retrains += 1;
        let seed = self.spec.seed ^ self.stats.retrains.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut cand = trainer::refit(&self.spec.model, self.in_bits, &train, self.spec.ste_epochs, seed);
        self.gate.prepare(&mut cand);
        let cand_acc = trainer::score(&cand, &holdout);
        let mut cur_correct = 0usize;
        for s in &holdout {
            let (class, _) = self.exec.classify(0, &s.packed);
            if class == usize::from(s.label != 0) {
                cur_correct += 1;
            }
        }
        let cur_acc = cur_correct as f64 / holdout.len() as f64;
        match self.gate.decide(cand_acc, cur_acc) {
            GateOutcome::Promote { .. } => self.pending_publish = Some(cand),
            GateOutcome::Reject { .. } => self.stats.rejections += 1,
        }
    }

    /// Perform the staged registry write.  Only call after every batch
    /// lane has been force-flushed (see the module docs) — this is what
    /// keeps pipelined verdicts identical to serial ones across a swap.
    pub fn commit_pending(&mut self) -> Result<(), RegistryError> {
        if let Some(pre) = self.pending_rollback.take() {
            self.registry.rollback(&self.spec.model, &pre)?;
            self.stats.rollbacks += 1;
            // The rolled-back-to model is still the one drift defeated:
            // stay in the retrain loop, but re-baseline the detector so
            // it doesn't refire on the same evidence.
            self.drifting = true;
            self.detector.reset();
        }
        if let Some(cand) = self.pending_publish.take() {
            let pre = self.registry.current(&self.spec.model);
            self.registry.publish(&self.spec.model, &cand)?;
            self.stats.promotions += 1;
            if let Some(pre) = pre {
                self.gate.begin_probation(pre);
            }
            self.drifting = false;
            self.detector.reset();
        }
        Ok(())
    }

    /// Copy the learn telemetry into a stats snapshot (live admin
    /// scrapes and the final report).
    pub fn publish_into(&mut self, stats: &mut crate::coordinator::ServiceStats) {
        self.stats.in_probation = self.gate.in_probation();
        self.stats.gate_last_candidate = self.gate.last_candidate;
        self.stats.gate_last_current = self.gate.last_current;
        stats.learn = Some(self.stats.clone());
        stats.accuracy_timeline = self.timeline.clone();
    }

    /// Disable further learner activity (a stage already failed; a
    /// half-coordinated publish would do more harm than stale weights).
    pub fn poison(&mut self) {
        self.spec.window_pkts = 0;
        self.pending_publish = None;
        self.pending_rollback = None;
    }

    pub fn stats(&self) -> &LearnStats {
        &self.stats
    }

    pub fn timeline(&self) -> &[AccuracyWindow] {
        &self.timeline
    }

    pub fn model_name(&self) -> &str {
        &self.spec.model
    }
}

/// Mean accuracy over the last `k` windows that evaluated anything —
/// the scenario's "recovered" measurement.
pub fn recovery_accuracy(timeline: &[AccuracyWindow], k: usize) -> f64 {
    let tail: Vec<&AccuracyWindow> =
        timeline.iter().rev().filter(|w| w.evaluated > 0).take(k.max(1)).collect();
    if tail.is_empty() {
        return 1.0;
    }
    let (c, e) = tail.iter().fold((0u64, 0u64), |(c, e), w| (c + w.correct, e + w.evaluated));
    c as f64 / e as f64
}

/// Lowest window accuracy observed (only windows that evaluated
/// anything) — the scenario's "accuracy actually fell" evidence.
pub fn min_window_accuracy(timeline: &[AccuracyWindow]) -> f64 {
    timeline
        .iter()
        .filter(|w| w.evaluated > 0)
        .map(AccuracyWindow::accuracy)
        .fold(1.0, f64::min)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trigger::TriggerCondition;
    use crate::net::packet::{Packet, Proto};

    /// Payload patterns: class 0 lives near all-zeros, the pre-drift
    /// class 1 near all-ones, and the *drifted* class 1 in a pattern the
    /// seed model reads as class 0 (closer to the zeros centroid).
    const ZEROS: [u32; 8] = [0; 8];
    const ONES: [u32; 8] = [!0; 8];
    const DRIFTED: [u32; 8] = [0, 0, 0, 0, 0, 0, !0, !0];

    fn seed_model() -> BnnModel {
        centroid_fit("m", 256, &[ZEROS.to_vec()], &[ONES.to_vec()])
    }

    /// Label oracle: src prefix 0x0C ⇒ class 1.
    fn labeler() -> LabelFn {
        Arc::new(|p: &Packet| usize::from(p.src_ip >> 24 == 0x0C))
    }

    fn event(i: u64, class1: bool, payload: [u32; 8]) -> PacketEvent {
        PacketEvent {
            packet: Packet {
                ts_ns: i as f64 * 100.0,
                src_ip: if class1 { 0x0C00_0000 + (i % 13) as u32 } else { 0x0A00_0000 + (i % 17) as u32 },
                dst_ip: 0x0B00_0001,
                src_port: 1000 + (i % 7) as u16,
                dst_port: 443,
                proto: Proto::Tcp,
                size: 256,
                tcp_flags: 0x10,
            },
            payload_words: Some(payload.to_vec()),
        }
    }

    fn learner(spec: LearnSpec) -> (OnlineLearner, RegistryHandle) {
        let reg = RegistryHandle::new();
        reg.publish("m", &seed_model()).unwrap();
        let l = OnlineLearner::new(
            spec,
            reg.clone(),
            RouteLogic::Trigger(TriggerCondition::EveryPacket),
            60.0,
            1 << 12,
            EvictPolicy::Lru,
        )
        .unwrap();
        (l, reg)
    }

    fn spec() -> LearnSpec {
        let mut s = LearnSpec::new("m", labeler());
        s.window_pkts = 50;
        s.holdout = 16;
        s.train_recent = 48;
        s.reservoir = 128;
        s
    }

    /// Drive `n` packets: alternate benign/class-1, class-1 payload per
    /// `drifted`.  Commits staged writes immediately (no batching here).
    fn drive(l: &mut OnlineLearner, start: u64, n: u64, drifted: bool) {
        for i in start..start + n {
            let class1 = i % 2 == 0;
            let payload = if !class1 {
                ZEROS
            } else if drifted {
                DRIFTED
            } else {
                ONES
            };
            if l.on_packet(&event(i, class1, payload)) {
                l.commit_pending().unwrap();
            }
        }
    }

    #[test]
    fn stable_traffic_never_retrains() {
        let (mut l, _reg) = learner(spec());
        drive(&mut l, 0, 2000, false);
        assert!(l.stats().drift_fired_at.is_none());
        assert_eq!(l.stats().retrains, 0);
        assert!(l.stats().last_window_accuracy > 0.99);
        assert_eq!(l.stats().windows, 40);
    }

    #[test]
    fn drift_fires_retrains_and_recovers() {
        let (mut l, reg) = learner(spec());
        drive(&mut l, 0, 1000, false);
        assert!(l.stats().drift_fired_at.is_none());
        drive(&mut l, 1000, 2000, true);
        let st = l.stats().clone();
        assert!(st.drift_fired_at.is_some(), "drift must fire: {st:?}");
        assert!(st.promotions >= 1, "a candidate must be promoted: {st:?}");
        assert!(reg.current("m").unwrap().version() > 1, "registry republished");
        assert!(st.last_window_accuracy > 0.9, "recovered: {st:?}");
        assert!(recovery_accuracy(l.timeline(), 4) > 0.9);
        assert!(min_window_accuracy(l.timeline()) < 0.6, "the dip is visible");
    }

    #[test]
    fn drift_firing_packet_is_deterministic() {
        let run = || {
            let (mut l, _reg) = learner(spec());
            drive(&mut l, 0, 1000, false);
            drive(&mut l, 1000, 1500, true);
            (l.stats().drift_fired_at, l.stats().promotions)
        };
        let (fired, promos) = run();
        assert!(fired.is_some());
        assert_eq!((fired, promos), run());
    }

    #[test]
    fn sabotage_mode_rejects_every_candidate() {
        let mut s = spec();
        s.mode = GateMode::SabotageCandidate;
        let (mut l, reg) = learner(s);
        drive(&mut l, 0, 1000, false);
        drive(&mut l, 1000, 2000, true);
        let st = l.stats();
        assert!(st.drift_fired_at.is_some());
        assert!(st.retrains >= 1);
        assert_eq!(st.promotions, 0, "{st:?}");
        assert!(st.rejections >= st.retrains, "every attempt rejected: {st:?}");
        assert_eq!(reg.current("m").unwrap().version(), 1, "nothing published");
    }

    #[test]
    fn force_accept_rolls_back_then_recovers() {
        let mut s = spec();
        s.mode = GateMode::ForceAccept;
        let (mut l, reg) = learner(s);
        drive(&mut l, 0, 1000, false);
        drive(&mut l, 1000, 2500, true);
        let st = l.stats().clone();
        assert!(st.rollbacks >= 1, "probation must catch the bad forced model: {st:?}");
        assert!(st.promotions >= 2, "forced promotion + honest recovery: {st:?}");
        assert!(st.last_window_accuracy > 0.9, "recovered after rollback: {st:?}");
        // Rollback bumps the slot version too: publish(bad) + rollback +
        // publish(good) ⇒ at least v4.
        assert!(reg.current("m").unwrap().version() >= 4);
    }

    #[test]
    fn forced_retrain_is_one_shot_and_gated() {
        let (mut l, _reg) = learner(spec());
        drive(&mut l, 0, 600, false);
        assert_eq!(l.stats().retrains, 0);
        l.request_retrain();
        drive(&mut l, 600, 100, false);
        // One attempt; same-distribution candidate can't beat the live
        // model by the margin, so it is rejected — and not retried.
        assert_eq!(l.stats().retrains, 1);
        assert_eq!(l.stats().rejections, 1);
        assert_eq!(l.stats().promotions, 0);
        drive(&mut l, 700, 500, false);
        assert_eq!(l.stats().retrains, 1);
    }

    #[test]
    fn learn_stats_merge_is_explicit_per_field() {
        let mut a = LearnStats {
            windows: 2,
            evaluated: 10,
            drift_fired_at: Some(500),
            retrains: 1,
            promotions: 1,
            rejections: 0,
            rollbacks: 0,
            last_window_accuracy: 0.5,
            gate_last_candidate: Some(0.9),
            gate_last_current: Some(0.4),
            in_probation: true,
        };
        let b = LearnStats {
            windows: 3,
            evaluated: 20,
            drift_fired_at: Some(250),
            retrains: 2,
            promotions: 0,
            rejections: 2,
            rollbacks: 1,
            last_window_accuracy: 0.8,
            gate_last_candidate: Some(0.7),
            gate_last_current: Some(0.6),
            in_probation: false,
        };
        a.merge(&b);
        assert_eq!(a.windows, 5);
        assert_eq!(a.evaluated, 30);
        assert_eq!(a.drift_fired_at, Some(250), "earliest firing wins");
        assert_eq!(a.retrains, 3);
        assert_eq!(a.promotions, 1);
        assert_eq!(a.rejections, 2);
        assert_eq!(a.rollbacks, 1);
        assert_eq!(a.last_window_accuracy, 0.8, "later snapshot wins");
        assert!(!a.in_probation);
        // The empty side of a stage merge changes nothing.
        let snapshot = a.clone();
        a.merge(&LearnStats::default());
        assert_eq!(a, snapshot);
    }

    #[test]
    fn timeline_is_bounded() {
        let mut s = spec();
        s.window_pkts = 1;
        let (mut l, _reg) = learner(s);
        for i in 0..(TIMELINE_CAP as u64 + 100) {
            // Benign-only traffic: windows close every packet.
            if l.on_packet(&event(i * 2 + 1, false, ZEROS)) {
                l.commit_pending().unwrap();
            }
        }
        assert_eq!(l.timeline().len(), TIMELINE_CAP);
    }
}

//! Guarded republish: holdout-scored promotion plus post-swap probation.
//!
//! A retrained candidate never reaches [`ModelRegistry::publish`]
//! (crate::bnn::ModelRegistry::publish) directly.  The
//! [`PromotionGate`] first scores it on a holdout slice the trainer
//! never saw and promotes only if the candidate (a) clears an absolute
//! accuracy floor and (b) beats the currently-served model by a margin.
//! After a promotion the gate runs a **probation window**: if the
//! freshly-served model's windowed live accuracy falls below
//! `min_accuracy − rollback_drop`, the gate hands back the pre-swap
//! epoch for an automatic [`rollback`](crate::bnn::ModelRegistry::rollback).
//!
//! The probation floor is deliberately *absolute* — not relative to the
//! candidate's own gate score.  A relative rule would let a bad
//! candidate that promised little escape rollback by delivering little.
//!
//! [`GateMode`] exists for the acceptance tests: `SabotageCandidate`
//! inverts every candidate's class rows (the gate must then reject every
//! attempt), and `ForceAccept` inverts *and* bypasses the gate exactly
//! once (the probation check must then catch the regression and roll
//! back).

use std::sync::Arc;

use crate::bnn::{BnnModel, ModelEpoch};

use super::trainer::invert_classes;

/// How the gate treats candidates — `Normal` in production; the other
/// modes are fault-injection switches for the drift scenario's
/// gate-rejection and auto-rollback acceptance runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GateMode {
    /// Honest candidates, gate enforced.
    #[default]
    Normal,
    /// Every candidate is class-inverted before scoring; the gate is
    /// expected to reject all of them (promotions stay at zero).
    SabotageCandidate,
    /// The *first* candidate is class-inverted and published without
    /// consulting the gate; afterwards the mode degenerates to
    /// `Normal` so the scenario can recover post-rollback.
    ForceAccept,
}

impl GateMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "normal" => Some(Self::Normal),
            "sabotage" => Some(Self::SabotageCandidate),
            "force-accept" => Some(Self::ForceAccept),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Self::Normal => "normal",
            Self::SabotageCandidate => "sabotage",
            Self::ForceAccept => "force-accept",
        }
    }
}

/// Verdict on one candidate.
#[derive(Debug, Clone, PartialEq)]
pub enum GateOutcome {
    /// Publish the candidate; `forced` marks a `ForceAccept` bypass.
    Promote { forced: bool },
    /// Keep serving the current model.
    Reject { candidate: f64, current: f64 },
}

#[derive(Debug)]
struct Probation {
    /// Epoch served before the swap — the rollback target.
    pre: Arc<ModelEpoch>,
    windows_left: u32,
}

/// Holdout-scored promotion gate with post-swap probation.
#[derive(Debug)]
pub struct PromotionGate {
    /// Absolute holdout-accuracy floor a candidate must clear.
    pub min_accuracy: f64,
    /// How much better than the live model the candidate must score.
    pub margin: f64,
    /// Windows of post-swap probation before a promotion is final.
    pub probation_windows: u32,
    /// Probation tolerance below `min_accuracy` before auto-rollback.
    pub rollback_drop: f64,
    mode: GateMode,
    /// `ForceAccept` fires once; afterwards the gate behaves normally.
    forced_done: bool,
    probation: Option<Probation>,
    /// Last candidate/current holdout scores (admin `/stats` telemetry).
    pub last_candidate: Option<f64>,
    pub last_current: Option<f64>,
}

impl PromotionGate {
    pub fn new(
        min_accuracy: f64,
        margin: f64,
        probation_windows: u32,
        rollback_drop: f64,
        mode: GateMode,
    ) -> Self {
        Self {
            min_accuracy,
            margin,
            probation_windows,
            rollback_drop,
            mode,
            forced_done: false,
            probation: None,
            last_candidate: None,
            last_current: None,
        }
    }

    /// Apply the fault-injection mode to a fresh candidate (class
    /// inversion under `SabotageCandidate`, and under `ForceAccept`
    /// until its one bypass has fired).
    pub fn prepare(&self, candidate: &mut BnnModel) {
        match self.mode {
            GateMode::Normal => {}
            GateMode::SabotageCandidate => invert_classes(candidate),
            GateMode::ForceAccept if !self.forced_done => invert_classes(candidate),
            GateMode::ForceAccept => {}
        }
    }

    /// Score-based promotion decision for a prepared candidate.
    pub fn decide(&mut self, candidate_acc: f64, current_acc: f64) -> GateOutcome {
        self.last_candidate = Some(candidate_acc);
        self.last_current = Some(current_acc);
        if self.mode == GateMode::ForceAccept && !self.forced_done {
            self.forced_done = true;
            return GateOutcome::Promote { forced: true };
        }
        if candidate_acc >= self.min_accuracy && candidate_acc >= current_acc + self.margin {
            GateOutcome::Promote { forced: false }
        } else {
            GateOutcome::Reject { candidate: candidate_acc, current: current_acc }
        }
    }

    /// Arm probation after a publish: `pre` is the epoch to restore if
    /// the promotion regresses live accuracy.
    pub fn begin_probation(&mut self, pre: Arc<ModelEpoch>) {
        self.probation = Some(Probation { pre, windows_left: self.probation_windows.max(1) });
    }

    /// Feed one closed accuracy window.  Returns the pre-swap epoch when
    /// the promoted model must be rolled back; `None` otherwise.
    pub fn observe_window(&mut self, accuracy: f64) -> Option<Arc<ModelEpoch>> {
        self.probation.as_ref()?;
        if accuracy < self.min_accuracy - self.rollback_drop {
            return self.probation.take().map(|p| p.pre);
        }
        let p = self.probation.as_mut().expect("checked above");
        p.windows_left -= 1;
        if p.windows_left == 0 {
            self.probation = None; // promotion is final
        }
        None
    }

    pub fn in_probation(&self) -> bool {
        self.probation.is_some()
    }

    pub fn mode(&self) -> GateMode {
        self.mode
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::{BnnModel, ModelRegistry};

    fn gate(mode: GateMode) -> PromotionGate {
        PromotionGate::new(0.75, 0.05, 3, 0.10, mode)
    }

    fn epoch() -> Arc<ModelEpoch> {
        let reg = ModelRegistry::new();
        reg.publish("m", &BnnModel::random("m", 64, &[2], 3)).unwrap();
        reg.current("m").unwrap()
    }

    #[test]
    fn promotes_only_above_floor_and_margin() {
        let mut g = gate(GateMode::Normal);
        assert_eq!(g.decide(0.9, 0.5), GateOutcome::Promote { forced: false });
        // Clears the floor but not the margin over the live model.
        assert!(matches!(g.decide(0.80, 0.78), GateOutcome::Reject { .. }));
        // Beats the live model but misses the absolute floor.
        assert!(matches!(g.decide(0.70, 0.20), GateOutcome::Reject { .. }));
        assert_eq!(g.last_candidate, Some(0.70));
        assert_eq!(g.last_current, Some(0.20));
    }

    #[test]
    fn sabotage_mode_inverts_and_normal_gate_still_applies() {
        let g = gate(GateMode::SabotageCandidate);
        let mut m = BnnModel::random("m", 64, &[2], 3);
        let before = m.layers[0].words.clone();
        g.prepare(&mut m);
        assert_ne!(m.layers[0].words, before);
        // Rows swapped, nothing lost.
        let w = m.layers[0].in_words;
        assert_eq!(&m.layers[0].words[..w], &before[w..]);
        assert_eq!(&m.layers[0].words[w..], &before[..w]);
    }

    #[test]
    fn force_accept_bypasses_exactly_once() {
        let mut g = gate(GateMode::ForceAccept);
        let mut m = BnnModel::random("m", 64, &[2], 3);
        let before = m.layers[0].words.clone();
        g.prepare(&mut m);
        assert_ne!(m.layers[0].words, before, "first candidate is sabotaged");
        assert_eq!(g.decide(0.0, 0.9), GateOutcome::Promote { forced: true });
        // Second attempt: honest candidate, honest gate.
        let mut m2 = BnnModel::random("m", 64, &[2], 4);
        let before2 = m2.layers[0].words.clone();
        g.prepare(&mut m2);
        assert_eq!(m2.layers[0].words, before2);
        assert!(matches!(g.decide(0.0, 0.9), GateOutcome::Reject { .. }));
        assert_eq!(g.decide(0.95, 0.1), GateOutcome::Promote { forced: false });
    }

    #[test]
    fn probation_rolls_back_on_absolute_floor_not_relative() {
        let mut g = gate(GateMode::Normal);
        let pre = epoch();
        g.begin_probation(Arc::clone(&pre));
        assert!(g.in_probation());
        // Floor is min_accuracy − rollback_drop = 0.65, regardless of
        // what the candidate scored at the gate.
        assert!(g.observe_window(0.66).is_none());
        let rolled = g.observe_window(0.10).expect("must roll back");
        assert_eq!(rolled.version(), pre.version());
        assert!(!g.in_probation());
    }

    #[test]
    fn probation_clears_after_configured_windows() {
        let mut g = gate(GateMode::Normal);
        g.begin_probation(epoch());
        assert!(g.observe_window(0.9).is_none());
        assert!(g.observe_window(0.9).is_none());
        assert!(g.observe_window(0.9).is_none());
        assert!(!g.in_probation(), "3 clean windows end probation");
        // Out of probation: even a terrible window is the detector's
        // problem now, not the gate's.
        assert!(g.observe_window(0.0).is_none());
    }
}

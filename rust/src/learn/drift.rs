//! Page–Hinkley change-point detection on the per-window error rate.
//!
//! The online-learning loop closes an accuracy window every
//! [`LearnSpec::window_pkts`](super::LearnSpec) packets on the *packet
//! clock* and feeds the window's error rate (1 − labeled accuracy) to
//! this detector.  Everything here is pure integer/float arithmetic over
//! the observed sequence — no wall time, no randomness — so the packet
//! index at which drift fires is a deterministic function of the traffic
//! stream, and serial, pipelined, and offline-replay runs all fire at
//! the same window boundary.
//!
//! The test is the classic Page–Hinkley statistic for upward mean shift:
//! after each observation `x_t` with running mean `x̄_t`,
//!
//! ```text
//! m_t = Σ_{i≤t} (x_i − x̄_i − δ)        (cumulative deviation)
//! PH_t = m_t − min_{i≤t} m_i           (rise above the low-water mark)
//! ```
//!
//! drift fires when `PH_t > λ`.  `δ` absorbs the pre-drift noise floor
//! (small window-to-window accuracy jitter); `λ` sets how much sustained
//! regression is required before the trainer is woken up.

/// Seeded Page–Hinkley test for an upward shift in window error rate.
#[derive(Debug, Clone)]
pub struct DriftDetector {
    /// Minimum magnitude of change to accumulate (noise tolerance).
    delta: f64,
    /// Detection threshold on the PH statistic.
    lambda: f64,
    /// Observations so far (for the running mean).
    n: u64,
    /// Running mean of the observed error rates.
    mean: f64,
    /// Cumulative deviation `m_t`.
    cum: f64,
    /// Low-water mark `min m_i`.
    cum_min: f64,
}

impl DriftDetector {
    pub fn new(delta: f64, lambda: f64) -> Self {
        Self { delta, lambda, n: 0, mean: 0.0, cum: 0.0, cum_min: 0.0 }
    }

    /// Feed one window's error rate; returns `true` when the cumulative
    /// upward deviation crosses `lambda` — the drift signal.
    pub fn observe(&mut self, error_rate: f64) -> bool {
        self.n += 1;
        self.mean += (error_rate - self.mean) / self.n as f64;
        self.cum += error_rate - self.mean - self.delta;
        if self.cum < self.cum_min {
            self.cum_min = self.cum;
        }
        self.cum - self.cum_min > self.lambda
    }

    /// Current PH statistic (telemetry; `> lambda` means fired).
    pub fn statistic(&self) -> f64 {
        self.cum - self.cum_min
    }

    /// Forget all history — called after a promotion or rollback so the
    /// detector re-baselines on the freshly served model.
    pub fn reset(&mut self) {
        self.n = 0;
        self.mean = 0.0;
        self.cum = 0.0;
        self.cum_min = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_error_rate_never_fires() {
        let mut d = DriftDetector::new(0.05, 0.6);
        for i in 0..200 {
            // 3–7% error, jittering deterministically.
            let x = 0.05 + 0.02 * f64::from(i % 3) - 0.02;
            assert!(!d.observe(x), "fired on stable stream at window {i}");
        }
        assert!(d.statistic() <= 0.6);
    }

    #[test]
    fn step_change_fires_within_a_few_windows() {
        let mut d = DriftDetector::new(0.05, 0.6);
        for _ in 0..20 {
            assert!(!d.observe(0.05));
        }
        // Accuracy collapses: 75% error per window.
        let mut fired_at = None;
        for w in 0..10 {
            if d.observe(0.75) {
                fired_at = Some(w);
                break;
            }
        }
        // (0.75 − mean − δ) ≈ 0.6 per window → fires by the second.
        assert!(fired_at.is_some_and(|w| w <= 2), "{fired_at:?}");
    }

    #[test]
    fn firing_window_is_deterministic_across_reruns() {
        let run = || {
            let mut d = DriftDetector::new(0.05, 0.6);
            let mut fired = None;
            for w in 0..100u32 {
                let x = if w < 40 { 0.08 } else { 0.7 };
                if d.observe(x) && fired.is_none() {
                    fired = Some(w);
                }
            }
            fired
        };
        let a = run();
        assert_eq!(a, run());
        assert!(a.is_some());
    }

    #[test]
    fn reset_rebaselines() {
        let mut d = DriftDetector::new(0.05, 0.6);
        for _ in 0..10 {
            d.observe(0.05);
        }
        while !d.observe(0.9) {}
        d.reset();
        assert_eq!(d.statistic(), 0.0);
        // The new baseline *is* the high error rate: no refire.
        for _ in 0..50 {
            assert!(!d.observe(0.9));
        }
    }

    #[test]
    fn slow_ramp_still_fires() {
        let mut d = DriftDetector::new(0.02, 0.5);
        let mut fired = false;
        for w in 0..200 {
            let x = 0.05 + 0.005 * f64::from(w); // +0.5% error per window
            if d.observe(x.min(0.95)) {
                fired = true;
                break;
            }
        }
        assert!(fired, "ramp to 95% error must eventually fire");
    }
}

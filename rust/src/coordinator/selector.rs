//! Input/output selectors (§3.2, Fig. 7): where the NN executor's input
//! comes from and where its verdict goes.  "When the input and output
//! selectors are configured to read or to write to a packet field, the NN
//! Executor works as an inline module."

use crate::net::features::FeatureVector;
use crate::net::flow::FlowStats;

/// Where the NN input comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputSelector {
    /// Read packed words directly from a packet field offset (inline mode:
    /// e.g. probe payloads carrying delay vectors).
    PacketField { offset: usize },
    /// Read from a memory region (collected flow statistics).
    FlowStats,
}

/// Where the inference result goes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutputSelector {
    /// Write the class into a packet field (inline tagging: the forwarding
    /// module can match on it for flow steering).
    PacketField { offset: usize },
    /// Write into a memory region the host can DMA (the shunting path).
    Memory,
}

/// Materialized NN input with provenance.
#[derive(Debug, Clone)]
pub struct SelectedInput {
    pub packed: Vec<u32>,
}

impl InputSelector {
    /// Build the packed input for an event.
    pub fn select(
        &self,
        payload_words: Option<&[u32]>,
        stats: Option<&FlowStats>,
        in_words: usize,
    ) -> Option<SelectedInput> {
        match self {
            InputSelector::PacketField { offset } => {
                let w = payload_words?;
                if w.len() < offset + in_words {
                    return None;
                }
                Some(SelectedInput {
                    packed: w[*offset..offset + in_words].to_vec(),
                })
            }
            InputSelector::FlowStats => {
                let s = stats?;
                let fv = FeatureVector::from_stats(s);
                Some(SelectedInput {
                    packed: fv.pack().to_vec(),
                })
            }
        }
    }
}

/// Verdict sink with both destinations observable (tests/metrics).
#[derive(Debug, Default, Clone)]
pub struct OutputSink {
    /// (flow/packet tag, class) pairs written to packet fields.
    pub inline_tags: Vec<(u64, usize)>,
    /// Classes written to the shared memory region.
    pub memory: Vec<(u64, usize)>,
}

impl OutputSink {
    pub fn write(&mut self, sel: OutputSelector, id: u64, class: usize) {
        match sel {
            OutputSelector::PacketField { .. } => self.inline_tags.push((id, class)),
            OutputSelector::Memory => self.memory.push((id, class)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::packet::{Packet, Proto};

    #[test]
    fn packet_field_selection() {
        let words: Vec<u32> = (0..12).collect();
        let sel = InputSelector::PacketField { offset: 2 };
        let got = sel.select(Some(&words), None, 8).unwrap();
        assert_eq!(got.packed, (2..10).collect::<Vec<u32>>());
        // Too-short payload → None.
        assert!(sel.select(Some(&words[..5]), None, 8).is_none());
        assert!(sel.select(None, None, 8).is_none());
    }

    #[test]
    fn flow_stats_selection_matches_feature_pack() {
        let mut s = FlowStats::default();
        let p = Packet {
            ts_ns: 10.0,
            src_ip: 1,
            dst_ip: 2,
            src_port: 7,
            dst_port: 443,
            proto: Proto::Tcp,
            size: 900,
            tcp_flags: 0x12,
        };
        s.update(&p, true);
        let sel = InputSelector::FlowStats;
        let got = sel.select(None, Some(&s), 8).unwrap();
        assert_eq!(got.packed, FeatureVector::from_stats(&s).pack().to_vec());
    }

    #[test]
    fn output_sink_routes() {
        let mut sink = OutputSink::default();
        sink.write(OutputSelector::Memory, 1, 0);
        sink.write(OutputSelector::PacketField { offset: 0 }, 2, 1);
        assert_eq!(sink.memory, vec![(1, 0)]);
        assert_eq!(sink.inline_tags, vec![(2, 1)]);
    }
}

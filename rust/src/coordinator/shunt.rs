//! Flow shunting (§5 #1, Fig. 11): N3IC pre-classifies on the NIC and
//! forwards only the "needs deeper analysis" share to the host
//! middlebox, splitting the classification task across the PCIe boundary.

use super::plane::InferencePlane;

/// Where a flow goes after NIC pre-classification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShuntDecision {
    /// Handled entirely on the NIC (e.g. class == P2P → police/steer).
    Nic(usize),
    /// Escalated to the host for fine-grained classification.
    Host,
}

/// Router: class `nic_class` is terminal on the NIC; everything else is
/// shunted to the host.  The NIC-side classifier is any
/// [`InferencePlane`] backend.
pub struct ShuntRouter<E: InferencePlane> {
    pub nic_exec: E,
    /// Class the NIC handles terminally (paper: P2P = 1).
    pub nic_class: usize,
    pub stats: ShuntStats,
}

/// Counters for the shunting split.
#[derive(Debug, Default, Clone, Copy)]
pub struct ShuntStats {
    pub total: u64,
    pub kept_on_nic: u64,
    pub sent_to_host: u64,
}

impl ShuntStats {
    /// Fraction of traffic the host no longer sees.
    pub fn offload_ratio(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.kept_on_nic as f64 / self.total as f64
        }
    }
}

impl<E: InferencePlane> ShuntRouter<E> {
    pub fn new(nic_exec: E, nic_class: usize) -> Self {
        Self {
            nic_exec,
            nic_class,
            stats: ShuntStats::default(),
        }
    }

    /// Classify on the NIC and decide the flow's path.
    pub fn route(&mut self, x: &[u32]) -> ShuntDecision {
        self.stats.total += 1;
        let (class, _tag) = self.nic_exec.classify(0, x);
        if class == self.nic_class {
            self.stats.kept_on_nic += 1;
            ShuntDecision::Nic(class)
        } else {
            self.stats.sent_to_host += 1;
            ShuntDecision::Host
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::{BnnLayer, BnnModel};
    use crate::coordinator::BackendFactory;

    #[test]
    fn router_splits_and_counts() {
        let model = BnnModel::random("traffic", 256, &[32, 16, 2], 5);
        let mut router = ShuntRouter::new(BackendFactory::single("fpga", model).unwrap(), 1);
        let mut nic = 0;
        let mut host = 0;
        for seed in 0..200 {
            let x = BnnLayer::random(1, 256, seed).words;
            match router.route(&x) {
                ShuntDecision::Nic(c) => {
                    assert_eq!(c, 1);
                    nic += 1;
                }
                ShuntDecision::Host => host += 1,
            }
        }
        assert_eq!(router.stats.total, 200);
        assert_eq!(router.stats.kept_on_nic, nic);
        assert_eq!(router.stats.sent_to_host, host);
        assert!(
            (router.stats.offload_ratio() - nic as f64 / 200.0).abs() < 1e-12
        );
        // A random model splits both ways on random inputs.
        assert!(nic > 0 && host > 0, "nic={nic} host={host}");
    }
}

//! Trigger conditions (§3.2/§4.1): when the forwarding module hands a
//! flow to the NN executor.  "Typical conditions could be the arrival of
//! a new flow, the reception of a predefined number of packets for a
//! given flow, the parsing of a given value in a packet header."

use crate::net::flow::ShardedFlowTable;
use crate::net::packet::Packet;

/// When to fire the NN executor for a packet/flow event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TriggerCondition {
    /// Fire on the first packet of every new flow.
    NewFlow,
    /// Fire when a flow reaches exactly `n` packets (enough statistics).
    EveryNPackets(u32),
    /// Fire when a header field matches (dst_port == value).
    DstPort(u16),
    /// Fire for every packet (the stress-test configuration, App. B.1.1).
    EveryPacket,
}

impl TriggerCondition {
    /// Decide for a packet given flow state after the statistics update.
    ///
    /// Shard-safety invariant (load-bearing for the pipelined runtime's
    /// determinism contract): the decision is a pure function of the
    /// packet and *that flow's* state — no clock, no cross-flow state,
    /// no interior mutability.  Any future variant that breaks this
    /// (e.g. a global rate limiter) must either live outside the
    /// sharded stage or carry its own cross-shard ordering.
    ///
    /// The overload ladder's trigger-only level
    /// ([`ServiceLevel::TriggerOnly`](super::ServiceLevel)) relies on
    /// this purity from the other side: triggers keep being evaluated
    /// and counted at full rate while degraded — only the inference
    /// behind them is suppressed — so stepping down and back up never
    /// changes *which* flows fire, only which admitted ones ran.
    pub fn fires(&self, pkt: &Packet, is_new_flow: bool, flow_pkts: u32) -> bool {
        match *self {
            TriggerCondition::NewFlow => is_new_flow,
            TriggerCondition::EveryNPackets(n) => flow_pkts == n,
            TriggerCondition::DstPort(p) => pkt.dst_port == p,
            TriggerCondition::EveryPacket => true,
        }
    }
}

/// How routed flows pick their model.
#[derive(Debug, Clone)]
enum RouteKind {
    /// First rule whose [`TriggerCondition`] fires wins; its model index
    /// is the route.  Lets different trigger classes hit different
    /// models (tab01: new-flow → `anomaly`, port match → `traffic-class`,
    /// probe packets → `tomography`).
    Rules(Vec<(TriggerCondition, usize)>),
    /// One trigger gates all inference; firing flows are split across
    /// the model set by canonical flow hash (multi-tenant sharding: both
    /// directions of a flow always land on the same model).
    HashSplit(TriggerCondition),
}

/// Maps trigger outcomes to **named models** — the per-flow routing
/// layer of the multi-model registry.  Route indices returned by
/// [`route`](Self::route) index [`model_names`](Self::model_names),
/// which is also the order a
/// [`MultiModelExecutor`](crate::bnn::MultiModelExecutor) binds them in.
///
/// Shard-safety invariant (inherited from [`TriggerCondition::fires`]
/// and load-bearing for the routed pipeline's determinism): the routing
/// decision is a pure function of the packet and *that flow's* state —
/// no clock, no cross-flow state, no registry version.  A publish
/// changes which *weights* a model name resolves to, never which model
/// name a flow routes to.
#[derive(Debug, Clone)]
pub struct ModelRouter {
    names: Vec<String>,
    kind: RouteKind,
}

impl ModelRouter {
    /// First-match-wins rule list; duplicate model names collapse onto
    /// one route index (first occurrence order).
    pub fn rules(rules: Vec<(TriggerCondition, String)>) -> Self {
        assert!(!rules.is_empty(), "ModelRouter needs at least one rule");
        let mut names: Vec<String> = Vec::new();
        let mut compiled = Vec::with_capacity(rules.len());
        for (cond, model) in rules {
            let idx = names.iter().position(|n| n == &model).unwrap_or_else(|| {
                names.push(model.clone());
                names.len() - 1
            });
            compiled.push((cond, idx));
        }
        Self { names, kind: RouteKind::Rules(compiled) }
    }

    /// Split flows that fire `cond` across `names` by canonical flow
    /// hash ([`ShardedFlowTable::shard_of`] — the same formula the
    /// pipeline shards with, so both directions of a flow agree).
    pub fn hash_split(cond: TriggerCondition, names: Vec<String>) -> Self {
        assert!(!names.is_empty(), "ModelRouter needs at least one model");
        Self { names, kind: RouteKind::HashSplit(cond) }
    }

    /// The routed model names, in route-index order.
    pub fn model_names(&self) -> &[String] {
        &self.names
    }

    pub fn n_models(&self) -> usize {
        self.names.len()
    }

    /// Route a packet event: `Some(model index)` if any trigger fires.
    /// Same argument contract as [`TriggerCondition::fires`].
    pub fn route(&self, pkt: &Packet, is_new_flow: bool, flow_pkts: u32) -> Option<usize> {
        match &self.kind {
            RouteKind::Rules(rules) => rules
                .iter()
                .find(|(c, _)| c.fires(pkt, is_new_flow, flow_pkts))
                .map(|&(_, idx)| idx),
            RouteKind::HashSplit(cond) => cond
                .fires(pkt, is_new_flow, flow_pkts)
                .then(|| ShardedFlowTable::shard_of(pkt, self.names.len())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::packet::Proto;

    fn pkt(dst_port: u16) -> Packet {
        Packet {
            ts_ns: 0.0,
            src_ip: 1,
            dst_ip: 2,
            src_port: 9,
            dst_port,
            proto: Proto::Tcp,
            size: 64,
            tcp_flags: 0,
        }
    }

    #[test]
    fn conditions() {
        let p = pkt(443);
        assert!(TriggerCondition::NewFlow.fires(&p, true, 1));
        assert!(!TriggerCondition::NewFlow.fires(&p, false, 5));
        assert!(TriggerCondition::EveryNPackets(10).fires(&p, false, 10));
        assert!(!TriggerCondition::EveryNPackets(10).fires(&p, false, 11));
        assert!(TriggerCondition::DstPort(443).fires(&p, false, 3));
        assert!(!TriggerCondition::DstPort(80).fires(&p, false, 3));
        assert!(TriggerCondition::EveryPacket.fires(&p, false, 7));
    }

    #[test]
    fn router_rules_first_match_wins_and_names_dedupe() {
        let r = ModelRouter::rules(vec![
            (TriggerCondition::DstPort(443), "traffic-class".into()),
            (TriggerCondition::NewFlow, "anomaly".into()),
            (TriggerCondition::EveryNPackets(10), "anomaly".into()),
        ]);
        assert_eq!(r.model_names(), ["traffic-class".to_string(), "anomaly".to_string()]);
        assert_eq!(r.n_models(), 2);
        // Port rule shadows the new-flow rule when both fire.
        assert_eq!(r.route(&pkt(443), true, 1), Some(0));
        // New flow on another port → anomaly.
        assert_eq!(r.route(&pkt(80), true, 1), Some(1));
        // 10th packet on another port → anomaly via the duplicate name.
        assert_eq!(r.route(&pkt(80), false, 10), Some(1));
        // Nothing fires → no inference.
        assert_eq!(r.route(&pkt(80), false, 3), None);
    }

    #[test]
    fn router_hash_split_is_direction_stable_and_in_range() {
        let names: Vec<String> = (0..3).map(|i| format!("m{i}")).collect();
        let r = ModelRouter::hash_split(TriggerCondition::EveryPacket, names);
        for i in 0..64u32 {
            let mut fwd = pkt(443);
            fwd.src_ip = 100 + i;
            fwd.dst_ip = 7;
            fwd.src_port = 9000;
            let mut rev = fwd;
            std::mem::swap(&mut rev.src_ip, &mut rev.dst_ip);
            std::mem::swap(&mut rev.src_port, &mut rev.dst_port);
            let a = r.route(&fwd, false, 1).unwrap();
            let b = r.route(&rev, false, 1).unwrap();
            assert_eq!(a, b, "both directions of flow {i} must share a model");
            assert!(a < 3);
        }
        // Non-firing trigger routes nothing.
        let gated = ModelRouter::hash_split(
            TriggerCondition::EveryNPackets(10),
            vec!["only".into()],
        );
        assert_eq!(gated.route(&pkt(1), false, 3), None);
        assert_eq!(gated.route(&pkt(1), false, 10), Some(0));
    }

    #[test]
    fn decision_is_pure_per_flow_function() {
        // Repeating the same (packet, flow-state) query must repeat the
        // same answer regardless of what other flows were asked in
        // between — the property that lets stage-1 workers evaluate
        // triggers independently per shard.
        let conds = [
            TriggerCondition::NewFlow,
            TriggerCondition::EveryNPackets(10),
            TriggerCondition::DstPort(443),
            TriggerCondition::EveryPacket,
        ];
        for c in conds {
            let first: Vec<bool> = (0..40)
                .map(|i| c.fires(&pkt(400 + i), i % 7 == 0, i as u32))
                .collect();
            // Interleave unrelated queries, then replay.
            for i in 0..100 {
                c.fires(&pkt(i), i % 2 == 0, (i % 13) as u32);
            }
            let replay: Vec<bool> = (0..40)
                .map(|i| c.fires(&pkt(400 + i), i % 7 == 0, i as u32))
                .collect();
            assert_eq!(first, replay, "{c:?}");
        }
    }
}

//! Trigger conditions (§3.2/§4.1): when the forwarding module hands a
//! flow to the NN executor.  "Typical conditions could be the arrival of
//! a new flow, the reception of a predefined number of packets for a
//! given flow, the parsing of a given value in a packet header."

use crate::net::packet::Packet;

/// When to fire the NN executor for a packet/flow event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TriggerCondition {
    /// Fire on the first packet of every new flow.
    NewFlow,
    /// Fire when a flow reaches exactly `n` packets (enough statistics).
    EveryNPackets(u32),
    /// Fire when a header field matches (dst_port == value).
    DstPort(u16),
    /// Fire for every packet (the stress-test configuration, App. B.1.1).
    EveryPacket,
}

impl TriggerCondition {
    /// Decide for a packet given flow state after the statistics update.
    ///
    /// Shard-safety invariant (load-bearing for the pipelined runtime's
    /// determinism contract): the decision is a pure function of the
    /// packet and *that flow's* state — no clock, no cross-flow state,
    /// no interior mutability.  Any future variant that breaks this
    /// (e.g. a global rate limiter) must either live outside the
    /// sharded stage or carry its own cross-shard ordering.
    pub fn fires(&self, pkt: &Packet, is_new_flow: bool, flow_pkts: u32) -> bool {
        match *self {
            TriggerCondition::NewFlow => is_new_flow,
            TriggerCondition::EveryNPackets(n) => flow_pkts == n,
            TriggerCondition::DstPort(p) => pkt.dst_port == p,
            TriggerCondition::EveryPacket => true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::packet::Proto;

    fn pkt(dst_port: u16) -> Packet {
        Packet {
            ts_ns: 0.0,
            src_ip: 1,
            dst_ip: 2,
            src_port: 9,
            dst_port,
            proto: Proto::Tcp,
            size: 64,
            tcp_flags: 0,
        }
    }

    #[test]
    fn conditions() {
        let p = pkt(443);
        assert!(TriggerCondition::NewFlow.fires(&p, true, 1));
        assert!(!TriggerCondition::NewFlow.fires(&p, false, 5));
        assert!(TriggerCondition::EveryNPackets(10).fires(&p, false, 10));
        assert!(!TriggerCondition::EveryNPackets(10).fires(&p, false, 11));
        assert!(TriggerCondition::DstPort(443).fires(&p, false, 3));
        assert!(!TriggerCondition::DstPort(80).fires(&p, false, 3));
        assert!(TriggerCondition::EveryPacket.fires(&p, false, 7));
    }

    #[test]
    fn decision_is_pure_per_flow_function() {
        // Repeating the same (packet, flow-state) query must repeat the
        // same answer regardless of what other flows were asked in
        // between — the property that lets stage-1 workers evaluate
        // triggers independently per shard.
        let conds = [
            TriggerCondition::NewFlow,
            TriggerCondition::EveryNPackets(10),
            TriggerCondition::DstPort(443),
            TriggerCondition::EveryPacket,
        ];
        for c in conds {
            let first: Vec<bool> = (0..40)
                .map(|i| c.fires(&pkt(400 + i), i % 7 == 0, i as u32))
                .collect();
            // Interleave unrelated queries, then replay.
            for i in 0..100 {
                c.fires(&pkt(i), i % 2 == 0, (i % 13) as u32);
            }
            let replay: Vec<bool> = (0..40)
                .map(|i| c.fires(&pkt(400 + i), i % 7 == 0, i as u32))
                .collect();
            assert_eq!(first, replay, "{c:?}");
        }
    }
}

//! Overload control plane: admission shedding, the degradation ladder,
//! per-plane circuit breakers with cost-aware placement, and pipeline
//! stage supervision.
//!
//! The paper's pitch is inference that keeps up with line rate; the
//! serving loop must therefore *degrade* under overload instead of
//! collapsing its bounded stage queues.  Four cooperating mechanisms,
//! all driven by the deterministic packet clock and the modeled
//! [`Capabilities`] cost hook (never wall time, so replay stays
//! deterministic):
//!
//! 1. **Admission** ([`ShedPolicy`] / [`AdmissionController`]) — a leaky
//!    bucket of modeled backlog at ingress.  Every admitted trigger adds
//!    its modeled inference cost; the packet clock drains it.  Past
//!    `max_backlog_ns` the controller sheds with hysteresis until the
//!    backlog falls below `resume_backlog_ns`, *before* `sync_channel`
//!    backpressure can stall the forwarding path.
//! 2. **Degradation ladder** ([`LadderPolicy`] / [`DegradationLadder`])
//!    — sustained pressure steps the service down one rung at a time:
//!    full model → a smaller fallback model hot-swapped into the
//!    registry → trigger-only mode (count triggers, run no inference),
//!    and back up on recovery.  Every transition lands in the
//!    [`ServiceReport::degradation`](super::ServiceReport) timeline.
//! 3. **Backend health** ([`BreakerPolicy`] / [`CircuitBreaker`] /
//!    [`PlacedPlane`]) — a placement plane fronting several member
//!    planes, dispatching each call to the cheapest member whose breaker
//!    is closed (mice to the constrained pisa/fpga planes, elephants to
//!    the sharded host engine) and failing over when one opens.
//! 4. **Supervision** ([`SupervisorPolicy`]) — a parse / inference /
//!    sink stage that dies mid-run is restarted with bounded
//!    retry+backoff instead of aborting the run.  With no supervisor
//!    configured the old die-loudly semantics are untouched, preserving
//!    the deterministic-replay contract.
//!
//! Only wall time measured *around* member calls feeds the breakers
//! (a health signal); verdicts, admission, and the ladder see the
//! virtual clock exclusively.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::bnn::{BnnModel, EngineError, ModelEpoch, RegistryError, RegistryHandle, VersionTag};

use super::plane::{Capabilities, InferencePlane, SwapController};
use super::service::{ServiceError, StageFailure};

// ---------------------------------------------------------------------------
// Admission control
// ---------------------------------------------------------------------------

/// When to shed triggered work at ingress, in modeled-backlog
/// nanoseconds.  Shedding starts once the backlog would exceed
/// `max_backlog_ns` and continues (hysteresis) until it has drained
/// below `resume_backlog_ns`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShedPolicy {
    /// Backlog ceiling: a trigger that would push the modeled backlog
    /// past this is shed instead of enqueued.
    pub max_backlog_ns: f64,
    /// Hysteresis floor: once shedding, admit again only after the
    /// backlog drains below this.
    pub resume_backlog_ns: f64,
}

impl ShedPolicy {
    /// `resume_backlog_ns` is clamped to at most `max_backlog_ns`.
    pub fn new(max_backlog_ns: f64, resume_backlog_ns: f64) -> Self {
        Self { max_backlog_ns, resume_backlog_ns: resume_backlog_ns.min(max_backlog_ns) }
    }

    /// A policy that never sheds — used when only the degradation
    /// ladder is enabled and the controller serves purely as the
    /// backlog estimator.
    pub(crate) fn never() -> Self {
        Self { max_backlog_ns: f64::INFINITY, resume_backlog_ns: f64::INFINITY }
    }
}

/// Leaky-bucket admission controller on the packet clock.  Admitted
/// work deposits its modeled cost; elapsed virtual time drains at
/// `drain_per_ns` (the plane's modeled parallelism, e.g. shard count).
/// Fully deterministic: same event stream in, same shed decisions out.
#[derive(Debug)]
pub struct AdmissionController {
    policy: ShedPolicy,
    drain_per_ns: f64,
    backlog_ns: f64,
    last_ns: f64,
    shedding: bool,
    sheds: u64,
    admitted: u64,
}

impl AdmissionController {
    pub fn new(policy: ShedPolicy, drain_per_ns: f64) -> Self {
        Self {
            policy,
            drain_per_ns: drain_per_ns.max(1e-9),
            backlog_ns: 0.0,
            last_ns: 0.0,
            shedding: false,
            sheds: 0,
            admitted: 0,
        }
    }

    /// Advance the packet clock: drain backlog for the elapsed virtual
    /// time and clear the shedding latch once below the resume floor.
    pub fn observe(&mut self, now_ns: f64) {
        if now_ns > self.last_ns {
            self.backlog_ns =
                (self.backlog_ns - (now_ns - self.last_ns) * self.drain_per_ns).max(0.0);
            self.last_ns = now_ns;
        }
        if self.shedding && self.backlog_ns <= self.policy.resume_backlog_ns {
            self.shedding = false;
        }
    }

    /// Admit one unit of work costing `cost_ns`, or shed it.  The
    /// shedding latch trips *before* the backlog can exceed the
    /// ceiling and holds until [`observe`](Self::observe) sees the
    /// backlog drain below the resume floor.
    pub fn admit(&mut self, now_ns: f64, cost_ns: f64) -> bool {
        self.observe(now_ns);
        if !self.shedding && self.backlog_ns + cost_ns > self.policy.max_backlog_ns {
            self.shedding = true;
        }
        if self.shedding {
            self.sheds += 1;
            false
        } else {
            self.backlog_ns += cost_ns;
            self.admitted += 1;
            true
        }
    }

    /// Count a shed that bypassed the admit decision (trigger-only mode
    /// suppressions).
    pub fn shed_unconditionally(&mut self) {
        self.sheds += 1;
    }

    /// Charge a blocked `sync_channel` send: downstream is visibly
    /// slower than the model claims, so deposit one extra work unit.
    pub fn on_blocked_send(&mut self, penalty_ns: f64) {
        self.backlog_ns += penalty_ns;
    }

    pub fn backlog_ns(&self) -> f64 {
        self.backlog_ns
    }

    pub fn is_shedding(&self) -> bool {
        self.shedding
    }

    pub fn sheds(&self) -> u64 {
        self.sheds
    }

    pub fn admitted(&self) -> u64 {
        self.admitted
    }
}

// ---------------------------------------------------------------------------
// Degradation ladder
// ---------------------------------------------------------------------------

/// The rung the service currently runs at.  Ordered: higher = more
/// degraded.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum ServiceLevel {
    /// Normal operation on the configured model(s).
    Full,
    /// A smaller fallback model hot-swapped into every registry slot.
    Fallback,
    /// Triggers are still evaluated and counted, but no inference runs.
    TriggerOnly,
}

impl ServiceLevel {
    pub(crate) fn as_u8(self) -> u8 {
        match self {
            ServiceLevel::Full => 0,
            ServiceLevel::Fallback => 1,
            ServiceLevel::TriggerOnly => 2,
        }
    }

    pub(crate) fn from_u8(v: u8) -> Self {
        match v {
            1 => ServiceLevel::Fallback,
            2 => ServiceLevel::TriggerOnly,
            _ => ServiceLevel::Full,
        }
    }
}

impl std::fmt::Display for ServiceLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ServiceLevel::Full => "full",
            ServiceLevel::Fallback => "fallback-model",
            ServiceLevel::TriggerOnly => "trigger-only",
        })
    }
}

/// When the ladder moves.  Pressure (modeled backlog + queued batch
/// wait) must stay above `step_down_backlog_ns` — or below
/// `step_up_backlog_ns` — for `dwell_packets` consecutive packets
/// before a transition fires; the dwell filters the sawtooth the
/// admission hysteresis produces.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LadderPolicy {
    pub step_down_backlog_ns: f64,
    pub step_up_backlog_ns: f64,
    pub dwell_packets: u64,
}

impl LadderPolicy {
    pub fn new(step_down_backlog_ns: f64, step_up_backlog_ns: f64, dwell_packets: u64) -> Self {
        Self {
            step_down_backlog_ns,
            step_up_backlog_ns: step_up_backlog_ns.min(step_down_backlog_ns),
            dwell_packets: dwell_packets.max(1),
        }
    }

    /// Derive ladder thresholds from a shed policy.  The admission
    /// hysteresis makes the backlog sawtooth between `resume` and
    /// `max`, so the step-down threshold must sit *inside* that band
    /// (the midpoint) for sustained pressure to register; the step-up
    /// threshold sits below the resume floor so recovery only fires on
    /// a genuine drain.
    pub fn from_shed(shed: &ShedPolicy) -> Self {
        Self::new(
            (shed.max_backlog_ns + shed.resume_backlog_ns) / 2.0,
            shed.resume_backlog_ns / 2.0,
            64,
        )
    }
}

impl Default for LadderPolicy {
    /// Step down above 2ms of modeled backlog, back up below 200µs,
    /// after 64 consecutive packets on the wrong side.
    fn default() -> Self {
        Self::new(2e6, 2e5, 64)
    }
}

/// One ladder transition, recorded in the
/// [`ServiceReport::degradation`](super::ServiceReport) timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct DegradationEvent {
    /// Ingress packet ordinal at which the transition fired.
    pub at_packet: u64,
    /// Packet-clock timestamp (ns).
    pub at_ns: f64,
    pub from: ServiceLevel,
    pub to: ServiceLevel,
    /// The pressure reading that tipped the dwell counter.
    pub backlog_ns: f64,
}

impl DegradationEvent {
    pub fn is_step_down(&self) -> bool {
        self.to > self.from
    }
}

impl std::fmt::Display for DegradationEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} {}\u{2192}{} at pkt {} (pressure {:.1} us)",
            if self.is_step_down() { "step-down" } else { "step-up" },
            self.from,
            self.to,
            self.at_packet,
            self.backlog_ns / 1000.0,
        )
    }
}

/// Dwell-filtered ladder state machine: one rung per transition, the
/// `Fallback` rung skipped when no fallback model is available.
#[derive(Debug)]
pub struct DegradationLadder {
    policy: LadderPolicy,
    level: ServiceLevel,
    has_fallback: bool,
    above: u64,
    below: u64,
    timeline: Vec<DegradationEvent>,
}

impl DegradationLadder {
    pub fn new(policy: LadderPolicy, has_fallback: bool) -> Self {
        Self {
            policy,
            level: ServiceLevel::Full,
            has_fallback,
            above: 0,
            below: 0,
            timeline: Vec::new(),
        }
    }

    pub fn level(&self) -> ServiceLevel {
        self.level
    }

    /// Stop offering the `Fallback` rung (after a failed fallback
    /// publish, reported separately as a [`StageFailure::Swap`]).
    pub(crate) fn disable_fallback(&mut self) {
        self.has_fallback = false;
    }

    fn next_down(&self) -> Option<ServiceLevel> {
        match self.level {
            ServiceLevel::Full => Some(if self.has_fallback {
                ServiceLevel::Fallback
            } else {
                ServiceLevel::TriggerOnly
            }),
            ServiceLevel::Fallback => Some(ServiceLevel::TriggerOnly),
            ServiceLevel::TriggerOnly => None,
        }
    }

    fn next_up(&self) -> Option<ServiceLevel> {
        match self.level {
            ServiceLevel::Full => None,
            ServiceLevel::Fallback => Some(ServiceLevel::Full),
            ServiceLevel::TriggerOnly => Some(if self.has_fallback {
                ServiceLevel::Fallback
            } else {
                ServiceLevel::Full
            }),
        }
    }

    /// Feed one packet's pressure reading; returns the transition it
    /// fired, if any.
    pub fn observe(
        &mut self,
        packet: u64,
        now_ns: f64,
        pressure_ns: f64,
    ) -> Option<&DegradationEvent> {
        if pressure_ns > self.policy.step_down_backlog_ns {
            self.above += 1;
            self.below = 0;
        } else if pressure_ns < self.policy.step_up_backlog_ns {
            self.below += 1;
            self.above = 0;
        } else {
            self.above = 0;
            self.below = 0;
        }
        let to = if self.above >= self.policy.dwell_packets {
            self.next_down()
        } else if self.below >= self.policy.dwell_packets {
            self.next_up()
        } else {
            None
        }?;
        self.above = 0;
        self.below = 0;
        let ev = DegradationEvent {
            at_packet: packet,
            at_ns: now_ns,
            from: self.level,
            to,
            backlog_ns: pressure_ns,
        };
        self.level = to;
        self.timeline.push(ev);
        self.timeline.last()
    }

    pub fn timeline(&self) -> &[DegradationEvent] {
        &self.timeline
    }

    pub(crate) fn into_timeline(self) -> Vec<DegradationEvent> {
        self.timeline
    }
}

/// What the degradation ladder may do, set via `ServeBuilder::degrade`.
/// Trigger-only degradation works on every backend; a fallback model
/// additionally requires a hot-swappable (registry) backend whose slot
/// shapes it matches.
#[derive(Clone, Default)]
pub struct DegradeSpec {
    pub(crate) ladder: Option<LadderPolicy>,
    pub(crate) fallback: Option<BnnModel>,
}

impl DegradeSpec {
    /// Degrade straight to trigger-only mode under pressure (no
    /// fallback model rung).
    pub fn trigger_only() -> Self {
        Self::default()
    }

    /// Degrade via `model` first: sustained pressure hot-swaps it into
    /// every registry slot, recovery rolls the original weights back.
    pub fn with_fallback(model: BnnModel) -> Self {
        Self { ladder: None, fallback: Some(model) }
    }

    /// Override the derived [`LadderPolicy`].
    pub fn ladder(mut self, policy: LadderPolicy) -> Self {
        self.ladder = Some(policy);
        self
    }
}

/// The registry-side actions a ladder transition performs: step-down
/// snapshots every slot's current epoch and publishes the fallback;
/// step-up rolls the snapshots back (as *new* versions — the registry
/// stays monotone).
pub(crate) struct DegradeActions {
    registry: RegistryHandle,
    names: Vec<String>,
    fallback: BnnModel,
    saved: Vec<(String, Arc<ModelEpoch>)>,
}

impl DegradeActions {
    pub(crate) fn new(registry: RegistryHandle, names: Vec<String>, fallback: BnnModel) -> Self {
        let mut unique: Vec<String> = Vec::new();
        for n in names {
            if !unique.contains(&n) {
                unique.push(n);
            }
        }
        Self { registry, names: unique, fallback, saved: Vec::new() }
    }

    fn step_down(&mut self) -> Result<(), RegistryError> {
        self.saved.clear();
        for name in &self.names {
            if let Some(ep) = self.registry.current(name) {
                self.saved.push((name.clone(), ep));
            }
        }
        for name in &self.names {
            self.registry.publish(name, &self.fallback)?;
        }
        Ok(())
    }

    fn step_up(&mut self) -> Result<(), RegistryError> {
        for (name, ep) in self.saved.drain(..) {
            self.registry.rollback(&name, &ep)?;
        }
        Ok(())
    }

    /// Apply the registry side of one ladder transition.  Only the
    /// Full↔Fallback edges touch the registry: Fallback↔TriggerOnly
    /// keeps the fallback weights published while inference is
    /// suppressed.
    pub(crate) fn apply(
        &mut self,
        from: ServiceLevel,
        to: ServiceLevel,
    ) -> Result<(), RegistryError> {
        match (from, to) {
            (ServiceLevel::Full, ServiceLevel::Fallback) => self.step_down(),
            (ServiceLevel::Fallback, ServiceLevel::Full) => self.step_up(),
            _ => Ok(()),
        }
    }
}

/// Build the ladder + registry actions for a service, shared by the
/// serial and pipelined runtimes.  The ladder policy is taken from the
/// spec, derived from the shed policy, or defaulted — in that order.
pub(crate) fn ladder_for(
    degrade: Option<&DegradeSpec>,
    shed: Option<ShedPolicy>,
    swap: Option<&SwapController>,
) -> (Option<DegradationLadder>, Option<DegradeActions>) {
    let Some(spec) = degrade else {
        return (None, None);
    };
    let policy = spec.ladder.unwrap_or_else(|| match shed {
        Some(s) if s.max_backlog_ns.is_finite() => LadderPolicy::from_shed(&s),
        _ => LadderPolicy::default(),
    });
    let actions = spec.fallback.as_ref().and_then(|fb| {
        swap.map(|s| DegradeActions::new(s.registry().clone(), s.names().to_vec(), fb.clone()))
    });
    let ladder = DegradationLadder::new(policy, actions.is_some());
    (Some(ladder), actions)
}

// ---------------------------------------------------------------------------
// Backend health: circuit breakers + the placement plane
// ---------------------------------------------------------------------------

/// When a member plane's breaker trips.  A *strike* is either a hard
/// fault ([`EngineError`]) or a batch observed slower than
/// `latency_tolerance ×` its modeled cost **and** slower than the
/// absolute `min_violation_ns` floor (the floor keeps a slow CI box
/// from tripping breakers on nanosecond-scale models).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BreakerPolicy {
    /// Consecutive strikes that open the breaker.
    pub trip_after: u32,
    /// Observed/modeled latency ratio counted as a strike.
    pub latency_tolerance: f64,
    /// Observed latency below this never counts as a strike.
    pub min_violation_ns: f64,
    /// Calls an open breaker skips before letting one probe through.
    pub cooldown_calls: u32,
}

impl Default for BreakerPolicy {
    fn default() -> Self {
        Self { trip_after: 3, latency_tolerance: 8.0, min_violation_ns: 5e7, cooldown_calls: 64 }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

/// Per-plane circuit breaker: Closed → (strikes) → Open → (cooldown) →
/// HalfOpen probe → Closed on success, back to Open on failure.
#[derive(Debug)]
pub struct CircuitBreaker {
    policy: BreakerPolicy,
    state: BreakerState,
    strikes: u32,
    cooldown: u32,
    trips: u64,
}

impl CircuitBreaker {
    pub fn new(policy: BreakerPolicy) -> Self {
        Self { policy, state: BreakerState::Closed, strikes: 0, cooldown: 0, trips: 0 }
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    pub fn trips(&self) -> u64 {
        self.trips
    }

    fn trip(&mut self) {
        self.state = BreakerState::Open;
        self.trips += 1;
        self.strikes = 0;
        self.cooldown = self.policy.cooldown_calls.max(1);
    }

    /// May this plane take the next call?  Open breakers count the call
    /// against their cooldown and eventually let a half-open probe
    /// through.
    pub fn available(&mut self) -> bool {
        match self.state {
            BreakerState::Closed | BreakerState::HalfOpen => true,
            BreakerState::Open => {
                self.cooldown = self.cooldown.saturating_sub(1);
                if self.cooldown == 0 {
                    self.state = BreakerState::HalfOpen;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Record a successful call with its observed wall latency against
    /// the modeled budget.
    pub fn record_ok(&mut self, observed_ns: f64, budget_ns: f64) {
        let slow = observed_ns > budget_ns * self.policy.latency_tolerance
            && observed_ns > self.policy.min_violation_ns;
        match self.state {
            BreakerState::HalfOpen => {
                if slow {
                    self.trip();
                } else {
                    self.state = BreakerState::Closed;
                    self.strikes = 0;
                }
            }
            BreakerState::Closed => {
                if slow {
                    self.strikes += 1;
                    if self.strikes >= self.policy.trip_after {
                        self.trip();
                    }
                } else {
                    self.strikes = 0;
                }
            }
            BreakerState::Open => {}
        }
    }

    /// Record a hard fault (an [`EngineError`] from the member).
    pub fn record_fault(&mut self) {
        match self.state {
            BreakerState::HalfOpen => self.trip(),
            BreakerState::Closed => {
                self.strikes += 1;
                if self.strikes >= self.policy.trip_after {
                    self.trip();
                }
            }
            BreakerState::Open => {}
        }
    }
}

/// Per-member health counters, surfaced via
/// [`InferencePlane::health_snapshot`] into `ServiceReport::health`.
#[derive(Debug, Clone, PartialEq)]
pub struct PlaneHealth {
    /// Member backend name.
    pub backend: &'static str,
    /// Calls dispatched to this member.
    pub calls: u64,
    /// Calls this member failed and handed to the next candidate.
    pub failovers: u64,
    /// Times its breaker opened.
    pub trips: u64,
    /// Breaker open at end of run.
    pub open: bool,
}

struct Member {
    plane: Box<dyn InferencePlane>,
    caps: Capabilities,
    breaker: CircuitBreaker,
    calls: u64,
    failovers: u64,
}

/// Batch width from which [`PlacedPlane`]'s placement cost model starts
/// crediting a member's SIMD width (an elephant batch, in the paper's
/// mice/elephants flow taxonomy).
pub(crate) const ELEPHANT_BATCH: usize = 16;

/// A placement plane fronting several bit-exact member planes.  Each
/// call goes to the cheapest member (by the modeled
/// [`batch_latency_ns`](InferencePlane::batch_latency_ns) cost curve at
/// the call's batch width) whose breaker is closed: single-input mice
/// land on the constrained fpga/pisa planes, wide elephant batches on
/// the sharded host engine.  A member that faults is failed over and
/// strikes its breaker; verdicts never change because every member
/// computes the same Algorithm 1.
pub struct PlacedPlane {
    members: Vec<Member>,
    n_classes: usize,
}

impl PlacedPlane {
    /// Members must be single-route, non-epoch-pinning planes agreeing
    /// on the class count — anything else would let a failover change
    /// observable output.
    pub fn new(
        members: Vec<Box<dyn InferencePlane>>,
        policy: BreakerPolicy,
    ) -> Result<Self, ServiceError> {
        if members.is_empty() {
            return Err(ServiceError::InvalidConfig {
                option: "placed",
                reason: "a placement plane needs at least one member".into(),
            });
        }
        let n_classes = members[0].n_classes();
        let mut built = Vec::with_capacity(members.len());
        for plane in members {
            let caps = plane.capabilities();
            if caps.routes != 1 {
                return Err(ServiceError::InvalidConfig {
                    option: "placed",
                    reason: format!("member {:?} binds {} routes, want 1", caps.backend, caps.routes),
                });
            }
            if caps.supports_epoch_pinning {
                return Err(ServiceError::InvalidConfig {
                    option: "placed",
                    reason: format!(
                        "member {:?} pins epochs; failover between pinning members \
                         could tag verdicts inconsistently",
                        caps.backend
                    ),
                });
            }
            if plane.n_classes() != n_classes {
                return Err(ServiceError::InvalidConfig {
                    option: "placed",
                    reason: format!(
                        "member {:?} scores {} classes, other members score {n_classes}",
                        caps.backend,
                        plane.n_classes()
                    ),
                });
            }
            built.push(Member { plane, caps, breaker: CircuitBreaker::new(policy), calls: 0, failovers: 0 });
        }
        Ok(Self { members: built, n_classes })
    }

    /// Member indices able to take a batch of `b`, cheapest modeled
    /// cost first (stable sort: ties keep construction order, so the
    /// placement is deterministic).
    ///
    /// From [`ELEPHANT_BATCH`] inputs up, each member's modeled cost is
    /// discounted by its [`Capabilities::simd_lanes`]: a 4-lane AVX2
    /// member retires a wide batch's popcount work in a quarter of the
    /// scalar ops, which the per-backend analytic latency curves (tuned
    /// on the scalar device models) don't capture.  The discount biases
    /// *placement only* — the aggregate
    /// [`batch_latency_ns`](InferencePlane::batch_latency_ns) cost
    /// curve reports undiscounted member costs, so latency accounting
    /// never claims the speedup, it just routes the elephants at the
    /// member most able to deliver it.  Mice keep the raw cost order:
    /// a single input can't fill a vector register.
    fn order(&self, b: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.members.len())
            .filter(|&i| self.members[i].caps.max_batch >= b)
            .collect();
        if idx.is_empty() {
            // Nothing fits the width (the builder clamps batch sizes to
            // our max, so this is belt-and-braces): widest member wins.
            let widest = (0..self.members.len())
                .max_by_key(|&i| self.members[i].caps.max_batch)
                .unwrap();
            return vec![widest];
        }
        let cost = |i: usize| {
            let m = &self.members[i];
            let ns = m.plane.batch_latency_ns(b);
            if b >= ELEPHANT_BATCH {
                ns / m.caps.simd_lanes.max(1) as f64
            } else {
                ns
            }
        };
        idx.sort_by(|&a, &c| cost(a).partial_cmp(&cost(c)).unwrap_or(std::cmp::Ordering::Equal));
        idx
    }

    /// Candidates for the next call: the cost-ordered eligible members
    /// with closed breakers — or, if every breaker is open, the full
    /// cost order (shedding is the admission controller's job, not
    /// ours; somebody must take the work).
    fn candidates(&mut self, b: usize) -> Vec<usize> {
        let order = self.order(b);
        let avail: Vec<usize> =
            order.iter().copied().filter(|&i| self.members[i].breaker.available()).collect();
        if avail.is_empty() {
            order
        } else {
            avail
        }
    }
}

impl InferencePlane for PlacedPlane {
    fn capabilities(&self) -> Capabilities {
        Capabilities {
            backend: "placed",
            max_batch: self.members.iter().map(|m| m.caps.max_batch).max().unwrap_or(1),
            shards: self.members.iter().map(|m| m.caps.shards).max().unwrap_or(1),
            routes: 1,
            supports_hot_swap: false,
            supports_epoch_pinning: false,
            inference_ns: self
                .members
                .iter()
                .map(|m| m.caps.inference_ns)
                .fold(f64::INFINITY, f64::min),
            simd_lanes: self.members.iter().map(|m| m.caps.simd_lanes).max().unwrap_or(1),
        }
    }

    fn classify(&mut self, route: usize, x: &[u32]) -> (usize, Option<VersionTag>) {
        let i = self.candidates(1)[0];
        let m = &mut self.members[i];
        m.calls += 1;
        let budget = m.plane.latency_ns().max(1.0);
        let t0 = Instant::now();
        let out = m.plane.classify(route, x);
        m.breaker.record_ok(t0.elapsed().as_nanos() as f64, budget);
        out
    }

    fn try_run_batch(
        &mut self,
        route: usize,
        inputs: &[Vec<u32>],
        classes: &mut Vec<usize>,
    ) -> Result<Option<VersionTag>, EngineError> {
        let b = inputs.len().max(1);
        let candidates = self.candidates(b);
        let n = candidates.len();
        let mut last = EngineError::WorkerDied;
        for (k, &i) in candidates.iter().enumerate() {
            let m = &mut self.members[i];
            m.calls += 1;
            let budget = m.plane.batch_latency_ns(b).max(1.0);
            let t0 = Instant::now();
            match m.plane.try_run_batch(route, inputs, classes) {
                Ok(tag) => {
                    m.breaker.record_ok(t0.elapsed().as_nanos() as f64, budget);
                    return Ok(tag);
                }
                Err(e) => {
                    m.breaker.record_fault();
                    if k + 1 < n {
                        m.failovers += 1;
                    }
                    last = e;
                }
            }
        }
        Err(last)
    }

    fn batch_latency_ns(&self, b: usize) -> f64 {
        // The placer's own cost curve is its cheapest eligible member's.
        let eligible = self
            .members
            .iter()
            .filter(|m| m.caps.max_batch >= b)
            .map(|m| m.plane.batch_latency_ns(b))
            .fold(f64::INFINITY, f64::min);
        if eligible.is_finite() {
            return eligible;
        }
        self.members
            .iter()
            .map(|m| m.plane.batch_latency_ns(b))
            .fold(f64::INFINITY, f64::min)
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn health_snapshot(&self) -> Option<Vec<PlaneHealth>> {
        Some(
            self.members
                .iter()
                .map(|m| PlaneHealth {
                    backend: m.caps.backend,
                    calls: m.calls,
                    failovers: m.failovers,
                    trips: m.breaker.trips(),
                    open: m.breaker.state() == BreakerState::Open,
                })
                .collect(),
        )
    }
}

// ---------------------------------------------------------------------------
// Stage supervision
// ---------------------------------------------------------------------------

/// Bounded retry+backoff for a pipeline stage that dies mid-run.  The
/// budget is per stage instance for the whole run, not per event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SupervisorPolicy {
    /// Restarts a stage may consume before the run aborts with
    /// [`StageFailure::RestartsExhausted`].
    pub max_restarts: u32,
    /// First backoff; doubles per consecutive restart (capped at
    /// `base × 2⁶`).
    pub backoff_base_us: u64,
}

impl Default for SupervisorPolicy {
    fn default() -> Self {
        Self { max_restarts: 3, backoff_base_us: 100 }
    }
}

impl SupervisorPolicy {
    pub(crate) fn backoff(&self, attempt: u32) -> Duration {
        let shift = attempt.saturating_sub(1).min(6);
        Duration::from_micros(self.backoff_base_us.saturating_mul(1 << shift))
    }
}

/// Run one supervised unit of stage work.  Without a supervisor this is
/// a plain call — panics propagate and kill the stage thread exactly as
/// before, preserving deterministic replay.  With one, panics and
/// retryable failures ([`StageFailure::Inference`]) are caught and the
/// unit is re-run after backoff until the restart budget is spent;
/// non-retryable failures (channel disconnects) pass straight through.
pub(crate) fn guard<T>(
    supervisor: Option<&SupervisorPolicy>,
    stage: &'static str,
    used: &mut u32,
    restarts: &mut u64,
    mut f: impl FnMut() -> Result<T, StageFailure>,
) -> Result<T, StageFailure> {
    let Some(policy) = supervisor else {
        return f();
    };
    loop {
        let last = match catch_unwind(AssertUnwindSafe(&mut f)) {
            Ok(Ok(v)) => return Ok(v),
            Ok(Err(fail)) => {
                if !matches!(fail, StageFailure::Inference(_)) {
                    return Err(fail);
                }
                fail.to_string()
            }
            Err(payload) => panic_text(payload.as_ref()),
        };
        if *used >= policy.max_restarts {
            return Err(StageFailure::RestartsExhausted { stage, restarts: *used, last });
        }
        *used += 1;
        *restarts += 1;
        std::thread::sleep(policy.backoff(*used));
    }
}

/// Best-effort panic payload extraction (shared with the pipeline's
/// join-side handling).
pub(crate) fn panic_text(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

/// Test-only fault injection: arm a one-shot panic at the Nth unit of
/// work in a chosen stage.  Shared (`Arc`) across stage threads so a
/// plan fires exactly once per run whatever the parallelism.
#[doc(hidden)]
#[derive(Clone, Default)]
pub struct FaultPlan {
    inner: Arc<FaultInner>,
}

#[derive(Default)]
struct FaultInner {
    parse: FaultPoint,
    inference: FaultPoint,
    sink: FaultPoint,
}

#[derive(Default)]
struct FaultPoint {
    at: AtomicU64,
    count: AtomicU64,
    fired: AtomicBool,
}

impl FaultPoint {
    fn arm(&self, at: u64) {
        self.at.store(at.max(1), Ordering::Relaxed);
    }

    fn tick(&self, stage: &str) {
        if self.at.load(Ordering::Relaxed) == 0 {
            return;
        }
        let n = self.count.fetch_add(1, Ordering::Relaxed) + 1;
        if n >= self.at.load(Ordering::Relaxed) && !self.fired.swap(true, Ordering::Relaxed) {
            panic!("injected {stage} fault");
        }
    }
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Panic the parse stage at its `n`th event.
    pub fn kill_parse_at(self, n: u64) -> Self {
        self.inner.parse.arm(n);
        self
    }

    /// Panic the inference stage at its `n`th call (batch or inline).
    pub fn kill_inference_at(self, n: u64) -> Self {
        self.inner.inference.arm(n);
        self
    }

    /// Panic the sink stage at its `n`th verdict.
    pub fn kill_sink_at(self, n: u64) -> Self {
        self.inner.sink.arm(n);
        self
    }

    pub(crate) fn tick_parse(&self) {
        self.inner.parse.tick("parse");
    }

    pub(crate) fn tick_inference(&self) {
        self.inner.inference.tick("inference");
    }

    pub(crate) fn tick_sink(&self) {
        self.inner.sink.tick("sink");
    }
}

// ---------------------------------------------------------------------------
// Runtime glue
// ---------------------------------------------------------------------------

/// The serial runtime's overload state: one admission controller plus
/// the ladder and its registry actions.
pub(crate) struct OverloadControl {
    admission: AdmissionController,
    ladder: Option<DegradationLadder>,
    actions: Option<DegradeActions>,
    cost_ns: f64,
    packets: u64,
    swap_failure: Option<StageFailure>,
}

impl OverloadControl {
    pub(crate) fn new(
        admission: AdmissionController,
        ladder: Option<DegradationLadder>,
        actions: Option<DegradeActions>,
        cost_ns: f64,
    ) -> Self {
        Self { admission, ladder, actions, cost_ns, packets: 0, swap_failure: None }
    }

    /// Per-packet bookkeeping: drain the bucket, feed the ladder the
    /// combined pressure (modeled backlog + oldest queued batch wait),
    /// and apply any transition's registry actions.
    pub(crate) fn on_packet(&mut self, now_ns: f64, queued_wait_ns: f64) {
        self.packets += 1;
        self.admission.observe(now_ns);
        let pressure = self.admission.backlog_ns() + queued_wait_ns.max(0.0);
        let Some(ladder) = self.ladder.as_mut() else {
            return;
        };
        let Some(ev) = ladder.observe(self.packets, now_ns, pressure) else {
            return;
        };
        let (from, to) = (ev.from, ev.to);
        let mut failed = false;
        if let Some(actions) = self.actions.as_mut() {
            if let Err(e) = actions.apply(from, to) {
                if self.swap_failure.is_none() {
                    self.swap_failure = Some(StageFailure::Swap(e));
                }
                failed = true;
            }
        }
        if failed {
            self.actions = None;
            ladder.disable_fallback();
        }
    }

    /// Admit or shed one trigger.  Trigger-only mode sheds everything;
    /// otherwise the leaky bucket decides.
    pub(crate) fn admit_trigger(&mut self, now_ns: f64) -> bool {
        if self.level() == ServiceLevel::TriggerOnly {
            self.admission.shed_unconditionally();
            return false;
        }
        self.admission.admit(now_ns, self.cost_ns)
    }

    pub(crate) fn level(&self) -> ServiceLevel {
        self.ladder.as_ref().map_or(ServiceLevel::Full, DegradationLadder::level)
    }

    pub(crate) fn sheds(&self) -> u64 {
        self.admission.sheds()
    }

    pub(crate) fn take_swap_failure(&mut self) -> Option<StageFailure> {
        self.swap_failure.take()
    }

    pub(crate) fn into_timeline(self) -> Vec<DegradationEvent> {
        self.ladder.map_or(Vec::new(), DegradationLadder::into_timeline)
    }
}

/// One parse worker's slice of the pipelined admission control: a local
/// leaky bucket (drain split evenly across workers) publishing its
/// backlog to the ingress ladder through an atomic cell, and reading
/// the ladder's level back the same way.
pub(crate) struct WorkerAdmission {
    ctl: AdmissionController,
    cost_ns: f64,
    backlog_cell: Arc<AtomicU64>,
    level: Arc<AtomicU8>,
}

impl WorkerAdmission {
    pub(crate) fn new(
        ctl: AdmissionController,
        cost_ns: f64,
        backlog_cell: Arc<AtomicU64>,
        level: Arc<AtomicU8>,
    ) -> Self {
        Self { ctl, cost_ns, backlog_cell, level }
    }

    pub(crate) fn on_packet(&mut self, now_ns: f64) {
        self.ctl.observe(now_ns);
        self.backlog_cell.store(self.ctl.backlog_ns().to_bits(), Ordering::Relaxed);
    }

    pub(crate) fn admit(&mut self, now_ns: f64) -> bool {
        if ServiceLevel::from_u8(self.level.load(Ordering::Relaxed)) == ServiceLevel::TriggerOnly {
            self.ctl.shed_unconditionally();
            return false;
        }
        let ok = self.ctl.admit(now_ns, self.cost_ns);
        self.backlog_cell.store(self.ctl.backlog_ns().to_bits(), Ordering::Relaxed);
        ok
    }

    pub(crate) fn on_blocked(&mut self) {
        self.ctl.on_blocked_send(self.cost_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::BnnModel;
    use crate::coordinator::BackendFactory;

    #[test]
    fn admission_is_a_deterministic_leaky_bucket_with_hysteresis() {
        let run = || {
            let mut ctl = AdmissionController::new(ShedPolicy::new(1000.0, 300.0), 1.0);
            let mut decisions = Vec::new();
            // 400ns of work arriving every 100ns: 4× overload.
            for i in 0..50u64 {
                decisions.push(ctl.admit(i as f64 * 100.0, 400.0));
            }
            (decisions, ctl.sheds(), ctl.admitted(), ctl.backlog_ns())
        };
        let (decisions, sheds, admitted, backlog) = run();
        // First admits fill the bucket, then the latch trips...
        assert!(decisions[0] && decisions[1]);
        assert!(sheds > 0, "4x overload must shed");
        assert!(admitted > 2, "hysteresis must re-admit after draining");
        // ...and the bucket never exceeds the ceiling.
        assert!(backlog <= 1000.0, "backlog {backlog}");
        // Same inputs, same decisions: determinism is the whole point.
        assert_eq!(run(), (decisions, sheds, admitted, backlog));

        // Draining below the resume floor clears the latch.
        let mut ctl = AdmissionController::new(ShedPolicy::new(1000.0, 300.0), 1.0);
        assert!(ctl.admit(0.0, 900.0));
        assert!(!ctl.admit(1.0, 900.0), "second deposit would burst the bucket");
        assert!(ctl.is_shedding());
        ctl.observe(700.0); // backlog ~200 < resume 300
        assert!(!ctl.is_shedding());
        assert!(ctl.admit(700.0, 100.0));
    }

    #[test]
    fn ladder_steps_one_rung_after_dwell_and_skips_fallback_without_one() {
        let policy = LadderPolicy::new(1000.0, 100.0, 4);
        let mut ladder = DegradationLadder::new(policy, false);
        // 3 packets above threshold: dwell not met.
        for p in 1..=3 {
            assert!(ladder.observe(p, p as f64, 5000.0).is_none());
        }
        // 4th fires — straight to trigger-only (no fallback rung).
        let ev = ladder.observe(4, 4.0, 5000.0).cloned().unwrap();
        assert_eq!((ev.from, ev.to), (ServiceLevel::Full, ServiceLevel::TriggerOnly));
        assert!(ev.is_step_down());
        assert_eq!(ladder.level(), ServiceLevel::TriggerOnly);
        // A dip resets the dwell counter.
        assert!(ladder.observe(5, 5.0, 50.0).is_none());
        assert!(ladder.observe(6, 6.0, 5000.0).is_none());
        // Sustained recovery steps back up.
        for p in 7..=9 {
            assert!(ladder.observe(p, p as f64, 50.0).is_none());
        }
        let ev = ladder.observe(10, 10.0, 50.0).cloned().unwrap();
        assert_eq!((ev.from, ev.to), (ServiceLevel::TriggerOnly, ServiceLevel::Full));
        assert!(!ev.is_step_down());
        assert_eq!(ladder.timeline().len(), 2);

        // With a fallback rung the ladder walks Full→Fallback→TriggerOnly.
        let mut ladder = DegradationLadder::new(policy, true);
        for p in 1..=3 {
            ladder.observe(p, p as f64, 5000.0);
        }
        let ev = ladder.observe(4, 4.0, 5000.0).cloned().unwrap();
        assert_eq!(ev.to, ServiceLevel::Fallback);
        for p in 5..=7 {
            ladder.observe(p, p as f64, 5000.0);
        }
        let ev = ladder.observe(8, 8.0, 5000.0).cloned().unwrap();
        assert_eq!((ev.from, ev.to), (ServiceLevel::Fallback, ServiceLevel::TriggerOnly));
    }

    #[test]
    fn derived_ladder_thresholds_sit_inside_the_shed_sawtooth() {
        let shed = ShedPolicy::new(500_000.0, 100_000.0);
        let ladder = LadderPolicy::from_shed(&shed);
        assert!(ladder.step_down_backlog_ns < shed.max_backlog_ns);
        assert!(ladder.step_down_backlog_ns > shed.resume_backlog_ns);
        assert!(ladder.step_up_backlog_ns < shed.resume_backlog_ns);
    }

    #[test]
    fn breaker_walks_closed_open_halfopen_closed() {
        let mut b = CircuitBreaker::new(BreakerPolicy {
            trip_after: 2,
            latency_tolerance: 4.0,
            min_violation_ns: 100.0,
            cooldown_calls: 3,
        });
        assert_eq!(b.state(), BreakerState::Closed);
        // One fault is a strike, not a trip.
        b.record_fault();
        assert_eq!(b.state(), BreakerState::Closed);
        // A fast call resets the strike count.
        b.record_ok(10.0, 10.0);
        b.record_fault();
        assert_eq!(b.state(), BreakerState::Closed);
        b.record_fault();
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.trips(), 1);
        // Open: calls are refused through the cooldown, then one probe.
        assert!(!b.available());
        assert!(!b.available());
        assert!(b.available());
        assert_eq!(b.state(), BreakerState::HalfOpen);
        // Probe succeeds fast → closed again.
        b.record_ok(10.0, 10.0);
        assert_eq!(b.state(), BreakerState::Closed);
        // Slow-call strikes need both the ratio and the absolute floor.
        b.record_ok(90.0, 10.0); // 9× over but under the 100ns floor
        b.record_ok(90.0, 10.0);
        assert_eq!(b.state(), BreakerState::Closed);
        b.record_ok(900.0, 10.0);
        b.record_ok(900.0, 10.0);
        assert_eq!(b.state(), BreakerState::Open);
    }

    #[test]
    fn placed_plane_orders_members_by_modeled_cost_and_respects_width() {
        let m = BnnModel::random("traffic", 256, &[16, 2], 7);
        // fpga: cheap serial device; pisa: batch width 1; host: PCIe
        // cost curve, expensive for mice.
        let members = vec![
            BackendFactory::single("host", m.clone()).unwrap(),
            BackendFactory::single("fpga", m.clone()).unwrap(),
            BackendFactory::single("pisa", m.clone()).unwrap(),
        ];
        let placed = PlacedPlane::new(members, BreakerPolicy::default()).unwrap();
        let caps = placed.capabilities();
        assert_eq!(caps.backend, "placed");
        assert!(!caps.supports_hot_swap && !caps.supports_epoch_pinning);
        // Mice avoid the host plane (PCIe round-trip dominates)...
        let first = placed.order(1)[0];
        assert_ne!(placed.members[first].caps.backend, "host");
        // ...and pisa (max_batch 1) is excluded from wide batches.
        for &i in &placed.order(16) {
            assert_ne!(placed.members[i].caps.backend, "pisa");
        }
        // The aggregate cost curve is the cheapest member's.
        let best = placed
            .members
            .iter()
            .map(|mm| mm.plane.batch_latency_ns(1))
            .fold(f64::INFINITY, f64::min);
        assert_eq!(placed.batch_latency_ns(1), best);
    }

    /// Single-route plane with a fixed per-item cost and a declared
    /// SIMD width — the placement cost model's two inputs, isolated.
    struct StubPlane {
        backend: &'static str,
        ns_per_item: f64,
        lanes: usize,
    }

    impl InferencePlane for StubPlane {
        fn capabilities(&self) -> Capabilities {
            Capabilities {
                simd_lanes: self.lanes,
                ..Capabilities::single(self.backend, self.ns_per_item)
            }
        }

        fn classify(&mut self, _route: usize, _x: &[u32]) -> (usize, Option<VersionTag>) {
            (0, None)
        }

        fn try_run_batch(
            &mut self,
            _route: usize,
            inputs: &[Vec<u32>],
            classes: &mut Vec<usize>,
        ) -> Result<Option<VersionTag>, EngineError> {
            classes.clear();
            classes.resize(inputs.len(), 0);
            Ok(None)
        }

        fn n_classes(&self) -> usize {
            2
        }
    }

    #[test]
    fn placed_plane_prefers_simd_members_for_elephant_batches_only() {
        // The scalar member is slightly cheaper per item; the vector
        // member has 4 lanes.  Mice must go scalar (raw cost), elephants
        // vector (discounted cost: 100/4 < 80).
        let members: Vec<Box<dyn InferencePlane>> = vec![
            Box::new(StubPlane { backend: "scalar", ns_per_item: 80.0, lanes: 1 }),
            Box::new(StubPlane { backend: "vector", ns_per_item: 100.0, lanes: 4 }),
        ];
        let placed = PlacedPlane::new(members, BreakerPolicy::default()).unwrap();
        assert_eq!(placed.capabilities().simd_lanes, 4, "aggregate advertises the widest");

        let mouse = placed.order(1);
        assert_eq!(placed.members[mouse[0]].caps.backend, "scalar");
        let sub_elephant = placed.order(ELEPHANT_BATCH - 1);
        assert_eq!(
            placed.members[sub_elephant[0]].caps.backend, "scalar",
            "the discount must not kick in below the elephant width"
        );
        let elephant = placed.order(ELEPHANT_BATCH);
        assert_eq!(placed.members[elephant[0]].caps.backend, "vector");

        // Placement bias only: the aggregate cost curve stays
        // undiscounted (cheapest member's raw model at every width).
        let b = ELEPHANT_BATCH;
        assert_eq!(placed.batch_latency_ns(b), 80.0 * b as f64);

        // Equal lanes ⇒ the discount cancels and raw cost decides.
        let members: Vec<Box<dyn InferencePlane>> = vec![
            Box::new(StubPlane { backend: "a", ns_per_item: 100.0, lanes: 4 }),
            Box::new(StubPlane { backend: "b", ns_per_item: 80.0, lanes: 4 }),
        ];
        let placed = PlacedPlane::new(members, BreakerPolicy::default()).unwrap();
        assert_eq!(placed.members[placed.order(ELEPHANT_BATCH)[0]].caps.backend, "b");
    }

    #[test]
    fn supervisor_backoff_is_bounded_and_monotone() {
        let p = SupervisorPolicy { max_restarts: 10, backoff_base_us: 100 };
        assert_eq!(p.backoff(1), Duration::from_micros(100));
        assert_eq!(p.backoff(2), Duration::from_micros(200));
        assert_eq!(p.backoff(4), Duration::from_micros(800));
        // Capped at base × 2⁶ however deep the retry goes.
        assert_eq!(p.backoff(50), Duration::from_micros(6400));
    }

    #[test]
    fn guard_without_supervisor_is_transparent_and_with_one_retries() {
        // No supervisor: failures pass through untouched.
        let mut used = 0;
        let mut restarts = 0;
        let out: Result<u32, _> = guard(None, "t", &mut used, &mut restarts, || Ok(7));
        assert_eq!(out.unwrap(), 7);
        assert_eq!((used, restarts), (0, 0));

        // Supervised: panics are caught and retried until success.
        let policy = SupervisorPolicy { max_restarts: 3, backoff_base_us: 1 };
        let mut used = 0;
        let mut restarts = 0;
        let mut calls = 0;
        let out = guard(Some(&policy), "t", &mut used, &mut restarts, || {
            calls += 1;
            if calls < 3 {
                panic!("boom");
            }
            Ok(calls)
        });
        assert_eq!(out.unwrap(), 3);
        assert_eq!((used, restarts), (2, 2));

        // Budget exhaustion surfaces the last failure, typed.
        let mut used = 0;
        let mut restarts = 0;
        let out: Result<(), _> = guard(Some(&policy), "t", &mut used, &mut restarts, || {
            panic!("always")
        });
        let Err(StageFailure::RestartsExhausted { stage, restarts: n, last }) = out else {
            panic!("want RestartsExhausted");
        };
        assert_eq!(stage, "t");
        assert_eq!(n, 3);
        assert!(last.contains("always"), "{last}");
    }

    #[test]
    fn fault_plan_fires_exactly_once() {
        let plan = FaultPlan::new().kill_inference_at(3);
        plan.tick_inference();
        plan.tick_inference();
        let hit = catch_unwind(AssertUnwindSafe(|| plan.tick_inference()));
        assert!(hit.is_err(), "third tick must fire");
        // One-shot: the retried unit of work passes.
        plan.tick_inference();
        plan.tick_inference();
        // Other stages are disarmed entirely.
        plan.tick_parse();
        plan.tick_sink();
    }
}

//! The unified inference-plane API (ISSUE 5 tentpole).
//!
//! N3IC's core claim is that one NN inference primitive can be placed on
//! whichever data plane is available — NFP SmartNIC, FPGA, PISA switch,
//! or host CPU.  [`InferencePlane`] is that claim as a trait: every
//! backend answers the same three calls (`classify`, `run_batch`,
//! `try_run_batch`) and publishes a [`Capabilities`] descriptor so the
//! serving runtime can *query* what a backend supports (batching width,
//! shard count, hot swap, epoch pinning, cost model) instead of being
//! specialized to it.
//!
//! Concrete backends are constructed by name through
//! [`BackendFactory`](super::BackendFactory); the one serving runtime
//! ([`Service`](super::Service), built by
//! [`ServeBuilder`](super::ServeBuilder)) composes against this trait
//! only.  The previous pair of executor traits (`NnExecutor` /
//! `NnBatchExecutor`) and the free-standing `bnnexec` run surface were
//! folded in here in ISSUE 5; their deprecated shims have since been
//! deleted.

use crate::bnn::{EngineError, EngineStats, RegistryError, RegistryHandle, VersionTag};

/// What a backend supports — the serving runtime composes features
/// (batching, sharded fan-out, hot swap, routed models) by reading this
/// descriptor rather than by knowing concrete backend types.
#[derive(Debug, Clone, PartialEq)]
pub struct Capabilities {
    /// Backend name as registered in the
    /// [`BackendFactory`](super::BackendFactory) (or a custom
    /// implementation's own tag).
    pub backend: &'static str,
    /// Largest batch one `run_batch` call accepts.  `usize::MAX` means
    /// unbounded; `1` means the data plane classifies strictly inline
    /// (the PISA switch shape) and the builder rejects batched configs.
    pub max_batch: usize,
    /// Worker cores behind the batch path (1 = single core).
    pub shards: usize,
    /// Routed model lanes this plane serves (1 = single model).  A
    /// service routing `n` named models requires `routes == n`.
    pub routes: usize,
    /// Weights can be republished while serving (registry backends).
    pub supports_hot_swap: bool,
    /// Every batch pins one immutable weight epoch and verdicts carry
    /// `(name, version)` tags.
    pub supports_epoch_pinning: bool,
    /// Modeled device latency of one inference, ns — the scalar half of
    /// the cost model.  The full batch-cost hook is
    /// [`InferencePlane::batch_latency_ns`].
    pub inference_ns: f64,
    /// 64-bit qword lanes one vector op of the scoring kernel covers:
    /// `1` = the scalar loop, `4` = the AVX2 XNOR/popcount path resolved
    /// at kernel construction (see [`crate::bnn::simd`]).
    pub simd_lanes: usize,
}

impl Capabilities {
    /// Descriptor of a plain single-model, single-core backend with an
    /// unbounded batch path and no swap machinery.
    pub fn single(backend: &'static str, inference_ns: f64) -> Self {
        Self {
            backend,
            max_batch: usize::MAX,
            shards: 1,
            routes: 1,
            supports_hot_swap: false,
            supports_epoch_pinning: false,
            inference_ns,
            simd_lanes: 1,
        }
    }

    /// One-line human summary for the admin capability endpoint and CLI.
    pub fn summary(&self) -> String {
        let batch = if self.max_batch == usize::MAX {
            "unbounded".to_string()
        } else {
            self.max_batch.to_string()
        };
        format!(
            "backend={} shards={} routes={} max_batch={} hot_swap={} epoch_pinning={} inference_ns={:.1} simd_lanes={}",
            self.backend,
            self.shards,
            self.routes,
            batch,
            self.supports_hot_swap,
            self.supports_epoch_pinning,
            self.inference_ns,
            self.simd_lanes,
        )
    }
}

/// Uniform interface over every inference backend: host scalar executor,
/// weight-stationary batch kernel, sharded multi-core engine, PISA
/// pipeline interpreter, FPGA device model, and the registry-backed
/// multi-model executor all serve behind exactly this surface.
///
/// `route` selects the model lane on multi-model planes and is `0` on
/// single-model ones.  All implementations are bit-exact computations of
/// the paper's Algorithm 1 — the conformance suite
/// (`tests/plane_conformance.rs`) runs one seeded scenario matrix over
/// every registered backend and asserts identical verdict histograms.
pub trait InferencePlane: Send {
    /// The backend's capability descriptor (stable for the plane's
    /// lifetime).
    fn capabilities(&self) -> Capabilities;

    /// Classify one packed input on `route`; returns the verdict class
    /// and, on epoch-pinning backends, the `(name, version)` tag the
    /// inference ran under.
    fn classify(&mut self, route: usize, x: &[u32]) -> (usize, Option<VersionTag>);

    /// Fallible batch path: classify `inputs` under **one** weight
    /// snapshot; `classes` is cleared and refilled in input order.  A
    /// backend fault (dead or panicked shard worker) surfaces as
    /// `Err` instead of a panic or a hang.
    fn try_run_batch(
        &mut self,
        route: usize,
        inputs: &[Vec<u32>],
        classes: &mut Vec<usize>,
    ) -> Result<Option<VersionTag>, EngineError>;

    /// Infallible batch path; panics on a backend fault (callers that
    /// must stay up through one use [`try_run_batch`](Self::try_run_batch)).
    fn run_batch(
        &mut self,
        route: usize,
        inputs: &[Vec<u32>],
        classes: &mut Vec<usize>,
    ) -> Option<VersionTag> {
        match self.try_run_batch(route, inputs, classes) {
            Ok(tag) => tag,
            Err(e) => panic!("{e}"),
        }
    }

    /// Modeled per-inference device latency (ns).
    fn latency_ns(&self) -> f64 {
        self.capabilities().inference_ns
    }

    /// Modeled completion time of a batch of `b` — the cost-model hook.
    /// Every item of a batch observes the whole batch's completion.
    /// Default is a serial device (`b ×` per-inference latency);
    /// backends with a calibrated curve (PCIe + per-batch I/O) override.
    fn batch_latency_ns(&self, b: usize) -> f64 {
        self.latency_ns() * b as f64
    }

    /// Output classes of the widest deployed model (verdict-histogram
    /// sizing).
    fn n_classes(&self) -> usize;

    /// Route-indexed model names on multi-model planes; empty on
    /// single-model ones (per-model accounting is keyed by these).
    fn route_names(&self) -> &[String] {
        &[]
    }

    /// Throughput counters of an underlying multi-core engine, if the
    /// batch path routes through one.
    fn engine_stats(&self) -> Option<EngineStats> {
        None
    }

    /// Control handle for live hot swaps, on backends that support them.
    /// The runtime extracts this *before* moving the plane into a
    /// pipeline stage, so `.swap_every(n)` publishes from the ingress
    /// thread while inference keeps running — a true concurrent swap.
    fn swap_controller(&self) -> Option<SwapController> {
        None
    }

    /// Per-member health counters on placement/failover planes
    /// ([`PlacedPlane`](super::PlacedPlane)); `None` on planes without
    /// internal members.  Surfaced into `ServiceReport::health` at the
    /// end of a run.
    fn health_snapshot(&self) -> Option<Vec<super::overload::PlaneHealth>> {
        None
    }
}

/// Boxed planes are planes: forwarding keeps generic consumers (e.g.
/// [`ShuntRouter`](super::ShuntRouter)) working directly on what the
/// [`BackendFactory`](super::BackendFactory) returns.  Every method is
/// forwarded explicitly so inner overrides (cost curves, route names,
/// swap controllers) are never shadowed by the trait defaults.
impl<P: InferencePlane + ?Sized> InferencePlane for Box<P> {
    fn capabilities(&self) -> Capabilities {
        (**self).capabilities()
    }

    fn classify(&mut self, route: usize, x: &[u32]) -> (usize, Option<VersionTag>) {
        (**self).classify(route, x)
    }

    fn try_run_batch(
        &mut self,
        route: usize,
        inputs: &[Vec<u32>],
        classes: &mut Vec<usize>,
    ) -> Result<Option<VersionTag>, EngineError> {
        (**self).try_run_batch(route, inputs, classes)
    }

    fn run_batch(
        &mut self,
        route: usize,
        inputs: &[Vec<u32>],
        classes: &mut Vec<usize>,
    ) -> Option<VersionTag> {
        (**self).run_batch(route, inputs, classes)
    }

    fn latency_ns(&self) -> f64 {
        (**self).latency_ns()
    }

    fn batch_latency_ns(&self, b: usize) -> f64 {
        (**self).batch_latency_ns(b)
    }

    fn n_classes(&self) -> usize {
        (**self).n_classes()
    }

    fn route_names(&self) -> &[String] {
        (**self).route_names()
    }

    fn engine_stats(&self) -> Option<EngineStats> {
        (**self).engine_stats()
    }

    fn swap_controller(&self) -> Option<SwapController> {
        (**self).swap_controller()
    }

    fn health_snapshot(&self) -> Option<Vec<super::overload::PlaneHealth>> {
        (**self).health_snapshot()
    }
}

/// Control-plane handle a hot-swappable plane hands the serving runtime:
/// republishes the bound slots round-robin (same weights, new version —
/// the swap machinery is exercised without changing verdict semantics,
/// which is exactly what `.swap_every(n)` demonstrates).
pub struct SwapController {
    registry: RegistryHandle,
    names: Vec<String>,
    cursor: usize,
}

impl SwapController {
    /// Bind a controller to `names` (all must be published in
    /// `registry`).
    pub fn new(registry: RegistryHandle, names: Vec<String>) -> Self {
        assert!(!names.is_empty(), "SwapController needs at least one slot");
        Self { registry, names, cursor: 0 }
    }

    /// Hot-republish the next slot round-robin with its current weights
    /// (version +1, swap count +1, verdicts unchanged).
    pub fn tick(&mut self) -> Result<VersionTag, RegistryError> {
        let name = self.names[self.cursor % self.names.len()].clone();
        self.cursor += 1;
        self.registry.touch(&name)
    }

    /// The registry this controller publishes through (swap-count
    /// snapshots for reports).
    pub fn registry(&self) -> &RegistryHandle {
        &self.registry
    }

    /// Slots this controller rotates over.
    pub fn names(&self) -> &[String] {
        &self.names
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::BnnModel;

    #[test]
    fn single_capability_defaults() {
        let c = Capabilities::single("x", 42.0);
        assert_eq!(c.backend, "x");
        assert_eq!(c.max_batch, usize::MAX);
        assert_eq!((c.shards, c.routes), (1, 1));
        assert!(!c.supports_hot_swap && !c.supports_epoch_pinning);
        assert_eq!(c.inference_ns, 42.0);
        assert_eq!(c.simd_lanes, 1, "single() describes the scalar loop");
        assert!(c.summary().contains("simd_lanes=1"));
    }

    #[test]
    fn swap_controller_rotates_round_robin_and_bumps_versions() {
        let h = RegistryHandle::new();
        h.publish("a", &BnnModel::random("a", 64, &[8, 2], 1)).unwrap();
        h.publish("b", &BnnModel::random("b", 64, &[8, 2], 2)).unwrap();
        let mut ctl = SwapController::new(h.clone(), vec!["a".into(), "b".into()]);
        assert_eq!(ctl.tick().unwrap().to_string(), "a@v2");
        assert_eq!(ctl.tick().unwrap().to_string(), "b@v2");
        assert_eq!(ctl.tick().unwrap().to_string(), "a@v3");
        assert_eq!(ctl.registry().swap_count("a"), 2);
        assert_eq!(ctl.registry().swap_count("b"), 1);
        assert_eq!(ctl.names(), ["a".to_string(), "b".to_string()]);
    }
}

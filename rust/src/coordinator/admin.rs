//! Typed admin/introspection surface for a running service — the
//! operational control plane the ROADMAP asks for, without dragging an
//! HTTP stack into the crate.  An [`AdminHandle`] is handed to
//! [`ServeBuilder::admin`](super::ServeBuilder::admin) before `build()`;
//! the serving runtime (serial and pipelined) binds it with the
//! backend's [`Capabilities`] and keeps a live packet counter plus a
//! periodic [`ServiceStats`] snapshot current while the run is in
//! flight.  Any other thread can then route requests through
//! [`AdminRequest::route`] — health check, capability introspection,
//! stats scrape, and model touch-publish/rollback against the backing
//! [`RegistryHandle`] — exactly the surface a sidecar daemon would wrap
//! in HTTP.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::bnn::{BnnModel, ModelEpoch, RegistryError, RegistryHandle, VersionTag};

use super::pipeline::STAGE_LINKS;
use super::plane::Capabilities;
use super::service::ServiceStats;

/// Stats snapshot cadence in the serving loops (packets).
pub(crate) const SNAPSHOT_EVERY: u64 = 1024;

#[derive(Default)]
struct AdminState {
    serving: AtomicBool,
    failed: AtomicBool,
    packets: AtomicU64,
    snapshot: Mutex<ServiceStats>,
    caps: Mutex<Option<Capabilities>>,
    registry: Mutex<Option<RegistryHandle>>,
    /// Per-slot stack of archived epochs: every publish/touch pushes the
    /// previous current, rollback pops.
    history: Mutex<BTreeMap<String, Vec<Arc<ModelEpoch>>>>,
    /// Queued `POST /models/<name>/retrain` requests, drained by the
    /// serving loop's online learner at its snapshot cadence.
    retrains: Mutex<Vec<String>>,
}

/// Cloneable handle onto one service's admin state.  Create it, pass a
/// clone to the builder, keep the original to issue requests from any
/// thread while the run is live (and after it finishes).
#[derive(Clone, Default)]
pub struct AdminHandle(Arc<AdminState>);

impl std::fmt::Debug for AdminHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AdminHandle")
            .field("serving", &self.0.serving.load(Ordering::Relaxed))
            .field("failed", &self.0.failed.load(Ordering::Relaxed))
            .field("packets", &self.0.packets.load(Ordering::Relaxed))
            .finish()
    }
}

/// A parsed admin request (what an HTTP router would produce).
#[derive(Debug, Clone)]
pub enum AdminRequest {
    /// `GET /healthz`
    Health,
    /// `GET /capabilities`
    Capabilities,
    /// `GET /stats`
    Stats,
    /// `GET /metrics`: the stats snapshot in Prometheus text format.
    Metrics,
    /// `POST /models/<name>` with a model body: publish new weights.
    Publish { name: String, model: BnnModel },
    /// `POST /models/<name>/publish`: republish current weights
    /// (version bump, verdicts unchanged).
    Touch { name: String },
    /// `POST /models/<name>/rollback`: restore the previously archived
    /// epoch.
    Rollback { name: String },
    /// `POST /models/<name>/retrain`: queue one forced retrain for the
    /// online learner watching this slot (a no-op if no learner is
    /// armed or the name doesn't match its slot).
    Retrain { name: String },
}

impl AdminRequest {
    /// Route a `(method, path)` pair onto a typed request.  `Publish`
    /// carries a body and cannot be routed from a path alone.
    pub fn route(method: &str, path: &str) -> Result<Self, AdminError> {
        let not_found = || AdminError::NotFound(format!("{method} {path}"));
        match (method, path) {
            ("GET", "/healthz") => Ok(Self::Health),
            ("GET", "/capabilities") => Ok(Self::Capabilities),
            ("GET", "/stats") => Ok(Self::Stats),
            ("GET", "/metrics") => Ok(Self::Metrics),
            ("POST", _) => {
                let rest = path.strip_prefix("/models/").ok_or_else(not_found)?;
                let (name, action) = rest.rsplit_once('/').ok_or_else(not_found)?;
                if name.is_empty() || name.contains('/') {
                    return Err(not_found());
                }
                match action {
                    "publish" => Ok(Self::Touch { name: name.to_string() }),
                    "rollback" => Ok(Self::Rollback { name: name.to_string() }),
                    "retrain" => Ok(Self::Retrain { name: name.to_string() }),
                    _ => Err(not_found()),
                }
            }
            _ => Err(not_found()),
        }
    }
}

/// `GET /healthz` response.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HealthStatus {
    /// The serving loop is (still) processing packets.
    pub serving: bool,
    /// The run ended with a stage/overload failure.
    pub failed: bool,
    /// Packets ingested so far.
    pub packets: u64,
}

/// Typed admin response.
#[derive(Debug, Clone)]
pub enum AdminResponse {
    Health(HealthStatus),
    Capabilities(Capabilities),
    Stats(Box<ServiceStats>),
    /// Prometheus text-format rendering of the stats snapshot.
    Metrics(String),
    Published(VersionTag),
    RolledBack(VersionTag),
    /// The retrain request was queued for the learner.
    RetrainQueued { name: String },
}

/// Admin request failures.
#[derive(Debug, Clone, PartialEq)]
pub enum AdminError {
    /// No route for this method/path.
    NotFound(String),
    /// The handle was never bound to a built service.
    Unbound,
    /// The bound backend has no registry (publish/rollback need one).
    NoRegistry,
    /// Rollback with no archived epoch for this slot.
    NoHistory(String),
    /// Registry rejected the operation.
    Registry(RegistryError),
}

impl std::fmt::Display for AdminError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::NotFound(r) => write!(f, "no admin route: {r}"),
            Self::Unbound => write!(f, "admin handle not bound to a service"),
            Self::NoRegistry => write!(f, "backend has no model registry"),
            Self::NoHistory(n) => write!(f, "no archived epoch to roll {n:?} back to"),
            Self::Registry(e) => write!(f, "registry: {e}"),
        }
    }
}

impl std::error::Error for AdminError {}

impl From<RegistryError> for AdminError {
    fn from(e: RegistryError) -> Self {
        Self::Registry(e)
    }
}

impl AdminHandle {
    pub fn new() -> Self {
        Self::default()
    }

    /// Called by `ServeBuilder::build`: attach capabilities and (for
    /// registry backends) the registry, reset live counters.
    pub(crate) fn bind(&self, caps: Capabilities, registry: Option<RegistryHandle>) {
        *self.0.caps.lock().unwrap() = Some(caps);
        *self.0.registry.lock().unwrap() = registry;
        self.0.packets.store(0, Ordering::Relaxed);
        self.0.failed.store(false, Ordering::Relaxed);
        self.0.serving.store(true, Ordering::Relaxed);
    }

    /// One packet ingested (called from the serving hot loop).
    #[inline]
    pub(crate) fn on_packet(&self) {
        self.0.packets.fetch_add(1, Ordering::Relaxed);
    }

    /// Refresh the scrapeable stats snapshot.
    pub(crate) fn publish_stats(&self, stats: &ServiceStats) {
        *self.0.snapshot.lock().unwrap() = stats.clone();
    }

    /// Run finished: final snapshot + health flip.
    pub(crate) fn finish(&self, stats: &ServiceStats, failed: bool) {
        self.publish_stats(stats);
        self.0.failed.store(failed, Ordering::Relaxed);
        self.0.serving.store(false, Ordering::Relaxed);
    }

    fn registry(&self) -> Result<RegistryHandle, AdminError> {
        if self.0.caps.lock().unwrap().is_none() {
            return Err(AdminError::Unbound);
        }
        self.0.registry.lock().unwrap().clone().ok_or(AdminError::NoRegistry)
    }

    /// Archive the slot's current epoch so a later rollback can restore
    /// it.
    fn archive(&self, reg: &RegistryHandle, name: &str) {
        if let Some(cur) = reg.current(name) {
            self.0
                .history
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default()
                .push(cur);
        }
    }

    /// Serve one typed request.
    pub fn handle(&self, req: AdminRequest) -> Result<AdminResponse, AdminError> {
        match req {
            AdminRequest::Health => Ok(AdminResponse::Health(HealthStatus {
                serving: self.0.serving.load(Ordering::Relaxed),
                failed: self.0.failed.load(Ordering::Relaxed),
                packets: self.0.packets.load(Ordering::Relaxed),
            })),
            AdminRequest::Capabilities => self
                .0
                .caps
                .lock()
                .unwrap()
                .clone()
                .map(AdminResponse::Capabilities)
                .ok_or(AdminError::Unbound),
            AdminRequest::Stats => Ok(AdminResponse::Stats(Box::new(
                self.0.snapshot.lock().unwrap().clone(),
            ))),
            AdminRequest::Metrics => Ok(AdminResponse::Metrics(prometheus_text(
                &self.0.snapshot.lock().unwrap(),
            ))),
            AdminRequest::Publish { name, model } => {
                let reg = self.registry()?;
                self.archive(&reg, &name);
                Ok(AdminResponse::Published(reg.publish(&name, &model)?))
            }
            AdminRequest::Touch { name } => {
                let reg = self.registry()?;
                self.archive(&reg, &name);
                Ok(AdminResponse::Published(reg.touch(&name)?))
            }
            AdminRequest::Rollback { name } => {
                let reg = self.registry()?;
                let epoch = self
                    .0
                    .history
                    .lock()
                    .unwrap()
                    .get_mut(&name)
                    .and_then(Vec::pop)
                    .ok_or_else(|| AdminError::NoHistory(name.clone()))?;
                Ok(AdminResponse::RolledBack(reg.rollback(&name, &epoch)?))
            }
            AdminRequest::Retrain { name } => {
                self.0.retrains.lock().unwrap().push(name.clone());
                Ok(AdminResponse::RetrainQueued { name })
            }
        }
    }

    /// Drain the queued retrain requests (called by the serving loop at
    /// its snapshot cadence; the learner filters for its own slot).
    pub(crate) fn take_retrains(&self) -> Vec<String> {
        std::mem::take(&mut *self.0.retrains.lock().unwrap())
    }
}

/// Escape a Prometheus label value (`\` → `\\`, `"` → `\"`, newline →
/// `\n`).
fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Render a [`ServiceStats`] snapshot in the Prometheus text exposition
/// format — the `GET /metrics` body a sidecar exporter would serve.
/// Typed against the stats struct (every field is written out by name
/// here), so a new counter that should be scrapeable fails review, not
/// silently disappears.
pub fn prometheus_text(stats: &ServiceStats) -> String {
    let mut out = String::with_capacity(2048);
    let mut counter = |out: &mut String, name: &str, help: &str, v: u64| {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {v}");
    };
    counter(&mut out, "n3ic_packets_total", "Packets ingested.", stats.packets);
    counter(&mut out, "n3ic_triggers_total", "Flow triggers fired.", stats.triggers);
    counter(&mut out, "n3ic_inferences_total", "Verdicts produced.", stats.inferences);
    counter(&mut out, "n3ic_sheds_total", "Triggers shed by admission control.", stats.sheds);
    counter(&mut out, "n3ic_restarts_total", "Supervised stage restarts.", stats.restarts);

    let _ = writeln!(out, "# HELP n3ic_verdicts_total Verdict histogram by class.");
    let _ = writeln!(out, "# TYPE n3ic_verdicts_total counter");
    for (c, n) in stats.classes.iter().enumerate() {
        let _ = writeln!(out, "n3ic_verdicts_total{{class=\"{c}\"}} {n}");
    }

    if !stats.stage_blocked.is_empty() {
        let _ = writeln!(out, "# HELP n3ic_stage_blocked_total Backpressured sends per inter-stage link.");
        let _ = writeln!(out, "# TYPE n3ic_stage_blocked_total counter");
        for (i, n) in stats.stage_blocked.iter().enumerate() {
            let link = STAGE_LINKS.get(i).copied().unwrap_or("unknown");
            let _ = writeln!(out, "n3ic_stage_blocked_total{{link=\"{}\"}} {n}", escape_label(link));
        }
    }

    let _ = writeln!(out, "# HELP n3ic_latency_ns Verdict latency summary (modeled ns).");
    let _ = writeln!(out, "# TYPE n3ic_latency_ns gauge");
    let _ = writeln!(out, "n3ic_latency_ns{{stat=\"mean\"}} {}", stats.latency.mean_ns());
    let _ = writeln!(out, "n3ic_latency_ns{{stat=\"p50\"}} {}", stats.latency.percentile_ns(50.0));
    let _ = writeln!(out, "n3ic_latency_ns{{stat=\"p99\"}} {}", stats.latency.percentile_ns(99.0));
    let _ = writeln!(out, "n3ic_latency_ns{{stat=\"max\"}} {}", stats.latency.max_ns());

    let ft = &stats.flow_table;
    counter(&mut out, "n3ic_flow_evictions_total", "Flows displaced by eviction.", ft.evictions);
    counter(&mut out, "n3ic_flow_aged_out_total", "Idle flows removed by aging.", ft.aged_out);
    counter(&mut out, "n3ic_flow_collision_probes_total", "Hash-collision probe walks.", ft.collision_probes);
    counter(&mut out, "n3ic_flow_untracked_total", "Packets left untracked at capacity.", ft.untracked);
    let _ = writeln!(out, "# HELP n3ic_flow_occupied Live flows at snapshot time.");
    let _ = writeln!(out, "# TYPE n3ic_flow_occupied gauge");
    let _ = writeln!(out, "n3ic_flow_occupied {}", ft.occupied);
    let _ = writeln!(out, "# HELP n3ic_flow_slots Flow-table slot capacity.");
    let _ = writeln!(out, "# TYPE n3ic_flow_slots gauge");
    let _ = writeln!(out, "n3ic_flow_slots {}", ft.slots);

    if !stats.per_model.is_empty() {
        let _ = writeln!(out, "# HELP n3ic_model_inferences_total Verdicts per routed model.");
        let _ = writeln!(out, "# TYPE n3ic_model_inferences_total counter");
        for (name, m) in &stats.per_model {
            let _ = writeln!(
                out,
                "n3ic_model_inferences_total{{model=\"{}\"}} {}",
                escape_label(name),
                m.inferences
            );
        }
        let _ = writeln!(out, "# HELP n3ic_model_swaps_total Registry hot swaps per slot.");
        let _ = writeln!(out, "# TYPE n3ic_model_swaps_total counter");
        for (name, m) in &stats.per_model {
            let _ = writeln!(
                out,
                "n3ic_model_swaps_total{{model=\"{}\"}} {}",
                escape_label(name),
                m.swaps
            );
        }
    }

    if let Some(l) = &stats.learn {
        counter(&mut out, "n3ic_learn_windows_total", "Accuracy windows closed.", l.windows);
        counter(&mut out, "n3ic_learn_evaluated_total", "Labeled verdicts scored.", l.evaluated);
        counter(&mut out, "n3ic_learn_retrains_total", "Retraining attempts.", l.retrains);
        counter(&mut out, "n3ic_learn_promotions_total", "Candidates published through the gate.", l.promotions);
        counter(&mut out, "n3ic_learn_rejections_total", "Candidates the gate refused.", l.rejections);
        counter(&mut out, "n3ic_learn_rollbacks_total", "Probation rollbacks.", l.rollbacks);
        let _ = writeln!(out, "# HELP n3ic_learn_last_window_accuracy Labeled accuracy of the last closed window.");
        let _ = writeln!(out, "# TYPE n3ic_learn_last_window_accuracy gauge");
        let _ = writeln!(out, "n3ic_learn_last_window_accuracy {}", l.last_window_accuracy);
        let _ = writeln!(out, "# HELP n3ic_learn_in_probation A promotion is on probation (0/1).");
        let _ = writeln!(out, "# TYPE n3ic_learn_in_probation gauge");
        let _ = writeln!(out, "n3ic_learn_in_probation {}", u8::from(l.in_probation));
        if let Some(p) = l.drift_fired_at {
            let _ = writeln!(out, "# HELP n3ic_learn_drift_fired_at_packet Packet index of the first drift firing.");
            let _ = writeln!(out, "# TYPE n3ic_learn_drift_fired_at_packet gauge");
            let _ = writeln!(out, "n3ic_learn_drift_fired_at_packet {p}");
        }
        if let (Some(c), Some(cur)) = (l.gate_last_candidate, l.gate_last_current) {
            let _ = writeln!(out, "# HELP n3ic_learn_gate_accuracy Last gate decision's holdout scores.");
            let _ = writeln!(out, "# TYPE n3ic_learn_gate_accuracy gauge");
            let _ = writeln!(out, "n3ic_learn_gate_accuracy{{side=\"candidate\"}} {c}");
            let _ = writeln!(out, "n3ic_learn_gate_accuracy{{side=\"current\"}} {cur}");
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_parse_and_reject() {
        assert!(matches!(
            AdminRequest::route("GET", "/healthz").unwrap(),
            AdminRequest::Health
        ));
        assert!(matches!(
            AdminRequest::route("GET", "/capabilities").unwrap(),
            AdminRequest::Capabilities
        ));
        assert!(matches!(
            AdminRequest::route("GET", "/stats").unwrap(),
            AdminRequest::Stats
        ));
        match AdminRequest::route("POST", "/models/anomaly/publish").unwrap() {
            AdminRequest::Touch { name } => assert_eq!(name, "anomaly"),
            other => panic!("{other:?}"),
        }
        match AdminRequest::route("POST", "/models/tomography_64/rollback").unwrap() {
            AdminRequest::Rollback { name } => assert_eq!(name, "tomography_64"),
            other => panic!("{other:?}"),
        }
        assert!(matches!(
            AdminRequest::route("GET", "/metrics").unwrap(),
            AdminRequest::Metrics
        ));
        match AdminRequest::route("POST", "/models/traffic/retrain").unwrap() {
            AdminRequest::Retrain { name } => assert_eq!(name, "traffic"),
            other => panic!("{other:?}"),
        }
        for (m, p) in [
            ("GET", "/nope"),
            ("POST", "/models//publish"),
            ("POST", "/models/a/b/publish"),
            ("POST", "/models/a/drop"),
            ("DELETE", "/stats"),
        ] {
            assert!(
                matches!(AdminRequest::route(m, p), Err(AdminError::NotFound(_))),
                "{m} {p}"
            );
        }
    }

    #[test]
    fn unbound_handle_reports_not_serving_and_rejects_caps() {
        let h = AdminHandle::new();
        match h.handle(AdminRequest::Health).unwrap() {
            AdminResponse::Health(s) => {
                assert!(!s.serving && !s.failed);
                assert_eq!(s.packets, 0);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(
            h.handle(AdminRequest::Capabilities).unwrap_err(),
            AdminError::Unbound
        );
        assert_eq!(
            h.handle(AdminRequest::Touch { name: "m".into() }).unwrap_err(),
            AdminError::Unbound
        );
    }

    #[test]
    fn bound_handle_tracks_lifecycle_and_stats() {
        let h = AdminHandle::new();
        h.bind(Capabilities::single("fpga", 1_700.0), None);
        h.on_packet();
        h.on_packet();
        match h.handle(AdminRequest::Health).unwrap() {
            AdminResponse::Health(s) => {
                assert!(s.serving && !s.failed);
                assert_eq!(s.packets, 2);
            }
            other => panic!("{other:?}"),
        }
        match h.handle(AdminRequest::Capabilities).unwrap() {
            AdminResponse::Capabilities(c) => assert_eq!(c.backend, "fpga"),
            other => panic!("{other:?}"),
        }
        let stats = ServiceStats { packets: 2, ..Default::default() };
        h.finish(&stats, true);
        match h.handle(AdminRequest::Health).unwrap() {
            AdminResponse::Health(s) => assert!(!s.serving && s.failed),
            other => panic!("{other:?}"),
        }
        match h.handle(AdminRequest::Stats).unwrap() {
            AdminResponse::Stats(s) => assert_eq!(s.packets, 2),
            other => panic!("{other:?}"),
        }
        // Registry ops still rejected: this backend has none.
        assert_eq!(
            h.handle(AdminRequest::Touch { name: "m".into() }).unwrap_err(),
            AdminError::NoRegistry
        );
    }

    #[test]
    fn publish_touch_rollback_round_trip() {
        let reg = RegistryHandle::new();
        let m1 = BnnModel::random("m", 64, &[8, 2], 1);
        reg.publish("m", &m1).unwrap();
        let h = AdminHandle::new();
        h.bind(Capabilities::single("registry", 800.0), Some(reg.clone()));

        // Touch: version bump, old epoch archived.
        match h.handle(AdminRequest::Touch { name: "m".into() }).unwrap() {
            AdminResponse::Published(tag) => assert_eq!(tag.version(), 2),
            other => panic!("{other:?}"),
        }
        // Publish new weights on top.
        let m2 = BnnModel::random("m", 64, &[8, 2], 9);
        match h
            .handle(AdminRequest::Publish { name: "m".into(), model: m2 })
            .unwrap()
        {
            AdminResponse::Published(tag) => assert_eq!(tag.version(), 3),
            other => panic!("{other:?}"),
        }
        // Rollback restores the archived v2 epoch under a new version.
        match h.handle(AdminRequest::Rollback { name: "m".into() }).unwrap() {
            AdminResponse::RolledBack(tag) => assert_eq!(tag.version(), 4),
            other => panic!("{other:?}"),
        }
        // One more rollback pops the v1 archive; a third is empty.
        h.handle(AdminRequest::Rollback { name: "m".into() }).unwrap();
        assert_eq!(
            h.handle(AdminRequest::Rollback { name: "m".into() }).unwrap_err(),
            AdminError::NoHistory("m".into())
        );
    }

    #[test]
    fn retrain_queue_is_fifo_and_drains_once() {
        let h = AdminHandle::new();
        assert!(h.take_retrains().is_empty());
        for name in ["a", "b", "a"] {
            match h.handle(AdminRequest::Retrain { name: name.into() }).unwrap() {
                AdminResponse::RetrainQueued { name: n } => assert_eq!(n, name),
                other => panic!("{other:?}"),
            }
        }
        assert_eq!(h.take_retrains(), vec!["a", "b", "a"]);
        assert!(h.take_retrains().is_empty(), "drained");
    }

    #[test]
    fn prometheus_text_covers_core_and_learn_series() {
        use crate::learn::LearnStats;
        let mut stats = ServiceStats {
            packets: 100,
            triggers: 10,
            inferences: 9,
            classes: vec![4, 5],
            stage_blocked: vec![0, 2, 0],
            ..Default::default()
        };
        stats.latency.record(500.0);
        stats.per_model.insert(
            "anomaly".into(),
            crate::coordinator::service::ModelServiceStats {
                inferences: 9,
                classes: vec![4, 5],
                swaps: 3,
            },
        );
        stats.learn = Some(LearnStats {
            windows: 8,
            evaluated: 80,
            drift_fired_at: Some(2500),
            retrains: 2,
            promotions: 1,
            rejections: 1,
            rollbacks: 0,
            last_window_accuracy: 0.95,
            gate_last_candidate: Some(0.97),
            gate_last_current: Some(0.55),
            in_probation: true,
        });
        let text = prometheus_text(&stats);
        for needle in [
            "n3ic_packets_total 100",
            "n3ic_triggers_total 10",
            "n3ic_inferences_total 9",
            "n3ic_verdicts_total{class=\"1\"} 5",
            "n3ic_stage_blocked_total{link=\"parse→inference\"} 2",
            "n3ic_model_inferences_total{model=\"anomaly\"} 9",
            "n3ic_model_swaps_total{model=\"anomaly\"} 3",
            "n3ic_learn_windows_total 8",
            "n3ic_learn_retrains_total 2",
            "n3ic_learn_promotions_total 1",
            "n3ic_learn_drift_fired_at_packet 2500",
            "n3ic_learn_last_window_accuracy 0.95",
            "n3ic_learn_in_probation 1",
            "n3ic_learn_gate_accuracy{side=\"candidate\"} 0.97",
            "# TYPE n3ic_packets_total counter",
            "# TYPE n3ic_latency_ns gauge",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // No learner, no learn series.
        stats.learn = None;
        assert!(!prometheus_text(&stats).contains("n3ic_learn_"));
    }

    #[test]
    fn metrics_request_renders_the_snapshot() {
        let h = AdminHandle::new();
        h.bind(Capabilities::single("fpga", 1_700.0), None);
        h.publish_stats(&ServiceStats { packets: 42, ..Default::default() });
        match h.handle(AdminRequest::Metrics).unwrap() {
            AdminResponse::Metrics(text) => {
                assert!(text.contains("n3ic_packets_total 42"), "{text}");
            }
            other => panic!("{other:?}"),
        }
    }
}

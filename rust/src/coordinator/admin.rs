//! Typed admin/introspection surface for a running service — the
//! operational control plane the ROADMAP asks for, without dragging an
//! HTTP stack into the crate.  An [`AdminHandle`] is handed to
//! [`ServeBuilder::admin`](super::ServeBuilder::admin) before `build()`;
//! the serving runtime (serial and pipelined) binds it with the
//! backend's [`Capabilities`] and keeps a live packet counter plus a
//! periodic [`ServiceStats`] snapshot current while the run is in
//! flight.  Any other thread can then route requests through
//! [`AdminRequest::route`] — health check, capability introspection,
//! stats scrape, and model touch-publish/rollback against the backing
//! [`RegistryHandle`] — exactly the surface a sidecar daemon would wrap
//! in HTTP.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::bnn::{BnnModel, ModelEpoch, RegistryError, RegistryHandle, VersionTag};

use super::plane::Capabilities;
use super::service::ServiceStats;

/// Stats snapshot cadence in the serving loops (packets).
pub(crate) const SNAPSHOT_EVERY: u64 = 1024;

#[derive(Default)]
struct AdminState {
    serving: AtomicBool,
    failed: AtomicBool,
    packets: AtomicU64,
    snapshot: Mutex<ServiceStats>,
    caps: Mutex<Option<Capabilities>>,
    registry: Mutex<Option<RegistryHandle>>,
    /// Per-slot stack of archived epochs: every publish/touch pushes the
    /// previous current, rollback pops.
    history: Mutex<BTreeMap<String, Vec<Arc<ModelEpoch>>>>,
}

/// Cloneable handle onto one service's admin state.  Create it, pass a
/// clone to the builder, keep the original to issue requests from any
/// thread while the run is live (and after it finishes).
#[derive(Clone, Default)]
pub struct AdminHandle(Arc<AdminState>);

impl std::fmt::Debug for AdminHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AdminHandle")
            .field("serving", &self.0.serving.load(Ordering::Relaxed))
            .field("failed", &self.0.failed.load(Ordering::Relaxed))
            .field("packets", &self.0.packets.load(Ordering::Relaxed))
            .finish()
    }
}

/// A parsed admin request (what an HTTP router would produce).
#[derive(Debug, Clone)]
pub enum AdminRequest {
    /// `GET /healthz`
    Health,
    /// `GET /capabilities`
    Capabilities,
    /// `GET /stats`
    Stats,
    /// `POST /models/<name>` with a model body: publish new weights.
    Publish { name: String, model: BnnModel },
    /// `POST /models/<name>/publish`: republish current weights
    /// (version bump, verdicts unchanged).
    Touch { name: String },
    /// `POST /models/<name>/rollback`: restore the previously archived
    /// epoch.
    Rollback { name: String },
}

impl AdminRequest {
    /// Route a `(method, path)` pair onto a typed request.  `Publish`
    /// carries a body and cannot be routed from a path alone.
    pub fn route(method: &str, path: &str) -> Result<Self, AdminError> {
        let not_found = || AdminError::NotFound(format!("{method} {path}"));
        match (method, path) {
            ("GET", "/healthz") => Ok(Self::Health),
            ("GET", "/capabilities") => Ok(Self::Capabilities),
            ("GET", "/stats") => Ok(Self::Stats),
            ("POST", _) => {
                let rest = path.strip_prefix("/models/").ok_or_else(not_found)?;
                let (name, action) = rest.rsplit_once('/').ok_or_else(not_found)?;
                if name.is_empty() || name.contains('/') {
                    return Err(not_found());
                }
                match action {
                    "publish" => Ok(Self::Touch { name: name.to_string() }),
                    "rollback" => Ok(Self::Rollback { name: name.to_string() }),
                    _ => Err(not_found()),
                }
            }
            _ => Err(not_found()),
        }
    }
}

/// `GET /healthz` response.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HealthStatus {
    /// The serving loop is (still) processing packets.
    pub serving: bool,
    /// The run ended with a stage/overload failure.
    pub failed: bool,
    /// Packets ingested so far.
    pub packets: u64,
}

/// Typed admin response.
#[derive(Debug, Clone)]
pub enum AdminResponse {
    Health(HealthStatus),
    Capabilities(Capabilities),
    Stats(Box<ServiceStats>),
    Published(VersionTag),
    RolledBack(VersionTag),
}

/// Admin request failures.
#[derive(Debug, Clone, PartialEq)]
pub enum AdminError {
    /// No route for this method/path.
    NotFound(String),
    /// The handle was never bound to a built service.
    Unbound,
    /// The bound backend has no registry (publish/rollback need one).
    NoRegistry,
    /// Rollback with no archived epoch for this slot.
    NoHistory(String),
    /// Registry rejected the operation.
    Registry(RegistryError),
}

impl std::fmt::Display for AdminError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::NotFound(r) => write!(f, "no admin route: {r}"),
            Self::Unbound => write!(f, "admin handle not bound to a service"),
            Self::NoRegistry => write!(f, "backend has no model registry"),
            Self::NoHistory(n) => write!(f, "no archived epoch to roll {n:?} back to"),
            Self::Registry(e) => write!(f, "registry: {e}"),
        }
    }
}

impl std::error::Error for AdminError {}

impl From<RegistryError> for AdminError {
    fn from(e: RegistryError) -> Self {
        Self::Registry(e)
    }
}

impl AdminHandle {
    pub fn new() -> Self {
        Self::default()
    }

    /// Called by `ServeBuilder::build`: attach capabilities and (for
    /// registry backends) the registry, reset live counters.
    pub(crate) fn bind(&self, caps: Capabilities, registry: Option<RegistryHandle>) {
        *self.0.caps.lock().unwrap() = Some(caps);
        *self.0.registry.lock().unwrap() = registry;
        self.0.packets.store(0, Ordering::Relaxed);
        self.0.failed.store(false, Ordering::Relaxed);
        self.0.serving.store(true, Ordering::Relaxed);
    }

    /// One packet ingested (called from the serving hot loop).
    #[inline]
    pub(crate) fn on_packet(&self) {
        self.0.packets.fetch_add(1, Ordering::Relaxed);
    }

    /// Refresh the scrapeable stats snapshot.
    pub(crate) fn publish_stats(&self, stats: &ServiceStats) {
        *self.0.snapshot.lock().unwrap() = stats.clone();
    }

    /// Run finished: final snapshot + health flip.
    pub(crate) fn finish(&self, stats: &ServiceStats, failed: bool) {
        self.publish_stats(stats);
        self.0.failed.store(failed, Ordering::Relaxed);
        self.0.serving.store(false, Ordering::Relaxed);
    }

    fn registry(&self) -> Result<RegistryHandle, AdminError> {
        if self.0.caps.lock().unwrap().is_none() {
            return Err(AdminError::Unbound);
        }
        self.0.registry.lock().unwrap().clone().ok_or(AdminError::NoRegistry)
    }

    /// Archive the slot's current epoch so a later rollback can restore
    /// it.
    fn archive(&self, reg: &RegistryHandle, name: &str) {
        if let Some(cur) = reg.current(name) {
            self.0
                .history
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default()
                .push(cur);
        }
    }

    /// Serve one typed request.
    pub fn handle(&self, req: AdminRequest) -> Result<AdminResponse, AdminError> {
        match req {
            AdminRequest::Health => Ok(AdminResponse::Health(HealthStatus {
                serving: self.0.serving.load(Ordering::Relaxed),
                failed: self.0.failed.load(Ordering::Relaxed),
                packets: self.0.packets.load(Ordering::Relaxed),
            })),
            AdminRequest::Capabilities => self
                .0
                .caps
                .lock()
                .unwrap()
                .clone()
                .map(AdminResponse::Capabilities)
                .ok_or(AdminError::Unbound),
            AdminRequest::Stats => Ok(AdminResponse::Stats(Box::new(
                self.0.snapshot.lock().unwrap().clone(),
            ))),
            AdminRequest::Publish { name, model } => {
                let reg = self.registry()?;
                self.archive(&reg, &name);
                Ok(AdminResponse::Published(reg.publish(&name, &model)?))
            }
            AdminRequest::Touch { name } => {
                let reg = self.registry()?;
                self.archive(&reg, &name);
                Ok(AdminResponse::Published(reg.touch(&name)?))
            }
            AdminRequest::Rollback { name } => {
                let reg = self.registry()?;
                let epoch = self
                    .0
                    .history
                    .lock()
                    .unwrap()
                    .get_mut(&name)
                    .and_then(Vec::pop)
                    .ok_or_else(|| AdminError::NoHistory(name.clone()))?;
                Ok(AdminResponse::RolledBack(reg.rollback(&name, &epoch)?))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_parse_and_reject() {
        assert!(matches!(
            AdminRequest::route("GET", "/healthz").unwrap(),
            AdminRequest::Health
        ));
        assert!(matches!(
            AdminRequest::route("GET", "/capabilities").unwrap(),
            AdminRequest::Capabilities
        ));
        assert!(matches!(
            AdminRequest::route("GET", "/stats").unwrap(),
            AdminRequest::Stats
        ));
        match AdminRequest::route("POST", "/models/anomaly/publish").unwrap() {
            AdminRequest::Touch { name } => assert_eq!(name, "anomaly"),
            other => panic!("{other:?}"),
        }
        match AdminRequest::route("POST", "/models/tomography_64/rollback").unwrap() {
            AdminRequest::Rollback { name } => assert_eq!(name, "tomography_64"),
            other => panic!("{other:?}"),
        }
        for (m, p) in [
            ("GET", "/nope"),
            ("POST", "/models//publish"),
            ("POST", "/models/a/b/publish"),
            ("POST", "/models/a/drop"),
            ("DELETE", "/stats"),
        ] {
            assert!(
                matches!(AdminRequest::route(m, p), Err(AdminError::NotFound(_))),
                "{m} {p}"
            );
        }
    }

    #[test]
    fn unbound_handle_reports_not_serving_and_rejects_caps() {
        let h = AdminHandle::new();
        match h.handle(AdminRequest::Health).unwrap() {
            AdminResponse::Health(s) => {
                assert!(!s.serving && !s.failed);
                assert_eq!(s.packets, 0);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(
            h.handle(AdminRequest::Capabilities).unwrap_err(),
            AdminError::Unbound
        );
        assert_eq!(
            h.handle(AdminRequest::Touch { name: "m".into() }).unwrap_err(),
            AdminError::Unbound
        );
    }

    #[test]
    fn bound_handle_tracks_lifecycle_and_stats() {
        let h = AdminHandle::new();
        h.bind(Capabilities::single("fpga", 1_700.0), None);
        h.on_packet();
        h.on_packet();
        match h.handle(AdminRequest::Health).unwrap() {
            AdminResponse::Health(s) => {
                assert!(s.serving && !s.failed);
                assert_eq!(s.packets, 2);
            }
            other => panic!("{other:?}"),
        }
        match h.handle(AdminRequest::Capabilities).unwrap() {
            AdminResponse::Capabilities(c) => assert_eq!(c.backend, "fpga"),
            other => panic!("{other:?}"),
        }
        let stats = ServiceStats { packets: 2, ..Default::default() };
        h.finish(&stats, true);
        match h.handle(AdminRequest::Health).unwrap() {
            AdminResponse::Health(s) => assert!(!s.serving && s.failed),
            other => panic!("{other:?}"),
        }
        match h.handle(AdminRequest::Stats).unwrap() {
            AdminResponse::Stats(s) => assert_eq!(s.packets, 2),
            other => panic!("{other:?}"),
        }
        // Registry ops still rejected: this backend has none.
        assert_eq!(
            h.handle(AdminRequest::Touch { name: "m".into() }).unwrap_err(),
            AdminError::NoRegistry
        );
    }

    #[test]
    fn publish_touch_rollback_round_trip() {
        let reg = RegistryHandle::new();
        let m1 = BnnModel::random("m", 64, &[8, 2], 1);
        reg.publish("m", &m1).unwrap();
        let h = AdminHandle::new();
        h.bind(Capabilities::single("registry", 800.0), Some(reg.clone()));

        // Touch: version bump, old epoch archived.
        match h.handle(AdminRequest::Touch { name: "m".into() }).unwrap() {
            AdminResponse::Published(tag) => assert_eq!(tag.version(), 2),
            other => panic!("{other:?}"),
        }
        // Publish new weights on top.
        let m2 = BnnModel::random("m", 64, &[8, 2], 9);
        match h
            .handle(AdminRequest::Publish { name: "m".into(), model: m2 })
            .unwrap()
        {
            AdminResponse::Published(tag) => assert_eq!(tag.version(), 3),
            other => panic!("{other:?}"),
        }
        // Rollback restores the archived v2 epoch under a new version.
        match h.handle(AdminRequest::Rollback { name: "m".into() }).unwrap() {
            AdminResponse::RolledBack(tag) => assert_eq!(tag.version(), 4),
            other => panic!("{other:?}"),
        }
        // One more rollback pops the v1 archive; a third is empty.
        h.handle(AdminRequest::Rollback { name: "m".into() }).unwrap();
        assert_eq!(
            h.handle(AdminRequest::Rollback { name: "m".into() }).unwrap_err(),
            AdminError::NoHistory("m".into())
        );
    }
}

//! Deprecated serving API — thin shims over the unified
//! [`Service`](super::Service) runtime, kept for **one PR** as a
//! migration bridge to [`ServeBuilder`](super::ServeBuilder) +
//! [`BackendFactory`](super::BackendFactory).
//!
//! The shims are *behavior*-preserving, not source-identical: the old
//! public fields (`stats`, `sink`, `flows`, `exec`) are now accessor
//! methods, the pipeline runtimes return the unified
//! [`ServiceReport`]/[`ServiceError`] instead of the deleted
//! `PipelineReport`/`PipelineError` pair, and backend faults panic at
//! the next `handle`/`flush` rather than mid-batch.  Out-of-tree
//! callers doing more than construct-configure-serve should jump
//! straight to the builder (README §Architecture has the mapping).
//!
//! Everything here delegates to the new machinery; nothing in this
//! module has behavior of its own.  In-repo callers are migrated and
//! `scripts/verify.sh` denies `deprecated` over tests/benches, so no
//! new use can land.
#![allow(deprecated)]

use std::marker::PhantomData;
use std::sync::mpsc;

use crate::bnn::{BnnModel, EngineStats, RegistryError, RegistryHandle, VersionTag};

use super::backend::registry_plane;
use super::plane::{Capabilities, InferencePlane};
use super::selector::{OutputSelector, OutputSink};
use super::service::{
    PacketEvent, RouteLogic, SerialCore, ServeBuilder, ServiceError, ServiceReport, ServiceStats,
    TaggedVerdict,
};
use super::trigger::{ModelRouter, TriggerCondition};

/// Uniform executor interface of the pre-`InferencePlane` API.
#[deprecated(note = "implement `InferencePlane` instead (one trait for every backend)")]
pub trait NnExecutor: Send {
    /// Bit-exact classification of one packed input.
    fn classify(&mut self, x: &[u32]) -> usize;
    /// Raw final-layer scores.
    fn scores(&mut self, x: &[u32], out: &mut [i32]);
    /// Modeled (or measured) per-inference latency in ns.
    fn latency_ns(&self) -> f64;
    /// Backend name for logs/metrics.
    fn name(&self) -> &'static str;
    /// Output classes of the deployed model (verdict histogram width).
    fn n_classes(&self) -> usize;
}

/// Batch extension of [`NnExecutor`] (pre-`InferencePlane` API).
#[deprecated(note = "implement `InferencePlane` instead (one trait for every backend)")]
pub trait NnBatchExecutor: NnExecutor {
    /// Classify a whole batch; `classes` is cleared and refilled with
    /// one verdict per input, in input order.
    fn classify_batch(&mut self, inputs: &[Vec<u32>], classes: &mut Vec<usize>) {
        classes.clear();
        classes.reserve(inputs.len());
        for x in inputs {
            let c = self.classify(x);
            classes.push(c);
        }
    }

    /// Modeled time for this backend to complete a batch of `b`.
    fn batch_latency_ns(&self, b: usize) -> f64 {
        self.latency_ns() * b as f64
    }

    /// Throughput counters of an underlying multi-core engine, if any.
    fn engine_stats(&self) -> Option<EngineStats> {
        None
    }
}

/// Adapter: any legacy [`NnBatchExecutor`] serves behind the unified
/// [`InferencePlane`] API (this is how the shim services reuse the one
/// runtime).
#[deprecated(note = "implement `InferencePlane` directly")]
pub struct LegacyPlane<E> {
    exec: E,
}

impl<E: NnBatchExecutor> LegacyPlane<E> {
    pub fn new(exec: E) -> Self {
        Self { exec }
    }
}

impl<E: NnBatchExecutor> InferencePlane for LegacyPlane<E> {
    fn capabilities(&self) -> Capabilities {
        Capabilities::single(self.exec.name(), self.exec.latency_ns())
    }

    fn classify(&mut self, _route: usize, x: &[u32]) -> (usize, Option<VersionTag>) {
        (self.exec.classify(x), None)
    }

    fn try_run_batch(
        &mut self,
        _route: usize,
        inputs: &[Vec<u32>],
        classes: &mut Vec<usize>,
    ) -> Result<Option<VersionTag>, crate::bnn::EngineError> {
        self.exec.classify_batch(inputs, classes);
        Ok(None)
    }

    fn batch_latency_ns(&self, b: usize) -> f64 {
        self.exec.batch_latency_ns(b)
    }

    fn n_classes(&self) -> usize {
        self.exec.n_classes()
    }

    fn engine_stats(&self) -> Option<EngineStats> {
        self.exec.engine_stats()
    }
}

/// Host / device adapter of the pre-factory API.
#[deprecated(note = "use `BackendFactory::single(\"fpga\"| \"nfp\" | \"host\" | \"pisa\", model)`")]
pub struct CoreExecutor {
    exec: crate::bnn::BnnExecutor,
    /// Weight-stationary batch path, sharing `exec`'s packed weights.
    batch: crate::bnn::BatchKernel,
    /// Multi-core batch path (enabled by [`sharded`](Self::sharded)).
    engine: Option<crate::bnn::ShardedEngine>,
    latency_ns: f64,
    name: &'static str,
}

impl CoreExecutor {
    /// Wrap the bit-exact core with a backend-specific latency model.
    pub fn new(model: BnnModel, latency_ns: f64, name: &'static str) -> Self {
        let exec = crate::bnn::BnnExecutor::new(model);
        let batch = crate::bnn::BatchKernel::with_packed(exec.packed_model());
        Self { exec, batch, engine: None, latency_ns, name }
    }

    /// Route batches through a sharded engine of `n_shards` workers.
    pub fn sharded(mut self, n_shards: usize) -> Self {
        if n_shards > 1 {
            self.engine = Some(crate::bnn::ShardedEngine::with_packed(
                self.exec.packed_model(),
                n_shards,
            ));
        }
        self
    }

    /// N3IC-FPGA executor adapter.
    pub fn fpga(model: BnnModel) -> Self {
        let lat = crate::fpga::FpgaTiming::new(&model).latency_ns();
        Self::new(model, lat, "n3ic-fpga")
    }

    /// N3IC-NFP (data-parallel, CLS) adapter.
    pub fn nfp(model: BnnModel) -> Self {
        let lat = crate::nfp::DataParallelCost::new(&model, crate::nfp::MemKind::Cls).mean_ns();
        Self::new(model, lat, "n3ic-nfp")
    }

    /// Host `bnn-exec` adapter (batch-1 latency incl. PCIe).
    pub fn host(model: BnnModel) -> Self {
        let lat = crate::bnnexec::HostCostModel::default().batch_latency_ns(&model, 1);
        Self::new(model, lat, "bnn-exec")
    }

    /// N3IC-P4 adapter; fails for models the PISA target cannot fit.
    pub fn pisa(model: BnnModel) -> Result<Self, crate::pisa::CompileError> {
        let prog = crate::pisa::compile_bnn(&model)?;
        let lat = prog.latency_ns(64);
        Ok(Self::new(model, lat, "n3ic-p4"))
    }
}

impl NnExecutor for CoreExecutor {
    fn classify(&mut self, x: &[u32]) -> usize {
        self.exec.classify(x)
    }

    fn scores(&mut self, x: &[u32], out: &mut [i32]) {
        self.exec.infer(x, out)
    }

    fn latency_ns(&self) -> f64 {
        self.latency_ns
    }

    fn name(&self) -> &'static str {
        self.name
    }

    fn n_classes(&self) -> usize {
        self.exec.model().out_neurons()
    }
}

impl NnBatchExecutor for CoreExecutor {
    fn classify_batch(&mut self, inputs: &[Vec<u32>], classes: &mut Vec<usize>) {
        match self.engine.as_mut() {
            Some(engine) => engine.run_batch(inputs, classes),
            None => self.batch.run_batch(inputs, classes),
        }
    }

    fn engine_stats(&self) -> Option<EngineStats> {
        self.engine.as_ref().map(|e| e.stats())
    }
}

/// Tuning knobs of the old standalone pipeline runtimes.
#[deprecated(note = "use `ServeBuilder::pipeline/queue_depth/batching/flow_capacity`")]
#[derive(Debug, Clone, Copy)]
pub struct PipelineConfig {
    /// Stage-1 parse/flow-table workers (flow-hash shards), ≥ 1.
    pub workers: usize,
    /// Capacity of each bounded inter-stage channel, ≥ 1.
    pub queue_depth: usize,
    /// 0 = classify inline in stage 3; N ≥ 1 = accumulate batches of N.
    pub batch: usize,
    /// Packet-clock cap on batch queueing.
    pub max_wait_ns: f64,
    /// Flow-table capacity *per worker*.
    pub flow_capacity: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_depth: 1024,
            batch: 0,
            max_wait_ns: 1e6,
            flow_capacity: 1 << 16,
        }
    }
}

/// The old single-model serial loop.
#[deprecated(note = "use `ServeBuilder` — one `Service` replaces the four legacy runtimes")]
pub struct CoordinatorService<E: NnBatchExecutor + 'static> {
    core: SerialCore,
    _exec: PhantomData<E>,
}

impl<E: NnBatchExecutor + 'static> CoordinatorService<E> {
    pub fn new(exec: E, trigger: TriggerCondition, output: OutputSelector) -> Self {
        Self {
            core: SerialCore::unbatched(
                Box::new(LegacyPlane::new(exec)),
                RouteLogic::Trigger(trigger),
                output,
                1 << 16,
            ),
            _exec: PhantomData,
        }
    }

    pub fn with_batching(mut self, max_size: usize, max_wait_ns: f64) -> Self {
        self.core.set_batching(max_size, max_wait_ns);
        self
    }

    pub fn pending(&self) -> usize {
        self.core.pending()
    }

    pub fn handle(&mut self, ev: &PacketEvent) {
        self.core.handle(ev);
        panic_on_fault(&self.core);
    }

    pub fn flush(&mut self) {
        self.core.flush();
        panic_on_fault(&self.core);
    }

    pub fn stats(&self) -> &ServiceStats {
        self.core.stats()
    }

    pub fn sink(&self) -> &OutputSink {
        self.core.sink()
    }

    pub fn flows_tracked(&self) -> usize {
        self.core.flows_tracked()
    }

    /// Event loop: drain an mpsc channel until all senders drop.
    pub fn run(mut self, rx: mpsc::Receiver<PacketEvent>) -> ServiceStats {
        while let Ok(ev) = rx.recv() {
            self.handle(&ev);
        }
        self.flush();
        self.core.into_stats()
    }
}

/// The pre-unification serial loops panicked on a backend fault; the
/// unified core records it instead.  The shims keep the old contract.
fn panic_on_fault(core: &SerialCore) {
    if let Some(f) = core.failure() {
        panic!("{f}");
    }
}

/// The old registry-routed serial loop.
///
/// Shim caveat: `with_batching` / `with_shards` / `without_tag_log`
/// are builder-style and rebuild the underlying core — configure the
/// service **before** feeding traffic (as every known caller does);
/// reconfiguring mid-stream resets accumulated stats and sink state.
#[deprecated(note = "use `ServeBuilder` with `BackendFactory::registry` and `.router(...)`")]
pub struct MultiModelService {
    registry: RegistryHandle,
    router: ModelRouter,
    output: OutputSelector,
    latency_ns: f64,
    batch: Option<(usize, f64)>,
    shards: usize,
    log_tags: bool,
    core: SerialCore,
}

impl MultiModelService {
    pub fn new(
        registry: RegistryHandle,
        router: ModelRouter,
        output: OutputSelector,
        latency_ns: f64,
    ) -> Result<Self, RegistryError> {
        let core = Self::build_core(&registry, &router, output, latency_ns, None, 1, true)?;
        Ok(Self {
            registry,
            router,
            output,
            latency_ns,
            batch: None,
            shards: 1,
            log_tags: true,
            core,
        })
    }

    fn build_core(
        registry: &RegistryHandle,
        router: &ModelRouter,
        output: OutputSelector,
        latency_ns: f64,
        batch: Option<(usize, f64)>,
        shards: usize,
        log_tags: bool,
    ) -> Result<SerialCore, RegistryError> {
        let plane = registry_plane(registry, router.model_names(), latency_ns, shards)?;
        let mut core =
            SerialCore::unbatched(plane, RouteLogic::Router(router.clone()), output, 1 << 16);
        if let Some((size, wait)) = batch {
            core.set_batching(size, wait);
        }
        if !log_tags {
            core.disable_tag_log();
        }
        Ok(core)
    }

    fn rebuild(&mut self) {
        self.core = Self::build_core(
            &self.registry,
            &self.router,
            self.output,
            self.latency_ns,
            self.batch,
            self.shards,
            self.log_tags,
        )
        .expect("slots were validated at construction");
    }

    pub fn with_batching(mut self, max_size: usize, max_wait_ns: f64) -> Self {
        self.batch = Some((max_size, max_wait_ns));
        self.rebuild();
        self
    }

    pub fn with_shards(mut self, n_shards: usize) -> Self {
        self.shards = n_shards;
        self.rebuild();
        self
    }

    pub fn without_tag_log(mut self) -> Self {
        self.log_tags = false;
        self.rebuild();
        self
    }

    pub fn pending(&self) -> usize {
        self.core.pending()
    }

    pub fn handle(&mut self, ev: &PacketEvent) {
        self.core.handle(ev);
        panic_on_fault(&self.core);
    }

    pub fn flush(&mut self) {
        self.core.flush();
        panic_on_fault(&self.core);
    }

    pub fn stats(&self) -> &ServiceStats {
        self.core.stats()
    }

    pub fn sink(&self) -> &OutputSink {
        self.core.sink()
    }

    pub fn tagged(&self) -> &[TaggedVerdict] {
        self.core.tagged()
    }

    pub fn engine_stats(&self) -> Option<EngineStats> {
        self.core.engine_stats()
    }

    /// Event loop: drain the channel until all senders drop; flushes and
    /// returns the accumulated statistics plus the tagged verdict log.
    pub fn run(mut self, rx: mpsc::Receiver<PacketEvent>) -> (ServiceStats, Vec<TaggedVerdict>) {
        while let Ok(ev) = rx.recv() {
            self.handle(&ev);
        }
        self.flush();
        self.core.into_stats_and_tags()
    }
}

/// The old single-model staged runtime.
#[deprecated(note = "use `ServeBuilder::pipeline(n)` — the one `Service` runs staged too")]
pub struct PipelineService<E: NnBatchExecutor + 'static> {
    exec: E,
    trigger: TriggerCondition,
    output: OutputSelector,
    cfg: PipelineConfig,
}

impl<E: NnBatchExecutor + 'static> PipelineService<E> {
    pub fn new(
        exec: E,
        trigger: TriggerCondition,
        output: OutputSelector,
        cfg: PipelineConfig,
    ) -> Self {
        Self { exec, trigger, output, cfg }
    }

    pub fn run(
        self,
        events: impl IntoIterator<Item = PacketEvent>,
    ) -> Result<ServiceReport, ServiceError> {
        let mut b = ServeBuilder::new()
            .backend(Box::new(LegacyPlane::new(self.exec)))
            .trigger(self.trigger)
            .output(self.output)
            .pipeline(self.cfg.workers.max(1))
            .queue_depth(self.cfg.queue_depth)
            .flow_capacity(self.cfg.flow_capacity);
        if self.cfg.batch > 0 {
            b = b.batching(self.cfg.batch, self.cfg.max_wait_ns);
        }
        b.build()?.run(events)
    }
}

/// The old registry-routed staged runtime.
#[deprecated(note = "use `ServeBuilder::pipeline(n)` with `BackendFactory::registry`")]
pub struct RoutedPipelineService {
    registry: RegistryHandle,
    router: ModelRouter,
    output: OutputSelector,
    cfg: PipelineConfig,
    latency_ns: f64,
    shards: usize,
    log_tags: bool,
}

impl RoutedPipelineService {
    pub fn new(
        registry: RegistryHandle,
        router: ModelRouter,
        output: OutputSelector,
        cfg: PipelineConfig,
        latency_ns: f64,
    ) -> Result<Self, RegistryError> {
        // Surface unknown-slot errors here, as the old constructor did.
        for name in router.model_names() {
            registry.reader(name)?;
        }
        Ok(Self {
            registry,
            router,
            output,
            cfg,
            latency_ns,
            shards: 1,
            log_tags: true,
        })
    }

    pub fn with_shards(mut self, n_shards: usize) -> Self {
        self.shards = n_shards;
        self
    }

    pub fn without_tag_log(mut self) -> Self {
        self.log_tags = false;
        self
    }

    pub fn run(
        self,
        events: impl IntoIterator<Item = PacketEvent>,
    ) -> Result<ServiceReport, ServiceError> {
        let plane =
            registry_plane(&self.registry, self.router.model_names(), self.latency_ns, self.shards)
                .map_err(ServiceError::Registry)?;
        let mut b = ServeBuilder::new()
            .backend(plane)
            .router(self.router)
            .output(self.output)
            .pipeline(self.cfg.workers.max(1))
            .queue_depth(self.cfg.queue_depth)
            .flow_capacity(self.cfg.flow_capacity);
        if self.cfg.batch > 0 {
            b = b.batching(self.cfg.batch, self.cfg.max_wait_ns);
        }
        if !self.log_tags {
            b = b.without_tag_log();
        }
        b.build()?.run(events)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::{infer_packed, BnnLayer, BnnModel};
    use crate::coordinator::{BackendFactory, ServeBuilder};
    use crate::net::traffic::CbrSpec;

    fn model() -> BnnModel {
        BnnModel::random("traffic", 256, &[32, 16, 2], 1)
    }

    #[test]
    fn legacy_adapters_stay_bit_exact_and_latency_ordered() {
        let m = model();
        let x = BnnLayer::random(1, 256, 99).words;
        let want = infer_packed(&m, &x);
        let mut fpga = CoreExecutor::fpga(m.clone());
        let mut nfp = CoreExecutor::nfp(m.clone());
        let mut host = CoreExecutor::host(m.clone());
        let mut pisa = CoreExecutor::pisa(m.clone()).unwrap();
        for e in [&mut fpga as &mut dyn NnExecutor, &mut nfp, &mut host, &mut pisa] {
            assert_eq!(e.classify(&x), want, "{}", e.name());
        }
        // Fig. 14 ordering: FPGA < P4 < NFP; batch-1 host is 10s of µs.
        assert!(fpga.latency_ns() < pisa.latency_ns());
        assert!(pisa.latency_ns() < nfp.latency_ns());
        assert!(host.latency_ns() > 10_000.0);
    }

    #[test]
    fn legacy_coordinator_shim_matches_the_builder_service() {
        let events =
            PacketEvent::cbr_burst(CbrSpec { gbps: 10.0, pkt_size: 256 }, 40, 6, 4000);
        let mut shim = CoordinatorService::new(
            CoreExecutor::fpga(model()),
            TriggerCondition::EveryNPackets(10),
            OutputSelector::Memory,
        );
        for ev in &events {
            shim.handle(ev);
        }
        shim.flush();
        let rep = ServeBuilder::new()
            .backend(BackendFactory::single("fpga", model()).unwrap())
            .trigger(TriggerCondition::EveryNPackets(10))
            .build()
            .unwrap()
            .run(events.iter().cloned())
            .unwrap();
        assert_eq!(shim.stats().triggers, rep.stats.triggers);
        assert_eq!(shim.stats().classes, rep.stats.classes);
        let mut a = shim.sink().memory.clone();
        let mut b = rep.sink.memory.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }
}

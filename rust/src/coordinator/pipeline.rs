//! The staged (multi-threaded) mode of the unified [`Service`]: the
//! serial event loop decomposed into the staged co-processor shape the
//! NIC actually has, so the parse work for packet *n+1* overlaps the
//! inference for packet *n* instead of serializing behind it:
//!
//! ```text
//!  ingress ─┬─▶ parse/route/trigger worker 0 ─┐
//!  (shard   ├─▶ parse/route/trigger worker 1 ─┼─▶ batch lanes ─▶ ordered
//!  by flow  ┆            …                    ┆    + backend     sink +
//!  hash)    └─▶ parse/route/trigger worker N ─┘   (InferencePlane) metrics
//!     stage 0          stage 1+2                   stage 3       stage 4
//! ```
//!
//! One implementation serves every composition: single-model and routed
//! multi-model, inline and batched, tagged and untagged — the knobs are
//! [`ServeBuilder`](super::ServeBuilder) options, not separate runtimes.
//!
//! Stages are connected by **bounded** `sync_channel`s: a full queue
//! blocks the producer (lossless backpressure — no verdict is ever
//! dropped) and each blocked send is counted in
//! [`ServiceStats::stage_blocked`], indexed by [`STAGE_LINKS`].
//!
//! ## Determinism contract (the tier-1 equivalence property)
//!
//! Given the same seeded traffic, this staged mode produces
//! **bit-identical** verdict histograms, trigger counts, inference
//! counts, eviction counts, and per-flow verdicts to the serial mode,
//! for any worker count, queue depth, or batch size.  This holds by
//! construction:
//!
//! * flow state lives in [`FLOW_SHARDS`] fixed logical shards in *both*
//!   modes: the serial loop owns all of them, and here worker `w` owns
//!   the shards `l` with `l % workers == w`.  Ingress routes each packet
//!   to its shard's owner by canonical flow hash
//!   ([`ShardedFlowTable::shard_of`] over `FLOW_SHARDS`, then
//!   `% workers`), so every shard-table sees the exact same packet
//!   subsequence, in arrival order (`sync_channel` is FIFO), for any
//!   worker count;
//! * eviction and aging ([`EvictPolicy`](crate::net::flow::EvictPolicy)) are pure
//!   functions of one shard-table's update sequence on the packet clock
//!   — with the shard populations fixed above, who gets evicted (and
//!   therefore which flows re-trigger as new) cannot depend on thread
//!   scheduling;
//! * routing ([`RouteLogic`]) and the flow statistics a trigger
//!   snapshots are functions of that flow's packets only (plus its
//!   shard-local eviction history, fixed above), so cross-flow
//!   interleaving cannot change what fires, where it routes, or what
//!   gets packed;
//! * every [`InferencePlane`] classifies each packed input bit-exactly
//!   regardless of the batch it rides in, so batch composition (which
//!   *does* vary with timing) is invisible in the verdicts.
//!
//! Latency *histograms* are exempt from the contract — queueing delay is
//! real time, not packet time.  The contract is asserted end-to-end in
//! `tests/pipeline_equiv.rs` and over every factory backend in
//! `tests/plane_conformance.rs`.
//!
//! ## Failure semantics
//!
//! A stage that dies (backend panic, poisoned channel) must not hang
//! the service: its channel endpoints drop, upstream sends and
//! downstream receives error out, every surviving stage exits its loop
//! and reports, and [`Service::run`](super::Service::run) returns a
//! [`ServiceError::Stage`] carrying typed [`StageFailure`]s plus the
//! stats accumulated up to the fault (`tests/failure_injection.rs`).

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::bnn::{EngineStats, VersionTag};
use crate::net::flow::{FlowKey, FlowTable, ShardedFlowTable, FLOW_SHARDS};

use super::batcher::BatchSet;
use super::overload::{
    guard, ladder_for, panic_text, AdmissionController, DegradationLadder, FaultPlan, PlaneHealth,
    ServiceLevel, ShedPolicy, SupervisorPolicy, WorkerAdmission,
};
use super::admin::SNAPSHOT_EVERY;
use super::plane::InferencePlane;
use super::selector::{OutputSelector, OutputSink};
use super::service::{
    batch_item_latency_ns, flow_id, select_packed_input, ModelServiceStats, PacketEvent,
    PendingFlow, RouteLogic, Service, ServiceError, ServiceReport, ServiceStats, StageFailure,
    TaggedVerdict,
};

/// Inter-stage links, in `ServiceStats::stage_blocked` index order.
pub const STAGE_LINKS: [&str; 3] = ["ingress→parse", "parse→inference", "inference→sink"];

/// Stage 0 → stage 1+2 messages.
enum ParseMsg {
    /// One ingress packet, sharded to this worker by flow hash.
    Event(PacketEvent),
    /// Learner publish barrier (see the `learn` module docs): the
    /// worker forwards it downstream in FIFO position, so everything it
    /// parsed before the barrier reaches the inference stage before the
    /// barrier does.
    Barrier,
}

/// Stage 1+2 → stage 3 messages.
enum InfMsg {
    /// A triggered flow: its route (model lane), routing id, packed NN
    /// input, and the trigger packet's clock (drives batch timeouts).
    Flow {
        route: usize,
        id: u64,
        packed: Vec<u32>,
        ts_ns: f64,
    },
    /// Periodic packet-clock forwarding (every [`CLOCK_TICK_PKTS`]
    /// packets per worker) so batch timeouts advance through stretches
    /// of non-triggering traffic — the pipelined stand-in for the
    /// serial loop's poll-per-packet.  Ticks from different workers may
    /// arrive out of order; a stale tick is harmless (the poll
    /// condition is simply false), and ticks never change verdicts —
    /// only when a partial batch flushes.
    Clock(f64),
    /// Learner publish barrier, relayed by one parse worker.  Once one
    /// arrives from *every* worker, all flows triggered before the
    /// staged registry write are in the lanes: the stage drains them
    /// under the old weights and acks back to ingress.
    Barrier,
}

/// How often each parse worker forwards its packet clock to stage 3:
/// bounds batch-timeout staleness to this many packets per worker at
/// ~0.4% extra message traffic.
const CLOCK_TICK_PKTS: u64 = 256;

/// Stage 3 → stage 4 message: one accounted verdict.
struct VerdictMsg {
    route: usize,
    id: u64,
    class: usize,
    latency_ns: f64,
    tag: Option<VersionTag>,
}

/// What each stage thread returns at exit.
struct StageReport {
    stats: ServiceStats,
    failure: Option<StageFailure>,
    flows: usize,
    /// Populated by the inference stage only.
    engine: Option<EngineStats>,
    /// Populated by the inference stage only, on placement planes.
    health: Option<Vec<PlaneHealth>>,
}

/// Lossless counted send on a bounded channel: a full queue counts one
/// backpressure event then blocks; a disconnected peer is the caller's
/// cue to shut down.
fn send_counted<T>(tx: &SyncSender<T>, item: T, blocked: &mut u64) -> Result<(), ()> {
    match tx.try_send(item) {
        Ok(()) => Ok(()),
        Err(TrySendError::Full(it)) => {
            *blocked += 1;
            tx.send(it).map_err(|_| ())
        }
        Err(TrySendError::Disconnected(_)) => Err(()),
    }
}

fn blank_stats() -> ServiceStats {
    ServiceStats {
        stage_blocked: vec![0; STAGE_LINKS.len()],
        ..Default::default()
    }
}

/// Stage 1+2: flow update, routing/trigger, feature packing — each worker
/// owns its subset of the [`FLOW_SHARDS`] logical shard tables outright
/// (shard `l` lives at local index `l / n_workers` of the worker
/// `l % n_workers`).  With `admission`, each worker runs its share of the
/// leaky bucket and sheds triggers locally (shed decisions ride the
/// packet clock, so they stay deterministic per shard); with
/// `supervisor`, an injected or real panic in the per-packet compute is
/// retried instead of killing the shard.
#[allow(clippy::too_many_arguments)]
fn parse_stage(
    rx: Receiver<ParseMsg>,
    tx: SyncSender<InfMsg>,
    route: RouteLogic,
    mut flows: Vec<FlowTable>,
    worker: usize,
    n_workers: usize,
    mut admission: Option<WorkerAdmission>,
    supervisor: Option<SupervisorPolicy>,
    faults: Option<FaultPlan>,
) -> StageReport {
    let mut stats = blank_stats();
    let mut failure = None;
    let mut restarts_used = 0u32;
    let mut restarts = 0u64;
    while let Ok(msg) = rx.recv() {
        let ev = match msg {
            ParseMsg::Event(ev) => ev,
            ParseMsg::Barrier => {
                // Relay in FIFO position — not a packet, just a fence.
                if send_counted(&tx, InfMsg::Barrier, &mut stats.stage_blocked[1]).is_err() {
                    failure = Some(StageFailure::ParseDisconnected { worker });
                    break;
                }
                continue;
            }
        };
        stats.packets += 1;
        if let Some(a) = admission.as_mut() {
            a.on_packet(ev.packet.ts_ns);
        }
        // The canonical key is derived once per worker and passed down
        // (`update_keyed`); ingress hashed its own copy for routing — an
        // accepted duplication so the channel messages stay plain
        // `PacketEvent`s instead of carrying (key, hash) everywhere.
        // The fault hook ticks *before* the flow update, so a retried
        // event replays the update exactly once.
        let step = guard(supervisor.as_ref(), "parse worker", &mut restarts_used, &mut restarts, || {
            if let Some(fp) = faults.as_ref() {
                fp.tick_parse();
            }
            let (key, fwd) = FlowKey::from_packet(&ev.packet);
            let shard = ShardedFlowTable::shard_of_key(&key, FLOW_SHARDS);
            // `None` = untracked (EvictPolicy::Off on a full table):
            // forwarded without per-flow state, can't trigger — the
            // counted degradation that replaced the old panic.
            let Some(up) = flows[shard / n_workers].update_keyed(key, fwd, &ev.packet) else {
                return Ok(None);
            };
            // Shared with the serial loop — the determinism contract
            // says the two paths may never diverge.
            Ok(route.route(&ev.packet, up.is_new, up.pkts).map(|r| InfMsg::Flow {
                route: r,
                id: flow_id(&ev.packet),
                packed: select_packed_input(&ev, up.stats),
                ts_ns: ev.packet.ts_ns,
            }))
        });
        let msg = match step {
            Ok(m) => m,
            Err(f) => {
                failure = Some(f);
                break;
            }
        };
        if let Some(msg) = msg {
            stats.triggers += 1;
            let admitted = match admission.as_mut() {
                Some(a) => {
                    let ok = a.admit(ev.packet.ts_ns);
                    if !ok {
                        stats.sheds += 1;
                    }
                    ok
                }
                None => true,
            };
            if admitted {
                let before = stats.stage_blocked[1];
                if send_counted(&tx, msg, &mut stats.stage_blocked[1]).is_err() {
                    failure = Some(StageFailure::ParseDisconnected { worker });
                    break;
                }
                // A blocked send means downstream is already saturated:
                // charge the bucket so admission reacts before the next
                // stall instead of discovering it one packet at a time.
                if stats.stage_blocked[1] > before {
                    if let Some(a) = admission.as_mut() {
                        a.on_blocked();
                    }
                }
            }
        }
        // Forward the packet clock periodically so stage 3's batch
        // timeouts advance even when nothing triggers (the serial loop
        // polls its lanes on *every* packet).
        if stats.packets % CLOCK_TICK_PKTS == 0 {
            let tick = InfMsg::Clock(ev.packet.ts_ns);
            if send_counted(&tx, tick, &mut stats.stage_blocked[1]).is_err() {
                failure = Some(StageFailure::ParseDisconnected { worker });
                break;
            }
        }
    }
    stats.restarts += restarts;
    let flows_len = flows.iter().map(FlowTable::len).sum();
    for t in &flows {
        stats.flow_table.merge(&t.stats_snapshot());
    }
    StageReport { stats, failure, flows: flows_len, engine: None, health: None }
}

/// Stage 3: the single inference engine — per-route batch lanes feeding
/// one [`InferencePlane`].  Being the sole producer into stage 4, its
/// emission order *is* the sink order.  Each lane's batch scores under
/// one weight snapshot (epoch-pinning backends tag every verdict).
struct InferenceStage {
    plane: Box<dyn InferencePlane>,
    tx: SyncSender<VerdictMsg>,
    batchers: Option<BatchSet<PendingFlow>>,
    stats: ServiceStats,
    /// Scratch reused across batch flushes.
    inputs: Vec<Vec<u32>>,
    meta: Vec<(u64, f64)>,
    classes: Vec<usize>,
    supervisor: Option<SupervisorPolicy>,
    faults: Option<FaultPlan>,
    restarts_used: u32,
    /// Parse workers feeding this stage — the barrier quorum.
    n_producers: usize,
    /// Barriers seen in the current quorum round.
    barriers_seen: usize,
    /// Ack channel back to the (blocked) ingress thread.
    ack_tx: Sender<()>,
}

impl InferenceStage {
    fn new(
        plane: Box<dyn InferencePlane>,
        tx: SyncSender<VerdictMsg>,
        batchers: Option<BatchSet<PendingFlow>>,
        supervisor: Option<SupervisorPolicy>,
        faults: Option<FaultPlan>,
        n_producers: usize,
        ack_tx: Sender<()>,
    ) -> Self {
        Self {
            plane,
            tx,
            batchers,
            stats: blank_stats(),
            inputs: Vec::new(),
            meta: Vec::new(),
            classes: Vec::new(),
            supervisor,
            faults,
            restarts_used: 0,
            n_producers,
            barriers_seen: 0,
            ack_tx,
        }
    }

    /// Classify one lane's batch and emit its verdicts.  Latency
    /// semantics match the serial core's flush: packet-clock queueing
    /// wait plus the whole batch's modeled completion time.
    fn flush(
        &mut self,
        lane: usize,
        batch: Vec<(f64, PendingFlow)>,
        now_ns: f64,
    ) -> Result<(), StageFailure> {
        self.meta.clear();
        self.inputs.clear();
        for (enq_ns, flow) in batch {
            self.meta.push((flow.id, enq_ns));
            self.inputs.push(flow.packed);
        }
        // Supervised region: the batch call clears and refills `classes`,
        // so a retry after a panic or a retryable backend fault recomputes
        // the identical batch (the fault hook ticks first and is
        // one-shot).
        let Self { plane, inputs, classes, faults, supervisor, restarts_used, stats, .. } = self;
        let tag = guard(
            supervisor.as_ref(),
            "inference stage",
            restarts_used,
            &mut stats.restarts,
            || {
                if let Some(fp) = faults.as_ref() {
                    fp.tick_inference();
                }
                plane.try_run_batch(lane, inputs, classes).map_err(StageFailure::Inference)
            },
        )?;
        let exec_ns = self.plane.batch_latency_ns(self.classes.len());
        for i in 0..self.classes.len() {
            let (id, enq_ns) = self.meta[i];
            let v = VerdictMsg {
                route: lane,
                id,
                class: self.classes[i],
                latency_ns: batch_item_latency_ns(now_ns, enq_ns, exec_ns),
                tag: tag.clone(),
            };
            send_counted(&self.tx, v, &mut self.stats.stage_blocked[2])
                .map_err(|()| StageFailure::SinkDisconnected)?;
        }
        Ok(())
    }

    /// Advance the packet clock: flush any lane whose partial batch
    /// timed out.
    fn on_clock(&mut self, now_ns: f64) -> Result<(), StageFailure> {
        let due = match self.batchers.as_mut() {
            Some(b) => b.poll(now_ns),
            None => Vec::new(),
        };
        for (lane, batch) in due {
            self.flush(lane, batch, now_ns)?;
        }
        Ok(())
    }

    /// Handle one triggered flow: timed flush, then enqueue-or-classify.
    fn on_flow(
        &mut self,
        route: usize,
        id: u64,
        packed: Vec<u32>,
        ts_ns: f64,
    ) -> Result<(), StageFailure> {
        self.on_clock(ts_ns)?;
        if self.batchers.is_none() {
            let Self { plane, faults, supervisor, restarts_used, stats, .. } = self;
            let (class, tag) = guard(
                supervisor.as_ref(),
                "inference stage",
                restarts_used,
                &mut stats.restarts,
                || {
                    if let Some(fp) = faults.as_ref() {
                        fp.tick_inference();
                    }
                    Ok(plane.classify(route, &packed))
                },
            )?;
            let v = VerdictMsg {
                route,
                id,
                class,
                latency_ns: self.plane.latency_ns(),
                tag,
            };
            return send_counted(&self.tx, v, &mut self.stats.stage_blocked[2])
                .map_err(|()| StageFailure::SinkDisconnected);
        }
        let full = self
            .batchers
            .as_mut()
            .unwrap()
            .push(route, ts_ns, PendingFlow { id, packed });
        match full {
            Some(batch) => self.flush(route, batch, ts_ns),
            None => Ok(()),
        }
    }

    /// One parse worker's barrier arrived.  Sync_channels are FIFO per
    /// producer, so once every worker's barrier is in, every flow
    /// triggered before the staged registry write is in the lanes:
    /// drain them under the still-current weights, then ack so ingress
    /// can commit.  (A gone ack peer means ingress already abandoned
    /// the run — the stage keeps winding down normally.)
    fn on_barrier(&mut self) -> Result<(), StageFailure> {
        self.barriers_seen += 1;
        if self.barriers_seen < self.n_producers {
            return Ok(());
        }
        self.barriers_seen = 0;
        self.drain()?;
        let _ = self.ack_tx.send(());
        Ok(())
    }

    /// Full drain of every lane (newest enqueue time as "now" — the
    /// serial loop's shutdown semantics): at end-of-stream and at each
    /// learner publish barrier.
    fn drain(&mut self) -> Result<(), StageFailure> {
        let due = match self.batchers.as_mut() {
            Some(b) => b.poll(f64::INFINITY),
            None => Vec::new(),
        };
        for (lane, batch) in due {
            let now_ns = batch.last().map_or(0.0, |&(t, _)| t);
            self.flush(lane, batch, now_ns)?;
        }
        Ok(())
    }

    /// Event loop until every parse worker hangs up, then drain.
    fn run(mut self, rx: Receiver<InfMsg>) -> StageReport {
        let mut failure = None;
        while let Ok(msg) = rx.recv() {
            let step = match msg {
                InfMsg::Flow { route, id, packed, ts_ns } => self.on_flow(route, id, packed, ts_ns),
                InfMsg::Clock(ts_ns) => self.on_clock(ts_ns),
                InfMsg::Barrier => self.on_barrier(),
            };
            if let Err(f) = step {
                failure = Some(f);
                break;
            }
        }
        if failure.is_none() {
            if let Err(f) = self.drain() {
                failure = Some(f);
            }
        }
        let engine = self.plane.engine_stats();
        let health = self.plane.health_snapshot();
        StageReport { stats: self.stats, failure, flows: 0, engine, health }
    }
}

/// Stage 4: the single ordered selector/metrics sink, with per-model
/// accounting on routed (named) backends and the tagged verdict log.
fn sink_stage(
    rx: Receiver<VerdictMsg>,
    output: OutputSelector,
    n_classes: usize,
    log_tags: bool,
    names: Vec<String>,
    supervisor: Option<SupervisorPolicy>,
    faults: Option<FaultPlan>,
) -> (ServiceStats, OutputSink, Vec<TaggedVerdict>, Option<StageFailure>) {
    let mut stats = blank_stats();
    stats.classes = vec![0; n_classes];
    // Route-indexed during the run (no per-verdict key allocation);
    // folded into the name-keyed map once at exit.
    let mut per_route = vec![ModelServiceStats::default(); names.len()];
    let mut sink = OutputSink::default();
    let mut tagged = Vec::new();
    let mut failure = None;
    let mut restarts_used = 0u32;
    let mut restarts = 0u64;
    while let Ok(v) = rx.recv() {
        // Supervised region per verdict; the fault hook ticks before any
        // accounting, so a retried verdict is accounted exactly once.
        let step = guard(supervisor.as_ref(), "sink stage", &mut restarts_used, &mut restarts, || {
            if let Some(fp) = faults.as_ref() {
                fp.tick_sink();
            }
            stats.inferences += 1;
            if v.class >= stats.classes.len() {
                stats.classes.resize(v.class + 1, 0);
            }
            stats.classes[v.class] += 1;
            if !names.is_empty() {
                per_route[v.route].record(v.class);
            }
            stats.latency.record(v.latency_ns);
            sink.write(output, v.id, v.class);
            if log_tags {
                if let Some(tag) = v.tag.clone() {
                    tagged.push(TaggedVerdict { id: v.id, class: v.class, tag });
                }
            }
            Ok(())
        });
        if let Err(f) = step {
            failure = Some(f);
            break;
        }
    }
    stats.restarts += restarts;
    // Accumulate (don't insert) so duplicate route names — legal in a
    // hash-split router — merge their counts the same way the serial
    // core's fold does.
    for (name, m) in names.into_iter().zip(per_route) {
        stats.per_model.entry(name).or_default().absorb(&m);
    }
    (stats, sink, tagged, failure)
}

/// Drive `events` through the staged runtime (the calling thread is the
/// ingress sharder and, with `.swap_every(n)`, the live control plane)
/// and join every stage.  Returns the merged report, or — if any stage
/// died — a [`ServiceError::Stage`] with everything accumulated before
/// the fault.
pub(crate) fn run_staged(
    svc: Service,
    events: impl IntoIterator<Item = PacketEvent>,
) -> Result<ServiceReport, ServiceError> {
    let workers = svc.workers.max(1);
    let depth = svc.queue_depth; // validated ≥ 1 by ServeBuilder::build
    let n_classes = svc.plane.n_classes();
    let names: Vec<String> = svc.plane.route_names().to_vec();
    let n_routes = svc.route.n_routes();
    // Extracted before the plane moves into stage 3, so swap ticks and
    // the final swap-count snapshot run from this (ingress) thread while
    // inference proceeds — a true concurrent hot swap.
    let mut swap = svc.plane.swap_controller();
    // The online learner (if armed) lives on the ingress thread — the
    // only place that sees every packet exactly once, before fan-out —
    // and its registry writes go through the publish barrier below.
    let mut learner = svc.build_learner()?;

    // Overload control: each parse worker runs its share of the leaky
    // bucket (the drain rate — backend parallelism — splits evenly) and
    // publishes its backlog through an atomic cell; the ingress thread
    // runs the degradation ladder over the summed pressure and publishes
    // the service level back the same way.
    let overload_on = svc.shed.is_some() || svc.degrade.is_some();
    let caps = svc.plane.capabilities();
    let cost_ns = if svc.batch > 0 {
        svc.plane.batch_latency_ns(svc.batch) / svc.batch as f64
    } else {
        svc.plane.latency_ns()
    };
    let (mut ladder, mut actions) = if overload_on {
        ladder_for(svc.degrade.as_ref(), svc.shed, swap.as_ref())
    } else {
        (None, None)
    };
    let shed_policy = svc.shed.unwrap_or_else(ShedPolicy::never);
    let drain_per_worker = caps.shards.max(1) as f64 / workers as f64;
    let level = Arc::new(AtomicU8::new(ServiceLevel::Full.as_u8()));
    let mut backlog_cells: Vec<Arc<AtomicU64>> = Vec::new();

    let (tx_inf, rx_inf) = mpsc::sync_channel::<InfMsg>(depth);
    let (tx_sink, rx_sink) = mpsc::sync_channel::<VerdictMsg>(depth);
    // Barrier acks flow against the data direction (stage 3 → stage 0);
    // unbounded, since at most one barrier is ever in flight.
    let (ack_tx, ack_rx) = mpsc::channel::<()>();

    // Flow state: the same FLOW_SHARDS logical shard tables the serial
    // mode uses, dealt round-robin to workers (worker w owns shards l
    // with l % workers == w, at local index l / workers).  Fixing the
    // shard partition — instead of sharding by worker count — is what
    // keeps eviction, and therefore every verdict, independent of how
    // many workers run.
    let mut worker_tables: Vec<Vec<FlowTable>> = (0..workers).map(|_| Vec::new()).collect();
    for (l, table) in
        ShardedFlowTable::with_total_capacity(FLOW_SHARDS, svc.flow_capacity, svc.evict)
            .into_shards()
            .into_iter()
            .enumerate()
    {
        worker_tables[l % workers].push(table);
    }

    let mut parse_txs = Vec::with_capacity(workers);
    let mut parse_handles = Vec::with_capacity(workers);
    for (w, tables) in worker_tables.into_iter().enumerate() {
        let (tx, rx) = mpsc::sync_channel::<ParseMsg>(depth);
        let tx_inf = tx_inf.clone();
        let route = svc.route.clone();
        let admission = if overload_on {
            let cell = Arc::new(AtomicU64::new(0));
            backlog_cells.push(Arc::clone(&cell));
            Some(WorkerAdmission::new(
                AdmissionController::new(shed_policy, drain_per_worker),
                cost_ns,
                cell,
                Arc::clone(&level),
            ))
        } else {
            None
        };
        let supervisor = svc.supervisor;
        let faults = svc.faults.clone();
        parse_handles.push(thread::spawn(move || {
            parse_stage(rx, tx_inf, route, tables, w, workers, admission, supervisor, faults)
        }));
        parse_txs.push(tx);
    }
    drop(tx_inf); // stage 3's recv loop ends when all workers finish

    let plane = svc.plane;
    let batchers = if svc.batch > 0 {
        Some(BatchSet::new(n_routes, svc.batch, svc.max_wait_ns))
    } else {
        None
    };
    let inf_supervisor = svc.supervisor;
    let inf_faults = svc.faults.clone();
    let inf_handle = thread::spawn(move || {
        InferenceStage::new(plane, tx_sink, batchers, inf_supervisor, inf_faults, workers, ack_tx)
            .run(rx_inf)
    });
    let output = svc.output;
    let log_tags = svc.log_tags;
    let sink_names = names.clone();
    let sink_supervisor = svc.supervisor;
    let sink_faults = svc.faults.clone();
    let sink_handle = thread::spawn(move || {
        sink_stage(rx_sink, output, n_classes, log_tags, sink_names, sink_supervisor, sink_faults)
    });

    // Stage 0: shard by flow hash and feed.  A dead worker (its rx
    // dropped) surfaces here as a failed send, not a hang.
    let admin = svc.admin.clone();
    let mut ingress_blocked = 0u64;
    let mut failures: Vec<StageFailure> = Vec::new();
    let mut n = 0u64;
    // A failed republish is reported once and further ticks are
    // disabled (matching the serial mode) instead of pushing one
    // failure per interval for the rest of the run.
    let mut swap_ok = true;
    for ev in events {
        if svc.swap_every > 0 && swap_ok && n > 0 && n % svc.swap_every == 0 {
            if let Some(s) = swap.as_mut() {
                if let Err(e) = s.tick() {
                    failures.push(StageFailure::Swap(e));
                    swap_ok = false;
                }
            }
        }
        n += 1;
        // The ladder runs here — the only thread that sees every packet —
        // over the *summed* worker backlogs, so a degradation decision is
        // global even though shedding is per-shard.  The level is
        // published through the shared cell the workers read.
        if let Some(l) = ladder.as_mut() {
            let pressure: f64 = backlog_cells
                .iter()
                .map(|c| f64::from_bits(c.load(Ordering::Relaxed)))
                .sum();
            let fired = l.observe(n, ev.packet.ts_ns, pressure).map(|e| (e.from, e.to));
            if let Some((from, to)) = fired {
                level.store(to.as_u8(), Ordering::Relaxed);
                let mut kill_actions = false;
                if let Some(a) = actions.as_mut() {
                    if let Err(e) = a.apply(from, to) {
                        failures.push(StageFailure::Swap(e));
                        kill_actions = true;
                    }
                }
                if kill_actions {
                    actions = None;
                    l.disable_fallback();
                }
            }
        }
        // Admin liveness rides ingress: packet count is exact here.
        // Stage stats merge at join only, so mid-run the snapshot stays
        // whatever the last finished run published — except the learn
        // telemetry, which lives right here on the ingress thread and
        // *can* be kept live for `/stats` scrapes.
        if let Some(a) = admin.as_ref() {
            a.on_packet();
            if n % SNAPSHOT_EVERY == 0 {
                if let Some(l) = learner.as_mut() {
                    for name in a.take_retrains() {
                        if name == l.model_name() {
                            l.request_retrain();
                        }
                    }
                    let mut s = blank_stats();
                    s.packets = n;
                    l.publish_into(&mut s);
                    a.publish_stats(&s);
                }
            }
        }
        // The learner observes every packet here at ingress, before
        // fan-out, mirroring the serial loop's "serving side first"
        // order: the event is enqueued to its worker *before* any
        // barrier, so per-producer FIFO guarantees the committing
        // packet itself scores under the old weights.
        let commit = match learner.as_mut() {
            Some(l) => l.on_packet(&ev),
            None => false,
        };
        // Logical shard first, then its owning worker — the shard→worker
        // map must match the table deal-out above.
        let w = ShardedFlowTable::shard_of(&ev.packet, FLOW_SHARDS) % workers;
        if send_counted(&parse_txs[w], ParseMsg::Event(ev), &mut ingress_blocked).is_err() {
            failures.push(StageFailure::IngressUnreachable { worker: w });
            break;
        }
        if commit {
            // Publish barrier (two-phase commit; see the learn module
            // docs): fence every worker, wait for the inference stage
            // to drain all lanes under the old weights, then write the
            // registry.  The timeout only guards the *failure* path — a
            // healthy drain is pure arithmetic and acks immediately.
            let mut lost = false;
            for (bw, tx) in parse_txs.iter().enumerate() {
                if send_counted(tx, ParseMsg::Barrier, &mut ingress_blocked).is_err() {
                    failures.push(StageFailure::IngressUnreachable { worker: bw });
                    lost = true;
                    break;
                }
            }
            if !lost && ack_rx.recv_timeout(Duration::from_secs(10)).is_err() {
                lost = true;
            }
            if lost {
                failures.push(StageFailure::BarrierLost);
                if let Some(l) = learner.as_mut() {
                    l.poison();
                }
                break;
            }
            if let Some(l) = learner.as_mut() {
                if let Err(e) = l.commit_pending() {
                    failures.push(StageFailure::Swap(e));
                    l.poison();
                }
            }
        }
    }
    drop(parse_txs);

    // Join in dataflow order, merging stats and collecting faults.
    let mut stats = blank_stats();
    stats.classes = vec![0; n_classes];
    stats.stage_blocked[0] = ingress_blocked;
    let mut flows_tracked = 0usize;
    for h in parse_handles {
        match h.join() {
            Ok(rep) => {
                stats.merge(&rep.stats);
                flows_tracked += rep.flows;
                if let Some(f) = rep.failure {
                    failures.push(f);
                }
            }
            Err(p) => failures.push(StageFailure::Panicked {
                stage: "parse worker",
                message: panic_text(&p),
            }),
        }
    }
    let mut engine = None;
    let mut health = None;
    match inf_handle.join() {
        Ok(rep) => {
            stats.merge(&rep.stats);
            engine = rep.engine;
            health = rep.health;
            if let Some(f) = rep.failure {
                failures.push(f);
            }
        }
        Err(p) => failures.push(StageFailure::Panicked {
            stage: "inference stage",
            message: panic_text(&p),
        }),
    }
    let (sink, tagged) = match sink_handle.join() {
        Ok((sink_stats, sink, tagged, sink_failure)) => {
            stats.merge(&sink_stats);
            if let Some(f) = sink_failure {
                failures.push(f);
            }
            (sink, tagged)
        }
        Err(p) => {
            failures.push(StageFailure::Panicked {
                stage: "sink stage",
                message: panic_text(&p),
            });
            (OutputSink::default(), Vec::new())
        }
    };
    // Swap counts are a registry property, not a stage property:
    // snapshot once, after every stage has reported.
    if let Some(s) = swap.as_ref() {
        for name in &names {
            let entry = stats.per_model.entry(name.clone()).or_default();
            entry.swaps = s.registry().swap_count(name);
        }
    }
    // The learner lives on this thread, so its telemetry needs no merge
    // — stamp it onto the joined stats directly.
    if let Some(l) = learner.as_mut() {
        l.publish_into(&mut stats);
    }

    let degradation = ladder.map_or_else(Vec::new, DegradationLadder::into_timeline);
    let report = ServiceReport { stats, sink, tagged, flows_tracked, engine, degradation, health };
    if let Some(a) = admin.as_ref() {
        a.finish(&report.stats, !failures.is_empty());
    }
    if failures.is_empty() {
        Ok(report)
    } else {
        Err(ServiceError::Stage { failures, report: Box::new(report) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::{BnnModel, RegistryHandle};
    use crate::coordinator::{BackendFactory, ModelRouter, ServeBuilder, TriggerCondition};
    use crate::net::traffic::CbrSpec;

    fn events(n: usize, flows: u64, seed: u64) -> Vec<PacketEvent> {
        PacketEvent::cbr_burst(CbrSpec { gbps: 10.0, pkt_size: 256 }, flows, seed, n)
    }

    fn model() -> BnnModel {
        BnnModel::random("traffic", 256, &[32, 16, 2], 1)
    }

    fn pipeline(workers: usize, batch: usize) -> ServeBuilder {
        let mut b = ServeBuilder::new()
            .backend(BackendFactory::single("fpga", model()).unwrap())
            .trigger(TriggerCondition::EveryNPackets(10))
            .pipeline(workers);
        if batch > 0 {
            b = b.batching(batch, 1e6);
        }
        b
    }

    #[test]
    fn healthy_run_accounts_every_trigger() {
        let rep = pipeline(3, 0).build().unwrap().run(events(5000, 50, 3)).unwrap();
        assert_eq!(rep.stats.packets, 5000);
        assert!(rep.stats.triggers > 0);
        assert_eq!(rep.stats.triggers, rep.stats.inferences);
        assert_eq!(rep.sink.memory.len() as u64, rep.stats.inferences);
        assert_eq!(rep.stats.classes.iter().sum::<u64>(), rep.stats.inferences);
        assert_eq!(rep.stats.stage_blocked.len(), STAGE_LINKS.len());
        assert!(rep.flows_tracked > 0 && rep.flows_tracked <= 50);
    }

    #[test]
    fn batched_pipeline_drains_at_shutdown() {
        let rep = pipeline(2, 0)
            .batching(7, 1e12)
            .build()
            .unwrap()
            .run(events(4000, 40, 6))
            .unwrap();
        assert_eq!(rep.stats.triggers, rep.stats.inferences);
    }

    #[test]
    fn routed_pipeline_matches_routed_serial_per_model() {
        let h = RegistryHandle::new();
        h.publish("anomaly", &BnnModel::random("anomaly", 256, &[32, 16, 2], 31))
            .unwrap();
        h.publish("traffic-class", &BnnModel::random("traffic-class", 256, &[32, 16, 2], 32))
            .unwrap();
        let router = ModelRouter::hash_split(
            TriggerCondition::EveryNPackets(10),
            vec!["anomaly".into(), "traffic-class".into()],
        );
        let names = router.model_names().to_vec();
        let evs = events(6000, 50, 11);

        let serial = ServeBuilder::new()
            .backend(BackendFactory::registry(&h, &names, 100.0, 1).unwrap())
            .router(router.clone())
            .build()
            .unwrap()
            .run(evs.iter().cloned())
            .unwrap();

        for (workers, batch, shards) in [(1, 0, 1), (3, 0, 1), (2, 8, 1), (2, 8, 3)] {
            let mut b = ServeBuilder::new()
                .backend(BackendFactory::registry(&h, &names, 100.0, shards).unwrap())
                .router(router.clone())
                .pipeline(workers);
            if batch > 0 {
                b = b.batching(batch, 1e6);
            }
            let rep = b.build().unwrap().run(evs.iter().cloned()).unwrap();
            assert_eq!(rep.stats.packets, 6000, "w{workers} b{batch} s{shards}");
            assert_eq!(rep.stats.triggers, serial.stats.triggers);
            assert_eq!(rep.stats.inferences, serial.stats.inferences);
            assert_eq!(rep.stats.classes, serial.stats.classes);
            assert_eq!(rep.stats.per_model, serial.stats.per_model);
            assert_eq!(rep.tagged.len() as u64, rep.stats.inferences);
            // Same verdicts for the same flows, order aside.
            let mut want_mem = serial.sink.memory.clone();
            let mut got_mem = rep.sink.memory.clone();
            want_mem.sort_unstable();
            got_mem.sort_unstable();
            assert_eq!(want_mem, got_mem);
            // No publishes happened: everything ran at version 1.
            assert!(rep.tagged.iter().all(|t| t.tag.version() == 1));
            if shards > 1 && batch > 0 {
                assert!(rep.engine.is_some());
            }
        }
    }

    #[test]
    fn tiny_queues_only_add_backpressure_never_loss() {
        let evs = events(3000, 30, 9);
        let want = pipeline(2, 0).build().unwrap().run(evs.iter().cloned()).unwrap();
        let got = pipeline(2, 0)
            .queue_depth(1)
            .build()
            .unwrap()
            .run(evs.iter().cloned())
            .unwrap();
        assert_eq!(got.stats.triggers, want.stats.triggers);
        assert_eq!(got.stats.inferences, want.stats.inferences);
        assert_eq!(got.stats.classes, want.stats.classes);
    }
}

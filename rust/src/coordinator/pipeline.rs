//! Pipelined multi-stage serving runtime — the serial `CoordinatorService`
//! loop decomposed into the staged co-processor shape the NIC actually
//! has (parse/flow-update engines feeding an inference engine feeding a
//! verdict sink), so the parse work for packet *n+1* overlaps the
//! inference for packet *n* instead of serializing behind it:
//!
//! ```text
//!  ingress ─┬─▶ parse/flow/trigger worker 0 ─┐
//!  (shard   ├─▶ parse/flow/trigger worker 1 ─┼─▶ batcher ─▶ ordered
//!  by flow  ┆            …                   ┆    + NN      sink +
//!  hash)    └─▶ parse/flow/trigger worker N ─┘   executor   metrics
//!     stage 0          stage 1+2                 stage 3    stage 4
//! ```
//!
//! Stages are connected by **bounded** `sync_channel`s: a full queue
//! blocks the producer (lossless backpressure — no verdict is ever
//! dropped) and each blocked send is counted in
//! [`ServiceStats::stage_blocked`], indexed by [`STAGE_LINKS`].
//!
//! ## Determinism contract (the tier-1 equivalence property)
//!
//! Given the same seeded traffic, the pipelined runtime produces
//! **bit-identical** verdict histograms, trigger counts, inference
//! counts, and per-flow verdicts to the serial loop, for any worker
//! count, queue depth, or batch size.  This holds by construction:
//!
//! * packets are sharded by canonical flow hash
//!   ([`ShardedFlowTable::shard_of`]), so every packet of a flow — both
//!   directions — visits one stage-1 worker, in arrival order
//!   (`sync_channel` is FIFO);
//! * [`TriggerCondition`] and the flow statistics a trigger snapshots
//!   are functions of that flow's packets only, so cross-flow
//!   interleaving cannot change what fires or what gets packed;
//! * every executor classifies each packed input bit-exactly regardless
//!   of the batch it rides in, so batch composition (which *does* vary
//!   with timing) is invisible in the verdicts.
//!
//! Latency *histograms* are exempt from the contract — queueing delay is
//! real time, not packet time.  The contract is asserted end-to-end in
//! `tests/pipeline_equiv.rs`.
//!
//! ## Failure semantics
//!
//! A stage that dies (executor panic, poisoned channel) must not hang
//! the service: its channel endpoints drop, upstream sends and
//! downstream receives error out, every surviving stage exits its loop
//! and reports, and [`run`](PipelineService::run) returns a
//! [`PipelineError`] carrying both the failure descriptions and the
//! stats accumulated up to the fault (`tests/failure_injection.rs`).

use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::thread;

use crate::bnn::{EngineStats, MultiModelExecutor, RegistryError, RegistryHandle, VersionTag};
use crate::net::flow::{FlowTable, ShardedFlowTable};

use super::batcher::{BatchSet, Batcher, TimedBatch};
use super::selector::{OutputSelector, OutputSink};
use super::service::{
    batch_item_latency_ns, flow_id, select_packed_input, ModelServiceStats, PacketEvent,
    PendingFlow, ServiceStats, TaggedVerdict,
};
use super::trigger::{ModelRouter, TriggerCondition};
use super::NnBatchExecutor;

/// Inter-stage links, in `ServiceStats::stage_blocked` index order.
pub const STAGE_LINKS: [&str; 3] = ["ingress→parse", "parse→inference", "inference→sink"];

/// Tuning knobs of the pipelined runtime.
#[derive(Debug, Clone, Copy)]
pub struct PipelineConfig {
    /// Stage-1 parse/flow-table workers (flow-hash shards), ≥ 1.
    pub workers: usize,
    /// Capacity of each bounded inter-stage channel, ≥ 1.
    pub queue_depth: usize,
    /// 0 = classify inline in stage 3; N ≥ 1 = accumulate batches of N
    /// and take the executor's batch fast path.
    pub batch: usize,
    /// Packet-clock cap on batch queueing (same knob as the serial
    /// loop's `with_batching`).
    pub max_wait_ns: f64,
    /// Flow-table capacity *per worker* (each owns one shard).
    pub flow_capacity: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_depth: 1024,
            batch: 0,
            max_wait_ns: 1e6,
            flow_capacity: 1 << 16,
        }
    }
}

/// What a completed (or faulted) pipeline run leaves behind.
#[derive(Debug)]
pub struct PipelineReport {
    pub stats: ServiceStats,
    /// The single stage-4 sink — verdicts in inference-completion order.
    pub sink: OutputSink,
    /// Live flows summed over every worker's shard.
    pub flows_tracked: usize,
    /// Stage 3's sharded-engine counters, if its executor ran one.
    pub engine: Option<EngineStats>,
}

/// One or more stages died; partial statistics survive in `report`.
#[derive(Debug)]
pub struct PipelineError {
    pub failures: Vec<String>,
    pub report: PipelineReport,
}

impl std::fmt::Display for PipelineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "pipeline stage failure: {}", self.failures.join("; "))
    }
}

impl std::error::Error for PipelineError {}

/// Stage 1+2 → stage 3 messages.
enum InferenceMsg {
    /// A triggered flow: routing id, packed NN input, and the trigger
    /// packet's clock (drives batch timeouts).
    Flow { id: u64, packed: Vec<u32>, ts_ns: f64 },
    /// Periodic packet-clock forwarding (every [`CLOCK_TICK_PKTS`]
    /// packets per worker) so batch timeouts advance through stretches
    /// of non-triggering traffic — the pipelined stand-in for the
    /// serial loop's poll-per-packet.  Ticks from different workers may
    /// arrive out of order; a stale tick is harmless (the poll
    /// condition is simply false), and ticks never change verdicts —
    /// only when a partial batch flushes.
    Clock(f64),
}

/// How often each parse worker forwards its packet clock to stage 3:
/// bounds batch-timeout staleness to this many packets per worker at
/// ~0.4% extra message traffic.
const CLOCK_TICK_PKTS: u64 = 256;

/// Stage 3 → stage 4 message: one accounted verdict.
struct Verdict {
    id: u64,
    class: usize,
    latency_ns: f64,
}

/// What each stage thread returns at exit.
struct StageReport {
    stats: ServiceStats,
    failure: Option<String>,
    flows: usize,
    /// Populated by the inference stage only.
    engine: Option<EngineStats>,
}

/// Lossless counted send on a bounded channel: a full queue counts one
/// backpressure event then blocks; a disconnected peer is the caller's
/// cue to shut down.
fn send_counted<T>(tx: &SyncSender<T>, item: T, blocked: &mut u64) -> Result<(), ()> {
    match tx.try_send(item) {
        Ok(()) => Ok(()),
        Err(TrySendError::Full(it)) => {
            *blocked += 1;
            tx.send(it).map_err(|_| ())
        }
        Err(TrySendError::Disconnected(_)) => Err(()),
    }
}

fn blank_stats() -> ServiceStats {
    ServiceStats {
        stage_blocked: vec![0; STAGE_LINKS.len()],
        ..Default::default()
    }
}

/// Stage 1+2: flow update, trigger, feature packing — one worker per
/// flow shard, so this owns its `FlowTable` outright.
fn parse_stage(
    rx: Receiver<PacketEvent>,
    tx: SyncSender<InferenceMsg>,
    trigger: TriggerCondition,
    mut flows: FlowTable,
) -> StageReport {
    let mut stats = blank_stats();
    let mut failure = None;
    while let Ok(ev) = rx.recv() {
        stats.packets += 1;
        // The canonical key is hashed once more inside `update` after
        // ingress already hashed it for sharding — 4 multiplies per
        // packet, accepted so the channel messages stay plain
        // `PacketEvent`s instead of carrying (key, hash) everywhere.
        let (fstats, is_new, pkts) = flows.update(&ev.packet);
        if trigger.fires(&ev.packet, is_new, pkts) {
            stats.triggers += 1;
            // Shared with the serial loop — the determinism contract
            // says these two paths may never diverge.
            let msg = InferenceMsg::Flow {
                id: flow_id(&ev.packet),
                packed: select_packed_input(&ev, fstats),
                ts_ns: ev.packet.ts_ns,
            };
            if send_counted(&tx, msg, &mut stats.stage_blocked[1]).is_err() {
                failure = Some("parse stage: inference channel disconnected".into());
                break;
            }
        }
        // Forward the packet clock periodically so stage 3's batch
        // timeout advances even when nothing triggers (the serial loop
        // polls its batcher on *every* packet).
        if stats.packets % CLOCK_TICK_PKTS == 0 {
            let tick = InferenceMsg::Clock(ev.packet.ts_ns);
            if send_counted(&tx, tick, &mut stats.stage_blocked[1]).is_err() {
                failure = Some("parse stage: inference channel disconnected".into());
                break;
            }
        }
    }
    let flows_len = flows.len();
    StageReport { stats, failure, flows: flows_len, engine: None }
}

/// Stage 3: the single inference engine — batcher + executor.  Being
/// the sole producer into stage 4, its emission order *is* the sink
/// order.  Every `Err(())` below means one thing: the sink hung up.
struct InferenceStage<E: NnBatchExecutor> {
    exec: E,
    tx: SyncSender<Verdict>,
    batcher: Option<Batcher<PendingFlow>>,
    stats: ServiceStats,
    /// Scratch reused across batch flushes.
    inputs: Vec<Vec<u32>>,
    meta: Vec<(u64, f64)>,
    classes: Vec<usize>,
}

impl<E: NnBatchExecutor> InferenceStage<E> {
    fn new(exec: E, tx: SyncSender<Verdict>, batcher: Option<Batcher<PendingFlow>>) -> Self {
        Self {
            exec,
            tx,
            batcher,
            stats: blank_stats(),
            inputs: Vec::new(),
            meta: Vec::new(),
            classes: Vec::new(),
        }
    }

    /// Classify one accumulated batch and emit its verdicts.  Latency
    /// semantics match `CoordinatorService::flush_batch`: packet-clock
    /// queueing wait plus the whole batch's modeled completion time.
    fn flush(&mut self, batch: Vec<(f64, PendingFlow)>, now_ns: f64) -> Result<(), ()> {
        self.meta.clear();
        self.inputs.clear();
        for (enq_ns, flow) in batch {
            self.meta.push((flow.id, enq_ns));
            self.inputs.push(flow.packed);
        }
        self.exec.classify_batch(&self.inputs, &mut self.classes);
        let exec_ns = self.exec.batch_latency_ns(self.classes.len());
        for i in 0..self.classes.len() {
            let (id, enq_ns) = self.meta[i];
            let v = Verdict {
                id,
                class: self.classes[i],
                latency_ns: batch_item_latency_ns(now_ns, enq_ns, exec_ns),
            };
            send_counted(&self.tx, v, &mut self.stats.stage_blocked[2])?;
        }
        Ok(())
    }

    /// Advance the packet clock: flush the partial batch if it timed out.
    fn on_clock(&mut self, now_ns: f64) -> Result<(), ()> {
        match self.batcher.as_mut().and_then(|b| b.poll(now_ns)) {
            Some(batch) => self.flush(batch, now_ns),
            None => Ok(()),
        }
    }

    /// Handle one triggered flow: timed flush, then enqueue-or-classify.
    fn on_flow(&mut self, id: u64, packed: Vec<u32>, ts_ns: f64) -> Result<(), ()> {
        self.on_clock(ts_ns)?;
        if self.batcher.is_none() {
            let class = self.exec.classify(&packed);
            let v = Verdict { id, class, latency_ns: self.exec.latency_ns() };
            return send_counted(&self.tx, v, &mut self.stats.stage_blocked[2]);
        }
        let full = self
            .batcher
            .as_mut()
            .unwrap()
            .push(ts_ns, PendingFlow { id, packed });
        match full {
            Some(batch) => self.flush(batch, ts_ns),
            None => Ok(()),
        }
    }

    /// End-of-stream drain: flush the partial batch with the newest
    /// enqueue time as "now" (the serial loop's shutdown semantics).
    fn drain(&mut self) -> Result<(), ()> {
        match self.batcher.as_mut().and_then(|b| b.poll(f64::INFINITY)) {
            Some(batch) => {
                let now_ns = batch.last().map_or(0.0, |&(t, _)| t);
                self.flush(batch, now_ns)
            }
            None => Ok(()),
        }
    }

    /// Event loop until every parse worker hangs up, then drain.
    fn run(mut self, rx: Receiver<InferenceMsg>) -> StageReport {
        const SINK_GONE: &str = "inference stage: sink channel disconnected";
        let mut failure = None;
        while let Ok(msg) = rx.recv() {
            let step = match msg {
                InferenceMsg::Flow { id, packed, ts_ns } => self.on_flow(id, packed, ts_ns),
                InferenceMsg::Clock(ts_ns) => self.on_clock(ts_ns),
            };
            if step.is_err() {
                failure = Some(SINK_GONE.into());
                break;
            }
        }
        if failure.is_none() && self.drain().is_err() {
            failure = Some(SINK_GONE.into());
        }
        let engine = self.exec.engine_stats();
        StageReport { stats: self.stats, failure, flows: 0, engine }
    }
}

/// Stage 4: the single ordered selector/metrics sink.
fn sink_stage(
    rx: Receiver<Verdict>,
    output: OutputSelector,
    n_classes: usize,
) -> (ServiceStats, OutputSink) {
    let mut stats = blank_stats();
    stats.classes = vec![0; n_classes];
    let mut sink = OutputSink::default();
    while let Ok(v) = rx.recv() {
        stats.inferences += 1;
        if v.class >= stats.classes.len() {
            stats.classes.resize(v.class + 1, 0);
        }
        stats.classes[v.class] += 1;
        stats.latency.record(v.latency_ns);
        sink.write(output, v.id, v.class);
    }
    (stats, sink)
}

/// The pipelined counterpart of `CoordinatorService`: same executor,
/// trigger, and selector vocabulary, staged across threads.
pub struct PipelineService<E: NnBatchExecutor> {
    exec: E,
    trigger: TriggerCondition,
    output: OutputSelector,
    cfg: PipelineConfig,
}

impl<E: NnBatchExecutor + 'static> PipelineService<E> {
    pub fn new(
        exec: E,
        trigger: TriggerCondition,
        output: OutputSelector,
        cfg: PipelineConfig,
    ) -> Self {
        Self { exec, trigger, output, cfg }
    }

    /// Drive `events` through the pipeline (the calling thread is the
    /// ingress sharder) and join every stage.  Returns the merged stats
    /// and the ordered sink, or — if any stage died — a
    /// [`PipelineError`] with everything accumulated before the fault.
    pub fn run(
        self,
        events: impl IntoIterator<Item = PacketEvent>,
    ) -> Result<PipelineReport, PipelineError> {
        let workers = self.cfg.workers.max(1);
        let depth = self.cfg.queue_depth.max(1);
        let n_classes = self.exec.n_classes();

        let (tx_inf, rx_inf) = mpsc::sync_channel::<InferenceMsg>(depth);
        let (tx_sink, rx_sink) = mpsc::sync_channel::<Verdict>(depth);

        let mut parse_txs = Vec::with_capacity(workers);
        let mut parse_handles = Vec::with_capacity(workers);
        for table in ShardedFlowTable::new(workers, self.cfg.flow_capacity).into_shards() {
            let (tx, rx) = mpsc::sync_channel::<PacketEvent>(depth);
            let tx_inf = tx_inf.clone();
            let trigger = self.trigger;
            parse_handles.push(thread::spawn(move || parse_stage(rx, tx_inf, trigger, table)));
            parse_txs.push(tx);
        }
        drop(tx_inf); // stage 3's recv loop ends when all workers finish

        let exec = self.exec;
        let batcher = if self.cfg.batch > 0 {
            Some(Batcher::new(self.cfg.batch, self.cfg.max_wait_ns))
        } else {
            None
        };
        let inf_handle =
            thread::spawn(move || InferenceStage::new(exec, tx_sink, batcher).run(rx_inf));
        let output = self.output;
        let sink_handle = thread::spawn(move || sink_stage(rx_sink, output, n_classes));

        // Stage 0: shard by flow hash and feed.  A dead worker (its rx
        // dropped) surfaces here as a failed send, not a hang.
        let mut ingress_blocked = 0u64;
        let mut failures: Vec<String> = Vec::new();
        for ev in events {
            let w = ShardedFlowTable::shard_of(&ev.packet, workers);
            if send_counted(&parse_txs[w], ev, &mut ingress_blocked).is_err() {
                failures.push(format!("ingress: parse worker {w} unreachable"));
                break;
            }
        }
        drop(parse_txs);

        // Join in dataflow order, merging stats and collecting faults.
        let mut stats = blank_stats();
        stats.classes = vec![0; n_classes];
        stats.stage_blocked[0] = ingress_blocked;
        let mut flows_tracked = 0usize;
        for (w, h) in parse_handles.into_iter().enumerate() {
            match h.join() {
                Ok(rep) => {
                    stats.merge(&rep.stats);
                    flows_tracked += rep.flows;
                    if let Some(f) = rep.failure {
                        failures.push(format!("worker {w}: {f}"));
                    }
                }
                Err(p) => failures.push(format!("parse worker {w} panicked: {}", panic_msg(&p))),
            }
        }
        let mut engine = None;
        match inf_handle.join() {
            Ok(rep) => {
                stats.merge(&rep.stats);
                engine = rep.engine;
                if let Some(f) = rep.failure {
                    failures.push(f);
                }
            }
            Err(p) => failures.push(format!("inference stage panicked: {}", panic_msg(&p))),
        }
        let sink = match sink_handle.join() {
            Ok((sink_stats, sink)) => {
                stats.merge(&sink_stats);
                sink
            }
            Err(p) => {
                failures.push(format!("sink stage panicked: {}", panic_msg(&p)));
                OutputSink::default()
            }
        };

        let report = PipelineReport { stats, sink, flows_tracked, engine };
        if failures.is_empty() {
            Ok(report)
        } else {
            Err(PipelineError { failures, report })
        }
    }
}

// ---------------------------------------------------------------------------
// Registry-routed pipeline: the same staged shape, serving *named,
// versioned* models with zero-downtime hot swap.
//
// Deliberately a parallel implementation rather than a generalization
// of the single-model stages over a route/tag parameter: the
// single-model pipeline is the tier-1 determinism baseline and stays
// untouched.  The cost is that clock-tick, drain, and fault-handling
// fixes must land in both copies — when touching one, check the other.
// ---------------------------------------------------------------------------

/// Stage 1+2 → stage 3 messages on the routed pipeline: like
/// [`InferenceMsg`] plus the route (model index) the flow resolved to.
enum RoutedMsg {
    Flow { route: usize, id: u64, packed: Vec<u32>, ts_ns: f64 },
    Clock(f64),
}

/// Stage 3 → stage 4 message: one verdict with its version tag and the
/// route it ran on (route-indexed accounting keeps the sink's hot loop
/// free of per-verdict key allocations).
struct TaggedOut {
    route: usize,
    id: u64,
    class: usize,
    latency_ns: f64,
    tag: VersionTag,
}

/// Stage 1+2 of the routed pipeline: flow update + **model routing** +
/// feature packing.  Routing is a pure per-flow function
/// ([`ModelRouter`] invariant), so flow-hash sharding keeps it
/// deterministic exactly as in the single-model pipeline.
fn routed_parse_stage(
    rx: Receiver<PacketEvent>,
    tx: SyncSender<RoutedMsg>,
    router: ModelRouter,
    mut flows: FlowTable,
) -> StageReport {
    let mut stats = blank_stats();
    let mut failure = None;
    while let Ok(ev) = rx.recv() {
        stats.packets += 1;
        let (fstats, is_new, pkts) = flows.update(&ev.packet);
        if let Some(route) = router.route(&ev.packet, is_new, pkts) {
            stats.triggers += 1;
            let msg = RoutedMsg::Flow {
                route,
                id: flow_id(&ev.packet),
                packed: select_packed_input(&ev, fstats),
                ts_ns: ev.packet.ts_ns,
            };
            if send_counted(&tx, msg, &mut stats.stage_blocked[1]).is_err() {
                failure = Some("parse stage: inference channel disconnected".into());
                break;
            }
        }
        if stats.packets % CLOCK_TICK_PKTS == 0 {
            let tick = RoutedMsg::Clock(ev.packet.ts_ns);
            if send_counted(&tx, tick, &mut stats.stage_blocked[1]).is_err() {
                failure = Some("parse stage: inference channel disconnected".into());
                break;
            }
        }
    }
    let flows_len = flows.len();
    StageReport { stats, failure, flows: flows_len, engine: None }
}

/// Stage 3 of the routed pipeline: per-model batch lanes feeding a
/// versioned [`MultiModelExecutor`].  Each lane's batch pins exactly one
/// registry epoch — the zero-downtime swap contract — and every emitted
/// verdict carries the pinned tag.
struct RoutedInferenceStage {
    exec: MultiModelExecutor,
    tx: SyncSender<TaggedOut>,
    batchers: Option<BatchSet<PendingFlow>>,
    stats: ServiceStats,
    inputs: Vec<Vec<u32>>,
    meta: Vec<(u64, f64)>,
    classes: Vec<usize>,
}

impl RoutedInferenceStage {
    fn new(
        exec: MultiModelExecutor,
        tx: SyncSender<TaggedOut>,
        batchers: Option<BatchSet<PendingFlow>>,
    ) -> Self {
        Self {
            exec,
            tx,
            batchers,
            stats: blank_stats(),
            inputs: Vec::new(),
            meta: Vec::new(),
            classes: Vec::new(),
        }
    }

    /// One lane's batch under one pinned epoch; latency semantics match
    /// the serial loop's `flush_batch`.
    fn flush(
        &mut self,
        lane: usize,
        batch: TimedBatch<PendingFlow>,
        now_ns: f64,
    ) -> Result<(), ()> {
        self.meta.clear();
        self.inputs.clear();
        for (enq_ns, flow) in batch {
            self.meta.push((flow.id, enq_ns));
            self.inputs.push(flow.packed);
        }
        let tag = self.exec.classify_batch(lane, &self.inputs, &mut self.classes);
        let exec_ns = self.exec.batch_latency_ns(self.classes.len());
        for i in 0..self.classes.len() {
            let (id, enq_ns) = self.meta[i];
            let out = TaggedOut {
                route: lane,
                id,
                class: self.classes[i],
                latency_ns: batch_item_latency_ns(now_ns, enq_ns, exec_ns),
                tag: tag.clone(),
            };
            send_counted(&self.tx, out, &mut self.stats.stage_blocked[2])?;
        }
        Ok(())
    }

    fn on_clock(&mut self, now_ns: f64) -> Result<(), ()> {
        let due = match self.batchers.as_mut() {
            Some(b) => b.poll(now_ns),
            None => Vec::new(),
        };
        for (lane, batch) in due {
            self.flush(lane, batch, now_ns)?;
        }
        Ok(())
    }

    fn on_flow(&mut self, route: usize, id: u64, packed: Vec<u32>, ts_ns: f64) -> Result<(), ()> {
        self.on_clock(ts_ns)?;
        if self.batchers.is_none() {
            let (class, tag) = self.exec.classify(route, &packed);
            let out = TaggedOut { route, id, class, latency_ns: self.exec.latency_ns(), tag };
            return send_counted(&self.tx, out, &mut self.stats.stage_blocked[2]);
        }
        let full = self
            .batchers
            .as_mut()
            .unwrap()
            .push(route, ts_ns, PendingFlow { id, packed });
        match full {
            Some(batch) => self.flush(route, batch, ts_ns),
            None => Ok(()),
        }
    }

    /// End-of-stream drain of every lane (newest enqueue time as "now").
    fn drain(&mut self) -> Result<(), ()> {
        let due = match self.batchers.as_mut() {
            Some(b) => b.poll(f64::INFINITY),
            None => Vec::new(),
        };
        for (lane, batch) in due {
            let now_ns = batch.last().map_or(0.0, |&(t, _)| t);
            self.flush(lane, batch, now_ns)?;
        }
        Ok(())
    }

    fn run(mut self, rx: Receiver<RoutedMsg>) -> StageReport {
        const SINK_GONE: &str = "inference stage: sink channel disconnected";
        let mut failure = None;
        while let Ok(msg) = rx.recv() {
            let step = match msg {
                RoutedMsg::Flow { route, id, packed, ts_ns } => {
                    self.on_flow(route, id, packed, ts_ns)
                }
                RoutedMsg::Clock(ts_ns) => self.on_clock(ts_ns),
            };
            if step.is_err() {
                failure = Some(SINK_GONE.into());
                break;
            }
        }
        if failure.is_none() && self.drain().is_err() {
            failure = Some(SINK_GONE.into());
        }
        let engine = self.exec.engine_stats();
        StageReport { stats: self.stats, failure, flows: 0, engine }
    }
}

/// Stage 4 of the routed pipeline: ordered sink + global and per-model
/// accounting, plus the tagged verdict log.
fn routed_sink_stage(
    rx: Receiver<TaggedOut>,
    output: OutputSelector,
    n_classes: usize,
    log_tags: bool,
    model_names: Vec<String>,
) -> (ServiceStats, OutputSink, Vec<TaggedVerdict>) {
    let mut stats = blank_stats();
    stats.classes = vec![0; n_classes];
    // Route-indexed during the run (no per-verdict key allocation);
    // folded into the name-keyed map once at exit.
    let mut per_route = vec![ModelServiceStats::default(); model_names.len()];
    let mut sink = OutputSink::default();
    let mut tagged = Vec::new();
    while let Ok(v) = rx.recv() {
        stats.inferences += 1;
        if v.class >= stats.classes.len() {
            stats.classes.resize(v.class + 1, 0);
        }
        stats.classes[v.class] += 1;
        per_route[v.route].record(v.class);
        stats.latency.record(v.latency_ns);
        sink.write(output, v.id, v.class);
        if log_tags {
            tagged.push(TaggedVerdict { id: v.id, class: v.class, tag: v.tag });
        }
    }
    // Accumulate (don't insert) so duplicate route names — legal in a
    // hash-split router — merge their counts the same way the serial
    // service's fold does.
    for (name, m) in model_names.into_iter().zip(per_route) {
        let entry = stats.per_model.entry(name).or_default();
        entry.inferences += m.inferences;
        if m.classes.len() > entry.classes.len() {
            entry.classes.resize(m.classes.len(), 0);
        }
        for (a, b) in entry.classes.iter_mut().zip(&m.classes) {
            *a += b;
        }
    }
    (stats, sink, tagged)
}

/// What a completed (or faulted) routed pipeline run leaves behind:
/// the single-model [`PipelineReport`] fields plus the tagged verdict
/// log (per-model histograms and swap counts live in
/// [`ServiceStats::per_model`]).
#[derive(Debug)]
pub struct RoutedPipelineReport {
    pub stats: ServiceStats,
    pub sink: OutputSink,
    /// Every verdict with its `(model, version)` tag, in sink order.
    pub tagged: Vec<TaggedVerdict>,
    pub flows_tracked: usize,
    pub engine: Option<EngineStats>,
}

/// One or more routed stages died; partial statistics survive.
#[derive(Debug)]
pub struct RoutedPipelineError {
    pub failures: Vec<String>,
    pub report: RoutedPipelineReport,
}

impl std::fmt::Display for RoutedPipelineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "routed pipeline stage failure: {}", self.failures.join("; "))
    }
}

impl std::error::Error for RoutedPipelineError {}

/// The registry-routed counterpart of [`PipelineService`]: stage-1
/// workers route flows to named models, stage 3 serves them through a
/// versioned [`MultiModelExecutor`], and live `publish`es through the
/// shared [`RegistryHandle`] hot-swap weights mid-run without draining
/// any queue.  Inherits the single-model pipeline's determinism
/// contract per model (routing is flow-pure), its backpressure
/// accounting, and its failure semantics.
pub struct RoutedPipelineService {
    registry: RegistryHandle,
    router: ModelRouter,
    exec: MultiModelExecutor,
    output: OutputSelector,
    cfg: PipelineConfig,
    log_tags: bool,
}

impl RoutedPipelineService {
    /// Bind the router's model names against `registry` (all must be
    /// published); `latency_ns` as in
    /// [`MultiModelService::new`](super::MultiModelService::new).
    pub fn new(
        registry: RegistryHandle,
        router: ModelRouter,
        output: OutputSelector,
        cfg: PipelineConfig,
        latency_ns: f64,
    ) -> Result<Self, RegistryError> {
        let exec = MultiModelExecutor::new(&registry, router.model_names(), latency_ns)?;
        Ok(Self { registry, router, exec, output, cfg, log_tags: true })
    }

    /// Spread stage-3 batches over `n_shards` engine workers; every
    /// batch still pins exactly one epoch across all shards.
    pub fn with_shards(mut self, n_shards: usize) -> Self {
        self.exec = self.exec.sharded(n_shards);
        self
    }

    /// Drop the unbounded per-verdict tag log (long-running serves:
    /// memory stays flat; per-model stats and the sink are unaffected).
    pub fn without_tag_log(mut self) -> Self {
        self.log_tags = false;
        self
    }

    /// Drive `events` through the routed pipeline; same join/fault
    /// shape as [`PipelineService::run`].  Per-model swap counts are
    /// snapshotted from the registry after the stages join.
    pub fn run(
        self,
        events: impl IntoIterator<Item = PacketEvent>,
    ) -> Result<RoutedPipelineReport, RoutedPipelineError> {
        let workers = self.cfg.workers.max(1);
        let depth = self.cfg.queue_depth.max(1);
        let n_classes = self.exec.max_out_neurons();
        let model_names: Vec<String> = self.router.model_names().to_vec();

        let (tx_inf, rx_inf) = mpsc::sync_channel::<RoutedMsg>(depth);
        let (tx_sink, rx_sink) = mpsc::sync_channel::<TaggedOut>(depth);

        let mut parse_txs = Vec::with_capacity(workers);
        let mut parse_handles = Vec::with_capacity(workers);
        for table in ShardedFlowTable::new(workers, self.cfg.flow_capacity).into_shards() {
            let (tx, rx) = mpsc::sync_channel::<PacketEvent>(depth);
            let tx_inf = tx_inf.clone();
            let router = self.router.clone();
            parse_handles
                .push(thread::spawn(move || routed_parse_stage(rx, tx_inf, router, table)));
            parse_txs.push(tx);
        }
        drop(tx_inf);

        let exec = self.exec;
        let batchers = if self.cfg.batch > 0 {
            Some(BatchSet::new(self.router.n_models(), self.cfg.batch, self.cfg.max_wait_ns))
        } else {
            None
        };
        let inf_handle =
            thread::spawn(move || RoutedInferenceStage::new(exec, tx_sink, batchers).run(rx_inf));
        let output = self.output;
        let log_tags = self.log_tags;
        let sink_names = model_names.clone();
        let sink_handle = thread::spawn(move || {
            routed_sink_stage(rx_sink, output, n_classes, log_tags, sink_names)
        });

        let mut ingress_blocked = 0u64;
        let mut failures: Vec<String> = Vec::new();
        for ev in events {
            let w = ShardedFlowTable::shard_of(&ev.packet, workers);
            if send_counted(&parse_txs[w], ev, &mut ingress_blocked).is_err() {
                failures.push(format!("ingress: parse worker {w} unreachable"));
                break;
            }
        }
        drop(parse_txs);

        let mut stats = blank_stats();
        stats.classes = vec![0; n_classes];
        stats.stage_blocked[0] = ingress_blocked;
        let mut flows_tracked = 0usize;
        for (w, h) in parse_handles.into_iter().enumerate() {
            match h.join() {
                Ok(rep) => {
                    stats.merge(&rep.stats);
                    flows_tracked += rep.flows;
                    if let Some(f) = rep.failure {
                        failures.push(format!("worker {w}: {f}"));
                    }
                }
                Err(p) => failures.push(format!("parse worker {w} panicked: {}", panic_msg(&p))),
            }
        }
        let mut engine = None;
        match inf_handle.join() {
            Ok(rep) => {
                stats.merge(&rep.stats);
                engine = rep.engine;
                if let Some(f) = rep.failure {
                    failures.push(f);
                }
            }
            Err(p) => failures.push(format!("inference stage panicked: {}", panic_msg(&p))),
        }
        let (sink, tagged) = match sink_handle.join() {
            Ok((sink_stats, sink, tagged)) => {
                stats.merge(&sink_stats);
                (sink, tagged)
            }
            Err(p) => {
                failures.push(format!("sink stage panicked: {}", panic_msg(&p)));
                (OutputSink::default(), Vec::new())
            }
        };
        // Swap counts are a registry property, not a stage property:
        // snapshot once, after every stage has reported.
        for name in &model_names {
            let entry = stats.per_model.entry(name.clone()).or_default();
            entry.swaps = self.registry.swap_count(name);
        }

        let report = RoutedPipelineReport { stats, sink, tagged, flows_tracked, engine };
        if failures.is_empty() {
            Ok(report)
        } else {
            Err(RoutedPipelineError { failures, report })
        }
    }
}

/// Best-effort text of a cross-thread panic payload.
fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::BnnModel;
    use crate::coordinator::CoreExecutor;
    use crate::net::traffic::CbrSpec;

    fn events(n: usize, flows: u64, seed: u64) -> Vec<PacketEvent> {
        PacketEvent::cbr_burst(CbrSpec { gbps: 10.0, pkt_size: 256 }, flows, seed, n)
    }

    fn pipeline(cfg: PipelineConfig) -> PipelineService<CoreExecutor> {
        let model = BnnModel::random("traffic", 256, &[32, 16, 2], 1);
        PipelineService::new(
            CoreExecutor::fpga(model),
            TriggerCondition::EveryNPackets(10),
            OutputSelector::Memory,
            cfg,
        )
    }

    #[test]
    fn healthy_run_accounts_every_trigger() {
        let evs = events(5000, 50, 3);
        let cfg = PipelineConfig { workers: 3, ..Default::default() };
        let rep = pipeline(cfg).run(evs).unwrap();
        assert_eq!(rep.stats.packets, 5000);
        assert!(rep.stats.triggers > 0);
        assert_eq!(rep.stats.triggers, rep.stats.inferences);
        assert_eq!(rep.sink.memory.len() as u64, rep.stats.inferences);
        assert_eq!(rep.stats.classes.iter().sum::<u64>(), rep.stats.inferences);
        assert_eq!(rep.stats.stage_blocked.len(), STAGE_LINKS.len());
        assert!(rep.flows_tracked > 0 && rep.flows_tracked <= 50);
    }

    #[test]
    fn batched_pipeline_drains_at_shutdown() {
        let evs = events(4000, 40, 6);
        let rep = pipeline(PipelineConfig {
            workers: 2,
            batch: 7,
            max_wait_ns: 1e12,
            ..Default::default()
        })
        .run(evs)
        .unwrap();
        assert_eq!(rep.stats.triggers, rep.stats.inferences);
    }

    #[test]
    fn routed_pipeline_matches_routed_serial_per_model() {
        use crate::bnn::RegistryHandle;
        use crate::coordinator::MultiModelService;

        let h = RegistryHandle::new();
        h.publish("anomaly", &BnnModel::random("anomaly", 256, &[32, 16, 2], 31))
            .unwrap();
        h.publish("traffic-class", &BnnModel::random("traffic-class", 256, &[32, 16, 2], 32))
            .unwrap();
        let router = ModelRouter::hash_split(
            TriggerCondition::EveryNPackets(10),
            vec!["anomaly".into(), "traffic-class".into()],
        );
        let evs = events(6000, 50, 11);

        let mut serial =
            MultiModelService::new(h.clone(), router.clone(), OutputSelector::Memory, 100.0)
                .unwrap();
        for ev in &evs {
            serial.handle(ev);
        }
        serial.flush();

        for (workers, batch, shards) in [(1, 0, 1), (3, 0, 1), (2, 8, 1), (2, 8, 3)] {
            let cfg = PipelineConfig { workers, batch, ..Default::default() };
            let rep = RoutedPipelineService::new(
                h.clone(),
                router.clone(),
                OutputSelector::Memory,
                cfg,
                100.0,
            )
            .unwrap()
            .with_shards(shards)
            .run(evs.iter().cloned())
            .unwrap();
            assert_eq!(rep.stats.packets, 6000, "w{workers} b{batch} s{shards}");
            assert_eq!(rep.stats.triggers, serial.stats.triggers);
            assert_eq!(rep.stats.inferences, serial.stats.inferences);
            assert_eq!(rep.stats.classes, serial.stats.classes);
            assert_eq!(rep.stats.per_model, serial.stats.per_model);
            assert_eq!(rep.tagged.len() as u64, rep.stats.inferences);
            // Same verdicts for the same flows, order aside.
            let mut a = serial.sink.memory.clone();
            let mut b = rep.sink.memory.clone();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b);
            // No publishes happened: everything ran at version 1.
            assert!(rep.tagged.iter().all(|t| t.tag.version() == 1));
            if shards > 1 && batch > 0 {
                assert!(rep.engine.is_some());
            }
        }
    }

    #[test]
    fn tiny_queues_only_add_backpressure_never_loss() {
        let evs = events(3000, 30, 9);
        let want = pipeline(PipelineConfig::default()).run(evs.clone()).unwrap();
        let got = pipeline(PipelineConfig {
            workers: 2,
            queue_depth: 1,
            ..Default::default()
        })
        .run(evs)
        .unwrap();
        assert_eq!(got.stats.triggers, want.stats.triggers);
        assert_eq!(got.stats.inferences, want.stats.inferences);
        assert_eq!(got.stats.classes, want.stats.classes);
    }
}

//! The N3IC coordinator (§3.2, Fig. 7): triggers, input/output selectors,
//! flow shunting, batching, and the serving loop.
//!
//! This is the paper's system contribution seen from the NIC: the NN
//! executor is a data-plane module triggered by packet events or by the
//! forwarding module (e.g. "enough packets received for a flow"), with
//! selectors choosing where inputs come from and where verdicts go.

pub mod batcher;
pub mod multinn;
pub mod pipeline;
pub mod selector;
pub mod service;
pub mod shunt;
pub mod trigger;

pub use batcher::{BatchSet, Batcher, TimedBatch};
pub use pipeline::{
    PipelineConfig, PipelineError, PipelineReport, PipelineService, RoutedPipelineError,
    RoutedPipelineReport, RoutedPipelineService, STAGE_LINKS,
};
pub use selector::{InputSelector, OutputSelector};
pub use service::{
    CoordinatorService, ModelServiceStats, MultiModelService, PacketEvent, PendingFlow,
    ServiceStats, TaggedVerdict,
};
pub use shunt::{ShuntDecision, ShuntRouter};
pub use trigger::{ModelRouter, TriggerCondition};

use crate::bnn::BnnModel;

/// Uniform executor interface implemented by every backend (NFP / PISA /
/// FPGA device models, host `bnn-exec`, PJRT runtime).
pub trait NnExecutor: Send {
    /// Bit-exact classification of one packed input.
    fn classify(&mut self, x: &[u32]) -> usize;
    /// Raw final-layer scores.
    fn scores(&mut self, x: &[u32], out: &mut [i32]);
    /// Modeled (or measured) per-inference latency in ns.
    fn latency_ns(&self) -> f64;
    /// Backend name for logs/metrics.
    fn name(&self) -> &'static str;
    /// Output classes of the deployed model (verdict histogram width).
    fn n_classes(&self) -> usize;
}

/// Batch extension of [`NnExecutor`]: the serve loop hands
/// `Batcher`-accumulated flows to `classify_batch`.  The default is the
/// per-item loop, so any executor works behind the batch API; backends
/// with a real batch fast path (weight-stationary kernel, sharded
/// engine, PJRT artifacts) override it.
pub trait NnBatchExecutor: NnExecutor {
    /// Classify a whole batch; `classes` is cleared and refilled with
    /// one verdict per input, in input order.
    fn classify_batch(&mut self, inputs: &[Vec<u32>], classes: &mut Vec<usize>) {
        classes.clear();
        classes.reserve(inputs.len());
        for x in inputs {
            let c = self.classify(x);
            classes.push(c);
        }
    }

    /// Modeled time for this backend to complete a batch of `b` — every
    /// item in the batch observes the whole batch's completion.  Default
    /// is a serial device (`b ×` per-inference latency); backends with a
    /// calibrated batch model override it.
    fn batch_latency_ns(&self, b: usize) -> f64 {
        self.latency_ns() * b as f64
    }

    /// Throughput counters of an underlying multi-core engine, if this
    /// backend routes batches through one — serve-report material that
    /// survives the executor being moved into a pipeline stage.
    fn engine_stats(&self) -> Option<crate::bnn::EngineStats> {
        None
    }
}

/// Host / device adapters for the trait.
pub struct CoreExecutor {
    exec: crate::bnn::BnnExecutor,
    /// Weight-stationary batch path, sharing `exec`'s packed weights.
    batch: crate::bnn::BatchKernel,
    /// Multi-core batch path (enabled by [`sharded`](Self::sharded)).
    engine: Option<crate::bnn::ShardedEngine>,
    latency_ns: f64,
    name: &'static str,
}

impl CoreExecutor {
    /// Wrap the bit-exact core with a backend-specific latency model.
    pub fn new(model: BnnModel, latency_ns: f64, name: &'static str) -> Self {
        let exec = crate::bnn::BnnExecutor::new(model);
        let batch = crate::bnn::BatchKernel::with_packed(exec.packed_model());
        Self {
            exec,
            batch,
            engine: None,
            latency_ns,
            name,
        }
    }

    /// Route batches through a [`ShardedEngine`](crate::bnn::ShardedEngine)
    /// of `n_shards` worker cores (sharing this executor's packed
    /// weights).  `n_shards <= 1` keeps the single-core kernel.
    pub fn sharded(mut self, n_shards: usize) -> Self {
        if n_shards > 1 {
            self.engine = Some(crate::bnn::ShardedEngine::with_packed(
                self.exec.packed_model(),
                n_shards,
            ));
        }
        self
    }

    /// N3IC-FPGA executor adapter.
    pub fn fpga(model: BnnModel) -> Self {
        let lat = crate::fpga::FpgaTiming::new(&model).latency_ns();
        Self::new(model, lat, "n3ic-fpga")
    }

    /// N3IC-NFP (data-parallel, CLS) adapter.
    pub fn nfp(model: BnnModel) -> Self {
        let lat = crate::nfp::DataParallelCost::new(&model, crate::nfp::MemKind::Cls)
            .mean_ns();
        Self::new(model, lat, "n3ic-nfp")
    }

    /// Host `bnn-exec` adapter (batch-1 latency incl. PCIe).
    pub fn host(model: BnnModel) -> Self {
        let lat = crate::bnnexec::HostCostModel::default().batch_latency_ns(&model, 1);
        Self::new(model, lat, "bnn-exec")
    }

    /// N3IC-P4 adapter; fails for models the PISA target cannot fit.
    pub fn pisa(model: BnnModel) -> Result<Self, crate::pisa::CompileError> {
        let prog = crate::pisa::compile_bnn(&model)?;
        let lat = prog.latency_ns(64);
        Ok(Self::new(model, lat, "n3ic-p4"))
    }
}

impl NnExecutor for CoreExecutor {
    fn classify(&mut self, x: &[u32]) -> usize {
        self.exec.classify(x)
    }

    fn scores(&mut self, x: &[u32], out: &mut [i32]) {
        self.exec.infer(x, out)
    }

    fn latency_ns(&self) -> f64 {
        self.latency_ns
    }

    fn name(&self) -> &'static str {
        self.name
    }

    fn n_classes(&self) -> usize {
        self.exec.model().out_neurons()
    }
}

impl NnBatchExecutor for CoreExecutor {
    fn classify_batch(&mut self, inputs: &[Vec<u32>], classes: &mut Vec<usize>) {
        match self.engine.as_mut() {
            Some(engine) => engine.run_batch(inputs, classes),
            None => self.batch.run_batch(inputs, classes),
        }
    }

    fn engine_stats(&self) -> Option<crate::bnn::EngineStats> {
        self.engine.as_ref().map(|e| e.stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::{infer_packed, BnnLayer, BnnModel};

    #[test]
    fn sharded_adapter_matches_single_core_batch_path() {
        let model = BnnModel::random("traffic", 256, &[32, 16, 2], 8);
        let inputs: Vec<Vec<u32>> = (0..23)
            .map(|i| BnnLayer::random(1, 256, 700 + i).words)
            .collect();
        let mut single = CoreExecutor::fpga(model.clone());
        let mut sharded = CoreExecutor::fpga(model).sharded(3);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        single.classify_batch(&inputs, &mut a);
        sharded.classify_batch(&inputs, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn adapters_bit_exact_and_latency_ordered() {
        let model = BnnModel::random("traffic", 256, &[32, 16, 2], 1);
        let x = BnnLayer::random(1, 256, 99).words;
        let want = infer_packed(&model, &x);
        let mut fpga = CoreExecutor::fpga(model.clone());
        let mut nfp = CoreExecutor::nfp(model.clone());
        let mut host = CoreExecutor::host(model.clone());
        let mut pisa = CoreExecutor::pisa(model.clone()).unwrap();
        for e in [&mut fpga as &mut dyn NnExecutor, &mut nfp, &mut host, &mut pisa] {
            assert_eq!(e.classify(&x), want, "{}", e.name());
        }
        // Fig. 14 ordering: FPGA < P4 < NFP; batch-1 host is in the NFP's
        // 10s-of-µs neighbourhood, while any throughput-equivalent batch
        // puts the host 10-100× above every N3IC variant.
        assert!(fpga.latency_ns() < pisa.latency_ns());
        assert!(pisa.latency_ns() < nfp.latency_ns());
        assert!(host.latency_ns() > 10_000.0); // 10s of µs at batch 1
        let host_b1k = crate::bnnexec::HostCostModel::default()
            .batch_latency_ns(&model, 1000);
        assert!(nfp.latency_ns() * 10.0 < host_b1k);
    }
}

//! The N3IC coordinator (§3.2, Fig. 7): triggers, input/output selectors,
//! flow shunting, batching, routing, and the serving runtime.
//!
//! This is the paper's system contribution seen from the NIC: the NN
//! executor is a data-plane module triggered by packet events, with
//! selectors choosing where inputs come from and where verdicts go.
//! Since ISSUE 5 the whole serving surface is one API:
//!
//! * [`InferencePlane`] — the uniform backend trait (`classify`,
//!   `run_batch`, `try_run_batch`) plus a [`Capabilities`] descriptor
//!   the runtime queries instead of being specialized per backend;
//! * [`BackendFactory`] — every executor in the crate as a named
//!   backend (`"host" | "batch" | "sharded" | "pisa" | "fpga" |
//!   "placed" | "registry"`);
//! * [`Service`] / [`ServeBuilder`] — the one serving runtime;
//!   batching, pipelining, multi-model routing, hot swap, and overload
//!   control are builder options, not separate service types.
//!
//! The [`overload`] module is the control plane over that runtime:
//! admission shedding, the degradation ladder, per-backend circuit
//! breakers behind [`PlacedPlane`], and stage supervision.

pub mod admin;
pub mod backend;
pub mod batcher;
pub mod multinn;
pub mod overload;
pub mod pipeline;
pub mod plane;
pub mod selector;
pub mod service;
pub mod shunt;
pub mod trigger;

pub use admin::{
    prometheus_text, AdminError, AdminHandle, AdminRequest, AdminResponse, HealthStatus,
};
pub use backend::BackendFactory;
pub use batcher::{BatchSet, Batcher, TimedBatch};
pub use overload::{
    AdmissionController, BreakerPolicy, BreakerState, CircuitBreaker, DegradationEvent,
    DegradationLadder, DegradeSpec, FaultPlan, LadderPolicy, PlacedPlane, PlaneHealth,
    ServiceLevel, ShedPolicy, SupervisorPolicy,
};
pub use pipeline::STAGE_LINKS;
pub use plane::{Capabilities, InferencePlane, SwapController};
pub use selector::{InputSelector, OutputSelector};
pub use service::{
    ModelServiceStats, PacketEvent, PendingFlow, ServeBuilder, Service, ServiceError,
    ServiceReport, ServiceStats, StageFailure, TaggedVerdict,
};
pub use shunt::{ShuntDecision, ShuntRouter};
pub use trigger::{ModelRouter, TriggerCondition};

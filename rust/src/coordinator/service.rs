//! The serving loop: a threaded coordinator that consumes packet / flow
//! events, applies the trigger + selectors, runs the configured executor,
//! and routes verdicts.  This is the launcher's `serve` mode — the
//! end-to-end request path with Python nowhere in sight.
//!
//! Two inference routes share the loop:
//!
//! * **unbatched** (default): every triggered flow is classified inline —
//!   minimum latency, the NIC-style per-packet path;
//! * **batched** ([`CoordinatorService::with_batching`]): triggered flows
//!   accumulate in a [`Batcher`] and go through the executor's
//!   [`NnBatchExecutor::classify_batch`] fast path (weight-stationary
//!   kernel / sharded engine) when the batch fills or times out — the
//!   throughput path of §6.

use std::collections::BTreeMap;
use std::sync::mpsc;

use crate::bnn::{MultiModelExecutor, RegistryError, RegistryHandle, VersionTag};
use crate::metrics::LatencyHistogram;
use crate::net::features::FeatureVector;
use crate::net::flow::{FlowStats, FlowTable};
use crate::net::packet::Packet;
use crate::net::traffic::{CbrSpec, TrafficGen};

use super::batcher::{BatchSet, Batcher, TimedBatch};
use super::selector::{OutputSelector, OutputSink};
use super::trigger::{ModelRouter, TriggerCondition};
use super::NnBatchExecutor;

/// One event entering the coordinator (a received packet).
#[derive(Debug, Clone)]
pub struct PacketEvent {
    pub packet: Packet,
    /// Optional inline payload words (probe vectors etc.).
    pub payload_words: Option<Vec<u32>>,
}

impl PacketEvent {
    /// `n` payload-less events from a seeded CBR generator — the
    /// traffic shape every serving test and bench drives with.
    pub fn cbr_burst(spec: CbrSpec, flows: u64, seed: u64, n: usize) -> Vec<PacketEvent> {
        let mut gen = TrafficGen::new(spec, flows, seed);
        (0..n)
            .map(|_| PacketEvent { packet: gen.next_packet(), payload_words: None })
            .collect()
    }
}

/// A triggered flow waiting in the batcher: its routing id + packed input.
#[derive(Debug, Clone)]
pub struct PendingFlow {
    pub id: u64,
    pub packed: Vec<u32>,
}

/// Routing id of a flow event — the verdict's key in the sink.  One
/// definition shared by the serial loop and the pipelined runtime: the
/// two must stay bit-identical (the determinism contract), so neither
/// may grow its own copy.
#[inline]
pub(crate) fn flow_id(p: &Packet) -> u64 {
    ((p.src_ip as u64) << 32) | p.dst_ip as u64
}

/// Input selection shared by both runtimes: inline payload words if the
/// event carries them, else the packed flow features.
pub(crate) fn select_packed_input(ev: &PacketEvent, stats: &FlowStats) -> Vec<u32> {
    match &ev.payload_words {
        Some(w) => w.clone(),
        None => FeatureVector::from_stats(stats).pack().to_vec(),
    }
}

/// Latency of one batched item: packet-clock queueing wait plus the
/// whole batch's modeled completion time (every item waits for the
/// batch to finish) — shared by both runtimes' flush paths.
#[inline]
pub(crate) fn batch_item_latency_ns(now_ns: f64, enq_ns: f64, exec_ns: f64) -> f64 {
    (now_ns - enq_ns).max(0.0) + exec_ns
}

/// Aggregate statistics of a service run.
#[derive(Debug, Default, Clone)]
pub struct ServiceStats {
    pub packets: u64,
    pub triggers: u64,
    pub inferences: u64,
    /// Verdict histogram, sized from the executor's model and grown on
    /// demand if a verdict ever exceeds it.
    pub classes: Vec<u64>,
    pub latency: LatencyHistogram,
    /// Bounded-channel backpressure in the pipelined runtime: how many
    /// sends found the downstream queue full and had to wait, indexed by
    /// inter-stage link (see `coordinator::pipeline::STAGE_LINKS`).
    /// Empty in the serial loop, which has no queues.
    pub stage_blocked: Vec<u64>,
    /// Per-model accounting on the registry route, keyed by slot name.
    /// Empty in single-model serving.
    pub per_model: BTreeMap<String, ModelServiceStats>,
}

/// One routed model's share of a run: its verdict histogram plus the
/// hot swaps its registry slot absorbed while the run was live.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ModelServiceStats {
    pub inferences: u64,
    /// Verdict histogram for this model, grown on demand.
    pub classes: Vec<u64>,
    /// Registry swap count for this slot, snapshotted at report time.
    /// Merging takes the max: parallel stages snapshot the *same* slot
    /// counter, so adding would double-count.
    pub swaps: u64,
}

impl ModelServiceStats {
    /// Account one verdict (shared by the serial and pipelined routed
    /// sinks).
    pub(crate) fn record(&mut self, class: usize) {
        self.inferences += 1;
        if class >= self.classes.len() {
            self.classes.resize(class + 1, 0);
        }
        self.classes[class] += 1;
    }
}

impl ServiceStats {
    /// Fold another stage's (or shard's) counters into this one — the
    /// pipeline's join step.  Histograms merge bucket-wise; the verdict
    /// histogram grows to the wider of the two.
    pub fn merge(&mut self, other: &ServiceStats) {
        self.packets += other.packets;
        self.triggers += other.triggers;
        self.inferences += other.inferences;
        if other.classes.len() > self.classes.len() {
            self.classes.resize(other.classes.len(), 0);
        }
        for (a, b) in self.classes.iter_mut().zip(&other.classes) {
            *a += b;
        }
        self.latency.merge(&other.latency);
        if other.stage_blocked.len() > self.stage_blocked.len() {
            self.stage_blocked.resize(other.stage_blocked.len(), 0);
        }
        for (a, b) in self.stage_blocked.iter_mut().zip(&other.stage_blocked) {
            *a += b;
        }
        for (name, m) in &other.per_model {
            let mine = self.per_model.entry(name.clone()).or_default();
            mine.inferences += m.inferences;
            if m.classes.len() > mine.classes.len() {
                mine.classes.resize(m.classes.len(), 0);
            }
            for (a, b) in mine.classes.iter_mut().zip(&m.classes) {
                *a += b;
            }
            // Snapshots of one shared counter, not partitions of it.
            mine.swaps = mine.swaps.max(m.swaps);
        }
    }
}

/// The coordinator service: single-consumer event loop.
pub struct CoordinatorService<E: NnBatchExecutor> {
    pub exec: E,
    pub trigger: TriggerCondition,
    pub output: OutputSelector,
    pub flows: FlowTable,
    pub sink: OutputSink,
    pub stats: ServiceStats,
    batcher: Option<Batcher<PendingFlow>>,
    /// Scratch for batch flushes ((flow id, enqueue ts) per item),
    /// reused across batches.
    batch_meta: Vec<(u64, f64)>,
    batch_inputs: Vec<Vec<u32>>,
    batch_classes: Vec<usize>,
}

impl<E: NnBatchExecutor> CoordinatorService<E> {
    pub fn new(exec: E, trigger: TriggerCondition, output: OutputSelector) -> Self {
        let n_classes = exec.n_classes();
        Self {
            exec,
            trigger,
            output,
            flows: FlowTable::new(1 << 16),
            sink: OutputSink::default(),
            stats: ServiceStats {
                classes: vec![0; n_classes],
                ..Default::default()
            },
            batcher: None,
            batch_meta: Vec::new(),
            batch_inputs: Vec::new(),
            batch_classes: Vec::new(),
        }
    }

    /// Enable batch accumulation: triggered flows queue until `max_size`
    /// or `max_wait_ns` (packet-clock), then take the batch fast path.
    pub fn with_batching(mut self, max_size: usize, max_wait_ns: f64) -> Self {
        self.batcher = Some(Batcher::new(max_size, max_wait_ns));
        self
    }

    /// Triggered flows currently waiting in the batcher.
    pub fn pending(&self) -> usize {
        self.batcher.as_ref().map_or(0, Batcher::pending)
    }

    /// Synchronous single-event path (also the unit the async loop calls).
    pub fn handle(&mut self, ev: &PacketEvent) {
        self.stats.packets += 1;
        // Time-based flush rides on packet arrival: the data plane has no
        // timer thread, so the oldest batched flow is checked against the
        // packet clock (same shape as §3.2's trigger module).
        let timed_out = self
            .batcher
            .as_mut()
            .and_then(|b| b.poll(ev.packet.ts_ns));
        if let Some(batch) = timed_out {
            self.flush_batch(batch, ev.packet.ts_ns);
        }
        let (stats, is_new, pkts) = self.flows.update(&ev.packet);
        if !self.trigger.fires(&ev.packet, is_new, pkts) {
            return;
        }
        self.stats.triggers += 1;
        let packed = select_packed_input(ev, stats);
        let id = flow_id(&ev.packet);
        if self.batcher.is_some() {
            let full = self
                .batcher
                .as_mut()
                .unwrap()
                .push(ev.packet.ts_ns, PendingFlow { id, packed });
            if let Some(batch) = full {
                self.flush_batch(batch, ev.packet.ts_ns);
            }
        } else {
            let class = self.exec.classify(&packed);
            let latency_ns = self.exec.latency_ns();
            self.finish_inference(id, class, latency_ns);
        }
    }

    /// Drain any batched-but-unflushed flows (end of stream / shutdown).
    pub fn flush(&mut self) {
        let batch = self.batcher.as_mut().and_then(|b| b.poll(f64::INFINITY));
        if let Some(batch) = batch {
            // Best "now" available at shutdown: the newest enqueue time.
            let now_ns = batch.last().map_or(0.0, |&(t, _)| t);
            self.flush_batch(batch, now_ns);
        }
    }

    /// Run one accumulated batch through the executor's batch fast path
    /// and account every verdict.  Per-flow latency is the queueing wait
    /// on the packet clock (`now_ns - enqueue`) plus the modeled
    /// completion time of the *whole* batch (every item waits for the
    /// batch to finish) — batching's latency price stays visible in the
    /// histogram (Fig. 6's trade-off) instead of silently vanishing.
    fn flush_batch(&mut self, batch: Vec<(f64, PendingFlow)>, now_ns: f64) {
        self.batch_meta.clear();
        self.batch_inputs.clear();
        for (enq_ns, flow) in batch {
            self.batch_meta.push((flow.id, enq_ns));
            self.batch_inputs.push(flow.packed);
        }
        let inputs = std::mem::take(&mut self.batch_inputs);
        let mut classes = std::mem::take(&mut self.batch_classes);
        self.exec.classify_batch(&inputs, &mut classes);
        let exec_ns = self.exec.batch_latency_ns(classes.len());
        for i in 0..classes.len() {
            let (id, enq_ns) = self.batch_meta[i];
            let latency_ns = batch_item_latency_ns(now_ns, enq_ns, exec_ns);
            self.finish_inference(id, classes[i], latency_ns);
        }
        self.batch_inputs = inputs;
        self.batch_classes = classes;
    }

    /// Account one verdict: stats, histogram (grown on demand), sink.
    fn finish_inference(&mut self, id: u64, class: usize, latency_ns: f64) {
        self.stats.inferences += 1;
        if class >= self.stats.classes.len() {
            self.stats.classes.resize(class + 1, 0);
        }
        self.stats.classes[class] += 1;
        self.stats.latency.record(latency_ns);
        self.sink.write(self.output, id, class);
    }

    /// Event loop: drain an mpsc channel until all senders drop; returns
    /// the accumulated statistics.  Run it on a dedicated thread; the
    /// traffic source(s) feed the channel from other threads (the NIC
    /// event-queue shape).  Any partial batch is flushed at shutdown.
    pub fn run(mut self, rx: mpsc::Receiver<PacketEvent>) -> ServiceStats {
        while let Ok(ev) = rx.recv() {
            self.handle(&ev);
        }
        self.flush();
        self.stats
    }
}

/// One verdict from the registry route, with the `(name, version)` it
/// ran under.
#[derive(Debug, Clone)]
pub struct TaggedVerdict {
    pub id: u64,
    pub class: usize,
    pub tag: VersionTag,
}

/// The registry-routed counterpart of [`CoordinatorService`]: flows are
/// routed to **named models** by a [`ModelRouter`], classified by a
/// [`MultiModelExecutor`] that pins one registry epoch per inference (or
/// per batch — per-model batch lanes never mix models), and every
/// verdict carries its [`VersionTag`].  Live `publish`es through the
/// shared [`RegistryHandle`] hot-swap weights between batches without
/// this loop ever pausing.
pub struct MultiModelService {
    pub router: ModelRouter,
    pub exec: MultiModelExecutor,
    pub flows: FlowTable,
    pub sink: OutputSink,
    pub stats: ServiceStats,
    /// Every verdict with its version tag, in emission order.  Grows
    /// for the life of the run — the consistency harness needs the full
    /// log; long-running serves disable it with
    /// [`without_tag_log`](Self::without_tag_log) (per-model histograms
    /// in [`ServiceStats::per_model`] stay complete either way).
    pub tagged: Vec<TaggedVerdict>,
    log_tags: bool,
    registry: RegistryHandle,
    output: OutputSelector,
    /// Route-indexed per-model accounting, folded into the name-keyed
    /// [`ServiceStats::per_model`] map at flush time — the hot path
    /// indexes a `Vec` instead of allocating a key for a map lookup.
    per_model_scratch: Vec<ModelServiceStats>,
    batchers: Option<BatchSet<PendingFlow>>,
    /// Scratch reused across batch flushes.
    batch_meta: Vec<(u64, f64)>,
    batch_inputs: Vec<Vec<u32>>,
    batch_classes: Vec<usize>,
}

impl MultiModelService {
    /// Bind the router's model names against `registry` (each must be
    /// published).  `latency_ns` is the modeled per-inference device
    /// latency, as in [`CoreExecutor::new`](super::CoreExecutor::new).
    pub fn new(
        registry: RegistryHandle,
        router: ModelRouter,
        output: OutputSelector,
        latency_ns: f64,
    ) -> Result<Self, RegistryError> {
        let exec = MultiModelExecutor::new(&registry, router.model_names(), latency_ns)?;
        let n_classes = exec.max_out_neurons();
        let n_models = router.n_models();
        Ok(Self {
            router,
            exec,
            flows: FlowTable::new(1 << 16),
            sink: OutputSink::default(),
            stats: ServiceStats {
                classes: vec![0; n_classes],
                ..Default::default()
            },
            tagged: Vec::new(),
            log_tags: true,
            registry,
            output,
            per_model_scratch: vec![ModelServiceStats::default(); n_models],
            batchers: None,
            batch_meta: Vec::new(),
            batch_inputs: Vec::new(),
            batch_classes: Vec::new(),
        })
    }

    /// Per-model batch lanes: triggered flows queue in their model's
    /// lane until `max_size` or `max_wait_ns` (packet-clock), then the
    /// whole lane-batch scores under one pinned epoch.
    pub fn with_batching(mut self, max_size: usize, max_wait_ns: f64) -> Self {
        self.batchers = Some(BatchSet::new(self.router.n_models(), max_size, max_wait_ns));
        self
    }

    /// Spread batches over a sharded engine of `n_shards` worker cores
    /// (each batch still pins exactly one epoch across all shards).
    pub fn with_shards(mut self, n_shards: usize) -> Self {
        self.exec = self.exec.sharded(n_shards);
        self
    }

    /// Drop the unbounded per-verdict tag log (production-shaped runs:
    /// memory stays flat; per-model stats and the sink are unaffected).
    pub fn without_tag_log(mut self) -> Self {
        self.log_tags = false;
        self
    }

    /// Flows currently waiting across all batch lanes.
    pub fn pending(&self) -> usize {
        self.batchers.as_ref().map_or(0, BatchSet::pending)
    }

    /// Synchronous single-event path (same shape as
    /// [`CoordinatorService::handle`]).
    pub fn handle(&mut self, ev: &PacketEvent) {
        self.stats.packets += 1;
        let due = match self.batchers.as_mut() {
            Some(b) => b.poll(ev.packet.ts_ns),
            None => Vec::new(),
        };
        for (lane, batch) in due {
            self.flush_batch(lane, batch, ev.packet.ts_ns);
        }
        let (stats, is_new, pkts) = self.flows.update(&ev.packet);
        let Some(route) = self.router.route(&ev.packet, is_new, pkts) else {
            return;
        };
        self.stats.triggers += 1;
        let packed = select_packed_input(ev, stats);
        let id = flow_id(&ev.packet);
        if self.batchers.is_some() {
            let full = self
                .batchers
                .as_mut()
                .unwrap()
                .push(route, ev.packet.ts_ns, PendingFlow { id, packed });
            if let Some(batch) = full {
                self.flush_batch(route, batch, ev.packet.ts_ns);
            }
        } else {
            let (class, tag) = self.exec.classify(route, &packed);
            let latency_ns = self.exec.latency_ns();
            self.finish_inference(route, id, class, tag, latency_ns);
        }
    }

    /// Drain every batch lane (end of stream / shutdown) and snapshot
    /// per-model swap counts from the registry.
    pub fn flush(&mut self) {
        let due = match self.batchers.as_mut() {
            Some(b) => b.poll(f64::INFINITY),
            None => Vec::new(),
        };
        for (lane, batch) in due {
            let now_ns = batch.last().map_or(0.0, |&(t, _)| t);
            self.flush_batch(lane, batch, now_ns);
        }
        self.snapshot_swaps();
    }

    /// Fold the route-indexed scratch into the name-keyed
    /// [`ServiceStats::per_model`] map and refresh each routed model's
    /// swap count from the live registry.  Draining the scratch makes
    /// repeated flushes safe (nothing is double-counted).
    pub fn snapshot_swaps(&mut self) {
        for (route, scratch) in self.per_model_scratch.iter_mut().enumerate() {
            let name = &self.router.model_names()[route];
            let entry = self.stats.per_model.entry(name.clone()).or_default();
            entry.inferences += scratch.inferences;
            if scratch.classes.len() > entry.classes.len() {
                entry.classes.resize(scratch.classes.len(), 0);
            }
            for (a, b) in entry.classes.iter_mut().zip(&scratch.classes) {
                *a += b;
            }
            entry.swaps = self.registry.swap_count(name);
            *scratch = ModelServiceStats::default();
        }
    }

    /// Score one lane's batch under a single pinned epoch and account
    /// every verdict (latency semantics shared with the single-model
    /// loop via [`batch_item_latency_ns`]).
    fn flush_batch(&mut self, lane: usize, batch: TimedBatch<PendingFlow>, now_ns: f64) {
        self.batch_meta.clear();
        self.batch_inputs.clear();
        for (enq_ns, flow) in batch {
            self.batch_meta.push((flow.id, enq_ns));
            self.batch_inputs.push(flow.packed);
        }
        let inputs = std::mem::take(&mut self.batch_inputs);
        let mut classes = std::mem::take(&mut self.batch_classes);
        let tag = self.exec.classify_batch(lane, &inputs, &mut classes);
        let exec_ns = self.exec.batch_latency_ns(classes.len());
        for i in 0..classes.len() {
            let (id, enq_ns) = self.batch_meta[i];
            let latency_ns = batch_item_latency_ns(now_ns, enq_ns, exec_ns);
            self.finish_inference(lane, id, classes[i], tag.clone(), latency_ns);
        }
        self.batch_inputs = inputs;
        self.batch_classes = classes;
    }

    fn finish_inference(
        &mut self,
        route: usize,
        id: u64,
        class: usize,
        tag: VersionTag,
        latency_ns: f64,
    ) {
        self.stats.inferences += 1;
        if class >= self.stats.classes.len() {
            self.stats.classes.resize(class + 1, 0);
        }
        self.stats.classes[class] += 1;
        // Route-indexed: no key allocation, no map walk per verdict.
        self.per_model_scratch[route].record(class);
        self.stats.latency.record(latency_ns);
        self.sink.write(self.output, id, class);
        if self.log_tags {
            self.tagged.push(TaggedVerdict { id, class, tag });
        }
    }

    /// Event loop: drain the channel until all senders drop; flushes and
    /// returns the accumulated statistics plus the tagged verdict log.
    pub fn run(mut self, rx: mpsc::Receiver<PacketEvent>) -> (ServiceStats, Vec<TaggedVerdict>) {
        while let Ok(ev) = rx.recv() {
            self.handle(&ev);
        }
        self.flush();
        (self.stats, self.tagged)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::BnnModel;
    use crate::coordinator::CoreExecutor;
    use crate::net::traffic::{CbrSpec, TrafficGen};

    fn service() -> CoordinatorService<CoreExecutor> {
        let model = BnnModel::random("traffic", 256, &[32, 16, 2], 1);
        CoordinatorService::new(
            CoreExecutor::fpga(model),
            TriggerCondition::EveryNPackets(10),
            OutputSelector::Memory,
        )
    }

    #[test]
    fn trigger_fires_once_per_flow_at_10_packets() {
        let mut svc = service();
        let mut gen = TrafficGen::new(CbrSpec { gbps: 10.0, pkt_size: 256 }, 50, 3);
        for _ in 0..5000 {
            let p = gen.next_packet();
            svc.handle(&PacketEvent { packet: p, payload_words: None });
        }
        assert_eq!(svc.stats.packets, 5000);
        assert!(svc.stats.triggers > 0);
        assert_eq!(svc.stats.triggers, svc.stats.inferences);
        // Every verdict was written to memory (the configured selector).
        assert_eq!(svc.sink.memory.len() as u64, svc.stats.inferences);
        assert!(svc.sink.inline_tags.is_empty());
        // Each flow triggers at most once (exactly at packet #10).
        assert!(svc.stats.triggers <= 50);
    }

    #[test]
    fn event_loop_drains_channel() {
        let svc = service();
        let (tx, rx) = mpsc::channel();
        let mut gen = TrafficGen::new(CbrSpec { gbps: 10.0, pkt_size: 256 }, 10, 4);
        let feeder = std::thread::spawn(move || {
            for _ in 0..500 {
                let p = gen.next_packet();
                tx.send(PacketEvent { packet: p, payload_words: None }).unwrap();
            }
        });
        let consumer = std::thread::spawn(move || svc.run(rx));
        feeder.join().unwrap();
        let stats = consumer.join().unwrap();
        assert_eq!(stats.packets, 500);
    }

    #[test]
    fn histogram_width_comes_from_model() {
        let svc = service();
        // traffic model has 2 output neurons → 2 counters, not 8.
        assert_eq!(svc.stats.classes.len(), 2);
    }

    #[test]
    fn batched_route_matches_unbatched() {
        let mut gen = TrafficGen::new(CbrSpec { gbps: 10.0, pkt_size: 256 }, 40, 6);
        let events: Vec<PacketEvent> = (0..4000)
            .map(|_| PacketEvent { packet: gen.next_packet(), payload_words: None })
            .collect();
        let mut plain = service();
        for ev in &events {
            plain.handle(ev);
        }
        let mut batched = service().with_batching(7, 1e12);
        for ev in &events {
            batched.handle(ev);
        }
        batched.flush();
        assert_eq!(batched.pending(), 0);
        assert_eq!(batched.stats.triggers, plain.stats.triggers);
        assert_eq!(batched.stats.inferences, plain.stats.inferences);
        assert_eq!(batched.stats.classes, plain.stats.classes);
        // Same verdicts for the same flows, order aside.
        let mut a = plain.sink.memory.clone();
        let mut b = batched.sink.memory.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn stats_merge_accumulates_and_grows() {
        let mut a = ServiceStats {
            packets: 10,
            triggers: 2,
            inferences: 2,
            classes: vec![1, 1],
            stage_blocked: vec![3],
            ..Default::default()
        };
        a.latency.record(100.0);
        let mut b = ServiceStats {
            packets: 5,
            triggers: 1,
            inferences: 1,
            classes: vec![0, 0, 7],
            stage_blocked: vec![1, 4],
            ..Default::default()
        };
        b.latency.record(900.0);
        a.merge(&b);
        assert_eq!(a.packets, 15);
        assert_eq!(a.triggers, 3);
        assert_eq!(a.inferences, 3);
        assert_eq!(a.classes, vec![1, 1, 7]);
        assert_eq!(a.stage_blocked, vec![4, 4]);
        assert_eq!(a.latency.count(), 2);
    }

    #[test]
    fn per_model_stats_merge_keywise_grow_and_max_swaps() {
        let mut a = ServiceStats::default();
        a.per_model.insert(
            "anomaly".into(),
            ModelServiceStats { inferences: 3, classes: vec![2, 1], swaps: 4 },
        );
        a.per_model.insert(
            "tomography".into(),
            ModelServiceStats { inferences: 1, classes: vec![1], swaps: 0 },
        );
        let mut b = ServiceStats::default();
        // Same slot seen by another stage: counts add, histogram grows,
        // swap snapshots of the shared counter take the max (not the
        // sum — both stages read the same registry slot).
        b.per_model.insert(
            "anomaly".into(),
            ModelServiceStats { inferences: 2, classes: vec![0, 1, 5], swaps: 2 },
        );
        // A slot only the other stage routed.
        b.per_model.insert(
            "traffic-class".into(),
            ModelServiceStats { inferences: 7, classes: vec![7], swaps: 1 },
        );
        a.merge(&b);
        assert_eq!(
            a.per_model["anomaly"],
            ModelServiceStats { inferences: 5, classes: vec![2, 2, 5], swaps: 4 }
        );
        assert_eq!(
            a.per_model["tomography"],
            ModelServiceStats { inferences: 1, classes: vec![1], swaps: 0 }
        );
        assert_eq!(
            a.per_model["traffic-class"],
            ModelServiceStats { inferences: 7, classes: vec![7], swaps: 1 }
        );
        // Merging an empty map changes nothing.
        let snapshot = a.per_model.clone();
        a.merge(&ServiceStats::default());
        assert_eq!(a.per_model, snapshot);
    }

    fn two_model_registry() -> (RegistryHandle, ModelRouter) {
        let h = RegistryHandle::new();
        h.publish("anomaly", &BnnModel::random("anomaly", 256, &[32, 16, 2], 21))
            .unwrap();
        h.publish("traffic-class", &BnnModel::random("traffic-class", 256, &[32, 16, 2], 22))
            .unwrap();
        let router = ModelRouter::hash_split(
            TriggerCondition::EveryNPackets(10),
            vec!["anomaly".into(), "traffic-class".into()],
        );
        (h, router)
    }

    #[test]
    fn routed_service_tags_every_verdict_and_accounts_per_model() {
        let (h, router) = two_model_registry();
        let mut svc =
            MultiModelService::new(h.clone(), router, OutputSelector::Memory, 100.0).unwrap();
        let mut gen = TrafficGen::new(CbrSpec { gbps: 10.0, pkt_size: 256 }, 60, 5);
        for _ in 0..6000 {
            let p = gen.next_packet();
            svc.handle(&PacketEvent { packet: p, payload_words: None });
        }
        svc.flush();
        assert!(svc.stats.triggers > 0);
        assert_eq!(svc.stats.triggers, svc.stats.inferences);
        assert_eq!(svc.tagged.len() as u64, svc.stats.inferences);
        assert_eq!(svc.sink.memory.len() as u64, svc.stats.inferences);
        // No publishes happened: every tag is version 1, swaps are 0.
        for t in &svc.tagged {
            assert_eq!(t.tag.version(), 1);
        }
        let pm = &svc.stats.per_model;
        assert_eq!(pm.len(), 2);
        assert_eq!(
            pm.values().map(|m| m.inferences).sum::<u64>(),
            svc.stats.inferences
        );
        for m in pm.values() {
            assert_eq!(m.swaps, 0);
        }
        // Per-model histograms sum to the global one.
        let mut summed = vec![0u64; svc.stats.classes.len()];
        for m in pm.values() {
            for (i, &c) in m.classes.iter().enumerate() {
                summed[i] += c;
            }
        }
        assert_eq!(summed, svc.stats.classes);
    }

    #[test]
    fn routed_batched_route_matches_unbatched_and_survives_hot_swap() {
        let (h, router) = two_model_registry();
        let mut gen = TrafficGen::new(CbrSpec { gbps: 10.0, pkt_size: 256 }, 40, 6);
        let events: Vec<PacketEvent> = (0..4000)
            .map(|_| PacketEvent { packet: gen.next_packet(), payload_words: None })
            .collect();
        let mut plain =
            MultiModelService::new(h.clone(), router.clone(), OutputSelector::Memory, 100.0)
                .unwrap();
        for ev in &events {
            plain.handle(ev);
        }
        plain.flush();
        let mut batched =
            MultiModelService::new(h.clone(), router, OutputSelector::Memory, 100.0)
                .unwrap()
                .with_batching(7, 1e12)
                .with_shards(3);
        for ev in &events {
            batched.handle(ev);
        }
        batched.flush();
        assert_eq!(batched.pending(), 0);
        assert_eq!(batched.stats.triggers, plain.stats.triggers);
        assert_eq!(batched.stats.classes, plain.stats.classes);
        assert_eq!(batched.stats.per_model, plain.stats.per_model);
        let mut a = plain.sink.memory.clone();
        let mut b = batched.sink.memory.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
        // Hot-swap both slots with the *same* weights mid-stream: a
        // fresh run's verdicts are bit-identical, but tags move to v2
        // and swap counts show up in the per-model stats.
        let mut swapped =
            MultiModelService::new(h.clone(), ModelRouter::hash_split(
                TriggerCondition::EveryNPackets(10),
                vec!["anomaly".into(), "traffic-class".into()],
            ), OutputSelector::Memory, 100.0)
            .unwrap();
        for (i, ev) in events.iter().enumerate() {
            if i == events.len() / 2 {
                h.publish("anomaly", &BnnModel::random("anomaly", 256, &[32, 16, 2], 21))
                    .unwrap();
                h.publish(
                    "traffic-class",
                    &BnnModel::random("traffic-class", 256, &[32, 16, 2], 22),
                )
                .unwrap();
            }
            swapped.handle(ev);
        }
        swapped.flush();
        assert_eq!(swapped.stats.classes, plain.stats.classes);
        assert!(swapped.tagged.iter().any(|t| t.tag.version() == 1));
        assert!(swapped.tagged.iter().any(|t| t.tag.version() == 2));
        for m in swapped.stats.per_model.values() {
            assert_eq!(m.swaps, 1);
        }
    }

    #[test]
    fn batcher_timeout_flushes_on_packet_clock() {
        // Huge batch size, tiny timeout: flows must still drain.
        let mut svc = service().with_batching(1 << 20, 1.0);
        let mut gen = TrafficGen::new(CbrSpec { gbps: 10.0, pkt_size: 256 }, 5, 8);
        for _ in 0..2000 {
            let p = gen.next_packet();
            svc.handle(&PacketEvent { packet: p, payload_words: None });
        }
        svc.flush();
        assert_eq!(svc.stats.inferences, svc.stats.triggers);
    }
}

//! The unified serving runtime: **one** [`Service`], built by a
//! [`ServeBuilder`].  Pipelining, batching, multi-model routing, hot
//! swap, and overload control are orthogonal options on this one
//! runtime instead of a product of structs:
//!
//! ```text
//! ServeBuilder::new()
//!     .backend(BackendFactory::single("fpga", model)?)  // any InferencePlane
//!     .trigger(TriggerCondition::EveryNPackets(10))     // or .router(rules)
//!     .batching(32, 1e6)                                // optional
//!     .pipeline(4)                                      // optional (0 = serial)
//!     .queue_depth(1024)
//!     .swap_every(100_000)                              // hot-swap backends only
//!     .build()?
//!     .run(events)?
//! ```
//!
//! The builder validates the configuration against the backend's
//! [`Capabilities`] (batch width, route count, hot-swap support) at
//! build time, so a misconfiguration is a typed [`ServiceError`] instead
//! of a mid-serve panic.
//!
//! `workers == 0` (the default) runs the single-threaded event loop on
//! the calling thread; `workers >= 1` runs the staged pipeline of
//! [`pipeline`](super::pipeline).  Both modes share this module's
//! routing/batching/accounting primitives, and the determinism contract
//! (same seeded traffic ⇒ bit-identical verdicts, any worker count or
//! batch size) is asserted end-to-end in `tests/pipeline_equiv.rs` and
//! `tests/plane_conformance.rs`.

use std::collections::BTreeMap;

use crate::bnn::{EngineError, RegistryError, VersionTag};
use crate::learn::{AccuracyWindow, LearnSpec, LearnStats, OnlineLearner};
use crate::metrics::LatencyHistogram;
use crate::net::features::FeatureVector;
use crate::net::flow::{EvictPolicy, FlowStats, FlowTableStats, ShardedFlowTable, FLOW_SHARDS};
use crate::net::packet::Packet;
use crate::net::traffic::{CbrSpec, TrafficGen};

use super::admin::{AdminHandle, SNAPSHOT_EVERY};
use super::batcher::{BatchSet, TimedBatch};
use super::overload::{
    AdmissionController, DegradationEvent, DegradeSpec, FaultPlan, OverloadControl, PlaneHealth,
    ShedPolicy, SupervisorPolicy,
};
use super::plane::{Capabilities, InferencePlane, SwapController};
use super::selector::{OutputSelector, OutputSink};
use super::trigger::{ModelRouter, TriggerCondition};

/// One event entering the coordinator (a received packet).
#[derive(Debug, Clone)]
pub struct PacketEvent {
    pub packet: Packet,
    /// Optional inline payload words (probe vectors etc.).
    pub payload_words: Option<Vec<u32>>,
}

impl PacketEvent {
    /// `n` payload-less events from a seeded CBR generator — the
    /// traffic shape every serving test and bench drives with.
    pub fn cbr_burst(spec: CbrSpec, flows: u64, seed: u64, n: usize) -> Vec<PacketEvent> {
        let mut gen = TrafficGen::new(spec, flows, seed);
        (0..n)
            .map(|_| PacketEvent { packet: gen.next_packet(), payload_words: None })
            .collect()
    }
}

/// A triggered flow waiting in the batcher: its routing id + packed input.
#[derive(Debug, Clone)]
pub struct PendingFlow {
    pub id: u64,
    pub packed: Vec<u32>,
}

/// Routing id of a flow event — the verdict's key in the sink.  One
/// definition shared by the serial loop and the pipelined runtime: the
/// two must stay bit-identical (the determinism contract), so neither
/// may grow its own copy.
#[inline]
pub(crate) fn flow_id(p: &Packet) -> u64 {
    ((p.src_ip as u64) << 32) | p.dst_ip as u64
}

/// Input selection shared by both runtimes: inline payload words if the
/// event carries them, else the packed flow features.
pub(crate) fn select_packed_input(ev: &PacketEvent, stats: &FlowStats) -> Vec<u32> {
    match &ev.payload_words {
        Some(w) => w.clone(),
        None => FeatureVector::from_stats(stats).pack().to_vec(),
    }
}

/// Latency of one batched item: packet-clock queueing wait plus the
/// whole batch's modeled completion time (every item waits for the
/// batch to finish) — shared by both runtimes' flush paths.
#[inline]
pub(crate) fn batch_item_latency_ns(now_ns: f64, enq_ns: f64, exec_ns: f64) -> f64 {
    (now_ns - enq_ns).max(0.0) + exec_ns
}

/// Aggregate statistics of a service run.
#[derive(Debug, Default, Clone)]
pub struct ServiceStats {
    pub packets: u64,
    pub triggers: u64,
    pub inferences: u64,
    /// Verdict histogram, sized from the backend's model and grown on
    /// demand if a verdict ever exceeds it.
    pub classes: Vec<u64>,
    pub latency: LatencyHistogram,
    /// Bounded-channel backpressure in the pipelined runtime: how many
    /// sends found the downstream queue full and had to wait, indexed by
    /// inter-stage link (see `coordinator::pipeline::STAGE_LINKS`).
    /// Empty in the serial loop, which has no queues.
    pub stage_blocked: Vec<u64>,
    /// Triggers shed by the admission controller (or suppressed in
    /// trigger-only degradation) instead of being inferred.  Always 0
    /// without a `.shed(...)` / `.degrade(...)` policy.
    pub sheds: u64,
    /// Supervised stage restarts consumed across the run.  Always 0
    /// without a `.supervise(...)` policy.
    pub restarts: u64,
    /// Per-model accounting on routed (multi-model) backends, keyed by
    /// slot name.  Empty in single-model serving.
    pub per_model: BTreeMap<String, ModelServiceStats>,
    /// Flow-table degradation accounting (evictions, aged-out flows,
    /// collision probes, untracked packets, probe histogram, occupancy),
    /// merged over every shard — and over every worker's shards in the
    /// pipelined mode.
    pub flow_table: FlowTableStats,
    /// Closed labeled-accuracy windows of the online learner, in packet
    /// order.  Empty unless `.online_learn(...)` armed the loop.
    pub accuracy_timeline: Vec<AccuracyWindow>,
    /// Online-learning loop counters (`None` when learning is off).
    pub learn: Option<LearnStats>,
}

/// One routed model's share of a run: its verdict histogram plus the
/// hot swaps its registry slot absorbed while the run was live.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ModelServiceStats {
    pub inferences: u64,
    /// Verdict histogram for this model, grown on demand.
    pub classes: Vec<u64>,
    /// Registry swap count for this slot, snapshotted at report time.
    /// Merging takes the max: parallel stages snapshot the *same* slot
    /// counter, so adding would double-count.
    pub swaps: u64,
}

impl ModelServiceStats {
    /// Account one verdict (shared by the serial and pipelined routed
    /// sinks).
    pub(crate) fn record(&mut self, class: usize) {
        self.inferences += 1;
        if class >= self.classes.len() {
            self.classes.resize(class + 1, 0);
        }
        self.classes[class] += 1;
    }

    /// Fold another accounting of the same model into this one:
    /// inference counts add, histograms merge bucket-wise growing to
    /// the wider of the two.  Swap counts are *not* folded here — they
    /// are snapshots of one shared registry counter, and each call site
    /// owns its own snapshot/merge policy.
    pub(crate) fn absorb(&mut self, other: &ModelServiceStats) {
        self.inferences += other.inferences;
        if other.classes.len() > self.classes.len() {
            self.classes.resize(other.classes.len(), 0);
        }
        for (a, b) in self.classes.iter_mut().zip(&other.classes) {
            *a += b;
        }
    }
}

impl ServiceStats {
    /// Fold another stage's (or shard's) counters into this one — the
    /// pipeline's join step.  Merge semantics are explicit per field,
    /// because the fields mean different things:
    ///
    /// * **partition counters** (each side counted disjoint work): add —
    ///   `packets`, `triggers`, `inferences`, `sheds`, `restarts`, the
    ///   `classes`/`stage_blocked` histograms (grown to the wider), the
    ///   latency histogram, per-model inference/verdict counts, and the
    ///   flow-table accounting.
    /// * **shared-counter snapshots** (both sides read the *same* live
    ///   counter): max — `per_model[..].swaps` is a report-time snapshot
    ///   of one registry slot's swap count, so adding would double-count
    ///   every retrain-driven republish once per merging stage.  Max is
    ///   exact for monotone counters: the later snapshot contains every
    ///   swap the earlier one saw.
    /// * **singleton telemetry** (exactly one side ever produces it):
    ///   take/fold — the learner timeline concatenates then restores
    ///   packet order, and `learn` folds via [`LearnStats::merge`]
    ///   (counts add, `drift_fired_at` takes the earliest).
    ///
    /// `tests` pins each rule (`stats_merge_semantics_are_per_field`).
    pub fn merge(&mut self, other: &ServiceStats) {
        // Partition counters: add.
        self.packets += other.packets;
        self.triggers += other.triggers;
        self.inferences += other.inferences;
        self.sheds += other.sheds;
        self.restarts += other.restarts;
        if other.classes.len() > self.classes.len() {
            self.classes.resize(other.classes.len(), 0);
        }
        for (a, b) in self.classes.iter_mut().zip(&other.classes) {
            *a += b;
        }
        self.latency.merge(&other.latency);
        if other.stage_blocked.len() > self.stage_blocked.len() {
            self.stage_blocked.resize(other.stage_blocked.len(), 0);
        }
        for (a, b) in self.stage_blocked.iter_mut().zip(&other.stage_blocked) {
            *a += b;
        }
        for (name, m) in &other.per_model {
            let mine = self.per_model.entry(name.clone()).or_default();
            mine.absorb(m);
            // Shared-counter snapshot: max, not add (see above).
            mine.swaps = mine.swaps.max(m.swaps);
        }
        self.flow_table.merge(&other.flow_table);
        // Singleton telemetry: one learner per service, so at most one
        // side carries these — but the merge is written for the general
        // case anyway.
        if !other.accuracy_timeline.is_empty() {
            self.accuracy_timeline.extend(other.accuracy_timeline.iter().cloned());
            self.accuracy_timeline
                .sort_by(|a, b| (a.end_packet, &a.model).cmp(&(b.end_packet, &b.model)));
        }
        if let Some(b) = &other.learn {
            self.learn.get_or_insert_with(LearnStats::default).merge(b);
        }
    }
}

/// One verdict from an epoch-pinning backend, with the `(name, version)`
/// it ran under.
#[derive(Debug, Clone)]
pub struct TaggedVerdict {
    pub id: u64,
    pub class: usize,
    pub tag: VersionTag,
}

/// What a completed (or faulted) service run leaves behind.
#[derive(Debug, Default)]
pub struct ServiceReport {
    pub stats: ServiceStats,
    /// Verdicts in emission order (inference-completion order in the
    /// pipelined mode).
    pub sink: OutputSink,
    /// Every tagged verdict, in emission order — only populated by
    /// epoch-pinning backends with the tag log enabled.
    pub tagged: Vec<TaggedVerdict>,
    /// Live flows tracked at shutdown (summed over worker shards in the
    /// pipelined mode).
    pub flows_tracked: usize,
    /// Sharded-engine counters, if the backend's batch path ran one.
    pub engine: Option<crate::bnn::EngineStats>,
    /// Degradation-ladder timeline: every step-down/step-up the run
    /// performed, in packet order.  Empty without `.degrade(...)` (and
    /// in clean runs that never came under pressure).
    pub degradation: Vec<DegradationEvent>,
    /// Per-member breaker/failover counters, on placement backends.
    pub health: Option<Vec<PlaneHealth>>,
}

/// One stage-level fault of a pipelined run — the typed replacement of
/// the old string-only failure lists.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StageFailure {
    /// Ingress could not reach a parse worker (its thread died).
    IngressUnreachable { worker: usize },
    /// A parse worker found the inference channel closed.
    ParseDisconnected { worker: usize },
    /// The inference stage found the sink channel closed.
    SinkDisconnected,
    /// The backend's batch path failed (dead or panicked shard worker).
    Inference(EngineError),
    /// A `.swap_every(n)` republish failed mid-run.
    Swap(RegistryError),
    /// A learner publish barrier could not complete (a stage died while
    /// ingress waited for the lanes to drain); the staged registry
    /// write was abandoned.
    BarrierLost,
    /// A stage thread panicked; the payload text is preserved.
    Panicked { stage: &'static str, message: String },
    /// A supervised stage kept dying until its restart budget ran out;
    /// the last failure's text is preserved.
    RestartsExhausted {
        stage: &'static str,
        restarts: u32,
        last: String,
    },
}

impl std::fmt::Display for StageFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StageFailure::IngressUnreachable { worker } => {
                write!(f, "ingress: parse worker {worker} unreachable")
            }
            StageFailure::ParseDisconnected { worker } => {
                write!(f, "parse worker {worker}: inference channel disconnected")
            }
            StageFailure::SinkDisconnected => {
                write!(f, "inference stage: sink channel disconnected")
            }
            StageFailure::Inference(e) => write!(f, "inference stage: {e}"),
            StageFailure::Swap(e) => write!(f, "hot-swap republish failed: {e}"),
            StageFailure::BarrierLost => {
                write!(f, "learner publish barrier lost: a stage died before acking")
            }
            StageFailure::Panicked { stage, message } => {
                write!(f, "{stage} panicked: {message}")
            }
            StageFailure::RestartsExhausted { stage, restarts, last } => {
                write!(f, "{stage}: supervisor gave up after {restarts} restart(s); last: {last}")
            }
        }
    }
}

/// Failure modes along the serve path — one typed enum from builder
/// validation through backend construction to stage death, replacing
/// the previous per-runtime string errors.
#[derive(Debug)]
pub enum ServiceError {
    /// One or more pipeline stages died.  Everything accumulated before
    /// the fault — stats, sink, tagged verdicts — survives in `report`.
    Stage {
        failures: Vec<StageFailure>,
        report: Box<ServiceReport>,
    },
    /// Registry binding or publish failed.
    Registry(RegistryError),
    /// A backend's batch path failed outside a pipeline stage.
    Engine(EngineError),
    /// The `pisa` backend's model does not fit the PISA target.
    Compile(crate::pisa::CompileError),
    /// No backend registered under this name.
    UnknownBackend { name: String },
    /// The builder configuration contradicts the backend's
    /// [`Capabilities`] (or is incomplete).
    Config(String),
    /// One specific option carries an invalid value (the strict
    /// contract: reject at build time, never silently clamp).
    InvalidConfig {
        option: &'static str,
        reason: String,
    },
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::Stage { failures, .. } => {
                let list: Vec<String> = failures.iter().map(ToString::to_string).collect();
                write!(f, "pipeline stage failure: {}", list.join("; "))
            }
            ServiceError::Registry(e) => write!(f, "registry: {e}"),
            ServiceError::Engine(e) => write!(f, "engine: {e}"),
            ServiceError::Compile(e) => write!(f, "pisa compile: {e}"),
            ServiceError::UnknownBackend { name } => write!(
                f,
                "unknown backend {name:?} (known: host|batch|sharded|pisa|fpga|placed|registry; \
                 aliases: nfp, p4, bnn-exec)"
            ),
            ServiceError::Config(msg) => write!(f, "service configuration: {msg}"),
            ServiceError::InvalidConfig { option, reason } => {
                write!(f, "service configuration: {option}: {reason}")
            }
        }
    }
}

impl std::error::Error for ServiceError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ServiceError::Registry(e) => Some(e),
            ServiceError::Engine(e) => Some(e),
            ServiceError::Compile(e) => Some(e),
            _ => None,
        }
    }
}

impl From<RegistryError> for ServiceError {
    fn from(e: RegistryError) -> Self {
        ServiceError::Registry(e)
    }
}

impl From<EngineError> for ServiceError {
    fn from(e: EngineError) -> Self {
        ServiceError::Engine(e)
    }
}

impl From<crate::pisa::CompileError> for ServiceError {
    fn from(e: crate::pisa::CompileError) -> Self {
        ServiceError::Compile(e)
    }
}

/// How triggered flows pick their route: a bare [`TriggerCondition`]
/// (single-model, route 0) or a [`ModelRouter`] (named multi-model
/// routes).  Both are pure per-flow functions — the property the
/// pipelined runtime's determinism rests on.
#[derive(Debug, Clone)]
pub(crate) enum RouteLogic {
    Trigger(TriggerCondition),
    Router(ModelRouter),
}

impl RouteLogic {
    #[inline]
    pub(crate) fn route(&self, pkt: &Packet, is_new_flow: bool, flow_pkts: u32) -> Option<usize> {
        match self {
            RouteLogic::Trigger(t) => t.fires(pkt, is_new_flow, flow_pkts).then_some(0),
            RouteLogic::Router(r) => r.route(pkt, is_new_flow, flow_pkts),
        }
    }

    pub(crate) fn n_routes(&self) -> usize {
        match self {
            RouteLogic::Trigger(_) => 1,
            RouteLogic::Router(r) => r.n_models(),
        }
    }

    /// Route-indexed model names, when this logic routes by name.
    pub(crate) fn names(&self) -> Option<&[String]> {
        match self {
            RouteLogic::Trigger(_) => None,
            RouteLogic::Router(r) => Some(r.model_names()),
        }
    }
}

/// Builder of the one [`Service`]: pick a backend, then compose routing,
/// batching, pipelining, and hot swap as independent options.  `build`
/// cross-checks every knob against the backend's [`Capabilities`].
pub struct ServeBuilder {
    plane: Option<Box<dyn InferencePlane>>,
    route: RouteLogic,
    output: OutputSelector,
    batch: usize,
    max_wait_ns: f64,
    workers: usize,
    queue_depth: usize,
    flow_capacity: usize,
    evict: EvictPolicy,
    log_tags: bool,
    swap_every: u64,
    shed: Option<ShedPolicy>,
    degrade: Option<DegradeSpec>,
    supervisor: Option<SupervisorPolicy>,
    faults: Option<FaultPlan>,
    admin: Option<AdminHandle>,
    learn: Option<LearnSpec>,
}

impl Default for ServeBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeBuilder {
    pub fn new() -> Self {
        Self {
            plane: None,
            route: RouteLogic::Trigger(TriggerCondition::EveryNPackets(10)),
            output: OutputSelector::Memory,
            batch: 0,
            max_wait_ns: 1e6,
            workers: 0,
            queue_depth: 1024,
            flow_capacity: 1 << 16,
            evict: EvictPolicy::Lru,
            log_tags: true,
            swap_every: 0,
            shed: None,
            degrade: None,
            supervisor: None,
            faults: None,
            admin: None,
            learn: None,
        }
    }

    /// The inference backend — anything implementing [`InferencePlane`],
    /// usually from [`BackendFactory`](super::BackendFactory).
    pub fn backend(mut self, plane: Box<dyn InferencePlane>) -> Self {
        self.plane = Some(plane);
        self
    }

    /// Single-model trigger condition (default: every 10th packet of a
    /// flow).  Mutually exclusive with [`router`](Self::router).
    pub fn trigger(mut self, trigger: TriggerCondition) -> Self {
        self.route = RouteLogic::Trigger(trigger);
        self
    }

    /// Multi-model routing rules; the backend must expose exactly as
    /// many routes as the router names.
    pub fn router(mut self, router: ModelRouter) -> Self {
        self.route = RouteLogic::Router(router);
        self
    }

    /// Where verdicts go (default: memory).
    pub fn output(mut self, output: OutputSelector) -> Self {
        self.output = output;
        self
    }

    /// Batch accumulation: triggered flows queue (per route lane) until
    /// `max_size` or `max_wait_ns` on the packet clock, then take the
    /// backend's batch fast path.  `0` classifies inline.
    pub fn batching(mut self, max_size: usize, max_wait_ns: f64) -> Self {
        self.batch = max_size;
        self.max_wait_ns = max_wait_ns;
        self
    }

    /// Staged multi-threaded runtime with `workers` parse/trigger
    /// workers (flow-hash shards).  `0` (default) runs the serial loop
    /// on the calling thread; verdicts are bit-identical either way.
    pub fn pipeline(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Capacity of each bounded inter-stage channel (pipelined mode).
    /// `0` is rejected at [`build`](Self::build) — a zero-slot
    /// `sync_channel` would deadlock rather than apply backpressure.
    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth;
        self
    }

    /// Total flow-table capacity budget for the whole service, split
    /// evenly over the [`FLOW_SHARDS`] logical shards (the same split in
    /// the serial and pipelined modes, so eviction behavior — and thus
    /// every verdict — is independent of the worker count).
    pub fn flow_capacity(mut self, capacity: usize) -> Self {
        self.flow_capacity = capacity;
        self
    }

    /// What the flow table does when a probe window fills: LRU
    /// replacement (default), LRU + idle aging, or the legacy
    /// no-eviction mode that leaves overflow packets untracked.
    pub fn evict(mut self, policy: EvictPolicy) -> Self {
        self.evict = policy;
        self
    }

    /// Drop the unbounded per-verdict tag log (production-shaped runs:
    /// memory stays flat; per-model stats and the sink are unaffected).
    pub fn without_tag_log(mut self) -> Self {
        self.log_tags = false;
        self
    }

    /// Hot-republish one bound slot (round-robin, same weights, new
    /// version) every `packets` packets while serving — the
    /// zero-downtime swap demo.  Requires a hot-swap-capable backend.
    pub fn swap_every(mut self, packets: u64) -> Self {
        self.swap_every = packets;
        self
    }

    /// Admission control: shed triggered work once the modeled backlog
    /// (per parse worker in the pipelined mode) passes the policy's
    /// ceiling, resume below its floor.  Entirely on the packet clock —
    /// shed decisions are deterministic for a given event stream.
    pub fn shed(mut self, policy: ShedPolicy) -> Self {
        self.shed = Some(policy);
        self
    }

    /// Degradation ladder: under sustained pressure step down to a
    /// fallback model (hot-swap backends, when the spec carries one)
    /// and/or trigger-only mode, stepping back up on recovery.  Every
    /// transition is recorded in [`ServiceReport::degradation`].
    pub fn degrade(mut self, spec: DegradeSpec) -> Self {
        self.degrade = Some(spec);
        self
    }

    /// Stage supervision (pipelined mode): a parse/inference/sink stage
    /// that panics or hits a retryable backend fault is restarted with
    /// bounded retry+backoff instead of aborting the run.
    pub fn supervise(mut self, policy: SupervisorPolicy) -> Self {
        self.supervisor = Some(policy);
        self
    }

    /// Test hook: arm deterministic stage faults (see [`FaultPlan`]).
    #[doc(hidden)]
    pub fn inject_faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Attach an admin/introspection handle
    /// ([`AdminHandle`](super::AdminHandle)): `build` binds it with the
    /// backend's capabilities (and registry, when there is one), and the
    /// serving loops keep its health counter and stats snapshot live so
    /// other threads can scrape a running service.
    pub fn admin(mut self, handle: AdminHandle) -> Self {
        self.admin = Some(handle);
        self
    }

    /// Arm the online-learning loop on one bound registry slot: drift
    /// detection on per-window labeled accuracy, in-process retraining
    /// from a bounded labeled reservoir, and gate-guarded republish
    /// with probation rollback (see [`crate::learn`]).  Requires a
    /// hot-swap backend with `spec.model` among its bound slots.
    pub fn online_learn(mut self, spec: LearnSpec) -> Self {
        self.learn = Some(spec);
        self
    }

    /// Validate the configuration against the backend's capabilities.
    pub fn build(self) -> Result<Service, ServiceError> {
        let plane = self
            .plane
            .ok_or_else(|| ServiceError::Config("no backend selected: call .backend(...)".into()))?;
        let caps = plane.capabilities();
        let want_routes = self.route.n_routes();
        if caps.routes != want_routes {
            return Err(ServiceError::Config(format!(
                "backend {:?} serves {} route(s) but the routing config names {}",
                caps.backend, caps.routes, want_routes
            )));
        }
        // Route indices are positional: when both sides carry names,
        // they must agree exactly — a count-only check would let a
        // reordered router silently classify every flow with the wrong
        // model.
        if let Some(router_names) = self.route.names() {
            let plane_names = plane.route_names();
            if !plane_names.is_empty() && plane_names != router_names {
                return Err(ServiceError::Config(format!(
                    "router names {router_names:?} do not match the backend's bound \
                     slots {plane_names:?} (order matters: route index = position)"
                )));
            }
        }
        if self.batch > caps.max_batch {
            return Err(ServiceError::Config(format!(
                "backend {:?} accepts batches of at most {} (asked for {})",
                caps.backend, caps.max_batch, self.batch
            )));
        }
        if self.swap_every > 0 && !caps.supports_hot_swap {
            return Err(ServiceError::Config(format!(
                "backend {:?} does not support hot swap (swap_every needs the registry backend)",
                caps.backend
            )));
        }
        if self.queue_depth == 0 {
            return Err(ServiceError::InvalidConfig {
                option: "queue_depth",
                reason: "bounded stage queues need at least one slot (0 would deadlock \
                         the pipeline rather than apply backpressure)"
                    .into(),
            });
        }
        // Workers own fixed logical flow shards; more workers than
        // shards would leave some workers with no flow state at all and
        // break the shard→worker routing formula.
        if self.workers > FLOW_SHARDS {
            return Err(ServiceError::InvalidConfig {
                option: "pipeline",
                reason: format!(
                    "at most {FLOW_SHARDS} parse workers (one per logical flow shard); \
                     asked for {}",
                    self.workers
                ),
            });
        }
        // A fallback model only makes sense on a hot-swap backend, and it
        // must fit every bound slot's wire shape — the registry would
        // reject the publish mid-run otherwise, turning a graceful
        // step-down into a swap failure under pressure.
        if let Some(fallback) = self.degrade.as_ref().and_then(|d| d.fallback.as_ref()) {
            if !caps.supports_hot_swap {
                return Err(ServiceError::InvalidConfig {
                    option: "degrade",
                    reason: format!(
                        "backend {:?} does not support hot swap; a fallback model needs \
                         the registry backend (trigger-only degradation works everywhere)",
                        caps.backend
                    ),
                });
            }
            let Some(ctl) = plane.swap_controller() else {
                return Err(ServiceError::InvalidConfig {
                    option: "degrade",
                    reason: "backend advertises hot swap but exposes no swap controller"
                        .into(),
                });
            };
            for name in ctl.names() {
                let Some(cur) = ctl.registry().current(name) else {
                    continue;
                };
                if fallback.in_words() != cur.in_words()
                    || fallback.out_neurons() != cur.out_neurons()
                {
                    return Err(ServiceError::InvalidConfig {
                        option: "degrade",
                        reason: format!(
                            "fallback model shape ({} in-words, {} classes) does not \
                             match slot {name:?} ({} in-words, {} classes)",
                            fallback.in_words(),
                            fallback.out_neurons(),
                            cur.in_words(),
                            cur.out_neurons()
                        ),
                    });
                }
            }
        }
        // The learner republishes through the registry, so it needs a
        // hot-swap backend — and the watched slot must actually be bound,
        // or every retrain would fail at publish time instead of here.
        if let Some(spec) = self.learn.as_ref() {
            if !caps.supports_hot_swap {
                return Err(ServiceError::InvalidConfig {
                    option: "online_learn",
                    reason: format!(
                        "backend {:?} does not support hot swap; online learning \
                         republishes through the registry backend",
                        caps.backend
                    ),
                });
            }
            let bound = plane.route_names();
            if !bound.is_empty() && !bound.iter().any(|n| *n == spec.model) {
                return Err(ServiceError::InvalidConfig {
                    option: "online_learn",
                    reason: format!(
                        "model {:?} is not among the bound slots {bound:?}",
                        spec.model
                    ),
                });
            }
        }
        if let Some(a) = self.admin.as_ref() {
            a.bind(caps, plane.swap_controller().map(|c| c.registry().clone()));
        }
        Ok(Service {
            plane,
            route: self.route,
            output: self.output,
            batch: self.batch,
            max_wait_ns: self.max_wait_ns,
            workers: self.workers,
            queue_depth: self.queue_depth,
            flow_capacity: self.flow_capacity,
            evict: self.evict,
            log_tags: self.log_tags,
            swap_every: self.swap_every,
            shed: self.shed,
            degrade: self.degrade,
            supervisor: self.supervisor,
            faults: self.faults,
            admin: self.admin,
            learn: self.learn,
        })
    }
}

/// The one serving runtime.  Constructed by [`ServeBuilder`]; consumed
/// by [`run`](Self::run).
pub struct Service {
    pub(crate) plane: Box<dyn InferencePlane>,
    pub(crate) route: RouteLogic,
    pub(crate) output: OutputSelector,
    pub(crate) batch: usize,
    pub(crate) max_wait_ns: f64,
    pub(crate) workers: usize,
    pub(crate) queue_depth: usize,
    pub(crate) flow_capacity: usize,
    pub(crate) evict: EvictPolicy,
    pub(crate) log_tags: bool,
    pub(crate) swap_every: u64,
    pub(crate) shed: Option<ShedPolicy>,
    pub(crate) degrade: Option<DegradeSpec>,
    pub(crate) supervisor: Option<SupervisorPolicy>,
    pub(crate) faults: Option<FaultPlan>,
    pub(crate) admin: Option<AdminHandle>,
    pub(crate) learn: Option<LearnSpec>,
}

impl Service {
    /// Build the [`OnlineLearner`] for this service's learn spec, if
    /// one is armed — shared by the serial loop and the pipelined
    /// ingress so both construct the *same* shadow state (same routing,
    /// same flow-table split, same eviction policy).
    pub(crate) fn build_learner(&self) -> Result<Option<OnlineLearner>, ServiceError> {
        let Some(spec) = self.learn.as_ref() else {
            return Ok(None);
        };
        let Some(ctl) = self.plane.swap_controller() else {
            return Err(ServiceError::Config(
                "online_learn: backend advertises hot swap but exposes no swap controller"
                    .into(),
            ));
        };
        let learner = OnlineLearner::new(
            spec.clone(),
            ctl.registry().clone(),
            self.route.clone(),
            self.plane.latency_ns(),
            self.flow_capacity,
            self.evict,
        )
        .map_err(ServiceError::Registry)?;
        Ok(Some(learner))
    }
}

impl Service {
    /// The backend's capability descriptor (report material).
    pub fn capabilities(&self) -> Capabilities {
        self.plane.capabilities()
    }

    /// Drive `events` through the service and return the report.  With
    /// `pipeline(0)` this is the synchronous event loop on the calling
    /// thread; with `pipeline(n)` the calling thread becomes the ingress
    /// sharder of the staged runtime and every stage is joined before
    /// returning.  On stage death the error carries everything
    /// accumulated before the fault.
    pub fn run(
        self,
        events: impl IntoIterator<Item = PacketEvent>,
    ) -> Result<ServiceReport, ServiceError> {
        if self.workers == 0 {
            self.run_serial(events)
        } else {
            super::pipeline::run_staged(self, events)
        }
    }

    fn run_serial(
        self,
        events: impl IntoIterator<Item = PacketEvent>,
    ) -> Result<ServiceReport, ServiceError> {
        let mut learner = self.build_learner()?;
        let overload = if self.shed.is_some() || self.degrade.is_some() {
            let caps = self.plane.capabilities();
            // Modeled cost of one admitted trigger: amortized batch cost
            // when batching, scalar device latency otherwise.  Drain rate
            // is the backend's parallelism — `shards` servers each retire
            // one ns of work per ns.
            let cost_ns = if self.batch > 0 {
                self.plane.batch_latency_ns(self.batch) / self.batch as f64
            } else {
                self.plane.latency_ns()
            };
            let swap = self.plane.swap_controller();
            let (ladder, actions) =
                super::overload::ladder_for(self.degrade.as_ref(), self.shed, swap.as_ref());
            let admission = AdmissionController::new(
                self.shed.unwrap_or_else(ShedPolicy::never),
                caps.shards.max(1) as f64,
            );
            Some(OverloadControl::new(admission, ladder, actions, cost_ns))
        } else {
            None
        };
        let mut core = SerialCore::unbatched(
            self.plane,
            self.route,
            self.output,
            self.flow_capacity,
            self.evict,
        );
        if self.batch > 0 {
            core.set_batching(self.batch, self.max_wait_ns);
        }
        if !self.log_tags {
            core.disable_tag_log();
        }
        if let Some(ctl) = overload {
            core.set_overload(ctl);
        }
        let admin = self.admin;
        let mut n = 0u64;
        // Same failure semantics as the staged mode: a failed republish
        // is reported once (further ticks are disabled), the run keeps
        // serving, and the error carries the full report.
        let mut swap_failures: Vec<StageFailure> = Vec::new();
        for ev in events {
            if self.swap_every > 0
                && swap_failures.is_empty()
                && n > 0
                && n % self.swap_every == 0
            {
                if let Err(e) = core.hot_swap_tick() {
                    swap_failures.push(StageFailure::Swap(e));
                }
            }
            n += 1;
            core.handle(&ev);
            // The learner observes strictly after the serving side: the
            // committing packet itself is always scored under the old
            // weights (the pipelined ingress keeps the same order).
            if let Some(l) = learner.as_mut() {
                if l.on_packet(&ev) {
                    // Publish barrier: score everything enqueued so far
                    // under the pre-publish weights, then swap.
                    core.flush_lanes();
                    if let Err(e) = l.commit_pending() {
                        swap_failures.push(StageFailure::Swap(e));
                        l.poison();
                    }
                }
            }
            if let Some(a) = admin.as_ref() {
                a.on_packet();
                if n % SNAPSHOT_EVERY == 0 {
                    if let Some(l) = learner.as_mut() {
                        for name in a.take_retrains() {
                            if name == l.model_name() {
                                l.request_retrain();
                            }
                        }
                        let mut s = core.stats().clone();
                        l.publish_into(&mut s);
                        a.publish_stats(&s);
                    } else {
                        a.publish_stats(core.stats());
                    }
                }
            }
        }
        core.flush();
        let mut failures = swap_failures;
        if let Some(f) = core.take_overload_failure() {
            failures.push(f);
        }
        if let Some(f) = core.take_failure() {
            failures.push(f);
        }
        let mut report = core.into_report();
        if let Some(l) = learner.as_mut() {
            l.publish_into(&mut report.stats);
        }
        if let Some(a) = admin.as_ref() {
            a.finish(&report.stats, !failures.is_empty());
        }
        if failures.is_empty() {
            Ok(report)
        } else {
            Err(ServiceError::Stage { failures, report: Box::new(report) })
        }
    }
}

/// The synchronous single-consumer engine behind the serial [`Service`]
/// mode: flow update → route → admission → (batch lanes | inline) →
/// backend → accounting/sink.
pub(crate) struct SerialCore {
    plane: Box<dyn InferencePlane>,
    route: RouteLogic,
    output: OutputSelector,
    /// Flow state in [`FLOW_SHARDS`] logical shards — the same partition
    /// the pipelined runtime splits over its workers, so eviction (which
    /// depends on which flows share a table) is identical in both modes.
    flows: ShardedFlowTable,
    batchers: Option<BatchSet<PendingFlow>>,
    stats: ServiceStats,
    sink: OutputSink,
    tagged: Vec<TaggedVerdict>,
    log_tags: bool,
    /// Route-indexed model names (empty = unnamed single-model serving).
    names: Vec<String>,
    /// Route-indexed per-model accounting, folded into the name-keyed
    /// [`ServiceStats::per_model`] map at flush time — the hot path
    /// indexes a `Vec` instead of allocating a key for a map lookup.
    per_route: Vec<ModelServiceStats>,
    swap: Option<SwapController>,
    /// First typed backend fault (dead/panicked engine shard).  Once
    /// set, further inference work is skipped — the same "stage died,
    /// partial stats survive" semantics as the pipelined mode.
    failure: Option<StageFailure>,
    /// Scratch reused across batch flushes.
    batch_meta: Vec<(u64, f64)>,
    batch_inputs: Vec<Vec<u32>>,
    batch_classes: Vec<usize>,
    /// Admission + degradation ladder (None = run unconditionally).
    overload: Option<OverloadControl>,
}

impl SerialCore {
    pub(crate) fn unbatched(
        plane: Box<dyn InferencePlane>,
        route: RouteLogic,
        output: OutputSelector,
        flow_capacity: usize,
        evict: EvictPolicy,
    ) -> Self {
        let n_classes = plane.n_classes();
        let names = plane.route_names().to_vec();
        let swap = plane.swap_controller();
        let n_routes = route.n_routes();
        Self {
            plane,
            route,
            output,
            flows: ShardedFlowTable::with_total_capacity(FLOW_SHARDS, flow_capacity, evict),
            batchers: None,
            stats: ServiceStats {
                classes: vec![0; n_classes],
                ..Default::default()
            },
            sink: OutputSink::default(),
            tagged: Vec::new(),
            log_tags: true,
            per_route: vec![ModelServiceStats::default(); n_routes],
            names,
            swap,
            failure: None,
            batch_meta: Vec::new(),
            batch_inputs: Vec::new(),
            batch_classes: Vec::new(),
            overload: None,
        }
    }

    /// Enable per-route batch lanes (call before any traffic).
    pub(crate) fn set_batching(&mut self, max_size: usize, max_wait_ns: f64) {
        self.batchers = Some(BatchSet::new(self.route.n_routes(), max_size, max_wait_ns));
    }

    pub(crate) fn disable_tag_log(&mut self) {
        self.log_tags = false;
    }

    /// Live accounting view (admin stats snapshots mid-run).
    pub(crate) fn stats(&self) -> &ServiceStats {
        &self.stats
    }

    /// Arm admission control + the degradation ladder (call before any
    /// traffic).
    pub(crate) fn set_overload(&mut self, ctl: OverloadControl) {
        self.overload = Some(ctl);
    }

    /// The first backend fault this core absorbed, if any.
    pub(crate) fn take_failure(&mut self) -> Option<StageFailure> {
        self.failure.take()
    }

    /// A failed ladder step (fallback publish/rollback), if one fired.
    /// The ladder disables its swap actions after the first failure, so
    /// this reports at most once.
    pub(crate) fn take_overload_failure(&mut self) -> Option<StageFailure> {
        self.overload.as_mut().and_then(OverloadControl::take_swap_failure)
    }

    /// Republish the next bound slot round-robin (no-op without a swap
    /// controller).
    pub(crate) fn hot_swap_tick(&mut self) -> Result<(), RegistryError> {
        if let Some(s) = self.swap.as_mut() {
            s.tick()?;
        }
        Ok(())
    }

    /// Synchronous single-event path.  Time-based batch flushes ride on
    /// packet arrival: the data plane has no timer thread, so pending
    /// lanes are checked against the packet clock (§3.2's trigger-module
    /// shape).
    pub(crate) fn handle(&mut self, ev: &PacketEvent) {
        self.stats.packets += 1;
        let due = match self.batchers.as_mut() {
            Some(b) => b.poll(ev.packet.ts_ns),
            None => Vec::new(),
        };
        for (lane, batch) in due {
            self.flush_batch(lane, batch, ev.packet.ts_ns);
        }
        if let Some(ctl) = self.overload.as_mut() {
            // Ladder pressure = modeled admission backlog plus the age of
            // the oldest queued batch item on the packet clock — sustained
            // queueing steps the service down even when admission alone
            // would keep absorbing it.
            let queued_ns = self
                .batchers
                .as_ref()
                .and_then(BatchSet::oldest_enqueue_ns)
                .map_or(0.0, |t| ev.packet.ts_ns - t);
            ctl.on_packet(ev.packet.ts_ns, queued_ns);
        }
        // `None` = untracked (EvictPolicy::Off on a full table): the
        // packet is forwarded without per-flow state and can't trigger —
        // the counted degradation that replaced the old panic.
        let Some(up) = self.flows.update(&ev.packet) else {
            return;
        };
        let Some(route) = self.route.route(&ev.packet, up.is_new, up.pkts) else {
            return;
        };
        self.stats.triggers += 1;
        if self.failure.is_some() {
            // Poisoned backend: keep parse/trigger accounting honest but
            // stop feeding it (mirrors a dead pipelined stage 3).
            return;
        }
        if let Some(ctl) = self.overload.as_mut() {
            if !ctl.admit_trigger(ev.packet.ts_ns) {
                self.stats.sheds += 1;
                return;
            }
        }
        let packed = select_packed_input(ev, up.stats);
        let id = flow_id(&ev.packet);
        if self.batchers.is_some() {
            let full = self
                .batchers
                .as_mut()
                .unwrap()
                .push(route, ev.packet.ts_ns, PendingFlow { id, packed });
            if let Some(batch) = full {
                self.flush_batch(route, batch, ev.packet.ts_ns);
            }
        } else {
            let (class, tag) = self.plane.classify(route, &packed);
            let latency_ns = self.plane.latency_ns();
            self.finish_inference(route, id, class, tag, latency_ns);
        }
    }

    /// Force-flush every pending batch lane *now* — the learner's
    /// publish barrier.  Each batch's "now" is its newest enqueue time,
    /// a pure packet-clock quantity, so the latency accounting of a
    /// barrier flush is identical in the serial and pipelined runtimes.
    pub(crate) fn flush_lanes(&mut self) {
        let due = match self.batchers.as_mut() {
            Some(b) => b.poll(f64::INFINITY),
            None => Vec::new(),
        };
        for (lane, batch) in due {
            let now_ns = batch.last().map_or(0.0, |&(t, _)| t);
            self.flush_batch(lane, batch, now_ns);
        }
    }

    /// Drain every batch lane (end of stream / shutdown) and fold the
    /// per-route scratch into the name-keyed per-model map.
    pub(crate) fn flush(&mut self) {
        self.flush_lanes();
        self.snapshot_per_model();
    }

    /// Fold route-indexed scratch into [`ServiceStats::per_model`] and
    /// refresh each named route's swap count from the live registry.
    /// Draining the scratch makes repeated flushes safe.
    fn snapshot_per_model(&mut self) {
        for (route, scratch) in self.per_route.iter_mut().enumerate() {
            let Some(name) = self.names.get(route) else {
                continue;
            };
            let entry = self.stats.per_model.entry(name.clone()).or_default();
            entry.absorb(scratch);
            if let Some(swap) = self.swap.as_ref() {
                entry.swaps = swap.registry().swap_count(name);
            }
            *scratch = ModelServiceStats::default();
        }
    }

    /// Score one lane's batch under a single weight snapshot and account
    /// every verdict.  Per-flow latency is the queueing wait on the
    /// packet clock plus the modeled completion time of the *whole*
    /// batch — batching's latency price stays visible in the histogram
    /// (Fig. 6's trade-off) instead of silently vanishing.
    fn flush_batch(&mut self, lane: usize, batch: TimedBatch<PendingFlow>, now_ns: f64) {
        if self.failure.is_some() {
            return;
        }
        self.batch_meta.clear();
        self.batch_inputs.clear();
        for (enq_ns, flow) in batch {
            self.batch_meta.push((flow.id, enq_ns));
            self.batch_inputs.push(flow.packed);
        }
        let inputs = std::mem::take(&mut self.batch_inputs);
        let mut classes = std::mem::take(&mut self.batch_classes);
        let outcome = self.plane.try_run_batch(lane, &inputs, &mut classes);
        match outcome {
            Ok(tag) => {
                let exec_ns = self.plane.batch_latency_ns(classes.len());
                for i in 0..classes.len() {
                    let (id, enq_ns) = self.batch_meta[i];
                    let latency_ns = batch_item_latency_ns(now_ns, enq_ns, exec_ns);
                    self.finish_inference(lane, id, classes[i], tag.clone(), latency_ns);
                }
            }
            // Typed fault: this batch's verdicts are lost (exactly as
            // they would be in a dead pipelined stage 3); everything
            // accounted so far survives into the report.
            Err(e) => self.failure = Some(StageFailure::Inference(e)),
        }
        self.batch_inputs = inputs;
        self.batch_classes = classes;
    }

    /// Account one verdict: stats, histogram (grown on demand), per-route
    /// scratch, sink, tag log.
    fn finish_inference(
        &mut self,
        route: usize,
        id: u64,
        class: usize,
        tag: Option<VersionTag>,
        latency_ns: f64,
    ) {
        self.stats.inferences += 1;
        if class >= self.stats.classes.len() {
            self.stats.classes.resize(class + 1, 0);
        }
        self.stats.classes[class] += 1;
        if !self.names.is_empty() {
            self.per_route[route].record(class);
        }
        self.stats.latency.record(latency_ns);
        self.sink.write(self.output, id, class);
        if self.log_tags {
            if let Some(tag) = tag {
                self.tagged.push(TaggedVerdict { id, class, tag });
            }
        }
    }

    pub(crate) fn into_report(mut self) -> ServiceReport {
        let engine = self.plane.engine_stats();
        let health = self.plane.health_snapshot();
        let flows_tracked = self.flows.len();
        self.stats.flow_table = self.flows.stats_snapshot();
        let degradation =
            self.overload.take().map_or_else(Vec::new, OverloadControl::into_timeline);
        ServiceReport {
            stats: std::mem::take(&mut self.stats),
            sink: std::mem::take(&mut self.sink),
            tagged: std::mem::take(&mut self.tagged),
            flows_tracked,
            engine,
            degradation,
            health,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::{BnnModel, RegistryHandle};
    use crate::coordinator::BackendFactory;
    use crate::net::traffic::{CbrSpec, TrafficGen};

    fn model() -> BnnModel {
        BnnModel::random("traffic", 256, &[32, 16, 2], 1)
    }

    fn builder() -> ServeBuilder {
        ServeBuilder::new()
            .backend(BackendFactory::single("fpga", model()).unwrap())
            .trigger(TriggerCondition::EveryNPackets(10))
            .output(OutputSelector::Memory)
    }

    fn events(n: usize, flows: u64, seed: u64) -> Vec<PacketEvent> {
        PacketEvent::cbr_burst(CbrSpec { gbps: 10.0, pkt_size: 256 }, flows, seed, n)
    }

    #[test]
    fn trigger_fires_once_per_flow_at_10_packets() {
        let rep = builder().build().unwrap().run(events(5000, 50, 3)).unwrap();
        assert_eq!(rep.stats.packets, 5000);
        assert!(rep.stats.triggers > 0);
        assert_eq!(rep.stats.triggers, rep.stats.inferences);
        // Every verdict was written to memory (the configured selector).
        assert_eq!(rep.sink.memory.len() as u64, rep.stats.inferences);
        assert!(rep.sink.inline_tags.is_empty());
        // Each flow triggers at most once (exactly at packet #10).
        assert!(rep.stats.triggers <= 50);
        // Single-model serving: no tags, no per-model entries.
        assert!(rep.tagged.is_empty());
        assert!(rep.stats.per_model.is_empty());
    }

    #[test]
    fn histogram_width_comes_from_model() {
        // traffic model has 2 output neurons → 2 counters, not 8.
        let rep = builder().build().unwrap().run(events(100, 5, 1)).unwrap();
        assert_eq!(rep.stats.classes.len(), 2);
    }

    #[test]
    fn batched_route_matches_unbatched() {
        let evs = events(4000, 40, 6);
        let plain = builder().build().unwrap().run(evs.iter().cloned()).unwrap();
        let batched = builder()
            .batching(7, 1e12)
            .build()
            .unwrap()
            .run(evs.iter().cloned())
            .unwrap();
        assert_eq!(batched.stats.triggers, plain.stats.triggers);
        assert_eq!(batched.stats.inferences, plain.stats.inferences);
        assert_eq!(batched.stats.classes, plain.stats.classes);
        // Same verdicts for the same flows, order aside.
        let mut a = plain.sink.memory.clone();
        let mut b = batched.sink.memory.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn batcher_timeout_flushes_on_packet_clock() {
        // Huge batch size, tiny timeout: flows must still drain.
        let rep = builder()
            .batching(1 << 20, 1.0)
            .build()
            .unwrap()
            .run(events(2000, 5, 8))
            .unwrap();
        assert_eq!(rep.stats.inferences, rep.stats.triggers);
    }

    #[test]
    fn stats_merge_accumulates_and_grows() {
        let mut a = ServiceStats {
            packets: 10,
            triggers: 2,
            inferences: 2,
            classes: vec![1, 1],
            stage_blocked: vec![3],
            ..Default::default()
        };
        a.latency.record(100.0);
        let mut b = ServiceStats {
            packets: 5,
            triggers: 1,
            inferences: 1,
            classes: vec![0, 0, 7],
            stage_blocked: vec![1, 4],
            ..Default::default()
        };
        b.latency.record(900.0);
        a.merge(&b);
        assert_eq!(a.packets, 15);
        assert_eq!(a.triggers, 3);
        assert_eq!(a.inferences, 3);
        assert_eq!(a.classes, vec![1, 1, 7]);
        assert_eq!(a.stage_blocked, vec![4, 4]);
        assert_eq!(a.latency.count(), 2);
    }

    #[test]
    fn per_model_stats_merge_keywise_grow_and_max_swaps() {
        let mut a = ServiceStats::default();
        a.per_model.insert(
            "anomaly".into(),
            ModelServiceStats { inferences: 3, classes: vec![2, 1], swaps: 4 },
        );
        a.per_model.insert(
            "tomography".into(),
            ModelServiceStats { inferences: 1, classes: vec![1], swaps: 0 },
        );
        let mut b = ServiceStats::default();
        // Same slot seen by another stage: counts add, histogram grows,
        // swap snapshots of the shared counter take the max (not the
        // sum — both stages read the same registry slot).
        b.per_model.insert(
            "anomaly".into(),
            ModelServiceStats { inferences: 2, classes: vec![0, 1, 5], swaps: 2 },
        );
        // A slot only the other stage routed.
        b.per_model.insert(
            "traffic-class".into(),
            ModelServiceStats { inferences: 7, classes: vec![7], swaps: 1 },
        );
        a.merge(&b);
        assert_eq!(
            a.per_model["anomaly"],
            ModelServiceStats { inferences: 5, classes: vec![2, 2, 5], swaps: 4 }
        );
        assert_eq!(
            a.per_model["tomography"],
            ModelServiceStats { inferences: 1, classes: vec![1], swaps: 0 }
        );
        assert_eq!(
            a.per_model["traffic-class"],
            ModelServiceStats { inferences: 7, classes: vec![7], swaps: 1 }
        );
        // Merging an empty map changes nothing.
        let snapshot = a.per_model.clone();
        a.merge(&ServiceStats::default());
        assert_eq!(a.per_model, snapshot);
    }

    #[test]
    fn stats_merge_semantics_are_per_field() {
        use crate::learn::{AccuracyWindow, LearnStats};
        // Retrain-driven multi-publish: one stage snapshots the slot's
        // swap counter at 3 (after three republishes), a later stage at
        // 5.  Max reconstructs the true count; adding would report 8
        // swaps that never happened.
        let mut a = ServiceStats::default();
        a.per_model
            .insert("drift".into(), ModelServiceStats { inferences: 4, classes: vec![4], swaps: 3 });
        a.accuracy_timeline.push(AccuracyWindow {
            model: "drift".into(),
            end_packet: 500,
            evaluated: 10,
            correct: 9,
            version: 1,
        });
        a.learn = Some(LearnStats { windows: 1, evaluated: 10, ..Default::default() });
        let mut b = ServiceStats::default();
        b.per_model
            .insert("drift".into(), ModelServiceStats { inferences: 6, classes: vec![6], swaps: 5 });
        b.accuracy_timeline.push(AccuracyWindow {
            model: "drift".into(),
            end_packet: 250,
            evaluated: 10,
            correct: 4,
            version: 1,
        });
        b.learn = Some(LearnStats {
            windows: 1,
            evaluated: 10,
            drift_fired_at: Some(250),
            retrains: 2,
            promotions: 1,
            rejections: 1,
            ..Default::default()
        });
        a.merge(&b);
        let m = &a.per_model["drift"];
        assert_eq!(m.swaps, 5, "shared-counter snapshot: max, not sum");
        assert_eq!(m.inferences, 10, "partition counter: sum");
        // Timeline restored to packet order after concatenation.
        let ends: Vec<u64> = a.accuracy_timeline.iter().map(|w| w.end_packet).collect();
        assert_eq!(ends, vec![250, 500]);
        let learn = a.learn.as_ref().unwrap();
        assert_eq!(learn.windows, 2);
        assert_eq!(learn.evaluated, 20);
        assert_eq!(learn.retrains, 2);
        assert_eq!(learn.promotions, 1);
        assert_eq!(learn.drift_fired_at, Some(250));
        // One-sided learn telemetry survives a merge with a learner-less
        // stage unchanged.
        let keep = a.learn.clone();
        a.merge(&ServiceStats::default());
        assert_eq!(a.learn, keep);
        let mut empty = ServiceStats::default();
        empty.merge(&a);
        assert_eq!(empty.learn, keep);
        assert_eq!(empty.accuracy_timeline, a.accuracy_timeline);
    }

    fn two_model_registry() -> (RegistryHandle, ModelRouter) {
        let h = RegistryHandle::new();
        h.publish("anomaly", &BnnModel::random("anomaly", 256, &[32, 16, 2], 21))
            .unwrap();
        h.publish("traffic-class", &BnnModel::random("traffic-class", 256, &[32, 16, 2], 22))
            .unwrap();
        let router = ModelRouter::hash_split(
            TriggerCondition::EveryNPackets(10),
            vec!["anomaly".into(), "traffic-class".into()],
        );
        (h, router)
    }

    fn routed_builder(h: &RegistryHandle, router: ModelRouter, shards: usize) -> ServeBuilder {
        let names = router.model_names().to_vec();
        ServeBuilder::new()
            .backend(BackendFactory::registry(h, &names, 100.0, shards).unwrap())
            .router(router)
            .output(OutputSelector::Memory)
    }

    #[test]
    fn routed_service_tags_every_verdict_and_accounts_per_model() {
        let (h, router) = two_model_registry();
        let mut gen = TrafficGen::new(CbrSpec { gbps: 10.0, pkt_size: 256 }, 60, 5);
        let evs: Vec<PacketEvent> = (0..6000)
            .map(|_| PacketEvent { packet: gen.next_packet(), payload_words: None })
            .collect();
        let rep = routed_builder(&h, router, 1).build().unwrap().run(evs).unwrap();
        assert!(rep.stats.triggers > 0);
        assert_eq!(rep.stats.triggers, rep.stats.inferences);
        assert_eq!(rep.tagged.len() as u64, rep.stats.inferences);
        assert_eq!(rep.sink.memory.len() as u64, rep.stats.inferences);
        // No publishes happened: every tag is version 1, swaps are 0.
        for t in &rep.tagged {
            assert_eq!(t.tag.version(), 1);
        }
        let pm = &rep.stats.per_model;
        assert_eq!(pm.len(), 2);
        assert_eq!(
            pm.values().map(|m| m.inferences).sum::<u64>(),
            rep.stats.inferences
        );
        for m in pm.values() {
            assert_eq!(m.swaps, 0);
        }
        // Per-model histograms sum to the global one.
        let mut summed = vec![0u64; rep.stats.classes.len()];
        for m in pm.values() {
            for (i, &c) in m.classes.iter().enumerate() {
                summed[i] += c;
            }
        }
        assert_eq!(summed, rep.stats.classes);
    }

    #[test]
    fn routed_batched_route_matches_unbatched_and_survives_hot_swap() {
        let (h, router) = two_model_registry();
        let evs = events(4000, 40, 6);
        let plain = routed_builder(&h, router.clone(), 1)
            .build()
            .unwrap()
            .run(evs.iter().cloned())
            .unwrap();
        let batched = routed_builder(&h, router.clone(), 3)
            .batching(7, 1e12)
            .build()
            .unwrap()
            .run(evs.iter().cloned())
            .unwrap();
        assert_eq!(batched.stats.triggers, plain.stats.triggers);
        assert_eq!(batched.stats.classes, plain.stats.classes);
        assert_eq!(batched.stats.per_model, plain.stats.per_model);
        let mut a = plain.sink.memory.clone();
        let mut b = batched.sink.memory.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
        // Hot-swap both slots with the *same* weights mid-stream (the
        // `.swap_every` machinery): a fresh run's verdicts are
        // bit-identical, but tags move past v1 and swap counts show up
        // in the per-model stats.  (Ticks land at packets 300/600/…;
        // this seed's triggers span packets ~207–631, so both v1 and
        // post-swap tags are guaranteed to appear.)
        let swapped = routed_builder(&h, router, 1)
            .swap_every(300)
            .build()
            .unwrap()
            .run(evs.iter().cloned())
            .unwrap();
        assert_eq!(swapped.stats.classes, plain.stats.classes);
        assert!(swapped.tagged.iter().any(|t| t.tag.version() == 1));
        assert!(swapped.tagged.iter().any(|t| t.tag.version() > 1));
        let total_swaps: u64 = swapped.stats.per_model.values().map(|m| m.swaps).sum();
        assert!(total_swaps > 0);
    }

    #[test]
    fn builder_rejects_online_learn_misconfig() {
        use crate::learn::{LabelFn, LearnSpec};
        let labeler: LabelFn = std::sync::Arc::new(|_p: &Packet| 0);
        // fpga single backend: no hot swap, no registry to republish to.
        let err = builder()
            .online_learn(LearnSpec::new("traffic", labeler.clone()))
            .build()
            .unwrap_err();
        assert!(matches!(err, ServiceError::InvalidConfig { option: "online_learn", .. }), "{err}");
        // Registry backend, but the watched slot is not bound.
        let (h, router) = two_model_registry();
        let err = routed_builder(&h, router, 1)
            .online_learn(LearnSpec::new("nope", labeler))
            .build()
            .unwrap_err();
        assert!(matches!(err, ServiceError::InvalidConfig { option: "online_learn", .. }), "{err}");
    }

    #[test]
    fn builder_rejects_capability_violations() {
        // No backend.
        assert!(matches!(
            ServeBuilder::new().build().unwrap_err(),
            ServiceError::Config(_)
        ));
        // Route-count mismatch: 2-route registry behind a bare trigger.
        let (h, router) = two_model_registry();
        let names = router.model_names().to_vec();
        let err = ServeBuilder::new()
            .backend(BackendFactory::registry(&h, &names, 100.0, 1).unwrap())
            .trigger(TriggerCondition::EveryPacket)
            .build()
            .unwrap_err();
        assert!(matches!(err, ServiceError::Config(_)), "{err}");
        // Same route count but reordered names: positional routes would
        // silently cross-wire models, so the builder refuses.
        let (h, router) = two_model_registry();
        let mut reversed = router.model_names().to_vec();
        reversed.reverse();
        let err = ServeBuilder::new()
            .backend(BackendFactory::registry(&h, &reversed, 100.0, 1).unwrap())
            .router(router)
            .build()
            .unwrap_err();
        assert!(matches!(err, ServiceError::Config(_)), "{err}");
        // Hot swap on a backend without it.
        let err = builder().swap_every(100).build().unwrap_err();
        assert!(matches!(err, ServiceError::Config(_)), "{err}");
        // Batch wider than the backend's max (pisa classifies inline).
        let err = ServeBuilder::new()
            .backend(BackendFactory::single("pisa", model()).unwrap())
            .batching(8, 1e6)
            .build()
            .unwrap_err();
        assert!(matches!(err, ServiceError::Config(_)), "{err}");
    }

    #[test]
    fn zero_queue_depth_is_a_typed_build_error_not_a_silent_clamp() {
        let err = builder().pipeline(2).queue_depth(0).build().unwrap_err();
        match err {
            ServiceError::InvalidConfig { option, reason } => {
                assert_eq!(option, "queue_depth");
                assert!(reason.contains("deadlock"), "{reason}");
            }
            other => panic!("expected InvalidConfig, got {other}"),
        }
        // Serial mode rejects it too: the knob is meaningless there, but a
        // config that would deadlock if pipelined should never validate.
        assert!(builder().queue_depth(0).build().is_err());
        // Depth 1 (the old clamp target) is still a valid explicit choice.
        assert!(builder().pipeline(2).queue_depth(1).build().is_ok());
    }

    #[test]
    fn degrade_fallback_is_validated_against_backend_and_shape() {
        use crate::coordinator::DegradeSpec;
        // Fallback model on a non-hot-swap backend: typed error.
        let fallback = BnnModel::random("lite", 256, &[8, 2], 99);
        let err = builder().degrade(DegradeSpec::with_fallback(fallback)).build().unwrap_err();
        assert!(
            matches!(err, ServiceError::InvalidConfig { option: "degrade", .. }),
            "{err}"
        );
        // Wrong-shaped fallback on a registry backend: typed error naming
        // the offending slot.
        let (h, router) = two_model_registry();
        let names = router.model_names().to_vec();
        let wrong = BnnModel::random("lite", 128, &[8, 2], 99);
        let err = ServeBuilder::new()
            .backend(BackendFactory::registry(&h, &names, 100.0, 1).unwrap())
            .router(router)
            .degrade(DegradeSpec::with_fallback(wrong))
            .build()
            .unwrap_err();
        match err {
            ServiceError::InvalidConfig { option, reason } => {
                assert_eq!(option, "degrade");
                assert!(reason.contains("shape"), "{reason}");
            }
            other => panic!("expected InvalidConfig, got {other}"),
        }
        // Trigger-only degradation needs no registry and works anywhere.
        assert!(builder().degrade(DegradeSpec::trigger_only()).build().is_ok());
    }

    #[test]
    fn service_error_display_is_actionable() {
        let err = ServiceError::UnknownBackend { name: "gpu".into() };
        let msg = err.to_string();
        assert!(msg.contains("gpu") && msg.contains("registry"), "{msg}");
        let stage = ServiceError::Stage {
            failures: vec![
                StageFailure::ParseDisconnected { worker: 1 },
                StageFailure::Panicked { stage: "inference stage", message: "boom".into() },
            ],
            report: Box::default(),
        };
        let msg = stage.to_string();
        assert!(msg.contains("worker 1") && msg.contains("boom"), "{msg}");
    }
}

//! The serving loop: a threaded coordinator that consumes packet / flow
//! events, applies the trigger + selectors, runs the configured executor,
//! and routes verdicts.  This is the launcher's `serve` mode — the
//! end-to-end request path with Python nowhere in sight.

use std::sync::mpsc;

use crate::metrics::LatencyHistogram;
use crate::net::features::FeatureVector;
use crate::net::flow::FlowTable;
use crate::net::packet::Packet;

use super::selector::{OutputSelector, OutputSink};
use super::trigger::TriggerCondition;
use super::NnExecutor;

/// One event entering the coordinator (a received packet).
#[derive(Debug, Clone)]
pub struct PacketEvent {
    pub packet: Packet,
    /// Optional inline payload words (probe vectors etc.).
    pub payload_words: Option<Vec<u32>>,
}

/// Aggregate statistics of a service run.
#[derive(Debug, Default, Clone)]
pub struct ServiceStats {
    pub packets: u64,
    pub triggers: u64,
    pub inferences: u64,
    pub classes: Vec<u64>,
    pub latency: LatencyHistogram,
}

/// The coordinator service: single-consumer event loop.
pub struct CoordinatorService<E: NnExecutor> {
    pub exec: E,
    pub trigger: TriggerCondition,
    pub output: OutputSelector,
    pub flows: FlowTable,
    pub sink: OutputSink,
    pub stats: ServiceStats,
}

impl<E: NnExecutor> CoordinatorService<E> {
    pub fn new(exec: E, trigger: TriggerCondition, output: OutputSelector) -> Self {
        let n_classes = 8;
        Self {
            exec,
            trigger,
            output,
            flows: FlowTable::new(1 << 16),
            sink: OutputSink::default(),
            stats: ServiceStats {
                classes: vec![0; n_classes],
                ..Default::default()
            },
        }
    }

    /// Synchronous single-event path (also the unit the async loop calls).
    pub fn handle(&mut self, ev: &PacketEvent) {
        self.stats.packets += 1;
        let (stats, is_new, pkts) = self.flows.update(&ev.packet);
        if !self.trigger.fires(&ev.packet, is_new, pkts) {
            return;
        }
        self.stats.triggers += 1;
        // Input selection: inline payload if present, else flow features.
        let packed: Vec<u32> = match &ev.payload_words {
            Some(w) => w.clone(),
            None => FeatureVector::from_stats(stats).pack().to_vec(),
        };
        let class = self.exec.classify(&packed);
        self.stats.inferences += 1;
        if class < self.stats.classes.len() {
            self.stats.classes[class] += 1;
        }
        self.stats.latency.record(self.exec.latency_ns());
        let id = ((ev.packet.src_ip as u64) << 32) | ev.packet.dst_ip as u64;
        self.sink.write(self.output, id, class);
    }

    /// Event loop: drain an mpsc channel until all senders drop; returns
    /// the accumulated statistics.  Run it on a dedicated thread; the
    /// traffic source(s) feed the channel from other threads (the NIC
    /// event-queue shape).
    pub fn run(mut self, rx: mpsc::Receiver<PacketEvent>) -> ServiceStats {
        while let Ok(ev) = rx.recv() {
            self.handle(&ev);
        }
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::BnnModel;
    use crate::coordinator::CoreExecutor;
    use crate::net::traffic::{CbrSpec, TrafficGen};

    fn service() -> CoordinatorService<CoreExecutor> {
        let model = BnnModel::random("traffic", 256, &[32, 16, 2], 1);
        CoordinatorService::new(
            CoreExecutor::fpga(model),
            TriggerCondition::EveryNPackets(10),
            OutputSelector::Memory,
        )
    }

    #[test]
    fn trigger_fires_once_per_flow_at_10_packets() {
        let mut svc = service();
        let mut gen = TrafficGen::new(CbrSpec { gbps: 10.0, pkt_size: 256 }, 50, 3);
        for _ in 0..5000 {
            let p = gen.next_packet();
            svc.handle(&PacketEvent { packet: p, payload_words: None });
        }
        assert_eq!(svc.stats.packets, 5000);
        assert!(svc.stats.triggers > 0);
        assert_eq!(svc.stats.triggers, svc.stats.inferences);
        // Every verdict was written to memory (the configured selector).
        assert_eq!(svc.sink.memory.len() as u64, svc.stats.inferences);
        assert!(svc.sink.inline_tags.is_empty());
        // Each flow triggers at most once (exactly at packet #10).
        assert!(svc.stats.triggers <= 50);
    }

    #[test]
    fn event_loop_drains_channel() {
        let svc = service();
        let (tx, rx) = mpsc::channel();
        let mut gen = TrafficGen::new(CbrSpec { gbps: 10.0, pkt_size: 256 }, 10, 4);
        let feeder = std::thread::spawn(move || {
            for _ in 0..500 {
                let p = gen.next_packet();
                tx.send(PacketEvent { packet: p, payload_words: None }).unwrap();
            }
        });
        let consumer = std::thread::spawn(move || svc.run(rx));
        feeder.join().unwrap();
        let stats = consumer.join().unwrap();
        assert_eq!(stats.packets, 500);
    }
}

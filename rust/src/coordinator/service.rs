//! The serving loop: a threaded coordinator that consumes packet / flow
//! events, applies the trigger + selectors, runs the configured executor,
//! and routes verdicts.  This is the launcher's `serve` mode — the
//! end-to-end request path with Python nowhere in sight.
//!
//! Two inference routes share the loop:
//!
//! * **unbatched** (default): every triggered flow is classified inline —
//!   minimum latency, the NIC-style per-packet path;
//! * **batched** ([`CoordinatorService::with_batching`]): triggered flows
//!   accumulate in a [`Batcher`] and go through the executor's
//!   [`NnBatchExecutor::classify_batch`] fast path (weight-stationary
//!   kernel / sharded engine) when the batch fills or times out — the
//!   throughput path of §6.

use std::sync::mpsc;

use crate::metrics::LatencyHistogram;
use crate::net::features::FeatureVector;
use crate::net::flow::{FlowStats, FlowTable};
use crate::net::packet::Packet;
use crate::net::traffic::{CbrSpec, TrafficGen};

use super::batcher::Batcher;
use super::selector::{OutputSelector, OutputSink};
use super::trigger::TriggerCondition;
use super::NnBatchExecutor;

/// One event entering the coordinator (a received packet).
#[derive(Debug, Clone)]
pub struct PacketEvent {
    pub packet: Packet,
    /// Optional inline payload words (probe vectors etc.).
    pub payload_words: Option<Vec<u32>>,
}

impl PacketEvent {
    /// `n` payload-less events from a seeded CBR generator — the
    /// traffic shape every serving test and bench drives with.
    pub fn cbr_burst(spec: CbrSpec, flows: u64, seed: u64, n: usize) -> Vec<PacketEvent> {
        let mut gen = TrafficGen::new(spec, flows, seed);
        (0..n)
            .map(|_| PacketEvent { packet: gen.next_packet(), payload_words: None })
            .collect()
    }
}

/// A triggered flow waiting in the batcher: its routing id + packed input.
#[derive(Debug, Clone)]
pub struct PendingFlow {
    pub id: u64,
    pub packed: Vec<u32>,
}

/// Routing id of a flow event — the verdict's key in the sink.  One
/// definition shared by the serial loop and the pipelined runtime: the
/// two must stay bit-identical (the determinism contract), so neither
/// may grow its own copy.
#[inline]
pub(crate) fn flow_id(p: &Packet) -> u64 {
    ((p.src_ip as u64) << 32) | p.dst_ip as u64
}

/// Input selection shared by both runtimes: inline payload words if the
/// event carries them, else the packed flow features.
pub(crate) fn select_packed_input(ev: &PacketEvent, stats: &FlowStats) -> Vec<u32> {
    match &ev.payload_words {
        Some(w) => w.clone(),
        None => FeatureVector::from_stats(stats).pack().to_vec(),
    }
}

/// Latency of one batched item: packet-clock queueing wait plus the
/// whole batch's modeled completion time (every item waits for the
/// batch to finish) — shared by both runtimes' flush paths.
#[inline]
pub(crate) fn batch_item_latency_ns(now_ns: f64, enq_ns: f64, exec_ns: f64) -> f64 {
    (now_ns - enq_ns).max(0.0) + exec_ns
}

/// Aggregate statistics of a service run.
#[derive(Debug, Default, Clone)]
pub struct ServiceStats {
    pub packets: u64,
    pub triggers: u64,
    pub inferences: u64,
    /// Verdict histogram, sized from the executor's model and grown on
    /// demand if a verdict ever exceeds it.
    pub classes: Vec<u64>,
    pub latency: LatencyHistogram,
    /// Bounded-channel backpressure in the pipelined runtime: how many
    /// sends found the downstream queue full and had to wait, indexed by
    /// inter-stage link (see `coordinator::pipeline::STAGE_LINKS`).
    /// Empty in the serial loop, which has no queues.
    pub stage_blocked: Vec<u64>,
}

impl ServiceStats {
    /// Fold another stage's (or shard's) counters into this one — the
    /// pipeline's join step.  Histograms merge bucket-wise; the verdict
    /// histogram grows to the wider of the two.
    pub fn merge(&mut self, other: &ServiceStats) {
        self.packets += other.packets;
        self.triggers += other.triggers;
        self.inferences += other.inferences;
        if other.classes.len() > self.classes.len() {
            self.classes.resize(other.classes.len(), 0);
        }
        for (a, b) in self.classes.iter_mut().zip(&other.classes) {
            *a += b;
        }
        self.latency.merge(&other.latency);
        if other.stage_blocked.len() > self.stage_blocked.len() {
            self.stage_blocked.resize(other.stage_blocked.len(), 0);
        }
        for (a, b) in self.stage_blocked.iter_mut().zip(&other.stage_blocked) {
            *a += b;
        }
    }
}

/// The coordinator service: single-consumer event loop.
pub struct CoordinatorService<E: NnBatchExecutor> {
    pub exec: E,
    pub trigger: TriggerCondition,
    pub output: OutputSelector,
    pub flows: FlowTable,
    pub sink: OutputSink,
    pub stats: ServiceStats,
    batcher: Option<Batcher<PendingFlow>>,
    /// Scratch for batch flushes ((flow id, enqueue ts) per item),
    /// reused across batches.
    batch_meta: Vec<(u64, f64)>,
    batch_inputs: Vec<Vec<u32>>,
    batch_classes: Vec<usize>,
}

impl<E: NnBatchExecutor> CoordinatorService<E> {
    pub fn new(exec: E, trigger: TriggerCondition, output: OutputSelector) -> Self {
        let n_classes = exec.n_classes();
        Self {
            exec,
            trigger,
            output,
            flows: FlowTable::new(1 << 16),
            sink: OutputSink::default(),
            stats: ServiceStats {
                classes: vec![0; n_classes],
                ..Default::default()
            },
            batcher: None,
            batch_meta: Vec::new(),
            batch_inputs: Vec::new(),
            batch_classes: Vec::new(),
        }
    }

    /// Enable batch accumulation: triggered flows queue until `max_size`
    /// or `max_wait_ns` (packet-clock), then take the batch fast path.
    pub fn with_batching(mut self, max_size: usize, max_wait_ns: f64) -> Self {
        self.batcher = Some(Batcher::new(max_size, max_wait_ns));
        self
    }

    /// Triggered flows currently waiting in the batcher.
    pub fn pending(&self) -> usize {
        self.batcher.as_ref().map_or(0, Batcher::pending)
    }

    /// Synchronous single-event path (also the unit the async loop calls).
    pub fn handle(&mut self, ev: &PacketEvent) {
        self.stats.packets += 1;
        // Time-based flush rides on packet arrival: the data plane has no
        // timer thread, so the oldest batched flow is checked against the
        // packet clock (same shape as §3.2's trigger module).
        let timed_out = self
            .batcher
            .as_mut()
            .and_then(|b| b.poll(ev.packet.ts_ns));
        if let Some(batch) = timed_out {
            self.flush_batch(batch, ev.packet.ts_ns);
        }
        let (stats, is_new, pkts) = self.flows.update(&ev.packet);
        if !self.trigger.fires(&ev.packet, is_new, pkts) {
            return;
        }
        self.stats.triggers += 1;
        let packed = select_packed_input(ev, stats);
        let id = flow_id(&ev.packet);
        if self.batcher.is_some() {
            let full = self
                .batcher
                .as_mut()
                .unwrap()
                .push(ev.packet.ts_ns, PendingFlow { id, packed });
            if let Some(batch) = full {
                self.flush_batch(batch, ev.packet.ts_ns);
            }
        } else {
            let class = self.exec.classify(&packed);
            let latency_ns = self.exec.latency_ns();
            self.finish_inference(id, class, latency_ns);
        }
    }

    /// Drain any batched-but-unflushed flows (end of stream / shutdown).
    pub fn flush(&mut self) {
        let batch = self.batcher.as_mut().and_then(|b| b.poll(f64::INFINITY));
        if let Some(batch) = batch {
            // Best "now" available at shutdown: the newest enqueue time.
            let now_ns = batch.last().map_or(0.0, |&(t, _)| t);
            self.flush_batch(batch, now_ns);
        }
    }

    /// Run one accumulated batch through the executor's batch fast path
    /// and account every verdict.  Per-flow latency is the queueing wait
    /// on the packet clock (`now_ns - enqueue`) plus the modeled
    /// completion time of the *whole* batch (every item waits for the
    /// batch to finish) — batching's latency price stays visible in the
    /// histogram (Fig. 6's trade-off) instead of silently vanishing.
    fn flush_batch(&mut self, batch: Vec<(f64, PendingFlow)>, now_ns: f64) {
        self.batch_meta.clear();
        self.batch_inputs.clear();
        for (enq_ns, flow) in batch {
            self.batch_meta.push((flow.id, enq_ns));
            self.batch_inputs.push(flow.packed);
        }
        let inputs = std::mem::take(&mut self.batch_inputs);
        let mut classes = std::mem::take(&mut self.batch_classes);
        self.exec.classify_batch(&inputs, &mut classes);
        let exec_ns = self.exec.batch_latency_ns(classes.len());
        for i in 0..classes.len() {
            let (id, enq_ns) = self.batch_meta[i];
            let latency_ns = batch_item_latency_ns(now_ns, enq_ns, exec_ns);
            self.finish_inference(id, classes[i], latency_ns);
        }
        self.batch_inputs = inputs;
        self.batch_classes = classes;
    }

    /// Account one verdict: stats, histogram (grown on demand), sink.
    fn finish_inference(&mut self, id: u64, class: usize, latency_ns: f64) {
        self.stats.inferences += 1;
        if class >= self.stats.classes.len() {
            self.stats.classes.resize(class + 1, 0);
        }
        self.stats.classes[class] += 1;
        self.stats.latency.record(latency_ns);
        self.sink.write(self.output, id, class);
    }

    /// Event loop: drain an mpsc channel until all senders drop; returns
    /// the accumulated statistics.  Run it on a dedicated thread; the
    /// traffic source(s) feed the channel from other threads (the NIC
    /// event-queue shape).  Any partial batch is flushed at shutdown.
    pub fn run(mut self, rx: mpsc::Receiver<PacketEvent>) -> ServiceStats {
        while let Ok(ev) = rx.recv() {
            self.handle(&ev);
        }
        self.flush();
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::BnnModel;
    use crate::coordinator::CoreExecutor;
    use crate::net::traffic::{CbrSpec, TrafficGen};

    fn service() -> CoordinatorService<CoreExecutor> {
        let model = BnnModel::random("traffic", 256, &[32, 16, 2], 1);
        CoordinatorService::new(
            CoreExecutor::fpga(model),
            TriggerCondition::EveryNPackets(10),
            OutputSelector::Memory,
        )
    }

    #[test]
    fn trigger_fires_once_per_flow_at_10_packets() {
        let mut svc = service();
        let mut gen = TrafficGen::new(CbrSpec { gbps: 10.0, pkt_size: 256 }, 50, 3);
        for _ in 0..5000 {
            let p = gen.next_packet();
            svc.handle(&PacketEvent { packet: p, payload_words: None });
        }
        assert_eq!(svc.stats.packets, 5000);
        assert!(svc.stats.triggers > 0);
        assert_eq!(svc.stats.triggers, svc.stats.inferences);
        // Every verdict was written to memory (the configured selector).
        assert_eq!(svc.sink.memory.len() as u64, svc.stats.inferences);
        assert!(svc.sink.inline_tags.is_empty());
        // Each flow triggers at most once (exactly at packet #10).
        assert!(svc.stats.triggers <= 50);
    }

    #[test]
    fn event_loop_drains_channel() {
        let svc = service();
        let (tx, rx) = mpsc::channel();
        let mut gen = TrafficGen::new(CbrSpec { gbps: 10.0, pkt_size: 256 }, 10, 4);
        let feeder = std::thread::spawn(move || {
            for _ in 0..500 {
                let p = gen.next_packet();
                tx.send(PacketEvent { packet: p, payload_words: None }).unwrap();
            }
        });
        let consumer = std::thread::spawn(move || svc.run(rx));
        feeder.join().unwrap();
        let stats = consumer.join().unwrap();
        assert_eq!(stats.packets, 500);
    }

    #[test]
    fn histogram_width_comes_from_model() {
        let svc = service();
        // traffic model has 2 output neurons → 2 counters, not 8.
        assert_eq!(svc.stats.classes.len(), 2);
    }

    #[test]
    fn batched_route_matches_unbatched() {
        let mut gen = TrafficGen::new(CbrSpec { gbps: 10.0, pkt_size: 256 }, 40, 6);
        let events: Vec<PacketEvent> = (0..4000)
            .map(|_| PacketEvent { packet: gen.next_packet(), payload_words: None })
            .collect();
        let mut plain = service();
        for ev in &events {
            plain.handle(ev);
        }
        let mut batched = service().with_batching(7, 1e12);
        for ev in &events {
            batched.handle(ev);
        }
        batched.flush();
        assert_eq!(batched.pending(), 0);
        assert_eq!(batched.stats.triggers, plain.stats.triggers);
        assert_eq!(batched.stats.inferences, plain.stats.inferences);
        assert_eq!(batched.stats.classes, plain.stats.classes);
        // Same verdicts for the same flows, order aside.
        let mut a = plain.sink.memory.clone();
        let mut b = batched.sink.memory.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn stats_merge_accumulates_and_grows() {
        let mut a = ServiceStats {
            packets: 10,
            triggers: 2,
            inferences: 2,
            classes: vec![1, 1],
            stage_blocked: vec![3],
            ..Default::default()
        };
        a.latency.record(100.0);
        let mut b = ServiceStats {
            packets: 5,
            triggers: 1,
            inferences: 1,
            classes: vec![0, 0, 7],
            stage_blocked: vec![1, 4],
            ..Default::default()
        };
        b.latency.record(900.0);
        a.merge(&b);
        assert_eq!(a.packets, 15);
        assert_eq!(a.triggers, 3);
        assert_eq!(a.inferences, 3);
        assert_eq!(a.classes, vec![1, 1, 7]);
        assert_eq!(a.stage_blocked, vec![4, 4]);
        assert_eq!(a.latency.count(), 2);
    }

    #[test]
    fn batcher_timeout_flushes_on_packet_clock() {
        // Huge batch size, tiny timeout: flows must still drain.
        let mut svc = service().with_batching(1 << 20, 1.0);
        let mut gen = TrafficGen::new(CbrSpec { gbps: 10.0, pkt_size: 256 }, 5, 8);
        for _ in 0..2000 {
            let p = gen.next_packet();
            svc.handle(&PacketEvent { packet: p, payload_words: None });
        }
        svc.flush();
        assert_eq!(svc.stats.inferences, svc.stats.triggers);
    }
}

//! Multi-NN scheduling on one NIC (§7: "it is possible to include
//! multiple [executor modules] if the need arises" / the tomography use
//! case runs one NN per monitored queue).
//!
//! Models a bank of executor slots (FPGA modules, or NFP thread groups)
//! serving a set of deployed NNs round-robin, and answers the §6.2
//! question: how many NNs fit a probe period on a given backend?

use crate::bnn::{BnnExecutor, BnnModel};

/// A set of deployed models sharing `slots` hardware executors.
pub struct MultiNnScheduler {
    execs: Vec<BnnExecutor>,
    /// Per-model device latency (ns) — from the backend timing model.
    latency_ns: Vec<f64>,
    /// Parallel executor slots (FPGA modules / chain instances).
    pub slots: usize,
}

impl MultiNnScheduler {
    pub fn new(models: Vec<(BnnModel, f64)>, slots: usize) -> Self {
        let (execs, latency_ns): (Vec<_>, Vec<_>) = models
            .into_iter()
            .map(|(m, l)| (BnnExecutor::new(m), l))
            .unzip();
        Self {
            execs,
            latency_ns,
            slots: slots.max(1),
        }
    }

    pub fn len(&self) -> usize {
        self.execs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.execs.is_empty()
    }

    /// Run every deployed NN on its input slice; returns argmax classes.
    /// (Functionally sequential — device parallelism only affects time.)
    pub fn classify_all(&mut self, inputs: &[Vec<u32>]) -> Vec<usize> {
        assert_eq!(inputs.len(), self.execs.len());
        self.execs
            .iter_mut()
            .zip(inputs)
            .map(|(e, x)| e.classify(x))
            .collect()
    }

    /// Makespan of one sweep over all NNs with `slots` parallel executors
    /// (longest-processing-time greedy — the static schedule a NIC would
    /// bake in).
    pub fn sweep_latency_ns(&self) -> f64 {
        let mut order: Vec<f64> = self.latency_ns.clone();
        order.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let mut loads = vec![0.0f64; self.slots];
        for l in order {
            // place on least-loaded slot
            let (i, _) = loads
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            loads[i] += l;
        }
        loads.into_iter().fold(0.0, f64::max)
    }

    /// Max NNs of uniform latency `l` that fit `period_ns` on `slots`.
    pub fn capacity(l_ns: f64, slots: usize, period_ns: f64) -> usize {
        if l_ns <= 0.0 {
            return usize::MAX;
        }
        ((period_ns / l_ns).floor() as usize) * slots.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::FpgaTiming;

    fn tomo_bank(n: usize, slots: usize) -> MultiNnScheduler {
        let models: Vec<(BnnModel, f64)> = (0..n)
            .map(|q| {
                let m = BnnModel::random(&format!("q{q}"), 152, &[128, 64, 2], q as u64);
                let l = FpgaTiming::new(&m).latency_ns();
                (m, l)
            })
            .collect();
        MultiNnScheduler::new(models, slots)
    }

    #[test]
    fn seventeen_queues_fit_400g_on_two_modules() {
        // 17 × ~1.7 µs serial = ~28 µs > 25 µs budget on one module;
        // two modules halve the sweep → fits (the §7 scaling argument).
        let one = tomo_bank(17, 1);
        let two = tomo_bank(17, 2);
        assert!(one.sweep_latency_ns() > 25_000.0, "{}", one.sweep_latency_ns());
        assert!(two.sweep_latency_ns() <= 25_000.0, "{}", two.sweep_latency_ns());
    }

    #[test]
    fn sweep_latency_scales_inverse_with_slots() {
        let b1 = tomo_bank(16, 1).sweep_latency_ns();
        let b4 = tomo_bank(16, 4).sweep_latency_ns();
        assert!((b1 / b4 - 4.0).abs() < 0.2, "{b1} vs {b4}");
    }

    #[test]
    fn classify_all_matches_individual_executors() {
        let mut bank = tomo_bank(5, 2);
        let inputs: Vec<Vec<u32>> = (0..5)
            .map(|i| crate::bnn::BnnLayer::random(1, 152, 100 + i).words)
            .collect();
        let got = bank.classify_all(&inputs);
        for (q, x) in inputs.iter().enumerate() {
            let m = BnnModel::random(&format!("q{q}"), 152, &[128, 64, 2], q as u64);
            assert_eq!(got[q], crate::bnn::infer_packed(&m, x));
        }
    }

    #[test]
    fn capacity_arithmetic() {
        assert_eq!(MultiNnScheduler::capacity(1_700.0, 1, 25_000.0), 14);
        assert_eq!(MultiNnScheduler::capacity(1_700.0, 4, 25_000.0), 56);
    }
}

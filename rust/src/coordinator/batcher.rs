//! Batching policy for the host path (Fig. 6): batches amortize PCIe and
//! dispatch overheads at the price of queueing latency — the trade-off
//! N3IC exists to avoid.

/// Size/timeout batcher: emits a batch when `max_size` is reached or the
/// oldest element is older than `max_wait_ns`.
#[derive(Debug)]
pub struct Batcher<T> {
    pub max_size: usize,
    pub max_wait_ns: f64,
    buf: Vec<(f64, T)>,
}

impl<T> Batcher<T> {
    pub fn new(max_size: usize, max_wait_ns: f64) -> Self {
        Self {
            max_size: max_size.max(1),
            max_wait_ns,
            buf: Vec::new(),
        }
    }

    /// Push an item at time `now_ns`; returns a full batch if ready.
    pub fn push(&mut self, now_ns: f64, item: T) -> Option<Vec<(f64, T)>> {
        self.buf.push((now_ns, item));
        if self.buf.len() >= self.max_size {
            return Some(std::mem::take(&mut self.buf));
        }
        None
    }

    /// Time-based flush: call with the current time; emits if the oldest
    /// item has waited too long.
    pub fn poll(&mut self, now_ns: f64) -> Option<Vec<(f64, T)>> {
        match self.buf.first() {
            Some(&(t0, _)) if now_ns - t0 >= self.max_wait_ns => {
                Some(std::mem::take(&mut self.buf))
            }
            _ => None,
        }
    }

    pub fn pending(&self) -> usize {
        self.buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_trigger() {
        let mut b = Batcher::new(3, 1e9);
        assert!(b.push(0.0, "a").is_none());
        assert!(b.push(1.0, "b").is_none());
        let batch = b.push(2.0, "c").unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn timeout_trigger() {
        let mut b = Batcher::new(100, 1000.0);
        b.push(0.0, 1u32);
        b.push(10.0, 2);
        assert!(b.poll(500.0).is_none());
        let batch = b.poll(1000.0).unwrap();
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn empty_poll_is_none() {
        let mut b: Batcher<u32> = Batcher::new(4, 10.0);
        assert!(b.poll(1e12).is_none());
    }
}

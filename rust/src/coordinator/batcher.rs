//! Batching policy for the host path (Fig. 6): batches amortize PCIe and
//! dispatch overheads at the price of queueing latency — the trade-off
//! N3IC exists to avoid.

/// Size/timeout batcher: emits a batch when `max_size` is reached or the
/// oldest element is older than `max_wait_ns`.
#[derive(Debug)]
pub struct Batcher<T> {
    pub max_size: usize,
    pub max_wait_ns: f64,
    buf: Vec<(f64, T)>,
}

impl<T> Batcher<T> {
    pub fn new(max_size: usize, max_wait_ns: f64) -> Self {
        Self {
            max_size: max_size.max(1),
            max_wait_ns,
            buf: Vec::new(),
        }
    }

    /// Push an item at time `now_ns`; returns a full batch if ready.
    pub fn push(&mut self, now_ns: f64, item: T) -> Option<Vec<(f64, T)>> {
        self.buf.push((now_ns, item));
        if self.buf.len() >= self.max_size {
            return Some(std::mem::take(&mut self.buf));
        }
        None
    }

    /// Time-based flush: call with the current time; emits if the oldest
    /// item has waited too long.
    pub fn poll(&mut self, now_ns: f64) -> Option<Vec<(f64, T)>> {
        match self.buf.first() {
            Some(&(t0, _)) if now_ns - t0 >= self.max_wait_ns => {
                Some(std::mem::take(&mut self.buf))
            }
            _ => None,
        }
    }

    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Enqueue time of the oldest waiting item (`None` when empty) — the
    /// overload ladder reads this as queueing-pressure evidence.
    pub fn oldest_enqueue_ns(&self) -> Option<f64> {
        self.buf.first().map(|&(t, _)| t)
    }
}

/// One emitted batch: `(enqueue time, item)` pairs in arrival order.
pub type TimedBatch<T> = Vec<(f64, T)>;

/// A bank of [`Batcher`]s, one **lane per routed model**, sharing one
/// size/timeout policy — the multi-model registry's per-model batching:
/// a batch never mixes flows routed to different models, so each batch
/// can pin exactly one model epoch.
#[derive(Debug)]
pub struct BatchSet<T> {
    lanes: Vec<Batcher<T>>,
}

impl<T> BatchSet<T> {
    pub fn new(n_lanes: usize, max_size: usize, max_wait_ns: f64) -> Self {
        Self {
            lanes: (0..n_lanes.max(1))
                .map(|_| Batcher::new(max_size, max_wait_ns))
                .collect(),
        }
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Push onto one lane; returns that lane's batch if it filled.
    pub fn push(&mut self, lane: usize, now_ns: f64, item: T) -> Option<TimedBatch<T>> {
        self.lanes[lane].push(now_ns, item)
    }

    /// Time-based flush across every lane: each lane whose oldest item
    /// has waited past the deadline emits, tagged with its lane index.
    /// Returns an empty `Vec` (no allocation) in the common nothing-due
    /// case.
    pub fn poll(&mut self, now_ns: f64) -> Vec<(usize, TimedBatch<T>)> {
        let mut due = Vec::new();
        for (lane, b) in self.lanes.iter_mut().enumerate() {
            if let Some(batch) = b.poll(now_ns) {
                due.push((lane, batch));
            }
        }
        due
    }

    /// Items waiting across all lanes.
    pub fn pending(&self) -> usize {
        self.lanes.iter().map(Batcher::pending).sum()
    }

    pub fn pending_lane(&self, lane: usize) -> usize {
        self.lanes[lane].pending()
    }

    /// Oldest enqueue time across every lane (`None` when all empty).
    pub fn oldest_enqueue_ns(&self) -> Option<f64> {
        self.lanes
            .iter()
            .filter_map(Batcher::oldest_enqueue_ns)
            .min_by(|a, b| a.partial_cmp(b).expect("enqueue times are never NaN"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_trigger() {
        let mut b = Batcher::new(3, 1e9);
        assert!(b.push(0.0, "a").is_none());
        assert!(b.push(1.0, "b").is_none());
        let batch = b.push(2.0, "c").unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn timeout_trigger() {
        let mut b = Batcher::new(100, 1000.0);
        b.push(0.0, 1u32);
        b.push(10.0, 2);
        assert!(b.poll(500.0).is_none());
        let batch = b.poll(1000.0).unwrap();
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn empty_poll_is_none() {
        let mut b: Batcher<u32> = Batcher::new(4, 10.0);
        assert!(b.poll(1e12).is_none());
    }

    #[test]
    fn partial_flush_preserves_arrival_order() {
        let mut b = Batcher::new(100, 50.0);
        for i in 0..7u32 {
            assert!(b.push(i as f64, i).is_none());
        }
        let batch = b.poll(60.0).expect("timeout flush");
        let items: Vec<u32> = batch.iter().map(|&(_, v)| v).collect();
        assert_eq!(items, vec![0, 1, 2, 3, 4, 5, 6]);
        // Enqueue timestamps ride along, also in order.
        let ts: Vec<f64> = batch.iter().map(|&(t, _)| t).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn size_flush_preserves_arrival_order() {
        let mut b = Batcher::new(5, 1e12);
        let mut full = None;
        for i in 0..5u32 {
            full = b.push(i as f64, i);
        }
        let items: Vec<u32> = full.unwrap().into_iter().map(|(_, v)| v).collect();
        assert_eq!(items, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn zero_timeout_flushes_on_first_poll() {
        // max_wait_ns = 0: any pending item is already too old, so the
        // batcher degrades to "flush at every clock tick" — never to
        // "drop" or "hang".
        let mut b = Batcher::new(1 << 20, 0.0);
        b.push(100.0, "x");
        let batch = b.poll(100.0).expect("zero timeout must flush at now == enqueue");
        assert_eq!(batch.len(), 1);
        assert_eq!(b.pending(), 0);
        // And again for the next item — state fully reset.
        b.push(200.0, "y");
        assert_eq!(b.poll(200.0).unwrap().len(), 1);
    }

    #[test]
    fn batch_of_one_emits_immediately() {
        let mut b = Batcher::new(1, 1e12);
        for i in 0..4u32 {
            let batch = b.push(i as f64, i).expect("size-1 batch fills on every push");
            assert_eq!(batch.len(), 1);
            assert_eq!(batch[0].1, i);
            assert_eq!(b.pending(), 0);
        }
        // Batcher::new clamps 0 to 1, so the degenerate config behaves
        // the same way instead of never emitting.
        let mut z = Batcher::new(0, 1e12);
        assert!(z.push(0.0, 9u32).is_some());
    }

    #[test]
    fn batch_set_lanes_fill_independently() {
        let mut s: BatchSet<u32> = BatchSet::new(2, 3, 1e9);
        assert_eq!(s.n_lanes(), 2);
        assert!(s.push(0, 0.0, 1).is_none());
        assert!(s.push(1, 1.0, 100).is_none());
        assert!(s.push(0, 2.0, 2).is_none());
        assert_eq!(s.pending(), 3);
        assert_eq!(s.pending_lane(0), 2);
        // Lane 0 fills without disturbing lane 1.
        let full = s.push(0, 3.0, 3).expect("lane 0 full");
        assert_eq!(full.iter().map(|&(_, v)| v).collect::<Vec<_>>(), [1, 2, 3]);
        assert_eq!(s.pending_lane(0), 0);
        assert_eq!(s.pending_lane(1), 1);
    }

    #[test]
    fn batch_set_poll_emits_only_due_lanes_tagged_with_their_index() {
        let mut s: BatchSet<&str> = BatchSet::new(3, 100, 50.0);
        s.push(0, 0.0, "old");
        s.push(2, 40.0, "young");
        // At t=55 only lane 0's oldest item crossed the 50ns wait.
        let due = s.poll(55.0);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].0, 0);
        assert_eq!(due[0].1[0].1, "old");
        // Final drain picks up the rest, lane-tagged.
        let rest = s.poll(f64::INFINITY);
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].0, 2);
        assert_eq!(s.pending(), 0);
        assert!(s.poll(f64::INFINITY).is_empty());
    }

    #[test]
    fn oldest_enqueue_tracks_the_head_across_lanes() {
        let mut s: BatchSet<&str> = BatchSet::new(2, 100, 1e9);
        assert_eq!(s.oldest_enqueue_ns(), None);
        s.push(1, 50.0, "later");
        s.push(0, 10.0, "earliest");
        s.push(0, 70.0, "newest");
        assert_eq!(s.oldest_enqueue_ns(), Some(10.0));
        // Draining lane 0 moves the head to lane 1's oldest.
        let _ = s.poll(f64::INFINITY);
        assert_eq!(s.oldest_enqueue_ns(), None);
    }

    #[test]
    fn no_item_is_ever_dropped_across_mixed_flushes() {
        // Interleave size flushes, timeout flushes, and a final drain;
        // every pushed item must come out exactly once, in order.
        let mut b = Batcher::new(3, 10.0);
        let mut out: Vec<u32> = Vec::new();
        let mut drain = |batch: Option<Vec<(f64, u32)>>, out: &mut Vec<u32>| {
            if let Some(batch) = batch {
                out.extend(batch.into_iter().map(|(_, v)| v));
            }
        };
        for i in 0..100u32 {
            let now = i as f64 * 4.0; // every ~3rd poll crosses the 10ns wait
            let timed = b.poll(now);
            drain(timed, &mut out);
            let full = b.push(now, i);
            drain(full, &mut out);
        }
        drain(b.poll(f64::INFINITY), &mut out);
        assert_eq!(b.pending(), 0);
        assert_eq!(out, (0..100).collect::<Vec<u32>>());
    }
}

//! Named backend construction: every executor in the crate registered
//! behind one [`BackendFactory`], each returning a boxed
//! [`InferencePlane`] the unified [`Service`](super::Service) composes
//! against.
//!
//! | name       | execution path                          | batch path            | notes |
//! |------------|-----------------------------------------|-----------------------|-------|
//! | `host`     | bit-exact core, host latency + PCIe     | weight-stationary kernel, calibrated Haswell batch-cost curve | the paper's `bnn-exec` comparison term |
//! | `batch`    | bit-exact core                          | weight-stationary [`BatchKernel`] | single core |
//! | `sharded`  | bit-exact core                          | multi-core [`ShardedEngine`] | `shards` worker threads |
//! | `pisa`     | PISA pipeline **interpreter** (NNtoP4)  | none (`max_batch = 1`, inline) | fails for models over the PHV budget |
//! | `fpga`     | bit-exact core, FPGA module timing      | weight-stationary kernel | §4.3 device model latency |
//! | `nfp`      | bit-exact core, NFP data-parallel timing| weight-stationary kernel | alias kept for the `serve` CLI |
//! | `placed`   | cost-aware [`PlacedPlane`] over fpga/sharded/host (+pisa when it compiles) | cheapest healthy member per batch width | per-member circuit breakers + failover |
//! | `qmlp`     | fixed-point i32 [`QmlpExecutor`] (Q-format, Taylor activations) | serial (inline per input) | P4-FPGA SmartNIC executor shape |
//! | `registry` | versioned [`MultiModelExecutor`]        | per-epoch kernel / engine | hot swap + epoch pinning |
//!
//! All of them produce Algorithm 1's verdicts bit-exactly — the BNN
//! planes compute it directly; `qmlp` computes the quantized-MLP
//! equivalent whose verdicts are provably identical
//! ([`QuantMlp::from_bnn`](crate::qmlp::QuantMlp::from_bnn)).  The
//! conformance suite (`tests/plane_conformance.rs`) asserts identical
//! verdict histograms across every row of this table.

use std::sync::Arc;

use crate::bnn::{
    argmax, BatchKernel, BnnExecutor, BnnModel, EngineError, EngineStats, MultiModelExecutor,
    RegistryError, RegistryHandle, ShardedEngine, VersionTag,
};
use crate::bnnexec::HostCostModel;
use crate::pisa::PisaProgram;
use crate::qmlp::{QmlpExecutor, QMLP_FRAC_BITS};

use super::overload::{BreakerPolicy, PlacedPlane};
use super::plane::{Capabilities, InferencePlane, SwapController};
use super::service::ServiceError;

/// Constructs [`InferencePlane`]s by registered name.
pub struct BackendFactory;

impl BackendFactory {
    /// Every registered backend name, in capability-table order.
    pub const BACKENDS: [&'static str; 8] =
        ["host", "batch", "sharded", "pisa", "fpga", "placed", "qmlp", "registry"];

    /// Build a single-model backend by name (single-core batch path
    /// where one applies; see [`single_sharded`](Self::single_sharded)).
    pub fn single(name: &str, model: BnnModel) -> Result<Box<dyn InferencePlane>, ServiceError> {
        Self::single_sharded(name, model, 1)
    }

    /// Build a single-model backend by name with `shards` worker cores
    /// behind the batch path (`shards <= 1` keeps the single-core
    /// kernel; the `"sharded"` backend always runs at least 2).  The
    /// `"registry"` name needs slots and goes through
    /// [`registry`](Self::registry); `"pisa"` has no batch path to
    /// shard.
    pub fn single_sharded(
        name: &str,
        model: BnnModel,
        shards: usize,
    ) -> Result<Box<dyn InferencePlane>, ServiceError> {
        let host_cost = HostCostModel::default();
        match name {
            "host" | "bnn-exec" => {
                let lat = host_cost.batch_latency_ns(&model, 1);
                Ok(Box::new(CorePlane::new(
                    "host",
                    model,
                    lat,
                    BatchCost::Host(host_cost),
                    shards,
                )))
            }
            // `batch` / `sharded` are the *raw* kernel and engine planes
            // (no PCIe in the loop), so their batch cost scales serially
            // from the same per-inference figure — continuous between
            // inline and batched serving.  `host` above is the paper's
            // comparison term and keeps the full PCIe + per-batch I/O
            // curve on both halves.
            "batch" => {
                let lat = host_cost.inference_ns(&model);
                Ok(Box::new(CorePlane::new(
                    "batch",
                    model,
                    lat,
                    BatchCost::Serial,
                    shards,
                )))
            }
            "sharded" => {
                let lat = host_cost.inference_ns(&model);
                Ok(Box::new(CorePlane::new(
                    "sharded",
                    model,
                    lat,
                    BatchCost::Serial,
                    shards.max(2),
                )))
            }
            "fpga" => {
                let lat = crate::fpga::FpgaTiming::new(&model).latency_ns();
                Ok(Box::new(CorePlane::new(
                    "fpga",
                    model,
                    lat,
                    BatchCost::Serial,
                    shards,
                )))
            }
            "nfp" => {
                let lat = crate::nfp::DataParallelCost::new(&model, crate::nfp::MemKind::Cls)
                    .mean_ns();
                Ok(Box::new(CorePlane::new(
                    "nfp",
                    model,
                    lat,
                    BatchCost::Serial,
                    shards,
                )))
            }
            "pisa" | "p4" => {
                if shards > 1 {
                    return Err(ServiceError::Config(
                        "the pisa backend classifies inline and has no batch path to shard"
                            .into(),
                    ));
                }
                let prog = crate::pisa::compile_bnn(&model)?;
                let latency_ns = prog.latency_ns(64);
                Ok(Box::new(PisaPlane {
                    prog,
                    n_classes: model.out_neurons(),
                    latency_ns,
                }))
            }
            // The placement plane: the same model on every data plane the
            // host has, fronted by per-member breakers.  Mice (inline
            // classifies) land on the fpga device model, elephants (wide
            // batches) on the sharded host engine; pisa joins when the
            // model fits its PHV budget.  All members are bit-exact, so
            // placement and failover never change verdicts.
            "placed" => {
                let mut members: Vec<Box<dyn InferencePlane>> =
                    vec![Self::single("fpga", model.clone())?];
                if let Ok(pisa) = Self::single("pisa", model.clone()) {
                    members.push(pisa);
                }
                members.push(Self::single_sharded("sharded", model.clone(), shards.max(2))?);
                members.push(Self::single("host", model)?);
                Ok(Box::new(PlacedPlane::new(members, BreakerPolicy::default())?))
            }
            // The quantized-MLP executor (P4-FPGA SmartNIC shape):
            // fixed-point i32 layers with Taylor activations, built from
            // the BNN by the verdict-preserving `from_bnn` quantization.
            // It scores each input serially (no tiled batch kernel), so
            // like pisa there is nothing to shard.
            "qmlp" => {
                if shards > 1 {
                    return Err(ServiceError::Config(
                        "the qmlp backend scores serially and has no batch path to shard".into(),
                    ));
                }
                let latency_ns = qmlp_latency_ns(&model);
                let exec = QmlpExecutor::from_bnn(&model, QMLP_FRAC_BITS)
                    .map_err(|e| ServiceError::Config(format!("qmlp quantization: {e}")))?;
                Ok(Box::new(QmlpPlane { exec, latency_ns }))
            }
            "registry" => Err(ServiceError::Config(
                "the registry backend serves named slots: publish models into a \
                 RegistryHandle and use BackendFactory::registry"
                    .into(),
            )),
            other => Err(ServiceError::UnknownBackend { name: other.to_string() }),
        }
    }

    /// Kernel-backed plane with a caller-measured latency — the PJRT
    /// route, where the device latency comes from running the AOT
    /// artifact rather than an analytic model.  `shards > 1` fans the
    /// batch path out over a [`ShardedEngine`], as for the analytic
    /// backends.
    pub fn custom(
        name: &'static str,
        model: BnnModel,
        latency_ns: f64,
        shards: usize,
    ) -> Box<dyn InferencePlane> {
        Box::new(CorePlane::new(name, model, latency_ns, BatchCost::Serial, shards))
    }

    /// The registry-backed multi-model plane: binds `names` (all must be
    /// published in `registry`), pins one epoch per inference or batch,
    /// tags every verdict, and hands the runtime a [`SwapController`]
    /// for live republishes.  `shards > 1` spreads each batch over a
    /// [`ShardedEngine`] (every batch still pins exactly one epoch
    /// across all shards).
    pub fn registry(
        registry: &RegistryHandle,
        names: &[String],
        latency_ns: f64,
        shards: usize,
    ) -> Result<Box<dyn InferencePlane>, ServiceError> {
        registry_plane(registry, names, latency_ns, shards).map_err(ServiceError::Registry)
    }
}

/// Crate-internal registry-plane constructor that keeps the
/// [`RegistryError`] type for callers that need to distinguish registry
/// faults from config errors.
pub(crate) fn registry_plane(
    registry: &RegistryHandle,
    names: &[String],
    latency_ns: f64,
    shards: usize,
) -> Result<Box<dyn InferencePlane>, RegistryError> {
    let mut exec = MultiModelExecutor::new(registry, names, latency_ns)?;
    if shards > 1 {
        exec = exec.sharded(shards);
    }
    Ok(Box::new(RegistryPlane {
        exec,
        registry: registry.clone(),
        names: names.to_vec(),
        shards: shards.max(1),
    }))
}

/// How a backend's batch completion time is modeled — the concrete
/// cost-model hook behind [`InferencePlane::batch_latency_ns`].
enum BatchCost {
    /// Serial device: `b ×` per-inference latency.
    Serial,
    /// Calibrated host curve: PCIe fetch/writeback + per-batch I/O +
    /// per-flow dispatch (§6.1's Haswell anchors) — batching amortizes
    /// fixed costs, which is the whole Fig. 6 trade-off.
    Host(HostCostModel),
}

/// The kernel-backed single-model plane: bit-exact single-input core +
/// weight-stationary batch kernel (optionally fanned out over a
/// [`ShardedEngine`]), sharing one `Arc` of packed weights, wearing a
/// backend-specific latency model.
struct CorePlane {
    backend: &'static str,
    exec: BnnExecutor,
    kernel: BatchKernel,
    engine: Option<ShardedEngine>,
    latency_ns: f64,
    cost: BatchCost,
}

impl CorePlane {
    fn new(
        backend: &'static str,
        model: BnnModel,
        latency_ns: f64,
        cost: BatchCost,
        shards: usize,
    ) -> Self {
        let exec = BnnExecutor::new(model);
        let kernel = BatchKernel::with_packed(exec.packed_model());
        let engine = (shards > 1)
            .then(|| ShardedEngine::with_packed(exec.packed_model(), shards));
        Self { backend, exec, kernel, engine, latency_ns, cost }
    }
}

impl InferencePlane for CorePlane {
    fn capabilities(&self) -> Capabilities {
        Capabilities {
            shards: self.engine.as_ref().map_or(1, ShardedEngine::n_shards),
            simd_lanes: self.kernel.simd_lanes(),
            ..Capabilities::single(self.backend, self.latency_ns)
        }
    }

    fn classify(&mut self, _route: usize, x: &[u32]) -> (usize, Option<VersionTag>) {
        (self.exec.classify(x), None)
    }

    fn try_run_batch(
        &mut self,
        _route: usize,
        inputs: &[Vec<u32>],
        classes: &mut Vec<usize>,
    ) -> Result<Option<VersionTag>, EngineError> {
        match self.engine.as_mut() {
            Some(engine) => {
                engine.try_run_batch_shared(&Arc::new(inputs.to_vec()), classes)?;
            }
            None => self.kernel.run_batch(inputs, classes),
        }
        Ok(None)
    }

    fn batch_latency_ns(&self, b: usize) -> f64 {
        match &self.cost {
            // A sharded engine retires a batch in parallel, so the
            // modeled completion divides by the worker count — without
            // this the placer would see a 4-core engine as no cheaper
            // than one core and never route elephants to it.
            BatchCost::Serial => {
                let shards = self.engine.as_ref().map_or(1, ShardedEngine::n_shards);
                self.latency_ns * b as f64 / shards as f64
            }
            BatchCost::Host(m) => m.batch_latency_ns(self.exec.model(), b),
        }
    }

    fn n_classes(&self) -> usize {
        self.exec.model().out_neurons()
    }

    fn engine_stats(&self) -> Option<EngineStats> {
        self.engine.as_ref().map(|e| e.stats())
    }
}

/// The PISA plane runs the **compiled NNtoP4 program** through the
/// match-action interpreter — a genuinely different execution path from
/// the host kernel, asserted bit-identical to it by the conformance
/// suite.  A PISA switch classifies strictly inline (one packet, one
/// pipeline traversal), so `max_batch = 1`: capability-driven selection
/// makes the builder reject batched configs instead of silently
/// emulating them.
struct PisaPlane {
    prog: PisaProgram,
    n_classes: usize,
    latency_ns: f64,
}

impl InferencePlane for PisaPlane {
    fn capabilities(&self) -> Capabilities {
        Capabilities {
            max_batch: 1,
            ..Capabilities::single("pisa", self.latency_ns)
        }
    }

    fn classify(&mut self, _route: usize, x: &[u32]) -> (usize, Option<VersionTag>) {
        (argmax(&self.prog.run(x)), None)
    }

    fn try_run_batch(
        &mut self,
        _route: usize,
        inputs: &[Vec<u32>],
        classes: &mut Vec<usize>,
    ) -> Result<Option<VersionTag>, EngineError> {
        classes.clear();
        for x in inputs {
            classes.push(argmax(&self.prog.run(x)));
        }
        Ok(None)
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }
}

/// Modeled per-inference latency of the quantized-MLP executor: a fixed
/// dispatch cost plus the integer MAC stream at 4 MACs/ns — a host-CPU
/// figure in the same analytic spirit as the other backends' models
/// (the conformance suite only requires it to be positive).
fn qmlp_latency_ns(model: &BnnModel) -> f64 {
    30.0 + model.work_words() as f64 * 32.0 / 4.0
}

/// The fixed-point quantized-MLP plane: a [`QmlpExecutor`] built from
/// the BNN by the verdict-preserving quantization, scoring each input
/// serially (data-plane MLP executors pipeline packets, they don't
/// batch).  No shards, no swap machinery, scalar kernel — the
/// capability row is deliberately modest; what the backend buys is
/// scenario reach beyond pure BNNs.
struct QmlpPlane {
    exec: QmlpExecutor,
    latency_ns: f64,
}

impl InferencePlane for QmlpPlane {
    fn capabilities(&self) -> Capabilities {
        Capabilities::single("qmlp", self.latency_ns)
    }

    fn classify(&mut self, _route: usize, x: &[u32]) -> (usize, Option<VersionTag>) {
        (self.exec.classify(x), None)
    }

    fn try_run_batch(
        &mut self,
        _route: usize,
        inputs: &[Vec<u32>],
        classes: &mut Vec<usize>,
    ) -> Result<Option<VersionTag>, EngineError> {
        classes.clear();
        for x in inputs {
            classes.push(self.exec.classify(x));
        }
        Ok(None)
    }

    fn n_classes(&self) -> usize {
        self.exec.mlp().out_neurons()
    }
}

/// The registry-backed multi-model plane: one
/// [`MultiModelExecutor`] behind the unified surface.  Epoch pinning
/// and verdict tagging are the backend's own guarantees
/// (`tests/registry_swap.rs`); this adapter only threads them through.
struct RegistryPlane {
    exec: MultiModelExecutor,
    registry: RegistryHandle,
    names: Vec<String>,
    shards: usize,
}

impl InferencePlane for RegistryPlane {
    fn capabilities(&self) -> Capabilities {
        Capabilities {
            backend: "registry",
            max_batch: usize::MAX,
            shards: self.shards,
            routes: self.names.len(),
            supports_hot_swap: true,
            supports_epoch_pinning: true,
            inference_ns: self.exec.latency_ns(),
            simd_lanes: crate::bnn::simd::active_lanes(),
        }
    }

    fn classify(&mut self, route: usize, x: &[u32]) -> (usize, Option<VersionTag>) {
        let (class, tag) = self.exec.classify(route, x);
        (class, Some(tag))
    }

    fn try_run_batch(
        &mut self,
        route: usize,
        inputs: &[Vec<u32>],
        classes: &mut Vec<usize>,
    ) -> Result<Option<VersionTag>, EngineError> {
        let tag = self.exec.try_classify_batch(route, inputs, classes)?;
        Ok(Some(tag))
    }

    fn batch_latency_ns(&self, b: usize) -> f64 {
        self.exec.batch_latency_ns(b)
    }

    fn n_classes(&self) -> usize {
        self.exec.max_out_neurons()
    }

    fn route_names(&self) -> &[String] {
        &self.names
    }

    fn engine_stats(&self) -> Option<EngineStats> {
        self.exec.engine_stats()
    }

    fn swap_controller(&self) -> Option<SwapController> {
        Some(SwapController::new(self.registry.clone(), self.names.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::{infer_packed, BnnLayer};

    fn model() -> BnnModel {
        BnnModel::random("traffic", 256, &[32, 16, 2], 1)
    }

    #[test]
    fn unknown_backend_is_a_typed_error() {
        let err = BackendFactory::single("gpu", model()).unwrap_err();
        assert!(matches!(err, ServiceError::UnknownBackend { .. }), "{err}");
        let err = BackendFactory::single("registry", model()).unwrap_err();
        assert!(matches!(err, ServiceError::Config(_)), "{err}");
    }

    #[test]
    fn every_registered_backend_constructs_and_is_bit_exact() {
        let m = model();
        let xs: Vec<Vec<u32>> = (0..11)
            .map(|i| BnnLayer::random(1, 256, 600 + i).words)
            .collect();
        let want: Vec<usize> = xs.iter().map(|x| infer_packed(&m, x)).collect();
        let registry = RegistryHandle::new();
        registry.publish("traffic", &m).unwrap();
        for name in BackendFactory::BACKENDS {
            let mut plane = if name == "registry" {
                BackendFactory::registry(&registry, &["traffic".to_string()], 100.0, 1).unwrap()
            } else {
                BackendFactory::single(name, m.clone()).unwrap()
            };
            let caps = plane.capabilities();
            assert_eq!(caps.backend, name);
            assert_eq!(plane.n_classes(), 2, "{name}");
            for (x, &w) in xs.iter().zip(&want) {
                assert_eq!(plane.classify(0, x).0, w, "{name}");
            }
            if caps.max_batch >= xs.len() {
                let mut classes = Vec::new();
                let tag = plane.run_batch(0, &xs, &mut classes);
                assert_eq!(classes, want, "{name}");
                assert_eq!(tag.is_some(), caps.supports_epoch_pinning, "{name}");
            }
        }
    }

    #[test]
    fn capability_table_is_honest() {
        let m = model();
        let pisa = BackendFactory::single("pisa", m.clone()).unwrap();
        assert_eq!(pisa.capabilities().max_batch, 1);
        let sharded = BackendFactory::single_sharded("sharded", m.clone(), 3).unwrap();
        assert_eq!(sharded.capabilities().shards, 3);
        // "sharded" means sharded even without an explicit count.
        let implied = BackendFactory::single("sharded", m.clone()).unwrap();
        assert!(implied.capabilities().shards >= 2);
        assert!(BackendFactory::single_sharded("pisa", m.clone(), 2).is_err());
        assert!(BackendFactory::single_sharded("qmlp", m.clone(), 2).is_err());
        let qmlp = BackendFactory::single("qmlp", m.clone()).unwrap();
        assert_eq!(qmlp.capabilities().max_batch, usize::MAX, "serial loop, still batchable");
        assert!(qmlp.latency_ns() > 0.0);
        let registry = RegistryHandle::new();
        registry.publish("a", &m).unwrap();
        registry.publish("b", &BnnModel::random("b", 256, &[32, 16, 2], 9)).unwrap();
        let names = vec!["a".to_string(), "b".to_string()];
        let reg = BackendFactory::registry(&registry, &names, 100.0, 2).unwrap();
        let caps = reg.capabilities();
        assert!(caps.supports_hot_swap && caps.supports_epoch_pinning);
        assert_eq!(caps.routes, 2);
        assert_eq!(reg.route_names(), names.as_slice());
        assert!(reg.swap_controller().is_some());
        // Latency ordering sanity (Fig. 14): FPGA < PISA < NFP.
        let fpga = BackendFactory::single("fpga", m.clone()).unwrap();
        let pisa = BackendFactory::single("pisa", m.clone()).unwrap();
        let nfp = BackendFactory::single("nfp", m.clone()).unwrap();
        assert!(fpga.latency_ns() < pisa.latency_ns());
        assert!(pisa.latency_ns() < nfp.latency_ns());
        // Batch-1 host is in the 10s-of-µs neighbourhood (PCIe + I/O),
        // and its calibrated batch curve beats the serial extrapolation
        // at scale — the cost-model hook is a curve, not a multiplier.
        let host = BackendFactory::single("host", m).unwrap();
        assert!(host.latency_ns() > 10_000.0);
        assert!(host.batch_latency_ns(1000) < host.latency_ns() * 1000.0);
    }

    #[test]
    fn sharded_batch_cost_divides_by_worker_count() {
        let m = model();
        let one = BackendFactory::single("batch", m.clone()).unwrap();
        let four = BackendFactory::single_sharded("sharded", m.clone(), 4).unwrap();
        // Same per-inference figure, but four cores retire the batch 4×
        // faster under the serial cost model.
        assert!((one.batch_latency_ns(64) / four.batch_latency_ns(64) - 4.0).abs() < 1e-9);
        // Batch of one still costs one inference on either.
        assert!((one.batch_latency_ns(1) - one.latency_ns()).abs() < 1e-9);
    }

    #[test]
    fn placed_backend_fronts_bit_exact_members() {
        let m = model();
        let mut placed = BackendFactory::single("placed", m.clone()).unwrap();
        let caps = placed.capabilities();
        assert_eq!(caps.backend, "placed");
        assert!(!caps.supports_hot_swap && !caps.supports_epoch_pinning);
        assert_eq!(caps.routes, 1);
        let xs: Vec<Vec<u32>> = (0..8)
            .map(|i| BnnLayer::random(1, 256, 700 + i).words)
            .collect();
        let want: Vec<usize> = xs.iter().map(|x| infer_packed(&m, x)).collect();
        for (x, &w) in xs.iter().zip(&want) {
            assert_eq!(placed.classify(0, x).0, w);
        }
        let mut classes = Vec::new();
        assert!(placed.run_batch(0, &xs, &mut classes).is_none());
        assert_eq!(classes, want);
        let health = placed.health_snapshot().expect("placement plane reports health");
        assert!(health.iter().any(|h| h.calls > 0));
        assert!(health.iter().all(|h| h.trips == 0 && !h.open));
    }

    #[test]
    fn host_alias_matches_cli_vocabulary() {
        assert!(BackendFactory::single("bnn-exec", model()).is_ok());
        assert!(BackendFactory::single("nfp", model()).is_ok());
    }
}

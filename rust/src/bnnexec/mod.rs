//! `bnn-exec` — the host-CPU comparison system (§6 "Comparison term").
//!
//! Since the `InferencePlane` unification this module holds only the
//! **cost model** ([`HostCostModel`]): it reproduces the paper's Haswell
//! E5-1630v3 numbers (batch latency/throughput incl. the PCIe fetch of
//! flow statistics from the NIC and the result writeback), so figures
//! can be regenerated with the paper's absolute scales.  The *real*
//! host executor — Algorithm 1 with 64-bit popcounts and the
//! weight-stationary batch kernel — is the `"host"` backend of
//! [`BackendFactory`](crate::coordinator::BackendFactory), behind the
//! same [`InferencePlane`](crate::coordinator::InferencePlane) surface
//! as every device model (its batch cost hook *is* this model's curve).
//!
//! Cost-model calibration anchors (§6.1, Fig. 6/14, App. B.1.2): max
//! 1.18M flows/s on one core at batch 10k; ~1 ms latency at batch 1k and
//! ~8 ms at 10k; 10s of µs at batch 1; ~40 µs for one tomography probe
//! set; ~100 µs for a 4096×2048 FC (a quarter of N3IC-NFP's 400 µs).

use crate::bnn::BnnModel;
use crate::pcie::PcieModel;

/// Calibrated Haswell cost model.
#[derive(Debug, Clone, Copy)]
pub struct HostCostModel {
    /// Effective cost per 64-bit weight qword (XNOR+popcnt+load), ns.
    pub per_qword_ns: f64,
    /// Per-neuron overhead (threshold, pack), ns.
    pub per_neuron_ns: f64,
    /// Per-layer loop overhead, ns.
    pub per_layer_ns: f64,
    /// Per-flow dispatch overhead (stats copy, batching bookkeeping), ns.
    pub per_flow_ns: f64,
    /// Fixed per-batch I/O cost: PCIe descriptor rings + driver/syscall
    /// path to fetch statistics from the NIC and write the result back.
    pub per_batch_io_ns: f64,
    pub pcie: PcieModel,
}

impl Default for HostCostModel {
    fn default() -> Self {
        Self {
            per_qword_ns: 0.8,
            per_neuron_ns: 2.0,
            per_layer_ns: 120.0,
            per_flow_ns: 180.0,
            per_batch_io_ns: 20_000.0,
            pcie: PcieModel::default(),
        }
    }
}

impl HostCostModel {
    /// Pure inference time of one input on one core (ns).
    pub fn inference_ns(&self, model: &BnnModel) -> f64 {
        let mut t = 0.0;
        for layer in &model.layers {
            let qwords = layer.neurons * layer.in_words.div_ceil(2);
            t += qwords as f64 * self.per_qword_ns
                + layer.neurons as f64 * self.per_neuron_ns
                + self.per_layer_ns;
        }
        t
    }

    /// End-to-end latency of a batch of `b` flows (ns): PCIe fetch of
    /// `b × stats_bytes`, inference, result writeback.
    pub fn batch_latency_ns(&self, model: &BnnModel, b: usize) -> f64 {
        let stats_bytes = 32 * b; // 16×16b features per flow
        let fetch = self.pcie.transfer_ns(stats_bytes);
        let write = self.pcie.transfer_ns(b); // 1B class per flow
        self.per_batch_io_ns
            + fetch
            + write
            + b as f64 * (self.inference_ns(model) + self.per_flow_ns)
    }

    /// Sustained throughput of one core at batch size `b` (flows/s).
    pub fn throughput_per_sec(&self, model: &BnnModel, b: usize) -> f64 {
        b as f64 * 1e9 / self.batch_latency_ns(model, b)
    }

    /// Max batch admissible under a latency budget (paper: 7 ms cap from
    /// the TPU paper's interactive-serving rule).
    pub fn max_batch_under(&self, model: &BnnModel, budget_ns: f64) -> usize {
        let mut b = 1;
        while self.batch_latency_ns(model, b * 2) <= budget_ns && b < 1 << 20 {
            b *= 2;
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn traffic() -> BnnModel {
        BnnModel::random("traffic", 256, &[32, 16, 2], 1)
    }

    #[test]
    fn max_throughput_near_1_18m() {
        // §6.1: "bnn-exec maximum throughput is 1.18M analyzed flows/s,
        // when using very large batches of 10K flows".
        let m = HostCostModel::default();
        let tput = m.throughput_per_sec(&traffic(), 10_000);
        assert!(
            (1.0e6..1.6e6).contains(&tput),
            "tput={tput}"
        );
    }

    #[test]
    fn batch_latency_anchors() {
        // Fig. 14: ~1 ms at batch 1k, ~8 ms at 10k, 10s of µs at batch 1.
        let m = HostCostModel::default();
        let t = traffic();
        let l1 = m.batch_latency_ns(&t, 1) / 1000.0;
        let l1k = m.batch_latency_ns(&t, 1000) / 1e6;
        let l10k = m.batch_latency_ns(&t, 10_000) / 1e6;
        assert!((15.0..80.0).contains(&l1), "batch1 {l1}µs");
        assert!((0.5..1.6).contains(&l1k), "batch1k {l1k}ms");
        assert!((5.0..11.0).contains(&l10k), "batch10k {l10k}ms");
    }

    #[test]
    fn tomography_latency_about_40us() {
        // Fig. 15: bnn-exec processes a probe set in ~40 µs (batch 1).
        let m = HostCostModel::default();
        let tomo = BnnModel::random("tomo", 152, &[128, 64, 2], 2);
        let l = m.batch_latency_ns(&tomo, 1) / 1000.0;
        assert!((25.0..55.0).contains(&l), "{l}µs");
    }

    #[test]
    fn big_fc_quarter_of_nfp_model_parallel() {
        // Fig. 25: bnn-exec ≈ 100 µs for 4096×2048 (N3IC-NFP is 4×).
        let m = HostCostModel::default();
        let fc = BnnModel::random("fc", 4096, &[2048], 3);
        let inf = m.inference_ns(&fc) / 1000.0;
        assert!((80.0..140.0).contains(&inf), "{inf}µs");
    }

    #[test]
    fn batch_under_7ms_budget_matches_appendix() {
        // App. B.1.2: 7 ms budget → batch 64 for the 2k-neuron layer
        // (powers of two; our search returns the nearest power).
        let m = HostCostModel::default();
        let fc = BnnModel::random("fc", 4096, &[2048], 3);
        let b = m.max_batch_under(&fc, 7e6);
        assert!((32..=128).contains(&b), "batch={b}");
    }
}

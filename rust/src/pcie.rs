//! Analytic PCIe transfer-cost model (DESIGN.md substitution S9).
//!
//! Reproduces the §2.1 motivation measurements (Fig. 3): on the paper's
//! testbed (PCIe x16 v3.0, NVIDIA GTX 1080 Ti), transferring "just few
//! bytes of input vector and retrieving back the result" costs 8–10 µs —
//! latency-dominated; bandwidth only matters for large batches.
//!
//! Model: `t(bytes) = base_latency + bytes / bandwidth`, applied once per
//! direction.  The GPU-offload path of Fig. 2 crosses PCIe up to four
//! times; helpers below compose the crossings for each deployment.

/// Nanoseconds, the time unit used across all cost models in this crate.
pub type Nanos = f64;

/// PCIe link model.
#[derive(Debug, Clone, Copy)]
pub struct PcieModel {
    /// One-way DMA setup + completion latency (ns).  Fig. 3 shows ~8–10 µs
    /// for a 1 B payload round trip (write + read), i.e. ~4.25 µs/way.
    pub base_latency_ns: Nanos,
    /// Effective payload bandwidth (bytes/ns = GB/s).  PCIe x16 v3.0
    /// delivers ~12.8 GB/s of usable DMA bandwidth.
    pub bandwidth_gbps: Nanos,
}

impl Default for PcieModel {
    fn default() -> Self {
        Self {
            base_latency_ns: 4_250.0,
            bandwidth_gbps: 12.8,
        }
    }
}

impl PcieModel {
    /// One-way transfer cost for `bytes` of payload.
    pub fn transfer_ns(&self, bytes: usize) -> Nanos {
        self.base_latency_ns + bytes as f64 / self.bandwidth_gbps
    }

    /// Fig. 3's experiment: send `bytes` to the GPU, read back a 1 B
    /// result — one round trip.
    pub fn rtt_ns(&self, bytes: usize) -> Nanos {
        self.transfer_ns(bytes) + self.transfer_ns(1)
    }

    /// GPU-offload path of Fig. 2 when the inference result must return to
    /// the NIC for a forwarding decision: NIC→host, host→GPU, GPU→host,
    /// host→NIC = four crossings.
    pub fn gpu_offload_ns(&self, input_bytes: usize, result_bytes: usize) -> Nanos {
        2.0 * self.transfer_ns(input_bytes) + 2.0 * self.transfer_ns(result_bytes)
    }

    /// Host-CPU offload (the `bnn-exec` deployment): statistics fetched
    /// NIC→host and the result written back host→NIC.
    pub fn host_offload_ns(&self, input_bytes: usize, result_bytes: usize) -> Nanos {
        self.transfer_ns(input_bytes) + self.transfer_ns(result_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_transfer_rtt_is_8_to_10_us() {
        // The paper's headline motivation number.
        let m = PcieModel::default();
        for bytes in [1, 32, 256] {
            let rtt = m.rtt_ns(bytes);
            assert!(
                (8_000.0..=10_500.0).contains(&rtt),
                "{bytes}B RTT {rtt}ns outside the paper's 8–10µs band"
            );
        }
    }

    #[test]
    fn bandwidth_term_dominates_large_transfers() {
        let m = PcieModel::default();
        let t = m.transfer_ns(128 << 20); // 128 MB
        assert!(t > 9_000_000.0); // ≫ base latency
        assert!((t - 128.0 * 1024.0 * 1024.0 / 12.8) < 10_000.0);
    }

    #[test]
    fn gpu_path_costs_more_than_host_path() {
        let m = PcieModel::default();
        assert!(m.gpu_offload_ns(64, 4) > m.host_offload_ns(64, 4));
    }
}

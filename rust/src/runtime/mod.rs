//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`) produced by
//! the Python/JAX/Pallas compile pass and execute them from Rust.
//!
//! Interchange is HLO **text** (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).  Artifacts take
//! the packed weight matrices as runtime arguments (`w_0..w_{L-1}, x`), so
//! one compiled executable serves any trained model of its architecture.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::bnn::BnnModel;
use crate::json::Json;
use crate::Result;

/// One entry of `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub in_bits: usize,
    pub neurons: Vec<usize>,
    pub batch: usize,
    pub in_words: usize,
    pub weight_shapes: Vec<Vec<usize>>,
    pub out_neurons: usize,
}

/// The artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest(pub HashMap<String, ArtifactSpec>);

impl Manifest {
    pub fn load(artifacts: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(artifacts.join("manifest.json"))?;
        let v = Json::parse(&text)?;
        let obj = v
            .as_object()
            .ok_or_else(|| anyhow::anyhow!("manifest is not an object"))?;
        let mut m = HashMap::new();
        for (k, e) in obj {
            let usizes = |key: &str| -> Result<Vec<usize>> {
                Ok(e.req_array(key)?
                    .iter()
                    .map(|x| x.as_usize().unwrap_or(0))
                    .collect())
            };
            let weight_shapes = e
                .req_array("weight_shapes")?
                .iter()
                .map(|row| {
                    row.as_array()
                        .unwrap_or(&[])
                        .iter()
                        .map(|x| x.as_usize().unwrap_or(0))
                        .collect()
                })
                .collect();
            m.insert(
                k.clone(),
                ArtifactSpec {
                    file: e.req_str("file")?.to_string(),
                    in_bits: e.req_usize("in_bits")?,
                    neurons: usizes("neurons")?,
                    batch: e.req_usize("batch")?,
                    in_words: e.req_usize("in_words")?,
                    weight_shapes,
                    out_neurons: e.req_usize("out_neurons")?,
                },
            );
        }
        Ok(Self(m))
    }

    /// Artifact key for an architecture + batch (e.g. mlp256_b32).
    pub fn key_for(model: &BnnModel, batch: usize) -> String {
        let arch = match (model.in_bits, model.neurons.as_slice()) {
            (256, [32, 16, 2]) => "mlp256",
            (152, [32, 16, 2]) => "tomo32",
            (152, [64, 32, 2]) => "tomo64",
            (152, [128, 64, 2]) => "tomo128",
            _ => "custom",
        };
        format!("{arch}_b{batch}")
    }
}

/// A loaded, compiled executable for one (architecture, batch) pair.
pub struct PjrtExecutable {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactSpec,
}

/// The runtime: one PJRT CPU client + an executable cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    artifacts: PathBuf,
    pub manifest: Manifest,
    cache: HashMap<String, PjrtExecutable>,
}

impl PjrtRuntime {
    pub fn new(artifacts: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let manifest = Manifest::load(artifacts)?;
        Ok(Self {
            client,
            artifacts: artifacts.to_path_buf(),
            manifest,
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by manifest key (cached).
    pub fn load(&mut self, key: &str) -> Result<&PjrtExecutable> {
        if !self.cache.contains_key(key) {
            let spec = self
                .manifest
                .0
                .get(key)
                .ok_or_else(|| anyhow::anyhow!("artifact {key} not in manifest"))?
                .clone();
            let path = self.artifacts.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow::anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {key}: {e:?}"))?;
            self.cache.insert(key.to_string(), PjrtExecutable { exe, spec });
        }
        Ok(&self.cache[key])
    }

    /// Execute a whole batch: `inputs` is `batch × in_words` packed rows;
    /// returns `batch × out_neurons` scores.  Weights travel as arguments
    /// (runtime reconfiguration, mirroring the paper's MAU/CLS stores).
    pub fn infer_batch(
        &mut self,
        key: &str,
        model: &BnnModel,
        inputs: &[Vec<u32>],
    ) -> Result<Vec<Vec<i32>>> {
        let exe = self.load(key)?;
        let spec = exe.spec.clone();
        anyhow::ensure!(
            inputs.len() == spec.batch,
            "batch {} != artifact batch {}",
            inputs.len(),
            spec.batch
        );
        anyhow::ensure!(
            model.neurons == spec.neurons && model.in_words() == spec.in_words,
            "model/artifact architecture mismatch"
        );
        let mut args: Vec<xla::Literal> = Vec::with_capacity(model.layers.len() + 1);
        for layer in &model.layers {
            let lit = xla::Literal::vec1(layer.words.as_slice())
                .reshape(&[layer.neurons as i64, layer.in_words as i64])
                .map_err(|e| anyhow::anyhow!("{e:?}"))?;
            args.push(lit);
        }
        let flat: Vec<u32> = inputs.iter().flatten().copied().collect();
        let x = xla::Literal::vec1(flat.as_slice())
            .reshape(&[spec.batch as i64, spec.in_words as i64])
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        args.push(x);
        let result = exe
            .exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let scores: Vec<i32> = out.to_vec().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        anyhow::ensure!(
            scores.len() == spec.batch * spec.out_neurons,
            "unexpected output size {}",
            scores.len()
        );
        Ok(scores
            .chunks(spec.out_neurons)
            .map(|c| c.to_vec())
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::{infer_scores, load_golden};

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn pjrt_matches_core_and_pallas_goldens() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let dir = artifacts_dir();
        let model = BnnModel::load_named(&dir, "traffic").unwrap();
        let golden = load_golden(&dir, "traffic").unwrap();
        let mut rt = PjrtRuntime::new(&dir).unwrap();
        let key = Manifest::key_for(&model, 1);
        for (x, want) in golden.inputs.iter().zip(&golden.scores).take(4) {
            let got = rt
                .infer_batch(&key, &model, std::slice::from_ref(x))
                .unwrap();
            assert_eq!(&got[0], want, "PJRT vs Pallas golden");
            assert_eq!(got[0], infer_scores(&model, x), "PJRT vs Rust core");
        }
    }

    #[test]
    fn batch32_artifact_consistent() {
        if !have_artifacts() {
            return;
        }
        let dir = artifacts_dir();
        let model = BnnModel::load_named(&dir, "traffic").unwrap();
        let mut rt = PjrtRuntime::new(&dir).unwrap();
        let key = Manifest::key_for(&model, 32);
        let inputs: Vec<Vec<u32>> = (0..32)
            .map(|i| crate::bnn::BnnLayer::random(1, 256, 500 + i).words)
            .collect();
        let got = rt.infer_batch(&key, &model, &inputs).unwrap();
        for (x, row) in inputs.iter().zip(&got) {
            assert_eq!(row, &infer_scores(&model, x));
        }
    }
}

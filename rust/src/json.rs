//! Minimal JSON parser/serializer (offline substitute for serde_json).
//!
//! Handles the full JSON grammar needed by the artifact formats (models,
//! goldens, manifest, summary): objects, arrays, strings with standard
//! escapes, numbers, booleans, null.  Numbers are stored as f64 — exact
//! for every u32/i32 the formats carry.

use std::collections::BTreeMap;

use crate::Result;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        anyhow::ensure!(p.i == p.b.len(), "trailing data at byte {}", p.i);
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers with good error messages.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a number"))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a string"))
    }

    pub fn req_array(&self, key: &str) -> Result<&[Json]> {
        self.req(key)?
            .as_array()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not an array"))
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, s: &mut String) {
        match self {
            Json::Null => s.push_str("null"),
            Json::Bool(b) => s.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    s.push_str(&format!("{}", *n as i64));
                } else {
                    s.push_str(&format!("{n}"));
                }
            }
            Json::Str(t) => {
                s.push('"');
                for c in t.chars() {
                    match c {
                        '"' => s.push_str("\\\""),
                        '\\' => s.push_str("\\\\"),
                        '\n' => s.push_str("\\n"),
                        '\t' => s.push_str("\\t"),
                        '\r' => s.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            s.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => s.push(c),
                    }
                }
                s.push('"');
            }
            Json::Arr(a) => {
                s.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    v.write(s);
                }
                s.push(']');
            }
            Json::Obj(m) => {
                s.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    Json::Str(k.clone()).write(s);
                    s.push(':');
                    v.write(s);
                }
                s.push('}');
            }
        }
    }
}

/// Build an object from pairs (ordering normalized by BTreeMap).
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        anyhow::ensure!(
            self.peek()? == c,
            "expected '{}' at byte {}, found '{}'",
            c as char,
            self.i,
            self.peek().unwrap() as char
        );
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += word.len();
        Ok(v)
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => anyhow::bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => anyhow::bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            anyhow::ensure!(self.i + 4 <= self.b.len(), "bad \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => anyhow::bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // Collect the full UTF-8 sequence.
                    let len = utf8_len(c);
                    let start = self.i - 1;
                    self.i += len - 1;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow::anyhow!("bad number '{text}' at byte {start}: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_model_like_document() {
        let text = r#"{"name":"traffic","in_bits":256,"neurons":[32,16,2],
            "layers":[{"neurons":2,"in_words":1,"threshold":16,
            "words":[4294967295,0]}],"metrics":{"bnn_test_acc":0.9217}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.req_str("name").unwrap(), "traffic");
        assert_eq!(v.req_usize("in_bits").unwrap(), 256);
        let ns: Vec<usize> = v
            .req_array("neurons")
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        assert_eq!(ns, vec![32, 16, 2]);
        let words = v.req_array("layers").unwrap()[0].req_array("words").unwrap();
        assert_eq!(words[0].as_u64().unwrap(), 4294967295); // u32::MAX exact
        // Reserialize and reparse — stable.
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn strings_escapes_unicode() {
        let v = Json::parse(r#"{"s":"a\"b\\c\ndéµ"}"#).unwrap();
        assert_eq!(v.req_str("s").unwrap(), "a\"b\\c\ndéµ");
        let d = v.dump();
        assert_eq!(Json::parse(&d).unwrap(), v);
    }

    #[test]
    fn numbers() {
        let v = Json::parse("[0, -1, 3.25, 1e3, 2.5e-2]").unwrap();
        let a = v.as_array().unwrap();
        assert_eq!(a[0].as_f64(), Some(0.0));
        assert_eq!(a[1].as_f64(), Some(-1.0));
        assert_eq!(a[2].as_f64(), Some(3.25));
        assert_eq!(a[3].as_f64(), Some(1000.0));
        assert_eq!(a[4].as_f64(), Some(0.025));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn bools_null_nested() {
        let v = Json::parse(r#"{"a":[true,false,null,{"b":[]}]}"#).unwrap();
        let a = v.req_array("a").unwrap();
        assert_eq!(a[0], Json::Bool(true));
        assert_eq!(a[2], Json::Null);
        assert!(a[3].get("b").unwrap().as_array().unwrap().is_empty());
    }
}

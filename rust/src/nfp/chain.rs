//! Model-parallel execution: the notification chain (App. A, Fig. 19/20).
//!
//! For big NNs (weights in EMEM), dispatch threads trigger a statically
//! configured chain of executor threads.  A start notification propagates
//! down the chain; each executor computes its neuron slice reading weights
//! from contiguous EMEM; the end notification propagates back.  Latency is
//! chain propagation + the slowest executor slice + result writeback.

use crate::bnn::BnnModel;

use super::memory::{MemKind, MemSpec};

/// Chain configuration.
#[derive(Debug, Clone, Copy)]
pub struct ChainConfig {
    /// Executor threads in the chain (e.g. 128 or 256).
    pub executors: usize,
    /// Dispatcher threads per ME (App. A: two per ME suffice).
    pub dispatchers_per_me: usize,
    /// Per-hop notification cost (ME-to-ME signal, ns).
    pub notify_ns: f64,
    /// Per-word EMEM cost for the chain's *bulk sequential* reads — lower
    /// than random-access (DRAM burst locality): calibrated to Fig. 25's
    /// 400 µs for a 4096×2048 FC with 256 executors.
    pub burst_read_ns: f64,
    /// IMEM result writeback per executor (ns).
    pub writeback_ns: f64,
}

impl Default for ChainConfig {
    fn default() -> Self {
        Self {
            executors: 256,
            dispatchers_per_me: 2,
            notify_ns: 50.0,
            burst_read_ns: 350.0,
            writeback_ns: 300.0,
        }
    }
}

/// Model-parallel executor model.
#[derive(Debug, Clone)]
pub struct ModelParallel {
    pub cfg: ChainConfig,
    pub model: BnnModel,
}

impl ModelParallel {
    pub fn new(model: BnnModel, cfg: ChainConfig) -> Self {
        Self { cfg, model }
    }

    /// Neurons computed by each executor for a layer of `n` neurons
    /// (App. A example: 4096 neurons / 128 executors = 32 each).
    pub fn neurons_per_executor(&self, layer_neurons: usize) -> usize {
        layer_neurons.div_ceil(self.cfg.executors)
    }

    /// Latency of one full-model inference (ns): per layer, start-chain +
    /// parallel slice work + back-propagated end notification; layers are
    /// sequential (the dispatcher synchronizes between layers).
    pub fn latency_ns(&self) -> f64 {
        let e = self.cfg.executors as f64;
        let mut total = 0.0;
        for layer in &self.model.layers {
            let slice_words =
                self.neurons_per_executor(layer.neurons) * layer.in_words;
            let work = slice_words as f64 * self.cfg.burst_read_ns;
            let chain = 2.0 * e * self.cfg.notify_ns; // start + end sweeps
            total += chain + work + self.cfg.writeback_ns;
        }
        total
    }

    /// Throughput: the chain processes one inference at a time (no
    /// batching on the NFP — App. B.1.2).
    pub fn throughput_per_sec(&self) -> f64 {
        1e9 / self.latency_ns()
    }

    /// EMEM footprint check.
    pub fn fits_memory(&self) -> bool {
        MemSpec::get(MemKind::Emem)
            .size_bytes
            .checked_sub(self.model.memory_bytes())
            .is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::BnnModel;

    /// Paper Fig. 25 workload: single FC, 4096 inputs, 2k–16k neurons.
    fn big_fc(neurons: usize) -> BnnModel {
        BnnModel::random("big", 4096, &[neurons], 1)
    }

    #[test]
    fn fig25_latency_anchors() {
        // Paper: 400 µs (2k neurons) → 2700 µs (16k), 256 executors.
        let cfg = ChainConfig::default();
        let l2k = ModelParallel::new(big_fc(2048), cfg).latency_ns() / 1000.0;
        let l16k = ModelParallel::new(big_fc(16384), cfg).latency_ns() / 1000.0;
        assert!((330.0..500.0).contains(&l2k), "2k: {l2k}µs");
        assert!((2_300.0..3_200.0).contains(&l16k), "16k: {l16k}µs");
        // Linear in size: 16k/2k ≈ 8×, modulo fixed chain overhead.
        assert!((6.0..9.0).contains(&(l16k / l2k)));
    }

    #[test]
    fn more_executors_reduce_latency_until_chain_dominates() {
        let mk = |e| {
            ModelParallel::new(
                big_fc(4096),
                ChainConfig {
                    executors: e,
                    ..ChainConfig::default()
                },
            )
            .latency_ns()
        };
        let l64 = mk(64);
        let l256 = mk(256);
        assert!(l256 < l64);
        // Chain propagation eventually wins: 4096 executors slower than 1024.
        assert!(mk(4096) > mk(1024));
    }

    #[test]
    fn neurons_split_evenly() {
        let mp = ModelParallel::new(big_fc(4096), ChainConfig::default());
        assert_eq!(mp.neurons_per_executor(4096), 16);
    }

    #[test]
    fn model_must_fit_emem() {
        // 16k × 4096 bits = 8 MB > 3 MB EMEM SRAM → does not fit;
        // the paper runs it from DRAM-backed EMEM (cache misses included
        // in the burst-read calibration), so we only check the arithmetic.
        let mp = ModelParallel::new(big_fc(16384), ChainConfig::default());
        assert_eq!(mp.model.memory_bytes(), 16384 * 128 * 4);
        assert!(mp.fits_memory() || mp.model.memory_bytes() > 3 << 20);
    }
}

//! Discrete-event simulation of N3IC-NFP under offered load, plus the
//! forwarding-budget model (Fig. 5 / Fig. 21).

use std::collections::BinaryHeap;

use crate::bnn::BnnModel;
use crate::metrics::LatencyHistogram;
use crate::net::traffic::Rng;

use super::chip;
use super::cost::DataParallelCost;
use super::memory::MemKind;

/// Result of an NFP simulation run.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub offered_per_sec: f64,
    pub completed_per_sec: f64,
    pub latency: LatencyHistogram,
    /// Fraction of offered inferences dropped (queue overflow).
    pub drop_frac: f64,
    /// Forwarding throughput achieved while running NN load (Mpps).
    pub forwarding_mpps: f64,
}

/// M/G/c queueing simulation: `threads` NN executors serve Poisson flow
/// arrivals with service times from [`DataParallelCost`].
pub struct NfpSim {
    pub cost: DataParallelCost,
    pub threads: usize,
    /// Queue bound (NIC work queues are shallow; beyond this, drops).
    pub queue_cap: usize,
}

impl NfpSim {
    pub fn new(model: &BnnModel, mem: MemKind, threads: usize) -> Self {
        Self {
            cost: DataParallelCost::new(model, mem),
            threads,
            // NIC work queues are shallow — overload shows up as drops,
            // not multi-ms latency (the paper's stress 95th percentiles
            // stay within ~1.5× the service time).
            queue_cap: 256,
        }
    }

    /// Simulate `n_events` flow arrivals at `rate_per_sec`; returns the
    /// latency distribution and achieved throughput.
    pub fn run(&self, rate_per_sec: f64, n_events: usize, seed: u64) -> SimReport {
        let mut rng = Rng::new(seed);
        let mut latency = LatencyHistogram::new();
        // Bandwidth cap: model as a reduction of effective service slots.
        let eff_rate = self.cost.max_throughput(self.threads);
        // server completion times (min-heap via Reverse)
        let mut servers: BinaryHeap<std::cmp::Reverse<u64>> = BinaryHeap::new();
        for _ in 0..self.threads {
            servers.push(std::cmp::Reverse(0));
        }
        let mut t_ns = 0.0f64;
        let mut dropped = 0usize;
        let mut completed = 0usize;
        let mut last_finish = 0.0f64;
        // Service-time inflation when offered load approaches the memory
        // bandwidth cap (DRAM queueing): scale by 1/(1-ρ_bw) up to 4×.
        let bw_cap = {
            let bytes_per_inf = self.cost.words as f64 * 4.0;
            self.cost.mem.bandwidth_bps / bytes_per_inf
        };
        for _ in 0..n_events {
            t_ns += rng.exp(1e9 / rate_per_sec);
            let arrival = t_ns as u64;
            let std::cmp::Reverse(free_at) = servers.pop().unwrap();
            let start = free_at.max(arrival);
            // Queue bound: if the backlog (start - arrival) exceeds the
            // queue capacity in service-time units, drop.
            let backlog_ns = start.saturating_sub(arrival) as f64;
            if backlog_ns > self.queue_cap as f64 * self.cost.mean_ns() / self.threads as f64 {
                servers.push(std::cmp::Reverse(free_at));
                dropped += 1;
                continue;
            }
            // DRAM-bandwidth bound: when the thread pool could outrun the
            // memory system, per-read stalls stretch the service time so
            // completions settle at the bandwidth cap.
            let thread_cap = self.threads as f64 / (self.cost.mean_ns() * 1e-9);
            let inflation = (thread_cap / bw_cap).clamp(1.0, 4.0);
            let service = self.cost.sample_ns(&mut rng) * inflation;
            let finish = start + service as u64;
            servers.push(std::cmp::Reverse(finish));
            latency.record((finish - arrival) as f64);
            completed += 1;
            last_finish = last_finish.max(finish as f64);
        }
        let window = last_finish.max(t_ns);
        let completed_per_sec = completed as f64 * 1e9 / window;
        // Forwarding impact: NN work steals thread capacity from the pool.
        let fwd = ForwardingModel::default();
        let forwarding_mpps = fwd.achieved_mpps(
            chip::TOTAL_THREADS,
            completed_per_sec.min(eff_rate),
            self.cost.mean_ns(),
        );
        SimReport {
            offered_per_sec: rate_per_sec,
            completed_per_sec,
            latency,
            drop_frac: dropped as f64 / n_events as f64,
            forwarding_mpps,
        }
    }
}

/// Forwarding-capacity model: the interplay between packet forwarding and
/// NN execution on the shared thread pool (Fig. 5 / Fig. 21).
#[derive(Debug, Clone, Copy)]
pub struct ForwardingModel {
    /// Line rate in Mpps for the reference workload (40Gb/s@256B).
    pub line_mpps: f64,
    /// Per-packet processing time (parse + lookup + counters), ns.
    pub pkt_ns: f64,
}

impl Default for ForwardingModel {
    fn default() -> Self {
        Self {
            line_mpps: 18.1,
            pkt_ns: chip::PKT_PROCESS_NS,
        }
    }
}

impl ForwardingModel {
    /// Achieved forwarding rate given `threads` total, an NN completion
    /// rate, and the NN service time: NN work occupies
    /// `nn_rate × t_nn` thread-seconds per second; the rest forwards.
    pub fn achieved_mpps(&self, threads: usize, nn_rate: f64, t_nn_ns: f64) -> f64 {
        let nn_threads = nn_rate * t_nn_ns * 1e-9;
        let free = (threads as f64 - nn_threads).max(0.0);
        let capacity_mpps = free / (self.pkt_ns * 1e-9) / 1e6;
        capacity_mpps.min(self.line_mpps)
    }

    /// Fig. 5: forwarding throughput when performing `extra_ops` integer
    /// operations per packet at `gbps`/`pkt_size` load.  The NFP has a
    /// fixed instruction budget; throughput holds at line rate until the
    /// budget is exhausted, then degrades as 1/ops.
    pub fn ops_budget_mpps(&self, gbps: f64, pkt_size: u16, extra_ops: u64) -> f64 {
        let line_pps = gbps * 1e9 / (pkt_size as f64 * 8.0 + 160.0);
        // Aggregate instruction rate: 60 MEs × 800 MHz, ~1 op/cycle,
        // with baseline parse/forward work taking ~600 ops/packet.
        let total_ops_per_sec = 60.0 * chip::ME_CLOCK_HZ;
        let ops_per_pkt = 600.0 + extra_ops as f64;
        let compute_pps = total_ops_per_sec / ops_per_pkt;
        line_pps.min(compute_pps) / 1e6
    }

    /// Fig. 5's "available budget": ops/packet sustainable at line rate.
    pub fn ops_budget_at_line_rate(&self, gbps: f64, pkt_size: u16) -> u64 {
        let line_pps = gbps * 1e9 / (pkt_size as f64 * 8.0 + 160.0);
        let total_ops_per_sec = 60.0 * chip::ME_CLOCK_HZ;
        (total_ops_per_sec / line_pps - 600.0).max(0.0) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::BnnModel;

    fn traffic() -> BnnModel {
        BnnModel::random("traffic", 256, &[32, 16, 2], 1)
    }

    #[test]
    fn meets_1_8m_offered_load_on_cls() {
        // Fig. 13: N3IC-NFP matches 1.81M flow analyses/s.
        let sim = NfpSim::new(&traffic(), MemKind::Cls, 480);
        let r = sim.run(1.81e6, 120_000, 7);
        assert!(r.drop_frac < 0.01, "drops={}", r.drop_frac);
        assert!(
            (r.completed_per_sec / 1.81e6 - 1.0).abs() < 0.05,
            "tput={}",
            r.completed_per_sec
        );
        // Fig. 14: p95 ≈ 42 µs.
        let p95 = r.latency.p95_us();
        assert!((30.0..60.0).contains(&p95), "p95={p95}µs");
        // Forwarding stays at line rate (Fig. 13: 40Gb/s@256B).
        assert!(r.forwarding_mpps > 18.0, "fwd={}", r.forwarding_mpps);
    }

    #[test]
    fn emem_saturates_near_1_4m() {
        let sim = NfpSim::new(&traffic(), MemKind::Emem, 480);
        let r = sim.run(3.0e6, 60_000, 3);
        assert!(
            (1.0e6..1.8e6).contains(&r.completed_per_sec),
            "tput={}",
            r.completed_per_sec
        );
    }

    #[test]
    fn fewer_threads_lower_throughput() {
        // §6.4: 120 threads → ~10× fewer analyzed flows than 480.
        let sim480 = NfpSim::new(&traffic(), MemKind::Cls, 480);
        let sim30 = NfpSim::new(&traffic(), MemKind::Cls, 30);
        let cap480 = sim480.cost.max_throughput(480);
        let cap30 = sim30.cost.max_throughput(30);
        assert!((cap480 / cap30 - 16.0).abs() < 0.1);
        // 30 NN threads still analyze >100k flows/s (paper's point).
        assert!(cap30 > 100_000.0, "cap30={cap30}");
    }

    #[test]
    fn ops_budget_512b_is_about_10k() {
        // §2.1: "considering an average case of 512B input packets ... the
        // available budget is of 10K operations per-packet".
        let f = ForwardingModel::default();
        let budget = f.ops_budget_at_line_rate(25.0, 512);
        assert!((7_000..13_000).contains(&budget), "budget={budget}");
        // Budget grows superlinearly when packets double (fewer pps).
        let b1024 = f.ops_budget_at_line_rate(25.0, 1024);
        assert!(b1024 > 2 * budget - 1000);
    }

    #[test]
    fn ops_budget_curve_flat_then_declining() {
        let f = ForwardingModel::default();
        let at_0 = f.ops_budget_mpps(25.0, 512, 0);
        let at_budget = f.ops_budget_mpps(25.0, 512, 8_000);
        let at_10x = f.ops_budget_mpps(25.0, 512, 80_000);
        assert!((at_0 - at_budget).abs() / at_0 < 0.15);
        assert!(at_10x < at_0 / 5.0);
    }
}

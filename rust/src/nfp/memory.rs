//! NFP4000 memory hierarchy (Table 3) + calibrated contention model.

/// The four memory areas of the NFP4000 (§4.1, Table 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemKind {
    /// 64 KB per-island scratch, 25–62.5 ns — where N3IC keeps weights.
    Cls,
    /// 256 KB per-island packet memory, 62.5–125 ns (avoided: packets).
    Ctm,
    /// 4 MB shared SRAM, 187.5–312.5 ns.
    Imem,
    /// 3 MB SRAM cache + DRAM, 312.5–625 ns.
    Emem,
}

impl std::fmt::Display for MemKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            MemKind::Cls => "CLS",
            MemKind::Ctm => "CTM",
            MemKind::Imem => "IMEM",
            MemKind::Emem => "EMEM",
        })
    }
}

/// Access-time + capacity + calibrated contention parameters for one area.
#[derive(Debug, Clone, Copy)]
pub struct MemSpec {
    pub kind: MemKind,
    /// Table 3 min/max access time (ns).
    pub access_min_ns: f64,
    pub access_max_ns: f64,
    /// Capacity in bytes.
    pub size_bytes: usize,
    /// Calibrated bus/arbiter contention multiplier under full NN load
    /// (App. B.1: the IMEM arbiter behaves anomalously — "using the IMEM
    /// is slower than using the EMEM ... an artefact of the NFP's memory
    /// access arbiter" — hence its large factor).
    pub contention: f64,
    /// Aggregate bandwidth cap in bytes/s (f64::INFINITY for per-island
    /// SRAM that the 480 threads cannot saturate).
    pub bandwidth_bps: f64,
}

impl MemSpec {
    pub fn get(kind: MemKind) -> Self {
        match kind {
            MemKind::Cls => Self {
                kind,
                access_min_ns: 25.0,
                access_max_ns: 62.5,
                size_bytes: 64 << 10,
                contention: 2.3,
                bandwidth_bps: f64::INFINITY,
            },
            MemKind::Ctm => Self {
                kind,
                access_min_ns: 62.5,
                access_max_ns: 125.0,
                size_bytes: 256 << 10,
                contention: 2.0,
                bandwidth_bps: f64::INFINITY,
            },
            MemKind::Imem => Self {
                kind,
                access_min_ns: 187.5,
                access_max_ns: 312.5,
                size_bytes: 4 << 20,
                contention: 5.0,
                bandwidth_bps: f64::INFINITY,
            },
            MemKind::Emem => Self {
                kind,
                access_min_ns: 312.5,
                access_max_ns: 625.0,
                size_bytes: 3 << 20,
                contention: 1.6,
                bandwidth_bps: 1.53e9,
            },
        }
    }

    /// Mean raw access time (ns).
    pub fn access_mean_ns(&self) -> f64 {
        0.5 * (self.access_min_ns + self.access_max_ns)
    }

    /// Effective per-32b-word read cost under NN load (ns).
    pub fn effective_read_ns(&self) -> f64 {
        self.access_mean_ns() * self.contention
    }

    /// Whether a model of `bytes` packed weights fits this area, leaving
    /// the paper's margin for per-thread state (§6.4: the traffic NNs use
    /// 1.5% of CLS).
    pub fn fits(&self, bytes: usize) -> bool {
        bytes * 2 <= self.size_bytes // ×2: intermediate buffers + headroom
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_access_times() {
        // Exactly Table 3.
        let cls = MemSpec::get(MemKind::Cls);
        assert_eq!((cls.access_min_ns, cls.access_max_ns), (25.0, 62.5));
        let ctm = MemSpec::get(MemKind::Ctm);
        assert_eq!((ctm.access_min_ns, ctm.access_max_ns), (62.5, 125.0));
        let imem = MemSpec::get(MemKind::Imem);
        assert_eq!((imem.access_min_ns, imem.access_max_ns), (187.5, 312.5));
        let emem = MemSpec::get(MemKind::Emem);
        assert_eq!((emem.access_min_ns, emem.access_max_ns), (312.5, 625.0));
        assert_eq!(cls.size_bytes, 65536);
        assert_eq!(imem.size_bytes, 4 << 20);
    }

    #[test]
    fn imem_slower_than_emem_under_contention() {
        // The paper's observed arbiter artefact must be reproduced.
        let imem = MemSpec::get(MemKind::Imem);
        let emem = MemSpec::get(MemKind::Emem);
        assert!(imem.effective_read_ns() > emem.effective_read_ns());
    }

    #[test]
    fn traffic_nn_fits_cls() {
        let cls = MemSpec::get(MemKind::Cls);
        assert!(cls.fits(1096)); // Table 1: 1.1 KB
        assert!(!cls.fits(64 << 10));
    }
}

//! Data-parallel per-inference service-time model (one thread runs the
//! whole NN; many threads run different inferences in parallel — Fig. 19
//! left, §4.1).

use crate::bnn::BnnModel;
use crate::net::traffic::Rng;

use super::memory::{MemKind, MemSpec};

/// Per-word execute cost on an ME: XNOR + popcount-accumulate + loop
/// bookkeeping.  ~6–7 instructions at 800 MHz ≈ 8 ns (the NFP has no
/// single-cycle popcount; micro-C lowers to the HAKMEM sequence).
pub const EXEC_PER_WORD_NS: f64 = 8.0;

/// Service-time model for running `model` out of `mem`.
#[derive(Debug, Clone)]
pub struct DataParallelCost {
    pub mem: MemSpec,
    /// Total weight words read per inference.
    pub words: usize,
    /// Deterministic base service time (ns).
    pub base_ns: f64,
}

impl DataParallelCost {
    pub fn new(model: &BnnModel, mem: MemKind) -> Self {
        let spec = MemSpec::get(mem);
        let words = model.work_words();
        let base_ns = words as f64 * (EXEC_PER_WORD_NS + spec.effective_read_ns());
        Self {
            mem: spec,
            words,
            base_ns,
        }
    }

    /// Mean service time (ns) of one inference on one thread.
    pub fn mean_ns(&self) -> f64 {
        self.base_ns
    }

    /// Sample a service time: base × U[0.9, 1.1) plus an exponential
    /// bus-stall tail (8% of base mean) — yields the p95/mean ≈ 1.2–1.3
    /// the paper reports (42 µs p95 vs ~31 µs mean on CLS).
    pub fn sample_ns(&self, rng: &mut Rng) -> f64 {
        self.base_ns * (0.9 + 0.2 * rng.next_f64()) + rng.exp(0.08 * self.base_ns)
    }

    /// Max sustainable inferences/s with `threads` NN threads (thread
    /// parallelism capped by the memory's aggregate bandwidth).
    pub fn max_throughput(&self, threads: usize) -> f64 {
        let thread_cap = threads as f64 / (self.base_ns * 1e-9);
        let bytes_per_inf = self.words as f64 * 4.0;
        let bw_cap = self.mem.bandwidth_bps / bytes_per_inf;
        thread_cap.min(bw_cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::BnnModel;

    fn traffic_model() -> BnnModel {
        BnnModel::random("traffic", 256, &[32, 16, 2], 1)
    }

    #[test]
    fn cls_service_time_matches_paper_band() {
        // Paper: p95 = 42 µs on CLS for the 32-16-2 net → mean ≈ 30 µs.
        let c = DataParallelCost::new(&traffic_model(), MemKind::Cls);
        let mean_us = c.mean_ns() / 1000.0;
        assert!((25.0..36.0).contains(&mean_us), "mean={mean_us}µs");
    }

    #[test]
    fn imem_emem_stress_throughput_1_4m() {
        // Paper Fig. 23: stress throughput drops to ~1.4 Mpps on both.
        for mem in [MemKind::Imem, MemKind::Emem] {
            let c = DataParallelCost::new(&traffic_model(), mem);
            let tput = c.max_throughput(480);
            assert!(
                (1.1e6..1.7e6).contains(&tput),
                "{mem:?} tput={tput}"
            );
        }
    }

    #[test]
    fn emem_latency_below_imem_but_throughput_equal_shape() {
        // The arbiter artefact: IMEM latency > EMEM latency.
        let ti = DataParallelCost::new(&traffic_model(), MemKind::Imem).mean_ns();
        let te = DataParallelCost::new(&traffic_model(), MemKind::Emem).mean_ns();
        assert!(ti > te);
        // Paper: IMEM p95 352 µs, EMEM p95 230 µs.
        assert!((300_000.0..400_000.0).contains(&ti), "imem {ti}");
        assert!((180_000.0..260_000.0).contains(&te), "emem {te}");
    }

    #[test]
    fn sampling_tail() {
        let c = DataParallelCost::new(&traffic_model(), MemKind::Cls);
        let mut rng = Rng::new(3);
        let mut v: Vec<f64> = (0..4000).map(|_| c.sample_ns(&mut rng)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        let p95 = v[(v.len() as f64 * 0.95) as usize];
        let ratio = p95 / mean;
        assert!((1.05..1.5).contains(&ratio), "p95/mean={ratio}");
        // p95 in the paper's 42 µs neighborhood.
        assert!((34_000.0..50_000.0).contains(&p95), "p95={p95}");
    }

    #[test]
    fn throughput_scales_linearly_with_inverse_size() {
        // Fig. 22: FC 256-in with 32/64/128 neurons — linear scaling.
        let t32 = DataParallelCost::new(
            &BnnModel::random("a", 256, &[32], 1),
            MemKind::Cls,
        )
        .max_throughput(480);
        let t64 = DataParallelCost::new(
            &BnnModel::random("b", 256, &[64], 1),
            MemKind::Cls,
        )
        .max_throughput(480);
        let t128 = DataParallelCost::new(
            &BnnModel::random("c", 256, &[128], 1),
            MemKind::Cls,
        )
        .max_throughput(480);
        assert!((t32 / t64 - 2.0).abs() < 0.05);
        assert!((t64 / t128 - 2.0).abs() < 0.05);
    }
}

//! Netronome NFP4000 SoC model (N3IC-NFP, §4.1 + Appendices A/B.1).
//!
//! The NFP4000 is modeled at the level that determines the paper's
//! numbers: **memory access time × Algorithm-1 word count**, hidden (or
//! not) by multi-threaded micro-engines.
//!
//! * [`memory`] — the four memory areas with Table 3's access times, plus
//!   calibrated bus-contention factors and bandwidth caps.
//! * [`cost`] — per-inference service-time model for data-parallel mode.
//! * [`sim`] — M/G/c-style discrete-event simulation of NN threads under
//!   offered flow load, plus the forwarding-budget model (Fig. 5, 21).
//! * [`chain`] — model-parallel notification-chain execution for big NNs
//!   (App. A, Fig. 19/20/25/26).
//!
//! Calibration: constants are fitted to the paper's published anchors
//! (Table 3 access times; 42/352/230 µs 95th-pct latency for CLS/IMEM/
//! EMEM; 1.4 Mpps stress throughput on IMEM/EMEM; 90-thread 40Gb/s@256B
//! forwarding baseline; model-parallel 400–2700 µs for 2k–16k neurons).
//! See EXPERIMENTS.md for the paper-vs-measured table.

pub mod chain;
pub mod cost;
pub mod crossover;
pub mod memory;
pub mod sim;

pub use chain::{ChainConfig, ModelParallel};
pub use crossover::{crossover_sweep, CrossoverPoint};
pub use cost::DataParallelCost;
pub use memory::{MemKind, MemSpec};
pub use sim::{ForwardingModel, NfpSim, SimReport};

/// Chip-level constants (NFP4000, §4.1).
pub mod chip {
    /// Micro-engine clock (Hz).
    pub const ME_CLOCK_HZ: f64 = 800e6;
    /// Islands with programmable MEs.
    pub const ISLANDS: usize = 6;
    /// MEs per island (60 total, 480 threads: "480 available threads").
    pub const MES_PER_ISLAND: usize = 10;
    /// Hardware threads per ME.
    pub const THREADS_PER_ME: usize = 8;
    /// Total hardware threads.
    pub const TOTAL_THREADS: usize = ISLANDS * MES_PER_ISLAND * THREADS_PER_ME;
    /// Threads needed for plain 40Gb/s@256B forwarding + stats (§6.1).
    pub const FORWARDING_THREADS: usize = 90;
    /// Line-rate packet processing time budget implied by the baseline:
    /// 90 threads / 18.1 Mpps ≈ 4.97 µs per packet.
    pub const PKT_PROCESS_NS: f64 = 90.0 / 18.1e6 * 1e9;
}

#[cfg(test)]
mod tests {
    #[test]
    fn chip_constants() {
        assert_eq!(super::chip::TOTAL_THREADS, 480);
        assert!((super::chip::PKT_PROCESS_NS - 4972.0).abs() < 5.0);
    }
}

//! Data-parallel vs model-parallel crossover analysis (App. A: "When NNs
//! are larger, running them in a single thread would take long, making the
//! use of multiple threads more effective, even if synchronization among
//! threads incurs some overhead").
//!
//! The ablation the appendix discusses but does not plot: for a growing FC
//! layer, when does the notification chain beat one-thread-per-inference?
//! Also models the *straggler* effect of asymmetric neuron assignment the
//! appendix calls out ("this in fact rises a problem of stragglers that
//! harms the overall performance").

use crate::bnn::BnnModel;

use super::chain::{ChainConfig, ModelParallel};
use super::cost::DataParallelCost;
use super::memory::{MemKind, MemSpec};

/// One row of the crossover sweep.  Data-parallel runs one inference per
/// thread (480 concurrent); model-parallel dedicates the whole chain to a
/// single inference — so the trade is dp-throughput vs mp-latency, and
/// the interesting question is where each axis flips.
#[derive(Debug, Clone, Copy)]
pub struct CrossoverPoint {
    pub neurons: usize,
    /// Which memory data-parallel mode must use (CLS if it fits, else EMEM).
    pub dp_mem: MemKind,
    pub dp_latency_ns: f64,
    pub mp_latency_ns: f64,
    /// Aggregate data-parallel throughput with 480 threads (inf/s).
    pub dp_tput: f64,
    /// Chain throughput: one inference at a time (inf/s).
    pub mp_tput: f64,
    /// The chain cuts latency for this size.
    pub mp_latency_wins: bool,
    /// Data-parallel still delivers more aggregate throughput.
    pub dp_tput_wins: bool,
}

/// Sweep FC sizes and report the data- vs model-parallel latency frontier.
pub fn crossover_sweep(in_bits: usize, sizes: &[usize], cfg: ChainConfig) -> Vec<CrossoverPoint> {
    sizes
        .iter()
        .map(|&n| {
            let model = BnnModel::random("fc", in_bits, &[n], 1);
            // Data-parallel keeps weights in CLS only while they fit.
            let dp_mem = if MemSpec::get(MemKind::Cls).fits(model.memory_bytes()) {
                MemKind::Cls
            } else {
                MemKind::Emem
            };
            let cost = DataParallelCost::new(&model, dp_mem);
            let dp = cost.mean_ns();
            let dp_tput = cost.max_throughput(super::chip::TOTAL_THREADS);
            let mp_model = ModelParallel::new(model, cfg);
            let mp = mp_model.latency_ns();
            let mp_tput = mp_model.throughput_per_sec();
            CrossoverPoint {
                neurons: n,
                dp_mem,
                dp_latency_ns: dp,
                mp_latency_ns: mp,
                dp_tput,
                mp_tput,
                mp_latency_wins: mp < dp,
                dp_tput_wins: dp_tput > mp_tput,
            }
        })
        .collect()
}

/// Straggler model: if one executor in the chain is assigned `skew`× the
/// even neuron share, layer completion waits for it (App. A's argument for
/// symmetric assignment).
pub fn straggler_latency_ns(model: &BnnModel, cfg: ChainConfig, skew: f64) -> f64 {
    let mp = ModelParallel::new(model.clone(), cfg);
    let even = mp.latency_ns();
    // The slowest executor's work term scales by `skew`; chain/notify
    // overhead is unchanged.
    let layer_work: f64 = model
        .layers
        .iter()
        .map(|l| {
            (mp.neurons_per_executor(l.neurons) * l.in_words) as f64 * cfg.burst_read_ns
        })
        .sum();
    even + layer_work * (skew - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_cuts_latency_dp_keeps_throughput() {
        let pts = crossover_sweep(
            4096,
            &[32, 128, 512, 2048, 8192],
            ChainConfig::default(),
        );
        for p in &pts {
            // The chain always wins single-inference latency on wide
            // (4096-bit) inputs — that is *why* App. A built it.
            assert!(p.mp_latency_wins, "{p:?}");
            // Aggregate throughput belongs to data-parallel while weights
            // stay in CLS; once they spill to EMEM, DRAM bandwidth caps
            // the 480 threads and the burst-reading chain wins *both*
            // axes — the full justification for model-parallel mode.
            if p.dp_mem == MemKind::Cls {
                assert!(p.dp_tput_wins, "{p:?}");
            }
        }
        // The spilled regime exists and flips the throughput axis too.
        assert!(pts.iter().any(|p| p.dp_mem == MemKind::Emem && !p.dp_tput_wins));
        // CLS→EMEM spill: big layers pay the slower memory in dp mode.
        assert_eq!(pts[0].dp_mem, MemKind::Cls);
        assert_eq!(pts.last().unwrap().dp_mem, MemKind::Emem);
        // Latency advantage grows with size (chain overhead amortizes).
        let small = pts[0].dp_latency_ns / pts[0].mp_latency_ns;
        let big = pts[4].dp_latency_ns / pts[4].mp_latency_ns;
        assert!(big > small, "small={small} big={big}");
    }

    #[test]
    fn cls_spill_point_matches_capacity() {
        // 4096-in FC: CLS (64KB, ×2 headroom rule) fits up to ~64 neurons.
        let pts = crossover_sweep(4096, &[32, 64, 128], ChainConfig::default());
        assert_eq!(pts[0].dp_mem, MemKind::Cls);
        assert_eq!(pts[2].dp_mem, MemKind::Emem);
    }

    #[test]
    fn stragglers_hurt_linearly() {
        let model = BnnModel::random("fc", 4096, &[4096], 2);
        let cfg = ChainConfig::default();
        let even = straggler_latency_ns(&model, cfg, 1.0);
        let skew2 = straggler_latency_ns(&model, cfg, 2.0);
        let skew4 = straggler_latency_ns(&model, cfg, 4.0);
        assert!(skew2 > even && skew4 > skew2);
        // Linear in skew: equal increments.
        let d1 = skew2 - even;
        let d2 = skew4 - skew2;
        assert!((d2 / d1 - 2.0).abs() < 1e-9);
    }
}

//! Fixed-point (Q-format i32) quantized-MLP executor with
//! Taylor-approximated activations — the `qmlp` backend (ISSUE 9).
//!
//! The P4-FPGA SmartNIC line of work (arXiv 2507.00428, PAPERS.md) runs
//! small quantized MLPs in the data plane with integer-only arithmetic:
//! weights and activations in a fixed Q-format, and transcendental
//! activations replaced by low-order Taylor polynomials evaluated in the
//! same integer domain.  This module reproduces that executor shape on
//! the host so the scenario suite can score it next to the BNN planes:
//!
//! * [`QFormat`] — `Qx.f` fixed point in `i32` with `f` fractional bits
//!   (`f ∈ 1..=16`), saturating add/mul, half-away-from-zero rounding,
//!   and a load-time gate that rejects zero/non-power-of-two scales.
//! * [`QFormat::sigmoid_taylor`] — `σ̃(x) = ½ + x/4 − x³/48` on the
//!   clamp range `[−2, 2]`, evaluated with a **single** rounded division
//!   of a monotone numerator, so the approximation is monotone and odd
//!   (`σ̃(x) + σ̃(−x) = 1` exactly) at every resolution.
//! * [`QuantMlp`] / [`QmlpExecutor`] — dense integer layers with
//!   [`Activation`] per layer and a scratch-reusing forward pass.
//!
//! The bridge to the rest of the crate is [`QuantMlp::from_bnn`]: a BNN
//! layer fires iff `popcount ≥ T = W/2` iff the ±1 dot product
//! `2·popcount − W ≥ 0`, and on those inputs the Taylor sigmoid crosses
//! ½ at exactly the same point, so the quantized network is
//! **verdict-identical** to Algorithm 1 (same class, ties included) —
//! which is what lets the `qmlp` backend ride the existing conformance
//! matrix and scenario floors unchanged (`tests/qmlp.rs` proves it).

use std::fmt;

use crate::bnn::{argmax, BnnModel};

/// Fractional bits the `qmlp` backend uses (`Q23.8`): enough headroom
/// for every scenario model and an exact `from_bnn` round trip.
pub const QMLP_FRAC_BITS: u32 = 8;

/// Typed errors for Q-format construction and model loading.
#[derive(Debug, Clone, PartialEq)]
pub enum QmlpError {
    /// Fractional bit count outside `1..=16`.
    BadFracBits(u32),
    /// Quantization scale that is zero, negative, non-finite, or not a
    /// power of two in `[2^-16, 2^-1]` — rejected at load time.
    BadScale(f64),
    /// A non-finite weight/bias/input reached the quantizer.
    NonFinite(f64),
    /// Layer geometry that cannot be wired into a network.
    Shape(String),
}

impl fmt::Display for QmlpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            QmlpError::BadFracBits(b) => write!(f, "frac_bits {b} outside the supported 1..=16"),
            QmlpError::BadScale(s) => {
                write!(f, "scale {s} is not a power-of-two in [2^-16, 2^-1]")
            }
            QmlpError::NonFinite(v) => write!(f, "non-finite value {v} cannot be quantized"),
            QmlpError::Shape(msg) => write!(f, "bad qmlp shape: {msg}"),
        }
    }
}

impl std::error::Error for QmlpError {}

/// Round `v / 2^f` half away from zero (the DSP convention; symmetric,
/// so negating the input negates the output).
fn round_shift(v: i64, f: u32) -> i64 {
    debug_assert!(f >= 1);
    let half = 1i64 << (f - 1);
    if v >= 0 {
        v.saturating_add(half) >> f
    } else {
        -(v.saturating_neg().saturating_add(half) >> f)
    }
}

/// Round `n / d` half away from zero (`d > 0`).
fn round_div(n: i64, d: i64) -> i64 {
    debug_assert!(d > 0);
    let half = d / 2;
    if n >= 0 {
        (n + half) / d
    } else {
        -((-n + half) / d)
    }
}

/// Saturate an `i64` into `i32`.
fn sat_i32(v: i64) -> i32 {
    v.clamp(i32::MIN as i64, i32::MAX as i64) as i32
}

/// A `Qx.f` fixed-point format in `i32`: `f` fractional bits, value
/// `q / 2^f`.  All arithmetic saturates instead of wrapping — data-plane
/// executors cannot trap on overflow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QFormat {
    frac_bits: u32,
}

impl QFormat {
    /// `f` fractional bits, `1..=16` (beyond 16 the Taylor numerator
    /// `12·2^2f·x − x³` would not fit the i64 intermediate).
    pub fn new(frac_bits: u32) -> Result<Self, QmlpError> {
        if !(1..=16).contains(&frac_bits) {
            return Err(QmlpError::BadFracBits(frac_bits));
        }
        Ok(Self { frac_bits })
    }

    /// Build from a quantization scale, the way model files carry it.
    /// Only exact power-of-two scales `2^-16 ..= 2^-1` are accepted;
    /// zero, negative, and non-finite scales are load-time errors.
    pub fn from_scale(scale: f64) -> Result<Self, QmlpError> {
        if scale.is_finite() && scale > 0.0 {
            for f in 1..=16u32 {
                if scale == 2f64.powi(-(f as i32)) {
                    return Self::new(f);
                }
            }
        }
        Err(QmlpError::BadScale(scale))
    }

    pub fn frac_bits(&self) -> u32 {
        self.frac_bits
    }

    /// The fixed-point representation of 1.0.
    pub fn one(&self) -> i32 {
        1i32 << self.frac_bits
    }

    /// Quantize an `f64` (round half away from zero, saturate to i32).
    /// Non-finite inputs are errors, not silent saturations.
    pub fn quantize(&self, v: f64) -> Result<i32, QmlpError> {
        if !v.is_finite() {
            return Err(QmlpError::NonFinite(v));
        }
        let scaled = (v * self.one() as f64).round();
        if scaled >= i32::MAX as f64 {
            Ok(i32::MAX)
        } else if scaled <= i32::MIN as f64 {
            Ok(i32::MIN)
        } else {
            Ok(scaled as i32)
        }
    }

    /// The real value a fixed-point number represents.
    pub fn to_f64(&self, q: i32) -> f64 {
        q as f64 / self.one() as f64
    }

    /// Saturating fixed-point add.
    pub fn sat_add(&self, a: i32, b: i32) -> i32 {
        a.saturating_add(b)
    }

    /// Saturating fixed-point multiply: exact i64 product, rounded back
    /// by `f` bits, saturated to i32.
    pub fn mul(&self, a: i32, b: i32) -> i32 {
        sat_i32(round_shift(a as i64 * b as i64, self.frac_bits))
    }

    /// Degree-3 Taylor sigmoid `σ̃(x) = ½ + x/4 − x³/48`, clamped to
    /// `x ∈ [−2, 2]` where the polynomial is monotone.
    ///
    /// Evaluated as `½ + round((12·2^2f·x − x³) / (48·2^2f))` — one
    /// rounded division of a numerator whose derivative `12·2^2f − 3x²`
    /// is ≥ 0 on the clamp range, so the fixed-point curve is monotone;
    /// half-away rounding is odd, so `σ̃(x) + σ̃(−x) = one` exactly and
    /// `σ̃(0) = one/2` exactly.
    pub fn sigmoid_taylor(&self, x: i32) -> i32 {
        let one = self.one() as i64;
        let x = (x as i64).clamp(-2 * one, 2 * one);
        let one_sq = one * one;
        let num = 12 * one_sq * x - x * x * x;
        let den = 48 * one_sq;
        sat_i32((one >> 1) + round_div(num, den))
    }
}

/// Per-layer activation of a quantized MLP.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    /// Pass the Q-format pre-activation through (final scoring layers).
    Identity,
    /// The clamped degree-3 Taylor sigmoid.
    TaylorSigmoid,
    /// Binarize on the sigmoid's ½ crossing: `+one` iff `σ̃(x) ≥ ½`.
    /// This is the BNN sign threshold in fixed point.
    TaylorSign,
}

impl Activation {
    fn apply(self, q: QFormat, x: i32) -> i32 {
        match self {
            Activation::Identity => x,
            Activation::TaylorSigmoid => q.sigmoid_taylor(x),
            Activation::TaylorSign => {
                if q.sigmoid_taylor(x) >= q.one() >> 1 {
                    q.one()
                } else {
                    -q.one()
                }
            }
        }
    }
}

/// One dense integer layer: `neurons × inputs` Q-format weights
/// (row-major), per-neuron bias, one activation.
#[derive(Debug, Clone)]
pub struct QuantLayer {
    pub neurons: usize,
    pub inputs: usize,
    weights: Vec<i32>,
    bias: Vec<i32>,
    pub act: Activation,
}

impl QuantLayer {
    /// Build from already-quantized weights.
    pub fn new(
        neurons: usize,
        inputs: usize,
        weights: Vec<i32>,
        bias: Vec<i32>,
        act: Activation,
    ) -> Result<Self, QmlpError> {
        if neurons == 0 || inputs == 0 {
            return Err(QmlpError::Shape(format!("empty layer {neurons}x{inputs}")));
        }
        if weights.len() != neurons * inputs {
            return Err(QmlpError::Shape(format!(
                "weight count {} != {neurons}x{inputs}",
                weights.len()
            )));
        }
        if bias.len() != neurons {
            return Err(QmlpError::Shape(format!("bias count {} != {neurons}", bias.len())));
        }
        Ok(Self { neurons, inputs, weights, bias, act })
    }

    /// The load path: quantize f64 weights/biases, rejecting non-finite
    /// values and shape mismatches before anything reaches the executor.
    pub fn quantized(
        neurons: usize,
        inputs: usize,
        weights: &[f64],
        bias: &[f64],
        act: Activation,
        q: QFormat,
    ) -> Result<Self, QmlpError> {
        let w = weights.iter().map(|&v| q.quantize(v)).collect::<Result<Vec<_>, _>>()?;
        let b = bias.iter().map(|&v| q.quantize(v)).collect::<Result<Vec<_>, _>>()?;
        Self::new(neurons, inputs, w, b, act)
    }

    /// Forward one input vector: i64 multiply-accumulate over Q(f)
    /// operands (product is Q(2f)), one rounding back to Q(f), bias,
    /// activation.
    fn forward(&self, q: QFormat, x: &[i32], out: &mut [i32]) {
        debug_assert_eq!(x.len(), self.inputs);
        for (n, o) in out.iter_mut().enumerate().take(self.neurons) {
            let row = &self.weights[n * self.inputs..(n + 1) * self.inputs];
            let acc = row
                .iter()
                .zip(x)
                .fold(0i64, |a, (&w, &v)| a.saturating_add(w as i64 * v as i64));
            let pre = q.sat_add(sat_i32(round_shift(acc, q.frac_bits())), self.bias[n]);
            *o = self.act.apply(q, pre);
        }
    }
}

/// A quantized MLP: layers chained with BNN-style width padding.  A
/// layer may feed a *wider* next layer only through
/// [`Activation::TaylorSign`], because the pad slots are filled with
/// `−one` — the packed-BNN convention that 0 pad bits mean −1 in the
/// ±1 algebra.
#[derive(Debug, Clone)]
pub struct QuantMlp {
    name: String,
    q: QFormat,
    layers: Vec<QuantLayer>,
}

impl QuantMlp {
    pub fn new(name: &str, q: QFormat, layers: Vec<QuantLayer>) -> Result<Self, QmlpError> {
        if layers.is_empty() {
            return Err(QmlpError::Shape("no layers".into()));
        }
        for (k, pair) in layers.windows(2).enumerate() {
            let (a, b) = (&pair[0], &pair[1]);
            if b.inputs < a.neurons {
                return Err(QmlpError::Shape(format!(
                    "layer {k} feeds {} neurons into {} inputs",
                    a.neurons, b.inputs
                )));
            }
            if b.inputs > a.neurons && a.act != Activation::TaylorSign {
                return Err(QmlpError::Shape(format!(
                    "layer {k} pads {} -> {} without a sign activation",
                    a.neurons, b.inputs
                )));
            }
        }
        Ok(Self { name: name.to_string(), q, layers })
    }

    /// Verdict-identical quantization of a packed BNN (see the module
    /// docs): ±1 weights become `±one`, the sign threshold `T` becomes
    /// the bias `(W − 2T)·one` (zero under Algorithm 1's `T = W/2`),
    /// hidden layers activate through [`Activation::TaylorSign`], and
    /// the final layer scores through [`Activation::Identity`] — an
    /// affine, order-preserving map of the BNN's popcount scores.
    pub fn from_bnn(model: &BnnModel, frac_bits: u32) -> Result<Self, QmlpError> {
        let q = QFormat::new(frac_bits)?;
        let one = q.one();
        let n_layers = model.layers.len();
        let mut layers = Vec::with_capacity(n_layers);
        for (k, l) in model.layers.iter().enumerate() {
            let inputs = l.in_words * 32;
            let mut weights = Vec::with_capacity(l.neurons * inputs);
            for n in 0..l.neurons {
                for &w32 in l.row(n) {
                    for b in 0..32 {
                        weights.push(if (w32 >> b) & 1 == 1 { one } else { -one });
                    }
                }
            }
            let bias_q = (inputs as i64 - 2 * l.threshold as i64) * one as i64;
            let bias = vec![sat_i32(bias_q); l.neurons];
            let act = if k + 1 == n_layers {
                Activation::Identity
            } else {
                Activation::TaylorSign
            };
            layers.push(QuantLayer::new(l.neurons, inputs, weights, bias, act)?);
        }
        Self::new(&model.name, q, layers)
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn q(&self) -> QFormat {
        self.q
    }

    pub fn layers(&self) -> &[QuantLayer] {
        &self.layers
    }

    /// Input width of the first layer.
    pub fn in_len(&self) -> usize {
        self.layers[0].inputs
    }

    pub fn out_neurons(&self) -> usize {
        self.layers.last().unwrap().neurons
    }
}

/// Scratch-reusing forward executor for a [`QuantMlp`] — the plane the
/// `qmlp` backend wraps.
pub struct QmlpExecutor {
    mlp: QuantMlp,
    buf_a: Vec<i32>,
    buf_b: Vec<i32>,
    scores: Vec<i32>,
}

impl QmlpExecutor {
    pub fn new(mlp: QuantMlp) -> Self {
        let width = mlp.layers.iter().map(|l| l.inputs.max(l.neurons)).max().unwrap_or(0);
        Self { mlp, buf_a: vec![0; width], buf_b: vec![0; width], scores: Vec::new() }
    }

    pub fn from_bnn(model: &BnnModel, frac_bits: u32) -> Result<Self, QmlpError> {
        Ok(Self::new(QuantMlp::from_bnn(model, frac_bits)?))
    }

    pub fn mlp(&self) -> &QuantMlp {
        &self.mlp
    }

    /// Forward a Q-format input vector; `scores` receives the final
    /// layer's outputs (`out_neurons` of them).
    pub fn infer(&mut self, x: &[i32], scores: &mut [i32]) {
        assert_eq!(x.len(), self.mlp.in_len(), "input width != first layer inputs");
        self.buf_a[..x.len()].copy_from_slice(x);
        self.run_layers(scores);
    }

    /// Forward a packed bit vector (the wire format every other backend
    /// consumes): bit `i` of word `i/32` expands to `±one`, exactly the
    /// BNN's ±1 input algebra.
    pub fn infer_bits(&mut self, x: &[u32], scores: &mut [i32]) {
        let n_in = self.mlp.in_len();
        assert_eq!(x.len() * 32, n_in, "packed input width != first layer inputs");
        let one = self.mlp.q.one();
        for (i, slot) in self.buf_a.iter_mut().enumerate().take(n_in) {
            let bit = (x[i / 32] >> (i % 32)) & 1;
            *slot = if bit == 1 { one } else { -one };
        }
        self.run_layers(scores);
    }

    /// Classify a packed bit input: forward + argmax (ties low, same as
    /// [`argmax`] everywhere else in the crate).
    pub fn classify(&mut self, x: &[u32]) -> usize {
        let mut scores = std::mem::take(&mut self.scores);
        scores.resize(self.mlp.out_neurons(), 0);
        self.infer_bits(x, &mut scores);
        let class = argmax(&scores);
        self.scores = scores;
        class
    }

    /// Run all layers assuming `buf_a` holds the first layer's inputs.
    fn run_layers(&mut self, scores: &mut [i32]) {
        assert_eq!(scores.len(), self.mlp.out_neurons(), "score buffer width");
        let q = self.mlp.q;
        let neg_one = -q.one();
        let n_layers = self.mlp.layers.len();
        let mut cur_in_a = true;
        for k in 0..n_layers - 1 {
            let layer = &self.mlp.layers[k];
            let next_inputs = self.mlp.layers[k + 1].inputs;
            let (src, dst) = if cur_in_a {
                (&self.buf_a, &mut self.buf_b)
            } else {
                (&self.buf_b, &mut self.buf_a)
            };
            layer.forward(q, &src[..layer.inputs], &mut dst[..layer.neurons]);
            // BNN-style width padding: pad slots carry −1 (= 0 pad bits
            // in the packed algebra); QuantMlp::new proved layer k is a
            // sign layer whenever this range is non-empty.
            dst[layer.neurons..next_inputs].fill(neg_one);
            cur_in_a = !cur_in_a;
        }
        let last = &self.mlp.layers[n_layers - 1];
        let src = if cur_in_a { &self.buf_a } else { &self.buf_b };
        last.forward(q, &src[..last.inputs], scores);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rounding_helpers_are_symmetric_and_half_away() {
        assert_eq!(round_shift(384, 8), 2, "256+128 rounds up");
        assert_eq!(round_shift(-384, 8), -2, "symmetric");
        assert_eq!(round_shift(383, 8), 1);
        assert_eq!(round_shift(-383, 8), -1);
        assert_eq!(round_shift(i64::MIN, 8), -(i64::MAX >> 8), "saturating negate");
        assert_eq!(round_div(5, 10), 1, "half rounds away");
        assert_eq!(round_div(-5, 10), -1);
        assert_eq!(round_div(4, 10), 0);
        assert_eq!(round_div(-1, 10), 0);
    }

    #[test]
    fn from_bnn_matches_the_bnn_classifier_on_a_small_model() {
        let model = BnnModel::random("q", 96, &[16, 4], 5);
        let mut bnn = crate::bnn::BnnExecutor::new(model.clone());
        let mut qx = QmlpExecutor::from_bnn(&model, QMLP_FRAC_BITS).unwrap();
        for seed in 0..24u64 {
            let x = crate::bnn::BnnLayer::random(1, 96, 1000 + seed).words;
            assert_eq!(qx.classify(&x), bnn.classify(&x), "seed {seed}");
        }
    }

    #[test]
    fn final_layer_scores_are_the_affine_bnn_scores() {
        let model = BnnModel::random("q", 64, &[8, 3], 7);
        let mut qx = QmlpExecutor::from_bnn(&model, QMLP_FRAC_BITS).unwrap();
        let x = crate::bnn::BnnLayer::random(1, 64, 77).words;
        let bnn_scores = crate::bnn::infer_scores(&model, &x);
        let mut q_scores = vec![0; model.out_neurons()];
        qx.infer_bits(&x, &mut q_scores);
        let one = qx.mlp().q().one();
        let w_last = qx.mlp().layers().last().unwrap().inputs as i32;
        for (&s, &sq) in bnn_scores.iter().zip(&q_scores) {
            assert_eq!(sq, (2 * s - w_last) * one, "q = (2s - W)*one");
        }
    }
}

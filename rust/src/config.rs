//! Launcher configuration: artifacts location, device selection, service
//! parameters.  Loaded from JSON (`--config`) with CLI overrides.
//!
//! Since the `InferencePlane` unification, backend names are registered
//! in [`BackendFactory`](crate::coordinator::BackendFactory) — the
//! [`Backend`] enum here is a deprecated duplicate vocabulary kept one
//! PR for config-file compatibility.
#![allow(deprecated)]

use std::path::PathBuf;
use std::str::FromStr;

use crate::json::Json;

/// Which executor backend the coordinator drives.
#[deprecated(
    note = "backend names live in `coordinator::BackendFactory` now; \
            build planes with `BackendFactory::single_sharded(name, …)`"
)]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// NFP4000 SoC model, data-parallel mode (N3IC-NFP).
    Nfp,
    /// PISA pipeline model compiled by NNtoP4 (N3IC-P4).
    Pisa,
    /// Dedicated hardware NN-executor model (N3IC-FPGA).
    Fpga,
    /// Host CPU `bnn-exec` baseline (over simulated PCIe).
    Host,
    /// PJRT runtime executing the AOT JAX/Pallas artifact.
    Pjrt,
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Backend::Nfp => "nfp",
            Backend::Pisa => "pisa",
            Backend::Fpga => "fpga",
            Backend::Host => "host",
            Backend::Pjrt => "pjrt",
        };
        f.write_str(s)
    }
}

impl FromStr for Backend {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "nfp" => Backend::Nfp,
            "pisa" | "p4" => Backend::Pisa,
            "fpga" => Backend::Fpga,
            "host" | "bnn-exec" => Backend::Host,
            "pjrt" => Backend::Pjrt,
            other => anyhow::bail!(
                "unknown backend '{other}' (nfp|pisa|fpga|host|pjrt)"
            ),
        })
    }
}

/// Top-level service configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Artifacts directory (models/, *.hlo.txt, manifest.json).
    pub artifacts: PathBuf,
    /// Model name to deploy (e.g. "traffic").
    pub model: String,
    /// Executor backend.
    pub backend: Backend,
    /// Offered load for simulated drivers (flows per second).
    pub flows_per_sec: f64,
    /// Batch size for the host baseline.
    pub batch: usize,
    /// NFP threads dedicated to NN execution.
    pub nfp_threads: usize,
    /// Number of FPGA NN-executor modules.
    pub fpga_modules: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            artifacts: PathBuf::from("artifacts"),
            model: "traffic".into(),
            backend: Backend::Fpga,
            flows_per_sec: 1_800_000.0,
            batch: 1,
            nfp_threads: 480,
            fpga_modules: 1,
        }
    }
}

impl Config {
    pub fn load(path: &std::path::Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let v = Json::parse(&text)?;
        let mut c = Self::default();
        if let Some(a) = v.get("artifacts").and_then(Json::as_str) {
            c.artifacts = PathBuf::from(a);
        }
        if let Some(m) = v.get("model").and_then(Json::as_str) {
            c.model = m.to_string();
        }
        if let Some(b) = v.get("backend").and_then(Json::as_str) {
            c.backend = b.parse()?;
        }
        if let Some(f) = v.get("flows_per_sec").and_then(Json::as_f64) {
            c.flows_per_sec = f;
        }
        if let Some(b) = v.get("batch").and_then(Json::as_usize) {
            c.batch = b;
        }
        if let Some(t) = v.get("nfp_threads").and_then(Json::as_usize) {
            c.nfp_threads = t;
        }
        if let Some(m) = v.get("fpga_modules").and_then(Json::as_usize) {
            c.fpga_modules = m;
        }
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse() {
        assert_eq!(Backend::from_str("fpga").unwrap(), Backend::Fpga);
        assert_eq!(Backend::from_str("p4").unwrap(), Backend::Pisa);
        assert!(Backend::from_str("gpu").is_err());
        assert_eq!(Backend::Host.to_string(), "host");
    }

    #[test]
    fn config_from_json() {
        let dir = std::env::temp_dir().join("n3ic_cfg_test.json");
        std::fs::write(
            &dir,
            r#"{"model":"anomaly","backend":"nfp","batch":64,"nfp_threads":120}"#,
        )
        .unwrap();
        let c = Config::load(&dir).unwrap();
        assert_eq!(c.model, "anomaly");
        assert_eq!(c.backend, Backend::Nfp);
        assert_eq!(c.batch, 64);
        assert_eq!(c.nfp_threads, 120);
        assert_eq!(c.fpga_modules, 1); // default preserved
        std::fs::remove_file(dir).ok();
    }
}

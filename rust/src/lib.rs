//! # N3IC — binary neural network inference in the NIC data plane
//!
//! Full-system reproduction of *"Running Neural Network Inference on the
//! NIC"* (Siracusano et al., 2020) as a three-layer Rust + JAX + Pallas
//! stack.  This crate is Layer 3: everything that runs at request time —
//! the NIC device models, the packet/flow substrate, the N3IC coordinator,
//! the host-CPU baseline (`bnn-exec`), and a PJRT runtime that executes the
//! AOT-compiled JAX/Pallas model (`artifacts/*.hlo.txt`).  Python never
//! appears on the request path.
//!
//! ## Module map (DESIGN.md §3 inventory)
//!
//! * [`bnn`] — packed binary-MLP model + the bit-exact executor shared by
//!   every device model (Algorithm 1 of the paper).
//! * [`pcie`] — analytic PCIe transfer-cost model (Fig. 3 motivation).
//! * [`arith`] — arithmetic-intensity model of NN layers (Fig. 4).
//! * [`net`] — packets, parsing, flow table, statistics, traffic generators.
//! * [`nfp`] — Netronome NFP4000 SoC model (islands/MEs/threads, CLS/CTM/
//!   IMEM/EMEM, data-parallel + model-parallel execution, Fig. 19–26).
//! * [`pisa`] — PISA match-action pipeline + the NNtoP4 compiler (§4.2).
//! * [`fpga`] — the dedicated NN-executor hardware module model (§4.3).
//! * [`fattree`] — discrete-event CLOS fat-tree network simulator (the
//!   ns-3 substitute for the SIMON tomography use case).
//! * [`tomography`] — modified-SIMON probe/inference pipeline (§5 #3).
//! * [`bnnexec`] — the host-CPU comparison system (§6 "comparison term").
//! * [`qmlp`] — fixed-point (Q-format i32) quantized-MLP executor with
//!   Taylor-approximated activations, after the P4-FPGA SmartNIC line of
//!   work; `QuantMlp::from_bnn` is verdict-equivalent to Algorithm 1, so
//!   the `qmlp` backend rides the same conformance matrix.
//! * [`coordinator`] — triggers, input/output selectors, flow shunting,
//!   batching, and the unified serving runtime: one `InferencePlane`
//!   trait over every backend, a named `BackendFactory`, and one
//!   `Service` built by `ServeBuilder` (§3.2's orchestration).
//! * [`learn`] — the online-learning subsystem: drift detection
//!   (Page–Hinkley on per-window labeled accuracy), in-process
//!   retraining from a bounded labeled reservoir, and gate-guarded
//!   live republish with probation rollback over the registry's
//!   zero-downtime hot swap.
//! * `runtime` — PJRT loader/executor for the AOT artifacts (behind the
//!   off-by-default `pjrt` feature: needs a vendored xla-rs).
//! * [`scenario`] — the three paper use cases (§5: traffic analysis,
//!   anomaly detection, tomography) as seeded, oracle-scored end-to-end
//!   scenarios behind one `Scenario` trait, all served by `ServeBuilder`.
//! * [`experiments`] — one reproduction driver per paper table/figure.

#![deny(rustdoc::broken_intra_doc_links)]

pub mod arith;
pub mod bench;
pub mod bnn;
pub mod bnnexec;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod fattree;
pub mod fpga;
pub mod json;
pub mod learn;
pub mod metrics;
pub mod net;
pub mod nfp;
pub mod pcie;
pub mod pisa;
pub mod qmlp;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod scenario;
pub mod tomography;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

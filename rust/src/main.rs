//! `repro` — the N3IC launcher.
//!
//! Subcommands:
//! * `serve`        — run the unified serving runtime on generated
//!   traffic (the end-to-end request path; Python never runs here).
//! * `experiment`   — regenerate a paper table/figure (or `all`).
//! * `models`       — list trained models in the artifacts directory.
//! * `compile-p4`   — run NNtoP4 and print the generated P4₁₆ source.
//!
//! Flag parsing is hand-rolled (the build is offline; no clap) but
//! **strict**: unknown flags, missing values, malformed numbers, and
//! malformed `--model NAME=PATH` pairs exit nonzero with usage instead
//! of being silently defaulted.

use std::path::PathBuf;

use n3ic::bnn::{BnnModel, RegistryHandle};
use n3ic::coordinator::{
    BackendFactory, DegradeSpec, InferencePlane, ModelRouter, OutputSelector, PacketEvent,
    ServeBuilder, ServiceReport, ShedPolicy, TriggerCondition, STAGE_LINKS,
};
use n3ic::net::flow::EvictPolicy;
use n3ic::net::traffic::{CbrSpec, ChurnGen, ChurnSpec, TrafficGen};

const USAGE: &str = "\
repro — N3IC: NN inference in the NIC data plane

USAGE:
  repro [--artifacts DIR] <command> [options]

COMMANDS:
  serve        --model NAME --backend host|batch|sharded|pisa|fpga|nfp|placed|qmlp|pjrt
               --packets N --flows N --trigger-pkts N
               --batch N (0 = classify inline; N>0 = batch fast path)
               --shards N (spread batches over N cores where the
                           backend's capabilities allow)
               --pipeline N (N>=1: staged runtime with N parse workers;
                             verdicts are bit-identical to the serial
                             loop on the same seeded traffic)
               --queue-depth N (with --pipeline: bounded stage queues;
                                0 is rejected — it would deadlock)
               --table-cap N (total flow-table capacity budget, split
                              over the fixed logical shards; default
                              65536 — set it below --flows to exercise
                              eviction)
               --evict lru|age:NS|off
                             (full-probe-window behavior: replace the
                              stalest flow in the window [default],
                              same plus aging out flows idle longer
                              than NS nanoseconds, or never evict and
                              leave overflow packets untracked)
               --churn FRAC  (0.0-1.0: drive adversarial churn traffic
                              instead of the fixed flow population —
                              FRAC of packets are one-shot never-
                              repeating flows, the rest a heavy-tailed
                              working set of --flows flows that
                              replaces itself as budgets drain)
               --shed-policy MAX_US[:RESUME_US] | off
                             (admission control: shed triggered work
                              once the modeled backlog passes MAX_US
                              microseconds, resume below RESUME_US;
                              RESUME_US defaults to MAX_US/4)
               --degrade on|off (degradation ladder: under sustained
                                 pressure step down to trigger-only
                                 mode and back up on recovery; every
                                 transition lands in the report)

               Multi-model registry mode (repeat --model with NAME=PATH
               pairs to serve several named, versioned models at once
               through the `registry` backend; flows are split across
               them by canonical flow hash):
               --model anomaly=m1.json --model traffic-class=m2.json
               --swap-every N (hot-republish one model every N packets
                               — zero-downtime weight swap demo: the
                               run never pauses, verdict tags move to
                               the new version, per-model swap counts
                               land in the report)
               --online-learn NAME (registry mode: attach the online-
                               learning loop to slot NAME — windowed
                               labeled accuracy, Page–Hinkley drift
                               detection, in-process refit, gated
                               republish; telemetry lands in the report)
               In-process control plane: hold a clone of the service's
               RegistryHandle and call publish(name, &model) from any
               thread; readers observe the new version on their next
               batch, never a torn one.
  scenario     <traffic|anomaly|tomography|drift> — serve one paper use
               case (§5) end-to-end with its seeded workload, calibrated
               model, and ground-truth oracle, then print the score
               --events N (0 = scenario default; packets for the
                           flow-stats scenarios, probe rounds for
                           tomography)
               --flows N --trigger-pkts N --seed N
               --backend NAME (any serve backend; `registry` publishes
                               the scenario model and serves it routed,
                               hot-swap capable)
               --pipeline N --batch N --shards N
               --table-cap N --evict lru|age:NS|off
               --shed-policy MAX_US[:RESUME_US] | off
               --gate normal|sabotage|force-accept
                             (drift only: promotion-gate fault injection.
                              `sabotage` inverts every retrained
                              candidate — the gate must reject them all;
                              `force-accept` publishes one bad candidate
                              past the gate — probation must roll it
                              back.  Either mode passes on correct gate
                              behavior instead of the accuracy floor)
               The report ends with `floor check ... PASS|FAIL` and an
               order-independent `verdict digest` — identical for
               serial and pipelined runs of the same seed.  The drift
               scenario also prints `drift check` and `recovery check`
               lines covering the online-learning loop.
  experiment   <fig03|...|tab02|abl-crossover|abl-cam|all>
  models
  compile-p4   --model NAME [--format p4|bmv2]
";

/// Print a parse/config error plus usage and exit nonzero.
fn usage_err(msg: &str) -> ! {
    eprintln!("error: {msg}\n\n{USAGE}");
    std::process::exit(2);
}

/// Strict flag parser: `--key value` pairs plus positionals.  Flags are
/// repeatable; scalar getters take the last occurrence, `get_all` sees
/// every one (the registry mode's repeated `--model NAME=PATH`).
struct Args {
    flags: std::collections::HashMap<String, Vec<String>>,
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self, String> {
        let mut flags: std::collections::HashMap<String, Vec<String>> =
            std::collections::HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                if key.is_empty() {
                    return Err("bare `--` is not a flag".into());
                }
                let Some(value) = argv.get(i + 1) else {
                    return Err(format!("--{key} needs a value"));
                };
                if value.starts_with("--") {
                    return Err(format!("--{key} needs a value (got flag {value})"));
                }
                flags.entry(key.to_string()).or_default().push(value.clone());
                i += 2;
            } else {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
        Ok(Self { flags, positional })
    }

    /// Reject flags outside `allowed` (per-subcommand whitelist).
    fn check_allowed(&self, cmd: &str, allowed: &[&str]) -> Result<(), String> {
        let mut keys: Vec<&String> = self.flags.keys().collect();
        keys.sort();
        for key in keys {
            if !allowed.contains(&key.as_str()) {
                return Err(format!(
                    "unknown flag --{key} for `{cmd}` (allowed: {})",
                    allowed
                        .iter()
                        .map(|a| format!("--{a}"))
                        .collect::<Vec<_>>()
                        .join(" ")
                ));
            }
        }
        Ok(())
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .and_then(|v| v.last())
            .cloned()
            .unwrap_or_else(|| default.into())
    }

    fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.flags.get(key).and_then(|v| v.last()) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} {v:?} is not a non-negative integer")),
        }
    }

    fn get_all(&self, key: &str) -> Vec<String> {
        self.flags.get(key).cloned().unwrap_or_default()
    }
}

fn load_model(artifacts: &std::path::Path, name: &str) -> BnnModel {
    BnnModel::load_named(artifacts, name).unwrap_or_else(|e| {
        eprintln!("warning: {e}; using random weights for shape {name}");
        // The scenario registry is the one authoritative list of use-case
        // model shapes; anything it doesn't know gets the flow-stats
        // default.
        match n3ic::scenario::model_shape(name) {
            Some((in_bits, arch)) => BnnModel::random(name, in_bits, arch, 1),
            None => BnnModel::random(name, 256, &[32, 16, 2], 1),
        }
    })
}

fn main() -> n3ic::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => usage_err(&e),
    };
    let artifacts = PathBuf::from(args.get("artifacts", "artifacts"));
    let cmd = args.positional.first().map(String::as_str).unwrap_or("");
    let allowed: &[&str] = match cmd {
        "serve" => &[
            "artifacts",
            "model",
            "backend",
            "packets",
            "flows",
            "trigger-pkts",
            "batch",
            "shards",
            "pipeline",
            "queue-depth",
            "table-cap",
            "evict",
            "churn",
            "swap-every",
            "shed-policy",
            "degrade",
            "online-learn",
        ],
        "scenario" => &[
            "artifacts",
            "events",
            "flows",
            "trigger-pkts",
            "seed",
            "backend",
            "pipeline",
            "batch",
            "shards",
            "table-cap",
            "evict",
            "shed-policy",
            "gate",
        ],
        "experiment" | "models" => &["artifacts"],
        "compile-p4" => &["artifacts", "model", "format"],
        _ => &["artifacts"],
    };
    if let Err(e) = args.check_allowed(if cmd.is_empty() { "repro" } else { cmd }, allowed) {
        usage_err(&e);
    }
    match cmd {
        "serve" => serve(&args, &artifacts),
        "scenario" => scenario_cmd(&args),
        "experiment" => {
            let id = args
                .positional
                .get(1)
                .map(String::as_str)
                .unwrap_or("all");
            if id == "all" {
                for e in n3ic::experiments::ALL {
                    println!("{}", n3ic::experiments::run(e, &artifacts)?);
                }
            } else {
                println!("{}", n3ic::experiments::run(id, &artifacts)?);
            }
            Ok(())
        }
        "models" => {
            let dir = artifacts.join("models");
            let mut found = false;
            if let Ok(rd) = std::fs::read_dir(&dir) {
                let mut entries: Vec<_> = rd.flatten().map(|e| e.path()).collect();
                entries.sort();
                for p in entries {
                    let name = p
                        .file_name()
                        .unwrap_or_default()
                        .to_string_lossy()
                        .to_string();
                    if name.ends_with(".json") && !name.ends_with(".golden.json") {
                        if let Ok(m) = BnnModel::load(&p) {
                            found = true;
                            println!(
                                "{:18} {:16} {:5}B  bin_acc={:.3} mlp_acc={:.3}",
                                m.name,
                                m.describe(),
                                m.memory_bytes(),
                                m.metrics.bnn_test_acc,
                                m.metrics.float_test_acc
                            );
                        }
                    }
                }
            }
            if !found {
                println!("no models in {} — run `make artifacts`", dir.display());
            }
            Ok(())
        }
        "compile-p4" => {
            let m = load_model(&artifacts, &args.get("model", "traffic"));
            let prog =
                n3ic::pisa::compile_bnn(&m).map_err(|e| anyhow::anyhow!("{e}"))?;
            match args.get("format", "p4").as_str() {
                "bmv2" => println!("{}", n3ic::pisa::bmv2::to_bmv2_json(&m, &prog).dump()),
                "p4" => println!("{}", n3ic::pisa::p4gen::to_p4(&m, &prog)),
                other => usage_err(&format!("--format {other:?} is not p4|bmv2")),
            }
            Ok(())
        }
        "" => {
            print!("{USAGE}");
            Ok(())
        }
        other => usage_err(&format!("unknown command {other:?}")),
    }
}

/// Run one paper use case end-to-end through the unified service and
/// print its oracle score, floor verdict, deadline checks, and the
/// order-independent verdict digest (the CI determinism gate compares
/// this line across serial and pipelined runs).
fn scenario_cmd(args: &Args) -> n3ic::Result<()> {
    let registry = n3ic::scenario::ScenarioRegistry::standard();
    let Some(name) = args.positional.get(1).map(String::as_str) else {
        usage_err(&format!(
            "scenario needs a name: {}",
            registry.names().join("|")
        ));
    };
    let cfg = n3ic::scenario::ScenarioConfig {
        events: match args.get_u64("events", 0) {
            Ok(v) => v,
            Err(e) => usage_err(&e),
        },
        flows: match args.get_u64("flows", 256) {
            Ok(v) => v,
            Err(e) => usage_err(&e),
        },
        trigger_pkts: match args.get_u64("trigger-pkts", 5) {
            Ok(v) => u32::try_from(v)
                .unwrap_or_else(|_| usage_err("--trigger-pkts does not fit in 32 bits")),
            Err(e) => usage_err(&e),
        },
        seed: match args.get_u64("seed", 7) {
            Ok(v) => v,
            Err(e) => usage_err(&e),
        },
        backend: args.get("backend", "fpga"),
        workers: match args.get_u64("pipeline", 0) {
            Ok(v) => v as usize,
            Err(e) => usage_err(&e),
        },
        batch: match args.get_u64("batch", 0) {
            Ok(v) => v as usize,
            Err(e) => usage_err(&e),
        },
        shards: match args.get_u64("shards", 1) {
            Ok(v) => v as usize,
            Err(e) => usage_err(&e),
        },
        flow_capacity: match args.get_u64("table-cap", 1 << 16) {
            Ok(v) => v as usize,
            Err(e) => usage_err(&e),
        },
        evict: match parse_evict(&args.get("evict", "lru")) {
            Ok(v) => v,
            Err(e) => usage_err(&e),
        },
        shed: match parse_shed_policy(&args.get("shed-policy", "off")) {
            Ok(v) => v,
            Err(e) => usage_err(&e),
        },
        admin: None,
        gate: {
            let g = args.get("gate", "normal");
            match n3ic::learn::GateMode::parse(&g) {
                Some(m) => Some(m),
                None => usage_err(&format!(
                    "--gate {g:?} is not normal|sabotage|force-accept"
                )),
            }
        },
    };
    let about = registry.get(name).map(|s| s.about().to_string());
    let rep = registry.run(name, &cfg)?;
    let st = &rep.service.stats;
    println!("== scenario report ==");
    println!("scenario         : {}", rep.scenario);
    if let Some(about) = about {
        println!("use case         : {about}");
    }
    println!("backend          : {}", rep.backend);
    println!("events           : {}", st.packets);
    println!("flows tracked    : {}", rep.service.flows_tracked);
    println!("nn inferences    : {}", st.inferences);
    if st.sheds > 0 {
        println!("sheds            : {}", st.sheds);
    }
    let ft = &st.flow_table;
    if ft.evictions + ft.aged_out > 0 {
        println!(
            "flow table       : evictions={} aged_out={}",
            ft.evictions, ft.aged_out
        );
    }
    let s = rep.score;
    println!(
        "score            : coverage={:.3} agreement={:.3} accuracy={:.3} \
         (scored {} of {} expected flows)",
        s.coverage, s.agreement, s.accuracy, s.scored, s.expected
    );
    for d in &rep.deadlines {
        println!(
            "deadline {:4}    : {} NNs in {:.0} us -> {}",
            d.link,
            d.nns,
            d.period_ns / 1e3,
            if d.ok { "ok" } else { "missed" }
        );
    }
    let gate_mode = cfg.gate.unwrap_or_default();
    if let Some(l) = &st.learn {
        println!(
            "learn            : windows={} evaluated={} retrains={} promotions={} \
             rejections={} rollbacks={}",
            l.windows, l.evaluated, l.retrains, l.promotions, l.rejections, l.rollbacks
        );
        if let (Some(c), Some(cur)) = (l.gate_last_candidate, l.gate_last_current) {
            println!("gate last score  : candidate={c:.3} current={cur:.3}");
        }
        match l.drift_fired_at {
            Some(p) => println!("drift check      : fired at packet {p} -> PASS"),
            None => println!("drift check      : never fired -> FAIL"),
        }
        let dip = n3ic::learn::min_window_accuracy(&st.accuracy_timeline);
        let rec = n3ic::learn::recovery_accuracy(&st.accuracy_timeline, 4);
        println!(
            "recovery check   : window accuracy dipped to {:.3}, last 4 windows {:.3} -> {}",
            dip,
            rec,
            if gate_mode == n3ic::learn::GateMode::Normal && rec >= 0.75 { "PASS" } else { "n/a" }
        );
    }
    println!(
        "floor check      : accuracy {:.3} vs floor {:.2} -> {}",
        s.accuracy,
        rep.floor,
        if rep.passes_floor() { "PASS" } else { "FAIL" }
    );
    println!("verdict digest   : 0x{:016x}", rep.digest());
    // Gate fault-injection runs pass on correct *gate* behavior — the
    // accuracy floor legitimately fails when the loop is sabotaged.
    match gate_mode {
        n3ic::learn::GateMode::Normal => {
            if !rep.passes_floor() {
                anyhow::bail!(
                    "scenario {name}: accuracy {:.3} below floor {:.2}",
                    s.accuracy,
                    rep.floor
                );
            }
        }
        n3ic::learn::GateMode::SabotageCandidate => {
            let Some(l) = &st.learn else {
                anyhow::bail!("--gate applies only to scenarios with a learning loop");
            };
            let ok = l.retrains >= 1 && l.promotions == 0 && l.rejections >= 1;
            println!(
                "gate check       : sabotaged candidates rejected={} promoted={} -> {}",
                l.rejections,
                l.promotions,
                if ok { "PASS" } else { "FAIL" }
            );
            if !ok {
                anyhow::bail!("scenario {name}: sabotaged candidate slipped the gate: {l:?}");
            }
        }
        n3ic::learn::GateMode::ForceAccept => {
            let Some(l) = &st.learn else {
                anyhow::bail!("--gate applies only to scenarios with a learning loop");
            };
            let ok = l.rollbacks >= 1;
            println!(
                "gate check       : forced bad publish rolled back {} time(s) -> {}",
                l.rollbacks,
                if ok { "PASS" } else { "FAIL" }
            );
            if !ok {
                anyhow::bail!("scenario {name}: probation never rolled back: {l:?}");
            }
        }
    }
    Ok(())
}

/// Verify the AOT artifact end to end, then serve through the bit-exact
/// core with the runtime's measured latency.
#[cfg(feature = "pjrt")]
fn pjrt_plane(
    m: BnnModel,
    artifacts: &std::path::Path,
    shards: usize,
) -> n3ic::Result<Box<dyn InferencePlane>> {
    let mut rt = n3ic::runtime::PjrtRuntime::new(artifacts)?;
    let key = n3ic::runtime::Manifest::key_for(&m, 1);
    let x = vec![0u32; m.in_words()];
    let t0 = std::time::Instant::now();
    let _ = rt.infer_batch(&key, &m, std::slice::from_ref(&x))?;
    let lat = t0.elapsed().as_nanos() as f64;
    println!("pjrt backend verified on {}", rt.platform());
    Ok(BackendFactory::custom("pjrt", m, lat, shards))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_plane(
    _m: BnnModel,
    _artifacts: &std::path::Path,
    _shards: usize,
) -> n3ic::Result<Box<dyn InferencePlane>> {
    anyhow::bail!(
        "the pjrt backend is compiled out: add a vendored `xla` path \
         dependency to rust/Cargo.toml (see the [features] comment there), \
         then build with `--features pjrt`"
    )
}

/// Common numeric serve knobs, parsed strictly.
struct ServeKnobs {
    packets: u64,
    flows: u64,
    trigger_pkts: u32,
    batch: usize,
    shards: usize,
    pipeline: usize,
    queue_depth: usize,
    table_cap: usize,
    evict: EvictPolicy,
    churn: f64,
    swap_every: u64,
    shed: Option<ShedPolicy>,
    degrade: bool,
}

/// Parse `--shed-policy MAX_US[:RESUME_US]` (microseconds) or `off`.
/// Resume defaults to a quarter of the ceiling — enough hysteresis that
/// the latch doesn't chatter around the threshold.
fn parse_shed_policy(v: &str) -> Result<Option<ShedPolicy>, String> {
    if v == "off" {
        return Ok(None);
    }
    let bad = || format!("--shed-policy {v:?} is not MAX_US[:RESUME_US] or off");
    let (max_s, resume_s) = match v.split_once(':') {
        Some((m, r)) => (m, Some(r)),
        None => (v, None),
    };
    let max_us: f64 = max_s.parse().map_err(|_| bad())?;
    let resume_us: f64 = match resume_s {
        Some(r) => r.parse().map_err(|_| bad())?,
        None => max_us / 4.0,
    };
    if max_us.is_nan() || max_us <= 0.0 || resume_us.is_nan() || resume_us < 0.0 {
        return Err(bad());
    }
    Ok(Some(ShedPolicy::new(max_us * 1e3, resume_us * 1e3)))
}

/// Parse `--evict lru|age:NS|off` (NS = max idle nanoseconds).
fn parse_evict(v: &str) -> Result<EvictPolicy, String> {
    match v {
        "lru" => Ok(EvictPolicy::Lru),
        "off" => Ok(EvictPolicy::Off),
        other => {
            let bad = || format!("--evict {other:?} is not lru|age:NS|off");
            let Some(ns) = other.strip_prefix("age:") else {
                return Err(bad());
            };
            let max_idle_ns: f64 = ns.parse().map_err(|_| bad())?;
            if max_idle_ns.is_nan() || max_idle_ns <= 0.0 {
                return Err(bad());
            }
            Ok(EvictPolicy::Age { max_idle_ns })
        }
    }
}

impl ServeKnobs {
    fn parse(args: &Args) -> Result<Self, String> {
        let queue_depth = args.get_u64("queue-depth", 1024)? as usize;
        if queue_depth == 0 {
            return Err("--queue-depth 0 would deadlock the pipeline; use 1 or more".into());
        }
        let degrade = match args.get("degrade", "off").as_str() {
            "on" => true,
            "off" => false,
            other => return Err(format!("--degrade {other:?} is not on|off")),
        };
        let churn_s = args.get("churn", "0");
        let churn: f64 = churn_s
            .parse()
            .map_err(|_| format!("--churn {churn_s:?} is not a number"))?;
        if !(0.0..=1.0).contains(&churn) {
            return Err(format!("--churn {churn} is outside 0.0..=1.0"));
        }
        Ok(Self {
            packets: args.get_u64("packets", 1_000_000)?,
            flows: args.get_u64("flows", 100_000)?,
            trigger_pkts: u32::try_from(args.get_u64("trigger-pkts", 10)?)
                .map_err(|_| "--trigger-pkts does not fit in 32 bits".to_string())?,
            batch: args.get_u64("batch", 0)? as usize,
            shards: args.get_u64("shards", 1)? as usize,
            pipeline: args.get_u64("pipeline", 0)? as usize,
            queue_depth,
            table_cap: args.get_u64("table-cap", 1 << 16)? as usize,
            evict: parse_evict(&args.get("evict", "lru"))?,
            churn,
            swap_every: args.get_u64("swap-every", 0)?,
            shed: parse_shed_policy(&args.get("shed-policy", "off"))?,
            degrade,
        })
    }
}

fn serve(args: &Args, artifacts: &std::path::Path) -> n3ic::Result<()> {
    let knobs = match ServeKnobs::parse(args) {
        Ok(k) => k,
        Err(e) => usage_err(&e),
    };
    // `--model NAME=PATH` (repeatable) selects the multi-model registry
    // backend; a bare `--model NAME` keeps the single-model path.
    let model_vals = args.get_all("model");
    let with_eq = model_vals.iter().filter(|v| v.contains('=')).count();
    if with_eq > 0 && with_eq < model_vals.len() {
        usage_err("mixing bare --model NAME with --model NAME=PATH is ambiguous");
    }
    if with_eq == 0 && model_vals.len() > 1 {
        usage_err("repeat --model only with NAME=PATH pairs (registry mode)");
    }
    let backend = args.get("backend", if with_eq > 0 { "registry" } else { "fpga" });
    if with_eq > 0 {
        if backend != "registry" {
            usage_err("--model NAME=PATH pairs serve through --backend registry");
        }
        let mut pairs: Vec<(String, String)> = Vec::new();
        for v in &model_vals {
            let Some((name, path)) = v.split_once('=') else {
                unreachable!("with_eq counted an '='");
            };
            if name.is_empty() || path.is_empty() {
                usage_err(&format!("malformed --model {v:?}: need NAME=PATH"));
            }
            if pairs.iter().any(|(n, _)| n == name) {
                usage_err(&format!("duplicate --model name {name:?}"));
            }
            pairs.push((name.to_string(), path.to_string()));
        }
        return serve_registry(&knobs, artifacts, &pairs, args.get("online-learn", ""));
    }
    if !args.get("online-learn", "").is_empty() {
        usage_err("--online-learn needs the registry backend (--model NAME=PATH pairs)");
    }
    if backend == "registry" {
        usage_err("--backend registry needs repeated --model NAME=PATH pairs");
    }
    if knobs.swap_every > 0 {
        usage_err("--swap-every needs the registry backend (--model NAME=PATH pairs)");
    }
    let model_name = args.get("model", "traffic");
    let m = load_model(artifacts, &model_name);
    let plane = if backend == "pjrt" {
        pjrt_plane(m, artifacts, knobs.shards)?
    } else {
        BackendFactory::single_sharded(&backend, m, knobs.shards)
            .map_err(|e| anyhow::anyhow!("{e}"))?
    };
    run_and_report(&knobs, plane, None, None)
}

/// Resolve one `--model NAME=PATH` pair: a readable model JSON wins;
/// otherwise fall back to the artifacts dir, then to seeded random
/// weights (keeps the demo runnable in a bare checkout).
fn load_registry_model(artifacts: &std::path::Path, name: &str, path: &str) -> BnnModel {
    if let Ok(mut m) = BnnModel::load(std::path::Path::new(path)) {
        m.name = name.to_string();
        return m;
    }
    let mut m = load_model(artifacts, path);
    m.name = name.to_string();
    m
}

/// Multi-model registry serving: every named model is published into a
/// shared registry, flows are hash-split across the slots, and
/// `--swap-every N` hot-republishes one slot every N packets while the
/// run keeps serving — the zero-downtime swap the registry exists for.
fn serve_registry(
    knobs: &ServeKnobs,
    artifacts: &std::path::Path,
    pairs: &[(String, String)],
    online_learn: String,
) -> n3ic::Result<()> {
    let registry = RegistryHandle::new();
    let mut names = Vec::new();
    let mut latency_ns = 0.0f64;
    for (name, path) in pairs {
        let m = load_registry_model(artifacts, name, path);
        // serve feeds flow-statistics features of a fixed width; a model
        // with any other input width would panic mid-serve on its first
        // routed flow — reject it up front with a usable message.
        let want_words = n3ic::bnn::words_for(n3ic::net::features::INPUT_BITS);
        if m.in_words() != want_words {
            anyhow::bail!(
                "--model {name}={path}: input width {} words does not match the \
                 flow-feature vector ({want_words} words / {} bits); all registry \
                 serve models must accept flow features",
                m.in_words(),
                n3ic::net::features::INPUT_BITS
            );
        }
        latency_ns = latency_ns.max(n3ic::fpga::FpgaTiming::new(&m).latency_ns());
        let tag = registry.publish(name, &m).map_err(|e| anyhow::anyhow!("{e}"))?;
        println!("published {tag}  ({})", m.describe());
        names.push(name.clone());
    }
    let plane = BackendFactory::registry(&registry, &names, latency_ns, knobs.shards)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    // The CLI demo has no ground-truth feed, so the learning loop runs
    // with an all-benign labeler: windowed accuracy measures how far the
    // served model strays from class 0 on live traffic, and a drifting
    // slot is refit toward it.  Real deployments plug a delayed-feedback
    // labeler into `ServeBuilder::online_learn` instead.
    let learn = if online_learn.is_empty() {
        None
    } else {
        if !names.iter().any(|n| *n == online_learn) {
            anyhow::bail!(
                "--online-learn {online_learn:?} is not among the served models ({})",
                names.join(", ")
            );
        }
        let mut spec = n3ic::learn::LearnSpec::new(
            &online_learn,
            std::sync::Arc::new(|_: &n3ic::net::packet::Packet| 0),
        );
        spec.window_pkts = (knobs.packets / 40).max(250);
        Some(spec)
    };
    let router = ModelRouter::hash_split(
        TriggerCondition::EveryNPackets(knobs.trigger_pkts),
        names,
    );
    run_and_report(knobs, plane, Some((router, registry)), learn)
}

/// Build the unified service from the parsed knobs, drive it with
/// seeded CBR traffic, and print the report — one path for every
/// backend, serial or pipelined, single- or multi-model.
fn run_and_report(
    knobs: &ServeKnobs,
    plane: Box<dyn InferencePlane>,
    routed: Option<(ModelRouter, RegistryHandle)>,
    learn: Option<n3ic::learn::LearnSpec>,
) -> n3ic::Result<()> {
    let caps = plane.capabilities();
    let mut builder = ServeBuilder::new()
        .backend(plane)
        .output(OutputSelector::Memory)
        .pipeline(knobs.pipeline)
        .queue_depth(knobs.queue_depth)
        .without_tag_log();
    let registry = match routed {
        Some((router, registry)) => {
            builder = builder.router(router);
            Some(registry)
        }
        None => {
            builder = builder.trigger(TriggerCondition::EveryNPackets(knobs.trigger_pkts));
            None
        }
    };
    if knobs.batch > 0 {
        // 1 ms packet-clock cap bounds queueing latency (Fig. 6's knee).
        builder = builder.batching(knobs.batch, 1e6);
    }
    if knobs.swap_every > 0 {
        builder = builder.swap_every(knobs.swap_every);
    }
    if let Some(policy) = knobs.shed {
        builder = builder.shed(policy);
    }
    if knobs.degrade {
        // CLI degradation is trigger-only (works on every backend); a
        // fallback-model ladder is API-level (`DegradeSpec::with_fallback`)
        // since it needs a shape-matched model per registry slot.
        builder = builder.degrade(DegradeSpec::trigger_only());
    }
    if let Some(spec) = learn {
        builder = builder.online_learn(spec);
    }
    let svc = builder
        .flow_capacity(knobs.table_cap)
        .evict(knobs.evict)
        .build()
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    // Seeded traffic (seed 7 in both modes: reruns are bit-identical).
    // `--churn 0` keeps the fixed `--flows`-sized population; a nonzero
    // fraction switches to the adversarial churn generator, whose
    // distinct-flow count grows without bound over the run.
    let cbr = CbrSpec { gbps: 40.0, pkt_size: 256 };
    let packets = knobs.packets;
    let events: Box<dyn Iterator<Item = PacketEvent>> = if knobs.churn > 0.0 {
        let spec = ChurnSpec {
            churn_frac: knobs.churn,
            ..ChurnSpec::adversarial(cbr, knobs.flows)
        };
        let mut gen = ChurnGen::new(spec, 7);
        Box::new((0..packets).map(move |_| PacketEvent {
            packet: gen.next_packet(),
            payload_words: None,
        }))
    } else {
        let mut gen = TrafficGen::new(cbr, knobs.flows, 7);
        Box::new((0..packets).map(move |_| PacketEvent {
            packet: gen.next_packet(),
            payload_words: None,
        }))
    };
    let t0 = std::time::Instant::now();
    let report: ServiceReport = svc.run(events).map_err(|e| anyhow::anyhow!("{e}"))?;
    let wall = t0.elapsed();

    let st = &report.stats;
    println!("== serve report ==");
    println!("backend          : {}", caps.backend);
    println!(
        "capabilities     : batch<={} shards={} routes={} hot-swap={} epoch-pinning={}",
        if caps.max_batch == usize::MAX { "inf".into() } else { caps.max_batch.to_string() },
        caps.shards,
        caps.routes,
        caps.supports_hot_swap,
        caps.supports_epoch_pinning
    );
    println!("packets          : {}", st.packets);
    println!("flows tracked    : {}", report.flows_tracked);
    // key=value form on one line so scripts can grep a single counter.
    let ft = &st.flow_table;
    println!(
        "flow table       : evictions={} aged_out={} collision_probes={} untracked={} \
         load={:.3}",
        ft.evictions,
        ft.aged_out,
        ft.collision_probes,
        ft.untracked,
        ft.load_factor()
    );
    println!("nn inferences    : {}", st.inferences);
    println!("class histogram  : {:?}", st.classes);
    if knobs.shed.is_some() || st.sheds > 0 {
        println!(
            "sheds            : {} (admitted {} of {} triggers)",
            st.sheds,
            st.triggers - st.sheds,
            st.triggers
        );
    }
    if st.restarts > 0 {
        println!("stage restarts   : {}", st.restarts);
    }
    for ev in &report.degradation {
        println!("degradation      : {ev}");
    }
    if let Some(health) = &report.health {
        for h in health {
            println!(
                "plane health     : {:8} calls={} failovers={} trips={} open={}",
                h.backend, h.calls, h.failovers, h.trips, h.open
            );
        }
    }
    if let Some(l) = &st.learn {
        println!(
            "online learn     : windows={} evaluated={} retrains={} promotions={} \
             rejections={} rollbacks={} last-window-acc={:.3}",
            l.windows,
            l.evaluated,
            l.retrains,
            l.promotions,
            l.rejections,
            l.rollbacks,
            l.last_window_accuracy
        );
        if let Some(p) = l.drift_fired_at {
            println!("drift            : fired at packet {p}");
        }
    }
    if let Some(registry) = registry {
        let versions = registry.versions();
        for (name, m) in &st.per_model {
            println!(
                "model {name:14}: v{} ({} swaps)  {} inferences  classes {:?}",
                versions.get(name).copied().unwrap_or(0),
                m.swaps,
                m.inferences,
                m.classes
            );
        }
    }
    println!("device p95 lat   : {:.2} us (modeled)", st.latency.p95_us());
    println!(
        "device lat tail  : p50={:.2} p99={:.2} p999={:.2} us (modeled)",
        st.latency.p50_us(),
        st.latency.p99_us(),
        st.latency.p999_us()
    );
    if knobs.pipeline > 0 {
        for (link, n) in STAGE_LINKS.iter().zip(&st.stage_blocked) {
            println!("backpressure     : {link:18} {n} blocked sends");
        }
    }
    if let Some(es) = report.engine {
        println!(
            "sharded engine   : {} batches, {:.2}M flows/s inside run_batch",
            es.batches,
            es.flows_per_sec() / 1e6
        );
    }
    println!(
        "host wall        : {:.2} s ({:.2} Mpkt/s through the service)",
        wall.as_secs_f64(),
        st.packets as f64 / wall.as_secs_f64() / 1e6
    );
    Ok(())
}

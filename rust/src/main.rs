//! `repro` — the N3IC launcher.
//!
//! Subcommands:
//! * `serve`        — run the coordinator service on generated traffic
//!   (the end-to-end request path; Python never runs here).
//! * `experiment`   — regenerate a paper table/figure (or `all`).
//! * `models`       — list trained models in the artifacts directory.
//! * `compile-p4`   — run NNtoP4 and print the generated P4₁₆ source.
//!
//! Flag parsing is hand-rolled (the build is offline; no clap).

use std::path::PathBuf;

use n3ic::bnn::{BnnModel, RegistryHandle};
use n3ic::config::Backend;
use n3ic::coordinator::{
    CoordinatorService, CoreExecutor, ModelRouter, MultiModelService, NnBatchExecutor,
    NnExecutor, OutputSelector, PacketEvent, PipelineConfig, PipelineService,
    RoutedPipelineService, TriggerCondition, STAGE_LINKS,
};
use n3ic::net::traffic::{CbrSpec, TrafficGen};

const USAGE: &str = "\
repro — N3IC: NN inference in the NIC data plane

USAGE:
  repro [--artifacts DIR] <command> [options]

COMMANDS:
  serve        --model NAME --backend nfp|pisa|fpga|host|pjrt
               --packets N --flows N --trigger-pkts N
               --batch N (0 = classify inline; N>0 = batch fast path)
               --shards N (with --batch: spread batches over N cores)
               --pipeline N (N>=1: staged runtime with N parse workers;
                             verdicts are bit-identical to the serial
                             loop on the same seeded traffic)
               --queue-depth N (with --pipeline: bounded stage queues)

               Multi-model registry mode (repeat --model with NAME=PATH
               pairs to serve several named, versioned models at once;
               flows are split across them by canonical flow hash):
               --model anomaly=m1.json --model traffic-class=m2.json
               --swap-every N (hot-republish one model every N packets
                               — zero-downtime weight swap demo: the
                               run never pauses, verdict tags move to
                               the new version, per-model swap counts
                               land in the report)
               In-process control plane: hold a clone of the service's
               RegistryHandle and call publish(name, &model) from any
               thread; readers observe the new version on their next
               batch, never a torn one.
  experiment   <fig03|...|tab02|abl-crossover|abl-cam|all>
  models
  compile-p4   --model NAME [--format p4|bmv2]
";

/// Tiny flag parser: --key value pairs after the subcommand.  Flags are
/// repeatable; scalar getters take the last occurrence, `get_all` sees
/// every one (the registry mode's repeated `--model NAME=PATH`).
struct Args {
    flags: std::collections::HashMap<String, Vec<String>>,
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut flags: std::collections::HashMap<String, Vec<String>> =
            std::collections::HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                if i + 1 < argv.len() {
                    flags.entry(key.to_string()).or_default().push(argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.entry(key.to_string()).or_default().push("true".into());
                    i += 1;
                }
            } else {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
        Self { flags, positional }
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .and_then(|v| v.last())
            .cloned()
            .unwrap_or_else(|| default.into())
    }

    fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .and_then(|v| v.last())
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn get_all(&self, key: &str) -> Vec<String> {
        self.flags.get(key).cloned().unwrap_or_default()
    }
}

fn load_model(artifacts: &std::path::Path, name: &str) -> BnnModel {
    BnnModel::load_named(artifacts, name).unwrap_or_else(|e| {
        eprintln!("warning: {e}; using random weights for shape {name}");
        match name {
            "tomography_128" => BnnModel::random(name, 152, &[128, 64, 2], 1),
            "tomography_64" => BnnModel::random(name, 152, &[64, 32, 2], 1),
            "tomography_32" => BnnModel::random(name, 152, &[32, 16, 2], 1),
            _ => BnnModel::random(name, 256, &[32, 16, 2], 1),
        }
    })
}

fn main() -> n3ic::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let artifacts = PathBuf::from(args.get("artifacts", "artifacts"));
    let cmd = args.positional.first().map(String::as_str).unwrap_or("");
    match cmd {
        "serve" => serve(&args, &artifacts),
        "experiment" => {
            let id = args
                .positional
                .get(1)
                .map(String::as_str)
                .unwrap_or("all");
            if id == "all" {
                for e in n3ic::experiments::ALL {
                    println!("{}", n3ic::experiments::run(e, &artifacts)?);
                }
            } else {
                println!("{}", n3ic::experiments::run(id, &artifacts)?);
            }
            Ok(())
        }
        "models" => {
            let dir = artifacts.join("models");
            let mut found = false;
            if let Ok(rd) = std::fs::read_dir(&dir) {
                let mut entries: Vec<_> = rd.flatten().map(|e| e.path()).collect();
                entries.sort();
                for p in entries {
                    let name = p
                        .file_name()
                        .unwrap_or_default()
                        .to_string_lossy()
                        .to_string();
                    if name.ends_with(".json") && !name.ends_with(".golden.json") {
                        if let Ok(m) = BnnModel::load(&p) {
                            found = true;
                            println!(
                                "{:18} {:16} {:5}B  bin_acc={:.3} mlp_acc={:.3}",
                                m.name,
                                m.describe(),
                                m.memory_bytes(),
                                m.metrics.bnn_test_acc,
                                m.metrics.float_test_acc
                            );
                        }
                    }
                }
            }
            if !found {
                println!("no models in {} — run `make artifacts`", dir.display());
            }
            Ok(())
        }
        "compile-p4" => {
            let m = load_model(&artifacts, &args.get("model", "traffic"));
            let prog =
                n3ic::pisa::compile_bnn(&m).map_err(|e| anyhow::anyhow!("{e}"))?;
            match args.get("format", "p4").as_str() {
                "bmv2" => println!("{}", n3ic::pisa::bmv2::to_bmv2_json(&m, &prog).dump()),
                _ => println!("{}", n3ic::pisa::p4gen::to_p4(&m, &prog)),
            }
            Ok(())
        }
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

/// Verify the AOT artifact end to end, then serve through the bit-exact
/// core with the runtime's measured latency.
#[cfg(feature = "pjrt")]
fn pjrt_executor(m: BnnModel, artifacts: &std::path::Path) -> n3ic::Result<CoreExecutor> {
    let mut rt = n3ic::runtime::PjrtRuntime::new(artifacts)?;
    let key = n3ic::runtime::Manifest::key_for(&m, 1);
    let x = vec![0u32; m.in_words()];
    let t0 = std::time::Instant::now();
    let _ = rt.infer_batch(&key, &m, std::slice::from_ref(&x))?;
    let lat = t0.elapsed().as_nanos() as f64;
    println!("pjrt backend verified on {}", rt.platform());
    Ok(CoreExecutor::new(m, lat, "pjrt"))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_executor(_m: BnnModel, _artifacts: &std::path::Path) -> n3ic::Result<CoreExecutor> {
    anyhow::bail!(
        "the pjrt backend is compiled out: add a vendored `xla` path \
         dependency to rust/Cargo.toml (see the [features] comment there), \
         then build with `--features pjrt`"
    )
}

fn serve(args: &Args, artifacts: &std::path::Path) -> n3ic::Result<()> {
    // `--model NAME=PATH` (repeatable) selects the multi-model registry
    // mode; a bare `--model NAME` keeps the single-model path.
    let registry_pairs: Vec<(String, String)> = args
        .get_all("model")
        .iter()
        .filter_map(|v| v.split_once('=').map(|(n, p)| (n.to_string(), p.to_string())))
        .collect();
    if !registry_pairs.is_empty() {
        return serve_registry(args, artifacts, &registry_pairs);
    }
    let model_name = args.get("model", "traffic");
    let backend: Backend = args.get("backend", "fpga").parse()?;
    let packets = args.get_u64("packets", 1_000_000);
    let flows = args.get_u64("flows", 100_000);
    let trigger_pkts = args.get_u64("trigger-pkts", 10) as u32;

    let m = load_model(artifacts, &model_name);
    let shards = args.get_u64("shards", 1) as usize;
    let exec = match backend {
        Backend::Fpga => CoreExecutor::fpga(m),
        Backend::Nfp => CoreExecutor::nfp(m),
        Backend::Host => CoreExecutor::host(m),
        Backend::Pisa => {
            CoreExecutor::pisa(m).map_err(|e| anyhow::anyhow!("{e}"))?
        }
        Backend::Pjrt => pjrt_executor(m, artifacts)?,
    }
    .sharded(shards);
    let batch = args.get_u64("batch", 0) as usize;
    let trigger = TriggerCondition::EveryNPackets(trigger_pkts);
    let backend_name = exec.name();
    let mut gen = TrafficGen::new(
        CbrSpec {
            gbps: 40.0,
            pkt_size: 256,
        },
        flows,
        7,
    );
    let pipeline = args.get_u64("pipeline", 0) as usize;
    let t0 = std::time::Instant::now();
    let (st, flows_tracked, blocked, engine) = if pipeline > 0 {
        // Staged runtime: the ingress sharder runs on this thread; the
        // determinism contract guarantees the verdict histogram below
        // matches the serial branch bit for bit on this same traffic.
        let cfg = PipelineConfig {
            workers: pipeline,
            queue_depth: args.get_u64("queue-depth", 1024) as usize,
            batch,
            max_wait_ns: 1e6,
            ..Default::default()
        };
        let svc = PipelineService::new(exec, trigger, OutputSelector::Memory, cfg);
        let events = (0..packets).map(|_| PacketEvent {
            packet: gen.next_packet(),
            payload_words: None,
        });
        let report = svc.run(events).map_err(|e| anyhow::anyhow!("{e}"))?;
        let blocked = Some(report.stats.stage_blocked.clone());
        (report.stats, report.flows_tracked, blocked, report.engine)
    } else {
        let mut svc = CoordinatorService::new(exec, trigger, OutputSelector::Memory);
        if batch > 0 {
            // 1 ms packet-clock cap bounds queueing latency (Fig. 6's
            // knee).
            svc = svc.with_batching(batch, 1e6);
        }
        for _ in 0..packets {
            let p = gen.next_packet();
            svc.handle(&PacketEvent {
                packet: p,
                payload_words: None,
            });
        }
        svc.flush();
        let flows_tracked = svc.flows.len();
        let engine = svc.exec.engine_stats();
        (svc.stats, flows_tracked, None, engine)
    };
    let wall = t0.elapsed();
    println!("== serve report ==");
    println!("backend          : {backend_name}");
    println!("packets          : {}", st.packets);
    println!("flows tracked    : {flows_tracked}");
    println!("nn inferences    : {}", st.inferences);
    println!("class histogram  : {:?}", st.classes);
    println!("device p95 lat   : {:.2} us (modeled)", st.latency.p95_us());
    if let Some(blocked) = blocked {
        for (link, n) in STAGE_LINKS.iter().zip(&blocked) {
            println!("backpressure     : {link:18} {n} blocked sends");
        }
    }
    if let Some(es) = engine {
        println!(
            "sharded engine   : {} batches, {:.2}M flows/s inside run_batch",
            es.batches,
            es.flows_per_sec() / 1e6
        );
    }
    println!(
        "host wall        : {:.2} s ({:.2} Mpkt/s through the pipeline)",
        wall.as_secs_f64(),
        st.packets as f64 / wall.as_secs_f64() / 1e6
    );
    Ok(())
}

/// Resolve one `--model NAME=PATH` pair: a readable model JSON wins;
/// otherwise fall back to the artifacts dir, then to seeded random
/// weights (keeps the demo runnable in a bare checkout).
fn load_registry_model(artifacts: &std::path::Path, name: &str, path: &str) -> BnnModel {
    if let Ok(mut m) = BnnModel::load(std::path::Path::new(path)) {
        m.name = name.to_string();
        return m;
    }
    let mut m = load_model(artifacts, path);
    m.name = name.to_string();
    m
}

/// Multi-model registry serving: every named model is published into a
/// shared registry, flows are hash-split across the slots, and
/// `--swap-every N` hot-republishes one slot every N packets while the
/// run keeps serving — the zero-downtime swap the registry exists for.
fn serve_registry(
    args: &Args,
    artifacts: &std::path::Path,
    pairs: &[(String, String)],
) -> n3ic::Result<()> {
    let packets = args.get_u64("packets", 1_000_000);
    let flows = args.get_u64("flows", 100_000);
    let trigger_pkts = args.get_u64("trigger-pkts", 10) as u32;
    let batch = args.get_u64("batch", 0) as usize;
    let shards = args.get_u64("shards", 1) as usize;
    let pipeline = args.get_u64("pipeline", 0) as usize;
    let swap_every = args.get_u64("swap-every", 0);

    let registry = RegistryHandle::new();
    let mut names = Vec::new();
    let mut models = Vec::new();
    let mut latency_ns = 0.0f64;
    for (name, path) in pairs {
        let m = load_registry_model(artifacts, name, path);
        // serve feeds flow-statistics features of a fixed width; a model
        // with any other input width would panic mid-serve on its first
        // routed flow — reject it up front with a usable message.
        let want_words = n3ic::bnn::words_for(n3ic::net::features::INPUT_BITS);
        if m.in_words() != want_words {
            anyhow::bail!(
                "--model {name}={path}: input width {} words does not match the \
                 flow-feature vector ({want_words} words / {} bits); all registry \
                 serve models must accept flow features",
                m.in_words(),
                n3ic::net::features::INPUT_BITS
            );
        }
        latency_ns = latency_ns.max(n3ic::fpga::FpgaTiming::new(&m).latency_ns());
        let tag = registry.publish(name, &m).map_err(|e| anyhow::anyhow!("{e}"))?;
        println!("published {tag}  ({})", m.describe());
        names.push(name.clone());
        models.push(m);
    }
    let router = ModelRouter::hash_split(
        TriggerCondition::EveryNPackets(trigger_pkts),
        names.clone(),
    );
    let mut gen = TrafficGen::new(CbrSpec { gbps: 40.0, pkt_size: 256 }, flows, 7);
    let t0 = std::time::Instant::now();
    let (st, blocked, engine) = if pipeline > 0 {
        let cfg = PipelineConfig {
            workers: pipeline,
            queue_depth: args.get_u64("queue-depth", 1024) as usize,
            batch,
            max_wait_ns: 1e6,
            ..Default::default()
        };
        let svc = RoutedPipelineService::new(
            registry.clone(),
            router,
            OutputSelector::Memory,
            cfg,
            latency_ns,
        )
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .with_shards(shards)
        .without_tag_log();
        // The ingress sharder evaluates this iterator on the calling
        // thread while the downstream stages run, so publishing from
        // inside it is a true live hot-swap — and it lands exactly
        // every `swap_every` packets, as documented (same weights, new
        // version: the swap machinery is exercised without changing
        // verdict semantics).
        let mut swap_cursor = 0usize;
        let events = (0..packets).map(|i| {
            if swap_every > 0 && i > 0 && i % swap_every == 0 {
                let k = swap_cursor % models.len();
                swap_cursor += 1;
                registry
                    .publish(&names[k], &models[k])
                    .expect("republish of unchanged shape cannot fail");
            }
            PacketEvent { packet: gen.next_packet(), payload_words: None }
        });
        let report = svc.run(events).map_err(|e| anyhow::anyhow!("{e}"))?;
        let blocked = Some(report.stats.stage_blocked.clone());
        (report.stats, blocked, report.engine)
    } else {
        let mut svc =
            MultiModelService::new(registry.clone(), router, OutputSelector::Memory, latency_ns)
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .with_shards(shards)
                .without_tag_log();
        if batch > 0 {
            svc = svc.with_batching(batch, 1e6);
        }
        let mut swap_cursor = 0usize;
        for i in 0..packets {
            if swap_every > 0 && i > 0 && i % swap_every == 0 {
                let k = swap_cursor % models.len();
                swap_cursor += 1;
                registry
                    .publish(&names[k], &models[k])
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
            }
            svc.handle(&PacketEvent { packet: gen.next_packet(), payload_words: None });
        }
        svc.flush();
        let engine = svc.exec.engine_stats();
        (svc.stats, None, engine)
    };
    let wall = t0.elapsed();
    println!("== serve report (multi-model registry) ==");
    println!("packets          : {}", st.packets);
    println!("nn inferences    : {}", st.inferences);
    println!("class histogram  : {:?}", st.classes);
    let versions = registry.versions();
    for (name, m) in &st.per_model {
        println!(
            "model {name:14}: v{} ({} swaps)  {} inferences  classes {:?}",
            versions.get(name).copied().unwrap_or(0),
            m.swaps,
            m.inferences,
            m.classes
        );
    }
    println!("device p95 lat   : {:.2} us (modeled)", st.latency.p95_us());
    if let Some(blocked) = blocked {
        for (link, n) in STAGE_LINKS.iter().zip(&blocked) {
            println!("backpressure     : {link:18} {n} blocked sends");
        }
    }
    if let Some(es) = engine {
        println!(
            "sharded engine   : {} batches, {:.2}M flows/s inside run_batch",
            es.batches,
            es.flows_per_sec() / 1e6
        );
    }
    println!(
        "host wall        : {:.2} s ({:.2} Mpkt/s through the registry route)",
        wall.as_secs_f64(),
        st.packets as f64 / wall.as_secs_f64() / 1e6
    );
    Ok(())
}

//! Feature extraction: flow statistics → the BNN's packed 256-bit input.
//!
//! App. C: "16 most important features ... each selected feature's numeric
//! value falls in the range [0, 65k], we represented them using 16b for
//! each, and provide each bit as separated input to the MLP."  The bit
//! layout (MSB-first per feature, feature-major) matches
//! `python/train/binarize.featurize` exactly — asserted by an integration
//! test against exported vectors.

use super::flow::FlowStats;
use crate::bnn::{words_for, BLOCK_SIZE};

pub const N_FEATURES: usize = 16;
pub const FEATURE_BITS: usize = 16;
pub const INPUT_BITS: usize = N_FEATURES * FEATURE_BITS; // 256

/// The quantized 16×16b feature vector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FeatureVector(pub [u16; N_FEATURES]);

impl FeatureVector {
    /// Compute the App.-C-style features from flow statistics.  Scales are
    /// fixed so values use the full 16-bit range on realistic traffic —
    /// the same scaling the Python dataset generator uses.
    pub fn from_stats(s: &FlowStats) -> Self {
        let sat = |v: f64| v.clamp(0.0, 65535.0) as u16;
        let mean = s.mean_size() as f64;
        let var = if s.pkts > 0 {
            (s.size_sq_sum as f64 / s.pkts as f64 - mean * mean).max(0.0)
        } else {
            0.0
        };
        let dur_ms = s.duration_ns() / 1e6;
        let up_ratio = if s.pkts > 0 {
            s.pkts_fwd as f64 / s.pkts as f64
        } else {
            0.0
        };
        let up_bytes_ratio = if s.bytes > 0 {
            s.bytes_fwd as f64 / s.bytes as f64
        } else {
            0.0
        };
        FeatureVector([
            sat(mean * 40.0),                      // 0 mean pkt size
            sat(s.min_size as f64 * 40.0),         // 1 min pkt size
            sat(s.max_size as f64 * 40.0),         // 2 max pkt size
            sat(var.sqrt() * 40.0),                // 3 size std
            sat(dur_ms * 100.0),                   // 4 duration
            sat(s.pkts as f64 * 20.0),             // 5 total pkts
            sat(s.bytes as f64 / 16.0),            // 6 total bytes
            sat(s.mean_iat_ns() / 250.0),          // 7 mean IAT
            sat(s.iat_max_ns / 4000.0),            // 8 max IAT
            sat(up_ratio * 65535.0),               // 9 up/down pkt ratio
            sat(up_bytes_ratio * 65535.0),         // 10 up/down byte ratio
            s.src_port,                            // 11 src port
            s.dst_port,                            // 12 dst port
            sat(s.tcp_flag_counts as f64 * 8192.0 / s.pkts.max(1) as f64), // 13
            sat((s.tcp_flag_or as f64) * 256.0),   // 14 flag union
            sat(if dur_ms > 0.0 {                  // 15 burstiness proxy
                s.pkts as f64 / dur_ms * 100.0
            } else {
                0.0
            }),
        ])
    }

    /// Bit-expand (MSB-first per feature) and pack into uint32 words —
    /// identical to `featurize` + `pack_bits` on the Python side.
    pub fn pack(&self) -> [u32; words_for(INPUT_BITS)] {
        let mut out = [0u32; words_for(INPUT_BITS)];
        let mut bit_idx = 0usize;
        for &feat in &self.0 {
            for b in (0..FEATURE_BITS).rev() {
                if (feat >> b) & 1 == 1 {
                    out[bit_idx / BLOCK_SIZE] |= 1 << (bit_idx % BLOCK_SIZE);
                }
                bit_idx += 1;
            }
        }
        out
    }
}

/// Pack arbitrary quantized features with `feature_bits` each, padding to
/// `in_words` words (the tomography path: 19 × 8-bit delays → 5 words).
pub fn pack_features(values: &[u16], feature_bits: usize, in_words: usize) -> Vec<u32> {
    let mut out = vec![0u32; in_words];
    let mut bit_idx = 0usize;
    for &v in values {
        for b in (0..feature_bits).rev() {
            if (v >> b) & 1 == 1 {
                out[bit_idx / BLOCK_SIZE] |= 1 << (bit_idx % BLOCK_SIZE);
            }
            bit_idx += 1;
        }
    }
    assert!(bit_idx <= in_words * BLOCK_SIZE, "features overflow input");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_layout_msb_first() {
        // Feature 0 = 0x8000 → logical bit 0 set → word 0 bit 0.
        let mut f = FeatureVector([0; 16]);
        f.0[0] = 0x8000;
        let p = f.pack();
        assert_eq!(p[0] & 1, 1);
        assert_eq!(p.iter().map(|w| w.count_ones()).sum::<u32>(), 1);
        // Feature 0 = 1 → logical bit 15 → word 0 bit 15.
        f.0[0] = 1;
        let p = f.pack();
        assert_eq!((p[0] >> 15) & 1, 1);
        // Feature 2 = 0x8000 → logical bit 32 → word 1 bit 0.
        f.0[0] = 0;
        f.0[2] = 0x8000;
        let p = f.pack();
        assert_eq!(p[1] & 1, 1);
    }

    #[test]
    fn pack_features_generic_matches_struct() {
        let f = FeatureVector([
            1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 0xFFFF,
        ]);
        let a = f.pack().to_vec();
        let b = pack_features(&f.0, 16, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn tomography_packing_19x8() {
        let delays: Vec<u16> = (0..19).map(|i| (i * 13 % 256) as u16).collect();
        let p = pack_features(&delays, 8, 5);
        assert_eq!(p.len(), 5);
        // 152 bits used; top 8 bits of word 4 must stay zero.
        assert_eq!(p[4] >> 24, 0);
    }

    fn tcp_packet(ts_ns: f64, size: u16) -> crate::net::packet::Packet {
        crate::net::packet::Packet {
            ts_ns,
            src_ip: 1,
            dst_ip: 2,
            src_port: 9,
            dst_port: 10,
            proto: crate::net::packet::Proto::Tcp,
            size,
            tcp_flags: 0x10,
        }
    }

    #[test]
    fn zero_packet_flow_features_are_all_zero() {
        // A never-updated FlowStats exercises every division guard at
        // once: pkts == 0 (mean, variance, up-ratio, flag rate), bytes
        // == 0 (byte ratio), duration == 0 (burstiness).
        let f = FeatureVector::from_stats(&FlowStats::default());
        assert_eq!(f.0, [0u16; N_FEATURES]);
        // And the packed form is the zero vector, not garbage.
        assert!(f.pack().iter().all(|&w| w == 0));
    }

    #[test]
    fn zero_byte_flow_guards_byte_ratio() {
        // Zero-length packets: pkts > 0 but bytes == 0, so the up/down
        // byte ratio hits its bytes-denominator guard while the packet
        // ratio still divides normally.
        let mut s = FlowStats::default();
        s.update(&tcp_packet(0.0, 0), true);
        s.update(&tcp_packet(1000.0, 0), true);
        assert_eq!(s.bytes, 0);
        let f = FeatureVector::from_stats(&s);
        assert_eq!(f.0[10], 0); // byte ratio guarded to 0, not NaN-cast
        assert_eq!(f.0[9], 65535); // pkt ratio: 2/2 forward
        assert_eq!(f.0[0], 0); // mean size of empty packets
        assert_eq!(f.0[6], 0); // total bytes
    }

    #[test]
    fn single_packet_flow_has_no_time_derived_features() {
        // One packet: duration 0 (burstiness guard), no IATs, variance
        // exactly mean² − mean² = 0.
        let mut s = FlowStats::default();
        s.update(&tcp_packet(5_000.0, 100), true);
        let f = FeatureVector::from_stats(&s);
        assert_eq!(f.0[3], 0); // size std of a single sample
        assert_eq!(f.0[4], 0); // duration
        assert_eq!(f.0[7], 0); // mean IAT
        assert_eq!(f.0[8], 0); // max IAT
        assert_eq!(f.0[15], 0); // burstiness: dur_ms == 0 branch
        assert_eq!(f.0[0], 4000); // mean size 100 × 40 still computed
        assert_eq!(f.0[9], 65535); // 1/1 forward packets
    }

    #[test]
    fn saturation_clamps_at_u16_max() {
        // Drive the byte counter far past 65535×16 and duration past the
        // scale: every clamped feature must read exactly 65535 — the
        // cast must never wrap.
        let mut s = FlowStats::default();
        for i in 0..5_000u32 {
            // 1 ms apart → 5 s duration → dur_ms × 100 ≫ 65535.
            s.update(&tcp_packet(i as f64 * 1e6, 1500), true);
        }
        let f = FeatureVector::from_stats(&s);
        assert_eq!(f.0[4], 65535); // duration clamp
        assert_eq!(f.0[5], 65535); // pkts × 20 clamp
        assert_eq!(f.0[6], 65535); // bytes / 16 clamp
        assert_eq!(f.0[9], 65535); // ratio upper bound is exact, no wrap
    }

    #[test]
    fn features_saturate() {
        let mut s = FlowStats::default();
        let p = crate::net::packet::Packet {
            ts_ns: 0.0,
            src_ip: 1,
            dst_ip: 2,
            src_port: 1,
            dst_port: 2,
            proto: crate::net::packet::Proto::Tcp,
            size: 1500,
            tcp_flags: 0xFF,
        };
        for i in 0..10_000 {
            let mut q = p;
            q.ts_ns = i as f64;
            s.update(&q, true);
        }
        let f = FeatureVector::from_stats(&s);
        assert_eq!(f.0[2], 60000); // max pkt size 1500 × scale 40
        assert_eq!(f.0[5], 65535); // 10k packets × 20 saturates
        assert_eq!(f.0[9], 65535); // all-forward ratio
    }
}

//! Minimal packet model + header parser.
//!
//! The NIC models parse Ethernet/IPv4/{TCP,UDP} — the work the paper's
//! "regular packet processing tasks" (parsing, counter update, lookup)
//! account for.  Packets carry a timestamp so device models can compute
//! queueing/latency without a wall clock.

/// L4 protocol of a parsed packet.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Proto {
    Tcp,
    Udp,
    Other(u8),
}

impl Proto {
    pub fn number(self) -> u8 {
        match self {
            Proto::Tcp => 6,
            Proto::Udp => 17,
            Proto::Other(n) => n,
        }
    }

    pub fn from_number(n: u8) -> Self {
        match n {
            6 => Proto::Tcp,
            17 => Proto::Udp,
            other => Proto::Other(other),
        }
    }
}

/// A network packet as the data plane sees it (headers + sizes + time).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Packet {
    pub ts_ns: f64,
    pub src_ip: u32,
    pub dst_ip: u32,
    pub src_port: u16,
    pub dst_port: u16,
    pub proto: Proto,
    /// Wire size in bytes (Ethernet frame).
    pub size: u16,
    /// TCP flags byte (0 for UDP).
    pub tcp_flags: u8,
}

impl Packet {
    /// Serialize the headers into a 54-byte Ethernet+IPv4+TCP frame prefix
    /// (payload elided).  Used to exercise the real parse path.
    pub fn to_wire(&self) -> [u8; 54] {
        let mut b = [0u8; 54];
        // Ethernet: dst/src MAC zeroed, ethertype IPv4.
        b[12] = 0x08;
        b[13] = 0x00;
        // IPv4 header at offset 14.
        b[14] = 0x45; // version + IHL
        let total_len = self.size.max(54) - 14;
        b[16..18].copy_from_slice(&total_len.to_be_bytes());
        b[22] = 64; // TTL
        b[23] = self.proto.number();
        b[26..30].copy_from_slice(&self.src_ip.to_be_bytes());
        b[30..34].copy_from_slice(&self.dst_ip.to_be_bytes());
        // L4 at offset 34.
        b[34..36].copy_from_slice(&self.src_port.to_be_bytes());
        b[36..38].copy_from_slice(&self.dst_port.to_be_bytes());
        if self.proto == Proto::Tcp {
            b[46] = 0x50; // data offset
            b[47] = self.tcp_flags;
        }
        b
    }
}

/// Parsed header view (what the MicroC/P4 parser stages produce).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParsedHeaders {
    pub src_ip: u32,
    pub dst_ip: u32,
    pub src_port: u16,
    pub dst_port: u16,
    pub proto: Proto,
    pub tcp_flags: u8,
}

/// Parse a wire-format frame prefix.  Returns `None` for non-IPv4 frames
/// or truncated buffers (the NIC forwards those without NN processing).
pub fn parse(frame: &[u8]) -> Option<ParsedHeaders> {
    if frame.len() < 38 {
        return None;
    }
    if frame[12] != 0x08 || frame[13] != 0x00 {
        return None; // not IPv4
    }
    if frame[14] >> 4 != 4 {
        return None;
    }
    let ihl = (frame[14] & 0xF) as usize * 4;
    if ihl < 20 || frame.len() < 14 + ihl + 4 {
        return None;
    }
    let proto = Proto::from_number(frame[23]);
    let l4 = 14 + ihl;
    let src_port = u16::from_be_bytes([frame[l4], frame[l4 + 1]]);
    let dst_port = u16::from_be_bytes([frame[l4 + 2], frame[l4 + 3]]);
    let tcp_flags = if proto == Proto::Tcp && frame.len() > l4 + 13 {
        frame[l4 + 13]
    } else {
        0
    };
    Some(ParsedHeaders {
        src_ip: u32::from_be_bytes([frame[26], frame[27], frame[28], frame[29]]),
        dst_ip: u32::from_be_bytes([frame[30], frame[31], frame[32], frame[33]]),
        src_port,
        dst_port,
        proto,
        tcp_flags,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pkt() -> Packet {
        Packet {
            ts_ns: 0.0,
            src_ip: 0x0A00_0001,
            dst_ip: 0x0A00_0002,
            src_port: 4242,
            dst_port: 443,
            proto: Proto::Tcp,
            size: 256,
            tcp_flags: 0x18,
        }
    }

    #[test]
    fn wire_roundtrip() {
        let p = pkt();
        let h = parse(&p.to_wire()).expect("parse");
        assert_eq!(h.src_ip, p.src_ip);
        assert_eq!(h.dst_ip, p.dst_ip);
        assert_eq!(h.src_port, p.src_port);
        assert_eq!(h.dst_port, p.dst_port);
        assert_eq!(h.proto, Proto::Tcp);
        assert_eq!(h.tcp_flags, 0x18);
    }

    #[test]
    fn udp_roundtrip() {
        let mut p = pkt();
        p.proto = Proto::Udp;
        p.tcp_flags = 0;
        let h = parse(&p.to_wire()).unwrap();
        assert_eq!(h.proto, Proto::Udp);
        assert_eq!(h.tcp_flags, 0);
    }

    #[test]
    fn garbage_rejected() {
        assert!(parse(&[0u8; 10]).is_none());
        let mut w = pkt().to_wire();
        w[13] = 0x06; // not IPv4 ethertype
        assert!(parse(&w).is_none());
        let mut w2 = pkt().to_wire();
        w2[14] = 0x65; // IPv6 version nibble
        assert!(parse(&w2).is_none());
    }

    #[test]
    fn proto_number_roundtrip() {
        for n in [6u8, 17, 1, 47] {
            assert_eq!(Proto::from_number(n).number(), n);
        }
    }
}

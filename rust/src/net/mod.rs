//! Packet / flow substrate: the plumbing every NIC model shares.
//!
//! * [`packet`] — minimal Ethernet/IPv4/TCP-UDP header model + parser.
//! * [`flow`] — 5-tuple keys, per-flow statistics, the hash flow table
//!   the NIC keeps in SRAM.
//! * [`features`] — the 16 × 16-bit feature vector (App. C) extracted
//!   from flow statistics and packed into the BNN's 256-bit input.
//! * [`traffic`] — workload generators standing in for the paper's DPDK
//!   pktgen: constant-bit-rate streams and flow-arrival processes.

pub mod features;
pub mod flow;
pub mod packet;
pub mod traffic;

pub use features::FeatureVector;
pub use flow::{
    EvictPolicy, FlowKey, FlowStats, FlowTable, FlowTableStats, FlowUpdate, ShardedFlowTable,
    FLOW_SHARDS,
};
pub use packet::{Packet, ParsedHeaders, Proto};
pub use traffic::{CbrSpec, ChurnGen, ChurnSpec, FlowArrivals, TrafficGen};

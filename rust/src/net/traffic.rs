//! Traffic generators — the in-process stand-in for the paper's 40Gb/s
//! DPDK pktgen (DESIGN.md substitution S7).
//!
//! Two processes are provided:
//! * [`CbrSpec`] — constant-bit-rate packet stream at a given rate and
//!   packet size (the §6 testbed loads, e.g. 40Gb/s@256B = 18.1 Mpps).
//! * [`FlowArrivals`] — Poisson flow arrivals with per-flow packet trains
//!   (the "1.8M flows/s, ~10 packets per flow" analysis workload).

use super::packet::{Packet, Proto};

/// Deterministic xorshift64* PRNG (no external dependency, reproducible).
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.0 ^= self.0 >> 12;
        self.0 ^= self.0 << 25;
        self.0 ^= self.0 >> 27;
        self.0.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Exponential variate with the given mean.
    #[inline]
    pub fn exp(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.next_f64()).ln()
    }

    /// Uniform draw in `[0, n)` via Lemire's 128-bit multiply-shift.
    /// The previous `next_u64() % n` overweighted the low residues
    /// whenever `n` did not divide 2^64; the multiply maps the full
    /// 64-bit stream onto `[0, n)` with bias below `n / 2^64` — of no
    /// statistical consequence for any `n` this crate draws — without
    /// the data-dependent retry loop of rejection sampling.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Hard assert: the old `% n` panicked on 0 in every build; a
        // silent always-0 stream would hide a degenerate config.
        assert!(n > 0, "Rng::below(0) is meaningless");
        (((self.next_u64() as u128) * (n as u128)) >> 64) as u64
    }
}

/// Constant-bit-rate stream specification.
#[derive(Debug, Clone, Copy)]
pub struct CbrSpec {
    pub gbps: f64,
    pub pkt_size: u16,
}

impl CbrSpec {
    /// Packets per second for this rate/size (wire bytes only; preamble
    /// and IFG ignored, as in the paper's Mpps arithmetic: 40Gb/s@256B ≈
    /// 18.1Mpps, 40Gb/s@1500B ≈ 3.3Mpps).
    pub fn pps(&self) -> f64 {
        self.gbps * 1e9 / (self.pkt_size as f64 * 8.0 + 160.0)
    }

    /// Inter-packet gap in ns.
    pub fn gap_ns(&self) -> f64 {
        1e9 / self.pps()
    }
}

/// Iterator-style generator of packets from a set of concurrent flows.
pub struct TrafficGen {
    rng: Rng,
    spec: CbrSpec,
    n_flows: u64,
    t_ns: f64,
}

impl TrafficGen {
    pub fn new(spec: CbrSpec, n_flows: u64, seed: u64) -> Self {
        Self {
            rng: Rng::new(seed),
            spec,
            n_flows: n_flows.max(1),
            t_ns: 0.0,
        }
    }

    /// Next packet (round-robin-ish over flows, CBR pacing).
    pub fn next_packet(&mut self) -> Packet {
        let flow = self.rng.below(self.n_flows);
        self.t_ns += self.spec.gap_ns();
        let tcp = flow % 4 != 0;
        Packet {
            ts_ns: self.t_ns,
            src_ip: 0x0A00_0000 | (flow as u32 & 0xFFFF),
            dst_ip: 0x0B00_0000 | ((flow >> 16) as u32 & 0xFF),
            src_port: 1024 + (flow % 50000) as u16,
            dst_port: if tcp { 443 } else { 53 },
            proto: if tcp { Proto::Tcp } else { Proto::Udp },
            size: self.spec.pkt_size,
            tcp_flags: if tcp { 0x10 } else { 0 },
        }
    }
}

/// Poisson flow arrivals; each flow emits a geometric packet train.
pub struct FlowArrivals {
    rng: Rng,
    /// Mean new flows per second.
    pub flow_rate: f64,
    /// Mean packets per flow (paper: ~10 at 40Gb/s@256B → 1.8M flows/s).
    pub pkts_per_flow: f64,
    t_ns: f64,
    next_id: u64,
}

/// One flow arrival event: id + start time + packet count.
#[derive(Debug, Clone, Copy)]
pub struct FlowEvent {
    pub id: u64,
    pub ts_ns: f64,
    pub pkts: u32,
}

impl FlowArrivals {
    pub fn new(flow_rate: f64, pkts_per_flow: f64, seed: u64) -> Self {
        Self {
            rng: Rng::new(seed),
            flow_rate,
            pkts_per_flow,
            t_ns: 0.0,
            next_id: 0,
        }
    }

    pub fn next_flow(&mut self) -> FlowEvent {
        self.t_ns += self.rng.exp(1e9 / self.flow_rate);
        let mut pkts = 1u32;
        // geometric with mean pkts_per_flow
        let p = 1.0 / self.pkts_per_flow;
        while self.rng.next_f64() > p && pkts < 10_000 {
            pkts += 1;
        }
        let ev = FlowEvent {
            id: self.next_id,
            ts_ns: self.t_ns,
            pkts,
        };
        self.next_id += 1;
        ev
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cbr_rates_match_paper_arithmetic() {
        let s = CbrSpec { gbps: 40.0, pkt_size: 256 };
        assert!((s.pps() / 1e6 - 18.1).abs() < 0.3, "pps={}", s.pps());
        let s2 = CbrSpec { gbps: 40.0, pkt_size: 1500 };
        assert!((s2.pps() / 1e6 - 3.28).abs() < 0.1);
    }

    #[test]
    fn traffic_gen_paces_monotonically() {
        let mut g = TrafficGen::new(CbrSpec { gbps: 10.0, pkt_size: 512 }, 100, 1);
        let mut last = 0.0;
        for _ in 0..1000 {
            let p = g.next_packet();
            assert!(p.ts_ns > last);
            last = p.ts_ns;
        }
    }

    #[test]
    fn poisson_arrivals_hit_rate() {
        let mut fa = FlowArrivals::new(1_000_000.0, 10.0, 42);
        let mut last = 0.0;
        let n = 200_000;
        let mut total_pkts = 0u64;
        for _ in 0..n {
            let ev = fa.next_flow();
            last = ev.ts_ns;
            total_pkts += ev.pkts as u64;
        }
        let rate = n as f64 * 1e9 / last;
        assert!((rate / 1_000_000.0 - 1.0).abs() < 0.05, "rate={rate}");
        let mean_pkts = total_pkts as f64 / n as f64;
        assert!((mean_pkts - 10.0).abs() < 0.5, "mean={mean_pkts}");
    }

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range_and_uniform() {
        let mut rng = Rng::new(1234);
        // Bounds: always < n; n = 1 is the degenerate always-0 draw.
        for _ in 0..1000 {
            assert_eq!(rng.below(1), 0);
            assert!(rng.below(7) < 7);
        }
        // Distribution sanity: 6 bins × 120k draws.  Each bin expects
        // 20000 ± ~129 (1σ binomial); ±5% is >7σ of slack, so a uniform
        // generator passes while the old `% n` bias pattern (which at
        // this n is invisible, but a broken mapper is not) still trips.
        let n = 6u64;
        let draws = 120_000u64;
        let mut bins = [0u64; 6];
        for _ in 0..draws {
            bins[rng.below(n) as usize] += 1;
        }
        let expect = (draws / n) as f64;
        for (i, &c) in bins.iter().enumerate() {
            let dev = (c as f64 - expect).abs() / expect;
            assert!(dev < 0.05, "bin {i}: {c} (dev {dev:.3})");
        }
        // Large-n mean check: below(2^62) should average ~2^61 — the
        // multiply-shift uses the *high* bits, so a low-bit artifact
        // (the classic modulo failure mode) would show here.
        let big = 1u64 << 62;
        let mean = (0..50_000).map(|_| rng.below(big) as f64).sum::<f64>() / 50_000.0;
        let half = (1u64 << 61) as f64;
        assert!((mean / half - 1.0).abs() < 0.02, "mean={mean:e}");
    }
}

//! Traffic generators — the in-process stand-in for the paper's 40Gb/s
//! DPDK pktgen (DESIGN.md substitution S7).
//!
//! Three processes are provided:
//! * [`CbrSpec`] — constant-bit-rate packet stream at a given rate and
//!   packet size (the §6 testbed loads, e.g. 40Gb/s@256B = 18.1 Mpps).
//! * [`FlowArrivals`] — Poisson flow arrivals with per-flow packet trains
//!   (the "1.8M flows/s, ~10 packets per flow" analysis workload).
//! * [`ChurnGen`] — the adversarial scale workload: a heavy-tailed
//!   (bounded-Pareto) flow-size mix over a rolling working set of
//!   long-lived flows, plus a tunable fraction of one-packet "mice" with
//!   never-repeating 5-tuples that exist only to thrash the flow table's
//!   eviction machinery.

use super::packet::{Packet, Proto};

/// Deterministic xorshift64* PRNG (no external dependency, reproducible).
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.0 ^= self.0 >> 12;
        self.0 ^= self.0 << 25;
        self.0 ^= self.0 >> 27;
        self.0.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Exponential variate with the given mean.
    #[inline]
    pub fn exp(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.next_f64()).ln()
    }

    /// Uniform draw in `[0, n)` via Lemire's 128-bit multiply-shift.
    /// The previous `next_u64() % n` overweighted the low residues
    /// whenever `n` did not divide 2^64; the multiply maps the full
    /// 64-bit stream onto `[0, n)` with bias below `n / 2^64` — of no
    /// statistical consequence for any `n` this crate draws — without
    /// the data-dependent retry loop of rejection sampling.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Hard assert: the old `% n` panicked on 0 in every build; a
        // silent always-0 stream would hide a degenerate config.
        assert!(n > 0, "Rng::below(0) is meaningless");
        (((self.next_u64() as u128) * (n as u128)) >> 64) as u64
    }
}

/// Constant-bit-rate stream specification.
#[derive(Debug, Clone, Copy)]
pub struct CbrSpec {
    pub gbps: f64,
    pub pkt_size: u16,
}

impl CbrSpec {
    /// Packets per second for this rate/size (wire bytes only; preamble
    /// and IFG ignored, as in the paper's Mpps arithmetic: 40Gb/s@256B ≈
    /// 18.1Mpps, 40Gb/s@1500B ≈ 3.3Mpps).
    pub fn pps(&self) -> f64 {
        self.gbps * 1e9 / (self.pkt_size as f64 * 8.0 + 160.0)
    }

    /// Inter-packet gap in ns.
    pub fn gap_ns(&self) -> f64 {
        1e9 / self.pps()
    }
}

/// Iterator-style generator of packets from a set of concurrent flows.
pub struct TrafficGen {
    rng: Rng,
    spec: CbrSpec,
    n_flows: u64,
    t_ns: f64,
}

impl TrafficGen {
    pub fn new(spec: CbrSpec, n_flows: u64, seed: u64) -> Self {
        Self {
            rng: Rng::new(seed),
            spec,
            n_flows: n_flows.max(1),
            t_ns: 0.0,
        }
    }

    /// Next packet (round-robin-ish over flows, CBR pacing).
    pub fn next_packet(&mut self) -> Packet {
        let flow = self.rng.below(self.n_flows);
        self.t_ns += self.spec.gap_ns();
        let tcp = flow % 4 != 0;
        Packet {
            ts_ns: self.t_ns,
            src_ip: 0x0A00_0000 | (flow as u32 & 0xFFFF),
            dst_ip: 0x0B00_0000 | ((flow >> 16) as u32 & 0xFF),
            src_port: 1024 + (flow % 50000) as u16,
            dst_port: if tcp { 443 } else { 53 },
            proto: if tcp { Proto::Tcp } else { Proto::Udp },
            size: self.spec.pkt_size,
            tcp_flags: if tcp { 0x10 } else { 0 },
        }
    }
}

/// Poisson flow arrivals; each flow emits a geometric packet train.
pub struct FlowArrivals {
    rng: Rng,
    /// Mean new flows per second.
    pub flow_rate: f64,
    /// Mean packets per flow (paper: ~10 at 40Gb/s@256B → 1.8M flows/s).
    pub pkts_per_flow: f64,
    t_ns: f64,
    next_id: u64,
}

/// One flow arrival event: id + start time + packet count.
#[derive(Debug, Clone, Copy)]
pub struct FlowEvent {
    pub id: u64,
    pub ts_ns: f64,
    pub pkts: u32,
}

impl FlowArrivals {
    pub fn new(flow_rate: f64, pkts_per_flow: f64, seed: u64) -> Self {
        Self {
            rng: Rng::new(seed),
            flow_rate,
            pkts_per_flow,
            t_ns: 0.0,
            next_id: 0,
        }
    }

    pub fn next_flow(&mut self) -> FlowEvent {
        self.t_ns += self.rng.exp(1e9 / self.flow_rate);
        let mut pkts = 1u32;
        // geometric with mean pkts_per_flow
        let p = 1.0 / self.pkts_per_flow;
        while self.rng.next_f64() > p && pkts < 10_000 {
            pkts += 1;
        }
        let ev = FlowEvent {
            id: self.next_id,
            ts_ns: self.t_ns,
            pkts,
        };
        self.next_id += 1;
        ev
    }
}

/// Adversarial-churn workload specification (see [`ChurnGen`]).
#[derive(Debug, Clone, Copy)]
pub struct ChurnSpec {
    /// Line rate + packet size (CBR pacing, like [`TrafficGen`]).
    pub cbr: CbrSpec,
    /// Concurrent long-lived ("elephant") flows at any instant.
    pub working_set: u64,
    /// Fraction of packets spent on one-shot "mouse" flows with
    /// never-repeating 5-tuples — each one forces a fresh table insert
    /// (and, on a full window, an eviction) for a single packet of
    /// payoff.  `0.0` = no mice, `1.0` = every packet is a new flow.
    pub churn_frac: f64,
    /// Bounded-Pareto shape for elephant flow lengths (smaller = heavier
    /// tail; 1.0–1.5 matches measured flow-size mixes).
    pub alpha: f64,
    /// Flow-length bounds (packets) for the Pareto draw.
    pub min_pkts: u32,
    pub max_pkts: u32,
}

impl ChurnSpec {
    /// The scale harness default: heavy-tailed elephants plus 30% mice.
    pub fn adversarial(cbr: CbrSpec, working_set: u64) -> Self {
        Self {
            cbr,
            working_set,
            churn_frac: 0.3,
            alpha: 1.2,
            min_pkts: 2,
            max_pkts: 10_000,
        }
    }
}

/// Closed-loop churn generator: a rolling working set of heavy-tailed
/// flows, each replaced by a brand-new 5-tuple the moment its packet
/// budget is spent, interleaved with one-shot mice.  Unlike
/// [`TrafficGen`] (a *fixed* flow population), the distinct-flow count
/// grows without bound over the run — the table must evict to survive,
/// which is the point.  Fully seeded: the packet stream is a pure
/// function of `(spec, seed)`.
pub struct ChurnGen {
    rng: Rng,
    spec: ChurnSpec,
    /// Live elephants: (flow id, remaining packet budget).
    live: Vec<(u64, u32)>,
    next_id: u64,
    t_ns: f64,
}

impl ChurnGen {
    pub fn new(spec: ChurnSpec, seed: u64) -> Self {
        let mut g = Self {
            rng: Rng::new(seed),
            spec,
            live: Vec::with_capacity(spec.working_set.max(1) as usize),
            next_id: 0,
            t_ns: 0.0,
        };
        for _ in 0..spec.working_set.max(1) {
            let id = g.fresh_id();
            let budget = g.flow_budget();
            g.live.push((id, budget));
        }
        g
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Bounded-Pareto flow length in `[min_pkts, max_pkts]`.
    fn flow_budget(&mut self) -> u32 {
        let u = self.rng.next_f64();
        let raw = self.spec.min_pkts.max(1) as f64 * (1.0 - u).powf(-1.0 / self.spec.alpha);
        (raw as u32).clamp(self.spec.min_pkts.max(1), self.spec.max_pkts)
    }

    /// Distinct flow ids emitted so far (mice + elephants, live or dead).
    pub fn flows_emitted(&self) -> u64 {
        self.next_id
    }

    /// A flow id's 5-tuple.  The two 24-bit ip halves encode the id
    /// exactly (unique for every id below 2^48), and the `0x0A…` source
    /// prefix sorts below the `0x0B…` destination prefix, so every id
    /// maps to a distinct canonical [`FlowKey`](super::flow::FlowKey)
    /// and churned flows never collide with each other's keys.
    fn packet_for(&self, id: u64) -> Packet {
        let tcp = id % 4 != 0;
        Packet {
            ts_ns: self.t_ns,
            src_ip: 0x0A00_0000 | (id as u32 & 0x00FF_FFFF),
            dst_ip: 0x0B00_0000 | ((id >> 24) as u32 & 0x00FF_FFFF),
            src_port: 1024 + (id % 50000) as u16,
            dst_port: if tcp { 443 } else { 53 },
            proto: if tcp { Proto::Tcp } else { Proto::Udp },
            size: self.spec.cbr.pkt_size,
            tcp_flags: if tcp { 0x10 } else { 0 },
        }
    }

    /// Next packet: a fresh one-shot mouse with probability
    /// `churn_frac`, otherwise one packet of a random live elephant
    /// (replacing it with a brand-new flow once its budget is spent).
    pub fn next_packet(&mut self) -> Packet {
        self.t_ns += self.spec.cbr.gap_ns();
        if self.spec.churn_frac > 0.0 && self.rng.next_f64() < self.spec.churn_frac {
            let id = self.fresh_id();
            return self.packet_for(id);
        }
        let slot = self.rng.below(self.live.len() as u64) as usize;
        let (id, budget) = self.live[slot];
        let p = self.packet_for(id);
        if budget <= 1 {
            let id = self.fresh_id();
            let budget = self.flow_budget();
            self.live[slot] = (id, budget);
        } else {
            self.live[slot].1 = budget - 1;
        }
        p
    }
}

/// Labeled attack-mix specification (see [`AttackMixGen`]).
#[derive(Debug, Clone, Copy)]
pub struct AttackSpec {
    /// Benign background: the adversarial churn workload.
    pub churn: ChurnSpec,
    /// Fraction of packets belonging to attack flows.
    pub attack_frac: f64,
    /// Packets each attacker sends before a fresh source takes over —
    /// size this above the serving trigger so every attacker is seen.
    pub attack_pkts: u32,
}

/// Seeded attack mix: benign [`ChurnGen`] background interleaved with
/// short-packet TCP SYN probe flows from a reserved `0x0C…` source
/// prefix, so ground truth is recoverable per packet via
/// [`AttackMixGen::is_attack`].  One master CBR clock paces the merged
/// stream (benign timestamps are overwritten), keeping time monotone
/// and the whole stream a pure function of `(spec, seed)`.
pub struct AttackMixGen {
    rng: Rng,
    spec: AttackSpec,
    benign: ChurnGen,
    cur_attacker: u64,
    cur_left: u32,
    t_ns: f64,
}

impl AttackMixGen {
    pub fn new(spec: AttackSpec, seed: u64) -> Self {
        Self {
            rng: Rng::new(seed ^ 0xA77A_C4E5_EED5_1234),
            spec,
            benign: ChurnGen::new(spec.churn, seed),
            cur_attacker: 0,
            cur_left: spec.attack_pkts.max(1),
            t_ns: 0.0,
        }
    }

    /// Ground truth: was this packet emitted by an attack flow?
    pub fn is_attack(p: &Packet) -> bool {
        p.src_ip >> 24 == 0x0C
    }

    fn attack_packet(&self) -> Packet {
        let id = self.cur_attacker;
        Packet {
            ts_ns: self.t_ns,
            src_ip: 0x0C00_0000 | (id as u32 & 0x00FF_FFFF),
            dst_ip: 0x0D00_0000 | ((id >> 24) as u32 & 0x00FF_FFFF),
            src_port: 1024 + (id % 50000) as u16,
            dst_port: 23,
            proto: Proto::Tcp,
            size: 64,
            tcp_flags: 0x02,
        }
    }

    /// Next packet of the merged stream (CBR-paced, monotone time).
    pub fn next_packet(&mut self) -> Packet {
        self.t_ns += self.spec.churn.cbr.gap_ns();
        if self.spec.attack_frac > 0.0 && self.rng.next_f64() < self.spec.attack_frac {
            if self.cur_left == 0 {
                self.cur_attacker += 1;
                self.cur_left = self.spec.attack_pkts.max(1);
            }
            self.cur_left -= 1;
            return self.attack_packet();
        }
        let mut p = self.benign.next_packet();
        p.ts_ns = self.t_ns;
        p
    }
}

/// Two-phase drift workload specification (see [`DriftMixGen`]).
#[derive(Debug, Clone, Copy)]
pub struct DriftSpec {
    /// Benign background: the adversarial churn workload.
    pub churn: ChurnSpec,
    /// Fraction of packets belonging to attack flows (both phases).
    pub attack_frac: f64,
    /// Packets each attacker identity sends before a fresh one takes
    /// over — size this above the serving trigger so every identity is
    /// classified before it rotates away.
    pub attack_pkts: u32,
    /// Packet index after which the phase-2 recipe replaces phase 1
    /// (the first `shift_at` packets use phase 1).
    pub shift_at: u64,
    /// Concurrent phase-2 attacker identities.  A pool spreads each
    /// identity's packets out in time, so the low-and-slow flows get
    /// benign-scale inter-arrival gaps instead of phase 1's bursts.
    pub pool: usize,
}

/// Concept-drift workload: the benign churn background never changes,
/// but the attack recipe does, mid-stream.
///
/// * **Phase 1** (packets `1..=shift_at`) is the [`AttackMixGen`]
///   recipe: bursty 64-byte TCP SYN probes to port 23 from the `0x0C…`
///   source prefix — loud, and trivially separable on packet-size and
///   flag features.
/// * **Phase 2** (after `shift_at`) switches to low-and-slow attackers
///   from the `0x0E…` prefix: benign-sized packets, benign ACK flags,
///   paced across a rotating identity pool so even their inter-arrival
///   gaps look like background flows.  Only the port pair
///   (`31337 → 8080`) and the pool's timing signature separate them —
///   none of which a model calibrated on phase 1 has ever seen.
///
/// Ground truth stays recoverable per packet across both phases via
/// [`DriftMixGen::is_attack`].  One master CBR clock paces the merged
/// stream; the whole stream is a pure function of `(spec, seed)`.
pub struct DriftMixGen {
    rng: Rng,
    spec: DriftSpec,
    benign: ChurnGen,
    /// Phase-1 burst attacker (one identity at a time).
    cur_attacker: u64,
    cur_left: u32,
    /// Phase-2 rotating pool: (identity, remaining packet budget).
    pool: Vec<(u64, u32)>,
    next_p2: u64,
    emitted: u64,
    t_ns: f64,
}

impl DriftMixGen {
    pub fn new(spec: DriftSpec, seed: u64) -> Self {
        Self {
            rng: Rng::new(seed ^ 0xD21F_7A11_AC5E_ED00),
            spec,
            benign: ChurnGen::new(spec.churn, seed),
            cur_attacker: 0,
            cur_left: spec.attack_pkts.max(1),
            pool: Vec::new(),
            next_p2: 0,
            emitted: 0,
            t_ns: 0.0,
        }
    }

    /// Ground truth: was this packet emitted by an attack flow (either
    /// phase's recipe)?
    pub fn is_attack(p: &Packet) -> bool {
        matches!(p.src_ip >> 24, 0x0C | 0x0E)
    }

    /// Is this a *phase-2* (post-shift recipe) attack packet?
    pub fn is_shifted_attack(p: &Packet) -> bool {
        p.src_ip >> 24 == 0x0E
    }

    /// Phase 1: the [`AttackMixGen`] recipe verbatim — short SYN probes.
    fn phase1_packet(&mut self) -> Packet {
        if self.cur_left == 0 {
            self.cur_attacker += 1;
            self.cur_left = self.spec.attack_pkts.max(1);
        }
        self.cur_left -= 1;
        let id = self.cur_attacker;
        Packet {
            ts_ns: self.t_ns,
            src_ip: 0x0C00_0000 | (id as u32 & 0x00FF_FFFF),
            dst_ip: 0x0D00_0000 | ((id >> 24) as u32 & 0x00FF_FFFF),
            src_port: 1024 + (id % 50000) as u16,
            dst_port: 23,
            proto: Proto::Tcp,
            size: 64,
            tcp_flags: 0x02,
        }
    }

    /// Phase 2: benign-shaped packets (background size, ACK flags) whose
    /// only stable tells are the fixed `31337 → 8080` port pair.  A
    /// random pool member emits each packet, so per-flow inter-arrival
    /// times stretch toward benign scales.
    fn phase2_packet(&mut self) -> Packet {
        if self.pool.is_empty() {
            for _ in 0..self.spec.pool.max(1) {
                let id = self.next_p2;
                self.next_p2 += 1;
                self.pool.push((id, self.spec.attack_pkts.max(1)));
            }
        }
        let slot = self.rng.below(self.pool.len() as u64) as usize;
        let (id, left) = self.pool[slot];
        let p = Packet {
            ts_ns: self.t_ns,
            src_ip: 0x0E00_0000 | (id as u32 & 0x00FF_FFFF),
            dst_ip: 0x0F00_0000 | ((id >> 24) as u32 & 0x00FF_FFFF),
            src_port: 31337,
            dst_port: 8080,
            proto: Proto::Tcp,
            size: self.spec.churn.cbr.pkt_size,
            tcp_flags: 0x10,
        };
        if left <= 1 {
            let id = self.next_p2;
            self.next_p2 += 1;
            self.pool[slot] = (id, self.spec.attack_pkts.max(1));
        } else {
            self.pool[slot].1 = left - 1;
        }
        p
    }

    /// Next packet of the merged stream (CBR-paced, monotone time).
    pub fn next_packet(&mut self) -> Packet {
        self.t_ns += self.spec.churn.cbr.gap_ns();
        self.emitted += 1;
        if self.spec.attack_frac > 0.0 && self.rng.next_f64() < self.spec.attack_frac {
            return if self.emitted <= self.spec.shift_at {
                self.phase1_packet()
            } else {
                self.phase2_packet()
            };
        }
        let mut p = self.benign.next_packet();
        p.ts_ns = self.t_ns;
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::flow::FlowKey;

    #[test]
    fn cbr_rates_match_paper_arithmetic() {
        let s = CbrSpec { gbps: 40.0, pkt_size: 256 };
        assert!((s.pps() / 1e6 - 18.1).abs() < 0.3, "pps={}", s.pps());
        let s2 = CbrSpec { gbps: 40.0, pkt_size: 1500 };
        assert!((s2.pps() / 1e6 - 3.28).abs() < 0.1);
    }

    #[test]
    fn traffic_gen_paces_monotonically() {
        let mut g = TrafficGen::new(CbrSpec { gbps: 10.0, pkt_size: 512 }, 100, 1);
        let mut last = 0.0;
        for _ in 0..1000 {
            let p = g.next_packet();
            assert!(p.ts_ns > last);
            last = p.ts_ns;
        }
    }

    #[test]
    fn poisson_arrivals_hit_rate() {
        let mut fa = FlowArrivals::new(1_000_000.0, 10.0, 42);
        let mut last = 0.0;
        let n = 200_000;
        let mut total_pkts = 0u64;
        for _ in 0..n {
            let ev = fa.next_flow();
            last = ev.ts_ns;
            total_pkts += ev.pkts as u64;
        }
        let rate = n as f64 * 1e9 / last;
        assert!((rate / 1_000_000.0 - 1.0).abs() < 0.05, "rate={rate}");
        let mean_pkts = total_pkts as f64 / n as f64;
        assert!((mean_pkts - 10.0).abs() < 0.5, "mean={mean_pkts}");
    }

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range_and_uniform() {
        let mut rng = Rng::new(1234);
        // Bounds: always < n; n = 1 is the degenerate always-0 draw.
        for _ in 0..1000 {
            assert_eq!(rng.below(1), 0);
            assert!(rng.below(7) < 7);
        }
        // Distribution sanity: 6 bins × 120k draws.  Each bin expects
        // 20000 ± ~129 (1σ binomial); ±5% is >7σ of slack, so a uniform
        // generator passes while the old `% n` bias pattern (which at
        // this n is invisible, but a broken mapper is not) still trips.
        let n = 6u64;
        let draws = 120_000u64;
        let mut bins = [0u64; 6];
        for _ in 0..draws {
            bins[rng.below(n) as usize] += 1;
        }
        let expect = (draws / n) as f64;
        for (i, &c) in bins.iter().enumerate() {
            let dev = (c as f64 - expect).abs() / expect;
            assert!(dev < 0.05, "bin {i}: {c} (dev {dev:.3})");
        }
        // Large-n mean check: below(2^62) should average ~2^61 — the
        // multiply-shift uses the *high* bits, so a low-bit artifact
        // (the classic modulo failure mode) would show here.
        let big = 1u64 << 62;
        let mean = (0..50_000).map(|_| rng.below(big) as f64).sum::<f64>() / 50_000.0;
        let half = (1u64 << 61) as f64;
        assert!((mean / half - 1.0).abs() < 0.02, "mean={mean:e}");
    }

    fn churn_spec(working_set: u64, churn_frac: f64) -> ChurnSpec {
        ChurnSpec {
            cbr: CbrSpec { gbps: 40.0, pkt_size: 256 },
            working_set,
            churn_frac,
            alpha: 1.2,
            min_pkts: 2,
            max_pkts: 10_000,
        }
    }

    #[test]
    fn churn_is_deterministic() {
        let mut a = ChurnGen::new(churn_spec(500, 0.4), 7);
        let mut b = ChurnGen::new(churn_spec(500, 0.4), 7);
        for _ in 0..5000 {
            assert_eq!(a.next_packet(), b.next_packet());
        }
        assert_eq!(a.flows_emitted(), b.flows_emitted());
    }

    #[test]
    fn all_mice_never_repeat_a_tuple() {
        let mut g = ChurnGen::new(churn_spec(10, 1.0), 3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..10_000 {
            let p = g.next_packet();
            let (key, _) = FlowKey::from_packet(&p);
            assert!(seen.insert(key), "mouse repeated a canonical 5-tuple");
        }
    }

    #[test]
    fn churn_grows_distinct_flows_past_working_set() {
        let mut g = ChurnGen::new(churn_spec(200, 0.3), 11);
        let mut last = 0.0;
        for _ in 0..50_000 {
            let p = g.next_packet();
            assert!(p.ts_ns > last, "CBR pacing must be monotone");
            last = p.ts_ns;
        }
        // Mice (~30% of 50k) plus finished elephants dwarf the base set.
        assert!(
            g.flows_emitted() > 10_000,
            "only {} distinct flows — no churn",
            g.flows_emitted()
        );
    }

    #[test]
    fn flow_budgets_are_heavy_tailed_and_bounded() {
        let mut g = ChurnGen::new(churn_spec(1, 0.0), 5);
        let budgets: Vec<u32> = (0..20_000).map(|_| g.flow_budget()).collect();
        assert!(budgets.iter().all(|&b| (2..=10_000).contains(&b)));
        // Heavy tail: most flows are short, but big elephants do occur.
        let short = budgets.iter().filter(|&&b| b <= 10).count();
        assert!(short > budgets.len() / 2, "short={short}");
        assert!(budgets.iter().any(|&b| b > 500), "no tail at all");
    }

    fn attack_spec(working_set: u64, attack_frac: f64) -> AttackSpec {
        AttackSpec {
            churn: churn_spec(working_set, 0.2),
            attack_frac,
            attack_pkts: 20,
        }
    }

    #[test]
    fn attack_mix_is_deterministic_and_monotone() {
        let mut a = AttackMixGen::new(attack_spec(256, 0.25), 9);
        let mut b = AttackMixGen::new(attack_spec(256, 0.25), 9);
        let mut last = 0.0;
        for _ in 0..5000 {
            let pa = a.next_packet();
            assert_eq!(pa, b.next_packet());
            assert!(pa.ts_ns > last, "merged clock must stay monotone");
            last = pa.ts_ns;
        }
    }

    #[test]
    fn attack_fraction_and_labels_match_spec() {
        let mut g = AttackMixGen::new(attack_spec(256, 0.25), 42);
        let n = 40_000;
        let mut attacks = 0usize;
        for _ in 0..n {
            let p = g.next_packet();
            if AttackMixGen::is_attack(&p) {
                attacks += 1;
                // Attack signature: SYN probe, short packet, telnet port.
                assert_eq!(p.dst_port, 23);
                assert_eq!(p.tcp_flags, 0x02);
                assert_eq!(p.size, 64);
                let (_, fwd) = FlowKey::from_packet(&p);
                assert!(fwd, "0x0C… source must already be canonical");
            } else {
                assert_eq!(p.src_ip >> 24, 0x0A, "benign keeps its prefix");
            }
        }
        let frac = attacks as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.02, "attack frac {frac}");
    }

    #[test]
    fn attackers_rotate_after_their_packet_budget() {
        let mut g = AttackMixGen::new(attack_spec(64, 1.0), 3);
        let mut per_src = std::collections::HashMap::new();
        for _ in 0..1000 {
            let p = g.next_packet();
            *per_src.entry(p.src_ip).or_insert(0u32) += 1;
        }
        assert!(per_src.len() >= 1000 / 20, "sources: {}", per_src.len());
        assert!(per_src.values().all(|&c| c <= 20));
    }

    fn drift_spec(shift_at: u64) -> DriftSpec {
        DriftSpec {
            churn: churn_spec(256, 0.2),
            attack_frac: 0.3,
            attack_pkts: 20,
            shift_at,
            pool: 16,
        }
    }

    #[test]
    fn drift_mix_swaps_attack_recipe_exactly_at_the_shift() {
        let mut a = DriftMixGen::new(drift_spec(5000), 9);
        let mut b = DriftMixGen::new(drift_spec(5000), 9);
        let mut last = 0.0;
        let (mut p1, mut p2) = (0usize, 0usize);
        for i in 0..10_000u64 {
            let p = a.next_packet();
            assert_eq!(p, b.next_packet(), "stream must be a pure function of (spec, seed)");
            assert!(p.ts_ns > last, "merged clock must stay monotone");
            last = p.ts_ns;
            match p.src_ip >> 24 {
                0x0C => {
                    p1 += 1;
                    assert!(i < 5000, "phase-1 recipe after the shift (packet {i})");
                    assert_eq!((p.dst_port, p.size, p.tcp_flags), (23, 64, 0x02));
                }
                0x0E => {
                    p2 += 1;
                    assert!(i >= 5000, "phase-2 recipe before the shift (packet {i})");
                    // Benign-shaped: background size and flags; only the
                    // port pair gives the flow away.
                    assert_eq!((p.src_port, p.dst_port), (31337, 8080));
                    assert_eq!((p.size, p.tcp_flags), (256, 0x10));
                    let (_, fwd) = FlowKey::from_packet(&p);
                    assert!(fwd, "0x0E… source must already be canonical");
                }
                0x0A => {}
                other => panic!("unexpected source prefix 0x{other:02X}"),
            }
        }
        // Both recipes actually ran, at roughly the configured fraction.
        assert!(p1 > 1000 && p2 > 1000, "p1={p1} p2={p2}");
        let frac = (p1 + p2) as f64 / 10_000.0;
        assert!((frac - 0.3).abs() < 0.03, "attack frac {frac}");
    }

    #[test]
    fn phase2_pool_rotates_identities_and_stretches_gaps() {
        // Attack-only stream, all phase 2: every identity must retire
        // after its budget, and per-flow gaps must span multiple ticks
        // (the pool property the low-and-slow disguise relies on).
        let mut g = DriftMixGen::new(
            DriftSpec { attack_frac: 1.0, ..drift_spec(0) },
            3,
        );
        let mut per_src: std::collections::HashMap<u32, (u32, f64, f64)> =
            std::collections::HashMap::new();
        for _ in 0..4000 {
            let p = g.next_packet();
            assert!(DriftMixGen::is_shifted_attack(&p));
            let e = per_src.entry(p.src_ip).or_insert((0, p.ts_ns, 0.0));
            e.0 += 1;
            e.2 = p.ts_ns - e.1; // span from first to latest packet
        }
        assert!(per_src.len() >= 4000 / 20, "identities: {}", per_src.len());
        assert!(per_src.values().all(|&(c, _, _)| c <= 20));
        // A 16-deep pool means a full-budget identity spans ≫ its own
        // packet count in ticks (phase 1 would span ~20).
        let gap = g.spec.churn.cbr.gap_ns();
        let stretched = per_src
            .values()
            .filter(|&&(c, _, span)| c == 20 && span > 100.0 * gap)
            .count();
        assert!(stretched > 0, "no identity paced across the pool");
    }

    #[test]
    fn distinct_ids_map_to_distinct_canonical_keys() {
        let g = ChurnGen::new(churn_spec(1, 0.0), 1);
        let mut keys = std::collections::HashSet::new();
        for id in (0..1u64 << 26).step_by(4097) {
            let (key, fwd) = FlowKey::from_packet(&g.packet_for(id));
            assert!(fwd, "0x0A… source must already be canonical");
            assert!(keys.insert(key), "id {id} collided");
        }
    }
}

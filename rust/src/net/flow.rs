//! Flow table + per-flow statistics (the NIC's SRAM state).
//!
//! The statistics mirror the 16 features of App. C (packet sizes, counts,
//! inter-arrival times, direction ratios, port/flag information) so the
//! feature extractor can build the BNN's 256-bit input without touching
//! payload bytes ("we assumed encrypted").

use super::packet::{Packet, Proto};

/// Bidirectional 5-tuple key (canonicalized so both directions map to one
/// flow; direction is recovered per packet).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FlowKey {
    pub ip_a: u32,
    pub ip_b: u32,
    pub port_a: u16,
    pub port_b: u16,
    pub proto: u8,
}

impl FlowKey {
    /// FxHash-style multiply-xor over the 13 key bytes.  One definition
    /// serves both consumers: [`FlowTable`] indexes with the *low* bits
    /// and [`ShardedFlowTable`] shards with the *high* bits, so the two
    /// uses stay decorrelated.
    #[inline]
    pub fn hash64(&self) -> u64 {
        let mut h: u64 = 0x51_7c_c1_b7_27_22_0a_95;
        for v in [
            self.ip_a as u64,
            self.ip_b as u64,
            ((self.port_a as u64) << 16) | self.port_b as u64,
            self.proto as u64,
        ] {
            h = (h ^ v).wrapping_mul(0x2127_599b_f432_5c37);
            h ^= h >> 29;
        }
        h
    }

    /// Canonical key: (ip, port) pairs ordered so A ≤ B.
    pub fn from_packet(p: &Packet) -> (Self, bool) {
        let fwd = (p.src_ip, p.src_port) <= (p.dst_ip, p.dst_port);
        let key = if fwd {
            Self {
                ip_a: p.src_ip,
                ip_b: p.dst_ip,
                port_a: p.src_port,
                port_b: p.dst_port,
                proto: p.proto.number(),
            }
        } else {
            Self {
                ip_a: p.dst_ip,
                ip_b: p.src_ip,
                port_a: p.dst_port,
                port_b: p.src_port,
                proto: p.proto.number(),
            }
        };
        (key, fwd)
    }
}

/// Per-flow running statistics (all integer/fixed-point, NIC-computable).
#[derive(Debug, Clone, Default)]
pub struct FlowStats {
    pub pkts: u32,
    pub bytes: u64,
    pub pkts_fwd: u32,
    pub bytes_fwd: u64,
    pub min_size: u16,
    pub max_size: u16,
    /// Sum of packet sizes (for the mean) and of squared sizes (for the
    /// std proxy) — both maintainable with NIC integer ALUs.
    pub size_sum: u64,
    pub size_sq_sum: u64,
    pub first_ts_ns: f64,
    pub last_ts_ns: f64,
    /// Sum of inter-arrival times and count (mean IAT).
    pub iat_sum_ns: f64,
    pub iat_max_ns: f64,
    pub tcp_flag_or: u8,
    pub tcp_flag_counts: u32,
    pub src_port: u16,
    pub dst_port: u16,
}

impl FlowStats {
    pub fn update(&mut self, p: &Packet, forward: bool) {
        if self.pkts == 0 {
            self.first_ts_ns = p.ts_ns;
            self.min_size = p.size;
            self.max_size = p.size;
            self.src_port = p.src_port;
            self.dst_port = p.dst_port;
        } else {
            let iat = (p.ts_ns - self.last_ts_ns).max(0.0);
            self.iat_sum_ns += iat;
            if iat > self.iat_max_ns {
                self.iat_max_ns = iat;
            }
            self.min_size = self.min_size.min(p.size);
            self.max_size = self.max_size.max(p.size);
        }
        self.pkts += 1;
        self.bytes += p.size as u64;
        self.size_sum += p.size as u64;
        self.size_sq_sum += (p.size as u64) * (p.size as u64);
        if forward {
            self.pkts_fwd += 1;
            self.bytes_fwd += p.size as u64;
        }
        if p.proto == Proto::Tcp {
            self.tcp_flag_or |= p.tcp_flags;
            self.tcp_flag_counts += p.tcp_flags.count_ones();
        }
        self.last_ts_ns = p.ts_ns;
    }

    pub fn mean_size(&self) -> u32 {
        if self.pkts == 0 {
            0
        } else {
            (self.size_sum / self.pkts as u64) as u32
        }
    }

    pub fn duration_ns(&self) -> f64 {
        (self.last_ts_ns - self.first_ts_ns).max(0.0)
    }

    pub fn mean_iat_ns(&self) -> f64 {
        if self.pkts <= 1 {
            0.0
        } else {
            self.iat_sum_ns / (self.pkts - 1) as f64
        }
    }
}

/// Open-addressing flow table sized like NIC SRAM tables; the paper's
/// per-packet work is parse + lookup + counter update.
pub struct FlowTable {
    slots: Vec<Option<(FlowKey, FlowStats)>>,
    mask: usize,
    pub occupied: usize,
    /// Lookups that probed more than one slot (collision metric).
    pub probe_overflows: u64,
}

impl FlowTable {
    /// `capacity` is rounded up to a power of two.
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.next_power_of_two().max(16);
        Self {
            slots: (0..cap * 2).map(|_| None).collect(),
            mask: cap * 2 - 1,
            occupied: 0,
            probe_overflows: 0,
        }
    }

    #[inline]
    fn hash(key: &FlowKey) -> usize {
        key.hash64() as usize
    }

    /// Update stats for a packet; returns (stats snapshot ref, is_new_flow,
    /// packet count after update).
    pub fn update(&mut self, p: &Packet) -> (&FlowStats, bool, u32) {
        let (key, fwd) = FlowKey::from_packet(p);
        let mut idx = Self::hash(&key) & self.mask;
        let mut probes = 0;
        loop {
            match &self.slots[idx] {
                Some((k, _)) if *k == key => break,
                None => break,
                _ => {
                    idx = (idx + 1) & self.mask;
                    probes += 1;
                    if probes > self.mask {
                        panic!("flow table full");
                    }
                }
            }
        }
        if probes > 0 {
            self.probe_overflows += 1;
        }
        let is_new = self.slots[idx].is_none();
        if is_new {
            self.slots[idx] = Some((key, FlowStats::default()));
            self.occupied += 1;
        }
        let entry = self.slots[idx].as_mut().unwrap();
        entry.1.update(p, fwd);
        let pkts = entry.1.pkts;
        (&self.slots[idx].as_ref().unwrap().1, is_new, pkts)
    }

    pub fn get(&self, key: &FlowKey) -> Option<&FlowStats> {
        let mut idx = Self::hash(key) & self.mask;
        loop {
            match &self.slots[idx] {
                Some((k, s)) if k == key => return Some(s),
                None => return None,
                _ => idx = (idx + 1) & self.mask,
            }
        }
    }

    pub fn len(&self) -> usize {
        self.occupied
    }

    pub fn is_empty(&self) -> bool {
        self.occupied == 0
    }

    /// Iterate all live flows (export path / end-of-run analysis).
    pub fn iter(&self) -> impl Iterator<Item = (&FlowKey, &FlowStats)> {
        self.slots.iter().filter_map(|s| s.as_ref().map(|(k, v)| (k, v)))
    }
}

/// Flow state partitioned by flow hash: shard `i` owns every flow whose
/// canonical key hashes to it, so the pipeline's stage-1 workers can each
/// own one partition with no cross-shard locking while the two directions
/// of a flow still land on the same worker.
///
/// The shard index comes from the *high* bits of [`FlowKey::hash64`];
/// [`FlowTable`] probes with the low bits, keeping shard choice and
/// in-table placement decorrelated.
pub struct ShardedFlowTable {
    shards: Vec<FlowTable>,
}

impl ShardedFlowTable {
    /// `n_shards` tables (clamped to ≥ 1) of `capacity_per_shard` each.
    pub fn new(n_shards: usize, capacity_per_shard: usize) -> Self {
        let n = n_shards.max(1);
        Self {
            shards: (0..n).map(|_| FlowTable::new(capacity_per_shard)).collect(),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shard owning a canonical flow key — the single definition of the
    /// routing formula; `shard_of`, `update`, and `get` must all agree
    /// or lookups silently probe the wrong partition.
    #[inline]
    pub fn shard_of_key(key: &FlowKey, n_shards: usize) -> usize {
        ((key.hash64() >> 32) % n_shards.max(1) as u64) as usize
    }

    /// Shard owning this packet's flow — a pure function of the canonical
    /// key, so every packet of a flow (either direction) maps to the same
    /// shard in every process that agrees on `n_shards`.
    #[inline]
    pub fn shard_of(p: &Packet, n_shards: usize) -> usize {
        let (key, _) = FlowKey::from_packet(p);
        Self::shard_of_key(&key, n_shards)
    }

    /// Route a packet to its shard and update that shard's statistics;
    /// same contract as [`FlowTable::update`].
    pub fn update(&mut self, p: &Packet) -> (&FlowStats, bool, u32) {
        let s = Self::shard_of(p, self.shards.len());
        self.shards[s].update(p)
    }

    pub fn get(&self, key: &FlowKey) -> Option<&FlowStats> {
        self.shards[Self::shard_of_key(key, self.shards.len())].get(key)
    }

    /// Live flows across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(FlowTable::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hand the partitions to per-shard owners (the pipeline's stage-1
    /// workers take one table each).
    pub fn into_shards(self) -> Vec<FlowTable> {
        self.shards
    }

    /// Iterate all live flows across every shard.
    pub fn iter(&self) -> impl Iterator<Item = (&FlowKey, &FlowStats)> {
        self.shards.iter().flat_map(FlowTable::iter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pkt(src_ip: u32, sport: u16, ts: f64, size: u16) -> Packet {
        Packet {
            ts_ns: ts,
            src_ip,
            dst_ip: 99,
            src_port: sport,
            dst_port: 443,
            proto: Proto::Tcp,
            size,
            tcp_flags: 0x10,
        }
    }

    #[test]
    fn bidirectional_key_canonical() {
        let a = pkt(5, 1000, 0.0, 100);
        let mut b = a;
        std::mem::swap(&mut b.src_ip, &mut b.dst_ip);
        std::mem::swap(&mut b.src_port, &mut b.dst_port);
        let (ka, fa) = FlowKey::from_packet(&a);
        let (kb, fb) = FlowKey::from_packet(&b);
        assert_eq!(ka, kb);
        assert_ne!(fa, fb);
    }

    #[test]
    fn stats_accumulate() {
        let mut t = FlowTable::new(64);
        let (_, new1, c1) = t.update(&pkt(1, 10, 0.0, 100));
        assert!(new1 && c1 == 1);
        let (_, new2, c2) = t.update(&pkt(1, 10, 1000.0, 300));
        assert!(!new2 && c2 == 2);
        let (key, _) = FlowKey::from_packet(&pkt(1, 10, 0.0, 0));
        let s = t.get(&key).unwrap();
        assert_eq!(s.pkts, 2);
        assert_eq!(s.bytes, 400);
        assert_eq!(s.min_size, 100);
        assert_eq!(s.max_size, 300);
        assert_eq!(s.mean_size(), 200);
        assert!((s.mean_iat_ns() - 1000.0).abs() < 1e-9);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn many_flows_no_collision_loss() {
        let mut t = FlowTable::new(4096);
        for i in 0..3000u32 {
            t.update(&pkt(i, (i % 60000) as u16, i as f64, 64));
        }
        assert_eq!(t.len(), 3000);
        assert_eq!(t.iter().count(), 3000);
    }

    #[test]
    fn both_directions_hit_one_shard() {
        for n_shards in [1usize, 2, 3, 8] {
            for i in 0..200u32 {
                let a = pkt(i, 1000 + i as u16, 0.0, 64);
                let mut b = a;
                std::mem::swap(&mut b.src_ip, &mut b.dst_ip);
                std::mem::swap(&mut b.src_port, &mut b.dst_port);
                assert_eq!(
                    ShardedFlowTable::shard_of(&a, n_shards),
                    ShardedFlowTable::shard_of(&b, n_shards),
                );
                assert!(ShardedFlowTable::shard_of(&a, n_shards) < n_shards);
            }
        }
    }

    #[test]
    fn sharded_table_matches_flat_table() {
        let mut flat = FlowTable::new(4096);
        let mut sharded = ShardedFlowTable::new(4, 1024);
        for i in 0..2000u32 {
            let p = pkt(i % 300, (i % 300) as u16, i as f64, 64);
            let (_, flat_new, flat_pkts) = flat.update(&p);
            let (_, sh_new, sh_pkts) = sharded.update(&p);
            assert_eq!(flat_new, sh_new, "pkt {i}");
            assert_eq!(flat_pkts, sh_pkts, "pkt {i}");
        }
        assert_eq!(flat.len(), sharded.len());
        assert_eq!(sharded.iter().count(), flat.len());
        // Per-flow stats agree through either access path.
        let (key, _) = FlowKey::from_packet(&pkt(7, 7, 0.0, 0));
        assert_eq!(flat.get(&key).unwrap().pkts, sharded.get(&key).unwrap().pkts);
    }

    #[test]
    fn shards_partition_without_loss() {
        let mut sharded = ShardedFlowTable::new(3, 1024);
        for i in 0..500u32 {
            sharded.update(&pkt(i, 9, i as f64, 64));
        }
        assert_eq!(sharded.len(), 500);
        let shards = sharded.into_shards();
        assert_eq!(shards.len(), 3);
        let total: usize = shards.iter().map(FlowTable::len).sum();
        assert_eq!(total, 500);
        // The hash actually spreads flows over the partitions.
        assert!(shards.iter().filter(|s| !s.is_empty()).count() >= 2);
    }
}

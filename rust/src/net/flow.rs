//! Flow table + per-flow statistics (the NIC's SRAM state).
//!
//! The statistics mirror the 16 features of App. C (packet sizes, counts,
//! inter-arrival times, direction ratios, port/flag information) so the
//! feature extractor can build the BNN's 256-bit input without touching
//! payload bytes ("we assumed encrypted").
//!
//! ## Bounded memory (the paper's headline workload)
//!
//! The paper serves "millions of network flows per second" from a table
//! that physically cannot hold millions of live entries — NIC SRAM is
//! bounded, so the table must *replace*, never grow and never die.  This
//! module adopts the contract both in-band co-processor designs assume
//! (Inference-to-complete, In-network Neural Networks): open addressing
//! with a **bounded probe window** and deterministic replacement on the
//! packet clock:
//!
//! * [`EvictPolicy::Lru`] — when a key's [`PROBE_WINDOW`] is exhausted
//!   by live flows, the entry with the oldest `last_ts_ns` in the window
//!   is replaced (ties resolve to probe order, so replacement is a pure
//!   function of table state — rerun-identical).
//! * [`EvictPolicy::Age`] — LRU replacement plus a periodic sweep (every
//!   [`SWEEP_INTERVAL`] updates of the table, on its own update counter)
//!   that removes flows idle longer than `max_idle_ns` of packet time.
//! * [`EvictPolicy::Off`] — the legacy shape: probe the whole table, and
//!   when it is completely full leave the packet **untracked** (the old
//!   code panicked here, which made the million-flow workload literally
//!   unrunnable).
//!
//! Every degradation is counted in [`FlowTableStats`] (evictions,
//! aged-out flows, collision probes, untracked packets, a probe-length
//! histogram), which merges key-wise across shards and workers like the
//! rest of the service counters.

use super::packet::{Packet, Proto};

/// Number of logical flow shards both runtimes partition flow state
/// into, regardless of worker count.  The serial loop owns all of them;
/// a pipelined run with `w` workers gives worker `i` the shards `l` with
/// `l % w == i`.  Because eviction makes per-flow state depend on table
/// *co-residents*, the determinism contract (pipelined ≡ serial for any
/// worker count) only survives if every run partitions flows into the
/// same tables — this constant is that partition.  Worker counts above
/// `FLOW_SHARDS` are rejected at build time.
pub const FLOW_SHARDS: usize = 64;

/// Bounded probe walk under [`EvictPolicy::Lru`] / [`EvictPolicy::Age`]:
/// a lookup or insert touches at most this many slots — the SRAM-style
/// worst-case bound the data plane needs — and a full window triggers
/// replacement instead of further probing.
pub const PROBE_WINDOW: usize = 16;

/// Under [`EvictPolicy::Age`], how many `update` calls a table absorbs
/// between idle-flow sweeps.  The cadence rides the table's own update
/// counter (not wall time), so serial and pipelined runs — whose tables
/// see identical per-shard update subsequences — sweep identically.
pub const SWEEP_INTERVAL: u64 = 512;

/// Replacement behavior once a key's probe walk finds neither its entry
/// nor a free slot.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EvictPolicy {
    /// Replace the oldest-`last_ts_ns` entry in the probe window.
    Lru,
    /// LRU replacement plus periodic sweeps removing flows idle longer
    /// than `max_idle_ns` on the packet clock.
    Age { max_idle_ns: f64 },
    /// Never replace: probe the whole table, and leave the packet
    /// untracked (no stats, no trigger) when the table is full.
    Off,
}

/// Bidirectional 5-tuple key (canonicalized so both directions map to one
/// flow; direction is recovered per packet).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FlowKey {
    pub ip_a: u32,
    pub ip_b: u32,
    pub port_a: u16,
    pub port_b: u16,
    pub proto: u8,
}

impl FlowKey {
    /// FxHash-style multiply-xor over the 13 key bytes.  One definition
    /// serves both consumers: [`FlowTable`] indexes with the *low* bits
    /// and [`ShardedFlowTable`] shards with the *high* bits, so the two
    /// uses stay decorrelated.
    #[inline]
    pub fn hash64(&self) -> u64 {
        let mut h: u64 = 0x51_7c_c1_b7_27_22_0a_95;
        for v in [
            self.ip_a as u64,
            self.ip_b as u64,
            ((self.port_a as u64) << 16) | self.port_b as u64,
            self.proto as u64,
        ] {
            h = (h ^ v).wrapping_mul(0x2127_599b_f432_5c37);
            h ^= h >> 29;
        }
        h
    }

    /// Canonical key: (ip, port) pairs ordered so A ≤ B.
    pub fn from_packet(p: &Packet) -> (Self, bool) {
        let fwd = (p.src_ip, p.src_port) <= (p.dst_ip, p.dst_port);
        let key = if fwd {
            Self {
                ip_a: p.src_ip,
                ip_b: p.dst_ip,
                port_a: p.src_port,
                port_b: p.dst_port,
                proto: p.proto.number(),
            }
        } else {
            Self {
                ip_a: p.dst_ip,
                ip_b: p.src_ip,
                port_a: p.dst_port,
                port_b: p.src_port,
                proto: p.proto.number(),
            }
        };
        (key, fwd)
    }
}

/// Per-flow running statistics (all integer/fixed-point, NIC-computable).
#[derive(Debug, Clone, Default)]
pub struct FlowStats {
    pub pkts: u32,
    pub bytes: u64,
    pub pkts_fwd: u32,
    pub bytes_fwd: u64,
    pub min_size: u16,
    pub max_size: u16,
    /// Sum of packet sizes (for the mean) and of squared sizes (for the
    /// std proxy) — both maintainable with NIC integer ALUs.
    pub size_sum: u64,
    pub size_sq_sum: u64,
    pub first_ts_ns: f64,
    pub last_ts_ns: f64,
    /// Sum of inter-arrival times and count (mean IAT).
    pub iat_sum_ns: f64,
    pub iat_max_ns: f64,
    pub tcp_flag_or: u8,
    pub tcp_flag_counts: u32,
    pub src_port: u16,
    pub dst_port: u16,
}

impl FlowStats {
    pub fn update(&mut self, p: &Packet, forward: bool) {
        if self.pkts == 0 {
            self.first_ts_ns = p.ts_ns;
            self.min_size = p.size;
            self.max_size = p.size;
            self.src_port = p.src_port;
            self.dst_port = p.dst_port;
        } else {
            let iat = (p.ts_ns - self.last_ts_ns).max(0.0);
            self.iat_sum_ns += iat;
            if iat > self.iat_max_ns {
                self.iat_max_ns = iat;
            }
            self.min_size = self.min_size.min(p.size);
            self.max_size = self.max_size.max(p.size);
        }
        self.pkts += 1;
        self.bytes += p.size as u64;
        self.size_sum += p.size as u64;
        self.size_sq_sum += (p.size as u64) * (p.size as u64);
        if forward {
            self.pkts_fwd += 1;
            self.bytes_fwd += p.size as u64;
        }
        if p.proto == Proto::Tcp {
            self.tcp_flag_or |= p.tcp_flags;
            self.tcp_flag_counts += p.tcp_flags.count_ones();
        }
        self.last_ts_ns = p.ts_ns;
    }

    pub fn mean_size(&self) -> u32 {
        if self.pkts == 0 {
            0
        } else {
            (self.size_sum / self.pkts as u64) as u32
        }
    }

    pub fn duration_ns(&self) -> f64 {
        (self.last_ts_ns - self.first_ts_ns).max(0.0)
    }

    pub fn mean_iat_ns(&self) -> f64 {
        if self.pkts <= 1 {
            0.0
        } else {
            self.iat_sum_ns / (self.pkts - 1) as f64
        }
    }
}

/// Degradation and collision accounting of one or more [`FlowTable`]s.
/// Merges key-wise (counters add; `occupied`/`slots` add so the load
/// factor of a merged snapshot is the aggregate over all tables), the
/// same way the rest of [`ServiceStats`](crate::coordinator::ServiceStats)
/// folds across shards and workers.
///
/// The pre-eviction code kept one `probe_overflows` counter that would
/// have conflated two different events once replacement landed: a probe
/// walk lengthened by *hash collisions* between live flows, and a walk
/// that ended in *replacement* of an evicted slot.  They are split here:
/// `collision_probes` counts only updates that resolved (hit or free
/// slot) after at least one collision probe, `evictions` counts
/// window-exhausted walks that displaced a flow — an update increments
/// exactly one of them (or neither, on a direct home-slot hit).  The
/// collision test in this module asserts on `collision_probes`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FlowTableStats {
    /// Live flows displaced by LRU/Age window replacement.
    pub evictions: u64,
    /// Idle flows removed by an [`EvictPolicy::Age`] sweep.
    pub aged_out: u64,
    /// Updates that resolved after probing past at least one live flow
    /// with a different key (hash collisions; excludes eviction walks).
    pub collision_probes: u64,
    /// Packets left untracked: [`EvictPolicy::Off`] with a full table.
    pub untracked: u64,
    /// Probe-walk length histogram: bucket `d` counts updates that
    /// probed `d` slots past the home slot; the last bucket absorbs
    /// walks of [`PROBE_WINDOW`] or more (window-exhausted or the
    /// unbounded `Off` walk).  Buckets sum to the table's update count.
    pub probe_hist: [u64; PROBE_WINDOW + 1],
    /// Live flows at snapshot time.
    pub occupied: u64,
    /// Slot capacity at snapshot time.
    pub slots: u64,
}

impl FlowTableStats {
    /// Fold another table's (or worker's) counters into this one.
    pub fn merge(&mut self, other: &FlowTableStats) {
        self.evictions += other.evictions;
        self.aged_out += other.aged_out;
        self.collision_probes += other.collision_probes;
        self.untracked += other.untracked;
        for (a, b) in self.probe_hist.iter_mut().zip(&other.probe_hist) {
            *a += b;
        }
        self.occupied += other.occupied;
        self.slots += other.slots;
    }

    /// Occupied fraction of the snapshotted slots (0 when no snapshot).
    pub fn load_factor(&self) -> f64 {
        if self.slots == 0 {
            0.0
        } else {
            self.occupied as f64 / self.slots as f64
        }
    }
}

/// One flow-table update's outcome: the refreshed stats plus what the
/// insert did to the table.
#[derive(Debug)]
pub struct FlowUpdate<'a> {
    /// The flow's statistics after absorbing this packet.
    pub stats: &'a FlowStats,
    /// This packet started a new table entry (first packet of the flow
    /// — or of its *return*, if it was evicted earlier and came back).
    pub is_new: bool,
    /// Packet count after the update (`1` when `is_new`).
    pub pkts: u32,
    /// The insert displaced a live flow (LRU/Age window replacement).
    pub evicted: bool,
}

/// Open-addressing flow table sized like NIC SRAM tables; the paper's
/// per-packet work is parse + lookup + counter update.  Bounded memory:
/// see the module docs for the probe-window/eviction contract.
pub struct FlowTable {
    slots: Vec<Option<(FlowKey, FlowStats)>>,
    mask: usize,
    policy: EvictPolicy,
    pub occupied: usize,
    /// Degradation counters (`occupied`/`slots` stay zero here; they are
    /// filled per snapshot by [`stats_snapshot`](Self::stats_snapshot)).
    counters: FlowTableStats,
    /// Updates absorbed — drives the [`SWEEP_INTERVAL`] aging cadence.
    updates: u64,
}

impl FlowTable {
    /// `capacity` is rounded up to a power of two; the table keeps the
    /// legacy [`EvictPolicy::Off`] behavior (minus the old full-table
    /// panic).  Use [`with_policy`](Self::with_policy) for eviction.
    pub fn new(capacity: usize) -> Self {
        Self::with_policy(capacity, EvictPolicy::Off)
    }

    /// `capacity` is rounded up to a power of two (≥ 16) and doubled
    /// into slots, as before; `policy` governs what happens when a probe
    /// window (or, under `Off`, the whole table) is exhausted.
    pub fn with_policy(capacity: usize, policy: EvictPolicy) -> Self {
        let cap = capacity.next_power_of_two().max(16);
        Self {
            slots: (0..cap * 2).map(|_| None).collect(),
            mask: cap * 2 - 1,
            policy,
            occupied: 0,
            counters: FlowTableStats::default(),
            updates: 0,
        }
    }

    #[inline]
    fn hash(key: &FlowKey) -> usize {
        key.hash64() as usize
    }

    /// Probe bound for this policy: the bounded window under eviction,
    /// the whole table under `Off`.
    #[inline]
    fn window(&self) -> usize {
        match self.policy {
            EvictPolicy::Off => self.slots.len(),
            _ => PROBE_WINDOW.min(self.slots.len()),
        }
    }

    /// Update stats for a packet.  Returns `None` only under
    /// [`EvictPolicy::Off`] when the table is full and the key absent —
    /// the packet is counted as untracked and forwarded without state
    /// (degrade, don't die).
    pub fn update(&mut self, p: &Packet) -> Option<FlowUpdate<'_>> {
        let (key, fwd) = FlowKey::from_packet(p);
        self.update_keyed(key, fwd, p)
    }

    /// [`update`](Self::update) for callers that already canonicalized
    /// the key (the sharded table and the pipelined ingress hash once
    /// per packet and pass the key down instead of re-deriving it).
    pub fn update_keyed(&mut self, key: FlowKey, fwd: bool, p: &Packet) -> Option<FlowUpdate<'_>> {
        self.updates += 1;
        if let EvictPolicy::Age { max_idle_ns } = self.policy {
            if self.updates % SWEEP_INTERVAL == 0 {
                self.sweep(p.ts_ns, max_idle_ns);
            }
        }
        let home = Self::hash(&key) & self.mask;
        let window = self.window();
        let mut found = None;
        let mut probes = window;
        for d in 0..window {
            let idx = (home + d) & self.mask;
            match &self.slots[idx] {
                Some((k, _)) if *k == key => {
                    found = Some(idx);
                    probes = d;
                    break;
                }
                None => {
                    found = Some(idx);
                    probes = d;
                    break;
                }
                Some(_) => {}
            }
        }
        self.counters.probe_hist[probes.min(PROBE_WINDOW)] += 1;
        let (idx, evicted) = match found {
            Some(idx) => {
                if probes > 0 {
                    self.counters.collision_probes += 1;
                }
                (idx, false)
            }
            None => {
                if matches!(self.policy, EvictPolicy::Off) {
                    self.counters.untracked += 1;
                    return None;
                }
                // Deterministic replacement: the stalest entry in the
                // window (oldest last_ts_ns; ties resolve to probe
                // order) — a pure function of table state, so reruns
                // and the pipelined runtime evict identically.
                let mut victim = home;
                let mut oldest = f64::INFINITY;
                for d in 0..window {
                    let i = (home + d) & self.mask;
                    if let Some((_, s)) = &self.slots[i] {
                        if s.last_ts_ns < oldest {
                            oldest = s.last_ts_ns;
                            victim = i;
                        }
                    }
                }
                self.counters.evictions += 1;
                self.slots[victim] = None;
                self.occupied -= 1;
                (victim, true)
            }
        };
        let is_new = self.slots[idx].is_none();
        if is_new {
            self.slots[idx] = Some((key, FlowStats::default()));
            self.occupied += 1;
        }
        let entry = self.slots[idx].as_mut().unwrap();
        entry.1.update(p, fwd);
        let pkts = entry.1.pkts;
        Some(FlowUpdate {
            stats: &self.slots[idx].as_ref().unwrap().1,
            is_new,
            pkts,
            evicted,
        })
    }

    /// Bounded lookup: probes at most [`window`](Self::window) slots and
    /// returns `None` when the key is absent — including on a completely
    /// full table, where the old unbounded walk spun forever.
    pub fn get(&self, key: &FlowKey) -> Option<&FlowStats> {
        let home = Self::hash(key) & self.mask;
        for d in 0..self.window() {
            let idx = (home + d) & self.mask;
            match &self.slots[idx] {
                Some((k, s)) if k == key => return Some(s),
                None => return None,
                Some(_) => {}
            }
        }
        None
    }

    /// Remove every flow idle longer than `max_idle_ns` as of `now_ns`.
    /// Deletions backward-shift later entries in the probe chain
    /// (standard linear-probing hole fill), so surviving flows stay
    /// reachable within their bounded window.
    fn sweep(&mut self, now_ns: f64, max_idle_ns: f64) {
        for i in 0..self.slots.len() {
            // A removal can shift a later entry into slot i; re-check it
            // until it holds a live flow (each pass removes one entry,
            // so this terminates).  An idle entry shifted *behind* the
            // scan survives until the next sweep — harmless, and still
            // deterministic.
            while let Some((_, s)) = &self.slots[i] {
                if now_ns - s.last_ts_ns > max_idle_ns {
                    self.remove_at(i);
                    self.counters.aged_out += 1;
                } else {
                    break;
                }
            }
        }
    }

    /// Empty slot `i` and backward-shift the probe chain into the hole,
    /// so no surviving entry ends up separated from its home slot by an
    /// empty one (which would make it unreachable to the bounded `get`).
    fn remove_at(&mut self, mut i: usize) {
        self.slots[i] = None;
        self.occupied -= 1;
        let mut j = i;
        // Bounded to one full cycle: on a table with no other empty slot
        // the chain scan has no terminator, and an unbounded walk would
        // spin — the exact failure mode this module exists to remove.
        for _ in 0..self.slots.len() {
            j = (j + 1) & self.mask;
            let Some((k, _)) = &self.slots[j] else { break };
            let home = Self::hash(k) & self.mask;
            // Entry at j may fill the hole at i iff its home lies
            // cyclically outside (i, j] — the standard deletion rule.
            if (j.wrapping_sub(home) & self.mask) >= (j.wrapping_sub(i) & self.mask) {
                self.slots[i] = self.slots[j].take();
                i = j;
            }
        }
    }

    /// Degradation counters plus an occupancy snapshot (live flows /
    /// slot capacity, for the load factor).
    pub fn stats_snapshot(&self) -> FlowTableStats {
        FlowTableStats {
            occupied: self.occupied as u64,
            slots: self.slots.len() as u64,
            ..self.counters.clone()
        }
    }

    pub fn len(&self) -> usize {
        self.occupied
    }

    pub fn is_empty(&self) -> bool {
        self.occupied == 0
    }

    /// Iterate all live flows (export path / end-of-run analysis).
    pub fn iter(&self) -> impl Iterator<Item = (&FlowKey, &FlowStats)> {
        self.slots.iter().filter_map(|s| s.as_ref().map(|(k, v)| (k, v)))
    }
}

/// Flow state partitioned by flow hash: shard `i` owns every flow whose
/// canonical key hashes to it, so the pipeline's stage-1 workers can each
/// own one partition with no cross-shard locking while the two directions
/// of a flow still land on the same worker.
///
/// The shard index comes from the *high* bits of [`FlowKey::hash64`];
/// [`FlowTable`] probes with the low bits, keeping shard choice and
/// in-table placement decorrelated.
pub struct ShardedFlowTable {
    shards: Vec<FlowTable>,
}

impl ShardedFlowTable {
    /// `n_shards` tables (clamped to ≥ 1) of `capacity_per_shard` each,
    /// with the legacy no-eviction policy.
    pub fn new(n_shards: usize, capacity_per_shard: usize) -> Self {
        Self::with_policy(n_shards, capacity_per_shard, EvictPolicy::Off)
    }

    /// `n_shards` tables of `capacity_per_shard` each under `policy`.
    pub fn with_policy(n_shards: usize, capacity_per_shard: usize, policy: EvictPolicy) -> Self {
        let n = n_shards.max(1);
        Self {
            shards: (0..n)
                .map(|_| FlowTable::with_policy(capacity_per_shard, policy))
                .collect(),
        }
    }

    /// Split a *total* capacity budget evenly over `n_shards` tables —
    /// the serving runtimes' constructor, so `flow_capacity` means one
    /// budget for the whole service rather than per-table.
    pub fn with_total_capacity(n_shards: usize, total_capacity: usize, policy: EvictPolicy) -> Self {
        let n = n_shards.max(1);
        Self::with_policy(n, total_capacity.div_ceil(n), policy)
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shard owning a canonical flow key — the single definition of the
    /// routing formula; `shard_of`, `update`, and `get` must all agree
    /// or lookups silently probe the wrong partition.
    #[inline]
    pub fn shard_of_key(key: &FlowKey, n_shards: usize) -> usize {
        ((key.hash64() >> 32) % n_shards.max(1) as u64) as usize
    }

    /// Shard owning this packet's flow — a pure function of the canonical
    /// key, so every packet of a flow (either direction) maps to the same
    /// shard in every process that agrees on `n_shards`.
    #[inline]
    pub fn shard_of(p: &Packet, n_shards: usize) -> usize {
        let (key, _) = FlowKey::from_packet(p);
        Self::shard_of_key(&key, n_shards)
    }

    /// Route a packet to its shard and update that shard's statistics;
    /// same contract as [`FlowTable::update`].  The key is canonicalized
    /// exactly once: shard choice and the in-table probe share it (the
    /// old path re-derived it inside the shard — double work per packet).
    pub fn update(&mut self, p: &Packet) -> Option<FlowUpdate<'_>> {
        let (key, fwd) = FlowKey::from_packet(p);
        let s = Self::shard_of_key(&key, self.shards.len());
        self.shards[s].update_keyed(key, fwd, p)
    }

    pub fn get(&self, key: &FlowKey) -> Option<&FlowStats> {
        self.shards[Self::shard_of_key(key, self.shards.len())].get(key)
    }

    /// Degradation counters + occupancy, merged over every shard.
    pub fn stats_snapshot(&self) -> FlowTableStats {
        let mut out = FlowTableStats::default();
        for s in &self.shards {
            out.merge(&s.stats_snapshot());
        }
        out
    }

    /// Live flows across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(FlowTable::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hand the partitions to per-shard owners (the pipeline's stage-1
    /// workers take every `workers`-th table each).
    pub fn into_shards(self) -> Vec<FlowTable> {
        self.shards
    }

    /// Iterate all live flows across every shard.
    pub fn iter(&self) -> impl Iterator<Item = (&FlowKey, &FlowStats)> {
        self.shards.iter().flat_map(FlowTable::iter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pkt(src_ip: u32, sport: u16, ts: f64, size: u16) -> Packet {
        Packet {
            ts_ns: ts,
            src_ip,
            dst_ip: 99,
            src_port: sport,
            dst_port: 443,
            proto: Proto::Tcp,
            size,
            tcp_flags: 0x10,
        }
    }

    #[test]
    fn bidirectional_key_canonical() {
        let a = pkt(5, 1000, 0.0, 100);
        let mut b = a;
        std::mem::swap(&mut b.src_ip, &mut b.dst_ip);
        std::mem::swap(&mut b.src_port, &mut b.dst_port);
        let (ka, fa) = FlowKey::from_packet(&a);
        let (kb, fb) = FlowKey::from_packet(&b);
        assert_eq!(ka, kb);
        assert_ne!(fa, fb);
    }

    #[test]
    fn stats_accumulate() {
        let mut t = FlowTable::new(64);
        let u = t.update(&pkt(1, 10, 0.0, 100)).unwrap();
        assert!(u.is_new && u.pkts == 1 && !u.evicted);
        let u = t.update(&pkt(1, 10, 1000.0, 300)).unwrap();
        assert!(!u.is_new && u.pkts == 2);
        let (key, _) = FlowKey::from_packet(&pkt(1, 10, 0.0, 0));
        let s = t.get(&key).unwrap();
        assert_eq!(s.pkts, 2);
        assert_eq!(s.bytes, 400);
        assert_eq!(s.min_size, 100);
        assert_eq!(s.max_size, 300);
        assert_eq!(s.mean_size(), 200);
        assert!((s.mean_iat_ns() - 1000.0).abs() < 1e-9);
        assert_eq!(t.len(), 1);
    }

    /// The collision metric after the split: this test asserts on
    /// `collision_probes` (walks lengthened by live same-table flows),
    /// which under `Off` can never be polluted by evicted-slot reuse —
    /// `evictions` stays 0 by construction.
    #[test]
    fn many_flows_no_collision_loss() {
        let mut t = FlowTable::new(4096);
        for i in 0..3000u32 {
            t.update(&pkt(i, (i % 60000) as u16, i as f64, 64));
        }
        assert_eq!(t.len(), 3000);
        assert_eq!(t.iter().count(), 3000);
        let st = t.stats_snapshot();
        // 3000 keys into 8192 slots: birthday collisions are certain.
        assert!(st.collision_probes > 0);
        assert_eq!(st.evictions, 0);
        assert_eq!(st.untracked, 0);
        // Every update lands in exactly one probe-length bucket.
        assert_eq!(st.probe_hist.iter().sum::<u64>(), 3000);
    }

    /// Satellite regression: a full table must answer a missing-key
    /// lookup with `None` (the old `get` probe loop had no terminator
    /// and spun forever) and an update must degrade to untracked (the
    /// old `update` panicked).
    #[test]
    fn full_table_get_returns_none_and_update_degrades() {
        // new(16) → 32 slots, EvictPolicy::Off.
        let mut t = FlowTable::new(16);
        let mut untracked_seen = false;
        for i in 0..200u32 {
            match t.update(&pkt(1000 + i, 7, i as f64, 64)) {
                Some(u) => assert!(!u.evicted),
                None => untracked_seen = true,
            }
        }
        assert!(untracked_seen, "200 distinct flows must overflow 32 slots");
        assert_eq!(t.len(), 32, "Off fills every slot, then stops");
        let st = t.stats_snapshot();
        assert_eq!(st.untracked + t.len() as u64, 200);
        assert_eq!(st.evictions, 0);
        // Missing key on the full table: bounded walk, None, no spin.
        let (missing, _) = FlowKey::from_packet(&pkt(9_999_999, 1, 0.0, 0));
        assert!(t.get(&missing).is_none());
        // Present keys still resolve.
        let found = t.iter().count();
        assert_eq!(found, 32);
    }

    #[test]
    fn lru_eviction_is_deterministic_and_counted() {
        let run = || {
            let mut t = FlowTable::with_policy(16, EvictPolicy::Lru);
            let mut evicted_flag_seen = false;
            for i in 0..500u32 {
                let u = t.update(&pkt(i, (i % 3000) as u16, i as f64, 64)).unwrap();
                evicted_flag_seen |= u.evicted;
            }
            (t.stats_snapshot(), evicted_flag_seen)
        };
        let (a, saw_evicted) = run();
        assert!(a.evictions > 0, "500 flows must thrash 32 slots");
        assert!(saw_evicted);
        assert_eq!(a.untracked, 0, "eviction policies never drop updates");
        assert!(a.occupied <= 32);
        assert_eq!(a.probe_hist.iter().sum::<u64>(), 500);
        // Pure function of the input stream: rerun-identical.
        let (b, _) = run();
        assert_eq!(a, b);
    }

    /// Satellite behavior: an evicted flow that returns is a *new* flow
    /// — stats reset, `is_new` fires again (so `NewFlow`/`EveryNPackets`
    /// triggers re-arm naturally).
    #[test]
    fn evicted_flow_returns_as_new() {
        let mut t = FlowTable::with_policy(16, EvictPolicy::Lru);
        let flow_a = |ts: f64| pkt(1, 1, ts, 64);
        for k in 0..5 {
            t.update(&flow_a(k as f64));
        }
        let (key_a, _) = FlowKey::from_packet(&flow_a(0.0));
        assert_eq!(t.get(&key_a).unwrap().pkts, 5);
        // Thrash with newer distinct flows until A (the oldest entry in
        // any window that covers it) is displaced.
        let mut i = 0u32;
        while t.get(&key_a).is_some() {
            i += 1;
            assert!(i < 100_000, "flow A was never evicted");
            t.update(&pkt(1000 + i, 2, 10.0 + i as f64, 64));
        }
        assert!(t.stats_snapshot().evictions > 0);
        let u = t.update(&flow_a(1e9)).unwrap();
        assert!(u.is_new, "a returning evicted flow restarts as new");
        assert_eq!(u.pkts, 1, "its statistics restart from zero");
    }

    #[test]
    fn aging_sweep_removes_idle_flows() {
        let mut t = FlowTable::with_policy(16, EvictPolicy::Age { max_idle_ns: 1000.0 });
        t.update(&pkt(1, 1, 0.0, 64));
        let (key_a, _) = FlowKey::from_packet(&pkt(1, 1, 0.0, 0));
        let (key_b, _) = FlowKey::from_packet(&pkt(2, 2, 0.0, 0));
        // Keep flow B hot past a sweep boundary; A sits idle at ts 0.
        for i in 0..(SWEEP_INTERVAL + 2) {
            t.update(&pkt(2, 2, 5000.0 + i as f64, 64));
        }
        assert!(t.get(&key_a).is_none(), "idle flow A must age out");
        assert!(t.get(&key_b).is_some(), "hot flow B must survive");
        let st = t.stats_snapshot();
        assert!(st.aged_out >= 1);
        assert_eq!(t.len(), t.iter().count());
    }

    /// Backward-shift deletion keeps probe chains intact: every survivor
    /// of a sweep is still reachable through the bounded `get`.
    #[test]
    fn aging_preserves_survivor_reachability() {
        let mut t = FlowTable::with_policy(64, EvictPolicy::Age { max_idle_ns: 500.0 });
        // 60 idle flows interleaved with 60 hot ones in one table, so
        // sweeps punch holes inside real probe chains.
        for i in 0..60u32 {
            t.update(&pkt(10_000 + i, 3, 0.0, 64));
        }
        let hot: Vec<FlowKey> = (0..60u32)
            .map(|i| FlowKey::from_packet(&pkt(20_000 + i, 4, 0.0, 0)).0)
            .collect();
        for round in 0..((SWEEP_INTERVAL / 60) + 2) {
            for i in 0..60u32 {
                t.update(&pkt(20_000 + i, 4, 2000.0 + round as f64, 64));
            }
        }
        assert!(t.stats_snapshot().aged_out > 0);
        for k in &hot {
            assert!(t.get(k).is_some(), "hot flow lost after sweep");
        }
        assert_eq!(t.len(), t.iter().count());
    }

    #[test]
    fn both_directions_hit_one_shard() {
        for n_shards in [1usize, 2, 3, 8] {
            for i in 0..200u32 {
                let a = pkt(i, 1000 + i as u16, 0.0, 64);
                let mut b = a;
                std::mem::swap(&mut b.src_ip, &mut b.dst_ip);
                std::mem::swap(&mut b.src_port, &mut b.dst_port);
                assert_eq!(
                    ShardedFlowTable::shard_of(&a, n_shards),
                    ShardedFlowTable::shard_of(&b, n_shards),
                );
                assert!(ShardedFlowTable::shard_of(&a, n_shards) < n_shards);
            }
        }
    }

    /// Satellite agreement test: the sharded table (one canonicalization
    /// per packet, key passed down via `update_keyed`) and the flat
    /// table must agree on every update for the same packet stream —
    /// including reverse-direction packets, where a canonicalization bug
    /// would split one flow in two.
    #[test]
    fn sharded_table_matches_flat_table() {
        let mut flat = FlowTable::new(4096);
        let mut sharded = ShardedFlowTable::new(4, 1024);
        for i in 0..2000u32 {
            let mut p = pkt(i % 300, (i % 300) as u16, i as f64, 64);
            if i % 2 == 1 {
                std::mem::swap(&mut p.src_ip, &mut p.dst_ip);
                std::mem::swap(&mut p.src_port, &mut p.dst_port);
            }
            let uf = flat.update(&p).unwrap();
            let (flat_new, flat_pkts) = (uf.is_new, uf.pkts);
            let us = sharded.update(&p).unwrap();
            assert_eq!(flat_new, us.is_new, "pkt {i}");
            assert_eq!(flat_pkts, us.pkts, "pkt {i}");
        }
        assert_eq!(flat.len(), sharded.len());
        assert_eq!(sharded.iter().count(), flat.len());
        // Per-flow stats agree through either access path.
        let (key, _) = FlowKey::from_packet(&pkt(7, 7, 0.0, 0));
        assert_eq!(flat.get(&key).unwrap().pkts, sharded.get(&key).unwrap().pkts);
    }

    #[test]
    fn update_keyed_matches_update() {
        let mut a = FlowTable::new(256);
        let mut b = FlowTable::new(256);
        for i in 0..400u32 {
            let p = pkt(i % 50, 9, i as f64, 64);
            let (key, fwd) = FlowKey::from_packet(&p);
            let ua = a.update(&p).unwrap();
            let (na, ca) = (ua.is_new, ua.pkts);
            let ub = b.update_keyed(key, fwd, &p).unwrap();
            assert_eq!(na, ub.is_new);
            assert_eq!(ca, ub.pkts);
        }
        assert_eq!(a.len(), b.len());
        assert_eq!(a.stats_snapshot(), b.stats_snapshot());
    }

    #[test]
    fn shards_partition_without_loss() {
        let mut sharded = ShardedFlowTable::new(3, 1024);
        for i in 0..500u32 {
            sharded.update(&pkt(i, 9, i as f64, 64));
        }
        assert_eq!(sharded.len(), 500);
        let shards = sharded.into_shards();
        assert_eq!(shards.len(), 3);
        let total: usize = shards.iter().map(FlowTable::len).sum();
        assert_eq!(total, 500);
        // The hash actually spreads flows over the partitions.
        assert!(shards.iter().filter(|s| !s.is_empty()).count() >= 2);
    }

    #[test]
    fn total_capacity_splits_across_shards() {
        let st = ShardedFlowTable::with_total_capacity(64, 1 << 16, EvictPolicy::Lru);
        assert_eq!(st.n_shards(), 64);
        // 65536 / 64 = 1024 per shard → 2048 slots each → 131072 total,
        // the same slot count the old single table allocated.
        assert_eq!(st.stats_snapshot().slots, 131_072);
    }

    #[test]
    fn flow_table_stats_merge_is_keywise() {
        let mut a = FlowTableStats {
            evictions: 1,
            aged_out: 2,
            collision_probes: 3,
            untracked: 4,
            occupied: 10,
            slots: 32,
            ..Default::default()
        };
        a.probe_hist[0] = 5;
        let mut b = FlowTableStats {
            evictions: 10,
            occupied: 6,
            slots: 32,
            ..Default::default()
        };
        b.probe_hist[0] = 1;
        b.probe_hist[PROBE_WINDOW] = 7;
        a.merge(&b);
        assert_eq!(a.evictions, 11);
        assert_eq!(a.aged_out, 2);
        assert_eq!(a.collision_probes, 3);
        assert_eq!(a.untracked, 4);
        assert_eq!(a.probe_hist[0], 6);
        assert_eq!(a.probe_hist[PROBE_WINDOW], 7);
        assert_eq!(a.occupied, 16);
        assert_eq!(a.slots, 64);
        assert!((a.load_factor() - 0.25).abs() < 1e-12);
    }
}

//! Latency/throughput metrics: fixed-bucket log histogram + summaries.
//!
//! Used by every experiment driver to report the paper's metrics
//! (median / 95th-percentile latency, sustained throughput).

/// Log-bucketed latency histogram (ns), 1ns .. ~17min range.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// Buckets at sub-decade resolution: 10^(k/8) ns.
    counts: Vec<u64>,
    total: u64,
    sum_ns: f64,
    max_ns: f64,
}

const BUCKETS: usize = 8 * 13; // 13 decades × 8 buckets

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            counts: vec![0; BUCKETS],
            total: 0,
            sum_ns: 0.0,
            max_ns: 0.0,
        }
    }

    fn bucket(ns: f64) -> usize {
        if ns <= 1.0 {
            return 0;
        }
        let b = (ns.log10() * 8.0) as usize;
        b.min(BUCKETS - 1)
    }

    pub fn record(&mut self, ns: f64) {
        self.counts[Self::bucket(ns)] += 1;
        self.total += 1;
        self.sum_ns += ns;
        if ns > self.max_ns {
            self.max_ns = ns;
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_ns(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_ns / self.total as f64
        }
    }

    pub fn max_ns(&self) -> f64 {
        self.max_ns
    }

    /// Percentile (0..=100) via bucket midpoint interpolation.
    pub fn percentile_ns(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * self.total as f64).ceil() as u64;
        let mut seen = 0;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                // geometric midpoint of bucket b
                return 10f64.powf((b as f64 + 0.5) / 8.0);
            }
        }
        self.max_ns
    }

    pub fn p50_us(&self) -> f64 {
        self.percentile_ns(50.0) / 1000.0
    }

    pub fn p95_us(&self) -> f64 {
        self.percentile_ns(95.0) / 1000.0
    }

    pub fn p99_us(&self) -> f64 {
        self.percentile_ns(99.0) / 1000.0
    }

    pub fn p999_us(&self) -> f64 {
        self.percentile_ns(99.9) / 1000.0
    }

    pub fn merge(&mut self, other: &Self) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

/// Simple throughput meter over a simulated time window.
#[derive(Debug, Clone, Copy, Default)]
pub struct Throughput {
    pub events: u64,
    pub window_ns: f64,
}

impl Throughput {
    pub fn per_second(&self) -> f64 {
        if self.window_ns <= 0.0 {
            0.0
        } else {
            self.events as f64 * 1e9 / self.window_ns
        }
    }

    pub fn mpps(&self) -> f64 {
        self.per_second() / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered_and_plausible() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(i as f64 * 100.0); // 100ns..100µs uniform
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.percentile_ns(50.0);
        let p95 = h.percentile_ns(95.0);
        let p99 = h.percentile_ns(99.0);
        assert!(p50 < p95 && p95 <= p99);
        // ~50µs and ~95µs within bucket resolution (×10^(1/8) ≈ ±33%)
        assert!((35_000.0..70_000.0).contains(&p50), "p50={p50}");
        assert!((70_000.0..140_000.0).contains(&p95), "p95={p95}");
    }

    #[test]
    fn empty_histogram_reports_zeros_everywhere() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_ns(), 0.0);
        assert_eq!(h.max_ns(), 0.0);
        for p in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(h.percentile_ns(p), 0.0, "p{p} of empty");
        }
        assert_eq!(h.p50_us(), 0.0);
        assert_eq!(h.p95_us(), 0.0);
    }

    #[test]
    fn single_sample_pins_every_percentile_to_its_bucket() {
        let mut h = LatencyHistogram::new();
        h.record(5_000.0); // 5 µs
        assert_eq!(h.count(), 1);
        assert_eq!(h.mean_ns(), 5_000.0);
        assert_eq!(h.max_ns(), 5_000.0);
        // Every percentile — including the degenerate p=0, whose target
        // is clamped to the first sample — lands on the one occupied
        // bucket's geometric midpoint, within bucket resolution
        // (×10^(1/16) ≈ ±15% around the sample).
        let p50 = h.percentile_ns(50.0);
        for p in [0.0, 50.0, 95.0, 100.0] {
            assert_eq!(h.percentile_ns(p), p50, "p{p} of single sample");
        }
        assert!(
            (p50 - 5_000.0).abs() / 5_000.0 < 0.16,
            "midpoint {p50} too far from the 5µs sample"
        );
    }

    #[test]
    fn sub_nanosecond_samples_clamp_into_the_first_bucket() {
        let mut h = LatencyHistogram::new();
        h.record(0.0);
        h.record(0.5);
        h.record(1.0);
        assert_eq!(h.count(), 3);
        // All three land in bucket 0; the percentile is its midpoint.
        let p = h.percentile_ns(99.0);
        assert_eq!(p, h.percentile_ns(1.0));
        assert!(p >= 1.0 && p < 2.0, "bucket-0 midpoint, got {p}");
    }

    #[test]
    fn saturating_sample_clamps_into_the_last_bucket() {
        let mut h = LatencyHistogram::new();
        // Far beyond the ~17min top of the 13-decade range.
        h.record(1e30);
        // Exact counters are unaffected by the clamp…
        assert_eq!(h.max_ns(), 1e30);
        assert_eq!(h.mean_ns(), 1e30);
        // …while percentiles saturate at the last bucket's midpoint
        // (10^((BUCKETS-0.5)/8)) instead of overflowing or panicking.
        let top = 10f64.powf((BUCKETS as f64 - 0.5) / 8.0);
        assert_eq!(h.percentile_ns(50.0), top);
        assert_eq!(h.percentile_ns(100.0), top);
        // A second out-of-range sample shares the bucket (no growth).
        h.record(1e25);
        assert_eq!(h.count(), 2);
        assert_eq!(h.percentile_ns(100.0), top);
    }

    #[test]
    fn merge_into_empty_and_from_empty_are_identities() {
        let mut filled = LatencyHistogram::new();
        for i in 1..=100u64 {
            filled.record(i as f64 * 50.0);
        }
        let p95_before = filled.percentile_ns(95.0);
        // Merging an empty histogram changes nothing.
        filled.merge(&LatencyHistogram::new());
        assert_eq!(filled.count(), 100);
        assert_eq!(filled.percentile_ns(95.0), p95_before);
        // Merging into an empty one reproduces the source exactly.
        let mut empty = LatencyHistogram::new();
        empty.merge(&filled);
        assert_eq!(empty.count(), filled.count());
        assert_eq!(empty.mean_ns(), filled.mean_ns());
        assert_eq!(empty.max_ns(), filled.max_ns());
        assert_eq!(empty.percentile_ns(95.0), p95_before);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(10.0);
        b.record(1000.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!(a.max_ns() == 1000.0);
    }

    #[test]
    fn throughput_math() {
        let t = Throughput {
            events: 1_800_000,
            window_ns: 1e9,
        };
        assert!((t.per_second() - 1.8e6).abs() < 1.0);
        assert!((t.mpps() - 1.8).abs() < 1e-9);
    }
}

//! `traffic` — §5 use case 1 (traffic analysis): classify flows from
//! the 256-bit packed flow-statistics vector, triggered once a flow has
//! accumulated enough packets.  This wraps the serving path the crate
//! has exercised since PR 1, but now with a ground-truth oracle: the
//! generator's protocol mix is the label (TCP/443 service traffic vs
//! UDP/53), the model is a nearest-centroid BNN calibrated on the
//! replayed trigger-point features, and the score checks the live
//! service reproduces the offline replay flow-for-flow.

use crate::coordinator::{PacketEvent, TriggerCondition};
use crate::net::features::INPUT_BITS;
use crate::net::packet::{Packet, Proto};
use crate::net::traffic::{CbrSpec, TrafficGen};

use super::{
    centroid_model, oracle_from_firings, replay_trigger_inputs, Prepared, Scenario,
    ScenarioConfig, UseCaseModel,
};

/// §5 use case 1: per-flow traffic analysis.
pub struct TrafficScenario;

const MODELS: &[UseCaseModel] = &[UseCaseModel {
    name: "traffic",
    in_bits: INPUT_BITS,
    arch: &[32, 16, 2],
}];

/// Class 1 = TCP service traffic, class 0 = UDP (the generator's mix).
fn label(p: &Packet) -> usize {
    usize::from(p.proto == Proto::Tcp)
}

impl Scenario for TrafficScenario {
    fn name(&self) -> &'static str {
        "traffic"
    }

    fn about(&self) -> &'static str {
        "traffic analysis: protocol class from 256-bit flow statistics (§5 use case 1)"
    }

    fn use_case_models(&self) -> &'static [UseCaseModel] {
        MODELS
    }

    fn default_events(&self) -> u64 {
        20_000
    }

    fn accuracy_floor(&self) -> f64 {
        0.9
    }

    fn prepare(&self, cfg: &ScenarioConfig) -> Prepared {
        let n = if cfg.events == 0 { self.default_events() } else { cfg.events } as usize;
        let spec = CbrSpec { gbps: 40.0, pkt_size: 256 };
        let mut gen = TrafficGen::new(spec, cfg.flows.max(1), cfg.seed);
        let events: Vec<PacketEvent> = (0..n)
            .map(|_| PacketEvent { packet: gen.next_packet(), payload_words: None })
            .collect();
        let trigger = TriggerCondition::EveryNPackets(cfg.trigger_pkts.max(1));
        let firings = replay_trigger_inputs(&events, trigger);
        let mut class0 = Vec::new();
        let mut class1 = Vec::new();
        for (_, packed, pkt) in &firings {
            if label(pkt) == 1 {
                class1.push(packed.clone());
            } else {
                class0.push(packed.clone());
            }
        }
        let model = centroid_model("traffic", INPUT_BITS, &class0, &class1);
        let oracle = oracle_from_firings(&firings, &model, label);
        Prepared { events, trigger, model, oracle, learn: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepared_oracle_separates_the_protocol_mix() {
        let cfg = ScenarioConfig::default();
        let p = TrafficScenario.prepare(&cfg);
        assert_eq!(p.model.in_bits, INPUT_BITS);
        assert_eq!(p.model.out_neurons(), 2);
        p.model.validate().unwrap();
        assert!(!p.oracle.expected.is_empty());
        assert_eq!(p.oracle.expected.len(), p.oracle.labels.len());
        // Both classes occur in the seeded mix (flow % 4 split).
        let ones: usize = p.oracle.labels.values().sum();
        assert!(ones > 0 && ones < p.oracle.labels.len());
        // The calibrated centroid model must separate its own
        // calibration transcript at least to the scenario floor —
        // otherwise the end-to-end floor could never pass.
        let agree = p
            .oracle
            .expected
            .iter()
            .filter(|&(id, class)| p.oracle.labels.get(id) == Some(class))
            .count();
        let acc = agree as f64 / p.oracle.expected.len() as f64;
        assert!(acc >= TrafficScenario.accuracy_floor(), "calibration acc {acc}");
    }

    #[test]
    fn prepare_is_deterministic() {
        let cfg = ScenarioConfig::default();
        let a = TrafficScenario.prepare(&cfg);
        let b = TrafficScenario.prepare(&cfg);
        assert_eq!(a.oracle.expected, b.oracle.expected);
        assert_eq!(a.model.layers[0].words, b.model.layers[0].words);
        assert_eq!(a.events.len(), b.events.len());
    }
}

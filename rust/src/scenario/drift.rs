//! `drift` — the online-learning use case: the §5 anomaly-detection
//! setting under concept drift.  Two-fifths of the way through the run
//! the attack recipe changes shape ([`DriftMixGen`]): the new attackers
//! mimic benign packet sizes, flags, and pacing, so the seed model —
//! calibrated on the pre-shift transcript only — reads them as benign
//! and windowed accuracy collapses.  The served run recovers only if
//! the online-learning loop (Page–Hinkley drift detection → in-process
//! refit → gated republish) actually works; the accuracy floor is the
//! pass/fail line for that whole loop, not just for the model.
//!
//! The oracle is built by **offline replay of the learning loop
//! itself**: the same serve-then-learn-then-commit order per packet the
//! serial runtime uses, against a private registry.  The pipelined
//! runtime's publish barrier guarantees the same verdict set, so
//! `agreement` stays 1.0 across serial/pipelined/batched runs and the
//! verdict digest is the determinism contract for live republishes.

use std::sync::Arc;

use crate::bnn::{BnnModel, MultiModelExecutor, RegistryHandle};
use crate::coordinator::service::{flow_id, select_packed_input, RouteLogic};
use crate::coordinator::{ModelRouter, PacketEvent, TriggerCondition};
use crate::fpga::FpgaTiming;
use crate::learn::{GateMode, LearnSpec, OnlineLearner};
use crate::net::features::INPUT_BITS;
use crate::net::flow::{ShardedFlowTable, FLOW_SHARDS};
use crate::net::packet::Packet;
use crate::net::traffic::{CbrSpec, ChurnSpec, DriftMixGen, DriftSpec};

use super::{
    centroid_model, replay_trigger_inputs, Oracle, Prepared, Scenario, ScenarioConfig,
    UseCaseModel,
};

/// Online-learning use case: anomaly detection under concept drift.
pub struct DriftScenario;

const MODELS: &[UseCaseModel] = &[UseCaseModel {
    name: "drift",
    in_bits: INPUT_BITS,
    // Nearest-centroid refits stay single-layer; the registry's shape
    // check only pins (in_words, out_neurons), so retrained candidates
    // republish over this slot.
    arch: &[2],
}];

/// Class 1 = attack flow (either recipe phase), class 0 = benign.
fn label(p: &Packet) -> usize {
    usize::from(DriftMixGen::is_attack(p))
}

impl Scenario for DriftScenario {
    fn name(&self) -> &'static str {
        "drift"
    }

    fn about(&self) -> &'static str {
        "online learning: attack recipe shifts mid-run; drift detection + retrain must recover"
    }

    fn use_case_models(&self) -> &'static [UseCaseModel] {
        MODELS
    }

    fn default_events(&self) -> u64 {
        16_000
    }

    fn accuracy_floor(&self) -> f64 {
        // Without retraining, every post-shift attacker scores benign and
        // whole-run accuracy lands near 0.75 — the floor is only
        // clearable when the loop promotes a corrected model.
        0.80
    }

    fn prepare(&self, cfg: &ScenarioConfig) -> Prepared {
        let n = if cfg.events == 0 { self.default_events() } else { cfg.events } as usize;
        let trigger_pkts = cfg.trigger_pkts.max(1);
        let shift_at = n as u64 * 2 / 5;
        let spec = DriftSpec {
            churn: ChurnSpec {
                cbr: CbrSpec { gbps: 40.0, pkt_size: 256 },
                working_set: cfg.flows.max(1),
                churn_frac: 0.2,
                alpha: 1.2,
                min_pkts: 2,
                max_pkts: 10_000,
            },
            attack_frac: 0.3,
            attack_pkts: trigger_pkts * 4,
            shift_at,
            pool: 16,
        };
        let mut gen = DriftMixGen::new(spec, cfg.seed);
        let events: Vec<PacketEvent> = (0..n)
            .map(|_| PacketEvent { packet: gen.next_packet(), payload_words: None })
            .collect();
        let trigger = TriggerCondition::EveryNPackets(trigger_pkts);
        // The seed model only ever sees the pre-shift prefix — exactly
        // the "trained offline, then the world moved" situation §5's
        // monitoring models live in.
        let pre = &events[..(shift_at as usize).min(events.len())];
        let firings = replay_trigger_inputs(pre, trigger);
        let mut class0 = Vec::new();
        let mut class1 = Vec::new();
        for (_, packed, pkt) in &firings {
            if label(pkt) == 1 {
                class1.push(packed.clone());
            } else {
                class0.push(packed.clone());
            }
        }
        let model = centroid_model("drift", INPUT_BITS, &class0, &class1);
        let learn = learn_spec(cfg, n as u64);
        let oracle = oracle_by_learner_replay(&events, trigger, &model, &learn, cfg);
        Prepared { events, trigger, model, oracle, learn: Some(learn) }
    }
}

/// The learning-loop knobs for one drift run, scaled to the event
/// count: ~40 accuracy windows per run regardless of size, so the
/// Page–Hinkley baseline settles pre-shift and the dip spans several
/// windows post-shift.
fn learn_spec(cfg: &ScenarioConfig, n: u64) -> LearnSpec {
    let mut s = LearnSpec::new(
        "drift",
        Arc::new(|p: &Packet| usize::from(DriftMixGen::is_attack(p))),
    );
    s.window_pkts = (n / 40).max(200);
    s.reservoir = 256;
    s.holdout = 16;
    s.train_recent = 64;
    s.probation_windows = 2;
    s.seed = cfg.seed;
    s.mode = cfg.gate.unwrap_or(GateMode::Normal);
    s
}

/// Offline replay of the full learning loop, producing the oracle the
/// live run is scored against.  Per packet this is exactly the serial
/// runtime's order: classify under the registry's *current* epoch, then
/// feed the learner, then commit any staged publish/rollback — so the
/// committing packet scores under the old weights, the next under the
/// new, in replay and in both live runtimes (the pipelined barrier
/// enforces the same boundary).  Gate fault-injection modes propagate
/// here too: a sabotaged oracle expects no recovery, keeping
/// `agreement` at 1.0 while the accuracy floor legitimately fails.
fn oracle_by_learner_replay(
    events: &[PacketEvent],
    trigger: TriggerCondition,
    seed_model: &BnnModel,
    spec: &LearnSpec,
    cfg: &ScenarioConfig,
) -> Oracle {
    let registry = RegistryHandle::new();
    registry
        .publish(&seed_model.name, seed_model)
        .expect("oracle replay publish");
    let latency_ns = FpgaTiming::new(seed_model).latency_ns();
    let route = RouteLogic::Router(ModelRouter::rules(vec![(trigger, seed_model.name.clone())]));
    let mut exec = MultiModelExecutor::new(&registry, &[seed_model.name.clone()], latency_ns)
        .expect("oracle replay executor");
    let mut learner = OnlineLearner::new(
        spec.clone(),
        registry.clone(),
        route.clone(),
        latency_ns,
        cfg.flow_capacity,
        cfg.evict,
    )
    .expect("oracle replay learner");
    let mut flows = ShardedFlowTable::with_total_capacity(FLOW_SHARDS, cfg.flow_capacity, cfg.evict);
    let mut oracle = Oracle::default();
    for ev in events {
        if let Some(up) = flows.update(&ev.packet) {
            if route.route(&ev.packet, up.is_new, up.pkts) == Some(0) {
                let packed = select_packed_input(ev, up.stats);
                let (class, _tag) = exec.classify(0, &packed);
                let id = flow_id(&ev.packet);
                let e = oracle.expected.entry(id).or_insert(class);
                if class > *e {
                    *e = class;
                }
                oracle.labels.insert(id, label(&ev.packet));
            }
        }
        if learner.on_packet(ev) {
            learner.commit_pending().expect("oracle replay commit");
        }
    }
    oracle
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::BnnExecutor;

    fn oracle_accuracy(o: &Oracle) -> f64 {
        let agree = o
            .expected
            .iter()
            .filter(|&(id, class)| o.labels.get(id) == Some(class))
            .count();
        agree as f64 / o.expected.len() as f64
    }

    #[test]
    fn seed_model_misses_the_shifted_attackers() {
        let cfg = ScenarioConfig::default();
        let p = DriftScenario.prepare(&cfg);
        p.model.validate().unwrap();
        let firings = replay_trigger_inputs(&p.events, p.trigger);
        let mut exec = BnnExecutor::new(p.model.clone());
        let (mut p1_hit, mut p1_n) = (0usize, 0usize);
        let (mut p2_hit, mut p2_n) = (0usize, 0usize);
        let (mut b_hit, mut b_n) = (0usize, 0usize);
        for (_, packed, pkt) in &firings {
            let class = exec.classify(packed);
            if DriftMixGen::is_shifted_attack(pkt) {
                p2_n += 1;
                p2_hit += usize::from(class == 1);
            } else if DriftMixGen::is_attack(pkt) {
                p1_n += 1;
                p1_hit += usize::from(class == 1);
            } else {
                b_n += 1;
                b_hit += usize::from(class == 0);
            }
        }
        assert!(p1_n > 10 && p2_n > 10 && b_n > 10, "{p1_n}/{p2_n}/{b_n}");
        let rate = |hit: usize, n: usize| hit as f64 / n as f64;
        assert!(
            rate(p1_hit, p1_n) >= 0.8,
            "seed model must catch the recipe it was calibrated on: {}",
            rate(p1_hit, p1_n)
        );
        assert!(
            rate(b_hit, b_n) >= 0.8,
            "seed model must pass benign traffic: {}",
            rate(b_hit, b_n)
        );
        assert!(
            rate(p2_hit, p2_n) < 0.5,
            "the shifted recipe must evade the seed model: {}",
            rate(p2_hit, p2_n)
        );
    }

    #[test]
    fn oracle_recovers_above_the_floor_only_through_learning() {
        let cfg = ScenarioConfig::default();
        let p = DriftScenario.prepare(&cfg);
        assert!(p.learn.is_some(), "drift must carry a learn spec");
        let acc = oracle_accuracy(&p.oracle);
        assert!(
            acc >= DriftScenario.accuracy_floor(),
            "learner-replay oracle must clear the floor: {acc}"
        );
        // Static baseline: the same firings scored by the frozen seed
        // model never recover from the shift.
        let firings = replay_trigger_inputs(&p.events, p.trigger);
        let frozen = super::super::oracle_from_firings(&firings, &p.model, label);
        let frozen_acc = oracle_accuracy(&frozen);
        assert!(
            frozen_acc < acc,
            "learning must beat the frozen model: {frozen_acc} vs {acc}"
        );
    }

    #[test]
    fn prepare_is_deterministic() {
        let cfg = ScenarioConfig { seed: 11, ..ScenarioConfig::default() };
        let a = DriftScenario.prepare(&cfg);
        let b = DriftScenario.prepare(&cfg);
        assert_eq!(a.oracle.expected, b.oracle.expected);
        assert_eq!(a.oracle.labels, b.oracle.labels);
        assert_eq!(a.model.layers[0].words, b.model.layers[0].words);
    }

    #[test]
    fn sabotaged_oracle_expects_no_recovery() {
        let cfg = ScenarioConfig {
            gate: Some(GateMode::SabotageCandidate),
            ..ScenarioConfig::default()
        };
        let sab = DriftScenario.prepare(&cfg);
        let normal = DriftScenario.prepare(&ScenarioConfig::default());
        // Same traffic, but the sabotaged loop never promotes: its
        // oracle keeps the seed model's post-shift misses.
        assert!(oracle_accuracy(&sab.oracle) < oracle_accuracy(&normal.oracle));
        assert!(oracle_accuracy(&sab.oracle) < DriftScenario.accuracy_floor());
    }
}

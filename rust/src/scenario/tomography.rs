//! `tomography` — §5 use case 3 (modified SIMON): bridge fat-tree probe
//! rounds into the serving plane as a packet-clocked event stream.  Each
//! probe round becomes one synthetic "flow" whose payload carries the
//! thermometer-encoded probe delays (19 × 8 unary bits = 152), fired at
//! the service through the `NewFlow` trigger; the congestion verdict per
//! round is scored against the simulator's ground-truth backlog for the
//! monitored queue.  The scenario also checks the Fig. 15 real-time
//! budget: the backend's per-NN latency × NNs-per-NIC against the probe
//! period at each link speed.

use crate::bnn::BnnExecutor;
use crate::coordinator::service::flow_id;
use crate::coordinator::{Capabilities, PacketEvent, TriggerCondition};
use crate::fattree::{
    FatTreeSim, IncastWorkload, ProbeCollector, SimConfig, Topology, N_MONITORED_QUEUES,
    THERMO_LEVELS,
};
use crate::net::packet::{Packet, Proto};
use crate::tomography::{
    meets_deadline, PROBE_PERIOD_100G_NS, PROBE_PERIOD_400G_NS, PROBE_PERIOD_40G_NS,
};

use super::{
    centroid_model, DeadlineCheck, Oracle, Prepared, Scenario, ScenarioConfig, UseCaseModel,
};

/// §5 use case 3: network tomography over probe delays.
pub struct TomographyScenario;

/// 19 probe paths × 8 thermometer levels.
const TOMO_BITS: usize = 19 * THERMO_LEVELS;

const MODELS: &[UseCaseModel] = &[
    UseCaseModel { name: "tomography_32", in_bits: 152, arch: &[32, 16, 2] },
    UseCaseModel { name: "tomography_64", in_bits: 152, arch: &[64, 32, 2] },
    UseCaseModel { name: "tomography_128", in_bits: 152, arch: &[128, 64, 2] },
];

impl Scenario for TomographyScenario {
    fn name(&self) -> &'static str {
        "tomography"
    }

    fn about(&self) -> &'static str {
        "network tomography: congestion verdicts from probe delays (§5 use case 3)"
    }

    fn use_case_models(&self) -> &'static [UseCaseModel] {
        MODELS
    }

    /// Total probe rounds; the first half calibrates, the second serves.
    fn default_events(&self) -> u64 {
        240
    }

    fn accuracy_floor(&self) -> f64 {
        0.6
    }

    fn prepare(&self, cfg: &ScenarioConfig) -> Prepared {
        let rounds = if cfg.events == 0 { self.default_events() } else { cfg.events } as usize;
        let topo = Topology::new();
        let sim_cfg = SimConfig { probe_interval_ns: 1e6, load: 1.1, ..SimConfig::default() };
        let mut wl = IncastWorkload::new(&topo, &sim_cfg);
        let mut sim = FatTreeSim::new(topo.clone(), sim_cfg, cfg.seed);
        let data = sim.run(rounds, &mut wl);
        let half = data.len() / 2;
        let collector = ProbeCollector::fit(&data[..half], 0.25);

        // Calibrate a nearest-centroid BNN on the first half: thermometer
        // packing makes Hamming distance the L1 delay distance, so the
        // centroid model is a genuine minimum-distance congestion test.
        let mut class0 = Vec::new();
        let mut class1 = Vec::new();
        for r in &data[..half] {
            let s = collector.thermo_sample(r);
            if s.congested[0] {
                class1.push(s.packed);
            } else {
                class0.push(s.packed);
            }
        }
        let model = centroid_model("tomography", TOMO_BITS, &class0, &class1);

        // Serve the second half: one synthetic flow per probe round,
        // payload = packed thermometer sample, label = sim ground truth.
        let mut exec = BnnExecutor::new(model.clone());
        let mut oracle = Oracle::default();
        let mut events = Vec::with_capacity(data.len() - half);
        for (i, r) in data[half..].iter().enumerate() {
            let s = collector.thermo_sample(r);
            let packet = Packet {
                ts_ns: r.t_ns,
                src_ip: 0x0A00_0000 | (i as u32 & 0x00FF_FFFF),
                dst_ip: 0x0B00_0000,
                src_port: 7777,
                dst_port: 7777,
                proto: Proto::Udp,
                size: 64,
                tcp_flags: 0,
            };
            let id = flow_id(&packet);
            oracle.labels.insert(id, usize::from(s.congested[0]));
            oracle.expected.insert(id, exec.classify(&s.packed));
            events.push(PacketEvent { packet, payload_words: Some(s.packed) });
        }
        Prepared { events, trigger: TriggerCondition::NewFlow, model, oracle, learn: None }
    }

    fn deadlines(&self, caps: &Capabilities) -> Vec<DeadlineCheck> {
        let nns = N_MONITORED_QUEUES;
        [
            ("40G", PROBE_PERIOD_40G_NS),
            ("100G", PROBE_PERIOD_100G_NS),
            ("400G", PROBE_PERIOD_400G_NS),
        ]
        .into_iter()
        .map(|(link, period_ns)| DeadlineCheck {
            link,
            period_ns,
            nns,
            ok: meets_deadline(caps.inference_ns, nns, period_ns),
        })
        .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_rounds_become_one_flow_each() {
        let cfg = ScenarioConfig { events: 160, ..ScenarioConfig::default() };
        let p = TomographyScenario.prepare(&cfg);
        assert_eq!(p.events.len(), 80, "second half of rounds serves");
        assert_eq!(p.oracle.labels.len(), 80, "every round gets a label");
        assert_eq!(p.trigger, TriggerCondition::NewFlow);
        p.model.validate().unwrap();
        assert_eq!(p.model.in_bits, TOMO_BITS);
        // Payload is pre-packed to the model's input width.
        for ev in &p.events {
            assert_eq!(ev.payload_words.as_ref().unwrap().len(), p.model.in_words());
        }
        // Both congestion classes occur under incast overload.
        let ones: usize = p.oracle.labels.values().sum();
        assert!(ones > 0 && ones < p.oracle.labels.len(), "ones={ones}");
        // The calibrated centroid clears the scenario floor on the
        // held-out serving half.
        let agree = p
            .oracle
            .expected
            .iter()
            .filter(|&(id, class)| p.oracle.labels.get(id) == Some(class))
            .count();
        let acc = agree as f64 / p.oracle.expected.len() as f64;
        assert!(acc >= TomographyScenario.accuracy_floor(), "held-out acc {acc}");
    }

    #[test]
    fn prepare_is_deterministic() {
        let cfg = ScenarioConfig { events: 120, seed: 3, ..ScenarioConfig::default() };
        let a = TomographyScenario.prepare(&cfg);
        let b = TomographyScenario.prepare(&cfg);
        assert_eq!(a.oracle.expected, b.oracle.expected);
        assert_eq!(a.model.layers[0].words, b.model.layers[0].words);
    }

    #[test]
    fn deadline_checks_cover_all_three_link_speeds() {
        let caps = Capabilities {
            backend: "fpga",
            max_batch: 1,
            shards: 1,
            routes: 1,
            supports_hot_swap: false,
            supports_epoch_pinning: false,
            inference_ns: 1_700.0,
            simd_lanes: 1,
        };
        let d = TomographyScenario.deadlines(&caps);
        assert_eq!(d.len(), 3);
        assert!(d.iter().all(|c| c.nns == N_MONITORED_QUEUES));
        // 1.7 µs × 17 NNs ≈ 29 µs: fits 250/100 µs, misses 25 µs.
        assert!(d[0].ok && d[1].ok && !d[2].ok);
    }
}

//! `anomaly` — §5 use case 2 (anomaly detection): a seeded attack mix
//! layered over the adversarial churn workload, with every malicious
//! flow labeled at the generator.  Attack flows are short-packet TCP
//! SYN probes from a reserved source prefix
//! ([`AttackMixGen::is_attack`]); benign background is the same
//! heavy-tailed [`ChurnGen`](crate::net::traffic::ChurnGen) mix the
//! scale harness uses — so this scenario composes directly with
//! eviction pressure and admission shedding, and the score's
//! `coverage`/`agreement` quantify exactly what those degradations
//! cost in detections.

use crate::coordinator::{PacketEvent, TriggerCondition};
use crate::net::features::INPUT_BITS;
use crate::net::packet::Packet;
use crate::net::traffic::{AttackMixGen, AttackSpec, CbrSpec, ChurnSpec};

use super::{
    centroid_model, oracle_from_firings, replay_trigger_inputs, Prepared, Scenario,
    ScenarioConfig, UseCaseModel,
};

/// §5 use case 2: anomaly detection over a labeled attack mix.
pub struct AnomalyScenario;

const MODELS: &[UseCaseModel] = &[UseCaseModel {
    name: "anomaly",
    in_bits: INPUT_BITS,
    arch: &[32, 16, 2],
}];

/// Class 1 = attack flow (by generator label), class 0 = benign.
fn label(p: &Packet) -> usize {
    usize::from(AttackMixGen::is_attack(p))
}

impl Scenario for AnomalyScenario {
    fn name(&self) -> &'static str {
        "anomaly"
    }

    fn about(&self) -> &'static str {
        "anomaly detection: labeled attack mix over churning background (§5 use case 2)"
    }

    fn use_case_models(&self) -> &'static [UseCaseModel] {
        MODELS
    }

    fn default_events(&self) -> u64 {
        20_000
    }

    fn accuracy_floor(&self) -> f64 {
        0.85
    }

    fn prepare(&self, cfg: &ScenarioConfig) -> Prepared {
        let n = if cfg.events == 0 { self.default_events() } else { cfg.events } as usize;
        let trigger_pkts = cfg.trigger_pkts.max(1);
        let spec = AttackSpec {
            churn: ChurnSpec {
                cbr: CbrSpec { gbps: 40.0, pkt_size: 256 },
                working_set: cfg.flows.max(1),
                churn_frac: 0.2,
                alpha: 1.2,
                min_pkts: 2,
                max_pkts: 10_000,
            },
            attack_frac: 0.25,
            // Each attacker sends enough packets to clear the trigger.
            attack_pkts: trigger_pkts * 4,
        };
        let mut gen = AttackMixGen::new(spec, cfg.seed);
        let events: Vec<PacketEvent> = (0..n)
            .map(|_| PacketEvent { packet: gen.next_packet(), payload_words: None })
            .collect();
        let trigger = TriggerCondition::EveryNPackets(trigger_pkts);
        let firings = replay_trigger_inputs(&events, trigger);
        let mut class0 = Vec::new();
        let mut class1 = Vec::new();
        for (_, packed, pkt) in &firings {
            if label(pkt) == 1 {
                class1.push(packed.clone());
            } else {
                class0.push(packed.clone());
            }
        }
        let model = centroid_model("anomaly", INPUT_BITS, &class0, &class1);
        let oracle = oracle_from_firings(&firings, &model, label);
        Prepared { events, trigger, model, oracle, learn: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attack_mix_is_labeled_and_separable() {
        let cfg = ScenarioConfig::default();
        let p = AnomalyScenario.prepare(&cfg);
        p.model.validate().unwrap();
        let attacks: usize = p.oracle.labels.values().sum();
        let benign = p.oracle.labels.len() - attacks;
        assert!(attacks > 10, "attack flows must trigger ({attacks})");
        assert!(benign > 10, "benign flows must trigger ({benign})");
        // Detection accuracy of the calibrated model on its own
        // transcript clears the scenario floor with margin.
        let agree = p
            .oracle
            .expected
            .iter()
            .filter(|&(id, class)| p.oracle.labels.get(id) == Some(class))
            .count();
        let acc = agree as f64 / p.oracle.expected.len() as f64;
        assert!(acc >= AnomalyScenario.accuracy_floor(), "calibration acc {acc}");
    }

    #[test]
    fn prepare_is_deterministic() {
        let cfg = ScenarioConfig { seed: 11, ..ScenarioConfig::default() };
        let a = AnomalyScenario.prepare(&cfg);
        let b = AnomalyScenario.prepare(&cfg);
        assert_eq!(a.oracle.expected, b.oracle.expected);
        assert_eq!(a.model.layers[0].words, b.model.layers[0].words);
    }
}

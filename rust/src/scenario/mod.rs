//! Scenario subsystem: the paper's three monitoring use cases (§5) as
//! first-class seeded workloads served end-to-end through the one
//! [`ServeBuilder`] runtime — serial or pipelined, any backend.
//!
//! Each [`Scenario`] packages what §5 treats as one "use case":
//! * a **seeded event source** (traffic generator, attack mix, or probe
//!   rounds bridged from the fat-tree simulator),
//! * the **trigger + feature adapter** that turns events into packed
//!   BNN inputs inside the service,
//! * **model provisioning** — a hand-crafted nearest-centroid BNN
//!   calibrated on the same seeded transcript (see [`centroid_model`]),
//!   publishable into the [`ModelRegistry`](crate::bnn::ModelRegistry),
//! * a **ground-truth oracle** built by offline replay of the exact
//!   trigger semantics both runtimes share, and
//! * a typed [`ScenarioScore`] with an accuracy floor.
//!
//! The implementations are [`TrafficScenario`] (§5 use case 1,
//! per-flow traffic analysis), [`AnomalyScenario`] (§5 use case 2, a
//! labeled attack mix over churning background traffic),
//! [`TomographyScenario`] (§5 use case 3, SIMON-style congestion
//! inference from probe delays, with per-link-speed deadline checks),
//! and [`DriftScenario`] (the online-learning loop: the anomaly setting
//! under a mid-run concept shift, recoverable only by live retraining —
//! see [`crate::learn`]).  [`ScenarioRegistry`] is the single
//! authoritative list — the CLI, the experiments table, and CI all
//! consult it instead of hardcoding scenario or model names.
//!
//! Scoring semantics: the service's memory sink is reduced to one
//! verdict per flow (the *maximum* class over all emissions — "flagged
//! if ever flagged", an order-independent reduction, so serial and
//! pipelined runs score identically).  `coverage` is the fraction of
//! oracle-expected flows that got any verdict, `agreement` the fraction
//! of covered flows whose verdict matches the oracle's offline replay
//! (1.0 whenever nothing was evicted or shed), and `accuracy` the
//! fraction of scored *labeled* flows classified correctly.

pub mod anomaly;
pub mod drift;
pub mod tomography;
pub mod traffic;

pub use anomaly::AnomalyScenario;
pub use drift::DriftScenario;
pub use tomography::TomographyScenario;
pub use traffic::TrafficScenario;

use std::collections::{BTreeMap, HashMap};

use crate::bnn::{BnnExecutor, BnnModel, RegistryHandle};
use crate::coordinator::admin::AdminHandle;
use crate::coordinator::service::{flow_id, select_packed_input};
use crate::coordinator::{
    BackendFactory, Capabilities, ModelRouter, PacketEvent, ServeBuilder, ServiceReport,
    ShedPolicy, TriggerCondition,
};
use crate::fpga::FpgaTiming;
use crate::learn::{GateMode, LearnSpec};
use crate::net::flow::{EvictPolicy, FlowKey, FlowStats};
use crate::net::packet::Packet;

/// One named model artifact a scenario deploys (the Table 1 / Table 5
/// shapes).  The registry aggregates these — `experiments::tab01` and
/// the CLI's shape fallback read the aggregate instead of keeping their
/// own name lists.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UseCaseModel {
    pub name: &'static str,
    /// Logical input width in bits.
    pub in_bits: usize,
    /// Layer widths, e.g. `[32, 16, 2]`.
    pub arch: &'static [usize],
}

/// Knobs shared by every scenario run.  Defaults are the smoke-test
/// shape: small, seeded, serial, no eviction pressure.
#[derive(Clone)]
pub struct ScenarioConfig {
    /// Event count; `0` = the scenario's own default (packets for the
    /// flow-stats scenarios, probe rounds for tomography).
    pub events: u64,
    /// Concurrent flows (traffic) / churn working set (anomaly).
    pub flows: u64,
    /// Per-flow packet count that fires the trigger (flow-stats
    /// scenarios; tomography triggers on every new probe round).
    pub trigger_pkts: u32,
    pub seed: u64,
    /// Backend name for [`BackendFactory`]; `"registry"` publishes the
    /// scenario's model into a fresh registry and serves routed.
    pub backend: String,
    /// Parse workers; `0` = the serial loop.
    pub workers: usize,
    /// Batch lane size; `0` = inline classification.
    pub batch: usize,
    pub shards: usize,
    pub flow_capacity: usize,
    pub evict: EvictPolicy,
    pub shed: Option<ShedPolicy>,
    /// Live admin/introspection surface to attach, if any.
    pub admin: Option<AdminHandle>,
    /// Promotion-gate fault-injection mode for scenarios with a
    /// learning loop (`None` = the scenario's default, `Normal`).
    pub gate: Option<GateMode>,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        Self {
            events: 0,
            flows: 256,
            trigger_pkts: 5,
            seed: 7,
            backend: "fpga".into(),
            workers: 0,
            batch: 0,
            shards: 1,
            flow_capacity: 1 << 16,
            evict: EvictPolicy::Lru,
            shed: None,
            admin: None,
            gate: None,
        }
    }
}

/// What [`Scenario::prepare`] hands the driver: the full seeded event
/// stream, the trigger that gates inference, the provisioned model, and
/// the ground-truth oracle for scoring the run afterwards.
pub struct Prepared {
    pub events: Vec<PacketEvent>,
    pub trigger: TriggerCondition,
    pub model: BnnModel,
    pub oracle: Oracle,
    /// Online-learning loop to attach to the run, if the scenario has
    /// one (forces the registry serving path — retraining republishes).
    pub learn: Option<LearnSpec>,
}

/// Ground truth for one prepared run, keyed by the sink's flow id.
#[derive(Debug, Default, Clone)]
pub struct Oracle {
    /// Flow id → use-case label (attack/benign, congested/clear, …).
    pub labels: BTreeMap<u64, usize>,
    /// Flow id → the class the model emits at the trigger point,
    /// derived by offline replay of the trigger semantics.
    pub expected: BTreeMap<u64, usize>,
}

/// How a served run scored against its oracle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScenarioScore {
    /// Expected flows that received at least one verdict.
    pub coverage: f64,
    /// Covered flows whose verdict matches the offline replay — the
    /// serving-fidelity number (1.0 without eviction/shedding).
    pub agreement: f64,
    /// Scored labeled flows classified correctly — the use-case number.
    pub accuracy: f64,
    /// Labeled flows that were scored.
    pub scored: usize,
    /// Flows the oracle expected a verdict for.
    pub expected: usize,
}

/// One `meets_deadline` check at a paper link speed (tomography).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeadlineCheck {
    pub link: &'static str,
    pub period_ns: f64,
    /// Inferences that must complete per probe period.
    pub nns: usize,
    pub ok: bool,
}

/// A scenario run's typed result: the score folded over the full
/// [`ServiceReport`] of the underlying run.
#[derive(Debug)]
pub struct ScenarioReport {
    pub scenario: &'static str,
    pub backend: &'static str,
    pub score: ScenarioScore,
    /// The scenario's minimum healthy accuracy.
    pub floor: f64,
    pub deadlines: Vec<DeadlineCheck>,
    pub service: ServiceReport,
}

impl ScenarioReport {
    /// Did labeled accuracy clear the scenario's floor?
    pub fn passes_floor(&self) -> bool {
        self.score.accuracy >= self.floor
    }

    /// Order-independent digest of the run's verdicts (see
    /// [`verdict_digest`]).
    pub fn digest(&self) -> u64 {
        verdict_digest(&self.service)
    }
}

/// One of the paper's use cases, runnable end-to-end through the
/// unified service.
pub trait Scenario {
    /// Registry key and CLI name.
    fn name(&self) -> &'static str;
    /// One-line description (the §5 mapping).
    fn about(&self) -> &'static str;
    /// Model artifacts this use case trains/deploys (Table 1 / Table 5).
    fn use_case_models(&self) -> &'static [UseCaseModel];
    /// Default event count when the config passes `0`.
    fn default_events(&self) -> u64;
    /// Minimum labeled accuracy a healthy run must clear.
    fn accuracy_floor(&self) -> f64;
    /// Build the seeded workload, model, and oracle for one run.
    fn prepare(&self, cfg: &ScenarioConfig) -> Prepared;
    /// Per-link-speed deadline checks (tomography overrides this).
    fn deadlines(&self, caps: &Capabilities) -> Vec<DeadlineCheck> {
        let _ = caps;
        Vec::new()
    }
}

/// The authoritative scenario list — one place to add a use case.
pub struct ScenarioRegistry {
    scenarios: Vec<Box<dyn Scenario>>,
}

impl Default for ScenarioRegistry {
    fn default() -> Self {
        Self::standard()
    }
}

impl ScenarioRegistry {
    /// The paper's three use cases in §5 order, then the
    /// online-learning drift case layered on top of them.
    pub fn standard() -> Self {
        Self {
            scenarios: vec![
                Box::new(TrafficScenario),
                Box::new(AnomalyScenario),
                Box::new(TomographyScenario),
                Box::new(DriftScenario),
            ],
        }
    }

    pub fn names(&self) -> Vec<&'static str> {
        self.scenarios.iter().map(|s| s.name()).collect()
    }

    pub fn get(&self, name: &str) -> Option<&dyn Scenario> {
        self.scenarios
            .iter()
            .find(|s| s.name() == name)
            .map(|s| s.as_ref())
    }

    pub fn iter(&self) -> impl Iterator<Item = &dyn Scenario> {
        self.scenarios.iter().map(|b| b.as_ref())
    }

    /// Every model artifact across all scenarios, in registry order —
    /// the one list `experiments::tab01` and the CLI consult.
    pub fn use_case_models(&self) -> Vec<UseCaseModel> {
        self.scenarios
            .iter()
            .flat_map(|s| s.use_case_models().iter().copied())
            .collect()
    }

    /// Prepare and serve one scenario by name.
    pub fn run(&self, name: &str, cfg: &ScenarioConfig) -> crate::Result<ScenarioReport> {
        let scenario = self.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown scenario {name:?} (known: {})",
                self.names().join("|")
            )
        })?;
        run_scenario(scenario, cfg)
    }
}

/// Shape of a named use-case model — `(in_bits, layer widths)` — for
/// consumers that need a model of the right dimensions when no trained
/// artifact exists (the CLI's random-weights fallback).
pub fn model_shape(name: &str) -> Option<(usize, &'static [usize])> {
    ScenarioRegistry::standard()
        .use_case_models()
        .into_iter()
        .find(|m| m.name == name)
        .map(|m| (m.in_bits, m.arch))
}

/// Drive one prepared scenario end-to-end through the unified service
/// and score the result.  `"registry"` as the backend name publishes
/// the scenario's model into a fresh [`RegistryHandle`] and serves it
/// routed (hot-swap capable — the admin surface's publish/rollback
/// handlers need this path); every other name goes through
/// [`BackendFactory::single_sharded`].
pub fn run_scenario(
    scenario: &dyn Scenario,
    cfg: &ScenarioConfig,
) -> crate::Result<ScenarioReport> {
    let Prepared { events, trigger, model, oracle, learn } = scenario.prepare(cfg);
    let mut builder = ServeBuilder::new()
        .pipeline(cfg.workers)
        .flow_capacity(cfg.flow_capacity)
        .evict(cfg.evict);
    if cfg.batch > 0 {
        builder = builder.batching(cfg.batch, 1e6);
    }
    if let Some(policy) = cfg.shed {
        builder = builder.shed(policy);
    }
    if let Some(admin) = cfg.admin.as_ref() {
        builder = builder.admin(admin.clone());
    }
    // A learning loop republishes into the registry, so it forces the
    // hot-swap-capable serving path regardless of the requested backend.
    builder = if cfg.backend == "registry" || learn.is_some() {
        let handle = RegistryHandle::default();
        handle
            .publish(&model.name, &model)
            .map_err(|e| anyhow::anyhow!("scenario model publish: {e}"))?;
        let latency_ns = FpgaTiming::new(&model).latency_ns();
        let names = vec![model.name.clone()];
        let plane = BackendFactory::registry(&handle, &names, latency_ns, cfg.shards.max(1))
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        builder
            .backend(plane)
            .router(ModelRouter::rules(vec![(trigger, model.name.clone())]))
    } else {
        let plane = BackendFactory::single_sharded(&cfg.backend, model, cfg.shards)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        builder.backend(plane).trigger(trigger)
    };
    if let Some(spec) = learn {
        builder = builder.online_learn(spec);
    }
    let service = builder.build().map_err(|e| anyhow::anyhow!("{e}"))?;
    let caps = service.capabilities();
    let report = service.run(events).map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(ScenarioReport {
        scenario: scenario.name(),
        backend: caps.backend,
        score: score(&oracle, &report),
        floor: scenario.accuracy_floor(),
        deadlines: scenario.deadlines(&caps),
        service: report,
    })
}

/// Reduce a run's memory sink to one verdict per flow (max class — an
/// emission-order-independent reduction) and score it against the
/// oracle.
pub fn score(oracle: &Oracle, report: &ServiceReport) -> ScenarioScore {
    let verdicts = flow_verdicts(report);
    let expected_n = oracle.expected.len();
    let mut covered = 0usize;
    let mut agree = 0usize;
    for (id, want) in &oracle.expected {
        if let Some(got) = verdicts.get(id) {
            covered += 1;
            if got == want {
                agree += 1;
            }
        }
    }
    let mut scored = 0usize;
    let mut correct = 0usize;
    for (id, label) in &oracle.labels {
        if let Some(got) = verdicts.get(id) {
            scored += 1;
            if got == label {
                correct += 1;
            }
        }
    }
    let frac = |num: usize, den: usize| if den == 0 { 1.0 } else { num as f64 / den as f64 };
    ScenarioScore {
        coverage: frac(covered, expected_n),
        agreement: frac(agree, covered),
        accuracy: frac(correct, scored),
        scored,
        expected: expected_n,
    }
}

/// One verdict per flow: the maximum class over every sink emission
/// ("flagged if ever flagged").  The pipelined runtime emits verdicts
/// in completion order, so any per-flow reduction used for scoring must
/// be order-independent — max is, first-wins is not.
pub fn flow_verdicts(report: &ServiceReport) -> BTreeMap<u64, usize> {
    let mut verdicts: BTreeMap<u64, usize> = BTreeMap::new();
    for &(id, class) in &report.sink.memory {
        let v = verdicts.entry(id).or_insert(class);
        if class > *v {
            *v = class;
        }
    }
    verdicts
}

/// FNV-1a digest over the *sorted* `(flow id, class)` verdict pairs —
/// the value the determinism contract is checked against: serial and
/// pipelined runs of the same seeded scenario must produce the same
/// digest (emission order differs; the verdict multiset must not).
pub fn verdict_digest(report: &ServiceReport) -> u64 {
    let mut pairs = report.sink.memory.clone();
    pairs.sort_unstable();
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |v: u64| {
        for b in v.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
    };
    for (id, class) in pairs {
        eat(id);
        eat(class as u64);
    }
    h
}

/// Hand-crafted nearest-centroid BNN: one binary layer of two neurons
/// whose weight rows are the per-class majority bits of the calibration
/// vectors.  Because `popcount(XNOR(w, x)) = bits − hamming(w, x)`,
/// `argmax` over the two raw output scores picks the Hamming-nearest
/// centroid — a genuine 1-nearest-centroid classifier expressed as an
/// ordinary [`BnnModel`], so it runs bit-identically on every backend
/// and publishes into the registry like any trained artifact.  Ties
/// resolve to class 0 (argmax ties low).
///
/// A class with no calibration vectors gets the complement of the other
/// centroid (the farthest point — everything classifies as the seen
/// class); with no calibration at all the centroids are all-zeros and
/// all-ones.
///
/// Since the online-learning subsystem landed this is the same fit the
/// in-process trainer uses for its refits
/// ([`centroid_fit`](crate::learn::trainer::centroid_fit)) — scenario
/// seed models and retrained candidates come from one implementation.
pub fn centroid_model(
    name: &str,
    in_bits: usize,
    class0: &[Vec<u32>],
    class1: &[Vec<u32>],
) -> BnnModel {
    crate::learn::trainer::centroid_fit(name, in_bits, class0, class1)
}

/// Offline replay of the exact per-flow trigger semantics both runtimes
/// share: statistics rebuilt packet by packet with the canonical
/// [`FlowKey`], the trigger evaluated after each update, and the
/// triggered flow's packed input captured.  Returns every firing as
/// `(flow id, packed input, packet)` in stream order — the transcript
/// scenarios calibrate their centroid models on and derive oracles
/// from.  (Replay assumes no eviction: under table pressure the live
/// service may diverge, which `agreement` then measures.)
pub(crate) fn replay_trigger_inputs(
    events: &[PacketEvent],
    trigger: TriggerCondition,
) -> Vec<(u64, Vec<u32>, Packet)> {
    let mut table: HashMap<FlowKey, FlowStats> = HashMap::new();
    let mut firings = Vec::new();
    for ev in events {
        let (key, fwd) = FlowKey::from_packet(&ev.packet);
        let stats = table.entry(key).or_default();
        stats.update(&ev.packet, fwd);
        let is_new = stats.pkts == 1;
        if !trigger.fires(&ev.packet, is_new, stats.pkts) {
            continue;
        }
        firings.push((
            flow_id(&ev.packet),
            select_packed_input(ev, stats),
            ev.packet,
        ));
    }
    firings
}

/// Build an oracle from replayed firings: `expected` reduces multiple
/// firings per flow with the same max-class rule as [`score`];
/// `labels` comes from the per-packet labeling function.
pub(crate) fn oracle_from_firings(
    firings: &[(u64, Vec<u32>, Packet)],
    model: &BnnModel,
    label: impl Fn(&Packet) -> usize,
) -> Oracle {
    let mut exec = BnnExecutor::new(model.clone());
    let mut oracle = Oracle::default();
    for (id, packed, pkt) in firings {
        let class = exec.classify(packed);
        let e = oracle.expected.entry(*id).or_insert(class);
        if class > *e {
            *e = class;
        }
        oracle.labels.insert(*id, label(pkt));
    }
    oracle
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::infer_packed;

    #[test]
    fn registry_lists_scenarios_in_paper_order() {
        let reg = ScenarioRegistry::standard();
        assert_eq!(reg.names(), vec!["traffic", "anomaly", "tomography", "drift"]);
        assert!(reg.get("traffic").is_some());
        assert!(reg.get("nope").is_none());
        // Every scenario carries at least one deployable model shape.
        for s in reg.iter() {
            assert!(!s.use_case_models().is_empty(), "{}", s.name());
            assert!(s.accuracy_floor() > 0.5, "{}", s.name());
            assert!(s.default_events() > 0, "{}", s.name());
        }
    }

    #[test]
    fn use_case_model_list_covers_all_artifacts() {
        let models = ScenarioRegistry::standard().use_case_models();
        let names: Vec<&str> = models.iter().map(|m| m.name).collect();
        assert_eq!(
            names,
            vec![
                "traffic",
                "anomaly",
                "tomography_32",
                "tomography_64",
                "tomography_128",
                "drift"
            ]
        );
        // Shape lookup resolves every listed artifact and nothing else.
        for m in &models {
            let (in_bits, arch) = model_shape(m.name).unwrap();
            assert_eq!(in_bits, m.in_bits);
            assert_eq!(arch, m.arch);
        }
        assert!(model_shape("unknown").is_none());
    }

    #[test]
    fn centroid_model_is_nearest_centroid() {
        // Two well-separated calibration clusters on 64 bits.
        let a = vec![vec![0xFFFF_0000u32, 0], vec![0xFFFF_0001, 0]];
        let b = vec![vec![0x0000_FFFFu32, !0u32], vec![0x0000_FFFE, !0u32]];
        let m = centroid_model("t", 64, &a, &b);
        m.validate().unwrap();
        assert_eq!(m.out_neurons(), 2);
        assert_eq!(infer_packed(&m, &a[0]), 0);
        assert_eq!(infer_packed(&m, &b[0]), 1);
        // Empty class 1 → complement fallback: everything is class 0.
        let m0 = centroid_model("t0", 64, &a, &[]);
        assert_eq!(infer_packed(&m0, &a[1]), 0);
        // Degenerate: no calibration at all still builds a valid model.
        centroid_model("tz", 64, &[], &[]).validate().unwrap();
    }

    fn mem_report(memory: Vec<(u64, usize)>) -> ServiceReport {
        ServiceReport {
            sink: crate::coordinator::selector::OutputSink {
                memory,
                inline_tags: Vec::new(),
            },
            ..Default::default()
        }
    }

    #[test]
    fn score_reduces_max_class_and_handles_misses() {
        let mut oracle = Oracle::default();
        oracle.expected.insert(1, 1);
        oracle.expected.insert(2, 0);
        oracle.expected.insert(3, 1); // never served → coverage miss
        oracle.labels.insert(1, 1);
        oracle.labels.insert(2, 1); // model expected 0 → accuracy miss
        // Flow 1 emits 0 then 1 (out of order): max-reduction → 1.
        let report = mem_report(vec![(1, 0), (1, 1), (2, 0)]);
        let s = score(&oracle, &report);
        assert!((s.coverage - 2.0 / 3.0).abs() < 1e-9);
        assert!((s.agreement - 1.0).abs() < 1e-9);
        assert!((s.accuracy - 0.5).abs() < 1e-9);
        assert_eq!(s.scored, 2);
        assert_eq!(s.expected, 3);
    }

    #[test]
    fn verdict_digest_is_order_independent_but_value_sensitive() {
        let a = mem_report(vec![(1, 0), (2, 1), (3, 0)]);
        let b = mem_report(vec![(3, 0), (1, 0), (2, 1)]);
        assert_eq!(verdict_digest(&a), verdict_digest(&b));
        let c = mem_report(vec![(1, 0), (2, 0), (3, 0)]);
        assert_ne!(verdict_digest(&a), verdict_digest(&c));
    }

    #[test]
    fn replay_matches_trigger_semantics() {
        let cfg = ScenarioConfig::default();
        let prepared = TrafficScenario.prepare(&cfg);
        let firings = replay_trigger_inputs(&prepared.events, prepared.trigger);
        // EveryNPackets fires once per flow; the oracle keys are the
        // distinct firing ids.
        let mut ids: Vec<u64> = firings.iter().map(|f| f.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), prepared.oracle.expected.len());
        assert!(!ids.is_empty());
    }
}

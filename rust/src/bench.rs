//! Minimal micro-benchmark harness (offline substitute for criterion).
//!
//! Used by the `cargo bench` binaries (`rust/benches/*.rs`, harness =
//! false).  Methodology: warm up, then run timed batches until both a
//! minimum wall time and a minimum iteration count are reached; report
//! mean ns/iter, the median of batch means, and throughput.
//!
//! Setting `N3IC_BENCH_SMOKE` (any value) cuts every time budget 10× —
//! a CI-speed smoke run (`scripts/verify.sh`) that still exercises each
//! bench body; numbers from a smoke run are not publication-grade.

use std::time::Instant;

use crate::json::{obj, Json};

/// True when the harness should run in short smoke mode.
pub fn smoke_mode() -> bool {
    std::env::var_os("N3IC_BENCH_SMOKE").is_some()
}

/// Merge one bench's result `fragment` into the repo-root `BENCH.json`
/// (`BENCH.smoke.json` in smoke mode, which is gitignored) under
/// `{"benches": {<name>: <fragment>}}`, preserving every other bench's
/// entry — so `batch_engine`, `pipeline`, and future grids share one
/// machine-trackable perf record instead of clobbering each other.
pub fn write_bench_json(name: &str, fragment: Json) -> std::io::Result<std::path::PathBuf> {
    let fname = if smoke_mode() { "BENCH.smoke.json" } else { "BENCH.json" };
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join(fname);
    let existing = std::fs::read_to_string(&path).ok();
    let doc = merge_bench_entry(existing.as_deref(), name, fragment);
    std::fs::write(&path, doc)?;
    Ok(path)
}

/// Pure merge step behind [`write_bench_json`].  Unparseable documents
/// are replaced; legacy single-bench documents (top-level `"bench"`
/// key, the pre-pipeline format) are migrated under `"benches"` first.
pub fn merge_bench_entry(existing: Option<&str>, name: &str, fragment: Json) -> String {
    let mut benches = std::collections::BTreeMap::new();
    if let Some(text) = existing {
        if let Ok(v) = Json::parse(text) {
            if let Some(Json::Obj(m)) = v.get("benches") {
                benches = m.clone();
            } else if let Some(old) = v.get("bench").and_then(Json::as_str) {
                let old = old.to_string();
                let mut body = v.clone();
                if let Json::Obj(m) = &mut body {
                    // The name now lives in the key; a stale copy inside
                    // the entry would make migrated and fresh entries
                    // shape-different forever.
                    m.remove("bench");
                }
                benches.insert(old, body);
            }
        }
    }
    benches.insert(name.to_string(), fragment);
    let mut s = obj(vec![("benches", Json::Obj(benches))]).dump();
    s.push('\n');
    s
}

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub ns_per_iter: f64,
    pub median_ns: f64,
    pub iters: u64,
}

impl BenchResult {
    pub fn per_second(&self) -> f64 {
        1e9 / self.ns_per_iter
    }
}

/// Run a closure under the harness and print a criterion-style line.
pub fn bench<F: FnMut() -> R, R>(name: &str, mut f: F) -> BenchResult {
    let (warm_ms, batch_target_ns, total_ms) = if smoke_mode() {
        (5u128, 2e6, 40u128)
    } else {
        (50, 20e6, 400)
    };
    // Warm-up.
    let w0 = Instant::now();
    let mut warm_iters = 0u64;
    while w0.elapsed().as_millis() < warm_ms {
        std::hint::black_box(f());
        warm_iters += 1;
    }
    // Choose batch size so one batch hits the per-batch time target.
    let est_ns = w0.elapsed().as_nanos() as f64 / warm_iters as f64;
    let batch = ((batch_target_ns / est_ns).ceil() as u64).max(1);
    let mut batch_means: Vec<f64> = Vec::new();
    let mut total_iters = 0u64;
    let t0 = Instant::now();
    while t0.elapsed().as_millis() < total_ms || batch_means.len() < 5 {
        let b0 = Instant::now();
        for _ in 0..batch {
            std::hint::black_box(f());
        }
        let ns = b0.elapsed().as_nanos() as f64 / batch as f64;
        batch_means.push(ns);
        total_iters += batch;
        if batch_means.len() > 200 {
            break;
        }
    }
    batch_means.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = batch_means[batch_means.len() / 2];
    let mean = batch_means.iter().sum::<f64>() / batch_means.len() as f64;
    let r = BenchResult {
        name: name.to_string(),
        ns_per_iter: mean,
        median_ns: median,
        iters: total_iters,
    };
    println!(
        "{:40} {:>12.1} ns/iter (median {:>12.1})  {:>14.0} /s  [{} iters]",
        r.name,
        r.ns_per_iter,
        r.median_ns,
        r.per_second(),
        r.iters
    );
    r
}

/// Group header, for readable `cargo bench` output.
pub fn group(title: &str) {
    println!("\n== {title} ==");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop_addition", || std::hint::black_box(1u64) + 1);
        assert!(r.ns_per_iter > 0.0);
        assert!(r.ns_per_iter < 1_000.0); // an add is not a microsecond
        assert!(r.iters > 1000);
    }

    #[test]
    fn merge_keeps_other_benches_and_migrates_legacy() {
        let frag = |v: f64| obj(vec![("x", Json::Num(v))]);
        // Fresh file.
        let a = merge_bench_entry(None, "alpha", frag(1.0));
        let va = Json::parse(&a).unwrap();
        assert_eq!(va.get("benches").unwrap().get("alpha").unwrap(), &frag(1.0));
        // Second bench does not clobber the first.
        let b = merge_bench_entry(Some(&a), "beta", frag(2.0));
        let vb = Json::parse(&b).unwrap();
        assert_eq!(vb.get("benches").unwrap().get("alpha").unwrap(), &frag(1.0));
        assert_eq!(vb.get("benches").unwrap().get("beta").unwrap(), &frag(2.0));
        // Re-running a bench replaces only its own entry.
        let c = merge_bench_entry(Some(&b), "alpha", frag(3.0));
        let vc = Json::parse(&c).unwrap();
        assert_eq!(vc.get("benches").unwrap().get("alpha").unwrap(), &frag(3.0));
        assert_eq!(vc.get("benches").unwrap().get("beta").unwrap(), &frag(2.0));
        // Legacy single-bench document migrates under its own name.
        let legacy = r#"{"bench":"batch_engine","rows":[]}"#;
        let d = merge_bench_entry(Some(legacy), "pipeline", frag(4.0));
        let vd = Json::parse(&d).unwrap();
        let m = vd.get("benches").unwrap();
        assert!(m.get("batch_engine").unwrap().get("rows").is_some());
        // The legacy name key is stripped: it lives in the map key now.
        assert!(m.get("batch_engine").unwrap().get("bench").is_none());
        assert_eq!(m.get("pipeline").unwrap(), &frag(4.0));
        // Garbage is replaced, not crashed on.
        let e = merge_bench_entry(Some("{not json"), "alpha", frag(5.0));
        assert!(Json::parse(&e).is_ok());
    }
}

//! Sharded multi-core inference engine: one [`BatchKernel`] worker per
//! shard, fed over mpsc channels — the "one core = 1.18M flows/s, so use
//! N cores" scaling axis of §6 made real.
//!
//! Workers are spawned once and live for the engine's lifetime, so the
//! steady state is allocation-light: each worker owns its kernel (and
//! therefore its preallocated activation tiles) and all workers share
//! one `Arc` of the packed weights.  `run_batch` splits the batch into
//! contiguous shards, scatters them, and reassembles verdicts in input
//! order regardless of worker completion order.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use super::batch::BatchKernel;
use super::exec::PackedModel;
use super::registry::ModelEpoch;
use super::BnnModel;

struct Job {
    start: usize,
    len: usize,
    inputs: Arc<Vec<Vec<u32>>>,
    /// Weights this shard must score under.  `run_batch` clones **one**
    /// `Arc` into every shard's job, so all shards of a batch see the
    /// same immutable weight snapshot by construction — a concurrent
    /// registry publish can only affect the *next* batch, never tear
    /// this one (asserted end-to-end in `tests/registry_swap.rs`).
    packed: Arc<PackedModel>,
}

struct ShardResult {
    start: usize,
    classes: Vec<usize>,
    /// The worker's kernel panicked on this shard (bad input width,
    /// bug); reported instead of silently dropping the result, which
    /// would leave the gather loop blocked forever.
    panicked: bool,
}

/// Aggregate throughput counters of an engine.
#[derive(Debug, Default, Clone, Copy)]
pub struct EngineStats {
    pub batches: u64,
    pub items: u64,
    /// Wall-clock spent inside `run_batch` (scatter → gather), ns.
    pub busy_ns: u64,
}

impl EngineStats {
    /// Sustained classification rate over every batch run so far.
    pub fn flows_per_sec(&self) -> f64 {
        if self.busy_ns == 0 {
            0.0
        } else {
            self.items as f64 * 1e9 / self.busy_ns as f64
        }
    }
}

/// A pool of shard workers behind a batch API.
pub struct ShardedEngine {
    txs: Vec<mpsc::Sender<Job>>,
    rx: mpsc::Receiver<ShardResult>,
    handles: Vec<thread::JoinHandle<()>>,
    n_shards: usize,
    /// Weights used by the plain (non-epoch) batch entry points.
    default_packed: Arc<PackedModel>,
    stats: EngineStats,
}

impl ShardedEngine {
    /// Spawn `n_shards` workers (clamped to ≥ 1) over one shared copy of
    /// the packed weights.
    pub fn new(model: &BnnModel, n_shards: usize) -> Self {
        Self::with_packed(PackedModel::arc(model), n_shards)
    }

    /// Same, reusing an existing packed-weight handle (e.g. from a
    /// sibling `BnnExecutor` or a registry epoch) instead of repacking.
    pub(crate) fn with_packed(packed: Arc<PackedModel>, n_shards: usize) -> Self {
        let n_shards = n_shards.max(1);
        let (res_tx, rx) = mpsc::channel::<ShardResult>();
        let mut txs = Vec::with_capacity(n_shards);
        let mut handles = Vec::with_capacity(n_shards);
        for _ in 0..n_shards {
            let (tx, job_rx) = mpsc::channel::<Job>();
            let res_tx = res_tx.clone();
            let mut kernel = BatchKernel::with_packed(Arc::clone(&packed));
            handles.push(thread::spawn(move || {
                while let Ok(job) = job_rx.recv() {
                    // A panicking kernel must still answer, or the
                    // engine's gather loop would wait forever on the
                    // missing shard (the other workers keep the result
                    // channel open, so recv() never errors).
                    let scored = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        let mut classes = Vec::with_capacity(job.len);
                        // Usually a pointer-equal no-op; a real retarget
                        // (hot swap) costs one scratch-grow, amortized
                        // to zero across a fixed model set.
                        kernel.retarget(&job.packed);
                        kernel.run_batch(
                            &job.inputs[job.start..job.start + job.len],
                            &mut classes,
                        );
                        classes
                    }));
                    match scored {
                        Ok(classes) => {
                            let done = ShardResult {
                                start: job.start,
                                classes,
                                panicked: false,
                            };
                            if res_tx.send(done).is_err() {
                                break;
                            }
                        }
                        Err(_) => {
                            // Kernel scratch may be inconsistent: report
                            // and retire this worker.
                            let _ = res_tx.send(ShardResult {
                                start: job.start,
                                classes: Vec::new(),
                                panicked: true,
                            });
                            break;
                        }
                    }
                }
            }));
            txs.push(tx);
        }
        Self {
            txs,
            rx,
            handles,
            n_shards,
            default_packed: packed,
            stats: EngineStats::default(),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    pub fn stats(&self) -> EngineStats {
        self.stats
    }

    /// Classify a batch across the shards; `classes[i]` is the verdict
    /// for `inputs[i]`.  Copies the inputs once to share them with the
    /// workers — use [`run_batch_owned`](Self::run_batch_owned) or
    /// [`run_batch_shared`](Self::run_batch_shared) when the caller can
    /// hand the batch over or already holds it in an `Arc`.
    pub fn run_batch(&mut self, inputs: &[Vec<u32>], classes: &mut Vec<usize>) {
        self.run_batch_shared(&Arc::new(inputs.to_vec()), classes)
    }

    /// Zero-copy variant of [`run_batch`](Self::run_batch).
    pub fn run_batch_owned(&mut self, inputs: Vec<Vec<u32>>, classes: &mut Vec<usize>) {
        self.run_batch_shared(&Arc::new(inputs), classes)
    }

    /// Cheapest entry point: per-shard cost is one `Arc` clone, no data
    /// copy at all (also what repeat callers like benches should use).
    /// Panics if a worker died or panicked; services that must stay up
    /// through a poisoned shard use [`try_run_batch_shared`]
    /// (Self::try_run_batch_shared) instead.
    pub fn run_batch_shared(&mut self, inputs: &Arc<Vec<Vec<u32>>>, classes: &mut Vec<usize>) {
        if let Err(e) = self.try_run_batch_shared(inputs, classes) {
            panic!("{e}");
        }
    }

    /// Fallible batch run: a dead or panicking shard worker surfaces as
    /// `Err` instead of a panic (or, worse, a hang on the missing
    /// shard's result).  Every result of the failed batch is drained
    /// before returning, so a later call can never observe another
    /// batch's stale verdicts; still, one or more workers may have
    /// retired, so rebuilding the engine after an `Err` is the safe
    /// move.  `classes` contents are unspecified on error.
    pub fn try_run_batch_shared(
        &mut self,
        inputs: &Arc<Vec<Vec<u32>>>,
        classes: &mut Vec<usize>,
    ) -> Result<(), EngineError> {
        self.try_run_batch_with(Arc::clone(&self.default_packed), inputs, classes)
    }

    /// Run a batch under a pinned registry epoch's weights: the epoch's
    /// packed handle is cloned into **every** shard's job before any
    /// shard starts, so all verdicts of this batch — regardless of which
    /// worker scores them — come from exactly this epoch.  A concurrent
    /// `publish` can only influence the epoch the *caller* pins next
    /// time, never the jobs already scattered (`tests/registry_swap.rs`
    /// hammers this).  Panics on a dead/panicked worker, like
    /// [`run_batch_shared`](Self::run_batch_shared).
    pub fn run_batch_epoch(
        &mut self,
        epoch: &ModelEpoch,
        inputs: &Arc<Vec<Vec<u32>>>,
        classes: &mut Vec<usize>,
    ) {
        if let Err(e) = self.try_run_batch_epoch(epoch, inputs, classes) {
            panic!("{e}");
        }
    }

    /// Fallible variant of [`run_batch_epoch`](Self::run_batch_epoch).
    pub fn try_run_batch_epoch(
        &mut self,
        epoch: &ModelEpoch,
        inputs: &Arc<Vec<Vec<u32>>>,
        classes: &mut Vec<usize>,
    ) -> Result<(), EngineError> {
        self.try_run_batch_with(Arc::clone(&epoch.packed), inputs, classes)
    }

    /// The one scatter/gather implementation: every entry point funnels
    /// here with the weight snapshot its whole batch must score under.
    fn try_run_batch_with(
        &mut self,
        packed: Arc<PackedModel>,
        inputs: &Arc<Vec<Vec<u32>>>,
        classes: &mut Vec<usize>,
    ) -> Result<(), EngineError> {
        classes.clear();
        let n = inputs.len();
        if n == 0 {
            return Ok(());
        }
        let t0 = Instant::now();
        // Contiguous shards of ceil(n / n_shards); with more shards than
        // inputs the tail workers simply receive nothing this round.
        let chunk = n.div_ceil(self.n_shards);
        let mut sent = 0usize;
        for (w, start) in (0..n).step_by(chunk).enumerate() {
            let job = Job {
                start,
                len: chunk.min(n - start),
                inputs: Arc::clone(inputs),
                packed: Arc::clone(&packed),
            };
            if self.txs[w].send(job).is_err() {
                // Drain what was already scattered (those workers are
                // alive and will answer) so the result queue holds
                // nothing stale for a future batch.
                for _ in 0..sent {
                    let _ = self.rx.recv();
                }
                return Err(EngineError::WorkerDied);
            }
            sent += 1;
        }
        classes.resize(n, 0);
        // Gather every outstanding shard even after a failure — leaving
        // results queued would corrupt the next batch's gather.
        let mut first_err = None;
        for _ in 0..sent {
            match self.rx.recv() {
                Ok(r) if r.panicked => {
                    first_err.get_or_insert(EngineError::WorkerPanicked { start: r.start });
                }
                Ok(r) => {
                    classes[r.start..r.start + r.classes.len()].copy_from_slice(&r.classes);
                }
                Err(_) => {
                    first_err.get_or_insert(EngineError::WorkerDied);
                    break;
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        self.stats.batches += 1;
        self.stats.items += n as u64;
        self.stats.busy_ns += t0.elapsed().as_nanos() as u64;
        Ok(())
    }
}

/// Failure modes of a [`ShardedEngine`] batch run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineError {
    /// A worker's channel disconnected (thread gone).
    WorkerDied,
    /// A worker's kernel panicked mid-shard (e.g. bad input widths).
    WorkerPanicked { start: usize },
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::WorkerDied => write!(f, "shard worker died"),
            EngineError::WorkerPanicked { start } => write!(
                f,
                "shard worker panicked scoring inputs [{start}..] — check input widths"
            ),
        }
    }
}

impl std::error::Error for EngineError {}

impl Drop for ShardedEngine {
    fn drop(&mut self) {
        // Closing the job channels ends each worker's recv loop.
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::{infer_packed, BnnLayer};

    #[test]
    fn ordered_results_across_shards() {
        let model = BnnModel::random("m", 256, &[32, 16, 2], 2);
        let inputs: Vec<Vec<u32>> = (0..37)
            .map(|i| BnnLayer::random(1, 256, 300 + i as u64).words)
            .collect();
        let mut eng = ShardedEngine::new(&model, 4);
        let mut classes = Vec::new();
        eng.run_batch(&inputs, &mut classes);
        assert_eq!(classes.len(), 37);
        for (x, &c) in inputs.iter().zip(&classes) {
            assert_eq!(c, infer_packed(&model, x));
        }
        let st = eng.stats();
        assert_eq!((st.batches, st.items), (1, 37));
        assert!(st.busy_ns > 0);
        assert!(st.flows_per_sec() > 0.0);
    }

    #[test]
    fn try_path_reports_worker_panic_without_hanging() {
        let model = BnnModel::random("w", 64, &[8, 2], 1);
        let mut engine = ShardedEngine::new(&model, 2);
        let mut classes = Vec::new();
        // Model wants 2 words; feed 3 → the worker's kernel panics.
        let err = engine
            .try_run_batch_shared(&Arc::new(vec![vec![0u32; 3]]), &mut classes)
            .unwrap_err();
        assert!(matches!(err, EngineError::WorkerPanicked { start: 0 }), "{err}");
    }

    #[test]
    fn failed_batch_drains_results_so_later_calls_never_see_stale_data() {
        let model = BnnModel::random("w", 64, &[8, 2], 1);
        let mut engine = ShardedEngine::new(&model, 2);
        let mut classes = Vec::new();
        // Shard 0's input is malformed (worker panics); shard 1's is
        // fine (worker answers).  The gather must consume *both*.
        let mixed = Arc::new(vec![vec![0u32; 3], BnnLayer::random(1, 64, 5).words]);
        let err = engine.try_run_batch_shared(&mixed, &mut classes).unwrap_err();
        assert_eq!(err, EngineError::WorkerPanicked { start: 0 });
        // Worker 0 retired and nothing is left queued: the next batch
        // fails cleanly instead of gathering the old batch's verdicts.
        let good = Arc::new(vec![BnnLayer::random(1, 64, 6).words]);
        let err = engine.try_run_batch_shared(&good, &mut classes).unwrap_err();
        assert_eq!(err, EngineError::WorkerDied);
    }

    #[test]
    fn epoch_batches_score_under_their_pinned_weights() {
        use crate::bnn::RegistryHandle;
        let m1 = BnnModel::random("m", 256, &[32, 16, 2], 1);
        let m2 = BnnModel::random("m", 256, &[32, 16, 2], 2);
        let h = RegistryHandle::new();
        h.publish("m", &m1).unwrap();
        let e1 = h.current("m").unwrap();
        h.publish("m", &m2).unwrap();
        let e2 = h.current("m").unwrap();
        let inputs: Arc<Vec<Vec<u32>>> = Arc::new(
            (0..21).map(|i| BnnLayer::random(1, 256, 40 + i).words).collect(),
        );
        let mut eng = ShardedEngine::new(&m1, 3);
        let mut classes = Vec::new();
        // A batch on a previously pinned epoch still scores under m1
        // even though the registry has moved on to v2.
        eng.run_batch_epoch(&e1, &inputs, &mut classes);
        for (x, &c) in inputs.iter().zip(&classes) {
            assert_eq!(c, infer_packed(&m1, x));
        }
        eng.run_batch_epoch(&e2, &inputs, &mut classes);
        for (x, &c) in inputs.iter().zip(&classes) {
            assert_eq!(c, infer_packed(&m2, x));
        }
        // The plain entry points keep the construction-time weights.
        eng.run_batch_shared(&inputs, &mut classes);
        for (x, &c) in inputs.iter().zip(&classes) {
            assert_eq!(c, infer_packed(&m1, x));
        }
    }

    #[test]
    fn empty_batch_and_oversharding() {
        let model = BnnModel::random("m", 64, &[8, 2], 3);
        let mut eng = ShardedEngine::new(&model, 16);
        let mut classes = vec![99usize];
        eng.run_batch(&[], &mut classes);
        assert!(classes.is_empty());
        let inputs: Vec<Vec<u32>> = (0..2)
            .map(|i| BnnLayer::random(1, 64, i).words)
            .collect();
        eng.run_batch(&inputs, &mut classes);
        assert_eq!(classes.len(), 2);
        for (x, &c) in inputs.iter().zip(&classes) {
            assert_eq!(c, infer_packed(&model, x));
        }
    }
}

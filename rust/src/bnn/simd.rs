//! Runtime-dispatched SIMD scoring path for the batch kernel (ISSUE 9).
//!
//! The scalar hot loop in [`BatchKernel`](super::BatchKernel) scores one
//! weight qword against all [`TILE`] lanes with `u64::count_ones`; this
//! module adds an explicit AVX2 twin behind the off-by-default `simd`
//! cargo feature: the weight qword is broadcast into a 256-bit register,
//! XNORed against two 4-lane stripes of the activation tile, and
//! popcounted with the nibble-lookup (`pshufb`) + `psadbw` reduction —
//! exact integer arithmetic end to end, so the vector path is
//! **bit-identical** to the scalar loop on every shape (asserted by the
//! widened differential suite in `tests/differential.rs`).
//!
//! Selection is a runtime decision, not a compile-time one: kernels
//! resolve a [`KernelPath`] at construction against
//! [`simd_available`] (compiled in **and** `avx2` detected on this CPU)
//! and the process-wide [`force_scalar`] override, so the same binary
//! serves the vector path where the hardware has it and falls back to
//! the scalar loop everywhere else.  Planes report the resolved width
//! through `Capabilities::simd_lanes`.
//!
//! Without the `simd` feature (or off x86-64) every entry point here
//! still exists — [`simd_available`] is `false`, every path resolves to
//! the scalar loop, and the differential tests pass trivially, which is
//! exactly what `scripts/verify.sh` checks by building both feature
//! sets.

use std::sync::atomic::{AtomicBool, Ordering};

use super::batch::TILE;

/// Which scoring loop a [`BatchKernel`](super::BatchKernel) should use.
/// Resolved once at kernel construction (and kept across `retarget`);
/// tests construct `Scalar` and `Simd` kernels side by side to prove
/// bit-exactness, production code uses `Auto`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelPath {
    /// Vector path when compiled in, detected, and not forced off —
    /// the default everywhere.
    Auto,
    /// Always the scalar loop (the differential reference).
    Scalar,
    /// Vector path whenever compiled + detected, ignoring
    /// [`force_scalar`] (the differential suite's forced arm).
    Simd,
}

/// Process-wide scalar override for `Auto` kernels, so end-to-end tests
/// and benches can run the same scenario through both paths of one
/// binary.  Only consulted at kernel *construction*: already-built
/// kernels keep their resolved path.
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Force (or unforce) every subsequently constructed `Auto` kernel onto
/// the scalar loop.  Both paths are bit-identical, so flipping this
/// mid-run can never change a verdict — it only changes speed.
pub fn force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::SeqCst);
}

/// Is the scalar override currently set?
pub fn scalar_forced() -> bool {
    FORCE_SCALAR.load(Ordering::SeqCst)
}

/// Was the vector path compiled into this binary (`--features simd` on
/// x86-64)?
pub const fn simd_compiled() -> bool {
    cfg!(all(feature = "simd", target_arch = "x86_64"))
}

/// Compiled in **and** AVX2 detected on this CPU (cached after the
/// first query).
pub fn simd_available() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        static DETECTED: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
        *DETECTED.get_or_init(|| is_x86_feature_detected!("avx2"))
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        false
    }
}

/// 64-bit qword lanes one vector op covers on the path an `Auto` kernel
/// would resolve to right now: 4 on the AVX2 path, 1 on the scalar
/// loop.  This is what planes publish as `Capabilities::simd_lanes`.
pub fn active_lanes() -> usize {
    if simd_available() && !scalar_forced() {
        4
    } else {
        1
    }
}

/// Resolve a [`KernelPath`] to "use the vector loop?" — the one place
/// the dispatch decision is made.
pub(crate) fn resolve(path: KernelPath) -> bool {
    match path {
        KernelPath::Scalar => false,
        KernelPath::Simd => simd_available(),
        KernelPath::Auto => simd_available() && !scalar_forced(),
    }
}

/// The scalar hot loop: one neuron's weight row against all TILE lanes,
/// `TILE` independent accumulators (LLVM turns the fixed-width inner
/// loop into a vector XNOR + vector popcount where the baseline ISA
/// allows).  This is the reference the vector path must match bit for
/// bit.
#[inline]
pub(crate) fn score_tile_scalar(row: &[u64], x: &[u64]) -> [u32; TILE] {
    let mut acc = [0u32; TILE];
    for (q, &w) in row.iter().enumerate() {
        let stripe = &x[q * TILE..q * TILE + TILE];
        for t in 0..TILE {
            acc[t] += (!(w ^ stripe[t])).count_ones();
        }
    }
    acc
}

/// Dispatch one tile score through the resolved path.  `use_simd` comes
/// from [`resolve`], so it is only ever true when AVX2 was detected at
/// runtime on a build that compiled the intrinsics in.
#[inline]
pub(crate) fn score_tile(row: &[u64], x: &[u64], use_simd: bool) -> [u32; TILE] {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if use_simd {
        // SAFETY: `resolve` gates on `simd_available()`, which requires
        // a positive `is_x86_feature_detected!("avx2")` on this CPU.
        return unsafe { avx2::score_tile(row, x) };
    }
    let _ = use_simd;
    score_tile_scalar(row, x)
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    use std::arch::x86_64::{
        __m256i, _mm256_add_epi64, _mm256_add_epi8, _mm256_and_si256, _mm256_loadu_si256,
        _mm256_sad_epu8, _mm256_set1_epi64x, _mm256_set1_epi8, _mm256_setr_epi8,
        _mm256_setzero_si256, _mm256_shuffle_epi8, _mm256_srli_epi16, _mm256_storeu_si256,
        _mm256_xor_si256,
    };

    use super::TILE;

    // The two-halves-of-4 layout below hardcodes the 8-lane tile.
    const _: () = assert!(TILE == 8);

    /// Per-64-bit-lane popcount of a 256-bit vector: nibble lookup via
    /// `pshufb` (each byte split into two 4-bit table indexes), then
    /// `psadbw` against zero sums the 8 byte-counts of each 64-bit lane.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn popcount_epi64(
        v: __m256i,
        lookup: __m256i,
        low_mask: __m256i,
        zero: __m256i,
    ) -> __m256i {
        let lo = _mm256_and_si256(v, low_mask);
        let hi = _mm256_and_si256(_mm256_srli_epi16(v, 4), low_mask);
        let cnt =
            _mm256_add_epi8(_mm256_shuffle_epi8(lookup, lo), _mm256_shuffle_epi8(lookup, hi));
        _mm256_sad_epu8(cnt, zero)
    }

    /// AVX2 twin of [`score_tile_scalar`](super::score_tile_scalar):
    /// each weight qword is broadcast once and XNORed (xor + complement)
    /// against the tile's 8-lane stripe, held as two 4×u64 vectors with
    /// two independent 4×u64 accumulators.  All arithmetic is exact
    /// integer popcounting — bit-identical to the scalar loop by
    /// construction.
    ///
    /// # Safety
    /// Requires AVX2 at runtime; `x` must hold at least
    /// `row.len() * TILE` qwords (the kernel's lane-interleaved layout
    /// guarantees this).
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn score_tile(row: &[u64], x: &[u64]) -> [u32; TILE] {
        debug_assert!(x.len() >= row.len() * TILE);
        let zero = _mm256_setzero_si256();
        let low_mask = _mm256_set1_epi8(0x0f);
        #[rustfmt::skip]
        let lookup = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let ones = _mm256_set1_epi8(-1);
        let mut acc0 = zero;
        let mut acc1 = zero;
        for (q, &w) in row.iter().enumerate() {
            let wv = _mm256_set1_epi64x(w as i64);
            let p = x.as_ptr().add(q * TILE);
            let s0 = _mm256_loadu_si256(p.cast());
            let s1 = _mm256_loadu_si256(p.add(4).cast());
            let v0 = _mm256_xor_si256(_mm256_xor_si256(wv, s0), ones);
            let v1 = _mm256_xor_si256(_mm256_xor_si256(wv, s1), ones);
            acc0 = _mm256_add_epi64(acc0, popcount_epi64(v0, lookup, low_mask, zero));
            acc1 = _mm256_add_epi64(acc1, popcount_epi64(v1, lookup, low_mask, zero));
        }
        let mut lanes = [0u64; TILE];
        _mm256_storeu_si256(lanes.as_mut_ptr().cast(), acc0);
        _mm256_storeu_si256(lanes.as_mut_ptr().add(4).cast(), acc1);
        let mut acc = [0u32; TILE];
        for (a, &l) in acc.iter_mut().zip(&lanes) {
            *a = l as u32;
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_honors_the_force_flag_and_feature_state() {
        assert!(!resolve(KernelPath::Scalar));
        // Simd/Auto resolve to the vector loop only where it exists.
        assert_eq!(resolve(KernelPath::Simd), simd_available());
        force_scalar(true);
        assert!(scalar_forced());
        assert!(!resolve(KernelPath::Auto), "force_scalar must win over Auto");
        assert_eq!(resolve(KernelPath::Simd), simd_available(), "Simd ignores the override");
        assert_eq!(active_lanes(), 1);
        force_scalar(false);
        assert!(!scalar_forced());
        assert_eq!(resolve(KernelPath::Auto), simd_available());
        assert_eq!(active_lanes() > 1, simd_available());
        if !simd_compiled() {
            assert!(!simd_available(), "vector path cannot appear uncompiled");
        }
    }

    #[test]
    fn scalar_tile_scorer_counts_xnor_matches() {
        // 2 qwords per row: all-ones weights against per-lane patterns.
        let row = [!0u64, !0u64];
        let mut x = [0u64; 2 * TILE];
        x[0] = !0; // lane 0, qword 0: full match = 64
        x[1] = 0; // lane 1, qword 0: no match
        x[TILE] = !0; // lane 0, qword 1: full match again
        x[TILE + 2] = 0xFFFF_FFFF; // lane 2, qword 1: half match
        let acc = score_tile_scalar(&row, &x);
        assert_eq!(acc[0], 128, "lane 0: two full-match qwords");
        assert_eq!(acc[1], 0, "lane 1: zero vs all-ones never matches");
        assert_eq!(acc[2], 32, "lane 2: only the low half of qword 1 matches");
        assert_eq!(acc[7], 0, "untouched lanes score zero against all-ones");
    }

    #[test]
    fn dispatched_tile_scorer_matches_scalar_on_every_path() {
        // Deterministic pseudo-random rows/stripes; compare the dispatch
        // (vector where available) against the scalar reference.
        let mut state = 0x9E37_79B9_7F4A_7C15u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for qwords in [1usize, 2, 3, 5, 8, 13] {
            let row: Vec<u64> = (0..qwords).map(|_| next()).collect();
            let x: Vec<u64> = (0..qwords * TILE).map(|_| next()).collect();
            let want = score_tile_scalar(&row, &x);
            for use_simd in [false, resolve(KernelPath::Simd)] {
                assert_eq!(score_tile(&row, &x, use_simd), want, "qwords={qwords}");
            }
        }
    }
}

//! Bit-exact execution of Algorithm 1 (the hot path of the whole crate).
//!
//! The inner loop pairs 32-bit words into `u64` lanes and uses the native
//! `popcnt` (`u64::count_ones`), mirroring the paper's `bnn-exec` which
//! uses the widest registers the CPU offers (the NFP uses 32-bit words —
//! its cost model accounts for that separately; the *numbers* are
//! identical either way).

use std::sync::Arc;

use super::{BnnLayer, BnnModel};

/// Popcount-sum score of one neuron: `Σ popcount(XNOR(w, x))`.
#[inline]
pub fn neuron_score(weights: &[u32], x: &[u32]) -> i32 {
    debug_assert_eq!(weights.len(), x.len());
    let mut acc: u32 = 0;
    let mut chunks_w = weights.chunks_exact(2);
    let mut chunks_x = x.chunks_exact(2);
    for (w2, x2) in (&mut chunks_w).zip(&mut chunks_x) {
        let w = (w2[0] as u64) | ((w2[1] as u64) << 32);
        let v = (x2[0] as u64) | ((x2[1] as u64) << 32);
        acc += (!(w ^ v)).count_ones();
    }
    if let ([w], [v]) = (chunks_w.remainder(), chunks_x.remainder()) {
        acc += (!(w ^ v)).count_ones();
    }
    acc as i32
}

/// One packed binary FC layer: scores → sign bits packed into `out`.
///
/// `out` must hold `layer.out_words()` words; unused high bits are zero.
pub fn layer_forward(layer: &BnnLayer, x: &[u32], out: &mut [u32]) {
    debug_assert_eq!(x.len(), layer.in_words);
    debug_assert!(out.len() >= layer.out_words());
    out[..layer.out_words()].fill(0);
    for neuron in 0..layer.neurons {
        let s = neuron_score(layer.row(neuron), x);
        if s >= layer.threshold {
            out[neuron / 32] |= 1 << (neuron % 32);
        }
    }
}

/// Final-layer raw scores (no sign), one per output neuron.
pub fn layer_scores(layer: &BnnLayer, x: &[u32], scores: &mut [i32]) {
    debug_assert_eq!(x.len(), layer.in_words);
    for neuron in 0..layer.neurons {
        scores[neuron] = neuron_score(layer.row(neuron), x);
    }
}

/// Full-model inference returning the final layer's integer scores.
pub fn infer_scores(model: &BnnModel, x: &[u32]) -> Vec<i32> {
    let mut scores = vec![0i32; model.out_neurons()];
    let mut exec = BnnExecutor::new(model.clone());
    exec.infer(x, &mut scores);
    scores
}

/// Full-model inference returning the predicted class (argmax, ties low).
pub fn infer_packed(model: &BnnModel, x: &[u32]) -> usize {
    let scores = infer_scores(model, x);
    argmax(&scores)
}

/// Argmax with ties resolved to the lowest index (matches jnp.argmax).
#[inline]
pub fn argmax(scores: &[i32]) -> usize {
    let mut best = 0;
    for (i, &s) in scores.iter().enumerate().skip(1) {
        if s > scores[best] {
            best = i;
        }
    }
    best
}

/// One layer with weights repacked into u64 qwords (perf pass, see
/// EXPERIMENTS.md §Perf: one `popcnt` per 64 synapses with no per-call
/// pairing work; odd word counts are zero-padded once at build time —
/// XNOR over a zero pad adds a constant `32` per pad qword to every
/// neuron's score, which cancels in the sign comparison only if counted,
/// so the pad contribution is subtracted via `pad_bias`).
///
/// Shared crate-wide (behind an `Arc`) between the single-input executor,
/// the weight-stationary batch kernel, and the sharded engine's workers,
/// so N executors over one model hold one copy of the packed weights.
pub(crate) struct Layer64 {
    pub(crate) neurons: usize,
    pub(crate) qwords: usize,
    pub(crate) threshold: i32,
    /// Score bias from padded qwords: popcount(XNOR(0,0)) per pad word.
    pub(crate) pad_bias: i32,
    pub(crate) rows: Vec<u64>,
}

impl Layer64 {
    pub(crate) fn new(layer: &BnnLayer) -> Self {
        let qwords = layer.in_words.div_ceil(2);
        let mut rows = vec![0u64; layer.neurons * qwords];
        for n in 0..layer.neurons {
            let src = layer.row(n);
            for (q, chunk) in src.chunks(2).enumerate() {
                rows[n * qwords + q] = qword(chunk);
            }
        }
        // A pad half-qword holds 0 in both x and w → XNOR = all ones in
        // the upper 32 bits → +32 per neuron, uniformly.
        let pad_bias = if layer.in_words % 2 == 1 { 32 } else { 0 };
        Self {
            neurons: layer.neurons,
            qwords,
            threshold: layer.threshold,
            pad_bias,
            rows,
        }
    }

    #[inline]
    pub(crate) fn row(&self, n: usize) -> &[u64] {
        &self.rows[n * self.qwords..(n + 1) * self.qwords]
    }

    /// Packed activation qwords this layer produces (64 sign bits each).
    #[inline]
    pub(crate) fn out_qwords(&self) -> usize {
        self.neurons.div_ceil(64)
    }
}

/// One model's weights in the shared qword form, plus the two dimensions
/// every batch consumer needs (input width for packing, output width for
/// the score/verdict buffers).  This is the crate's unit of *immutable
/// deployed weights*: the single-input executor, the batch kernel, the
/// sharded engine's workers, and the registry's published epochs all
/// hold `Arc<PackedModel>` handles to one copy.  Because a `PackedModel`
/// is never mutated after construction, "which weights did this batch
/// run under" is always answerable by pointer identity — the property
/// the hot-swap registry builds on.
pub(crate) struct PackedModel {
    pub(crate) in_words: usize,
    pub(crate) out_neurons: usize,
    pub(crate) layers: Vec<Layer64>,
}

impl PackedModel {
    pub(crate) fn arc(model: &BnnModel) -> Arc<Self> {
        Arc::new(Self {
            in_words: model.in_words(),
            out_neurons: model.out_neurons(),
            layers: model.layers.iter().map(Layer64::new).collect(),
        })
    }

    /// Largest qword buffer any layer of this model needs (activation
    /// double-buffer sizing, shared by the executor and batch kernel).
    pub(crate) fn max_qwords(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.qwords.max(l.out_qwords()))
            .max()
            .unwrap_or(1)
    }
}

/// Pair two u32 words (or one word + zero pad) into one u64 qword — the
/// single definition of the crate's word-pairing convention (lo word in
/// the low half).  `chunk` comes from `chunks(2)`: one or two words.
#[inline]
pub(crate) fn qword(chunk: &[u32]) -> u64 {
    let lo = chunk[0] as u64;
    let hi = if chunk.len() == 2 { chunk[1] as u64 } else { 0 };
    lo | (hi << 32)
}

/// Hot-loop score over prepacked qwords.  (§Perf iter 2 tried 4-way
/// manual unrolling for popcnt ILP; it measured *slower* on this host —
/// LLVM already vectorizes the simple form — so the simple loop stays.)
#[inline]
pub(crate) fn score_u64(w: &[u64], x: &[u64]) -> i32 {
    let mut acc = 0u32;
    for (a, b) in w.iter().zip(x) {
        acc += (!(a ^ b)).count_ones();
    }
    acc as i32
}

/// Reusable executor with preallocated activation buffers and u64-packed
/// weights (hot-path form; `infer` does zero allocation).
pub struct BnnExecutor {
    model: BnnModel,
    packed: Arc<PackedModel>,
    /// Double buffer large enough for any layer's packed activations.
    buf_a: Vec<u64>,
    buf_b: Vec<u64>,
}

impl BnnExecutor {
    pub fn new(model: BnnModel) -> Self {
        let packed = PackedModel::arc(&model);
        let max_q = packed.max_qwords();
        Self {
            model,
            packed,
            buf_a: vec![0; max_q],
            buf_b: vec![0; max_q],
        }
    }

    pub fn model(&self) -> &BnnModel {
        &self.model
    }

    /// Handle to the shared packed weights (for batch kernels that want
    /// to reuse them instead of repacking).
    pub(crate) fn packed_model(&self) -> Arc<PackedModel> {
        Arc::clone(&self.packed)
    }

    /// Pack a u32-word input into the executor's qword buffer.
    #[inline]
    fn pack_input(x: &[u32], out: &mut [u64]) {
        for (q, chunk) in x.chunks(2).enumerate() {
            out[q] = qword(chunk);
        }
    }

    /// Hidden layer over qwords: sign bits packed into the u64 output
    /// buffer (bit n of the logical output in qword n/64).
    fn layer64_forward(layer: &Layer64, x: &[u64], out: &mut [u64]) {
        let out_q = layer.neurons.div_ceil(64);
        out[..out_q].fill(0);
        for n in 0..layer.neurons {
            let s = score_u64(layer.row(n), x) - layer.pad_bias;
            if s >= layer.threshold {
                out[n / 64] |= 1 << (n % 64);
            }
        }
    }

    /// Run one inference; writes final-layer scores into `scores`.
    pub fn infer(&mut self, x: &[u32], scores: &mut [i32]) {
        let n_layers = self.packed.layers.len();
        debug_assert_eq!(scores.len(), self.model.out_neurons());
        let l0 = &self.packed.layers[0];
        debug_assert_eq!(x.len(), self.model.layers[0].in_words);
        Self::pack_input(x, &mut self.buf_a[..l0.qwords]);
        if n_layers == 1 {
            for (n, s) in scores.iter_mut().enumerate() {
                *s = score_u64(l0.row(n), &self.buf_a[..l0.qwords]) - l0.pad_bias;
            }
            return;
        }
        Self::layer64_forward(l0, &self.buf_a[..l0.qwords], &mut self.buf_b);
        let mut cur_in_b = true;
        for k in 1..n_layers - 1 {
            let layer = &self.packed.layers[k];
            let (src, dst) = if cur_in_b {
                (&self.buf_b, &mut self.buf_a)
            } else {
                (&self.buf_a, &mut self.buf_b)
            };
            Self::layer64_forward(layer, &src[..layer.qwords], dst);
            cur_in_b = !cur_in_b;
        }
        let last = &self.packed.layers[n_layers - 1];
        let src = if cur_in_b { &self.buf_b } else { &self.buf_a };
        for (n, s) in scores.iter_mut().enumerate() {
            *s = score_u64(last.row(n), &src[..last.qwords]) - last.pad_bias;
        }
    }

    /// Convenience: inference → class.
    pub fn classify(&mut self, x: &[u32]) -> usize {
        let mut scores = vec![0i32; self.model.out_neurons()];
        self.infer(x, &mut scores);
        argmax(&scores)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::BnnModel;

    /// Naive per-bit reference used only by these tests.
    fn naive_score(w: &[u32], x: &[u32]) -> i32 {
        let mut s = 0;
        for (a, b) in w.iter().zip(x) {
            for bit in 0..32 {
                let wa = (a >> bit) & 1;
                let xb = (b >> bit) & 1;
                if wa == xb {
                    s += 1;
                }
            }
        }
        s
    }

    #[test]
    fn neuron_score_matches_naive() {
        let layer = BnnLayer::random(4, 152, 3);
        let xs: Vec<Vec<u32>> = (0..8)
            .map(|i| BnnLayer::random(1, 152, 100 + i).words)
            .collect();
        for x in &xs {
            for n in 0..4 {
                assert_eq!(neuron_score(layer.row(n), x), naive_score(layer.row(n), x));
            }
        }
    }

    #[test]
    fn odd_word_count_handled() {
        // 5 words (152 bits) exercises the u64-pairing remainder path.
        let w = vec![0xFFFF_FFFF; 5];
        let x = vec![0xFFFF_FFFF; 5];
        assert_eq!(neuron_score(&w, &x), 160);
        let x0 = vec![0u32; 5];
        assert_eq!(neuron_score(&w, &x0), 0);
    }

    #[test]
    fn layer_forward_packs_signs() {
        let mut layer = BnnLayer::random(33, 64, 9);
        // Force neuron 0 to fire (weights == input) and neuron 32 to not.
        let x = BnnLayer::random(1, 64, 77).words;
        layer.words[0..2].copy_from_slice(&x);
        for w in layer.words[32 * 2..33 * 2].iter_mut() {
            *w = !x[0]; // all mismatched vs x[0]... close enough to 0 score
        }
        let mut out = vec![0u32; layer.out_words()];
        layer_forward(&layer, &x, &mut out);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0] & 1, 1, "identical weights must fire");
        for n in 0..33 {
            let s = neuron_score(layer.row(n), &x);
            let bit = (out[n / 32] >> (n % 32)) & 1;
            assert_eq!(bit == 1, s >= layer.threshold, "neuron {n}");
        }
    }

    #[test]
    fn executor_matches_functional_path() {
        let model = BnnModel::random("m", 256, &[32, 16, 2], 42);
        let x = BnnLayer::random(1, 256, 5).words;
        let mut exec = BnnExecutor::new(model.clone());
        let mut scores = vec![0i32; 2];
        exec.infer(&x, &mut scores);
        assert_eq!(scores, infer_scores(&model, &x));
        assert_eq!(exec.classify(&x), infer_packed(&model, &x));
    }

    #[test]
    fn single_layer_model() {
        let model = BnnModel::random("fc", 256, &[64], 3);
        let x = BnnLayer::random(1, 256, 8).words;
        let scores = infer_scores(&model, &x);
        assert_eq!(scores.len(), 64);
        for (n, &s) in scores.iter().enumerate() {
            assert_eq!(s, neuron_score(model.layers[0].row(n), &x));
        }
    }

    #[test]
    fn argmax_ties_low() {
        assert_eq!(argmax(&[3, 3]), 0);
        assert_eq!(argmax(&[1, 5, 5]), 1);
        assert_eq!(argmax(&[7]), 0);
    }
}

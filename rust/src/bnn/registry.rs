//! Multi-model registry with zero-downtime weight hot-swap (PAPER §4:
//! the control plane updates NN weights at runtime while the data plane
//! keeps forwarding).
//!
//! The registry holds **named model slots** (`anomaly`, `traffic-class`,
//! `tomography`, … — tab01's use cases coexisting in one process), each
//! an append-only sequence of versioned [`ModelEpoch`]s.  A `publish`
//! replaces a slot's current epoch atomically; nothing in the serving
//! path ever blocks on it, drains, or restarts.
//!
//! ## Consistency model
//!
//! * **Epochs are immutable.**  An epoch wraps an
//!   [`Arc<PackedModel>`](super::exec::PackedModel) built once at publish
//!   time; weights are never mutated in place, so "which weights did this
//!   inference run under" is always answerable by the epoch handle — the
//!   [`VersionTag`] every verdict carries.
//! * **Reads are lock-free on the hot path.**  A [`SlotReader`] caches
//!   the epoch `Arc` it last saw and polls one atomic version counter per
//!   [`pin`](SlotReader::pin); the un-swapped steady state costs a single
//!   `Acquire` load and a pointer clone.  Only the pin that first
//!   observes a new version touches the slot's lock to refresh its cache.
//! * **Pins are freshness-monotonic.**  `publish` installs the new epoch
//!   *before* releasing the version counter, so once `publish(name, m)`
//!   returns, every subsequent `pin` on that slot observes version ≥ the
//!   published one — the property the deterministic replay test in
//!   `tests/registry_swap.rs` leans on.
//! * **One batch, one version.**  A batch pins exactly one epoch and
//!   ships that epoch's `Arc<PackedModel>` to every consumer — including
//!   all shards of a [`ShardedEngine`] batch, which receive clones of the
//!   *same* handle in their jobs — so a concurrent publish can only
//!   affect the next batch, never tear an in-flight one.
//! * **Shapes are publish-stable.**  Republishing a slot with a different
//!   input width or class count is rejected ([`RegistryError`]): in-flight
//!   routing and feature packing are keyed to the slot's shape, and a
//!   shape change mid-stream would poison every reader's scratch.
//!
//! `tests/registry_swap.rs` hammers all of this from writer threads while
//! single-input, sharded-engine, and pipeline readers classify.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use super::batch::BatchKernel;
use super::engine::{EngineError, EngineStats, ShardedEngine};
use super::exec::PackedModel;
use super::BnnModel;

/// The `(name, version)` a verdict ran under.  Cheap to clone (the name
/// is a shared `Arc<str>`); equality and hashing are by value.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct VersionTag {
    name: Arc<str>,
    version: u64,
}

impl VersionTag {
    /// Slot name this tag belongs to.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Version within the slot (first publish = 1, monotonically +1).
    pub fn version(&self) -> u64 {
        self.version
    }
}

impl std::fmt::Display for VersionTag {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}@v{}", self.name, self.version)
    }
}

/// One published, immutable deployment of a model: the packed weights
/// plus the tag identifying them.  Everything that scores against this
/// epoch observes exactly these weights — there is no way to mutate them
/// short of publishing a successor epoch.
pub struct ModelEpoch {
    tag: VersionTag,
    pub(crate) packed: Arc<PackedModel>,
}

impl ModelEpoch {
    pub fn tag(&self) -> &VersionTag {
        &self.tag
    }

    pub fn name(&self) -> &str {
        self.tag.name()
    }

    pub fn version(&self) -> u64 {
        self.tag.version()
    }

    /// Packed input words the deployed model expects.
    pub fn in_words(&self) -> usize {
        self.packed.in_words
    }

    /// Output classes of the deployed model.
    pub fn out_neurons(&self) -> usize {
        self.packed.out_neurons
    }
}

impl std::fmt::Debug for ModelEpoch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelEpoch")
            .field("tag", &self.tag)
            .field("in_words", &self.packed.in_words)
            .field("out_neurons", &self.packed.out_neurons)
            .finish()
    }
}

/// One named slot: the current epoch behind a lock, plus the lock-free
/// version counter readers poll before touching the lock.
struct Slot {
    /// Latest published version.  Stored with `Release` *after* the epoch
    /// is installed, loaded with `Acquire` by readers — a reader that
    /// sees version `v` here will read an epoch ≥ `v` from the lock.
    version: AtomicU64,
    /// Hot-swap count: publishes that *replaced* a live epoch (i.e. all
    /// but the first).
    swaps: AtomicU64,
    epoch: RwLock<Arc<ModelEpoch>>,
}

/// Failure modes of registry operations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RegistryError {
    /// No slot with this name has ever been published.
    UnknownModel(String),
    /// Republish attempted with a different input width or class count
    /// than the slot's live epoch — rejected to protect in-flight
    /// readers, whose scratch and routing are keyed to the shape.
    ShapeMismatch {
        name: String,
        expected_in_words: usize,
        expected_classes: usize,
        got_in_words: usize,
        got_classes: usize,
    },
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::UnknownModel(name) => {
                write!(f, "no model published under {name:?}")
            }
            RegistryError::ShapeMismatch {
                name,
                expected_in_words,
                expected_classes,
                got_in_words,
                got_classes,
            } => write!(
                f,
                "hot-swap of {name:?} changes shape: slot serves \
                 {expected_in_words}w→{expected_classes} classes, \
                 publish offered {got_in_words}w→{got_classes}"
            ),
        }
    }
}

impl std::error::Error for RegistryError {}

/// Versioned, named model slots with atomic publish and lock-free reads.
/// Shared across threads behind an `Arc` — see [`RegistryHandle`].
#[derive(Default)]
pub struct ModelRegistry {
    slots: RwLock<BTreeMap<String, Arc<Slot>>>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Publish `model` under `name`: version 1 creates the slot, later
    /// publishes hot-swap it without draining any reader.  The new epoch
    /// is visible to every subsequent [`SlotReader::pin`] as soon as
    /// this returns; in-flight batches finish on the epoch they pinned.
    pub fn publish(&self, name: &str, model: &BnnModel) -> Result<VersionTag, RegistryError> {
        // Packing is the expensive part — do it outside every lock.
        let packed = PackedModel::arc(model);
        let existing = self.slots.read().unwrap().get(name).cloned();
        let slot = match existing {
            Some(slot) => slot,
            None => {
                let mut slots = self.slots.write().unwrap();
                // Re-check: another publisher may have created the slot
                // between the read and write locks.
                match slots.get(name) {
                    Some(slot) => Arc::clone(slot),
                    None => {
                        let tag = VersionTag { name: Arc::from(name), version: 1 };
                        let epoch = Arc::new(ModelEpoch { tag: tag.clone(), packed });
                        slots.insert(
                            name.to_string(),
                            Arc::new(Slot {
                                version: AtomicU64::new(1),
                                swaps: AtomicU64::new(0),
                                epoch: RwLock::new(epoch),
                            }),
                        );
                        return Ok(tag);
                    }
                }
            }
        };
        // Swap path: writers serialize on the slot's epoch lock; readers
        // only take it on a version change, so the swap never contends
        // with steady-state pins.
        let mut epoch = slot.epoch.write().unwrap();
        if epoch.packed.in_words != packed.in_words
            || epoch.packed.out_neurons != packed.out_neurons
        {
            return Err(RegistryError::ShapeMismatch {
                name: name.to_string(),
                expected_in_words: epoch.packed.in_words,
                expected_classes: epoch.packed.out_neurons,
                got_in_words: packed.in_words,
                got_classes: packed.out_neurons,
            });
        }
        let version = epoch.version() + 1;
        let tag = VersionTag { name: Arc::clone(&epoch.tag.name), version };
        *epoch = Arc::new(ModelEpoch { tag: tag.clone(), packed });
        // Epoch first, counter second — and the counter store happens
        // *while still holding the write guard*: writers serialize on
        // the guard, so the counter stays monotone with the installed
        // epoch.  (Storing after dropping the guard would let a slower
        // writer's older store land on top of a faster writer's newer
        // one, stranding readers on a stale cached epoch.)  A reader
        // that observes the new version refreshes under the read lock
        // and therefore finds an epoch at least that new.
        slot.version.store(version, Ordering::Release);
        slot.swaps.fetch_add(1, Ordering::Relaxed);
        Ok(tag)
    }

    /// Hot-republish a slot's **current** weights as a new version:
    /// version +1, swap count +1, the packed weights `Arc` reused.
    /// Readers observe a fresh epoch with identical verdict semantics —
    /// the cheapest way to exercise the swap machinery live (the serve
    /// runtime's `.swap_every(n)` knob is built on this).
    pub fn touch(&self, name: &str) -> Result<VersionTag, RegistryError> {
        let slot = self
            .slots
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| RegistryError::UnknownModel(name.to_string()))?;
        let mut epoch = slot.epoch.write().unwrap();
        let version = epoch.version() + 1;
        let tag = VersionTag { name: Arc::clone(&epoch.tag.name), version };
        let packed = Arc::clone(&epoch.packed);
        *epoch = Arc::new(ModelEpoch { tag: tag.clone(), packed });
        // Same ordering discipline as `publish`: epoch first, counter
        // second, both under the write guard.
        slot.version.store(version, Ordering::Release);
        slot.swaps.fetch_add(1, Ordering::Relaxed);
        Ok(tag)
    }

    /// Republish a previously snapshotted epoch's weights as a **new**
    /// version of `name` — the registry stays monotone (versions never
    /// go backwards), only the weights do.  This is how the overload
    /// ladder's step-up restores the full model after a fallback
    /// publish: snapshot [`current`](Self::current) before stepping
    /// down, `rollback` on recovery.  Same shape check and
    /// write-ordering discipline as [`publish`](Self::publish); the
    /// packed weights `Arc` is reused, so no repacking happens on the
    /// recovery path.
    pub fn rollback(&self, name: &str, epoch: &ModelEpoch) -> Result<VersionTag, RegistryError> {
        let packed = Arc::clone(&epoch.packed);
        let slot = self
            .slots
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| RegistryError::UnknownModel(name.to_string()))?;
        let mut cur = slot.epoch.write().unwrap();
        if cur.packed.in_words != packed.in_words || cur.packed.out_neurons != packed.out_neurons {
            return Err(RegistryError::ShapeMismatch {
                name: name.to_string(),
                expected_in_words: cur.packed.in_words,
                expected_classes: cur.packed.out_neurons,
                got_in_words: packed.in_words,
                got_classes: packed.out_neurons,
            });
        }
        let version = cur.version() + 1;
        let tag = VersionTag { name: Arc::clone(&cur.tag.name), version };
        *cur = Arc::new(ModelEpoch { tag: tag.clone(), packed });
        // Same ordering discipline as `publish`: epoch first, counter
        // second, both under the write guard.
        slot.version.store(version, Ordering::Release);
        slot.swaps.fetch_add(1, Ordering::Relaxed);
        Ok(tag)
    }

    /// A hot-path reader bound to one slot.
    pub fn reader(&self, name: &str) -> Result<SlotReader, RegistryError> {
        let slot = self
            .slots
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| RegistryError::UnknownModel(name.to_string()))?;
        let cached = slot.epoch.read().unwrap().clone();
        Ok(SlotReader { slot, cached })
    }

    /// Control-plane read of a slot's current epoch (takes the lock —
    /// fine off the hot path).
    pub fn current(&self, name: &str) -> Option<Arc<ModelEpoch>> {
        let slot = self.slots.read().unwrap().get(name).cloned()?;
        let epoch = slot.epoch.read().unwrap().clone();
        Some(epoch)
    }

    /// Latest version per slot.
    pub fn versions(&self) -> BTreeMap<String, u64> {
        self.slots
            .read()
            .unwrap()
            .iter()
            .map(|(n, s)| (n.clone(), s.version.load(Ordering::Acquire)))
            .collect()
    }

    /// Hot swaps (publishes beyond the first) a slot has absorbed.
    pub fn swap_count(&self, name: &str) -> u64 {
        self.slots
            .read()
            .unwrap()
            .get(name)
            .map_or(0, |s| s.swaps.load(Ordering::Relaxed))
    }

    pub fn names(&self) -> Vec<String> {
        self.slots.read().unwrap().keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.slots.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Cloneable control-channel handle to a shared [`ModelRegistry`]: the
/// serving loop holds one, and so can any control thread that wants to
/// publish retrained weights while traffic flows (`serve --swap-every`
/// demonstrates exactly that).
#[derive(Clone, Default)]
pub struct RegistryHandle(Arc<ModelRegistry>);

impl RegistryHandle {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn publish(&self, name: &str, model: &BnnModel) -> Result<VersionTag, RegistryError> {
        self.0.publish(name, model)
    }

    pub fn touch(&self, name: &str) -> Result<VersionTag, RegistryError> {
        self.0.touch(name)
    }

    pub fn rollback(&self, name: &str, epoch: &ModelEpoch) -> Result<VersionTag, RegistryError> {
        self.0.rollback(name, epoch)
    }

    pub fn reader(&self, name: &str) -> Result<SlotReader, RegistryError> {
        self.0.reader(name)
    }

    pub fn current(&self, name: &str) -> Option<Arc<ModelEpoch>> {
        self.0.current(name)
    }

    pub fn versions(&self) -> BTreeMap<String, u64> {
        self.0.versions()
    }

    pub fn swap_count(&self, name: &str) -> u64 {
        self.0.swap_count(name)
    }

    pub fn names(&self) -> Vec<String> {
        self.0.names()
    }
}

/// Hot-path reader of one slot: caches the last epoch `Arc` it saw and
/// revalidates with a single atomic load per [`pin`](Self::pin) — the
/// lock-free read the registry promises.  Each consumer (kernel owner,
/// shard feeder, pipeline stage) holds its own reader; readers never
/// coordinate with each other.
pub struct SlotReader {
    slot: Arc<Slot>,
    cached: Arc<ModelEpoch>,
}

impl SlotReader {
    /// Slot name this reader is bound to.
    pub fn name(&self) -> &str {
        self.cached.name()
    }

    /// The epoch as of the last `pin` — no synchronization, may be one
    /// publish behind.  Shape queries are safe here (shapes are
    /// publish-stable); version queries are not.
    pub fn snapshot(&self) -> &ModelEpoch {
        &self.cached
    }

    /// Pin the slot's current epoch for a unit of work (one inference or
    /// one whole batch).  Everything scored against the returned epoch —
    /// across every shard it is shipped to — sees exactly its weights;
    /// a publish that lands after this pin affects only later pins.
    ///
    /// Steady state (no publish since the last pin): one `Acquire` load
    /// plus an `Arc` clone; no lock.
    pub fn pin(&mut self) -> Arc<ModelEpoch> {
        if self.slot.version.load(Ordering::Acquire) != self.cached.version() {
            self.cached = self.slot.epoch.read().unwrap().clone();
        }
        Arc::clone(&self.cached)
    }
}

/// Versioned multi-model executor: one [`SlotReader`] per routed model,
/// one retargetable [`BatchKernel`] (and optionally a [`ShardedEngine`])
/// shared across them.  Every classification pins an epoch first and
/// returns the [`VersionTag`] it ran under — the serving layers thread
/// that tag through to the verdict sinks.
pub struct MultiModelExecutor {
    readers: Vec<SlotReader>,
    kernel: BatchKernel,
    engine: Option<ShardedEngine>,
    latency_ns: f64,
}

impl MultiModelExecutor {
    /// Bind to `names` (route index = position in `names`); every name
    /// must already be published.  `latency_ns` is the modeled per-
    /// inference device latency reported to the serving metrics.
    pub fn new(
        handle: &RegistryHandle,
        names: &[String],
        latency_ns: f64,
    ) -> Result<Self, RegistryError> {
        assert!(!names.is_empty(), "MultiModelExecutor needs at least one model");
        let mut readers = Vec::with_capacity(names.len());
        for name in names {
            readers.push(handle.reader(name)?);
        }
        let first = readers[0].pin();
        Ok(Self {
            kernel: BatchKernel::with_packed(Arc::clone(&first.packed)),
            readers,
            engine: None,
            latency_ns,
        })
    }

    /// Route batches through a [`ShardedEngine`] of `n_shards` workers.
    /// Each batch still pins one epoch; its packed handle is shipped in
    /// every shard's job, so shards cannot diverge within a batch.
    pub fn sharded(mut self, n_shards: usize) -> Self {
        if n_shards > 1 {
            let epoch = self.readers[0].pin();
            self.engine = Some(ShardedEngine::with_packed(
                Arc::clone(&epoch.packed),
                n_shards,
            ));
        }
        self
    }

    pub fn n_models(&self) -> usize {
        self.readers.len()
    }

    pub fn model_name(&self, route: usize) -> &str {
        self.readers[route].name()
    }

    /// Widest class count across the bound models (verdict-histogram
    /// sizing; shapes are publish-stable so the snapshot is authoritative).
    pub fn max_out_neurons(&self) -> usize {
        self.readers
            .iter()
            .map(|r| r.snapshot().out_neurons())
            .max()
            .unwrap_or(1)
    }

    /// Pin and return route's current epoch (test/inspection hook).
    pub fn epoch(&mut self, route: usize) -> Arc<ModelEpoch> {
        self.readers[route].pin()
    }

    /// Classify one input under route's current epoch.
    pub fn classify(&mut self, route: usize, x: &[u32]) -> (usize, VersionTag) {
        let epoch = self.readers[route].pin();
        // Pointer-equal in the un-swapped steady state — a no-op.
        self.kernel.retarget(&epoch.packed);
        (self.kernel.classify_one(x), epoch.tag().clone())
    }

    /// Classify a whole batch under **one** pinned epoch of `route`;
    /// `classes` is cleared and refilled in input order.  The returned
    /// tag is the single version every verdict of this batch ran under —
    /// including across engine shards.
    pub fn classify_batch(
        &mut self,
        route: usize,
        inputs: &[Vec<u32>],
        classes: &mut Vec<usize>,
    ) -> VersionTag {
        match self.try_classify_batch(route, inputs, classes) {
            Ok(tag) => tag,
            Err(e) => panic!("{e}"),
        }
    }

    /// Fallible variant of [`classify_batch`](Self::classify_batch): a
    /// dead or panicked engine shard surfaces as `Err` instead of a
    /// panic.  `classes` contents are unspecified on error.
    pub fn try_classify_batch(
        &mut self,
        route: usize,
        inputs: &[Vec<u32>],
        classes: &mut Vec<usize>,
    ) -> Result<VersionTag, EngineError> {
        let epoch = self.readers[route].pin();
        match self.engine.as_mut() {
            Some(engine) => {
                // The engine's job fan-out needs the batch behind an
                // `Arc`, and workers may still hold their job clones
                // for an instant after the gather returns, so the
                // caller's scratch buffer cannot be lent and reclaimed
                // (`Arc::try_unwrap` would be flaky) — one copy per
                // sharded batch is the price; the kernel path below
                // borrows the slices directly.
                engine.try_run_batch_epoch(&epoch, &Arc::new(inputs.to_vec()), classes)?;
            }
            None => {
                self.kernel.retarget(&epoch.packed);
                self.kernel.run_batch(inputs, classes);
            }
        }
        Ok(epoch.tag().clone())
    }

    /// Modeled per-inference device latency (ns).
    pub fn latency_ns(&self) -> f64 {
        self.latency_ns
    }

    /// Modeled completion time of a batch of `b` (serial-device model,
    /// matching [`InferencePlane`](crate::coordinator::InferencePlane)'s
    /// default).
    pub fn batch_latency_ns(&self, b: usize) -> f64 {
        self.latency_ns * b as f64
    }

    /// Underlying sharded-engine counters, when batches route through one.
    pub fn engine_stats(&self) -> Option<EngineStats> {
        self.engine.as_ref().map(|e| e.stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::{infer_packed, BnnLayer};

    fn model(seed: u64) -> BnnModel {
        BnnModel::random("anomaly", 256, &[32, 16, 2], seed)
    }

    fn handle_with(name: &str, seed: u64) -> RegistryHandle {
        let h = RegistryHandle::new();
        h.publish(name, &model(seed)).unwrap();
        h
    }

    #[test]
    fn publish_versions_are_dense_and_monotonic() {
        let h = handle_with("anomaly", 1);
        assert_eq!(h.versions()["anomaly"], 1);
        assert_eq!(h.swap_count("anomaly"), 0);
        for v in 2..=5u64 {
            let tag = h.publish("anomaly", &model(v)).unwrap();
            assert_eq!((tag.name(), tag.version()), ("anomaly", v));
        }
        assert_eq!(h.versions()["anomaly"], 5);
        assert_eq!(h.swap_count("anomaly"), 4);
        assert_eq!(h.current("anomaly").unwrap().version(), 5);
    }

    #[test]
    fn slots_are_independent() {
        let h = handle_with("anomaly", 1);
        h.publish("traffic-class", &model(9)).unwrap();
        h.publish("anomaly", &model(2)).unwrap();
        assert_eq!(h.versions()["anomaly"], 2);
        assert_eq!(h.versions()["traffic-class"], 1);
        assert_eq!(h.names(), vec!["anomaly".to_string(), "traffic-class".to_string()]);
    }

    #[test]
    fn unknown_model_is_an_error_not_a_panic() {
        let h = handle_with("anomaly", 1);
        assert_eq!(
            h.reader("nope").unwrap_err(),
            RegistryError::UnknownModel("nope".into())
        );
        assert!(h.current("nope").is_none());
        assert_eq!(h.swap_count("nope"), 0);
    }

    #[test]
    fn shape_changing_republish_is_rejected() {
        let h = handle_with("anomaly", 1);
        let narrow = BnnModel::random("anomaly", 64, &[8, 2], 3);
        let err = h.publish("anomaly", &narrow).unwrap_err();
        assert!(matches!(err, RegistryError::ShapeMismatch { .. }), "{err}");
        let more_classes = BnnModel::random("anomaly", 256, &[32, 16, 4], 3);
        assert!(h.publish("anomaly", &more_classes).is_err());
        // The slot still serves v1.
        assert_eq!(h.current("anomaly").unwrap().version(), 1);
    }

    #[test]
    fn rollback_restores_a_snapshotted_epoch_as_a_new_version() {
        let h = handle_with("anomaly", 1);
        let snap = h.current("anomaly").unwrap();
        h.publish("anomaly", &model(2)).unwrap();
        let tag = h.rollback("anomaly", &snap).unwrap();
        // Monotone: the rollback is version 3, not a return to 1...
        assert_eq!((tag.name(), tag.version()), ("anomaly", 3));
        assert_eq!(h.swap_count("anomaly"), 2);
        // ...but it serves the snapshotted weights bit-exactly.
        let x = BnnLayer::random(1, 256, 77).words;
        let mut exec =
            MultiModelExecutor::new(&h, &["anomaly".to_string()], 100.0).unwrap();
        let (class, served) = exec.classify(0, &x);
        assert_eq!(served.version(), 3);
        assert_eq!(class, infer_packed(&model(1), &x));
        // Shape-checked like any publish, and unknown slots are typed
        // errors.
        let other = RegistryHandle::new();
        other.publish("w", &BnnModel::random("w", 64, &[8, 2], 3)).unwrap();
        let wrong = other.current("w").unwrap();
        assert!(matches!(
            h.rollback("anomaly", &wrong).unwrap_err(),
            RegistryError::ShapeMismatch { .. }
        ));
        assert!(matches!(
            h.rollback("nope", &snap).unwrap_err(),
            RegistryError::UnknownModel(_)
        ));
    }

    #[test]
    fn concurrent_publishers_keep_counter_and_epoch_in_lockstep() {
        // Regression: the version counter is stored while the epoch
        // write guard is held, so racing writers cannot leave the
        // counter behind the installed epoch (which would strand
        // readers on a stale cached epoch forever).
        let h = handle_with("anomaly", 1);
        let writers: Vec<_> = (0..4u64)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..25u64 {
                        h.publish("anomaly", &model(10 + t * 100 + i)).unwrap();
                    }
                })
            })
            .collect();
        for w in writers {
            w.join().unwrap();
        }
        // 1 initial publish + 4×25 concurrent ones.
        assert_eq!(h.versions()["anomaly"], 101);
        assert_eq!(h.current("anomaly").unwrap().version(), 101);
        assert_eq!(h.swap_count("anomaly"), 100);
        let mut r = h.reader("anomaly").unwrap();
        assert_eq!(r.pin().version(), 101);
    }

    #[test]
    fn pin_observes_a_publish_immediately() {
        let h = handle_with("anomaly", 1);
        let mut r = h.reader("anomaly").unwrap();
        assert_eq!(r.pin().version(), 1);
        h.publish("anomaly", &model(2)).unwrap();
        // Freshness: once publish returned, the next pin must see it.
        assert_eq!(r.pin().version(), 2);
        // And the snapshot is whatever the last pin cached.
        assert_eq!(r.snapshot().version(), 2);
    }

    #[test]
    fn executor_classifies_under_the_pinned_version() {
        let h = handle_with("anomaly", 1);
        let names = vec!["anomaly".to_string()];
        let mut exec = MultiModelExecutor::new(&h, &names, 100.0).unwrap();
        let xs: Vec<Vec<u32>> = (0..11)
            .map(|i| BnnLayer::random(1, 256, 500 + i).words)
            .collect();
        for (i, x) in xs.iter().enumerate() {
            let (class, tag) = exec.classify(0, x);
            assert_eq!(tag.version(), 1);
            assert_eq!(class, infer_packed(&model(1), x), "input {i}");
        }
        h.publish("anomaly", &model(2)).unwrap();
        let mut classes = Vec::new();
        let tag = exec.classify_batch(0, &xs, &mut classes);
        assert_eq!(tag.version(), 2);
        for (i, x) in xs.iter().enumerate() {
            assert_eq!(classes[i], infer_packed(&model(2), x), "input {i}");
        }
    }

    #[test]
    fn sharded_batches_carry_one_version_across_shards() {
        let h = handle_with("anomaly", 1);
        let names = vec!["anomaly".to_string()];
        let mut exec = MultiModelExecutor::new(&h, &names, 100.0).unwrap().sharded(4);
        let xs: Vec<Vec<u32>> = (0..37)
            .map(|i| BnnLayer::random(1, 256, 900 + i).words)
            .collect();
        for seed in 2..=4u64 {
            h.publish("anomaly", &model(seed)).unwrap();
            let mut classes = Vec::new();
            let tag = exec.classify_batch(0, &xs, &mut classes);
            assert_eq!(tag.version(), seed);
            // Every shard's verdicts must match the tagged version's
            // model — a shard on an older epoch would mismatch here.
            for (i, x) in xs.iter().enumerate() {
                assert_eq!(classes[i], infer_packed(&model(seed), x), "input {i}");
            }
        }
        assert_eq!(exec.engine_stats().unwrap().batches, 3);
    }

    #[test]
    fn two_routes_share_one_kernel_without_crosstalk() {
        let h = handle_with("anomaly", 1);
        // Different shape in the second slot: scratch must grow, verdicts
        // must stay per-model exact while alternating routes.
        h.publish("tomography", &BnnModel::random("tomography", 152, &[64, 32, 2], 7))
            .unwrap();
        let names = vec!["anomaly".to_string(), "tomography".to_string()];
        let mut exec = MultiModelExecutor::new(&h, &names, 100.0).unwrap();
        assert_eq!(exec.n_models(), 2);
        assert_eq!(exec.model_name(1), "tomography");
        let tomo = BnnModel::random("tomography", 152, &[64, 32, 2], 7);
        for i in 0..6u64 {
            let xa = BnnLayer::random(1, 256, 40 + i).words;
            let xt = BnnLayer::random(1, 152, 80 + i).words;
            let (ca, ta) = exec.classify(0, &xa);
            let (ct, tt) = exec.classify(1, &xt);
            assert_eq!(ca, infer_packed(&model(1), &xa));
            assert_eq!(ct, infer_packed(&tomo, &xt));
            assert_eq!((ta.name(), tt.name()), ("anomaly", "tomography"));
        }
    }

    #[test]
    fn touch_republishes_current_weights_as_a_new_version() {
        let h = handle_with("anomaly", 1);
        let x = BnnLayer::random(1, 256, 77).words;
        let want = infer_packed(&model(1), &x);
        let tag = h.touch("anomaly").unwrap();
        assert_eq!((tag.name(), tag.version()), ("anomaly", 2));
        assert_eq!(h.swap_count("anomaly"), 1);
        // Same weights serve at the new version: verdicts unchanged.
        let names = vec!["anomaly".to_string()];
        let mut exec = MultiModelExecutor::new(&h, &names, 100.0).unwrap();
        let (class, tag) = exec.classify(0, &x);
        assert_eq!(tag.version(), 2);
        assert_eq!(class, want);
        assert_eq!(
            h.touch("nope").unwrap_err(),
            RegistryError::UnknownModel("nope".into())
        );
    }

    #[test]
    fn tag_display_and_identity() {
        let h = handle_with("anomaly", 1);
        let tag = h.publish("anomaly", &model(2)).unwrap();
        assert_eq!(tag.to_string(), "anomaly@v2");
        let again = h.current("anomaly").unwrap().tag().clone();
        assert_eq!(tag, again);
    }
}

//! Weight-stationary batched kernel (the throughput half of §6).
//!
//! The single-input executor streams every weight row through the cache
//! once *per input*; at batch B that reads the weights B times.  This
//! kernel inverts the loop nest: inputs are packed into tiles of
//! [`TILE`] lanes, and the inner loop loads each weight qword **once**
//! and XNOR/popcnts it against all lanes of the tile, which are held in
//! a register-resident accumulator array.  Weights stay stationary; the
//! per-qword loop work (load, not, loop bookkeeping) is amortized over
//! the tile, and the `TILE` independent accumulator chains give the CPU
//! the instruction-level parallelism a single popcount chain cannot.
//!
//! Activation layout between layers is lane-interleaved — qword `q` of
//! lane `t` lives at `act[q * TILE + t]` — so the inner loop reads one
//! contiguous `TILE`-wide stripe per weight qword.
//!
//! The per-tile scoring loop itself lives in [`super::simd`]: kernels
//! resolve a [`KernelPath`] at construction and score tiles through
//! either the scalar reference loop or the AVX2 XNOR/popcount path
//! (`--features simd` + runtime detection) — both exact integer
//! arithmetic, bit-identical on every shape.
//!
//! Bit-exact with [`BnnExecutor::infer`](super::BnnExecutor): asserted
//! by `tests/batch_exact.rs` across odd word counts, ragged final tiles,
//! and every batch size the tests sweep.

use std::sync::Arc;

use super::exec::{argmax, qword, Layer64, PackedModel};
use super::simd::{self, KernelPath};
use super::BnnModel;

/// Inputs scored per weight-row pass.  8 lanes is a design estimate,
/// not yet a measurement (see EXPERIMENTS.md §Perf — this PR's build
/// container has no Rust toolchain): 8 u32 accumulators should fit the
/// x86-64 integer register file while giving LLVM a full vector-width
/// ctpop reduction; re-tune against `cargo bench --bench batch_engine`
/// on a real host before trusting the value.
pub const TILE: usize = 8;

/// Reusable weight-stationary batch executor.  All scratch (activation
/// tiles, score tile) is preallocated; `run_batch` does no allocation
/// beyond growing the caller's output vector.
///
/// The kernel can be **retargeted** at a different packed model between
/// batches ([`retarget`](Self::retarget)) — the registry's hot-swap path
/// and the sharded engine's per-batch weight shipping both rely on this.
/// Scratch buffers grow monotonically, so steady-state swapping between
/// a fixed set of models allocates nothing.
pub struct BatchKernel {
    packed: Arc<PackedModel>,
    /// Activation double buffer, lane-interleaved (`[qword][lane]`).
    act_a: Vec<u64>,
    act_b: Vec<u64>,
    /// Final-layer scores of the current tile, `[lane][neuron]`.
    scores: Vec<i32>,
    /// Resolved once at construction from a [`KernelPath`]: score tiles
    /// through the AVX2 XNOR/popcount loop (`simd` feature + runtime
    /// detection) or the scalar reference.  Both are bit-identical.
    use_simd: bool,
}

impl BatchKernel {
    pub fn new(model: &BnnModel) -> Self {
        Self::new_with_path(model, KernelPath::Auto)
    }

    /// Build with an explicit scoring path — the differential suite uses
    /// this to run `Scalar` and `Simd` kernels side by side on one model.
    pub fn new_with_path(model: &BnnModel, path: KernelPath) -> Self {
        Self::with_packed_path(PackedModel::arc(model), path)
    }

    /// Build on an existing packed-weight handle (shared with a
    /// [`BnnExecutor`](super::BnnExecutor) or sibling shard workers).
    pub(crate) fn with_packed(packed: Arc<PackedModel>) -> Self {
        Self::with_packed_path(packed, KernelPath::Auto)
    }

    pub(crate) fn with_packed_path(packed: Arc<PackedModel>, path: KernelPath) -> Self {
        let mut k = Self {
            packed,
            act_a: Vec::new(),
            act_b: Vec::new(),
            scores: Vec::new(),
            use_simd: simd::resolve(path),
        };
        k.grow_scratch();
        k
    }

    /// 64-bit qword lanes one vector op covers on this kernel's resolved
    /// path (4 = AVX2, 1 = scalar) — surfaced as `Capabilities::simd_lanes`.
    pub fn simd_lanes(&self) -> usize {
        if self.use_simd {
            4
        } else {
            1
        }
    }

    /// Point this kernel at a different packed model (a registry epoch's
    /// weights, or a shard job's).  Pointer-equal handles are a no-op,
    /// so the un-swapped steady state costs one pointer compare.
    pub(crate) fn retarget(&mut self, packed: &Arc<PackedModel>) {
        if Arc::ptr_eq(&self.packed, packed) {
            return;
        }
        self.packed = Arc::clone(packed);
        self.grow_scratch();
    }

    /// Size scratch for the current model, never shrinking — a kernel
    /// bouncing between models of different widths reaches a fixed point
    /// after one pass over the set.
    fn grow_scratch(&mut self) {
        let need_act = self.packed.max_qwords() * TILE;
        if self.act_a.len() < need_act {
            self.act_a.resize(need_act, 0);
            self.act_b.resize(need_act, 0);
        }
        let need_scores = TILE * self.packed.out_neurons;
        if self.scores.len() < need_scores {
            self.scores.resize(need_scores, 0);
        }
    }

    pub fn in_words(&self) -> usize {
        self.packed.in_words
    }

    pub fn out_neurons(&self) -> usize {
        self.packed.out_neurons
    }

    /// Classify a whole batch; `classes` is cleared and refilled with one
    /// verdict per input, in input order.
    pub fn run_batch<T: AsRef<[u32]>>(&mut self, inputs: &[T], classes: &mut Vec<usize>) {
        classes.clear();
        classes.reserve(inputs.len());
        let out_n = self.packed.out_neurons;
        for tile in inputs.chunks(TILE) {
            self.run_tile(tile);
            for t in 0..tile.len() {
                classes.push(argmax(&self.scores[t * out_n..(t + 1) * out_n]));
            }
        }
    }

    /// Classify one input — a 1-lane tile (the inline serving route when
    /// the caller is already kernel-shaped, e.g. the registry executor).
    pub fn classify_one(&mut self, x: &[u32]) -> usize {
        self.run_tile(std::slice::from_ref(&x));
        argmax(&self.scores[..self.packed.out_neurons])
    }

    /// Raw final-layer scores for a whole batch, row-major
    /// (`inputs.len() × out_neurons`), bit-exact with per-input `infer`.
    pub fn infer_batch_scores<T: AsRef<[u32]>>(
        &mut self,
        inputs: &[T],
        scores_out: &mut Vec<i32>,
    ) {
        let out_n = self.packed.out_neurons;
        scores_out.clear();
        scores_out.resize(inputs.len() * out_n, 0);
        for (i, tile) in inputs.chunks(TILE).enumerate() {
            self.run_tile(tile);
            let dst = &mut scores_out[i * TILE * out_n..][..tile.len() * out_n];
            dst.copy_from_slice(&self.scores[..tile.len() * out_n]);
        }
    }

    /// Run one tile of `≤ TILE` inputs; leaves the tile's final-layer
    /// scores in `self.scores` (`[lane][neuron]`).
    fn run_tile<T: AsRef<[u32]>>(&mut self, tile: &[T]) {
        debug_assert!(!tile.is_empty() && tile.len() <= TILE);
        let lanes = tile.len();
        self.pack_tile(tile);
        let n_layers = self.packed.layers.len();
        let mut cur_in_a = true;
        for k in 0..n_layers - 1 {
            let layer = &self.packed.layers[k];
            let (src, dst) = if cur_in_a {
                (&self.act_a, &mut self.act_b)
            } else {
                (&self.act_b, &mut self.act_a)
            };
            Self::layer_forward_tile(layer, lanes, &src[..layer.qwords * TILE], dst, self.use_simd);
            cur_in_a = !cur_in_a;
        }
        let last = &self.packed.layers[n_layers - 1];
        let src = if cur_in_a { &self.act_a } else { &self.act_b };
        Self::layer_scores_tile(
            last,
            lanes,
            &src[..last.qwords * TILE],
            self.packed.out_neurons,
            &mut self.scores,
            self.use_simd,
        );
    }

    /// Pack a tile of u32-word inputs into the lane-interleaved qword
    /// layout; unused lanes of a ragged final tile are zeroed.
    fn pack_tile<T: AsRef<[u32]>>(&mut self, tile: &[T]) {
        let q0 = self.packed.layers[0].qwords;
        self.act_a[..q0 * TILE].fill(0);
        for (t, x) in tile.iter().enumerate() {
            let x = x.as_ref();
            assert_eq!(x.len(), self.packed.in_words, "input width != model in_words");
            for (q, chunk) in x.chunks(2).enumerate() {
                self.act_a[q * TILE + t] = qword(chunk);
            }
        }
    }

    /// One hidden layer over a tile.  The weight-stationary inner loop:
    /// each weight qword is loaded once and scored against every lane.
    fn layer_forward_tile(layer: &Layer64, lanes: usize, x: &[u64], out: &mut [u64], simd: bool) {
        let out_q = layer.out_qwords();
        out[..out_q * TILE].fill(0);
        for n in 0..layer.neurons {
            let acc = simd::score_tile(layer.row(n), x, simd);
            let base = (n / 64) * TILE;
            let bit = 1u64 << (n % 64);
            for (t, &a) in acc.iter().enumerate().take(lanes) {
                if a as i32 - layer.pad_bias >= layer.threshold {
                    out[base + t] |= bit;
                }
            }
        }
    }

    /// Final layer over a tile: raw scores per lane, `[lane][neuron]`.
    fn layer_scores_tile(
        layer: &Layer64,
        lanes: usize,
        x: &[u64],
        out_neurons: usize,
        scores: &mut [i32],
        simd: bool,
    ) {
        debug_assert_eq!(layer.neurons, out_neurons);
        for n in 0..layer.neurons {
            let acc = simd::score_tile(layer.row(n), x, simd);
            for (t, &a) in acc.iter().enumerate().take(lanes) {
                scores[t * out_neurons + n] = a as i32 - layer.pad_bias;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::{infer_scores, BnnLayer};

    #[test]
    fn tile_matches_single_executor() {
        let model = BnnModel::random("m", 256, &[32, 16, 2], 4);
        let inputs: Vec<Vec<u32>> = (0..TILE + 3)
            .map(|i| BnnLayer::random(1, 256, 60 + i as u64).words)
            .collect();
        let mut k = BatchKernel::new(&model);
        let mut scores = Vec::new();
        k.infer_batch_scores(&inputs, &mut scores);
        for (i, x) in inputs.iter().enumerate() {
            assert_eq!(scores[i * 2..(i + 1) * 2], infer_scores(&model, x)[..]);
        }
    }

    #[test]
    fn ragged_and_single_lane_tiles() {
        // 152-bit input → 5 words → odd qword pairing; 1-layer model too.
        for arch in [vec![33usize, 7, 3], vec![8usize]] {
            let model = BnnModel::random("m", 152, &arch, 9);
            let mut k = BatchKernel::new(&model);
            for batch in [1usize, TILE - 1, TILE, TILE + 1] {
                let inputs: Vec<Vec<u32>> = (0..batch)
                    .map(|i| BnnLayer::random(1, 152, 400 + i as u64).words)
                    .collect();
                let mut classes = Vec::new();
                k.run_batch(&inputs, &mut classes);
                for (x, &c) in inputs.iter().zip(&classes) {
                    assert_eq!(c, crate::bnn::infer_packed(&model, x), "batch {batch}");
                }
            }
        }
    }

    #[test]
    fn explicit_paths_agree_and_report_their_lanes() {
        let model = BnnModel::random("m", 256, &[32, 16, 2], 11);
        let inputs: Vec<Vec<u32>> = (0..TILE + 5)
            .map(|i| BnnLayer::random(1, 256, 900 + i as u64).words)
            .collect();
        let mut scalar = BatchKernel::new_with_path(&model, KernelPath::Scalar);
        let mut forced = BatchKernel::new_with_path(&model, KernelPath::Simd);
        assert_eq!(scalar.simd_lanes(), 1);
        assert_eq!(forced.simd_lanes() == 4, simd::simd_available());
        let (mut a, mut b) = (Vec::new(), Vec::new());
        scalar.infer_batch_scores(&inputs, &mut a);
        forced.infer_batch_scores(&inputs, &mut b);
        assert_eq!(a, b, "scalar and vector paths must be bit-identical");
    }
}

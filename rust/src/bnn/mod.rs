//! Packed binary-MLP core: the paper's Algorithm 1, bit-exact.
//!
//! Every executor in this crate — the host `bnn-exec` baseline, the NFP,
//! PISA and FPGA device models, and the PJRT runtime — computes *exactly*
//! this function; integration tests assert cross-executor equality and
//! equality with golden vectors produced by the Python/Pallas layer.
//!
//! Three execution forms share one packed-weight representation:
//! [`BnnExecutor`] (one input at a time, the per-packet inline path),
//! [`BatchKernel`] (weight-stationary tiles of [`TILE`] inputs per
//! weight pass), and [`ShardedEngine`] (a batch partitioned across
//! worker threads, one core each).  Deployment-time versioning lives in
//! [`registry`]: named model slots with atomic zero-downtime hot swap
//! ([`ModelRegistry`]) and a versioned multi-model executor
//! ([`MultiModelExecutor`]) that tags every verdict with the
//! `(name, version)` it ran under.
//!
//! Bit conventions match `python/compile/kernels/ref.py`: bit `i` of a
//! logical vector lives in word `i / 32`, position `i % 32`; widths are
//! padded to multiples of 32 with 0-bits (−1 in the ±1 algebra); hidden
//! layers threshold at `in_bits / 2`; the final layer returns raw integer
//! popcount scores (argmax = class).

pub mod batch;
pub mod engine;
pub mod exec;
mod model;
pub mod registry;
pub mod simd;

pub use batch::{BatchKernel, TILE};
pub use engine::{EngineError, EngineStats, ShardedEngine};
pub use exec::{argmax, infer_packed, infer_scores, layer_forward, BnnExecutor};
pub use model::{BnnLayer, BnnModel, ModelMetrics, load_golden, Golden};
pub use registry::{
    ModelEpoch, ModelRegistry, MultiModelExecutor, RegistryError, RegistryHandle, SlotReader,
    VersionTag,
};
pub use simd::KernelPath;

/// Word width of the packed representation (the paper's `block_size`).
pub const BLOCK_SIZE: usize = 32;

/// Pad a logical bit-width up to a whole number of 32-bit words.
pub const fn padded_bits(n: usize) -> usize {
    n.div_ceil(BLOCK_SIZE) * BLOCK_SIZE
}

/// Number of 32-bit words holding `n` logical bits.
pub const fn words_for(n: usize) -> usize {
    n.div_ceil(BLOCK_SIZE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padding_math() {
        assert_eq!(padded_bits(1), 32);
        assert_eq!(padded_bits(32), 32);
        assert_eq!(padded_bits(33), 64);
        assert_eq!(padded_bits(152), 160);
        assert_eq!(padded_bits(256), 256);
        assert_eq!(words_for(152), 5);
        assert_eq!(words_for(256), 8);
    }
}

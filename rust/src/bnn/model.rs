//! BNN model representation + JSON (de)serialization of trained artifacts.

use super::{padded_bits, words_for, BLOCK_SIZE};
use crate::json::Json;
use crate::Result;

/// One binary fully-connected layer, weights packed row-major.
#[derive(Debug, Clone)]
pub struct BnnLayer {
    /// Number of output neurons (logical, unpadded).
    pub neurons: usize,
    /// Packed input words per neuron (`padded_bits(in) / 32`).
    pub in_words: usize,
    /// Sign threshold: popcount-sum ≥ threshold → bit 1.  Always
    /// `in_words * 16` (= half the padded input bits) per Algorithm 1.
    pub threshold: i32,
    /// Weights, `neurons × in_words` row-major.
    pub words: Vec<u32>,
}

impl BnnLayer {
    /// Build from packed rows; validates dimensions.
    pub fn new(neurons: usize, in_words: usize, words: Vec<u32>) -> Result<Self> {
        anyhow::ensure!(
            words.len() == neurons * in_words,
            "layer needs {neurons}×{in_words} words, got {}",
            words.len()
        );
        Ok(Self {
            neurons,
            in_words,
            threshold: (in_words * BLOCK_SIZE / 2) as i32,
            words,
        })
    }

    /// Random layer (deterministic LCG) — used by benches and tests.
    pub fn random(neurons: usize, in_bits: usize, seed: u64) -> Self {
        let in_words = words_for(in_bits);
        let mut state = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
        let mut next = || {
            // xorshift64*
            state ^= state >> 12;
            state ^= state << 25;
            state ^= state >> 27;
            (state.wrapping_mul(0x2545_F491_4F6C_DD1D) >> 32) as u32
        };
        let words = (0..neurons * in_words).map(|_| next()).collect();
        Self {
            neurons,
            in_words,
            threshold: (in_words * BLOCK_SIZE / 2) as i32,
            words,
        }
    }

    /// Row slice of one neuron's packed weights.
    #[inline]
    pub fn row(&self, neuron: usize) -> &[u32] {
        &self.words[neuron * self.in_words..(neuron + 1) * self.in_words]
    }

    /// Packed output words this layer produces.
    pub fn out_words(&self) -> usize {
        words_for(self.neurons)
    }

    /// Weight memory, packed (bytes).
    pub fn memory_bytes(&self) -> usize {
        self.words.len() * 4
    }

    /// Total 32-bit weight words processed per inference (the unit of the
    /// NFP/bnn-exec cost models).
    pub fn work_words(&self) -> usize {
        self.neurons * self.in_words
    }
}

/// Accuracy / memory metadata exported by the Python training pass.
#[derive(Debug, Clone, Default)]
pub struct ModelMetrics {
    pub bnn_test_acc: f64,
    pub bnn_train_acc: f64,
    pub float_test_acc: f64,
    pub memory_bytes: usize,
    pub float_memory_bytes: usize,
}

impl ModelMetrics {
    fn from_json(v: &Json) -> Self {
        let f = |k: &str| v.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        Self {
            bnn_test_acc: f("bnn_test_acc"),
            bnn_train_acc: f("bnn_train_acc"),
            float_test_acc: f("float_test_acc"),
            memory_bytes: f("memory_bytes") as usize,
            float_memory_bytes: f("float_memory_bytes") as usize,
        }
    }
}

/// A full binarized MLP (the unit N3IC deploys per use case).
#[derive(Debug, Clone)]
pub struct BnnModel {
    pub name: String,
    /// Logical (unpadded) input width in bits.
    pub in_bits: usize,
    /// Logical neuron counts per layer, e.g. `[32, 16, 2]`.
    pub neurons: Vec<usize>,
    pub layers: Vec<BnnLayer>,
    pub metrics: ModelMetrics,
}

impl BnnModel {
    /// Load a trained model JSON exported by `python/train/export.py`.
    pub fn load(path: &std::path::Path) -> Result<Self> {
        let data = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let v = Json::parse(&data)?;
        let name = v.req_str("name")?.to_string();
        let in_bits = v.req_usize("in_bits")?;
        let neurons: Vec<usize> = v
            .req_array("neurons")?
            .iter()
            .map(|x| x.as_usize().unwrap_or(0))
            .collect();
        let mut layers = Vec::new();
        for lv in v.req_array("layers")? {
            let words: Vec<u32> = lv
                .req_array("words")?
                .iter()
                .map(|x| x.as_u64().unwrap_or(0) as u32)
                .collect();
            layers.push(BnnLayer {
                neurons: lv.req_usize("neurons")?,
                in_words: lv.req_usize("in_words")?,
                threshold: lv.req_usize("threshold")? as i32,
                words,
            });
        }
        let metrics = v
            .get("metrics")
            .map(ModelMetrics::from_json)
            .unwrap_or_default();
        let model = Self {
            name,
            in_bits,
            neurons,
            layers,
            metrics,
        };
        model.validate()?;
        Ok(model)
    }

    /// Load by name from an artifacts directory (`<dir>/models/<name>.json`).
    pub fn load_named(artifacts: &std::path::Path, name: &str) -> Result<Self> {
        Self::load(&artifacts.join("models").join(format!("{name}.json")))
    }

    /// Structural consistency: widths chain, thresholds are Algorithm 1's.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.layers.is_empty(), "model has no layers");
        anyhow::ensure!(
            self.layers.len() == self.neurons.len(),
            "layers/neurons mismatch"
        );
        let mut in_words = words_for(padded_bits(self.in_bits));
        for (k, layer) in self.layers.iter().enumerate() {
            anyhow::ensure!(
                layer.in_words == in_words,
                "layer {k}: in_words {} != expected {in_words}",
                layer.in_words
            );
            anyhow::ensure!(
                layer.neurons == self.neurons[k],
                "layer {k}: neuron count mismatch"
            );
            anyhow::ensure!(
                layer.words.len() == layer.neurons * layer.in_words,
                "layer {k}: weight length"
            );
            anyhow::ensure!(
                layer.threshold == (layer.in_words * BLOCK_SIZE / 2) as i32,
                "layer {k}: threshold is not in_bits/2"
            );
            in_words = layer.out_words();
        }
        Ok(())
    }

    /// Random model for benches/tests (e.g. a single FC layer sweep).
    pub fn random(name: &str, in_bits: usize, neurons: &[usize], seed: u64) -> Self {
        let mut layers = Vec::new();
        let mut in_b = padded_bits(in_bits);
        for (k, &n) in neurons.iter().enumerate() {
            layers.push(BnnLayer::random(n, in_b, seed ^ (k as u64) << 17));
            in_b = padded_bits(n);
        }
        Self {
            name: name.to_string(),
            in_bits,
            neurons: neurons.to_vec(),
            layers,
            metrics: ModelMetrics::default(),
        }
    }

    /// Packed input words expected by layer 0.
    pub fn in_words(&self) -> usize {
        self.layers[0].in_words
    }

    /// Output neuron count of the final layer.
    pub fn out_neurons(&self) -> usize {
        *self.neurons.last().unwrap()
    }

    /// Packed weight memory over all layers (bytes) — Table 1's "Memory".
    pub fn memory_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.memory_bytes()).sum()
    }

    /// Total weight words touched per inference (cost-model unit).
    pub fn work_words(&self) -> usize {
        self.layers.iter().map(|l| l.work_words()).sum()
    }

    /// Architecture string, e.g. `256b→[32, 16, 2]`.
    pub fn describe(&self) -> String {
        format!("{}b→{:?}", self.in_bits, self.neurons)
    }
}

/// Golden test vectors produced by the **Pallas** path in Python.
#[derive(Debug, Clone)]
pub struct Golden {
    pub model: String,
    pub in_words: usize,
    pub inputs: Vec<Vec<u32>>,
    pub scores: Vec<Vec<i32>>,
    pub classes: Vec<usize>,
}

/// Load `<dir>/models/<name>.golden.json`.
pub fn load_golden(artifacts: &std::path::Path, name: &str) -> Result<Golden> {
    let path = artifacts.join("models").join(format!("{name}.golden.json"));
    let data = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    let v = Json::parse(&data)?;
    let vec_u32 = |j: &Json| -> Vec<u32> {
        j.as_array()
            .unwrap_or(&[])
            .iter()
            .map(|x| x.as_u64().unwrap_or(0) as u32)
            .collect()
    };
    let vec_i32 = |j: &Json| -> Vec<i32> {
        j.as_array()
            .unwrap_or(&[])
            .iter()
            .map(|x| x.as_f64().unwrap_or(0.0) as i32)
            .collect()
    };
    Ok(Golden {
        model: v.req_str("model")?.to_string(),
        in_words: v.req_usize("in_words")?,
        inputs: v.req_array("inputs")?.iter().map(vec_u32).collect(),
        scores: v.req_array("scores")?.iter().map(vec_i32).collect(),
        classes: v
            .req_array("classes")?
            .iter()
            .map(|x| x.as_usize().unwrap_or(0))
            .collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_model_validates() {
        let m = BnnModel::random("t", 256, &[32, 16, 2], 7);
        m.validate().unwrap();
        assert_eq!(m.in_words(), 8);
        assert_eq!(m.out_neurons(), 2);
        // 32×8 + 16×1 + 2×1 words = 274 words = 1096 B (Table 1's 1.1KB).
        assert_eq!(m.work_words(), 274);
        assert_eq!(m.memory_bytes(), 1096);
    }

    #[test]
    fn tomography_memory_matches_table5() {
        // 128-64-2 on 152-bit input: Table 5 reports 3.4 KB binarized.
        let m = BnnModel::random("tomo", 152, &[128, 64, 2], 1);
        // 128×5 + 64×4 + 2×2 words = 900 words = 3600 B — Table 5 reports
        // 3.4 KB for the unpadded 152/128/64-bit widths (3472 B); our
        // 32-bit padding adds ~4%.
        assert_eq!(m.memory_bytes(), (128 * 5 + 64 * 4 + 2 * 2) * 4);
        assert!((3300..3700).contains(&m.memory_bytes()));
    }

    #[test]
    fn bad_shapes_rejected() {
        let mut m = BnnModel::random("t", 64, &[8, 2], 3);
        m.layers[1].threshold += 1;
        assert!(m.validate().is_err());
        let mut m2 = BnnModel::random("t", 64, &[8, 2], 3);
        m2.layers[0].words.pop();
        assert!(m2.validate().is_err());
    }
}

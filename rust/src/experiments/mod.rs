//! Experiment drivers: one per paper table/figure (DESIGN.md §4).
//!
//! Each driver regenerates the rows/series of its figure and returns a
//! printable report.  `repro experiment <id>` runs one; `repro experiment
//! all` runs the full evaluation and is what EXPERIMENTS.md records.
//! Absolute numbers come from the calibrated device models; *shapes*
//! (who wins, by what factor, where the crossovers fall) are the claims
//! under test.

use std::fmt::Write as _;
use std::path::Path;

use crate::arith;
use crate::bnn::BnnModel;
use crate::bnnexec::HostCostModel;
use crate::fpga::{FpgaResources, FpgaTiming};
use crate::nfp::{self, DataParallelCost, MemKind, NfpSim};
use crate::pcie::PcieModel;
use crate::pisa;
use crate::tomography;

/// All experiment ids, in paper order, plus the two ablations DESIGN.md
/// calls out (App. A's data-/model-parallel crossover; footnote 12's
/// shared-CAM optimization).
pub const ALL: &[&str] = &[
    "fig03", "fig04", "fig05", "fig06", "tab01", "fig13", "fig14", "fig15",
    "fig16", "fig17", "fig18", "tab02", "fig21", "fig22", "fig23", "fig25",
    "fig27", "fig29", "abl-crossover", "abl-cam",
];

/// Run one experiment by id; `artifacts` provides trained models where
/// available (falls back to random weights of the right shape: timing and
/// resource results do not depend on weight values).
pub fn run(id: &str, artifacts: &Path) -> crate::Result<String> {
    Ok(match id {
        "fig03" => fig03_pcie_vs_cpu(),
        "fig04" => fig04_arith_intensity(),
        "fig05" => fig05_op_budget(),
        "fig06" => fig06_cpu_batching(),
        "tab01" => tab01_use_cases(artifacts),
        "fig13" => fig13_throughput(),
        "fig14" => fig14_latency(),
        "fig15" => fig15_tomography_latency(),
        "fig16" => fig16_tomography_accuracy(artifacts),
        "fig17" => fig17_nn_size_throughput(),
        "fig18" => fig18_nn_size_latency(),
        "tab02" => tab02_resources(),
        "fig21" => fig21_nfp_flows(),
        "fig22" => fig22_nfp_size(),
        "fig23" => fig23_nfp_memory(),
        "fig25" => fig25_model_parallel(),
        "fig27" => fig27_fpga_scaling(),
        "fig29" => fig29_fpga_resources(),
        "abl-crossover" => ablation_crossover(),
        "abl-cam" => ablation_shared_cam(),
        other => anyhow::bail!("unknown experiment {other}; try one of {ALL:?}"),
    })
}

fn traffic_model() -> BnnModel {
    BnnModel::random("traffic", 256, &[32, 16, 2], 1)
}

fn load_or_random(artifacts: &Path, name: &str, in_bits: usize, ns: &[usize]) -> BnnModel {
    BnnModel::load_named(artifacts, name)
        .unwrap_or_else(|_| BnnModel::random(name, in_bits, ns, 1))
}

/// Fig. 3: PCIe RTT vs single-core NN inference time, by NN size.
pub fn fig03_pcie_vs_cpu() -> String {
    let pcie = PcieModel::default();
    let host = HostCostModel::default();
    let mut s = String::from(
        "Fig 3 — PCIe RTT vs CPU inference time\n\
         neurons  input_B  pcie_rtt_us  cpu_infer_us  cheaper\n",
    );
    for &n in &[16usize, 50, 128, 512, 2048, 8192] {
        let model = BnnModel::random("fc", 256, &[n], 1);
        let input_bytes = 32;
        let rtt = pcie.rtt_ns(input_bytes) / 1000.0;
        let cpu = host.inference_ns(&model) / 1000.0;
        let _ = writeln!(
            s,
            "{n:7}  {input_bytes:7}  {rtt:11.2}  {cpu:12.2}  {}",
            if cpu < rtt { "CPU" } else { "PCIe-accel" }
        );
    }
    s.push_str("shape: small NNs run on-CPU faster than one PCIe round trip\n");
    s
}

/// Fig. 4: arithmetic intensity / modeled IPC per VGG16 layer.
pub fn fig04_arith_intensity() -> String {
    let mut s = String::from("Fig 4 — VGG16 layer arithmetic intensity\nlayer     ops/byte  modeled_IPC  modeled_L3_MPKI\n");
    for l in arith::vgg16() {
        let _ = writeln!(
            s,
            "{:8}  {:8.2}  {:11.2}  {:15.2}",
            l.name,
            l.ops_per_byte(),
            l.modeled_ipc(),
            l.modeled_l3_mpki()
        );
    }
    s.push_str("shape: conv layers compute-bound, FC layers memory-bound\n");
    s
}

/// Fig. 5: NFP forwarding throughput vs per-packet extra operations.
pub fn fig05_op_budget() -> String {
    let f = nfp::ForwardingModel::default();
    let mut s = String::from("Fig 5 — per-packet op budget @25Gb/s\nops      512B_mpps  1024B_mpps  1500B_mpps\n");
    for ops in [1u64, 10, 100, 1_000, 10_000, 100_000] {
        let _ = writeln!(
            s,
            "{ops:7}  {:9.2}  {:10.2}  {:10.2}",
            f.ops_budget_mpps(25.0, 512, ops),
            f.ops_budget_mpps(25.0, 1024, ops),
            f.ops_budget_mpps(25.0, 1500, ops)
        );
    }
    for sz in [512u16, 1024, 1500] {
        let _ = writeln!(s, "budget@line-rate {sz}B: {} ops", f.ops_budget_at_line_rate(25.0, sz));
    }
    s
}

/// Fig. 6: host executor latency/throughput across batch sizes.
pub fn fig06_cpu_batching() -> String {
    let host = HostCostModel::default();
    let m = traffic_model();
    let mut s = String::from("Fig 6 — CPU executor batching trade-off\nbatch   latency     throughput_flows_s\n");
    for b in [1usize, 10, 100, 1_000, 10_000] {
        let lat = host.batch_latency_ns(&m, b);
        let _ = writeln!(
            s,
            "{b:6}  {:9.1}us  {:14.0}",
            lat / 1000.0,
            host.throughput_per_sec(&m, b)
        );
    }
    s.push_str("shape: batching buys throughput at 100-1000x latency cost\n");
    s
}

/// Table 1/5: use-case models, memory, accuracy (needs trained models).
pub fn tab01_use_cases(artifacts: &Path) -> String {
    let mut s = String::from(
        "Table 1/5 — use cases\nmodel            arch            bin_KB  mlp_KB  bin_acc  mlp_acc\n",
    );
    for model in crate::scenario::ScenarioRegistry::standard().use_case_models() {
        let name = model.name;
        match BnnModel::load_named(artifacts, name) {
            Ok(m) => {
                let _ = writeln!(
                    s,
                    "{name:15}  {:14}  {:6.1}  {:6.1}  {:7.3}  {:7.3}",
                    m.describe(),
                    m.memory_bytes() as f64 / 1024.0,
                    m.metrics.float_memory_bytes as f64 / 1024.0,
                    m.metrics.bnn_test_acc,
                    m.metrics.float_test_acc
                );
            }
            Err(_) => {
                let _ = writeln!(s, "{name:15}  (not trained — run `make artifacts`)");
            }
        }
    }
    s
}

/// Fig. 13: traffic-analysis throughput, all systems, 1.8M flows/s load.
pub fn fig13_throughput() -> String {
    let m = traffic_model();
    let offered = 1.81e6;
    let host = HostCostModel::default();
    let mut s = String::from("Fig 13 — traffic analysis throughput @1.81M flows/s offered\nsystem       achieved_flows_s  fwd_40g\n");
    let nfp = NfpSim::new(&m, MemKind::Cls, 480).run(offered, 150_000, 1);
    let _ = writeln!(
        s,
        "N3IC-NFP     {:16.0}  {}",
        nfp.completed_per_sec,
        if nfp.forwarding_mpps > 18.0 { "yes" } else { "no" }
    );
    let p4_tput = pisa::compile_bnn(&m)
        .map(|p| p.throughput_per_sec().min(offered))
        .unwrap_or(0.0);
    let _ = writeln!(s, "N3IC-P4      {:16.0}  yes", p4_tput);
    let fpga = FpgaTiming::new(&m).throughput_per_sec().min(offered);
    let _ = writeln!(s, "N3IC-FPGA    {:16.0}  yes (1 module ≈ 1.8M/s)", fpga);
    for b in [1usize, 1_000, 10_000] {
        let _ = writeln!(
            s,
            "bnn-exec b{b:<5} {:13.0}  n/a (host core)",
            host.throughput_per_sec(&m, b).min(offered)
        );
    }
    s.push_str("shape: all N3IC variants meet the offered load; bnn-exec caps at ~1.2M\n");
    s
}

/// Fig. 14: traffic-analysis latency (95th percentile).
pub fn fig14_latency() -> String {
    let m = traffic_model();
    let host = HostCostModel::default();
    let mut s = String::from("Fig 14 — traffic analysis latency\nsystem        p95_latency\n");
    let nfp = NfpSim::new(&m, MemKind::Cls, 480).run(1.81e6, 120_000, 2);
    let _ = writeln!(s, "N3IC-NFP      {:8.1}us", nfp.latency.p95_us());
    if let Ok(p) = pisa::compile_bnn(&m) {
        let _ = writeln!(s, "N3IC-P4       {:8.1}us", p.latency_ns(64) / 1000.0);
    }
    let _ = writeln!(
        s,
        "N3IC-FPGA     {:8.1}us",
        FpgaTiming::new(&m).latency_ns() / 1000.0
    );
    for b in [1usize, 1_000, 10_000] {
        let _ = writeln!(
            s,
            "bnn-exec b{b:<5} {:6.1}us",
            host.batch_latency_ns(&m, b) / 1000.0
        );
    }
    s.push_str("shape: N3IC 10-100x below bnn-exec at throughput-equivalent batches\n");
    s
}

/// Fig. 15: tomography latency vs probe-period budgets.
pub fn fig15_tomography_latency() -> String {
    let tomo = BnnModel::random("tomo128", 152, &[128, 64, 2], 1);
    let tomo32 = BnnModel::random("tomo32", 152, &[32, 16, 2], 1);
    let host = HostCostModel::default();
    let mut s = String::from("Fig 15 — network tomography latency vs probe budget\n");
    let rows: Vec<(&str, f64)> = vec![
        ("bnn-exec(128-64-2)", host.batch_latency_ns(&tomo, 1)),
        (
            "N3IC-NFP(128-64-2)",
            // ×1.7: several per-queue NNs share the thread pool (§7);
            // lands at the paper's ~170 µs.
            DataParallelCost::new(&tomo, MemKind::Cls).mean_ns() * 1.7,
        ),
        ("N3IC-FPGA(128-64-2)", FpgaTiming::new(&tomo).latency_ns()),
        (
            "N3IC-P4(32-16-2)",
            pisa::compile_bnn(&tomo32).map(|p| p.latency_ns(64)).unwrap_or(f64::NAN),
        ),
    ];
    s.push_str("system               latency_us  40G(250us) 100G(100us) 400G(25us)\n");
    for (name, lat) in rows {
        let f = |budget: f64| if lat <= budget { "ok" } else { "MISS" };
        let _ = writeln!(
            s,
            "{name:20} {:9.1}  {:>9} {:>10} {:>9}",
            lat / 1000.0,
            f(250_000.0),
            f(100_000.0),
            f(25_000.0)
        );
    }
    let _ = writeln!(
        s,
        "P4 on 128-64-2: {}",
        match pisa::compile_bnn(&tomo) {
            Err(e) => format!("does not compile ({e})"),
            Ok(_) => "unexpectedly compiled".into(),
        }
    );
    s.push_str("shape: only N3IC-FPGA fits the 400G budget (paper Result 2)\n");
    s
}

/// Fig. 16: tomography accuracy distribution (from Python training) plus
/// the Rust-side end-to-end check on the fat-tree simulator.
pub fn fig16_tomography_accuracy(artifacts: &Path) -> String {
    let mut s = String::from("Fig 16 — tomography accuracy by NN size\n");
    let acc_file = artifacts.join("tomography_accuracy.json");
    if let Ok(text) = std::fs::read_to_string(&acc_file) {
        if let Ok(v) = crate::json::Json::parse(&text) {
            for size in ["32", "64", "128"] {
                if let Some(obj) = v.get(size).and_then(|o| o.as_object()) {
                    let mut accs: Vec<f64> =
                        obj.values().filter_map(|x| x.as_f64()).collect();
                    accs.sort_by(|a, b| a.partial_cmp(b).unwrap());
                    if !accs.is_empty() {
                        let _ = writeln!(
                            s,
                            "bin {size:>3}: min={:.3} med={:.3} max={:.3} (n={})",
                            accs[0],
                            accs[accs.len() / 2],
                            accs[accs.len() - 1],
                            accs.len()
                        );
                    }
                }
            }
        }
    } else {
        s.push_str("(tomography_accuracy.json missing — run `make artifacts`)\n");
    }
    // End-to-end Rust check: deployed q0 model on the fat-tree sim.
    let model = load_or_random(artifacts, "tomography_128", 152, &[128, 64, 2]);
    let rep = tomography::TomographyRun::default().evaluate(&model, 200);
    let _ = writeln!(
        s,
        "fat-tree sim (rust, calibrated detectors): median acc {:.3} over {} queues",
        rep.median_accuracy,
        rep.accuracy.len()
    );
    s.push_str("shape: larger NNs more accurate; medians in the low-90s\n");
    s
}

/// Fig. 17: throughput vs NN size for all three implementations.
pub fn fig17_nn_size_throughput() -> String {
    let mut s = String::from("Fig 17 — single FC (256b in) throughput vs neurons\nneurons  nfp_s      p4_s       fpga_s\n");
    for n in [32usize, 64, 128] {
        let m = BnnModel::random("fc", 256, &[n], 1);
        let nfp = DataParallelCost::new(&m, MemKind::Cls).max_throughput(480);
        let p4 = match pisa::compile_bnn(&m) {
            Ok(p) => format!("{:9.2e}", p.throughput_per_sec()),
            Err(_) => "   (fail)".to_string(),
        };
        let fpga = FpgaTiming::new(&m).throughput_per_sec();
        let _ = writeln!(s, "{n:7}  {nfp:9.2e}  {p4}  {fpga:9.2e}");
    }
    s.push_str("shape: NFP/FPGA scale linearly; P4 fastest but absent at 128\n");
    s
}

/// Fig. 18: latency vs NN size.
pub fn fig18_nn_size_latency() -> String {
    let mut s = String::from("Fig 18 — single FC (256b in) latency vs neurons\nneurons  nfp_us     p4_us     fpga_us\n");
    for n in [32usize, 64, 128] {
        let m = BnnModel::random("fc", 256, &[n], 1);
        let nfp = DataParallelCost::new(&m, MemKind::Cls).mean_ns() / 1000.0;
        let p4 = match pisa::compile_bnn(&m) {
            Ok(p) => format!("{:8.2}", p.latency_ns(64) / 1000.0),
            Err(_) => "  (fail)".to_string(),
        };
        let fpga = FpgaTiming::new(&m).latency_ns() / 1000.0;
        let _ = writeln!(s, "{n:7}  {nfp:9.2}  {p4}  {fpga:8.2}");
    }
    s.push_str("shape: latency linear in NN size for NFP/FPGA\n");
    s
}

/// Table 2: NetFPGA resource usage.
pub fn tab02_resources() -> String {
    let m = traffic_model();
    let refnic = FpgaResources::reference_nic();
    let fpga = FpgaResources::n3ic_fpga(&m, 1);
    let p4 = pisa::PisaResources::for_model(&m).design;
    let mut s = String::from("Table 2 — NetFPGA resources\ndesign          LUT(k)  LUT%   BRAM  BRAM%\n");
    for (name, r) in [("REFERENCE NIC", refnic), ("N3IC-FPGA", fpga), ("N3IC-P4", p4)] {
        let _ = writeln!(
            s,
            "{name:14}  {:6.1}  {:5.1}  {:5}  {:5.1}",
            r.lut as f64 / 1000.0,
            r.lut_pct(),
            r.bram,
            r.bram_pct()
        );
    }
    s
}

/// Fig. 21: NFP forwarding vs flow-analysis rate × thread budget.
pub fn fig21_nfp_flows() -> String {
    let m = traffic_model();
    let fwd = nfp::ForwardingModel::default();
    let mut s = String::from("Fig 21 — NFP forwarding (Mpps) vs analyzed flows/s\nflows_s    thr=120    thr=240    thr=480\n");
    for rate in [1e4f64, 1e5, 2e5, 1e6, 2e6] {
        let mut row = format!("{rate:9.0}");
        for threads in [120usize, 240, 480] {
            let cost = DataParallelCost::new(&m, MemKind::Cls);
            // NN work competes with forwarding for the same thread pool.
            let nn_rate = rate.min(cost.max_throughput(threads));
            let mpps = fwd.achieved_mpps(threads, nn_rate, cost.mean_ns());
            let _ = write!(row, "  {mpps:9.2}");
        }
        let _ = writeln!(s, "{row}");
    }
    s.push_str("shape: 120 threads match baseline at 200k flows/s; 480 at ~2M\n");
    s
}

/// Fig. 22: NFP data-parallel throughput vs BNN size.
pub fn fig22_nfp_size() -> String {
    let mut s = String::from("Fig 22 — NFP data-parallel max throughput vs FC size (CLS, 480 thr)\nneurons  weights  tput_s\n");
    for n in [32usize, 64, 128] {
        let m = BnnModel::random("fc", 256, &[n], 1);
        let t = DataParallelCost::new(&m, MemKind::Cls).max_throughput(480);
        let _ = writeln!(s, "{n:7}  {:7}  {t:9.3e}", n * 256);
    }
    s.push_str("shape: throughput scales linearly with 1/size\n");
    s
}

/// Fig. 23/24: NFP throughput/latency by weight memory.
pub fn fig23_nfp_memory() -> String {
    let m = traffic_model();
    let mut s = String::from("Fig 23/24 — NFP stress by weight memory (480 thr)\nmem    tput_s      mean_us   p95_us\n");
    for mem in [MemKind::Cls, MemKind::Imem, MemKind::Emem] {
        let sim = NfpSim::new(&m, mem, 480);
        let r = sim.run(3e6, 60_000, 5);
        let _ = writeln!(
            s,
            "{:5}  {:9.3e}  {:8.1}  {:7.1}",
            mem.to_string(),
            r.completed_per_sec,
            r.latency.mean_ns() / 1000.0,
            r.latency.p95_us()
        );
    }
    s.push_str("shape: CLS ≫ IMEM/EMEM; IMEM latency worst (arbiter artefact)\n");
    s
}

/// Fig. 25/26: model-parallel vs bnn-exec on big FCs.
pub fn fig25_model_parallel() -> String {
    let host = HostCostModel::default();
    let mut s = String::from(
        "Fig 25/26 — big FC (4096 in): N3IC-NFP model-parallel vs bnn-exec\nneurons  nfp_lat_us  host_lat_us  ratio  nfp_tput_s  host_tput_s(4c)\n",
    );
    for n in [2048usize, 4096, 8192, 16384] {
        let m = BnnModel::random("fc", 4096, &[n], 1);
        let mp = nfp::ModelParallel::new(m.clone(), nfp::ChainConfig::default());
        let nfp_lat = mp.latency_ns() / 1000.0;
        let host_lat = host.inference_ns(&m) / 1000.0;
        let batch = host.max_batch_under(&m, 7e6);
        let host_tput = 4.0 * host.throughput_per_sec(&m, batch);
        let _ = writeln!(
            s,
            "{n:7}  {nfp_lat:10.0}  {host_lat:11.0}  {:5.1}  {:10.0}  {host_tput:14.0}",
            nfp_lat / host_lat,
            mp.throughput_per_sec()
        );
    }
    s.push_str("shape: NFP ≈4x host latency; tput ≈4-8% of a 4-core host\n");
    s
}

/// Fig. 27/28: FPGA throughput/latency scaling with modules.
pub fn fig27_fpga_scaling() -> String {
    let mut s = String::from("Fig 27/28 — FPGA modules scaling (FC 256b in)\nneurons  modules  tput_s      lat_us\n");
    for n in [32usize, 64, 128] {
        for modules in [1usize, 4, 16] {
            let m = BnnModel::random("fc", 256, &[n], 1);
            let e = crate::fpga::FpgaExecutor::new(m, modules);
            let _ = writeln!(
                s,
                "{n:7}  {modules:7}  {:9.3e}  {:7.2}",
                e.throughput_per_sec(),
                e.latency_ns() / 1000.0
            );
        }
    }
    s.push_str("shape: tput linear in modules; latency flat\n");
    s
}

/// Fig. 29–31: FPGA throughput + resources vs module count.
pub fn fig29_fpga_resources() -> String {
    let m = traffic_model();
    let mut s = String::from("Fig 29-31 — FPGA scaling (anomaly-class NN)\nmodules  tput_s      LUT(k)  BRAM\n");
    for modules in [1usize, 2, 4, 8, 16] {
        let (tput, r) = FpgaResources::scaling_point(&m, modules);
        let _ = writeln!(
            s,
            "{modules:7}  {tput:9.3e}  {:6.1}  {:4}",
            r.lut as f64 / 1000.0,
            r.bram
        );
    }
    s.push_str("shape: ~1.8M inf/s and fixed LUT/BRAM increments per module\n");
    s
}

/// Ablation (App. A): data-parallel vs model-parallel crossover for a
/// growing 4096-input FC, including the CLS→EMEM spill point.
pub fn ablation_crossover() -> String {
    let mut s = String::from(
        "Ablation — data-parallel vs model-parallel (4096-in FC, 480 thr vs 256-exec chain)\nneurons  dp_mem  dp_lat_us  mp_lat_us  dp_tput_s  mp_tput_s\n",
    );
    let pts = nfp::crossover_sweep(
        4096,
        &[32, 64, 128, 256, 512, 1024, 2048, 4096, 8192],
        nfp::ChainConfig::default(),
    );
    for p in &pts {
        let _ = writeln!(
            s,
            "{:7}  {:6}  {:9.1}  {:9.1}  {:9.3e}  {:9.3e}",
            p.neurons,
            p.dp_mem.to_string(),
            p.dp_latency_ns / 1000.0,
            p.mp_latency_ns / 1000.0,
            p.dp_tput,
            p.mp_tput
        );
    }
    s.push_str("shape: the chain buys 5-15x latency, data-parallel keeps 10-100x throughput;\n       dp spills CLS -> EMEM as weights outgrow the island scratch\n");
    s
}

/// Ablation (footnote 12): sharing the read-only CAM weight store across
/// FPGA executor modules.
pub fn ablation_shared_cam() -> String {
    let m = traffic_model();
    let mut s = String::from(
        "Ablation — shared CAM weight store (traffic NN)\nmodules  bram_dedicated  bram_shared  saved\n",
    );
    for modules in [1usize, 2, 4, 8, 16] {
        let d = FpgaResources::n3ic_fpga(&m, modules);
        let sh = FpgaResources::n3ic_fpga_shared_cam(&m, modules);
        let _ = writeln!(
            s,
            "{modules:7}  {:14}  {:11}  {:5}",
            d.bram,
            sh.bram,
            d.bram - sh.bram
        );
    }
    s.push_str("shape: BRAM growth drops from ~18/module to ~2/module when shared\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_experiments_run() {
        let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        for id in ALL {
            let out = run(id, &artifacts).unwrap_or_else(|e| panic!("{id}: {e}"));
            assert!(out.len() > 40, "{id} output too short");
            assert!(out.contains('\n'));
        }
    }

    #[test]
    fn unknown_experiment_rejected() {
        assert!(run("fig99", Path::new(".")).is_err());
    }
}

//! Arithmetic-intensity model of NN layers (Fig. 4 motivation).
//!
//! The paper instruments VGG16 on a Haswell core and shows convolutional
//! layers are compute-bound (high IPC, few L3 misses) while fully-connected
//! layers are memory-bound (low IPC, many misses).  We model the underlying
//! quantity directly: **operations per byte of parameter data loaded**
//! (arithmetic intensity), which is what the IPC/miss counters proxy.
//!
//! conv: every weight is reused across all output positions of its feature
//! map → ops/byte grows with the spatial output size.  FC: every weight is
//! used exactly once per inference → ops/byte is a small constant (2 ops
//! per 4-byte weight = 0.5 op/B).

/// A VGG16-style layer for the intensity model.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub name: &'static str,
    pub kind: LayerKind,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// Convolution: (in_ch, out_ch, kernel, out_h, out_w).
    Conv {
        in_ch: usize,
        out_ch: usize,
        k: usize,
        out_hw: usize,
    },
    /// Fully connected: (in_features, out_features).
    Fc { inf: usize, outf: usize },
}

impl LayerSpec {
    /// Multiply-accumulate count for one inference.
    pub fn macs(&self) -> u64 {
        match self.kind {
            LayerKind::Conv {
                in_ch,
                out_ch,
                k,
                out_hw,
            } => (in_ch * out_ch * k * k * out_hw * out_hw) as u64,
            LayerKind::Fc { inf, outf } => (inf * outf) as u64,
        }
    }

    /// Parameter bytes loaded (float32 weights).
    pub fn param_bytes(&self) -> u64 {
        match self.kind {
            LayerKind::Conv {
                in_ch, out_ch, k, ..
            } => (in_ch * out_ch * k * k * 4) as u64,
            LayerKind::Fc { inf, outf } => (inf * outf * 4) as u64,
        }
    }

    /// Arithmetic intensity: ops (2×MAC) per parameter byte.
    pub fn ops_per_byte(&self) -> f64 {
        2.0 * self.macs() as f64 / self.param_bytes() as f64
    }

    /// Modeled IPC on a Haswell-class core: saturates at ~3.2 for
    /// compute-bound layers and drops toward ~0.4 for memory-bound ones
    /// (the two plateaus visible in Fig. 4).
    pub fn modeled_ipc(&self) -> f64 {
        let i = self.ops_per_byte();
        0.4 + 2.8 * (i / (i + 32.0))
    }

    /// Modeled L3 misses per kilo-instruction (inverse shape of IPC).
    pub fn modeled_l3_mpki(&self) -> f64 {
        let i = self.ops_per_byte();
        24.0 * 32.0 / (i + 32.0)
    }
}

/// The VGG16 layer sequence used in Fig. 4.
pub fn vgg16() -> Vec<LayerSpec> {
    use LayerKind::*;
    let conv = |name, in_ch, out_ch, out_hw| LayerSpec {
        name,
        kind: Conv {
            in_ch,
            out_ch,
            k: 3,
            out_hw,
        },
    };
    vec![
        conv("conv1_1", 3, 64, 224),
        conv("conv1_2", 64, 64, 224),
        conv("conv2_1", 64, 128, 112),
        conv("conv2_2", 128, 128, 112),
        conv("conv3_1", 128, 256, 56),
        conv("conv3_2", 256, 256, 56),
        conv("conv3_3", 256, 256, 56),
        conv("conv4_1", 256, 512, 28),
        conv("conv4_2", 512, 512, 28),
        conv("conv4_3", 512, 512, 28),
        conv("conv5_1", 512, 512, 14),
        conv("conv5_2", 512, 512, 14),
        conv("conv5_3", 512, 512, 14),
        LayerSpec { name: "fc6", kind: Fc { inf: 25088, outf: 4096 } },
        LayerSpec { name: "fc7", kind: Fc { inf: 4096, outf: 4096 } },
        LayerSpec { name: "fc8", kind: Fc { inf: 4096, outf: 1000 } },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fc_layers_are_memory_bound() {
        for l in vgg16() {
            match l.kind {
                LayerKind::Fc { .. } => {
                    assert!((l.ops_per_byte() - 0.5).abs() < 1e-9);
                    assert!(l.modeled_ipc() < 0.6, "{}", l.name);
                }
                LayerKind::Conv { .. } => {
                    assert!(l.ops_per_byte() > 90.0, "{}", l.name);
                    assert!(l.modeled_ipc() > 2.0, "{}", l.name);
                }
            }
        }
    }

    #[test]
    fn conv_misses_below_fc_misses() {
        let layers = vgg16();
        let conv_mpki = layers[0].modeled_l3_mpki();
        let fc_mpki = layers[14].modeled_l3_mpki();
        assert!(fc_mpki > 10.0 * conv_mpki);
    }

    #[test]
    fn vgg16_macs_total_plausible() {
        // VGG16 is ~15.5 GMACs; our spec should land in that ballpark.
        let total: u64 = vgg16().iter().map(|l| l.macs()).sum();
        assert!((14_000_000_000..17_000_000_000).contains(&total));
    }
}

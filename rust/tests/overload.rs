//! Overload-control integration tests (ISSUE 6): admission shedding
//! bounds latency instead of letting queues collapse, the degradation
//! ladder steps down under pressure and back up on recovery (including
//! the registry fallback-model rung), placement breakers fail over
//! without changing verdicts, and unfired policies leave reports
//! bit-identical to policy-free runs.

use n3ic::bnn::{BnnModel, EngineError, RegistryHandle, VersionTag};
use n3ic::coordinator::{
    BackendFactory, BreakerPolicy, Capabilities, DegradationEvent, DegradeSpec, InferencePlane,
    OutputSelector, PacketEvent, PlacedPlane, ServeBuilder, ServiceLevel, ServiceReport,
    ShedPolicy, TriggerCondition,
};
use n3ic::net::traffic::CbrSpec;

use std::time::Duration;

fn model() -> BnnModel {
    BnnModel::random("traffic", 256, &[32, 16, 2], 1)
}

/// A line-rate burst followed by a calm tail: the burst piles modeled
/// work onto the backend far faster than it drains (tripping shedding
/// and the ladder's step-down), the calm tail lets the backlog drain so
/// recovery — the step back up to [`ServiceLevel::Full`] — is
/// deterministic before the run ends.
fn burst_then_calm(burst: usize, calm: usize, flows: u64, seed: u64) -> Vec<PacketEvent> {
    let mut events =
        PacketEvent::cbr_burst(CbrSpec { gbps: 40.0, pkt_size: 256 }, flows, seed, burst);
    let mut tail =
        PacketEvent::cbr_burst(CbrSpec { gbps: 0.05, pkt_size: 256 }, flows, seed + 1, calm);
    let t0 = events.last().expect("burst is non-empty").packet.ts_ns + 1.0;
    let c0 = tail.first().expect("tail is non-empty").packet.ts_ns;
    for ev in &mut tail {
        ev.packet.ts_ns += t0 - c0;
    }
    events.extend(tail);
    events
}

fn ladder_shape(timeline: &[DegradationEvent]) -> Vec<(u64, ServiceLevel, ServiceLevel)> {
    timeline.iter().map(|e| (e.at_packet, e.from, e.to)).collect()
}

// ---------------------------------------------------------------------------
// Serial runtime: shed + trigger-only ladder.
// ---------------------------------------------------------------------------

#[test]
fn shedding_bounds_latency_and_walks_the_ladder_down_and_back_up() {
    // A 50 µs-per-inference backend against 51 ns packet arrivals: the
    // burst's triggers represent ~5x more modeled work than the device
    // can retire, so an unshed run would queue without bound.
    let events = burst_then_calm(80_000, 20_000, 400, 7);
    let run = || {
        ServeBuilder::new()
            .backend(BackendFactory::custom("slownic", model(), 50_000.0, 1))
            .trigger(TriggerCondition::EveryNPackets(5))
            .output(OutputSelector::Memory)
            .shed(ShedPolicy::new(400_000.0, 100_000.0))
            .degrade(DegradeSpec::trigger_only())
            .build()
            .unwrap()
            .run(events.iter().cloned())
            .unwrap()
    };
    let rep = run();
    assert!(rep.stats.sheds > 0, "the burst must trip the admission controller");
    assert!(rep.stats.inferences > 0, "shedding must not starve the service entirely");
    assert_eq!(
        rep.stats.triggers,
        rep.stats.inferences + rep.stats.sheds,
        "every trigger is either inferred or shed, never lost"
    );
    // Admitted inferences never see the unbounded queue the shed ones
    // would have formed — the latency profile stays the device's own.
    assert!(
        rep.stats.latency.p99_us() < 200.0,
        "p99 {} µs must stay near the 50 µs device latency",
        rep.stats.latency.p99_us()
    );
    let tl = &rep.degradation;
    assert!(tl.iter().any(DegradationEvent::is_step_down), "{tl:?}");
    assert!(tl.iter().any(|e| !e.is_step_down()), "{tl:?}");
    assert_eq!(
        tl.last().unwrap().to,
        ServiceLevel::Full,
        "the calm tail must recover full service: {tl:?}"
    );
    // Everything above is packet-clock arithmetic: a rerun is identical.
    let rep2 = run();
    assert_eq!(rep.stats.sheds, rep2.stats.sheds);
    assert_eq!(rep.stats.inferences, rep2.stats.inferences);
    assert_eq!(rep.stats.classes, rep2.stats.classes);
    assert_eq!(ladder_shape(&rep.degradation), ladder_shape(&rep2.degradation));
    assert_eq!(rep.sink.memory, rep2.sink.memory);
}

// ---------------------------------------------------------------------------
// Registry fallback rung: hot-swap down, roll back up.
// ---------------------------------------------------------------------------

#[test]
fn fallback_degradation_swaps_and_rolls_back_registry_weights() {
    let reg = RegistryHandle::new();
    let full = BnnModel::random("traffic", 256, &[32, 16, 2], 11);
    reg.publish("traffic", &full).unwrap();
    let names = vec!["traffic".to_string()];

    let events = burst_then_calm(80_000, 20_000, 400, 9);
    let rep = ServeBuilder::new()
        .backend(BackendFactory::registry(&reg, &names, 50_000.0, 1).unwrap())
        .trigger(TriggerCondition::EveryNPackets(5))
        .output(OutputSelector::Memory)
        .shed(ShedPolicy::new(400_000.0, 100_000.0))
        .degrade(DegradeSpec::with_fallback(BnnModel::random("traffic-lite", 256, &[8, 2], 43)))
        .build()
        .unwrap()
        .run(events.iter().cloned())
        .unwrap();

    let tl = &rep.degradation;
    assert!(tl.len() >= 2, "expected at least one step-down and one step-up: {tl:?}");
    assert_eq!(
        (tl[0].from, tl[0].to),
        (ServiceLevel::Full, ServiceLevel::Fallback),
        "the first rung under pressure is the fallback model: {tl:?}"
    );
    assert_eq!(tl.last().unwrap().to, ServiceLevel::Full, "{tl:?}");

    // publish(v1) + at least one fallback swap + one rollback — the
    // registry stays monotone, rollback republishes as a new version.
    let cur = reg.current("traffic").unwrap();
    assert!(cur.version() >= 3, "got v{}", cur.version());

    // The rolled-back slot classifies exactly like the original model.
    let mut restored = BackendFactory::registry(&reg, &names, 50_000.0, 1).unwrap();
    let pristine = RegistryHandle::new();
    pristine.publish("traffic", &full).unwrap();
    let mut reference = BackendFactory::registry(&pristine, &names, 50_000.0, 1).unwrap();
    for i in 0..32u32 {
        let x: Vec<u32> =
            (0..8).map(|w| i.wrapping_mul(2_654_435_761).wrapping_add(w * 97)).collect();
        assert_eq!(restored.classify(0, &x).0, reference.classify(0, &x).0, "input {i}");
    }
}

// ---------------------------------------------------------------------------
// Pipelined runtime: queue collapse without shedding, bounded with it.
// ---------------------------------------------------------------------------

/// Backend that really sleeps per inference — the pipelined collapse
/// test needs wall-clock contention on the bounded channels, not just
/// modeled cost (which it also advertises, for the admission math).
struct SleepyPlane {
    sleep: Duration,
}

impl InferencePlane for SleepyPlane {
    fn capabilities(&self) -> Capabilities {
        Capabilities::single("sleepy", 50_000.0)
    }

    fn classify(&mut self, _route: usize, x: &[u32]) -> (usize, Option<VersionTag>) {
        std::thread::sleep(self.sleep);
        ((x.first().copied().unwrap_or(0) & 1) as usize, None)
    }

    fn try_run_batch(
        &mut self,
        route: usize,
        inputs: &[Vec<u32>],
        classes: &mut Vec<usize>,
    ) -> Result<Option<VersionTag>, EngineError> {
        classes.clear();
        for x in inputs {
            let (c, _) = self.classify(route, x);
            classes.push(c);
        }
        Ok(None)
    }

    fn n_classes(&self) -> usize {
        2
    }
}

fn sleepy(shed: bool) -> ServeBuilder {
    let mut b = ServeBuilder::new()
        .backend(Box::new(SleepyPlane { sleep: Duration::from_micros(200) }))
        .trigger(TriggerCondition::EveryNPackets(2))
        .output(OutputSelector::Memory)
        .pipeline(4)
        .queue_depth(1);
    if shed {
        b = b
            .shed(ShedPolicy::new(200_000.0, 50_000.0))
            .degrade(DegradeSpec::trigger_only());
    }
    b
}

#[test]
fn without_shedding_the_pipeline_collapses_into_blocked_sends() {
    // 600 flows fire their trigger within the first few thousand
    // packets; at 200 µs per inference the inference stage cannot keep
    // up and the depth-1 parse→inference channel backs up into the
    // parse workers.
    let events = PacketEvent::cbr_burst(CbrSpec { gbps: 40.0, pkt_size: 256 }, 600, 13, 45_000);
    let blocked = |r: &ServiceReport| r.stats.stage_blocked.iter().sum::<u64>();

    let collapsed = sleepy(false).build().unwrap().run(events.iter().cloned()).unwrap();
    assert_eq!(collapsed.stats.sheds, 0);
    assert!(
        blocked(&collapsed) > collapsed.stats.triggers / 2,
        "unshed run must spend its time blocked on full queues: {} blocked of {} triggers",
        blocked(&collapsed),
        collapsed.stats.triggers
    );

    let shed = sleepy(true).build().unwrap().run(events.iter().cloned()).unwrap();
    assert!(shed.stats.sheds > 0);
    assert_eq!(shed.stats.triggers, collapsed.stats.triggers, "triggering is load-independent");
    assert!(
        blocked(&shed) * 4 < blocked(&collapsed),
        "admission must shed before backpressure stalls forwarding: {} vs {}",
        blocked(&shed),
        blocked(&collapsed)
    );
    assert!(
        shed.degradation.iter().any(DegradationEvent::is_step_down),
        "sustained pressure must step the ladder down: {:?}",
        shed.degradation
    );
}

// ---------------------------------------------------------------------------
// No-op policies: reports stay bit-identical when nothing fires.
// ---------------------------------------------------------------------------

#[test]
fn unfired_policies_leave_reports_bit_identical() {
    let events = PacketEvent::cbr_burst(CbrSpec { gbps: 10.0, pkt_size: 256 }, 200, 21, 20_000);
    for (name, workers) in [("fpga", 0), ("placed", 0), ("fpga", 2)] {
        let run = |policies: bool| {
            let mut b = ServeBuilder::new()
                .backend(BackendFactory::single(name, model()).unwrap())
                .trigger(TriggerCondition::EveryNPackets(2))
                .output(OutputSelector::Memory)
                .batching(8, 1e6);
            if workers > 0 {
                b = b.pipeline(workers).queue_depth(64);
            }
            if policies {
                // Thresholds far above anything this run can reach.
                b = b
                    .shed(ShedPolicy::new(1e15, 1e14))
                    .degrade(DegradeSpec::trigger_only());
            }
            b.build().unwrap().run(events.iter().cloned()).unwrap()
        };
        let plain = run(false);
        let armed = run(true);
        let tag = format!("{name}/{workers} workers");
        assert_eq!(armed.stats.sheds, 0, "{tag}");
        assert!(armed.degradation.is_empty(), "{tag}: {:?}", armed.degradation);
        assert_eq!(armed.stats.packets, plain.stats.packets, "{tag}");
        assert_eq!(armed.stats.triggers, plain.stats.triggers, "{tag}");
        assert_eq!(armed.stats.inferences, plain.stats.inferences, "{tag}");
        assert_eq!(armed.stats.classes, plain.stats.classes, "{tag}");
        let mut want = plain.sink.memory;
        let mut got = armed.sink.memory;
        want.sort_unstable();
        got.sort_unstable();
        assert_eq!(want, got, "{tag}");
    }
}

// ---------------------------------------------------------------------------
// Placement breakers: failover is invisible in the verdict stream.
// ---------------------------------------------------------------------------

/// Member whose batch path always faults — the breaker-bait in front of
/// the healthy fpga member below.
struct FlakyPlane;

impl InferencePlane for FlakyPlane {
    fn capabilities(&self) -> Capabilities {
        // Cheapest modeled cost, so the placer always tries it first.
        Capabilities::single("flaky", 10.0)
    }

    fn classify(&mut self, _route: usize, _x: &[u32]) -> (usize, Option<VersionTag>) {
        unreachable!("the batched service must route through try_run_batch");
    }

    fn try_run_batch(
        &mut self,
        _route: usize,
        _inputs: &[Vec<u32>],
        _classes: &mut Vec<usize>,
    ) -> Result<Option<VersionTag>, EngineError> {
        Err(EngineError::WorkerDied)
    }

    fn n_classes(&self) -> usize {
        2
    }
}

#[test]
fn placed_plane_fails_over_from_a_faulting_member_without_changing_verdicts() {
    let events = PacketEvent::cbr_burst(CbrSpec { gbps: 10.0, pkt_size: 256 }, 100, 31, 8_000);
    let run = |backend: Box<dyn InferencePlane>| {
        ServeBuilder::new()
            .backend(backend)
            .trigger(TriggerCondition::EveryNPackets(2))
            .output(OutputSelector::Memory)
            .batching(4, 1e6)
            .build()
            .unwrap()
            .run(events.iter().cloned())
            .unwrap()
    };

    let placed = PlacedPlane::new(
        vec![Box::new(FlakyPlane), BackendFactory::single("fpga", model()).unwrap()],
        BreakerPolicy { trip_after: 2, cooldown_calls: 4, ..BreakerPolicy::default() },
    )
    .unwrap();
    let rep = run(Box::new(placed));
    let reference = run(BackendFactory::single("fpga", model()).unwrap());

    // Failover must be invisible: the healthy member computes the same
    // Algorithm 1, so verdicts match a plain fpga run exactly.
    assert_eq!(rep.sink.memory, reference.sink.memory);
    assert_eq!(rep.stats.classes, reference.stats.classes);
    assert_eq!(rep.stats.inferences, reference.stats.inferences);

    let health = rep.health.expect("placement planes report member health");
    let flaky = health.iter().find(|h| h.backend == "flaky").unwrap();
    let fpga = health.iter().find(|h| h.backend == "fpga").unwrap();
    assert!(flaky.trips >= 1, "{flaky:?}");
    assert!(flaky.failovers >= 2, "{flaky:?}");
    assert!(flaky.calls >= flaky.failovers, "{flaky:?}");
    assert!(fpga.calls > 0, "{fpga:?}");
    assert_eq!(fpga.trips, 0, "{fpga:?}");
    assert!(!fpga.open, "{fpga:?}");
}

//! Cross-executor differential fuzz (ISSUE 2): every execution path in
//! the crate computes Algorithm 1, so on ~50 random models and random
//! inputs the host single-input executor, the weight-stationary
//! [`BatchKernel`], the multi-core [`ShardedEngine`], the PISA pipeline
//! interpreter, and the FPGA device model must agree **bit for bit** —
//! scores where the path exposes them, argmax verdicts everywhere.
//!
//! Property-style over the crate's deterministic `Rng` (offline build:
//! no proptest), extending `batch_exact.rs` from the batch subsystem to
//! every backend.

use n3ic::bnn::{
    argmax, BatchKernel, BnnExecutor, BnnModel, KernelPath, RegistryHandle, ShardedEngine, TILE,
};
use n3ic::coordinator::{
    BackendFactory, ModelRouter, OutputSelector, PacketEvent, ServeBuilder, TriggerCondition,
};
use n3ic::fpga::FpgaExecutor;
use n3ic::net::flow::ShardedFlowTable;
use n3ic::net::traffic::{CbrSpec, Rng};
use n3ic::pisa::compile_bnn;

const MODELS: u64 = 50;
const INPUTS_PER_MODEL: usize = 8;

/// Random architecture constrained to what *every* backend accepts —
/// PISA is the binding constraint: each layer's parallel lane bits
/// (`neurons × in_words × 32`) must fit the 16384-bit PHV budget.
fn random_shape(rng: &mut Rng) -> (usize, Vec<usize>) {
    let in_bits = 32 + rng.below(225) as usize; // 32..=256, often unpadded
    let in_words = in_bits.div_ceil(32);
    let depth = 1 + rng.below(3) as usize; // 1..=3 layers
    let mut arch = Vec::with_capacity(depth);
    let mut prev_words = in_words;
    for d in 0..depth {
        let lane_cap = 16_384 / (prev_words * 32); // PISA PHV ceiling
        let cap = lane_cap.min(if d + 1 == depth { 9 } else { 48 });
        let n = 1 + rng.below(cap as u64) as usize;
        arch.push(n);
        prev_words = n.div_ceil(32);
    }
    (in_bits, arch)
}

fn random_input(rng: &mut Rng, in_words: usize) -> Vec<u32> {
    (0..in_words).map(|_| rng.next_u64() as u32).collect()
}

#[test]
fn all_five_executor_paths_agree_bit_for_bit() {
    let mut rng = Rng::new(0xD1FF);
    for m in 0..MODELS {
        let (in_bits, arch) = random_shape(&mut rng);
        let model = BnnModel::random(&format!("diff{m}"), in_bits, &arch, 0xBEEF + m);

        // Path 1 (reference): host single-input executor.
        let mut host = BnnExecutor::new(model.clone());
        // Path 2: weight-stationary batch kernel.
        let mut kernel = BatchKernel::new(&model);
        // Path 3: sharded multi-core engine.
        let mut engine = ShardedEngine::new(&model, 3);
        // Path 4: PISA pipeline interpreter (shape chosen to compile).
        let prog = compile_bnn(&model).unwrap_or_else(|e| {
            panic!("diff{m} {in_bits}b {arch:?} must fit PISA: {e}")
        });
        prog.check_stage_hazards().unwrap();
        // Path 5: FPGA device model (functional half).
        let mut fpga = FpgaExecutor::new(model.clone(), 1);

        let inputs: Vec<Vec<u32>> = (0..INPUTS_PER_MODEL)
            .map(|_| random_input(&mut rng, model.in_words()))
            .collect();

        // Reference scores + classes from the host path.
        let mut want_scores = vec![0i32; model.out_neurons()];
        let mut want_classes = Vec::with_capacity(inputs.len());
        let mut flat_scores = Vec::new();
        for x in &inputs {
            host.infer(x, &mut want_scores);
            flat_scores.extend_from_slice(&want_scores);
            want_classes.push(argmax(&want_scores));
        }

        // Batch kernel: classes and raw scores, whole batch at once.
        let (mut k_classes, mut k_scores) = (Vec::new(), Vec::new());
        kernel.run_batch(&inputs, &mut k_classes);
        kernel.infer_batch_scores(&inputs, &mut k_scores);
        assert_eq!(k_classes, want_classes, "diff{m} kernel classes");
        assert_eq!(k_scores, flat_scores, "diff{m} kernel scores");

        // Sharded engine: classes, reassembled in input order.
        let mut e_classes = Vec::new();
        engine.run_batch(&inputs, &mut e_classes);
        assert_eq!(e_classes, want_classes, "diff{m} engine classes");

        // PISA interpreter and FPGA model, input by input.
        let mut f_scores = vec![0i32; model.out_neurons()];
        for (i, x) in inputs.iter().enumerate() {
            let p_scores = prog.run(x);
            assert_eq!(
                p_scores,
                flat_scores[i * model.out_neurons()..(i + 1) * model.out_neurons()],
                "diff{m} input {i} pisa scores"
            );
            assert_eq!(argmax(&p_scores), want_classes[i], "diff{m} input {i} pisa class");
            fpga.infer(x, &mut f_scores);
            assert_eq!(
                f_scores,
                &flat_scores[i * model.out_neurons()..(i + 1) * model.out_neurons()],
                "diff{m} input {i} fpga scores"
            );
            assert_eq!(fpga.classify(x), want_classes[i], "diff{m} input {i} fpga class");
        }
    }
}

/// ISSUE 4 satellite: fuzz the registry *route*.  N random models under
/// random names, traffic hash-split across them — the routed service's
/// verdicts must be bit-identical to running each model standalone on
/// exactly its flow subset (the subset `ShardedFlowTable::shard_of`
/// carves out, which is also how the router splits).
#[test]
fn registry_route_matches_standalone_per_flow_subset() {
    const N_MODELS: usize = 6;
    const PACKETS: usize = 20_000;
    let mut rng = Rng::new(0xA11C_E000);

    // Random names (unique by construction: an index plus random hex).
    let names: Vec<String> = (0..N_MODELS)
        .map(|i| format!("m{i}-{:04x}", rng.next_u64() & 0xFFFF))
        .collect();
    let models: Vec<BnnModel> = names
        .iter()
        .map(|n| BnnModel::random(n, 256, &[32, 16, 2], rng.next_u64()))
        .collect();
    let registry = RegistryHandle::new();
    for (n, m) in names.iter().zip(&models) {
        registry.publish(n, m).unwrap();
    }

    let trigger = TriggerCondition::EveryNPackets(5);
    let router = ModelRouter::hash_split(trigger, names.clone());
    let events: Vec<PacketEvent> = PacketEvent::cbr_burst(
        CbrSpec { gbps: 40.0, pkt_size: 256 },
        300,
        0xBEE5,
        PACKETS,
    );

    // Routed run — batched + sharded, the most machinery at once.
    let routed = ServeBuilder::new()
        .backend(BackendFactory::registry(&registry, &names, 100.0, 3).unwrap())
        .router(router)
        .output(OutputSelector::Memory)
        .batching(8, 1e12)
        .build()
        .unwrap()
        .run(events.iter().cloned())
        .unwrap();
    assert_eq!(routed.stats.triggers, routed.stats.inferences);

    // Standalone reference: model i over only its hash subset.
    let mut total_standalone = 0u64;
    for (i, (name, model)) in names.iter().zip(&models).enumerate() {
        let rep = ServeBuilder::new()
            .backend(BackendFactory::single("fpga", model.clone()).unwrap())
            .trigger(trigger)
            .output(OutputSelector::Memory)
            .build()
            .unwrap()
            .run(
                events
                    .iter()
                    .filter(|ev| ShardedFlowTable::shard_of(&ev.packet, N_MODELS) == i)
                    .cloned(),
            )
            .unwrap();
        total_standalone += rep.stats.inferences;

        // Per-model verdicts: bit-identical multiset of (flow, class).
        let mut want = rep.sink.memory.clone();
        want.sort_unstable();
        let mut got: Vec<(u64, usize)> = routed
            .tagged
            .iter()
            .filter(|t| t.tag.name() == name)
            .map(|t| (t.id, t.class))
            .collect();
        got.sort_unstable();
        assert_eq!(got, want, "model {name} (route {i})");

        // And the per-model histogram matches the standalone one.
        let pm = &routed.stats.per_model[name];
        assert_eq!(pm.inferences, rep.stats.inferences, "model {name}");
        let mut padded = pm.classes.clone();
        if padded.len() < rep.stats.classes.len() {
            padded.resize(rep.stats.classes.len(), 0);
        }
        assert_eq!(padded, rep.stats.classes, "model {name}");
        // Nothing was republished: v1 everywhere, zero swaps.
        assert_eq!(pm.swaps, 0);
    }
    assert_eq!(total_standalone, routed.stats.inferences);
    assert!(
        routed.tagged.iter().all(|t| t.tag.version() == 1),
        "no publish happened, every tag must be v1"
    );
    // The hash split actually used several models (not all flows on one).
    let active = routed
        .stats
        .per_model
        .values()
        .filter(|m| m.inferences > 0)
        .count();
    assert!(active >= 3, "only {active} of {N_MODELS} models saw traffic");
}

/// ISSUE 9 satellite: the SIMD-vs-scalar shape fuzzer.  Unlike the
/// five-path fuzz above, these shapes are *not* clamped to the PISA PHV
/// budget — the kernel paths must agree on every width, so the grid
/// deliberately hits 1-bit inputs, lane-multiple ± 1 widths, ragged
/// qword pairings, and batch sizes straddling the tile boundary.  All
/// three [`KernelPath`]s (and the single-input executor) must agree bit
/// for bit on classes *and* raw scores; without `--features simd` (or
/// AVX2) every path resolves scalar and the test still pins the
/// kernel-vs-executor contract.
#[test]
fn simd_scalar_and_single_input_executor_agree_across_fuzzed_shapes() {
    const FUZZ_MODELS: u64 = 40;
    // Widths the tile/lane math is most likely to get wrong: around one
    // word (17, 64), one odd-word qword pad (96), one qword + 1 (129) —
    // then random, including non-multiples of 32 and 64.
    const PINNED_BITS: [usize; 4] = [17, 64, 96, 129];
    let batches = [1usize, TILE - 1, TILE, TILE + 1, 3 * TILE + 5];
    let mut rng = Rng::new(0x51D0);
    for m in 0..FUZZ_MODELS {
        let in_bits = PINNED_BITS
            .get(m as usize)
            .copied()
            .unwrap_or_else(|| 1 + rng.below(300) as usize);
        let depth = 1 + rng.below(3) as usize;
        let arch: Vec<usize> = (0..depth).map(|_| 1 + rng.below(70) as usize).collect();
        let model = BnnModel::random(&format!("simd{m}"), in_bits, &arch, 0x51D0 + m);

        let mut host = BnnExecutor::new(model.clone());
        let mut scalar = BatchKernel::new_with_path(&model, KernelPath::Scalar);
        let mut auto = BatchKernel::new_with_path(&model, KernelPath::Auto);
        let mut forced = BatchKernel::new_with_path(&model, KernelPath::Simd);

        let max_batch = *batches.iter().max().unwrap();
        let inputs: Vec<Vec<u32>> = (0..max_batch)
            .map(|_| random_input(&mut rng, model.in_words()))
            .collect();

        // Reference scores + classes from the single-input executor.
        let mut buf = vec![0i32; model.out_neurons()];
        let mut ref_scores = Vec::new();
        let mut ref_classes = Vec::new();
        for x in &inputs {
            host.infer(x, &mut buf);
            ref_scores.extend_from_slice(&buf);
            ref_classes.push(argmax(&buf));
        }

        for &b in &batches {
            let slice = &inputs[..b];
            let want_scores = &ref_scores[..b * model.out_neurons()];
            let want_classes = &ref_classes[..b];
            for (tag, kernel) in [
                ("scalar", &mut scalar),
                ("auto", &mut auto),
                ("simd", &mut forced),
            ] {
                let (mut classes, mut scores) = (Vec::new(), Vec::new());
                kernel.run_batch(slice, &mut classes);
                kernel.infer_batch_scores(slice, &mut scores);
                assert_eq!(classes, want_classes, "simd{m} {tag} b={b} classes");
                assert_eq!(scores, want_scores, "simd{m} {tag} b={b} scores");
            }
        }
    }
}

#[test]
fn shape_generator_covers_the_corner_cases() {
    // The fuzz above is only as good as its generator: over the 50
    // shapes it must hit odd word counts, single-layer models, depth-3
    // models, and multi-class (>2) outputs.
    let mut rng = Rng::new(0xD1FF);
    let (mut odd_bits, mut single, mut deep, mut multiclass) = (0, 0, 0, 0);
    for _ in 0..MODELS {
        let (in_bits, arch) = random_shape(&mut rng);
        if in_bits % 32 != 0 {
            odd_bits += 1;
        }
        if arch.len() == 1 {
            single += 1;
        }
        if arch.len() == 3 {
            deep += 1;
        }
        if *arch.last().unwrap() > 2 {
            multiclass += 1;
        }
        // Keep the generator honest about the PISA budget.
        let mut prev_words = in_bits.div_ceil(32);
        for &n in &arch {
            assert!(n * prev_words * 32 <= 16_384, "{in_bits}b {arch:?}");
            prev_words = n.div_ceil(32);
        }
        // Burn the same draws the fuzz test burns so both walks see the
        // same shape sequence.
        for _ in 0..INPUTS_PER_MODEL {
            random_input(&mut rng, in_bits.div_ceil(32));
        }
    }
    assert!(odd_bits > 5, "odd in_bits: {odd_bits}");
    assert!(single > 0, "single-layer models: {single}");
    assert!(deep > 0, "depth-3 models: {deep}");
    assert!(multiclass > 5, "multi-class models: {multiclass}");
}

//! ISSUE 5 acceptance: the backend conformance suite.
//!
//! One shared seeded scenario matrix — single (serial inline), batched,
//! pipelined, and hot-swap where [`Capabilities`] allow — runs over
//! **every** backend registered in the [`BackendFactory`], and every
//! cell must produce a verdict history bit-identical to the host
//! reference: same trigger count, same inference count, same verdict
//! histogram, same per-flow verdict multiset.
//!
//! This folds the cross-executor differential checks in as one lens:
//! every backend (the registered names plus the `nfp` CLI alias)
//! produces the paper's Algorithm 1 verdicts — the BNN planes compute
//! it directly, the `qmlp` plane through its verdict-preserving
//! quantization — so any divergence anywhere in the matrix is a real
//! defect (a torn swap, a mis-sharded batch, a broken interpreter, a
//! rounding bug), never an "expected backend quirk".

use n3ic::bnn::{infer_packed, BnnLayer, BnnModel, RegistryHandle};
use n3ic::coordinator::{
    BackendFactory, Capabilities, InferencePlane, OutputSelector, PacketEvent, ServeBuilder,
    TriggerCondition,
};
use n3ic::net::traffic::CbrSpec;

/// Shared seeded scenario: 20k packets over 300 flows (seed 42), flows
/// trigger at their 10th packet — trigger times span packets ~787–6475,
/// so the hot-swap scenario's republish cadence (every 2000 packets)
/// interleaves with live triggers.
const PACKETS: usize = 20_000;
const FLOWS: u64 = 300;
const SEED: u64 = 42;
const SWAP_EVERY: u64 = 2000;

fn model() -> BnnModel {
    // Fits every backend, including the PISA PHV budget.
    BnnModel::random("traffic", 256, &[32, 16, 2], 42)
}

fn events() -> Vec<PacketEvent> {
    PacketEvent::cbr_burst(CbrSpec { gbps: 40.0, pkt_size: 256 }, FLOWS, SEED, PACKETS)
}

fn registry() -> RegistryHandle {
    let h = RegistryHandle::new();
    h.publish("traffic", &model()).unwrap();
    h
}

/// Every factory name the suite sweeps: the registered backends plus
/// the `nfp` CLI alias (a distinct latency model over the shared
/// kernel — it must conform like everything else).
fn all_backends() -> Vec<&'static str> {
    let mut names = BackendFactory::BACKENDS.to_vec();
    names.push("nfp");
    names
}

/// A fresh plane for `name` (planes are consumed by each service run).
fn plane(name: &str, registry: &RegistryHandle) -> Box<dyn InferencePlane> {
    match name {
        "registry" => {
            BackendFactory::registry(registry, &["traffic".to_string()], 100.0, 2).unwrap()
        }
        "sharded" => BackendFactory::single_sharded(name, model(), 3).unwrap(),
        _ => BackendFactory::single(name, model()).unwrap(),
    }
}

/// The fields the conformance contract covers (latency histograms are
/// modeled per backend and deliberately excluded).
#[derive(Debug, PartialEq)]
struct Outcome {
    triggers: u64,
    inferences: u64,
    classes: Vec<u64>,
    sink: Vec<(u64, usize)>,
}

fn run_scenario(
    plane: Box<dyn InferencePlane>,
    workers: usize,
    batch: usize,
    swap_every: u64,
) -> (Outcome, Vec<u64>) {
    let mut b = ServeBuilder::new()
        .backend(plane)
        .trigger(TriggerCondition::EveryNPackets(10))
        .output(OutputSelector::Memory)
        .pipeline(workers);
    if swap_every > 0 && workers > 0 {
        // Bound the ingress thread's lookahead so republishes (done at
        // ingress) deterministically interleave with classification:
        // early triggers must pin v1, late ones a post-swap version.
        b = b.queue_depth(4);
    }
    if batch > 0 {
        b = b.batching(batch, 1e6);
    }
    if swap_every > 0 {
        b = b.swap_every(swap_every);
    }
    let rep = b.build().unwrap().run(events()).unwrap();
    let mut sink = rep.sink.memory.clone();
    sink.sort_unstable();
    let versions: Vec<u64> = rep.tagged.iter().map(|t| t.tag.version()).collect();
    (
        Outcome {
            triggers: rep.stats.triggers,
            inferences: rep.stats.inferences,
            classes: rep.stats.classes,
            sink,
        },
        versions,
    )
}

#[test]
fn every_backend_matches_the_host_reference_across_the_scenario_matrix() {
    let reg = registry();
    let (reference, _) = run_scenario(plane("host", &reg), 0, 0, 0);
    assert!(reference.triggers > 0, "scenario must actually trigger");
    assert_eq!(reference.triggers, reference.inferences);

    for name in all_backends() {
        let caps: Capabilities = plane(name, &reg).capabilities();
        // Scenario 1: serial inline.
        let (single, _) = run_scenario(plane(name, &reg), 0, 0, 0);
        assert_eq!(single, reference, "{name} / serial inline");
        // Scenario 2: serial batched, clamped to the backend's width
        // (capability-driven: pisa batches at most 1 — i.e. inline
        // through the batch lanes).
        let batch = 7.min(caps.max_batch);
        let (batched, _) = run_scenario(plane(name, &reg), 0, batch, 0);
        assert_eq!(batched, reference, "{name} / serial batched({batch})");
        // Scenario 3: staged pipeline.
        let batch = 8.min(caps.max_batch);
        let (staged, _) = run_scenario(plane(name, &reg), 3, batch, 0);
        assert_eq!(staged, reference, "{name} / pipelined batched({batch})");
    }
}

#[test]
fn hot_swap_scenario_keeps_verdicts_identical_while_versions_move() {
    let reg = registry();
    let (reference, _) = run_scenario(plane("host", &reg), 0, 0, 0);
    for name in all_backends() {
        let caps = plane(name, &reg).capabilities();
        if !caps.supports_hot_swap {
            continue;
        }
        let (swapped, versions) = run_scenario(plane(name, &reg), 2, 8, SWAP_EVERY);
        // Same weights republished ⇒ bit-identical verdicts...
        assert_eq!(swapped, reference, "{name} / hot-swap");
        // ...with the swap machinery demonstrably live: verdict tags
        // straddle the republishes.
        assert_eq!(versions.len() as u64, swapped.inferences);
        let base = versions.iter().min().copied().unwrap();
        let top = versions.iter().max().copied().unwrap();
        assert!(top > base, "{name}: no verdict observed a hot swap");
    }
    // The registry slot absorbed the swaps this test drove.
    assert!(reg.swap_count("traffic") > 0);
}

#[test]
fn epoch_pinning_backends_tag_every_verdict_and_others_tag_none() {
    let reg = registry();
    for name in all_backends() {
        let caps = plane(name, &reg).capabilities();
        let (outcome, versions) = run_scenario(plane(name, &reg), 0, 0, 0);
        if caps.supports_epoch_pinning {
            assert_eq!(versions.len() as u64, outcome.inferences, "{name}");
        } else {
            assert!(versions.is_empty(), "{name} must not invent tags");
        }
    }
}

/// The differential lens at the plane level: classify and run_batch on
/// every backend agree with the functional reference, input by input.
#[test]
fn plane_calls_agree_with_functional_reference() {
    let m = model();
    let xs: Vec<Vec<u32>> = (0..13)
        .map(|i| BnnLayer::random(1, 256, 9_000 + i).words)
        .collect();
    let want: Vec<usize> = xs.iter().map(|x| infer_packed(&m, x)).collect();
    let reg = registry();
    for name in all_backends() {
        let mut p = plane(name, &reg);
        let caps = p.capabilities();
        for (x, &w) in xs.iter().zip(&want) {
            assert_eq!(p.classify(0, x).0, w, "{name}");
        }
        let mut classes = Vec::new();
        if caps.max_batch >= xs.len() {
            p.run_batch(0, &xs, &mut classes);
            assert_eq!(classes, want, "{name} batch");
        } else {
            // Capability-clamped backends still serve the batch API one
            // input at a time.
            for (x, &w) in xs.iter().zip(&want) {
                p.run_batch(0, std::slice::from_ref(x), &mut classes);
                assert_eq!(classes, vec![w], "{name} batch-of-1");
            }
        }
    }
}

/// The capability table the redesign promises (README §Architecture).
#[test]
fn capability_table_matches_the_documented_contract() {
    let reg = registry();
    let rows: Vec<Capabilities> = BackendFactory::BACKENDS
        .iter()
        .map(|n| plane(n, &reg).capabilities())
        .collect();
    for (name, caps) in BackendFactory::BACKENDS.iter().zip(&rows) {
        assert_eq!(&caps.backend, name);
        assert!(caps.inference_ns > 0.0, "{name}");
        assert_eq!(caps.routes, 1, "{name}: one bound model in this suite");
    }
    let by_name = |n: &str| {
        let i = BackendFactory::BACKENDS.iter().position(|b| *b == n).unwrap();
        rows[i].clone()
    };
    assert_eq!(by_name("pisa").max_batch, 1);
    assert!(by_name("sharded").shards >= 2);
    assert!(by_name("registry").supports_hot_swap);
    assert!(by_name("registry").supports_epoch_pinning);
    for n in ["host", "batch", "sharded", "pisa", "fpga", "qmlp"] {
        assert!(!by_name(n).supports_hot_swap, "{n}");
        assert!(!by_name(n).supports_epoch_pinning, "{n}");
    }
    // The quantized-MLP plane scores serially but accepts any batch
    // width, and never shards.
    assert_eq!(by_name("qmlp").max_batch, usize::MAX);
    assert_eq!(by_name("qmlp").shards, 1);
    // Every row reports a kernel lane width.
    for (name, caps) in BackendFactory::BACKENDS.iter().zip(&rows) {
        assert!(caps.simd_lanes == 1 || caps.simd_lanes == 4, "{name}");
    }
}

/// ISSUE 9 satellite: the vector and scalar kernels must be
/// indistinguishable at the far end of the system — identical verdict
/// digests and floor outcomes on all three paper scenarios.  On builds
/// without `--features simd` (or without AVX2) both runs take the scalar
/// path and the equality is trivially green, which is exactly the
/// both-feature-sets contract `scripts/verify.sh` drives.
#[test]
fn simd_and_scalar_kernels_produce_identical_scenario_digests() {
    use n3ic::bnn::simd;
    use n3ic::scenario::{ScenarioConfig, ScenarioRegistry};

    let registry = ScenarioRegistry::standard();
    for name in registry.names() {
        let events = if name == "tomography" { 120 } else { 6_000 };
        let cfg = ScenarioConfig {
            events,
            backend: "batch".into(),
            batch: 8,
            ..ScenarioConfig::default()
        };
        let auto = registry.run(name, &cfg).unwrap();
        simd::force_scalar(true);
        let scalar = registry.run(name, &cfg).unwrap();
        simd::force_scalar(false);
        assert_eq!(auto.digest(), scalar.digest(), "{name}: path changed verdicts");
        assert_eq!(auto.passes_floor(), scalar.passes_floor(), "{name}");
        assert_eq!(auto.score.scored, scalar.score.scored, "{name}");
    }
}

/// ISSUE 9 acceptance: the quantized-MLP backend is scored by the
/// scenario suite and clears the floor — and because `from_bnn` is
/// verdict-preserving, its digest matches the reference backend's run
/// of the same seeded scenario exactly.
#[test]
fn qmlp_backend_clears_the_traffic_scenario_floor() {
    use n3ic::scenario::{ScenarioConfig, ScenarioRegistry};

    let registry = ScenarioRegistry::standard();
    let cfg = |backend: &str| ScenarioConfig {
        events: 8_000,
        backend: backend.into(),
        ..ScenarioConfig::default()
    };
    let qmlp = registry.run("traffic", &cfg("qmlp")).unwrap();
    assert!(qmlp.passes_floor(), "qmlp accuracy {}", qmlp.score.accuracy);
    assert!(qmlp.score.scored > 0);
    let reference = registry.run("traffic", &cfg("fpga")).unwrap();
    assert_eq!(qmlp.digest(), reference.digest(), "quantization changed a verdict");
    assert_eq!(qmlp.score.accuracy, reference.score.accuracy);
}

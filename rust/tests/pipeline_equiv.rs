//! ISSUE 2 acceptance (re-anchored on the unified API): the pipelined
//! mode of the one `Service` is a *refactoring* of the serial mode, not
//! a new behaviour — on the same seeded traffic it must produce
//! bit-identical verdict histograms, trigger counts, inference counts,
//! and per-flow verdicts, for every worker count, queue depth, and
//! batch size.  Latency histograms are exempt (queueing time differs by
//! construction).

use n3ic::bnn::BnnModel;
use n3ic::coordinator::{
    BackendFactory, OutputSelector, PacketEvent, ServeBuilder, TriggerCondition, STAGE_LINKS,
};
use n3ic::net::traffic::CbrSpec;

fn traffic_events(packets: usize, flows: u64, seed: u64) -> Vec<PacketEvent> {
    PacketEvent::cbr_burst(CbrSpec { gbps: 40.0, pkt_size: 256 }, flows, seed, packets)
}

fn model() -> BnnModel {
    BnnModel::random("traffic", 256, &[32, 16, 2], 1)
}

type Fingerprint = (u64, u64, u64, Vec<u64>, Vec<(u64, usize)>, usize);

/// One service run (serial when `workers == 0`); returns the fields the
/// determinism contract covers, with the sink sorted into a multiset.
fn run(
    events: &[PacketEvent],
    trigger: TriggerCondition,
    workers: usize,
    batch: usize,
    queue_depth: usize,
) -> Fingerprint {
    let mut b = ServeBuilder::new()
        .backend(BackendFactory::single("fpga", model()).unwrap())
        .trigger(trigger)
        .output(OutputSelector::Memory)
        .pipeline(workers)
        .queue_depth(queue_depth);
    if batch > 0 {
        b = b.batching(batch, 1e6);
    }
    let rep = b
        .build()
        .unwrap()
        .run(events.iter().cloned())
        .expect("healthy run");
    if workers > 0 {
        assert_eq!(rep.stats.stage_blocked.len(), STAGE_LINKS.len());
    }
    let mut mem = rep.sink.memory.clone();
    mem.sort_unstable();
    (
        rep.stats.packets,
        rep.stats.triggers,
        rep.stats.inferences,
        rep.stats.classes.clone(),
        mem,
        rep.flows_tracked,
    )
}

fn serial(events: &[PacketEvent], trigger: TriggerCondition, batch: usize) -> Fingerprint {
    run(events, trigger, 0, batch, 1024)
}

#[test]
fn pipeline_matches_serial_across_workers_and_batches() {
    let events = traffic_events(30_000, 300, 42);
    let trigger = TriggerCondition::EveryNPackets(10);
    let want = serial(&events, trigger, 0);
    assert!(want.1 > 0, "traffic must actually trigger");
    for workers in [1usize, 2, 4] {
        for batch in [0usize, 7, 64] {
            let got = run(&events, trigger, workers, batch, 1024);
            assert_eq!(got, want, "workers={workers} batch={batch}");
        }
    }
}

#[test]
fn pipeline_matches_serial_with_batched_serial_reference() {
    // The serial mode's own batched route and the pipelined batched
    // route agree too — all four corners of the matrix are one verdict
    // multiset.
    let events = traffic_events(20_000, 150, 7);
    let trigger = TriggerCondition::EveryNPackets(10);
    let serial_inline = serial(&events, trigger, 0);
    let serial_batched = serial(&events, trigger, 32);
    assert_eq!(serial_inline, serial_batched);
    let piped = run(&events, trigger, 3, 32, 1024);
    assert_eq!(piped, serial_inline);
}

#[test]
fn pipeline_matches_serial_under_every_trigger_kind() {
    let events = traffic_events(8_000, 60, 11);
    for trigger in [
        TriggerCondition::NewFlow,
        TriggerCondition::EveryNPackets(5),
        TriggerCondition::DstPort(443),
    ] {
        let want = serial(&events, trigger, 0);
        let got = run(&events, trigger, 4, 0, 1024);
        assert_eq!(got, want, "{trigger:?}");
    }
}

#[test]
fn pipeline_matches_serial_under_starved_queues() {
    // queue_depth = 1 maximizes backpressure and reordering pressure —
    // the contract must hold regardless.
    let events = traffic_events(10_000, 100, 99);
    let trigger = TriggerCondition::EveryNPackets(10);
    let want = serial(&events, trigger, 0);
    let got = run(&events, trigger, 2, 0, 1);
    assert_eq!(got, want);
}

#[test]
fn pipeline_replays_are_bit_identical_to_each_other() {
    // Same seed, two pipelined runs: thread scheduling may differ, the
    // observable results may not.
    let events = traffic_events(12_000, 80, 5);
    let trigger = TriggerCondition::EveryNPackets(10);
    let a = run(&events, trigger, 4, 16, 1024);
    let b = run(&events, trigger, 4, 16, 1024);
    assert_eq!(a, b);
}
